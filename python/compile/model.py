"""L2: CNN forward pass in JAX, convolutions via the L1 Pallas GEMM kernel.

Mirrors the ARM-CL structure the paper models: each *major layer* (conv or
fully-connected node, Table I) is im2col + GEMM (+ bias/ReLU epilogue and any
trailing pool, which the paper folds into the preceding major layer). Each
major layer is lowered to its own HLO module by ``aot.py`` so the Rust
coordinator can place layers on pipeline stages independently (layer-level
splitting); the whole network is additionally lowered as one module for the
kernel-level baseline.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from compile.kernels import gemm_pallas


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One major layer (ARM-CL node) descriptor — the paper's Fig. 10 view."""

    name: str
    kind: str  # "conv" | "fc"
    fh: int = 1
    fw: int = 1
    cin: int = 1
    cout: int = 1
    stride: int = 1
    pad: int = 0
    relu: bool = True
    pool: str | None = None  # None | "max2" (2x2/s2 max) | "gap" (global avg)

    def out_hw(self, ih: int, iw: int) -> tuple[int, int]:
        """Paper Eq. (3): O = floor((I - F + 2*Pad)/S) + 1 (then pool)."""
        oh = (ih - self.fh + 2 * self.pad) // self.stride + 1
        ow = (iw - self.fw + 2 * self.pad) // self.stride + 1
        if self.pool == "max2":
            oh, ow = oh // 2, ow // 2
        return oh, ow

    def gemm_dims(self, ih: int, iw: int) -> tuple[int, int, int]:
        """Paper Eq. (4): N = Ow*Oh, K = Fw*Fh*Fd, M = Ofm (pre-pool dims)."""
        if self.kind == "fc":
            return 1, self.cin, self.cout
        oh = (ih - self.fh + 2 * self.pad) // self.stride + 1
        ow = (iw - self.fw + 2 * self.pad) // self.stride + 1
        return oh * ow, self.fh * self.fw * self.cin, self.cout


@dataclasses.dataclass(frozen=True)
class NetworkSpec:
    name: str
    input_hw: tuple[int, int]
    input_c: int
    layers: tuple[LayerSpec, ...]

    def shapes(self) -> list[tuple[tuple[int, ...], tuple[int, ...]]]:
        """(input_shape, output_shape) per layer, threading Eq. (3) through."""
        out: list[tuple[tuple[int, ...], tuple[int, ...]]] = []
        h, w = self.input_hw
        c = self.input_c
        shape: tuple[int, ...] = (h, w, c)
        for l in self.layers:
            in_shape = shape
            if l.kind == "fc":
                shape = (l.cout,)
            else:
                oh, ow = l.out_hw(in_shape[0], in_shape[1])
                shape = (l.cout,) if l.pool == "gap" else (oh, ow, l.cout)
            out.append((in_shape, shape))
        return out


def im2col(x: jax.Array, fh: int, fw: int, *, stride: int, pad: int) -> jax.Array:
    """Vectorized im2col: (H,W,C) -> (Oh*Ow, Fh*Fw*C), ARM-CL's Im2Col kernel.

    Column layout is (fh, fw, c) row-major, matching ``ref.ref_im2col`` and a
    (Fh,Fw,Cin,Cout) filter reshaped to (Fh*Fw*Cin, Cout).
    """
    h, w, c = x.shape
    xp = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    oh = (h - fh + 2 * pad) // stride + 1
    ow = (w - fw + 2 * pad) // stride + 1
    i0 = jnp.arange(oh) * stride
    j0 = jnp.arange(ow) * stride
    di = jnp.arange(fh)
    dj = jnp.arange(fw)
    # (oh, ow, fh, fw, c) gather, then flatten patches to rows.
    patches = xp[
        (i0[:, None, None, None] + di[None, None, :, None])[..., None],
        (j0[None, :, None, None] + dj[None, None, None, :])[..., None],
        jnp.arange(c)[None, None, None, None, :],
    ]
    return patches.reshape(oh * ow, fh * fw * c)


def init_layer_params(key: jax.Array, spec: LayerSpec) -> dict[str, jax.Array]:
    """He-init weights + zero bias. Weight layout: (Fh*Fw*Cin, Cout) GEMM-ready."""
    k = spec.fh * spec.fw * spec.cin
    scale = jnp.sqrt(2.0 / k)
    w = scale * jax.random.normal(key, (k, spec.cout), dtype=jnp.float32)
    b = jnp.zeros((spec.cout,), dtype=jnp.float32)
    return {"w": w, "b": b}


def init_network_params(net: NetworkSpec, seed: int = 0) -> list[dict[str, jax.Array]]:
    keys = jax.random.split(jax.random.PRNGKey(seed), len(net.layers))
    return [init_layer_params(k, l) for k, l in zip(keys, net.layers)]


def apply_layer(
    x: jax.Array, params: dict[str, jax.Array], spec: LayerSpec
) -> jax.Array:
    """One major layer: im2col -> Pallas GEMM -> bias/ReLU -> optional pool."""
    if spec.kind == "fc":
        y = gemm_pallas.matmul(x.reshape(1, -1), params["w"])
        y = gemm_pallas.bias_act(y, params["b"], relu=spec.relu)
        return y.reshape(-1)

    h, w, _ = x.shape
    cols = im2col(x, spec.fh, spec.fw, stride=spec.stride, pad=spec.pad)
    y = gemm_pallas.matmul(cols, params["w"])  # (Oh*Ow, Cout)
    y = gemm_pallas.bias_act(y, params["b"], relu=spec.relu)
    oh = (h - spec.fh + 2 * spec.pad) // spec.stride + 1
    ow = (w - spec.fw + 2 * spec.pad) // spec.stride + 1
    y = y.reshape(oh, ow, spec.cout)
    if spec.pool == "max2":
        y = jnp.max(y.reshape(oh // 2, 2, ow // 2, 2, spec.cout), axis=(1, 3))
    elif spec.pool == "gap":
        y = jnp.mean(y, axis=(0, 1))
    return y


def network_fn(
    net: NetworkSpec, params: list[dict[str, jax.Array]]
) -> Callable[[jax.Array], jax.Array]:
    """Whole-network forward pass (kernel-level baseline path)."""

    def fwd(x: jax.Array) -> jax.Array:
        for p, spec in zip(params, net.layers):
            x = apply_layer(x, p, spec)
        return x

    return fwd


# --------------------------------------------------------------------------
# Network zoo. PipeNet-Micro is the fast-test net; PipeNet-Tiny is the
# end-to-end serving model (a scaled-down VGG/MobileNet-style stack whose
# front-heavy per-layer cost profile mirrors the paper's Fig. 7).
# --------------------------------------------------------------------------

PIPENET_MICRO = NetworkSpec(
    name="pipenet_micro",
    input_hw=(16, 16),
    input_c=3,
    layers=(
        LayerSpec("conv1", "conv", 3, 3, 3, 8, 1, 1),
        LayerSpec("conv2", "conv", 3, 3, 8, 8, 1, 1, pool="max2"),
        LayerSpec("conv3", "conv", 3, 3, 8, 16, 1, 1, pool="gap"),
        LayerSpec("fc", "fc", cin=16, cout=10, relu=False),
    ),
)

PIPENET_TINY = NetworkSpec(
    name="pipenet_tiny",
    input_hw=(32, 32),
    input_c=3,
    layers=(
        LayerSpec("conv1", "conv", 3, 3, 3, 16, 1, 1),
        LayerSpec("conv2", "conv", 3, 3, 16, 16, 1, 1, pool="max2"),
        LayerSpec("conv3", "conv", 3, 3, 16, 32, 1, 1),
        LayerSpec("conv4", "conv", 3, 3, 32, 32, 1, 1, pool="max2"),
        LayerSpec("conv5", "conv", 3, 3, 32, 64, 1, 1),
        LayerSpec("conv6", "conv", 3, 3, 64, 64, 1, 1),
        LayerSpec("conv7", "conv", 3, 3, 64, 96, 2, 1),
        LayerSpec("conv8", "conv", 1, 1, 96, 128, 1, 0, pool="gap"),
        LayerSpec("fc", "fc", cin=128, cout=10, relu=False),
    ),
)

NETWORKS: dict[str, NetworkSpec] = {
    n.name: n for n in (PIPENET_MICRO, PIPENET_TINY)
}
