"""L2 performance analysis: op-census of the AOT-lowered HLO modules
(EXPERIMENTS §Perf). Verifies the lowered graphs are lean: no stray
transposes/copies, fusion where XLA can fuse, and quantifies the per-layer
module overhead vs the whole-network module (what the kernel-level baseline
gets from cross-layer fusion).

Usage:  cd python && python -m compile.hlo_stats [--artifacts ../artifacts]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import re
from collections import Counter

OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*[\w\[\]{},\s/]*?\s*(\w+)\(")


def op_census(text: str) -> Counter:
    ops: Counter = Counter()
    for line in text.splitlines():
        m = OP_RE.match(line)
        if m:
            ops[m.group(1)] += 1
    return ops


def module_stats(path: pathlib.Path) -> dict:
    ops = op_census(path.read_text())
    total = sum(ops.values())
    return {
        "total_ops": total,
        "dot": ops.get("dot", 0),
        "fusion": ops.get("fusion", 0),
        "transpose": ops.get("transpose", 0),
        "copy": ops.get("copy", 0),
        "gather": ops.get("gather", 0),
        "constant": ops.get("constant", 0),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="../artifacts")
    args = ap.parse_args()
    root = pathlib.Path(args.artifacts)

    for net_dir in sorted(d for d in root.iterdir() if d.is_dir()):
        manifest = json.loads((net_dir / "manifest.json").read_text())
        print(f"== {manifest['name']} ==")
        layer_total = 0
        for layer in manifest["layers"]:
            p = net_dir / layer["hlo"]["1"]
            s = module_stats(p)
            layer_total += s["total_ops"]
            print(
                f"  layer {layer['index']:>2} {layer['name']:<8} "
                f"ops={s['total_ops']:>4} dot={s['dot']} gather={s['gather']} "
                f"transpose={s['transpose']} copy={s['copy']}"
            )
        full = module_stats(net_dir / manifest["full"]["1"])
        print(
            f"  full-net module: ops={full['total_ops']} "
            f"(per-layer sum {layer_total}; "
            f"delta {layer_total - full['total_ops']:+} = "
            f"cross-layer fusion headroom lost by layer splitting)"
        )
        print()


if __name__ == "__main__":
    main()
