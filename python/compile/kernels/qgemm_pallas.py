"""L1 Pallas kernel: QASYMM8 quantized GEMM (paper §VII-D, Fig. 13).

ARM-CL's QASYMM8 path computes the convolution GEMM in 8-bit asymmetric
integers: real = scale * (q - zero_point). The integer core is

    acc[n,m] = sum_k xq[n,k] * yq[k,m]          (int32 accumulation)

and the affine correction applied afterwards is

    real[n,m] = sx*sy * ( acc - yz*rowsum(xq) - xz*colsum(yq) + K*xz*yz )

The paper's observation (after [26]) is that the de/re-quantization epilogue
can eat the integer-core speedup — our Rust quantization cost model
(baselines::quant) mirrors exactly this kernel/epilogue split.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qmatmul_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.int32),
        y_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )


def _pad_axis(a: jax.Array, axis: int, multiple: int) -> jax.Array:
    rem = (-a.shape[axis]) % multiple
    if rem == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, rem)
    return jnp.pad(a, widths)


@functools.partial(
    jax.jit, static_argnames=("x_zero", "y_zero", "bn", "bm", "bk")
)
def qmatmul(
    xq: jax.Array,
    yq: jax.Array,
    *,
    x_scale: float,
    x_zero: int,
    y_scale: float,
    y_zero: int,
    bn: int = 64,
    bm: int = 64,
    bk: int = 64,
) -> jax.Array:
    """Quantized GEMM: uint8 (N,K) @ uint8 (K,M) -> dequantized f32 (N,M).

    Zero padding is exact here because padded rows/columns contribute
    ``0 * yq`` to the int32 accumulator and the correction sums are computed
    on the *unpadded* operands.
    """
    n, k = xq.shape
    k2, m = yq.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {xq.shape} @ {yq.shape}")
    bn = min(bn, max(8, n))
    bm = min(bm, max(8, m))
    bk = min(bk, max(8, k))

    xp = _pad_axis(_pad_axis(xq, 0, bn), 1, bk)
    yp = _pad_axis(_pad_axis(yq, 0, bk), 1, bm)
    np_, kp = xp.shape
    mp = yp.shape[1]

    acc = pl.pallas_call(
        _qmatmul_kernel,
        grid=(np_ // bn, mp // bm, kp // bk),
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bm), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, mp), jnp.int32),
        interpret=True,
    )(xp, yp)[:n, :m]

    # Affine zero-point correction (the "de-quantization epilogue").
    # Padded entries are zero, not zero_point, so sums use unpadded operands.
    row_sum = jnp.sum(xq.astype(jnp.int32), axis=1, keepdims=True)  # (N,1)
    col_sum = jnp.sum(yq.astype(jnp.int32), axis=0, keepdims=True)  # (1,M)
    corrected = acc - y_zero * row_sum - x_zero * col_sum + k * x_zero * y_zero
    return (x_scale * y_scale) * corrected.astype(jnp.float32)


def quantize(x: jax.Array) -> tuple[jax.Array, float, int]:
    """Asymmetric uint8 quantization of an f32 array (QASYMM8 convention)."""
    lo = jnp.minimum(jnp.min(x), 0.0)
    hi = jnp.maximum(jnp.max(x), 0.0)
    scale = jnp.maximum((hi - lo) / 255.0, 1e-8)
    zero = jnp.clip(jnp.round(-lo / scale), 0, 255).astype(jnp.int32)
    q = jnp.clip(jnp.round(x / scale) + zero, 0, 255).astype(jnp.uint8)
    return q, float(scale), int(zero)
