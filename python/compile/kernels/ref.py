"""Pure-jnp reference oracles for the Pallas kernels (build-time only).

These are the ground truth the pytest suite checks the Pallas kernels and the
im2col+GEMM convolution path against. They intentionally use a *different*
implementation strategy (XLA's native convolution / plain ``jnp.dot``) so that a
bug in the kernel path cannot be masked by sharing code with the oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Reference GEMM: plain jnp.dot with f32 accumulation."""
    return jnp.dot(
        x.astype(jnp.float32), y.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def ref_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    pad: int = 0,
) -> jax.Array:
    """Reference NHWC convolution via XLA's native conv.

    x: (H, W, Cin)  w: (Fh, Fw, Cin, Cout)  ->  (Oh, Ow, Cout)
    Output dims follow the paper's Eq. (3):
        O = floor((I - F + 2*Pad) / S) + 1
    """
    out = jax.lax.conv_general_dilated(
        x[None].astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out[0]


def ref_depthwise_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    pad: int = 0,
) -> jax.Array:
    """Reference depthwise convolution. x: (H,W,C)  w: (Fh,Fw,C) -> (Oh,Ow,C)."""
    c = x.shape[-1]
    out = jax.lax.conv_general_dilated(
        x[None].astype(jnp.float32),
        w[..., None].astype(jnp.float32),  # (Fh,Fw,C,1) HWIO with groups
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )
    return out[0]


def ref_im2col(x: jax.Array, fh: int, fw: int, *, stride: int = 1, pad: int = 0) -> jax.Array:
    """Reference im2col: (H,W,C) -> (Oh*Ow, Fh*Fw*C) image matrix (paper Fig. 10).

    Row r corresponds to output pixel (r // Ow, r % Ow); column layout is
    (fh, fw, c) row-major, matching a (Fh,Fw,Cin,Cout) filter reshaped to
    (Fh*Fw*Cin, Cout).
    """
    h, w_, c = x.shape
    xp = jnp.pad(x.astype(jnp.float32), ((pad, pad), (pad, pad), (0, 0)))
    oh = (h - fh + 2 * pad) // stride + 1
    ow = (w_ - fw + 2 * pad) // stride + 1
    rows = []
    for i in range(oh):
        for j in range(ow):
            patch = jax.lax.dynamic_slice(xp, (i * stride, j * stride, 0), (fh, fw, c))
            rows.append(patch.reshape(-1))
    return jnp.stack(rows)


def ref_maxpool2(x: jax.Array) -> jax.Array:
    """2x2 max pool, stride 2. (H,W,C) -> (H/2,W/2,C)."""
    h, w, c = x.shape
    return jnp.max(x.reshape(h // 2, 2, w // 2, 2, c), axis=(1, 3))


def ref_global_avgpool(x: jax.Array) -> jax.Array:
    """(H,W,C) -> (C,)."""
    return jnp.mean(x, axis=(0, 1))


def ref_quant_matmul(
    xq: jax.Array,
    yq: jax.Array,
    *,
    x_scale: float,
    x_zero: int,
    y_scale: float,
    y_zero: int,
) -> jax.Array:
    """Reference QASYMM8-style GEMM: dequantize to f32 then jnp.dot.

    xq: (N,K) uint8, yq: (K,M) uint8. real = scale * (q - zero_point).
    """
    xf = (xq.astype(jnp.float32) - x_zero) * x_scale
    yf = (yq.astype(jnp.float32) - y_zero) * y_scale
    return jnp.dot(xf, yf, preferred_element_type=jnp.float32)
