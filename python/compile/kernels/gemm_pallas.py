"""L1 Pallas kernel: tiled GEMM — the convolution hot-spot (paper §V-A).

ARM-CL implements convolution as im2col + tiled GEMM, splitting the image
matrix's N rows into ``n_iter = N / ts`` chunks dispatched to a thread pool.
This kernel re-expresses that schedule in the TPU programming model (see
DESIGN.md §Hardware-Adaptation):

  * ARM-CL row-chunk / thread-pool iteration  ->  Pallas grid axis 0 (N / bn)
  * NEON 128-bit SIMD inner product           ->  MXU ``jnp.dot`` on VMEM tiles
  * L2-sized tile ``ts``                      ->  BlockSpec (bn, bk, bm) chosen
                                                  for VMEM residency

The grid is (N/bn, M/bm, K/bk); the (bn, bm) f32 accumulator tile stays
resident in VMEM while K-slabs stream HBM->VMEM, i.e. a classic systolic
matmul schedule. ``interpret=True`` everywhere: the CPU PJRT plugin cannot run
Mosaic custom-calls, so interpret mode is both the correctness and the AOT
path (the lowered HLO is plain XLA ops the rust runtime executes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block shape. 64x64 f32 tiles: x(64x64) + y(64x64) + acc(64x64)
# = 48 KiB VMEM — far under the ~16 MiB/core budget, and a multiple of the
# 8x128 f32 native VPU tile in both sunk dims. See EXPERIMENTS.md §Perf for
# the sweep that selected it.
DEFAULT_BN = 64
DEFAULT_BM = 64
DEFAULT_BK = 64


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (bn, bm) output tile; grid axis 2 streams K-slabs and accumulates."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        y_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def _pad_axis(a: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = a.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, rem)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("bn", "bm", "bk"))
def matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    bn: int = DEFAULT_BN,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
) -> jax.Array:
    """Tiled Pallas GEMM: (N,K) @ (K,M) -> (N,M) f32.

    Inputs may be f32 or bf16; accumulation is always f32 (MXU-style).
    Arbitrary N/K/M are supported by zero-padding up to the block multiple and
    slicing the result back (zero padding is exact for matmul).
    """
    if x.ndim != 2 or y.ndim != 2:
        raise ValueError(f"matmul expects 2-D operands, got {x.shape} @ {y.shape}")
    n, k = x.shape
    k2, m = y.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {y.shape}")

    bn = min(bn, max(8, n))
    bm = min(bm, max(8, m))
    bk = min(bk, max(8, k))

    xp = _pad_axis(_pad_axis(x, 0, bn), 1, bk)
    yp = _pad_axis(_pad_axis(y, 0, bk), 1, bm)
    np_, kp = xp.shape
    mp = yp.shape[1]

    grid = (np_ // bn, mp // bm, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bm), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, mp), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:n, :m]


def _bias_act_kernel(x_ref, b_ref, o_ref, *, relu: bool):
    v = x_ref[...] + b_ref[...]
    if relu:
        v = jnp.maximum(v, 0.0)
    o_ref[...] = v


@functools.partial(jax.jit, static_argnames=("relu",))
def bias_act(x: jax.Array, b: jax.Array, *, relu: bool = True) -> jax.Array:
    """Fused bias-add (+ optional ReLU) epilogue over an (N, M) GEMM result.

    The bias (M,) broadcasts over rows; kept as a separate tiny Pallas kernel
    so the epilogue is exercised through the same lowering path as the GEMM.
    """
    n, m = x.shape
    return pl.pallas_call(
        functools.partial(_bias_act_kernel, relu=relu),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n, m), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, m), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(x, b[None, :])
