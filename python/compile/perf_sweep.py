"""L1 performance analysis: Pallas GEMM block-shape sweep (EXPERIMENTS §Perf).

interpret=True gives CPU-numpy timings that are NOT a TPU proxy, so this tool
optimizes *structure*: for each candidate (bn, bm, bk) it reports

  * VMEM residency: bytes of x-tile + y-tile + f32 accumulator tile
    (must sit comfortably under the ~16 MiB/core VMEM budget; we also flag
    the classic 2x double-buffering footprint),
  * MXU occupancy estimate: how well the tile dims align to the 128x128
    systolic array (fraction of the MXU used per pass),
  * grid size and K-stream length for the representative layer shapes of
    the exported networks,
  * HBM traffic per output tile (bytes moved per useful FLOP — the
    roofline-side figure of merit).

Usage:  cd python && python -m compile.perf_sweep
"""

from __future__ import annotations

import dataclasses

from compile import model as M

MXU_DIM = 128  # TPU systolic array edge
VMEM_BYTES = 16 * 1024 * 1024


@dataclasses.dataclass
class BlockStats:
    bn: int
    bm: int
    bk: int
    vmem_bytes: int
    vmem_2x_bytes: int
    mxu_occupancy: float
    bytes_per_flop: float

    @property
    def fits(self) -> bool:
        return self.vmem_2x_bytes <= VMEM_BYTES


def analyze_block(bn: int, bm: int, bk: int) -> BlockStats:
    """Static cost model of one (bn, bm, bk) block choice."""
    tile_bytes = 4 * (bn * bk + bk * bm + bn * bm)
    # MXU occupancy: each (min(bn,128) x min(bk,128)) x (bk x bm) pass uses
    # a (bn x bk) x (bk x bm) slab; occupancy is the utilized fraction of
    # the 128x128 array in both dims.
    occ = min(bn, MXU_DIM) * min(bm, MXU_DIM) / (MXU_DIM * MXU_DIM)
    # HBM traffic per output tile across the K loop of length K/bk:
    # x tile (bn*bk) + y tile (bk*bm) per K step, result written once.
    # Per-FLOP: traffic / (2*bn*bm*bk) per step.
    traffic_per_step = 4.0 * (bn * bk + bk * bm)
    flops_per_step = 2.0 * bn * bm * bk
    return BlockStats(
        bn=bn,
        bm=bm,
        bk=bk,
        vmem_bytes=tile_bytes,
        vmem_2x_bytes=2 * tile_bytes,
        mxu_occupancy=occ,
        bytes_per_flop=traffic_per_step / flops_per_step,
    )


def representative_gemms() -> list[tuple[str, int, int, int]]:
    """GEMM (N, K, M) shapes of every exported network layer (Eq. 4)."""
    out = []
    for net in M.NETWORKS.values():
        shapes = net.shapes()
        for spec, (in_shape, _) in zip(net.layers, shapes):
            if spec.kind == "conv":
                n, k, m = spec.gemm_dims(in_shape[0], in_shape[1])
            else:
                n, k, m = spec.gemm_dims(0, 0)
            out.append((f"{net.name}/{spec.name}", n, k, m))
    return out


CANDIDATES = [
    (32, 32, 32),
    (64, 64, 64),
    (128, 128, 64),
    (128, 128, 128),
    (256, 128, 64),
    (128, 256, 128),
    (256, 256, 128),
    (512, 512, 256),
]


def padded_work(gemms: list[tuple[str, int, int, int]], bn: int, bm: int, bk: int) -> float:
    """Total padded MAC work across representative GEMMs, relative to the
    useful MAC count (1.0 = zero padding waste)."""
    useful = 0.0
    padded = 0.0
    for _, n, k, m in gemms:
        useful += n * k * m
        gn, gm, gk = -(-n // bn), -(-m // bm), -(-k // bk)
        padded += (gn * bn) * (gm * bm) * (gk * bk)
    return padded / useful


def main() -> None:
    gemms = representative_gemms()
    print(f"{'bn':>4} {'bm':>4} {'bk':>4} {'VMEM(2x)':>10} {'MXU occ':>8} "
          f"{'B/FLOP':>7} {'pad x':>6}  fits")
    best = None
    best_key = None
    for bn, bm, bk in CANDIDATES:
        s = analyze_block(bn, bm, bk)
        pad = padded_work(gemms, bn, bm, bk)
        print(
            f"{s.bn:>4} {s.bm:>4} {s.bk:>4} {s.vmem_2x_bytes/1024:>8.0f}KiB "
            f"{s.mxu_occupancy:>8.2f} {s.bytes_per_flop:>7.3f} {pad:>6.1f}  {s.fits}"
        )
        # Selection: minimize TOTAL work including padding on the shapes we
        # actually serve (big blocks drown small layers in padding), then
        # prefer lower HBM bytes/FLOP; must fit double-buffered.
        if s.fits:
            key = (pad * (1.0 + s.bytes_per_flop * 4.0),)
            if best is None or key < best_key:
                best, best_key = s, key
    assert best is not None
    print(f"\nselected block: ({best.bn}, {best.bm}, {best.bk}) — "
          f"MXU occ {best.mxu_occupancy:.2f}, "
          f"{best.bytes_per_flop:.3f} B/FLOP, "
          f"{best.vmem_2x_bytes/1024:.0f} KiB double-buffered, "
          f"padded-work x{padded_work(gemms, best.bn, best.bm, best.bk):.2f}")

    print("\nper-layer grid shapes at the selected block "
          "(ragged tails flagged — they waste MXU passes):")
    bn, bm, bk = best.bn, best.bm, best.bk
    waste_count = 0
    for name, n, k, m in representative_gemms():
        gn, gm, gk = -(-n // bn), -(-m // bm), -(-k // bk)
        pad_waste = 1.0 - (n * m * k) / (gn * bn * gm * bm * gk * bk)
        flag = " <- padding waste" if pad_waste > 0.5 else ""
        if pad_waste > 0.5:
            waste_count += 1
        print(f"  {name:<28} N={n:<6} K={k:<5} M={m:<5} grid=({gn},{gm},{gk})"
              f" pad-waste={pad_waste:.0%}{flag}")
    print(f"\n{waste_count} layer(s) with >50% padding waste at this block — "
          "the kernel clamps blocks to the operand size for these "
          "(see gemm_pallas.matmul).")


if __name__ == "__main__":
    main()
