"""AOT bridge: lower each major layer (and the whole net) to HLO *text*.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per network, under ``artifacts/<net>/``:

    layer_NN_b{B}.hlo.txt   one module per major layer per batch size
    full_b{B}.hlo.txt       whole network as one module (kernel-level baseline)
    manifest.json           layer order, shapes, GEMM dims, file map

Weights are seeded-random and folded into the modules as constants: the
paper's metric is throughput, which is weight-value independent (DESIGN.md
§1). Python runs only at ``make artifacts``; the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
from typing import Callable

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M

BATCH_SIZES = (1, 4)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn: Callable, in_shape: tuple[int, ...]) -> str:
    spec = jax.ShapeDtypeStruct(in_shape, jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def _batched(fn: Callable, batch: int) -> Callable:
    if batch == 1:
        return fn
    return jax.vmap(fn)


def export_network(net: M.NetworkSpec, out_dir: pathlib.Path, seed: int = 0) -> dict:
    """Write all HLO modules + manifest for one network; returns the manifest."""
    net_dir = out_dir / net.name
    net_dir.mkdir(parents=True, exist_ok=True)
    params = M.init_network_params(net, seed=seed)
    shapes = net.shapes()

    layers_meta = []
    for idx, (spec, p) in enumerate(zip(net.layers, params)):
        in_shape, out_shape = shapes[idx]

        def layer_fn(x, p=p, spec=spec):
            return (M.apply_layer(x, p, spec),)

        hlo_files: dict[str, str] = {}
        for b in BATCH_SIZES:
            fname = f"layer_{idx:02d}_b{b}.hlo.txt"
            full_in = in_shape if b == 1 else (b, *in_shape)
            text = lower_fn(_batched(layer_fn, b), full_in)
            (net_dir / fname).write_text(text)
            hlo_files[str(b)] = fname

        n, k, m = (
            spec.gemm_dims(in_shape[0], in_shape[1])
            if spec.kind == "conv"
            else spec.gemm_dims(0, 0)
        )
        layers_meta.append(
            {
                "index": idx,
                "name": spec.name,
                "kind": spec.kind,
                "input_shape": list(in_shape),
                "output_shape": list(out_shape),
                "hlo": hlo_files,
                "gemm": {"n": n, "k": k, "m": m},
                "macs": n * k * m,
                "params_bytes": 4 * (p["w"].size + p["b"].size),
            }
        )

    def full_fn(x):
        return (M.network_fn(net, params)(x),)

    full_files: dict[str, str] = {}
    in_shape = (net.input_hw[0], net.input_hw[1], net.input_c)
    for b in BATCH_SIZES:
        fname = f"full_b{b}.hlo.txt"
        full_in = in_shape if b == 1 else (b, *in_shape)
        (net_dir / fname).write_text(lower_fn(_batched(full_fn, b), full_in))
        full_files[str(b)] = fname

    # Stage-granular segment modules: one fused module per contiguous layer
    # range [lo, hi). A pipeline stage running a range executes ONE module,
    # recovering the cross-layer XLA fusion that per-layer modules lose
    # (~2x on the CPU host — EXPERIMENTS.md §Perf L2). Quadratic in W but W
    # is small for the exported nets, and lowering happens once.
    segments_meta: dict[str, dict[str, str]] = {}
    for lo in range(len(net.layers)):
        for hi in range(lo + 2, len(net.layers) + 1):
            if lo == 0 and hi == len(net.layers):
                continue  # that's the full module

            def seg_fn(x, lo=lo, hi=hi):
                for p, spec in zip(params[lo:hi], net.layers[lo:hi]):
                    x = M.apply_layer(x, p, spec)
                return (x,)

            seg_in = shapes[lo][0]
            files: dict[str, str] = {}
            for b in BATCH_SIZES:
                fname = f"segment_{lo:02d}_{hi:02d}_b{b}.hlo.txt"
                full_in = seg_in if b == 1 else (b, *seg_in)
                (net_dir / fname).write_text(lower_fn(_batched(seg_fn, b), full_in))
                files[str(b)] = fname
            segments_meta[f"{lo}-{hi}"] = files

    manifest = {
        "name": net.name,
        "input_shape": list(in_shape),
        "output_shape": list(shapes[-1][1]),
        "batch_sizes": list(BATCH_SIZES),
        "seed": seed,
        "layers": layers_meta,
        "full": full_files,
        "segments": segments_meta,
    }
    (net_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    return manifest


def _source_fingerprint() -> str:
    """Hash of the compile-path sources, used for the artifacts staleness stamp."""
    root = pathlib.Path(__file__).resolve().parent
    h = hashlib.sha256()
    for f in sorted(root.rglob("*.py")):
        h.update(f.read_bytes())
    return h.hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--net", action="append", choices=sorted(M.NETWORKS),
                    help="network(s) to export; default: all")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    nets = args.net or sorted(M.NETWORKS)
    for name in nets:
        manifest = export_network(M.NETWORKS[name], out_dir, seed=args.seed)
        n_files = len(manifest["layers"]) * len(BATCH_SIZES) + len(BATCH_SIZES)
        print(f"{name}: {len(manifest['layers'])} layers, {n_files} HLO modules -> {out_dir / name}")
    (out_dir / ".stamp").write_text(_source_fingerprint())


if __name__ == "__main__":
    main()
