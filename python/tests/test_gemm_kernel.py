"""L1 correctness: Pallas tiled GEMM vs the pure-jnp oracle.

This is the core kernel-correctness signal: hypothesis sweeps shapes and
dtypes (including non-block-multiple dims that exercise the padding path) and
asserts allclose against ``ref.ref_matmul``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gemm_pallas, ref

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, dtype, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("n,k,m", [(8, 8, 8), (64, 64, 64), (128, 64, 32)])
def test_matmul_block_multiples(n, k, m):
    x, y = _rand((n, k), jnp.float32, 0), _rand((k, m), jnp.float32, 1)
    np.testing.assert_allclose(
        gemm_pallas.matmul(x, y), ref.ref_matmul(x, y), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize(
    "n,k,m",
    [(1, 27, 16), (100, 144, 32), (196, 1152, 96), (7, 3, 5), (65, 129, 33)],
)
def test_matmul_ragged_shapes(n, k, m):
    """Non-multiples of the block shape exercise the pad-and-slice path."""
    x, y = _rand((n, k), jnp.float32, 2), _rand((k, m), jnp.float32, 3)
    # Tolerance scales with K: blocked accumulation reorders f32 sums.
    tol = 1e-5 * max(1.0, k / 10.0)
    np.testing.assert_allclose(
        gemm_pallas.matmul(x, y), ref.ref_matmul(x, y), rtol=tol, atol=tol
    )


def test_matmul_bf16_inputs_f32_accum():
    x, y = _rand((32, 48), jnp.bfloat16, 4), _rand((48, 24), jnp.bfloat16, 5)
    out = gemm_pallas.matmul(x, y)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(out, ref.ref_matmul(x, y), rtol=2e-2, atol=2e-2)


def test_matmul_custom_blocks_match_default():
    x, y = _rand((96, 80), jnp.float32, 6), _rand((80, 72), jnp.float32, 7)
    a = gemm_pallas.matmul(x, y, bn=32, bm=16, bk=8)
    b = gemm_pallas.matmul(x, y)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_matmul_rejects_bad_shapes():
    x = _rand((4, 5), jnp.float32, 0)
    y = _rand((6, 4), jnp.float32, 1)
    with pytest.raises((ValueError, TypeError)):
        gemm_pallas.matmul(x, y)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 96),
    k=st.integers(1, 96),
    m=st.integers(1, 96),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    seed=st.integers(0, 2**16),
)
def test_matmul_hypothesis_sweep(n, k, m, dtype, seed):
    dt = jnp.float32 if dtype == "float32" else jnp.bfloat16
    x, y = _rand((n, k), dt, seed), _rand((k, m), dt, seed + 1)
    tol = 1e-5 if dtype == "float32" else 3e-2
    np.testing.assert_allclose(
        gemm_pallas.matmul(x, y), ref.ref_matmul(x, y), rtol=tol, atol=tol
    )


def test_bias_act_relu():
    x = _rand((16, 8), jnp.float32, 8)
    b = _rand((8,), jnp.float32, 9)
    out = gemm_pallas.bias_act(x, b, relu=True)
    np.testing.assert_allclose(out, jnp.maximum(x + b[None, :], 0.0), rtol=1e-6)
    assert float(jnp.min(out)) >= 0.0


def test_bias_act_linear():
    x = _rand((5, 11), jnp.float32, 10)
    b = _rand((11,), jnp.float32, 11)
    out = gemm_pallas.bias_act(x, b, relu=False)
    np.testing.assert_allclose(out, x + b[None, :], rtol=1e-6)
