"""QASYMM8 quantized GEMM kernel vs dequantize-then-dot oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import qgemm_pallas, ref

jax.config.update("jax_platform_name", "cpu")


def _quantized_pair(n, k, m, seed):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.uniform(kx, (n, k), minval=-3.0, maxval=5.0)
    y = jax.random.uniform(ky, (k, m), minval=-1.0, maxval=2.0)
    xq, xs, xz = qgemm_pallas.quantize(x)
    yq, ys, yz = qgemm_pallas.quantize(y)
    return (xq, xs, xz), (yq, ys, yz)


@pytest.mark.parametrize("n,k,m", [(8, 16, 8), (33, 70, 9), (64, 64, 64)])
def test_qmatmul_matches_dequant_oracle(n, k, m):
    (xq, xs, xz), (yq, ys, yz) = _quantized_pair(n, k, m, seed=0)
    got = qgemm_pallas.qmatmul(
        xq, yq, x_scale=xs, x_zero=xz, y_scale=ys, y_zero=yz
    )
    want = ref.ref_quant_matmul(
        xq, yq, x_scale=xs, x_zero=xz, y_scale=ys, y_zero=yz
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_qmatmul_integer_core_is_exact():
    """The int32 core must be bit-exact: compare at scale=1, zero=0."""
    xq = jnp.arange(24, dtype=jnp.uint8).reshape(4, 6)
    yq = (jnp.arange(30, dtype=jnp.uint8) % 7).reshape(6, 5)
    got = qgemm_pallas.qmatmul(xq, yq, x_scale=1.0, x_zero=0, y_scale=1.0, y_zero=0)
    want = xq.astype(jnp.int32) @ yq.astype(jnp.int32)
    np.testing.assert_array_equal(got, want.astype(jnp.float32))


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(3), (50, 50)) * 4.0
    q, s, z = qgemm_pallas.quantize(x)
    deq = (q.astype(jnp.float32) - z) * s
    assert float(jnp.max(jnp.abs(deq - x))) <= s * 0.5 + 1e-6


def test_quantize_covers_zero():
    """QASYMM8 requires exact-zero representability."""
    x = jax.random.uniform(jax.random.PRNGKey(4), (10, 10), minval=0.5, maxval=2.0)
    q, s, z = qgemm_pallas.quantize(x)
    assert 0 <= z <= 255
    np.testing.assert_allclose((z - z) * s, 0.0)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 48), k=st.integers(1, 48), m=st.integers(1, 48),
       seed=st.integers(0, 1000))
def test_qmatmul_hypothesis(n, k, m, seed):
    (xq, xs, xz), (yq, ys, yz) = _quantized_pair(n, k, m, seed)
    got = qgemm_pallas.qmatmul(xq, yq, x_scale=xs, x_zero=xz, y_scale=ys, y_zero=yz)
    want = ref.ref_quant_matmul(xq, yq, x_scale=xs, x_zero=xz, y_scale=ys, y_zero=yz)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)
