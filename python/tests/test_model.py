"""Network-level shape threading and forward-pass sanity for the zoo nets."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("name", sorted(M.NETWORKS))
def test_shape_threading_consistent(name):
    net = M.NETWORKS[name]
    shapes = net.shapes()
    assert len(shapes) == len(net.layers)
    # Consecutive layers must connect.
    for (i_in, i_out), (j_in, _) in zip(shapes, shapes[1:]):
        assert i_out == j_in
    assert shapes[0][0] == (net.input_hw[0], net.input_hw[1], net.input_c)


@pytest.mark.parametrize("name", sorted(M.NETWORKS))
def test_forward_matches_declared_shapes(name):
    net = M.NETWORKS[name]
    params = M.init_network_params(net, seed=0)
    shapes = net.shapes()
    x = jax.random.normal(
        jax.random.PRNGKey(7), (net.input_hw[0], net.input_hw[1], net.input_c)
    )
    for p, spec, (in_shape, out_shape) in zip(params, net.layers, shapes):
        assert x.shape == in_shape
        x = M.apply_layer(x, p, spec)
        assert x.shape == out_shape
    assert jnp.all(jnp.isfinite(x))


def test_full_network_fn_equals_layerwise():
    """Whole-net module (kernel-level baseline) == per-layer chain (pipeline)."""
    net = M.PIPENET_MICRO
    params = M.init_network_params(net, seed=0)
    x = jax.random.normal(jax.random.PRNGKey(8), (16, 16, 3))
    full = M.network_fn(net, params)(x)
    y = x
    for p, spec in zip(params, net.layers):
        y = M.apply_layer(y, p, spec)
    np.testing.assert_allclose(full, y, rtol=1e-5, atol=1e-5)


def test_gemm_dims_match_paper_eq4():
    # conv1 of pipenet_tiny: 32x32x3, 3x3 pad1 s1 -> N=1024, K=27, M=16
    spec = M.PIPENET_TINY.layers[0]
    assert spec.gemm_dims(32, 32) == (32 * 32, 3 * 3 * 3, 16)
    # strided conv7: 8x8 input, 3x3 pad1 s2 -> O=4 -> N=16, K=576, M=96
    spec7 = M.PIPENET_TINY.layers[6]
    assert spec7.gemm_dims(8, 8) == (16, 3 * 3 * 64, 96)


def test_params_are_deterministic_by_seed():
    a = M.init_network_params(M.PIPENET_MICRO, seed=0)
    b = M.init_network_params(M.PIPENET_MICRO, seed=0)
    c = M.init_network_params(M.PIPENET_MICRO, seed=1)
    np.testing.assert_array_equal(a[0]["w"], b[0]["w"])
    assert not np.array_equal(a[0]["w"], c[0]["w"])
