"""L2 conv path (im2col + Pallas GEMM) vs XLA-native convolution oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _conv_params(spec, seed=0):
    p = M.init_layer_params(jax.random.PRNGKey(seed), spec)
    return p


def _apply_ref(x, p, spec):
    w4 = p["w"].reshape(spec.fh, spec.fw, spec.cin, spec.cout)
    y = ref.ref_conv2d(x, w4, stride=spec.stride, pad=spec.pad) + p["b"]
    if spec.relu:
        y = jnp.maximum(y, 0.0)
    if spec.pool == "max2":
        y = ref.ref_maxpool2(y)
    elif spec.pool == "gap":
        y = ref.ref_global_avgpool(y)
    return y


@pytest.mark.parametrize(
    "h,fh,cin,cout,stride,pad",
    [
        (16, 3, 3, 8, 1, 1),
        (16, 3, 8, 16, 2, 1),
        (14, 1, 16, 32, 1, 0),
        (12, 5, 4, 6, 1, 2),
        (11, 3, 5, 7, 2, 0),
    ],
)
def test_conv_layer_vs_native(h, fh, cin, cout, stride, pad):
    spec = M.LayerSpec("c", "conv", fh, fh, cin, cout, stride, pad)
    x = jax.random.normal(jax.random.PRNGKey(1), (h, h, cin))
    p = _conv_params(spec)
    np.testing.assert_allclose(
        M.apply_layer(x, p, spec), _apply_ref(x, p, spec), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("pool", ["max2", "gap"])
def test_conv_layer_pools(pool):
    spec = M.LayerSpec("c", "conv", 3, 3, 4, 8, 1, 1, pool=pool)
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 8, 4))
    p = _conv_params(spec)
    np.testing.assert_allclose(
        M.apply_layer(x, p, spec), _apply_ref(x, p, spec), rtol=1e-4, atol=1e-4
    )


def test_conv_layer_no_relu_preserves_negatives():
    spec = M.LayerSpec("c", "conv", 3, 3, 2, 4, 1, 1, relu=False)
    x = jax.random.normal(jax.random.PRNGKey(3), (6, 6, 2))
    p = _conv_params(spec)
    out = M.apply_layer(x, p, spec)
    np.testing.assert_allclose(out, _apply_ref(x, p, spec), rtol=1e-4, atol=1e-4)
    assert float(jnp.min(out)) < 0.0  # ReLU genuinely off


def test_im2col_matches_ref():
    x = jax.random.normal(jax.random.PRNGKey(4), (9, 9, 3))
    got = M.im2col(x, 3, 3, stride=2, pad=1)
    want = ref.ref_im2col(x, 3, 3, stride=2, pad=1)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@settings(max_examples=12, deadline=None)
@given(
    h=st.integers(5, 20),
    fh=st.sampled_from([1, 3, 5]),
    cin=st.integers(1, 8),
    cout=st.integers(1, 12),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 500),
)
def test_conv_hypothesis(h, fh, cin, cout, stride, seed):
    pad = fh // 2
    spec = M.LayerSpec("c", "conv", fh, fh, cin, cout, stride, pad)
    x = jax.random.normal(jax.random.PRNGKey(seed), (h, h, cin))
    p = _conv_params(spec, seed)
    np.testing.assert_allclose(
        M.apply_layer(x, p, spec), _apply_ref(x, p, spec), rtol=1e-4, atol=1e-4
    )


def test_fc_layer():
    spec = M.LayerSpec("fc", "fc", cin=32, cout=10, relu=False)
    x = jax.random.normal(jax.random.PRNGKey(5), (32,))
    p = _conv_params(spec)
    want = x @ p["w"] + p["b"]
    np.testing.assert_allclose(M.apply_layer(x, p, spec), want, rtol=1e-4, atol=1e-5)
