"""AOT export: manifest schema, HLO text well-formedness, shape consistency."""

import json

import jax
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.export_network(M.PIPENET_MICRO, out, seed=0)
    return out / M.PIPENET_MICRO.name, manifest


def test_manifest_written_and_loadable(exported):
    net_dir, manifest = exported
    on_disk = json.loads((net_dir / "manifest.json").read_text())
    assert on_disk == manifest


def test_manifest_schema(exported):
    _, m = exported
    assert m["name"] == "pipenet_micro"
    assert m["input_shape"] == [16, 16, 3]
    assert m["batch_sizes"] == [1, 4]
    assert len(m["layers"]) == len(M.PIPENET_MICRO.layers)
    for i, layer in enumerate(m["layers"]):
        assert layer["index"] == i
        assert set(layer["hlo"]) == {"1", "4"}
        assert layer["gemm"]["n"] >= 1 and layer["macs"] > 0


def test_layer_shapes_chain(exported):
    _, m = exported
    layers = m["layers"]
    for a, b in zip(layers, layers[1:]):
        assert a["output_shape"] == b["input_shape"]
    assert layers[0]["input_shape"] == m["input_shape"]
    assert layers[-1]["output_shape"] == m["output_shape"]


def test_hlo_files_exist_and_are_hlo_text(exported):
    net_dir, m = exported
    files = [f for l in m["layers"] for f in l["hlo"].values()]
    files += list(m["full"].values())
    for f in files:
        text = (net_dir / f).read_text()
        assert "ENTRY" in text and "HloModule" in text
        # return_tuple=True: root must be a tuple for rust's to_tuple1().
        assert "tuple(" in text


def test_hlo_batch4_has_batched_input(exported):
    net_dir, m = exported
    text = (net_dir / m["layers"][0]["hlo"]["4"]).read_text()
    assert "f32[4,16,16,3]" in text


def test_stamp_fingerprint_stable():
    a = aot._source_fingerprint()
    b = aot._source_fingerprint()
    assert a == b and len(a) == 64


def test_segments_exported_and_consistent(exported):
    net_dir, m = exported
    w = len(m["layers"])
    # All contiguous ranges except single layers and the full net.
    want = {(lo, hi) for lo in range(w) for hi in range(lo + 2, w + 1)} - {(0, w)}
    got = {tuple(map(int, k.split("-"))) for k in m["segments"]}
    assert got == want
    for key, files in m["segments"].items():
        assert set(files) == {"1", "4"}
        for f in files.values():
            text = (net_dir / f).read_text()
            assert "ENTRY" in text and "tuple(" in text
