"""Python mirror of the Rust DES event core (rust/src/simulator/engine.rs).

The build container carries no Rust toolchain, so this mirror is the
in-container validation for the event-core rewrite (DESIGN.md §15): it
reimplements, operation for operation, the SplitMix64 arrival streams,
the historical O(n²) full-history tenancy engine, the bounded-ring +
binary-heap fast engine, the per-stage disturbance-factor timeline, the
stationary-segment fast path, and the joint-split counting DP — then
checks the same differential properties the Rust test suite pins:

  1. fast tenancy engine ≡ reference engine, bit for bit, on hundreds of
     randomized fleets and arrival streams (outcome fields AND per-stage
     event traces);
  2. factor-timeline lookups ≡ the O(events) product scan, bit for bit,
     and the disturbed pipeline engine ≡ its full-history reference;
  3. the stationary closed form ≡ exact stepping (bitwise on dyadic
     service times, ≤1e-9 relative otherwise);
  4. count_splits DP ≡ brute-force enumeration on small grids, and the
     documented 8-core/8-core/8-tenant blowup exceeds the budget;
  5. front-door complexity at 1M arrivals: the fast engine's heap pops
     stay ≤ admitted while the reference's scan count is quadratic —
     the measured operation ratio is the speedup floor.

Both engines here share Python's float (IEEE-754 binary64) and the same
libm, so bit-identity within the mirror is exact, mirroring how the Rust
fast/reference pair shares one binary.

Run:  python3 python/mirror/des_core.py
"""

import heapq
import math
import struct
import time
from collections import deque

MASK = (1 << 64) - 1
GOLDEN = 0x9E3779B97F4A7C15


class Rng:
    """SplitMix64, matching rust/src/util/rng.rs exactly."""

    def __init__(self, seed):
        self.state = (seed + GOLDEN) & MASK

    def next_u64(self):
        self.state = (self.state + GOLDEN) & MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        return z ^ (z >> 31)

    def uniform(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def index(self, n):
        return self.next_u64() % n


def poisson_arrivals(rate_hz, count, seed):
    rng = Rng(seed)
    t = 0.0
    out = []
    for _ in range(count):
        t += -math.log(max(rng.uniform(), 1e-12)) / rate_hz
        out.append(t)
    return out


def bits(x):
    return struct.pack("<d", x)


# ---------------------------------------------------------------------------
# Tenancy engines: reference (full history, O(n²) door) vs fast (event core)
# ---------------------------------------------------------------------------


def tenant_reference(replica_stage_times, arrivals, queue_cap, admission_cap):
    """Mirror of simulate_tenant_fleet_reference (+ a trace for diffing)."""
    r = len(replica_stage_times)
    dep = [[[] for _ in ts] for ts in replica_stage_times]
    start0_all = []
    latencies, dispatched, shed, scan_iters = [], [0] * r, 0, 0
    trace = []
    for i, a in enumerate(arrivals):
        scan_iters += len(start0_all)
        waiting = sum(1 for t in start0_all if t > a)
        if waiting >= admission_cap:
            shed += 1
            trace.append(("shed", i, a))
            continue
        pick = min(
            range(r),
            key=lambda x: (max(dep[x][0][-1] if dep[x][0] else 0.0, a), x),
        )
        times = replica_stage_times[pick]
        p = len(times)
        k = len(dep[pick][0])
        prev_stage_dep = 0.0
        for s in range(p):
            prev = dep[pick][s][k - 1] if k else 0.0
            arrive = max(a, prev) if s == 0 else max(prev_stage_dep, prev)
            unblock = (
                dep[pick][s + 1][k - queue_cap - 1]
                if s + 1 < p and k > queue_cap
                else 0.0
            )
            start = max(arrive, unblock)
            if s == 0:
                start0_all.append(start)
            prev_stage_dep = start + times[s]
            dep[pick][s].append(prev_stage_dep)
            trace.append(("stage", i, pick, s, start, prev_stage_dep))
        latencies.append(prev_stage_dep - a)
        dispatched[pick] += 1
    makespan = max(
        (stages[-1][-1] if stages[-1] else 0.0 for stages in dep), default=0.0
    )
    makespan = max(makespan, 0.0)
    return dict(
        offered=len(arrivals),
        admitted=len(latencies),
        shed=shed,
        makespan=makespan,
        latencies=latencies,
        dispatched=dispatched,
        scan_iters=scan_iters,
        trace=trace,
    )


def tenant_fast(replica_stage_times, arrivals, queue_cap, admission_cap):
    """Mirror of the event-core engine: bounded rings + admission heap."""
    r = len(replica_stage_times)
    rings = [
        [deque(maxlen=queue_cap + 1) for _ in ts] for ts in replica_stage_times
    ]
    door = []  # heap of stage-0 starts of admitted items
    pops = 0
    latencies, dispatched, shed = [], [0] * r, 0
    last_final = [0.0] * r
    trace = []
    for i, a in enumerate(arrivals):
        while door and door[0] <= a:  # live_after(a)
            heapq.heappop(door)
            pops += 1
        waiting = len(door)
        if waiting >= admission_cap:
            shed += 1
            trace.append(("shed", i, a))
            continue
        pick = min(
            range(r),
            key=lambda x: (max(rings[x][0][-1] if rings[x][0] else 0.0, a), x),
        )
        times = replica_stage_times[pick]
        p = len(times)
        prev_dep = 0.0
        for s in range(p):
            ring = rings[pick][s]
            prev_same = ring[-1] if ring else 0.0
            arrive = max(a, prev_same) if s == 0 else max(prev_dep, prev_same)
            nxt = rings[pick][s + 1] if s + 1 < p else None
            unblock = nxt[0] if nxt is not None and len(nxt) == nxt.maxlen else 0.0
            start = max(arrive, unblock)
            if s == 0:
                heapq.heappush(door, start)
            prev_dep = start + times[s]
            ring.append(start + times[s])
            trace.append(("stage", i, pick, s, start, prev_dep))
        last_final[pick] = prev_dep
        latencies.append(prev_dep - a)
        dispatched[pick] += 1
    return dict(
        offered=len(arrivals),
        admitted=len(latencies),
        shed=shed,
        makespan=max(last_final + [0.0]),
        latencies=latencies,
        dispatched=dispatched,
        scan_iters=pops,
        trace=trace,
    )


def check_tenancy_differential():
    rng = Rng(2026)
    for case in range(300):
        r = 1 + rng.index(3)
        p = 1 + rng.index(4)
        fleets = [
            [0.002 + rng.uniform() * 0.03 for _ in range(p)] for _ in range(r)
        ]
        rate = 20.0 + rng.uniform() * 400.0
        n = 50 + rng.index(200)
        arrivals = poisson_arrivals(rate, n, rng.next_u64())
        qc = 1 + rng.index(3)
        ac = 1 + rng.index(8)
        fast = tenant_fast(fleets, arrivals, qc, ac)
        ref = tenant_reference(fleets, arrivals, qc, ac)
        for key in ("offered", "admitted", "shed", "dispatched"):
            assert fast[key] == ref[key], (case, key, fast[key], ref[key])
        assert bits(fast["makespan"]) == bits(ref["makespan"]), case
        assert len(fast["latencies"]) == len(ref["latencies"]), case
        for x, y in zip(fast["latencies"], ref["latencies"]):
            assert bits(x) == bits(y), case
        # Trace identity: same events at the same times, byte for byte.
        assert len(fast["trace"]) == len(ref["trace"]), case
        for ef, er in zip(fast["trace"], ref["trace"]):
            assert ef[:4] == er[:4] and all(
                bits(a) == bits(b)
                for a, b in zip(ef[4:], er[4:])
                if isinstance(a, float)
            ), (case, ef, er)
        # Complexity: the fix itself.
        assert fast["scan_iters"] <= fast["admitted"], case
        assert ref["scan_iters"] >= fast["scan_iters"], case
    print("PASS tenancy fast engine ≡ reference, bit for bit (300 cases)")


# ---------------------------------------------------------------------------
# Pipeline engine: factor timeline + ring engine vs full-history reference
# ---------------------------------------------------------------------------


def disturbance_factor(events, replica, stage, t):
    f = 1.0
    for at, factor, scope in events:
        if at <= t and (not scope or (replica, stage) in scope):
            f *= factor
    return f


class FactorTimeline:
    """Mirror of pipeline_sim's step-function timeline (monotone cursor)."""

    def __init__(self, events, replica, stage):
        ts = sorted(
            {
                at
                for at, _, scope in events
                if not math.isnan(at) and (not scope or (replica, stage) in scope)
            }
        )
        self.thresholds = ts
        self.products = [
            disturbance_factor(events, replica, stage, t) for t in ts
        ]
        self.idx = 0

    def factor_at(self, t):
        while self.idx < len(self.thresholds) and self.thresholds[self.idx] <= t:
            self.idx += 1
        return 1.0 if self.idx == 0 else self.products[self.idx - 1]


def pipeline_reference(stage_times, images, queue_cap, events, t0, replica):
    """Mirror of simulate_disturbed_reference: full history, O(events)
    factor scan per service, latency from the previous item's stage-0
    start (`dep[0][i-1] - svc0[i-1]`)."""
    p = len(stage_times)
    dep = [[0.0] * images for _ in range(p)]
    svc0 = [0.0] * images
    for i in range(images):
        for s in range(p):
            if s == 0:
                arrive = 0.0 if i == 0 else dep[0][i - 1]
            else:
                prev_here = dep[s][i - 1] if i else 0.0
                arrive = max(dep[s - 1][i], prev_here)
            unblock = (
                dep[s + 1][i - queue_cap - 1]
                if s + 1 < p and i > queue_cap
                else 0.0
            )
            start = max(arrive, unblock)
            svc = stage_times[s] * disturbance_factor(
                events, replica, s, t0 + start
            )
            if s == 0:
                svc0[i] = svc
            dep[s][i] = start + svc
    lat = []
    for i in range(images):
        enter = 0.0 if i == 0 else dep[0][i - 1] - svc0[i - 1]
        lat.append(dep[p - 1][i] - max(enter, 0.0))
    return dep[p - 1][images - 1], lat


def pipeline_fast(stage_times, images, queue_cap, events, t0, replica):
    p = len(stage_times)
    rings = [deque(maxlen=queue_cap + 1) for _ in range(p)]
    timelines = [FactorTimeline(events, replica, s) for s in range(p)]
    latencies = []
    prev_dep0 = prev_svc0 = 0.0
    out = 0.0
    for i in range(images):
        dep0 = svc0 = 0.0
        prev_dep = 0.0
        for s in range(p):
            ring = rings[s]
            prev_same = ring[-1] if ring else 0.0
            arrive = max(0.0, prev_same) if s == 0 else max(prev_dep, prev_same)
            nxt = rings[s + 1] if s + 1 < p else None
            unblock = nxt[0] if nxt is not None and len(nxt) == nxt.maxlen else 0.0
            start = max(arrive, unblock)
            svc = stage_times[s] * timelines[s].factor_at(t0 + start)
            prev_dep = start + svc
            ring.append(prev_dep)
            if s == 0:
                svc0, dep0 = svc, prev_dep
        out = prev_dep
        enter = 0.0 if i == 0 else prev_dep0 - prev_svc0
        latencies.append(out - max(enter, 0.0))
        prev_dep0, prev_svc0 = dep0, svc0
    return out, latencies


def check_pipeline_differential():
    rng = Rng(4096)
    for case in range(200):
        p = 1 + rng.index(4)
        times = [0.004 + rng.uniform() * 0.05 for _ in range(p)]
        events = []
        for _ in range(rng.index(4)):
            scope = (
                [] if rng.index(2) == 0 else [(0, rng.index(p))]
            )
            events.append(
                (rng.uniform() * 3.0, 0.5 + rng.uniform() * 2.0, scope)
            )
        images = 30 + rng.index(150)
        qc = 1 + rng.index(3)
        t0 = rng.uniform() * 2.0
        # Timeline vs direct product scan at monotone query times.
        probes = sorted(rng.uniform() * 5.0 for _ in range(40))
        for s in range(p):
            cursor = FactorTimeline(events, 0, s)
            for q in probes:
                assert bits(cursor.factor_at(q)) == bits(
                    disturbance_factor(events, 0, s, q)
                ), (case, s, q)
        mk_f, lat_f = pipeline_fast(times, images, qc, events, t0, 0)
        mk_r, lat_r = pipeline_reference(times, images, qc, events, t0, 0)
        assert bits(mk_f) == bits(mk_r), (case, mk_f, mk_r)
        for x, y in zip(lat_f, lat_r):
            assert bits(x) == bits(y), case
    print("PASS pipeline ring engine + factor timeline ≡ reference (200 cases)")


# ---------------------------------------------------------------------------
# Stationary fast path
# ---------------------------------------------------------------------------


def simulate_plain(stage_times, images, queue_cap):
    mk, lat = pipeline_fast(stage_times, images, queue_cap, [], 0.0, 0)
    return mk, lat


def simulate_stationary(stage_times, images, queue_cap):
    """Mirror of simulate_stationary: step until the per-stage departure
    increments repeat bitwise for queue_cap+2 consecutive items with one
    uniform Δ, then continue in closed form."""
    p = len(stage_times)
    need = queue_cap + 2
    rings = [deque(maxlen=queue_cap + 1) for _ in range(p)]
    prev = [0.0] * p
    delta = [0.0] * p
    streak = 0
    primed = False
    latencies = []
    prev_dep0 = 0.0
    out = 0.0
    i = 0
    while i < images:
        prev_dep = 0.0
        deps_now = [0.0] * p
        for s in range(p):
            ring = rings[s]
            prev_same = ring[-1] if ring else 0.0
            arrive = max(0.0, prev_same) if s == 0 else max(prev_dep, prev_same)
            nxt = rings[s + 1] if s + 1 < p else None
            unblock = nxt[0] if nxt is not None and len(nxt) == nxt.maxlen else 0.0
            start = max(arrive, unblock)
            prev_dep = start + stage_times[s]
            ring.append(prev_dep)
            deps_now[s] = prev_dep
        out = prev_dep
        enter = 0.0 if i == 0 else prev_dep0 - stage_times[0]
        latencies.append(out - max(enter, 0.0))
        prev_dep0 = deps_now[0]
        i += 1
        # PeriodDetector.observe, then uniform_delta.
        if not primed:
            prev = list(deps_now)
            primed = True
            continue
        same = True
        for s in range(p):
            d = deps_now[s] - prev[s]
            if bits(d) != bits(delta[s]):
                same = False
                delta[s] = d
        prev = list(deps_now)
        streak = streak + 1 if same else 1
        if i < images and streak >= need:
            if all(bits(d) == bits(delta[0]) for d in delta):
                dv = delta[0]
                if math.isfinite(dv) and dv > 0.0:
                    remaining = images - i
                    makespan = out + remaining * dv
                    lat = (out + dv) - max(deps_now[0] - stage_times[0], 0.0)
                    latencies.extend([lat] * remaining)
                    return makespan, latencies, i
    return out, latencies, None


def check_stationary():
    # Dyadic times: closed form must be bitwise identical to stepping.
    rng = Rng(777)
    for case in range(50):
        p = 1 + rng.index(4)
        times = [(1 + rng.index(16)) * 0.0078125 for _ in range(p)]
        qc = 1 + rng.index(3)
        images = 200 + rng.index(800)
        mk_s, lat_s = simulate_plain(times, images, qc)
        mk_a, lat_a, engaged = simulate_stationary(times, images, qc)
        assert engaged is not None, case
        assert bits(mk_s) == bits(mk_a), (case, mk_s, mk_a)
        assert len(lat_s) == len(lat_a)
        for x, y in zip(lat_s, lat_a):
            assert bits(x) == bits(y), case
    # General times: ≤ 1e-9 relative.
    for case in range(50):
        p = 1 + rng.index(4)
        times = [0.003 + rng.uniform() * 0.02 for _ in range(p)]
        qc = 1 + rng.index(3)
        images = 200 + rng.index(800)
        mk_s, _ = simulate_plain(times, images, qc)
        mk_a, _, _ = simulate_stationary(times, images, qc)
        assert abs(mk_a - mk_s) <= 1e-9 * mk_s, (case, mk_s, mk_a)
    print("PASS stationary closed form ≡ stepping (bitwise dyadic, 1e-9 general)")


# ---------------------------------------------------------------------------
# Joint-split budget DP
# ---------------------------------------------------------------------------


def count_splits(hb, hs, tenants):
    """Mirror of tenancy::joint::count_splits: ordered assignments of the
    FULL (hb, hs) budget to `tenants` slices, each slice ≥ 1 core (the
    enumeration's last slice absorbs the remainder, so the budget is
    always exhausted)."""
    if tenants == 0 or hb + hs < tenants:
        return 0
    ways = [[0] * (hs + 1) for _ in range(hb + 1)]
    ways[0][0] = 1
    for _ in range(tenants):
        nxt = [[0] * (hs + 1) for _ in range(hb + 1)]
        for b in range(hb + 1):
            for s in range(hs + 1):
                if not ways[b][s]:
                    continue
                for db in range(hb - b + 1):
                    for ds in range(hs - s + 1):
                        if db + ds >= 1:
                            nxt[b + db][s + ds] += ways[b][s]
        ways = nxt
    return ways[hb][hs]


def brute_splits(hb, hs, tenants):
    """Direct mirror of the recursive `splits` enumeration's count: first
    tenants−1 slices free (≥ 1 core each), last slice = the remainder."""

    def rec(b, s, left):
        if left == 1:
            return 1 if b + s >= 1 else 0
        total = 0
        for db in range(b + 1):
            for ds in range(s + 1):
                if db + ds == 0 or (b - db) + (s - ds) < left - 1:
                    continue
                total += rec(b - db, s - ds, left - 1)
        return total

    if tenants == 0 or hb + hs < tenants:
        return 0
    return rec(hb, hs, tenants)


def check_split_budget():
    for hb in range(5):
        for hs in range(5):
            for t in range(1, 5):
                assert count_splits(hb, hs, t) == brute_splits(hb, hs, t), (
                    hb,
                    hs,
                    t,
                )
    assert count_splits(1, 1, 2) == 2
    assert count_splits(1, 1, 3) == 0
    assert count_splits(4, 4, 8) == 70  # one core each: C(8,4)
    blowup = count_splits(8, 8, 8)
    assert blowup == 3716695 and blowup > 200000, blowup
    print(
        "PASS count_splits DP ≡ splits enumeration; "
        "8/8/8 = {:,} splits exceeds the 200k budget".format(blowup)
    )


# ---------------------------------------------------------------------------
# 1M-arrival complexity measurement
# ---------------------------------------------------------------------------


def check_million():
    fleets = [[0.010, 0.014, 0.008], [0.012, 0.012, 0.012]]
    arrivals = poisson_arrivals(220.0, 1_000_000, 7)
    start = time.perf_counter()
    fast = tenant_fast(fleets, arrivals, 2, 8)
    elapsed = time.perf_counter() - start
    events = fast["offered"] + sum(fast["dispatched"]) * len(fleets[0])
    # The reference's scan count at this stream, computed exactly without
    # paying for the O(n²) run: it scans every prior admitted start at
    # every arrival. Replay admission decisions from the fast trace
    # (bit-identical, so the reference admits exactly the same items).
    ref_scans = 0
    admitted_so_far = 0
    for ev in fast["trace"]:
        if ev[0] == "shed":
            ref_scans += admitted_so_far
        elif ev[0] == "stage" and ev[3] == 0:
            ref_scans += admitted_so_far
            admitted_so_far += 1
    assert fast["scan_iters"] <= fast["admitted"] <= events
    ratio = ref_scans / max(fast["scan_iters"], 1)
    print(
        "PASS 1M arrivals: admitted={:,} shed={:,} events={:,} "
        "fast scans={:,} ref scans={:,} (op ratio {:.0f}×) "
        "mirror rate {:,.0f} events/s".format(
            fast["admitted"],
            fast["shed"],
            events,
            fast["scan_iters"],
            ref_scans,
            ratio,
            events / elapsed,
        )
    )
    assert ratio >= 10.0, "front-door op ratio below the 10× target"


if __name__ == "__main__":
    check_tenancy_differential()
    check_pipeline_differential()
    check_stationary()
    check_split_budget()
    check_million()
    print("OK: event-core mirror checks all passed")
