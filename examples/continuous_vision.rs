//! Continuous-vision scenario (paper §I motivation): multiple independent
//! inference engines running concurrently on one SoC — e.g. an ADAS stack
//! classifying objects while a second model handles scene segmentation.
//!
//!   make artifacts && cargo run --release --example continuous_vision
//!
//! Serves two models at once: `pipenet_tiny` through a 3-stage pipeline and
//! `pipenet_micro` through a 2-stage pipeline, each in its own thread
//! group, then reports per-model and aggregate throughput. On the paper's
//! board these pipelines would be pinned to disjoint core sets; on this
//! host they share the CPU, demonstrating the coordinator's multi-tenancy.

use anyhow::{Context, Result};
use std::thread;

use pipeit::coordinator::serve_pipelined;
use pipeit::dse::Allocation;
use pipeit::runtime::Manifest;
use pipeit::util::cli::Args;

fn even_split(w: usize, k: usize) -> Allocation {
    let k = k.clamp(1, w);
    let ranges = (0..k)
        .map(|i| (i * w / k, (i + 1) * w / k))
        .collect();
    Allocation { ranges }
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[]);
    let images = args.get_usize("images", 60)?;

    let tiny = Manifest::load(std::path::Path::new("artifacts/pipenet_tiny"))
        .context("run `make artifacts` first")?;
    let micro = Manifest::load(std::path::Path::new("artifacts/pipenet_micro"))?;

    println!(
        "serving {} ({} layers) and {} ({} layers) concurrently, {} images each\n",
        tiny.name,
        tiny.num_layers(),
        micro.name,
        micro.num_layers(),
        images
    );

    let t1 = {
        let m = tiny.clone();
        thread::spawn(move || {
            let alloc = even_split(m.num_layers(), 3);
            serve_pipelined(&m, &alloc, images, 1, 2, 11)
        })
    };
    let t2 = {
        let m = micro.clone();
        thread::spawn(move || {
            let alloc = even_split(m.num_layers(), 2);
            serve_pipelined(&m, &alloc, images, 1, 2, 13)
        })
    };

    let (_, rep_tiny) = t1.join().expect("tiny thread")?;
    let (_, rep_micro) = t2.join().expect("micro thread")?;

    println!("--- {} ---", tiny.name);
    print!("{}", rep_tiny.render());
    println!("\n--- {} ---", micro.name);
    print!("{}", rep_micro.render());

    println!(
        "\naggregate: {:.1} inferences/s across both models",
        rep_tiny.throughput() + rep_micro.throughput()
    );
    Ok(())
}
