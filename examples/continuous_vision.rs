//! Continuous-vision scenario (paper §I motivation): multiple independent
//! inference engines running concurrently on one SoC — e.g. an ADAS stack
//! classifying objects while a second model handles scene segmentation.
//!
//!   make artifacts && cargo run --release --example continuous_vision
//!
//! Compiles one serving plan per model (`pipenet_tiny` as a 3-stage
//! pipeline, `pipenet_micro` as 2 stages) and deploys both at once, each
//! in its own thread group, then reports per-model and aggregate
//! throughput. On the paper's board these pipelines would be pinned to
//! disjoint core sets; on this host they share the CPU, demonstrating the
//! coordinator's multi-tenancy.

use std::thread;

use anyhow::{Context, Result};

use pipeit::api::{DeployOptions, PlanSpec};
use pipeit::reports::render_serve;
use pipeit::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let images = args.get_usize("images", 60)?;

    let tiny = PlanSpec::from_artifacts("artifacts/pipenet_tiny")
        .stages(3)
        .compile()
        .context("run `make artifacts` first")?;
    let micro = PlanSpec::from_artifacts("artifacts/pipenet_micro").stages(2).compile()?;
    println!(
        "serving {} and {} concurrently, {images} images each\n",
        tiny.network, micro.network
    );

    let t1 = {
        let plan = tiny.clone();
        let opts = DeployOptions { images, seed: 11, ..DeployOptions::default() };
        thread::spawn(move || plan.deploy(&opts))
    };
    let t2 = {
        let plan = micro.clone();
        let opts = DeployOptions { images, seed: 13, ..DeployOptions::default() };
        thread::spawn(move || plan.deploy(&opts))
    };

    let rep_tiny = t1.join().expect("tiny thread")?;
    let rep_micro = t2.join().expect("micro thread")?;

    println!("--- {} ---", rep_tiny.network);
    print!("{}", render_serve(&rep_tiny));
    println!("\n--- {} ---", rep_micro.network);
    print!("{}", render_serve(&rep_micro));

    println!(
        "\naggregate: {:.1} inferences/s across both models",
        rep_tiny.throughput + rep_micro.throughput
    );
    Ok(())
}
