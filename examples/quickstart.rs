//! Quickstart: the Pipe-it API in ~30 lines.
//!
//!   cargo run --release --example quickstart
//!
//! Fits the layer-level performance model, explores the pipeline design
//! space for ResNet50 on the (simulated) HiKey 970, and cross-checks the
//! chosen design point with the discrete-event pipeline simulator.

use pipeit::config::Config;
use pipeit::cnn::zoo;
use pipeit::dse;
use pipeit::perfmodel::{PerfModel, TimeMatrix};
use pipeit::simulator::pipeline_sim;

fn main() {
    let cfg = Config::default(); // HiKey 970: 4x A73 + 4x A53
    let net = zoo::resnet50();

    // 1. Fit the paper's Eq. 5-8 performance predictor from
    //    micro-benchmarks run on the (simulated) board.
    let model = PerfModel::fit(&cfg.platform);

    // 2. Build the time matrix T (54 layers x 8 stage configs) and explore
    //    the design space (millions of points, milliseconds of search).
    let tm = TimeMatrix::predicted(&cfg.platform, &model, &net);
    let point = dse::explore(&tm, cfg.platform.big.cores, cfg.platform.small.cores);
    println!("pipeline   : {}", point.pipeline);
    println!("allocation : {}", point.allocation.display_1based());
    println!("predicted  : {:.2} imgs/s (Eq. 12)", point.throughput);

    // 3. Cross-check with the discrete-event simulator over a 500-image
    //    stream (includes pipeline fill/drain).
    let times = dse::point_stage_times(&tm, &point);
    let sim = pipeline_sim::simulate(&times, 500, 2);
    println!(
        "simulated  : {:.2} imgs/s (bottleneck stage {})",
        sim.throughput, sim.bottleneck
    );

    // 4. Compare with the best the default strategy can do (Big cluster).
    let b4 = tm.config_index(pipeit::simulator::CoreType::Big, 4).unwrap();
    let baseline = 1.0 / tm.range(0, tm.num_layers(), b4);
    println!(
        "baseline B4: {baseline:.2} imgs/s  (Pipe-it gain {:+.0}%)",
        100.0 * (sim.throughput / baseline - 1.0)
    );
}
