//! Quickstart: the Plan → Deploy facade in ~20 lines.
//!
//!   cargo run --release --example quickstart
//!
//! Compiles a predicted-time serving plan for ResNet50 on the (simulated)
//! HiKey 970 — the same artifact `pipeit plan --net resnet50 --predicted`
//! writes — cross-checks it in the discrete-event simulator, and compares
//! against the Big-cluster serial baseline.

use pipeit::api::{PlanSpec, Strategy, TimeSource};
use pipeit::reports::render_serve;

fn main() -> anyhow::Result<()> {
    // 1. Plan: fit the Eq. 5-8 predictor, build the time matrix, explore
    //    the pipeline design space. The result is a serializable artifact
    //    (`plan.save(...)` / `Plan::load(...)`).
    let plan = PlanSpec::new("resnet50")
        .time_source(TimeSource::Predicted)
        .compile()?;
    print!("{}", plan.summary());

    // 2. Cross-check with the discrete-event simulator over a 500-image
    //    stream (includes pipeline fill/drain).
    let sim = plan.simulate(500, 2)?;
    print!("{}", render_serve(&sim));

    // 3. Compare with the best the default strategy can do (Big cluster).
    let serial = PlanSpec::new("resnet50")
        .time_source(TimeSource::Predicted)
        .strategy(Strategy::Serial)
        .compile()?;
    println!(
        "baseline B4: {:.2} imgs/s  (Pipe-it gain {:+.0}%)",
        serial.throughput,
        100.0 * (sim.throughput / serial.throughput - 1.0)
    );
    Ok(())
}
