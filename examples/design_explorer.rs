//! Design explorer: runs the full Pipe-it DSE for all five benchmark CNNs
//! and prints the paper's Tables IV, V and VI plus the design-space sizes.
//!
//!   cargo run --release --example design_explorer [-- --platform configs/x.json]
//!
//! Also demonstrates platform retargeting: pass any configs/*.json to see
//! how the chosen pipelines change on a different big.LITTLE design.

use pipeit::config::Config;
use pipeit::reports::Reporter;
use pipeit::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[]);
    let cfg = Config::load_or_default(args.get("platform"))?;
    println!(
        "platform: {} ({}B + {}s)\n",
        cfg.platform.name, cfg.platform.big.cores, cfg.platform.small.cores
    );

    let rep = Reporter::new(cfg);
    rep.design_space().print();
    rep.table4().print();
    rep.table5().print();
    rep.table6().print();
    rep.ablation().print();
    Ok(())
}
