//! Design explorer: compiles a replicated serving plan for all five
//! benchmark CNNs through the `pipeit::api` facade, then prints the
//! paper's Tables IV, V and VI plus the design-space sizes.
//!
//!   cargo run --release --example design_explorer [-- --platform configs/x.json]
//!
//! Also demonstrates platform retargeting: pass any configs/*.json to see
//! how the chosen pipelines change on a different big.LITTLE design.

use pipeit::api::{PlanSpec, Strategy};
use pipeit::cnn::zoo;
use pipeit::config::Config;
use pipeit::reports::Reporter;
use pipeit::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let cfg = Config::load_or_default(args.get("platform"))?;
    println!(
        "platform: {} ({}B + {}s)\n",
        cfg.platform.name, cfg.platform.big.cores, cfg.platform.small.cores
    );

    // One compiled plan per network — the artifact `pipeit plan` emits.
    for net in zoo::all_networks() {
        let plan = PlanSpec::new(&net.name)
            .platform(cfg.clone())
            .strategy(Strategy::Replicated { max_replicas: 4, exact: false })
            .compile()?;
        println!(
            "{:<11} {:<28} {:>6.2} imgs/s (R={})",
            plan.network,
            plan.partition_display(),
            plan.throughput,
            plan.num_replicas()
        );
    }
    println!();

    let rep = Reporter::new(cfg);
    rep.design_space().print();
    rep.table4().print();
    rep.table5().print();
    rep.table6().print();
    rep.ablation().print();
    rep.replicated().print();
    Ok(())
}
