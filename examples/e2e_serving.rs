//! End-to-end serving driver (DESIGN.md §6) — the proof that all three
//! layers compose: Pallas GEMM kernels (L1) -> JAX layer graphs (L2) ->
//! AOT HLO artifacts -> Rust pipelined serving over PJRT (L3).
//!
//!   make artifacts && cargo run --release --example e2e_serving
//!   (options: -- --artifacts artifacts/pipenet_tiny --images 200
//!             --stages 3 --batch 4 --queue-cap 2)
//!
//! Loads the small real CNN exported by `python/compile/aot.py`, serves a
//! synthetic image stream through (a) the serial kernel-level analogue and
//! (b) the layer-level pipeline, verifies both produce identical
//! classifications, and reports throughput / latency / stage utilization.
//! Results are recorded in EXPERIMENTS.md.

use anyhow::{Context, Result};

use pipeit::coordinator::{serve_pipelined, serve_serial};
use pipeit::dse::Allocation;
use pipeit::runtime::Manifest;
use pipeit::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["no-profile"]);
    let dir = args.get_or("artifacts", "artifacts/pipenet_tiny");
    let images = args.get_usize("images", 200)?;
    let stages = args.get_usize("stages", 3)?;
    let batch = args.get_usize("batch", 1)?;
    let cap = args.get_usize("queue-cap", 2)?;
    let seed = 7u64;

    let manifest = Manifest::load(std::path::Path::new(dir))
        .context("run `make artifacts` first")?;
    println!(
        "model {}: {} major layers, input {:?}, {:.1} MMACs/image",
        manifest.name,
        manifest.num_layers(),
        manifest.input_shape,
        manifest.layers.iter().map(|l| l.macs).sum::<usize>() as f64 / 1e6
    );

    // Stage allocation: profile-guided (measure per-layer times on this
    // host with a short calibration run, then balance ranges on time — the
    // launcher analogue of the paper's Table VI "measured layer timings").
    // Falls back to MAC-proportional balancing with --no-profile.
    let alloc = if args.has_flag("no-profile") {
        balance_by_macs(&manifest, stages)
    } else {
        let times = pipeit::coordinator::profile_layer_times(&manifest, 16, 3)?;
        println!(
            "profiled layer times (ms): {:?}",
            times.iter().map(|t| (t * 1e5).round() / 100.0).collect::<Vec<_>>()
        );
        pipeit::coordinator::balance_by_times(&times, stages)
    };
    println!("pipeline stages: {}\n", alloc.display_1based());

    println!("--- serial (kernel-level analogue, whole-net module) ---");
    let (serial_jobs, serial_report) = serve_serial(&manifest, images, batch, seed)?;
    print!("{}", serial_report.render());

    println!("\n--- pipelined (layer-level split, {stages} stage threads) ---");
    let (piped_jobs, piped_report) =
        serve_pipelined(&manifest, &alloc, images, batch, cap, seed)?;
    print!("{}", piped_report.render());

    // Functional equivalence: identical argmax classifications.
    let argmax = |jobs: &[pipeit::coordinator::Job]| -> Vec<usize> {
        let mut out: Vec<(usize, usize)> = Vec::new();
        for j in jobs {
            for (k, t) in j.tensors.iter().enumerate() {
                let am = t
                    .data
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap();
                out.push((j.seq + k, am));
            }
        }
        out.sort();
        out.into_iter().map(|(_, v)| v).collect()
    };
    let a = argmax(&serial_jobs);
    let b = argmax(&piped_jobs);
    anyhow::ensure!(a == b, "serial and pipelined classifications diverge!");
    println!("\nfunctional check: {} classifications identical across modes ✓", a.len());
    println!(
        "pipeline speedup over serial: {:.2}x",
        piped_report.throughput() / serial_report.throughput()
    );
    Ok(())
}

fn balance_by_macs(manifest: &Manifest, k: usize) -> Allocation {
    let w = manifest.num_layers();
    let k = k.clamp(1, w);
    let total: usize = manifest.layers.iter().map(|l| l.macs).sum();
    let target = total as f64 / k as f64;
    let mut ranges = Vec::with_capacity(k);
    let mut lo = 0;
    let mut acc = 0.0;
    for (i, l) in manifest.layers.iter().enumerate() {
        acc += l.macs as f64;
        let stages_left = k - ranges.len();
        let layers_left = w - i - 1;
        if (acc >= target && stages_left > 1 && layers_left >= stages_left - 1)
            || layers_left + 1 == stages_left
        {
            ranges.push((lo, i + 1));
            lo = i + 1;
            acc = 0.0;
        }
    }
    if lo < w {
        ranges.push((lo, w));
    }
    Allocation { ranges }
}
