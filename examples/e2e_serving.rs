//! End-to-end serving driver (DESIGN.md §6) — the proof that all three
//! layers compose: Pallas GEMM kernels (L1) -> JAX layer graphs (L2) ->
//! AOT HLO artifacts -> Rust pipelined serving over PJRT (L3), driven
//! through the Plan → Deploy facade.
//!
//!   make artifacts && cargo run --release --example e2e_serving
//!   (options: -- --artifacts artifacts/pipenet_tiny --images 200
//!             --stages 3 --batch 4 --queue-cap 2 [--no-profile])
//!
//! Plans the small real CNN exported by `python/compile/aot.py` —
//! profile-guided stage balancing by default, MAC-proportional with
//! `--no-profile` — serves the stream through (a) the serial kernel-level
//! analogue and (b) the layer-level pipeline plan, verifies both produce
//! identical classifications, and reports throughput / latency / stage
//! utilization. Results are recorded in EXPERIMENTS.md.

use anyhow::{Context, Result};

use pipeit::api::{DeployOptions, PlanSpec, Strategy, TimeSource};
use pipeit::coordinator::Job;
use pipeit::reports::render_serve;
use pipeit::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["no-profile"])?;
    let dir = args.get_or("artifacts", "artifacts/pipenet_tiny");
    let stages = args.get_usize("stages", 3)?;
    let opts = DeployOptions {
        images: args.get_usize("images", 200)?,
        batch: args.get_usize("batch", 1)?,
        queue_cap: args.get_usize("queue-cap", 2)?,
        seed: 7,
        ..DeployOptions::default()
    };

    let mut spec = PlanSpec::from_artifacts(dir).stages(stages);
    if !args.has_flag("no-profile") {
        spec = spec.time_source(TimeSource::ProfiledArtifacts);
    }
    let plan = spec.compile().context("run `make artifacts` first")?;
    print!("{}", plan.summary());

    println!("\n--- serial (kernel-level analogue, whole-net module) ---");
    let serial = PlanSpec::from_artifacts(dir).strategy(Strategy::Serial).compile()?;
    let (serial_jobs, serial_report) = serial.deploy_collect(&opts)?;
    print!("{}", render_serve(&serial_report));

    println!("\n--- pipelined (layer-level split, {stages} stage threads) ---");
    let (piped_jobs, piped_report) = plan.deploy_collect(&opts)?;
    print!("{}", render_serve(&piped_report));

    // Functional equivalence: identical argmax classifications.
    let a = argmax(&serial_jobs);
    let b = argmax(&piped_jobs);
    anyhow::ensure!(a == b, "serial and pipelined classifications diverge!");
    println!("\nfunctional check: {} classifications identical across modes ✓", a.len());
    println!(
        "pipeline speedup over serial: {:.2}x",
        piped_report.throughput / serial_report.throughput
    );
    Ok(())
}

fn argmax(jobs: &[Job]) -> Vec<usize> {
    let mut out: Vec<(usize, usize)> = Vec::new();
    for j in jobs {
        for (k, t) in j.tensors.iter().enumerate() {
            let am = t
                .data
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or_else(|| panic!("empty output tensor in job {}", j.seq));
            out.push((j.seq + k, am));
        }
    }
    out.sort();
    out.into_iter().map(|(_, v)| v).collect()
}
