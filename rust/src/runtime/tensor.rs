//! Host-side tensor: shape + f32 buffer, the payload flowing between
//! pipeline stages and into/out of PJRT executables.

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn elems(&self) -> usize {
        self.data.len()
    }

    /// Stack `n` equally-shaped tensors into a leading batch dimension.
    pub fn stack(ts: &[Tensor]) -> Tensor {
        assert!(!ts.is_empty());
        let shape = &ts[0].shape;
        assert!(ts.iter().all(|t| &t.shape == shape), "ragged stack");
        let mut data = Vec::with_capacity(ts.len() * ts[0].elems());
        for t in ts {
            data.extend_from_slice(&t.data);
        }
        let mut out_shape = vec![ts.len()];
        out_shape.extend_from_slice(shape);
        Tensor { shape: out_shape, data }
    }

    /// Split a batched tensor back along its leading dimension.
    pub fn unstack(&self) -> Vec<Tensor> {
        assert!(!self.shape.is_empty());
        let b = self.shape[0];
        let inner: Vec<usize> = self.shape[1..].to_vec();
        let stride: usize = inner.iter().product();
        (0..b)
            .map(|i| Tensor::new(inner.clone(), self.data[i * stride..(i + 1) * stride].to_vec()))
            .collect()
    }

    pub fn shape_i64(&self) -> Vec<i64> {
        self.shape.iter().map(|&d| d as i64).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stack_unstack_roundtrip() {
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(vec![2, 2], vec![5.0, 6.0, 7.0, 8.0]);
        let s = Tensor::stack(&[a.clone(), b.clone()]);
        assert_eq!(s.shape, vec![2, 2, 2]);
        let back = s.unstack();
        assert_eq!(back, vec![a, b]);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn rejects_bad_shape() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    #[should_panic(expected = "ragged stack")]
    fn rejects_ragged_stack() {
        let a = Tensor::zeros(&[2]);
        let b = Tensor::zeros(&[3]);
        Tensor::stack(&[a, b]);
    }
}
