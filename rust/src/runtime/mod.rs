//! PJRT runtime: artifact manifests, host tensors, and per-stage compiled
//! executables (the only module that touches the `xla` crate, and only when
//! built with `--features pjrt` — see [`executor::pjrt_available`] and
//! DESIGN.md §6).

pub mod executor;
pub mod manifest;
pub mod tensor;

pub use executor::{pjrt_available, LayerExecutable, StageRunner, StageRunnerSpec};
pub use manifest::{Manifest, ManifestGemm, ManifestLayer};
pub use tensor::Tensor;
