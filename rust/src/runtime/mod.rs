//! PJRT runtime: artifact manifests, host tensors, and per-stage compiled
//! executables (the only module that touches the `xla` crate).

pub mod executor;
pub mod manifest;
pub mod tensor;

pub use executor::{LayerExecutable, StageRunner, StageRunnerSpec};
pub use manifest::{Manifest, ManifestGemm, ManifestLayer};
pub use tensor::Tensor;
