//! PJRT execution of AOT-lowered HLO modules (the L3 <-> L1/L2 bridge).
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin): load HLO *text* ->
//! `HloModuleProto::from_text_file` -> compile -> execute. Text is the
//! interchange format because jax >= 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1's proto path rejects (see /opt/xla-example/README).
//!
//! THREADING: `xla::PjRtClient` is `Rc`-based — neither `Send` nor `Sync`.
//! Every pipeline-stage thread therefore builds its own [`StageRunner`]
//! (client + compiled executables) via [`StageRunnerSpec`], which IS `Send`
//! (DESIGN.md §1).
//!
//! FEATURE GATE: the `xla` crate is not part of the offline vendor set, so
//! the real execution path only compiles with `--features pjrt` (DESIGN.md
//! §6). The default build substitutes an API-compatible stub whose
//! [`StageRunnerSpec::build`] returns an error; everything that merely
//! *describes* executables ([`StageRunnerSpec::from_manifest`],
//! [`StageRunnerSpec::full_network`]) works in both builds.

use std::path::PathBuf;

use anyhow::Result;

use super::manifest::Manifest;

/// True when the crate was compiled with the `pjrt` feature, i.e. when
/// [`StageRunnerSpec::build`] can actually create PJRT clients. Serving
/// entry points check this up front to fail with a clear error instead of
/// panicking inside a stage thread.
pub fn pjrt_available() -> bool {
    cfg!(feature = "pjrt")
}

/// `Send` description of a stage's executables; materialized per-thread.
#[derive(Debug, Clone)]
pub struct StageRunnerSpec {
    /// (hlo path, input shape, output shape) per layer, in order, for each
    /// supported batch size: batch -> layer list.
    pub batches: Vec<(usize, Vec<(PathBuf, Vec<usize>, Vec<usize>)>)>,
}

impl StageRunnerSpec {
    /// Build the spec for layers `[lo, hi)` of a manifest, for the given
    /// batch sizes (must be exported in the artifacts).
    pub fn from_manifest(
        m: &Manifest,
        lo: usize,
        hi: usize,
        batch_sizes: &[usize],
    ) -> Result<StageRunnerSpec> {
        anyhow::ensure!(lo < hi && hi <= m.num_layers(), "bad layer range {lo}..{hi}");
        let mut batches = Vec::new();
        for &b in batch_sizes {
            let mut in_shape = m.layers[lo].input_shape.clone();
            let mut out_shape = m.layers[hi - 1].output_shape.clone();
            if b > 1 {
                in_shape.insert(0, b);
                out_shape.insert(0, b);
            }
            // Prefer the fused segment module (stage-granular XLA fusion,
            // ~2x over chaining per-layer modules on CPU — §Perf L2);
            // fall back to the per-layer chain for older artifacts.
            if hi - lo > 1 {
                if let Some(path) = m.segment_hlo_path(lo, hi, b) {
                    batches.push((b, vec![(path, in_shape, out_shape)]));
                    continue;
                }
            }
            let mut layers = Vec::new();
            for idx in lo..hi {
                let l = &m.layers[idx];
                let mut li = l.input_shape.clone();
                let mut lo_ = l.output_shape.clone();
                if b > 1 {
                    li.insert(0, b);
                    lo_.insert(0, b);
                }
                layers.push((m.layer_hlo_path(idx, b)?, li, lo_));
            }
            batches.push((b, layers));
        }
        Ok(StageRunnerSpec { batches })
    }

    /// Spec for the whole network as one module (kernel-level baseline).
    pub fn full_network(m: &Manifest, batch_sizes: &[usize]) -> Result<StageRunnerSpec> {
        let mut batches = Vec::new();
        for &b in batch_sizes {
            let mut in_shape = m.input_shape.clone();
            let mut out_shape = m.output_shape.clone();
            if b > 1 {
                in_shape.insert(0, b);
                out_shape.insert(0, b);
            }
            batches.push((b, vec![(m.full_hlo_path(b)?, in_shape, out_shape)]));
        }
        Ok(StageRunnerSpec { batches })
    }

    /// Materialize on the current thread: create a PJRT client and compile
    /// every executable. Called from inside the stage thread. Fails in
    /// builds without the `pjrt` feature.
    pub fn build(&self) -> Result<StageRunner> {
        imp::build(self)
    }
}

pub use imp::{LayerExecutable, StageRunner};

#[cfg(feature = "pjrt")]
mod imp {
    use std::path::PathBuf;

    use anyhow::{Context, Result};

    use super::StageRunnerSpec;
    use crate::runtime::tensor::Tensor;

    /// One compiled layer executable (single input -> 1-tuple output).
    pub struct LayerExecutable {
        exe: xla::PjRtLoadedExecutable,
        pub in_shape: Vec<usize>,
        pub out_shape: Vec<usize>,
    }

    impl LayerExecutable {
        /// Load + compile an HLO text file on the given client.
        pub fn load(
            client: &xla::PjRtClient,
            path: &PathBuf,
            in_shape: Vec<usize>,
            out_shape: Vec<usize>,
        ) -> Result<LayerExecutable> {
            let proto = xla::HloModuleProto::from_text_file(path.to_str().context("path utf8")?)
                .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {}: {e}", path.display()))?;
            Ok(LayerExecutable { exe, in_shape, out_shape })
        }

        /// Execute on one tensor; shape-checked both ways.
        pub fn run(&self, x: &Tensor) -> Result<Tensor> {
            anyhow::ensure!(
                x.shape == self.in_shape,
                "input shape {:?} != expected {:?}",
                x.shape,
                self.in_shape
            );
            let lit = xla::Literal::vec1(&x.data)
                .reshape(&x.shape_i64())
                .map_err(|e| anyhow::anyhow!("reshape: {e}"))?;
            let result = self
                .exe
                .execute::<xla::Literal>(&[lit])
                .map_err(|e| anyhow::anyhow!("execute: {e}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("to_literal: {e}"))?;
            // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
            let out = result
                .to_tuple1()
                .map_err(|e| anyhow::anyhow!("to_tuple1: {e}"))?;
            let data = out
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("to_vec: {e}"))?;
            anyhow::ensure!(
                data.len() == self.out_shape.iter().product::<usize>(),
                "output element count {} != shape {:?}",
                data.len(),
                self.out_shape
            );
            Ok(Tensor::new(self.out_shape.clone(), data))
        }
    }

    pub fn build(spec: &StageRunnerSpec) -> Result<StageRunner> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt client: {e}"))?;
        let mut by_batch = Vec::new();
        for (b, layers) in &spec.batches {
            let exes = layers
                .iter()
                .map(|(path, i, o)| LayerExecutable::load(&client, path, i.clone(), o.clone()))
                .collect::<Result<Vec<_>>>()?;
            by_batch.push((*b, exes));
        }
        Ok(StageRunner { _client: client, by_batch })
    }

    /// Thread-local stage runner: owns the client + compiled layer chain.
    pub struct StageRunner {
        _client: xla::PjRtClient,
        by_batch: Vec<(usize, Vec<LayerExecutable>)>,
    }

    impl StageRunner {
        pub fn supported_batches(&self) -> Vec<usize> {
            self.by_batch.iter().map(|(b, _)| *b).collect()
        }

        /// Run a whole batch through this stage's layer chain. Uses the native
        /// batch-B executables when `imgs.len()` matches one, else falls back
        /// to per-image batch-1 execution.
        pub fn run_batch(&self, imgs: &[Tensor]) -> Result<Vec<Tensor>> {
            self.run_batch_owned(imgs.to_vec())
        }

        /// Allocation-lean variant for the pipeline hot path: consumes the
        /// batch, so per-image chains start from the owned tensor instead of a
        /// defensive clone (§Perf L3 iteration 1 — see EXPERIMENTS.md).
        pub fn run_batch_owned(&self, imgs: Vec<Tensor>) -> Result<Vec<Tensor>> {
            if let Some((_, exes)) =
                self.by_batch.iter().find(|(b, _)| *b == imgs.len() && *b > 1)
            {
                let mut x = Tensor::stack(&imgs);
                drop(imgs);
                for e in exes {
                    x = e.run(&x)?;
                }
                return Ok(x.unstack());
            }
            let (_, exes) = self
                .by_batch
                .iter()
                .find(|(b, _)| *b == 1)
                .context("no batch-1 executables")?;
            imgs.into_iter()
                .map(|mut x| {
                    for e in exes {
                        x = e.run(&x)?;
                    }
                    Ok(x)
                })
                .collect()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use anyhow::Result;

    use super::StageRunnerSpec;
    use crate::runtime::tensor::Tensor;

    fn unavailable() -> anyhow::Error {
        anyhow::anyhow!(
            "PJRT runtime not built: recompile with `--features pjrt` and the \
             vendored `xla` crate (DESIGN.md §6)"
        )
    }

    /// Stub of the compiled-layer handle; never constructible without the
    /// `pjrt` feature, kept so `use pipeit::runtime::LayerExecutable`
    /// compiles in both builds.
    pub struct LayerExecutable {
        pub in_shape: Vec<usize>,
        pub out_shape: Vec<usize>,
    }

    impl LayerExecutable {
        pub fn run(&self, _x: &Tensor) -> Result<Tensor> {
            Err(unavailable())
        }
    }

    /// Stub runner; [`StageRunnerSpec::build`] never returns one.
    pub struct StageRunner {
        _private: (),
    }

    impl StageRunner {
        pub fn supported_batches(&self) -> Vec<usize> {
            Vec::new()
        }

        pub fn run_batch(&self, _imgs: &[Tensor]) -> Result<Vec<Tensor>> {
            Err(unavailable())
        }

        pub fn run_batch_owned(&self, _imgs: Vec<Tensor>) -> Result<Vec<Tensor>> {
            Err(unavailable())
        }
    }

    pub fn build(_spec: &StageRunnerSpec) -> Result<StageRunner> {
        Err(unavailable())
    }
}
