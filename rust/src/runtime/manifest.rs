//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime (DESIGN.md §3). Parsed with the in-tree JSON substrate.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// GEMM dims recorded for each layer (paper Eq. 4, from the L2 model).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ManifestGemm {
    pub n: usize,
    pub k: usize,
    pub m: usize,
}

/// One major layer's artifact record.
#[derive(Debug, Clone)]
pub struct ManifestLayer {
    pub index: usize,
    pub name: String,
    pub kind: String,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    /// batch size -> HLO file name (relative to the network dir).
    pub hlo: BTreeMap<usize, String>,
    pub gemm: ManifestGemm,
    pub macs: usize,
    pub params_bytes: usize,
}

/// Parsed manifest for one network's artifacts.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub name: String,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub batch_sizes: Vec<usize>,
    pub layers: Vec<ManifestLayer>,
    /// Whole-network modules (kernel-level baseline), batch -> file.
    pub full: BTreeMap<usize, String>,
    /// Fused segment modules per contiguous layer range [lo, hi), batch ->
    /// file (stage-granular fusion — EXPERIMENTS.md §Perf L2). Optional:
    /// absent in older artifacts.
    pub segments: BTreeMap<(usize, usize), BTreeMap<usize, String>>,
}

fn batch_map(j: &Json) -> Result<BTreeMap<usize, String>> {
    let Json::Obj(m) = j else { anyhow::bail!("expected object of batch->file") };
    let mut out = BTreeMap::new();
    for (k, v) in m {
        let b: usize = k.parse().context("batch size key")?;
        out.insert(b, v.as_str().context("hlo file name")?.to_string());
    }
    Ok(out)
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;

        let layers_json = j.req("layers")?.as_arr().context("layers array")?;
        let mut layers = Vec::with_capacity(layers_json.len());
        for (i, lj) in layers_json.iter().enumerate() {
            let g = lj.req("gemm")?;
            let layer = ManifestLayer {
                index: lj.req("index")?.as_usize().context("index")?,
                name: lj.req("name")?.as_str().context("name")?.to_string(),
                kind: lj.req("kind")?.as_str().context("kind")?.to_string(),
                input_shape: lj.req("input_shape")?.usize_arr().context("input_shape")?,
                output_shape: lj.req("output_shape")?.usize_arr().context("output_shape")?,
                hlo: batch_map(lj.req("hlo")?)?,
                gemm: ManifestGemm {
                    n: g.req("n")?.as_usize().context("gemm.n")?,
                    k: g.req("k")?.as_usize().context("gemm.k")?,
                    m: g.req("m")?.as_usize().context("gemm.m")?,
                },
                macs: lj.req("macs")?.as_usize().context("macs")?,
                params_bytes: lj.req("params_bytes")?.as_usize().context("params_bytes")?,
            };
            anyhow::ensure!(layer.index == i, "layer index out of order at {i}");
            layers.push(layer);
        }

        let mut segments = BTreeMap::new();
        if let Some(Json::Obj(seg)) = j.get("segments") {
            for (k, v) in seg {
                let (lo, hi) = k
                    .split_once('-')
                    .context("segment key format lo-hi")?;
                segments.insert(
                    (lo.parse::<usize>()?, hi.parse::<usize>()?),
                    batch_map(v)?,
                );
            }
        }

        let m = Manifest {
            dir: dir.to_path_buf(),
            name: j.req("name")?.as_str().context("name")?.to_string(),
            input_shape: j.req("input_shape")?.usize_arr().context("input_shape")?,
            output_shape: j.req("output_shape")?.usize_arr().context("output_shape")?,
            batch_sizes: j.req("batch_sizes")?.usize_arr().context("batch_sizes")?,
            layers,
            full: batch_map(j.req("full")?)?,
            segments,
        };
        m.validate()?;
        Ok(m)
    }

    /// Structural checks: shapes chain, files exist on disk.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.layers.is_empty(), "no layers");
        anyhow::ensure!(
            self.layers[0].input_shape == self.input_shape,
            "first layer input != network input"
        );
        for w in self.layers.windows(2) {
            anyhow::ensure!(
                w[0].output_shape == w[1].input_shape,
                "shape chain broken at layer {}",
                w[1].index
            );
        }
        for l in &self.layers {
            for b in &self.batch_sizes {
                let f = l
                    .hlo
                    .get(b)
                    .with_context(|| format!("layer {} missing batch {b}", l.index))?;
                let p = self.dir.join(f);
                anyhow::ensure!(p.is_file(), "missing HLO file {}", p.display());
            }
        }
        for (b, f) in &self.full {
            anyhow::ensure!(
                self.dir.join(f).is_file(),
                "missing full-net HLO for batch {b}"
            );
        }
        for ((lo, hi), files) in &self.segments {
            anyhow::ensure!(lo < hi && *hi <= self.layers.len(), "bad segment {lo}-{hi}");
            for f in files.values() {
                anyhow::ensure!(
                    self.dir.join(f).is_file(),
                    "missing segment HLO {}",
                    f
                );
            }
        }
        Ok(())
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Absolute path of a layer's HLO for a batch size.
    pub fn layer_hlo_path(&self, layer: usize, batch: usize) -> Result<PathBuf> {
        let l = self.layers.get(layer).context("layer index")?;
        let f = l.hlo.get(&batch).context("batch size not exported")?;
        Ok(self.dir.join(f))
    }

    pub fn full_hlo_path(&self, batch: usize) -> Result<PathBuf> {
        Ok(self.dir.join(self.full.get(&batch).context("batch size not exported")?))
    }

    /// Fused module covering layers [lo, hi) at `batch`, if exported.
    /// The whole-network module doubles as the (0, W) segment.
    pub fn segment_hlo_path(&self, lo: usize, hi: usize, batch: usize) -> Option<PathBuf> {
        if lo == 0 && hi == self.layers.len() {
            return self.full.get(&batch).map(|f| self.dir.join(f));
        }
        self.segments
            .get(&(lo, hi))
            .and_then(|m| m.get(&batch))
            .map(|f| self.dir.join(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests against real artifacts run in rust/tests/ (integration); here
    /// we exercise the parser on a synthetic manifest written to tmp.
    fn write_fake(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        for f in ["l0_b1.hlo.txt", "l0_b4.hlo.txt", "full_b1.hlo.txt", "full_b4.hlo.txt"] {
            std::fs::write(dir.join(f), "HloModule fake ENTRY tuple()").unwrap();
        }
        let manifest = r#"{
            "name": "fake", "input_shape": [4,4,1], "output_shape": [2],
            "batch_sizes": [1,4], "seed": 0,
            "layers": [{
                "index": 0, "name": "l0", "kind": "conv",
                "input_shape": [4,4,1], "output_shape": [2],
                "hlo": {"1": "l0_b1.hlo.txt", "4": "l0_b4.hlo.txt"},
                "gemm": {"n": 16, "k": 9, "m": 2}, "macs": 288, "params_bytes": 80
            }],
            "full": {"1": "full_b1.hlo.txt", "4": "full_b4.hlo.txt"}
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    }

    #[test]
    fn parses_and_validates() {
        let dir = std::env::temp_dir().join("pipeit_manifest_test");
        write_fake(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.name, "fake");
        assert_eq!(m.num_layers(), 1);
        assert_eq!(m.layers[0].gemm, ManifestGemm { n: 16, k: 9, m: 2 });
        assert!(m.layer_hlo_path(0, 4).unwrap().ends_with("l0_b4.hlo.txt"));
        assert!(m.layer_hlo_path(0, 2).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_fails_validation() {
        let dir = std::env::temp_dir().join("pipeit_manifest_test2");
        write_fake(&dir);
        std::fs::remove_file(dir.join("l0_b4.hlo.txt")).unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
