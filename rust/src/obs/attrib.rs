//! Prediction-error attribution: why a run missed its Eq. 10/12 numbers.
//!
//! PR 7's recorder says *what* happened (spans, counters, histograms);
//! this module says *why* the end-to-end numbers look the way they do
//! (DESIGN.md §14). It consumes the span chains of one run and
//!
//! 1. decomposes every admitted item's end-to-end latency into
//!    **front-door wait** (admission to first stage start), **queue
//!    wait** (inter-stage gaps, plus the departure gap on wall twins)
//!    and **per-stage service** (Σ of stage span widths) — a telescoping
//!    sum, so the three components reproduce the observed latency
//!    *exactly* (the conservation invariant the `obs_tracing` suite pins
//!    at 1e-9);
//! 2. compares each `(group, replica, stage)`'s observed mean service
//!    time against the plan's stored Eq. 10 prediction and reports the
//!    **residual** (observed − predicted) and the **excess** (residual ×
//!    items: the error budget in seconds that stage contributed to the
//!    run), sorted so the biggest model miss reads first.
//!
//! Every attribution input is [`audit_chains`]-verified first: a report
//! is only ever computed over conserved chains.
//!
//! [`AttribReport`] embeds in `ServeReport` / `MultiServeReport` /
//! `ClusterServeReport` (rendered by `reports::render_attrib`) and is
//! the payload of `pipeit attrib`.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use super::export::audit_chains;
use super::recorder::Recorder;
use super::span::{span_cmp, Span, SpanKind};
use crate::util::json::Json;

/// The plan's Eq. 10 per-stage service predictions, keyed by
/// `(group, replica)` — group is the board index (cluster), tenant
/// index (multi-tenant), else 0, matching [`Span::group`].
#[derive(Debug, Clone, Default)]
pub struct PredictedTimes {
    by_replica: BTreeMap<(u32, u32), Vec<f64>>,
}

impl PredictedTimes {
    pub fn new() -> PredictedTimes {
        PredictedTimes::default()
    }

    /// Store one replica's per-stage predicted service times (seconds).
    pub fn insert(&mut self, group: u32, replica: u32, stage_times: Vec<f64>) {
        self.by_replica.insert((group, replica), stage_times);
    }

    /// Store a whole group's replica list in replica-index order.
    pub fn insert_replicas(&mut self, group: u32, replicas: &[Vec<f64>]) {
        for (r, times) in replicas.iter().enumerate() {
            self.insert(group, r as u32, times.clone());
        }
    }

    /// Predicted service time for one stage, if the plan carries it.
    pub fn get(&self, group: u32, replica: u32, stage: u32) -> Option<f64> {
        self.by_replica.get(&(group, replica))?.get(stage as usize).copied()
    }

    /// True when no predictions were loaded (trace-only attribution:
    /// the decomposition still runs, residual columns render as `-`).
    pub fn is_empty(&self) -> bool {
        self.by_replica.is_empty()
    }
}

/// One `(group, replica, stage)` row of the residual table.
#[derive(Debug, Clone, PartialEq)]
pub struct StageAttrib {
    pub group: u32,
    pub replica: u32,
    pub stage: u32,
    /// Items served by this stage.
    pub items: u64,
    /// Mean observed service time (s).
    pub observed_s: f64,
    /// Eq. 10 prediction (s), when the plan carries one.
    pub predicted_s: Option<f64>,
    /// `observed_s - predicted_s` (0 when there is no prediction).
    pub residual_s: f64,
    /// `residual_s * items`: the seconds of run time this stage's model
    /// miss cost (negative = faster than predicted).
    pub excess_s: f64,
}

impl StageAttrib {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("excess_s", Json::num(self.excess_s)),
            ("group", Json::num(self.group as f64)),
            ("items", Json::num(self.items as f64)),
            ("observed_s", Json::num(self.observed_s)),
            ("replica", Json::num(self.replica as f64)),
            ("residual_s", Json::num(self.residual_s)),
            ("stage", Json::num(self.stage as f64)),
        ];
        if let Some(p) = self.predicted_s {
            fields.push(("predicted_s", Json::num(p)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<StageAttrib> {
        Ok(StageAttrib {
            group: j.req("group")?.as_usize().context("group")? as u32,
            replica: j.req("replica")?.as_usize().context("replica")? as u32,
            stage: j.req("stage")?.as_usize().context("stage")? as u32,
            items: j.req("items")?.as_usize().context("items")? as u64,
            observed_s: j.req("observed_s")?.as_f64().context("observed_s")?,
            predicted_s: match j.get("predicted_s") {
                None => None,
                Some(v) => Some(v.as_f64().context("predicted_s")?),
            },
            residual_s: j.req("residual_s")?.as_f64().context("residual_s")?,
            excess_s: j.req("excess_s")?.as_f64().context("excess_s")?,
        })
    }
}

/// Where the latency went, and where the prediction was wrong — the
/// explanation layer's artifact (module docs; DESIGN.md §14). Wait and
/// service fields are means over admitted items, in seconds.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct AttribReport {
    /// Admitted items with a complete chain.
    pub items: u64,
    /// Shed items (single-span chains; they carry no latency).
    pub shed: u64,
    /// Mean admission → first-stage-start wait (s).
    pub front_wait_s: f64,
    /// Mean inter-stage queue wait (s), incl. the stage-end → departure
    /// gap on wall twins (zero in the DES by construction).
    pub queue_wait_s: f64,
    /// Mean total stage service (s).
    pub service_s: f64,
    /// Mean observed end-to-end latency (s).
    pub latency_s: f64,
    /// Conservation check: max over chains of
    /// `|front + queue + service - latency|` — the decomposition
    /// telescopes, so this is floating-point noise (≤ 1e-9).
    pub max_abs_err_s: f64,
    /// Per-stage residual rows, biggest |excess| first.
    pub stages: Vec<StageAttrib>,
    /// Run events that reframe the residuals (e.g. adaptation swaps:
    /// service observed under more than one partition).
    pub annotations: Vec<String>,
}

impl AttribReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "annotations",
                Json::Arr(self.annotations.iter().map(|s| Json::str(s)).collect()),
            ),
            ("front_wait_s", Json::num(self.front_wait_s)),
            ("items", Json::num(self.items as f64)),
            ("latency_s", Json::num(self.latency_s)),
            ("max_abs_err_s", Json::num(self.max_abs_err_s)),
            ("queue_wait_s", Json::num(self.queue_wait_s)),
            ("service_s", Json::num(self.service_s)),
            ("shed", Json::num(self.shed as f64)),
            ("stages", Json::Arr(self.stages.iter().map(|s| s.to_json()).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<AttribReport> {
        let stages = j
            .req("stages")?
            .as_arr()
            .context("stages must be an array")?
            .iter()
            .enumerate()
            .map(|(i, s)| StageAttrib::from_json(s).with_context(|| format!("stage {i}")))
            .collect::<Result<Vec<_>>>()?;
        let annotations = j
            .req("annotations")?
            .as_arr()
            .context("annotations must be an array")?
            .iter()
            .map(|a| Ok(a.as_str().context("annotation must be a string")?.to_string()))
            .collect::<Result<Vec<_>>>()?;
        Ok(AttribReport {
            items: j.req("items")?.as_usize().context("items")? as u64,
            shed: j.req("shed")?.as_usize().context("shed")? as u64,
            front_wait_s: j.req("front_wait_s")?.as_f64().context("front_wait_s")?,
            queue_wait_s: j.req("queue_wait_s")?.as_f64().context("queue_wait_s")?,
            service_s: j.req("service_s")?.as_f64().context("service_s")?,
            latency_s: j.req("latency_s")?.as_f64().context("latency_s")?,
            max_abs_err_s: j.req("max_abs_err_s")?.as_f64().context("max_abs_err_s")?,
            stages,
            annotations,
        })
    }
}

/// Decompose every chain in `spans` (any order; a sorted copy is made)
/// and build the residual table against `pred`. The input is
/// [`audit_chains`]-verified first — attribution never runs over
/// unconserved chains.
pub fn attribute(spans: &[Span], pred: &PredictedTimes) -> Result<AttribReport> {
    let mut sorted = spans.to_vec();
    sorted.sort_by(span_cmp);
    audit_chains(&sorted).context("attribution input failed the span-chain audit")?;

    let mut by_item: BTreeMap<(u32, u64), Vec<&Span>> = BTreeMap::new();
    for s in &sorted {
        by_item.entry((s.group, s.item)).or_default().push(s);
    }

    let mut report = AttribReport::default();
    // (group, replica, stage) -> (items, Σ service).
    let mut per_stage: BTreeMap<(u32, u32, u32), (u64, f64)> = BTreeMap::new();
    let (mut front_sum, mut queue_sum, mut service_sum, mut latency_sum) =
        (0.0, 0.0, 0.0, 0.0);
    for chain in by_item.values() {
        if chain[0].kind == SpanKind::Shed {
            report.shed += 1;
            continue;
        }
        // Audited shape: Admit, Stage(0..P-1), Depart.
        let admit = chain[0];
        let depart = chain[chain.len() - 1];
        let stages = &chain[1..chain.len() - 1];
        let front = stages[0].t0 - admit.t0;
        let mut queue = depart.t1 - stages[stages.len() - 1].t1;
        let mut service = 0.0;
        for (k, s) in stages.iter().enumerate() {
            if k > 0 {
                queue += s.t0 - stages[k - 1].t1;
            }
            service += s.t1 - s.t0;
            let e = per_stage.entry((s.group, s.replica, s.stage)).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += s.t1 - s.t0;
        }
        let latency = depart.t1 - admit.t0;
        let err = ((front + queue + service) - latency).abs();
        report.max_abs_err_s = report.max_abs_err_s.max(err);
        report.items += 1;
        front_sum += front;
        queue_sum += queue;
        service_sum += service;
        latency_sum += latency;
    }
    if report.items > 0 {
        let n = report.items as f64;
        report.front_wait_s = front_sum / n;
        report.queue_wait_s = queue_sum / n;
        report.service_s = service_sum / n;
        report.latency_s = latency_sum / n;
    }
    report.stages = per_stage
        .into_iter()
        .map(|((g, r, s), (items, sum))| {
            let observed = sum / items as f64;
            let predicted = pred.get(g, r, s);
            let residual = predicted.map_or(0.0, |p| observed - p);
            StageAttrib {
                group: g,
                replica: r,
                stage: s,
                items,
                observed_s: observed,
                predicted_s: predicted,
                residual_s: residual,
                excess_s: residual * items as f64,
            }
        })
        .collect();
    // Biggest model miss first; key order breaks ties deterministically.
    report.stages.sort_by(|a, b| {
        b.excess_s
            .abs()
            .total_cmp(&a.excess_s.abs())
            .then((a.group, a.replica, a.stage).cmp(&(b.group, b.replica, b.stage)))
    });
    Ok(report)
}

/// Report-embedding wrapper used by the serving paths: `None` when the
/// recorder is off or recorded nothing (attribution is opt-in evidence,
/// not a run requirement). An audit failure here would mean a serving
/// path emitted unconserved chains — loud in debug builds, never fatal
/// to the run that was being served.
pub fn attrib_for(
    rec: &Recorder,
    pred: &PredictedTimes,
    annotations: Vec<String>,
) -> Option<AttribReport> {
    if !rec.enabled() {
        return None;
    }
    let spans = rec.spans_sorted();
    if spans.is_empty() {
        return None;
    }
    match attribute(&spans, pred) {
        Ok(mut report) => {
            report.annotations = annotations;
            Some(report)
        }
        Err(e) => {
            debug_assert!(false, "serving path produced unconserved chains: {e:#}");
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two items through a 2-stage pipeline, one shed; hand-checkable.
    fn demo_recorder() -> Recorder {
        let r = Recorder::on();
        r.admit(0, 0, 0.0);
        r.stage(0, 0, 0, 0, 0.1, 0.3); // front wait 0.1
        r.stage(0, 0, 0, 1, 0.5, 0.6); // queue gap 0.2
        r.depart(0, 0, 0, 0.6);
        r.admit(0, 1, 1.0);
        r.stage(0, 1, 0, 0, 1.0, 1.2);
        r.stage(0, 1, 0, 1, 1.2, 1.3);
        r.depart(0, 1, 0, 1.3);
        r.shed(0, 2, 1.05);
        r
    }

    #[test]
    fn decomposition_matches_hand_computation() {
        let a = attribute(&demo_recorder().spans_sorted(), &PredictedTimes::new())
            .expect("conserved");
        assert_eq!((a.items, a.shed), (2, 1));
        assert!((a.front_wait_s - 0.05).abs() < 1e-12, "{}", a.front_wait_s);
        assert!((a.queue_wait_s - 0.1).abs() < 1e-12, "{}", a.queue_wait_s);
        assert!((a.service_s - 0.3).abs() < 1e-12, "{}", a.service_s);
        assert!((a.latency_s - 0.45).abs() < 1e-12, "{}", a.latency_s);
        assert!(a.max_abs_err_s <= 1e-9, "{}", a.max_abs_err_s);
        // No predictions: rows exist, residuals are zero, predicted None.
        assert_eq!(a.stages.len(), 2);
        assert!(a.stages.iter().all(|s| s.predicted_s.is_none() && s.residual_s == 0.0));
    }

    #[test]
    fn residuals_rank_biggest_miss_first() {
        let mut pred = PredictedTimes::new();
        // Stage 0 predicted 0.15 (observed mean 0.2), stage 1 spot-on.
        pred.insert(0, 0, vec![0.15, 0.1]);
        let a = attribute(&demo_recorder().spans_sorted(), &pred).expect("conserved");
        assert_eq!(a.stages[0].stage, 0);
        assert!((a.stages[0].residual_s - 0.05).abs() < 1e-12);
        assert!((a.stages[0].excess_s - 0.1).abs() < 1e-12, "2 items x 0.05s");
        assert!((a.stages[1].residual_s - 0.0).abs() < 1e-12);
    }

    #[test]
    fn depart_gap_folds_into_queue_wait() {
        // Wall-twin shape: departure recorded after the last stage ends.
        let r = Recorder::on();
        r.admit(0, 0, 0.0);
        r.stage(0, 0, 0, 0, 0.0, 0.2);
        r.depart(0, 0, 0, 0.25);
        let a = attribute(&r.spans_sorted(), &PredictedTimes::new()).expect("conserved");
        assert!((a.queue_wait_s - 0.05).abs() < 1e-12);
        assert!(a.max_abs_err_s <= 1e-9);
    }

    #[test]
    fn unconserved_input_is_rejected() {
        let r = Recorder::on();
        r.admit(0, 0, 0.0);
        r.stage(0, 0, 0, 0, 0.0, 0.1);
        // No departure: audit must veto attribution.
        let err = attribute(&r.spans_sorted(), &PredictedTimes::new())
            .unwrap_err()
            .to_string();
        assert!(err.contains("span-chain audit"), "unhelpful error: {err}");
    }

    #[test]
    fn attrib_for_is_none_when_off_or_empty() {
        let pred = PredictedTimes::new();
        assert!(attrib_for(&Recorder::off(), &pred, Vec::new()).is_none());
        assert!(attrib_for(&Recorder::on(), &pred, Vec::new()).is_none());
        let r = demo_recorder();
        let a = attrib_for(&r, &pred, vec!["note".into()]).expect("some");
        assert_eq!(a.annotations, vec!["note".to_string()]);
    }

    #[test]
    fn report_json_round_trips() {
        let mut pred = PredictedTimes::new();
        pred.insert_replicas(0, &[vec![0.15, 0.1]]);
        let mut a = attribute(&demo_recorder().spans_sorted(), &pred).expect("conserved");
        a.annotations.push("t=1.00s after 1 imgs: swap".into());
        let back = AttribReport::from_json(&a.to_json()).expect("parses");
        assert_eq!(a, back);
        assert_eq!(a.to_json().to_string(), back.to_json().to_string());
    }
}
