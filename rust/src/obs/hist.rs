//! Log-bucketed latency histograms: the mergeable aggregate the metrics
//! registry stores latencies and service times in.
//!
//! A [`LogHist`] counts observations in geometric buckets of ratio
//! `2^(1/8)` (eight buckets per octave, ~9% relative width), so quantile
//! queries are exact *within one bucket width* while merging two
//! histograms is a plain per-bucket count addition — no sample vectors
//! cross replica/board boundaries. This is what lets fleet, multi-tenant
//! and cluster reports pool per-replica latency populations without
//! carrying every raw sample (DESIGN.md §13).
//!
//! Quantiles are **nearest-rank**: `quantile(q)` returns the geometric
//! midpoint of the bucket containing the order statistic at rank
//! `round(q/100 · (n-1))`. Merging is exact (the merged histogram equals
//! the histogram of the pooled samples, bucket for bucket), so a merged
//! quantile always lands in the same bucket as the pooled-vector
//! nearest-rank percentile — the property test below pins this.
//!
//! Non-positive observations (a zero-width span, a degenerate latency)
//! are counted in a dedicated zero bucket that sorts below every
//! geometric bucket; `quantile` answers `0.0` while the rank is inside it.

use std::collections::BTreeMap;

use anyhow::{ensure, Context, Result};

use crate::util::json::Json;

/// Buckets per octave (factor-of-two span). Eight gives bucket edges at
/// ratio `2^(1/8) ≈ 1.0905` — better than 10% latency resolution.
pub const BUCKETS_PER_OCTAVE: i32 = 8;

/// Bucket index clamp: `±360` covers `2^±45` (≈ 3e-14 .. 3.5e13), far
/// beyond any latency in seconds this system can produce.
const MIN_BUCKET: i32 = -360;
const MAX_BUCKET: i32 = 360;

/// Bucket index of a positive value: `floor(8 · log2(x))`, clamped.
fn bucket_of(x: f64) -> i32 {
    let b = (x.log2() * BUCKETS_PER_OCTAVE as f64).floor();
    (b as i32).clamp(MIN_BUCKET, MAX_BUCKET)
}

/// Lower edge of bucket `b`.
pub fn bucket_lo(b: i32) -> f64 {
    2f64.powf(b as f64 / BUCKETS_PER_OCTAVE as f64)
}

/// Upper edge of bucket `b` (the lower edge of `b + 1`).
pub fn bucket_hi(b: i32) -> f64 {
    bucket_lo(b + 1)
}

/// Geometric midpoint of bucket `b` — the representative value quantile
/// queries answer with.
fn bucket_mid(b: i32) -> f64 {
    2f64.powf((b as f64 + 0.5) / BUCKETS_PER_OCTAVE as f64)
}

/// A mergeable log-bucketed histogram (see module docs).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LogHist {
    /// Sparse bucket counts, keyed by bucket index (sorted — the JSON
    /// form is deterministic by construction).
    buckets: BTreeMap<i32, u64>,
    /// Observations with `x <= 0`, ordered below every bucket.
    zeros: u64,
    /// Total observations, including zeros.
    count: u64,
    /// Exact running sum (busy-time accounting must not be bucketed).
    sum: f64,
    /// Largest observation seen.
    max: f64,
}

impl LogHist {
    pub fn new() -> LogHist {
        LogHist::default()
    }

    /// Record one observation.
    pub fn record(&mut self, x: f64) {
        self.count += 1;
        if x <= 0.0 {
            self.zeros += 1;
            return;
        }
        *self.buckets.entry(bucket_of(x)).or_insert(0) += 1;
        self.sum += x;
        if x > self.max {
            self.max = x;
        }
    }

    /// Record every sample of a slice.
    pub fn record_all(&mut self, xs: &[f64]) {
        for &x in xs {
            self.record(x);
        }
    }

    /// Build a histogram of a slice in one call.
    pub fn of(xs: &[f64]) -> LogHist {
        let mut h = LogHist::new();
        h.record_all(xs);
        h
    }

    /// Absorb another histogram: per-bucket count addition. Exact — the
    /// result equals the histogram of the pooled samples.
    pub fn merge(&mut self, other: &LogHist) {
        for (&b, &c) in &other.buckets {
            *self.buckets.entry(b).or_insert(0) += c;
        }
        self.zeros += other.zeros;
        self.count += other.count;
        self.sum += other.sum;
        if other.max > self.max {
            self.max = other.max;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of positive observations (total busy seconds when the
    /// histogram holds service times).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Nearest-rank quantile, `q` in [0, 100]: the geometric midpoint of
    /// the bucket holding the order statistic at rank
    /// `round(q/100 · (count-1))`. `0.0` for an empty histogram or while
    /// the rank falls among non-positive observations.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q / 100.0) * (self.count - 1) as f64).round() as u64;
        if rank < self.zeros {
            return 0.0;
        }
        let mut seen = self.zeros;
        for (&b, &c) in &self.buckets {
            seen += c;
            if rank < seen {
                return bucket_mid(b);
            }
        }
        // Rank beyond the last bucket cannot happen (counts sum to
        // `count`), but stay total: answer the largest observation.
        self.max
    }

    /// JSON form: sorted `[bucket, count]` pairs plus the exact
    /// aggregates. Deterministic byte-for-byte for equal histograms.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "buckets",
                Json::Arr(
                    self.buckets
                        .iter()
                        .map(|(&b, &c)| {
                            Json::Arr(vec![Json::num(b as f64), Json::num(c as f64)])
                        })
                        .collect(),
                ),
            ),
            ("zeros", Json::num(self.zeros as f64)),
            ("count", Json::num(self.count as f64)),
            ("sum", Json::num(self.sum)),
            ("max", Json::num(self.max)),
        ])
    }

    /// Inverse of [`LogHist::to_json`].
    pub fn from_json(j: &Json) -> Result<LogHist> {
        let mut buckets = BTreeMap::new();
        for (i, pair) in j
            .req("buckets")?
            .as_arr()
            .context("histogram buckets must be an array")?
            .iter()
            .enumerate()
        {
            let pair = pair
                .as_arr()
                .with_context(|| format!("bucket {i} must be a [index, count] pair"))?;
            ensure!(pair.len() == 2, "bucket {i} must have exactly two fields");
            let b = pair[0].as_f64().context("bucket index")? as i32;
            let c = pair[1].as_f64().context("bucket count")? as u64;
            buckets.insert(b, c);
        }
        Ok(LogHist {
            buckets,
            zeros: j.req("zeros")?.as_usize().context("zeros")? as u64,
            count: j.req("count")?.as_usize().context("count")? as u64,
            sum: j.req("sum")?.as_f64().context("sum")?,
            max: j.req("max")?.as_f64().context("max")?,
        })
    }
}

/// Pool per-replica latency populations: the one merge loop that fleet
/// ([`crate::coordinator::FleetReport`]), multi-tenant co-simulation
/// ([`crate::tenancy`]) and cluster assembly ([`crate::cluster`]) all
/// share. Returns the pooled raw vector (reports keep exact interpolated
/// percentiles — behavior unchanged) *and* the merged histogram the
/// metrics snapshot carries.
pub fn pool_latencies<'a, I>(parts: I) -> (Vec<f64>, LogHist)
where
    I: IntoIterator<Item = &'a [f64]>,
{
    let mut pooled = Vec::new();
    let mut hist = LogHist::new();
    for part in parts {
        pooled.extend_from_slice(part);
        hist.merge(&LogHist::of(part));
    }
    (pooled, hist)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn empty_hist_is_well_defined() {
        let h = LogHist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum(), 0.0);
        assert_eq!(h.quantile(50.0), 0.0);
        assert_eq!(h.quantile(99.0), 0.0);
    }

    #[test]
    fn single_sample_lands_in_its_own_bucket() {
        let h = LogHist::of(&[0.125]);
        assert_eq!(h.count(), 1);
        let q = h.quantile(50.0);
        assert!(
            q >= 0.125 / 1.0906 && q <= 0.125 * 1.0906,
            "q={q} not within one bucket of 0.125"
        );
        assert_eq!(h.max(), 0.125);
    }

    #[test]
    fn zeros_sort_below_every_bucket() {
        let h = LogHist::of(&[0.0, 0.0, 0.0, 1.0]);
        assert_eq!(h.quantile(0.0), 0.0);
        assert!(h.quantile(100.0) > 0.9);
    }

    #[test]
    fn merge_equals_histogram_of_pooled_samples() {
        let a = [0.01, 0.02, 0.5];
        let b = [0.011, 3.0];
        let mut m = LogHist::of(&a);
        m.merge(&LogHist::of(&b));
        let pooled: Vec<f64> = a.iter().chain(b.iter()).copied().collect();
        assert_eq!(m, LogHist::of(&pooled));
    }

    #[test]
    fn json_round_trips_losslessly() {
        let h = LogHist::of(&[0.001, 0.002, 0.0, 0.5, 12.0]);
        let j = h.to_json();
        let back = LogHist::from_json(&j).expect("deserializes");
        assert_eq!(h, back);
        // And byte-identical re-serialization (determinism contract).
        assert_eq!(j.to_string(), back.to_json().to_string());
    }

    #[test]
    fn pool_latencies_matches_manual_extend() {
        let parts: Vec<Vec<f64>> = vec![vec![0.1, 0.2], vec![], vec![0.3]];
        let (pooled, hist) =
            pool_latencies(parts.iter().map(|p| p.as_slice()));
        assert_eq!(pooled, vec![0.1, 0.2, 0.3]);
        assert_eq!(hist.count(), 3);
        assert_eq!(hist, LogHist::of(&pooled));
    }

    /// The ISSUE 8 satellite property: merged-histogram quantiles equal
    /// the pooled-vector nearest-rank percentiles within one bucket width,
    /// for arbitrary samples split arbitrarily across replicas.
    #[test]
    fn property_merged_quantiles_within_one_bucket_of_pooled() {
        check(200, |rng| {
            let n = 1 + rng.index(120);
            let samples: Vec<f64> =
                (0..n).map(|_| rng.range_f64(1e-5, 50.0)).collect();
            // Split into 1..=4 parts at random, merge per-part histograms.
            let parts = 1 + rng.index(4);
            let mut hists = vec![LogHist::new(); parts];
            for (i, &x) in samples.iter().enumerate() {
                hists[i % parts].record(x);
            }
            let mut merged = LogHist::new();
            for h in &hists {
                merged.merge(h);
            }
            crate::prop_assert!(
                merged == LogHist::of(&samples),
                "merge is not exact on {n} samples in {parts} parts"
            );
            let mut sorted = samples.clone();
            sorted.sort_by(|a, b| a.total_cmp(b));
            for q in [0.0, 50.0, 95.0, 99.0, 100.0] {
                let rank = ((q / 100.0) * (n - 1) as f64).round() as usize;
                let exact = sorted[rank];
                let got = merged.quantile(q);
                let ratio = got / exact;
                crate::prop_assert!(
                    ratio >= 1.0 / 1.0906 && ratio <= 1.0906,
                    "q{q}: hist {got} vs exact {exact} differ by more \
                     than one bucket width (ratio {ratio})"
                );
            }
            Ok(())
        });
    }
}
