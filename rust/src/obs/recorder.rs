//! The [`Recorder`]: one cheaply-clonable handle that every serving path
//! threads through — DES recurrences, wall-clock stage threads, front
//! doors and routers — bundling the span buffer and the metrics registry.
//!
//! # Zero cost when off
//!
//! A disabled recorder ([`Recorder::off`]) holds no allocation at all:
//! every recording method starts with `if self.inner.is_none() { return }`
//! — one branch on the hot path, no span construction, no lock. The
//! harness conformance suite pins that a disabled recorder changes no
//! report field on any scenario.
//!
//! # Determinism
//!
//! The DES twins record spans in recurrence order, which is itself a
//! function of the seed only; [`Recorder::spans_sorted`] additionally
//! sorts by the canonical key ([`Span::sort_key`]) so the exported bytes
//! do not depend on recording interleavings — this is what makes
//! same-seed trace files byte-identical on the wall-clock-free paths.
//!
//! # Wall-clock stamps
//!
//! Wall paths stamp spans with [`WallClock`]: a shared epoch captured
//! once at run start, read lock-free from every stage thread
//! (`Instant::elapsed` on a shared immutable epoch — no synchronization
//! beyond the `Arc`).

use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::hist::LogHist;
use super::metrics::{MetricsRegistry, MetricsSnapshot};
use super::span::{span_cmp, Span, SpanKind};

#[derive(Debug)]
struct RecorderInner {
    spans: Mutex<Vec<Span>>,
    metrics: MetricsRegistry,
}

/// See module docs. `Clone` shares the same buffer and registry.
#[derive(Debug, Clone, Default)]
pub struct Recorder {
    inner: Option<Arc<RecorderInner>>,
}

impl Recorder {
    /// The disabled recorder: no allocation, every method a no-op after
    /// one branch.
    pub fn off() -> Recorder {
        Recorder { inner: None }
    }

    /// An enabled recorder with an empty span buffer and fresh registry.
    pub fn on() -> Recorder {
        Recorder {
            inner: Some(Arc::new(RecorderInner {
                spans: Mutex::new(Vec::new()),
                metrics: MetricsRegistry::new(),
            })),
        }
    }

    /// Whether recording is on. Hot paths may branch on this once and
    /// skip timestamp capture entirely.
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Record a raw span.
    pub fn span(&self, span: Span) {
        if let Some(inner) = &self.inner {
            inner.spans.lock().unwrap().push(span);
        }
    }

    /// Zero-width admission span plus the `admitted` counter.
    pub fn admit(&self, group: u32, item: u64, at_s: f64) {
        if let Some(inner) = &self.inner {
            inner.spans.lock().unwrap().push(Span {
                group,
                item,
                replica: 0,
                stage: 0,
                kind: SpanKind::Admit,
                t0: at_s,
                t1: at_s,
            });
            inner.metrics.inc("admitted", 1);
        }
    }

    /// Zero-width shed span plus the `shed` counter — the whole chain of
    /// a turned-away item.
    pub fn shed(&self, group: u32, item: u64, at_s: f64) {
        if let Some(inner) = &self.inner {
            inner.spans.lock().unwrap().push(Span {
                group,
                item,
                replica: 0,
                stage: 0,
                kind: SpanKind::Shed,
                t0: at_s,
                t1: at_s,
            });
            inner.metrics.inc("shed", 1);
        }
    }

    /// One stage's service interval, also recorded into the per-stage
    /// service-time histogram.
    pub fn stage(&self, group: u32, item: u64, replica: u32, stage: u32, t0: f64, t1: f64) {
        if let Some(inner) = &self.inner {
            inner.spans.lock().unwrap().push(Span {
                group,
                item,
                replica,
                stage,
                kind: SpanKind::Stage,
                t0,
                t1,
            });
            inner
                .metrics
                .observe(&format!("stage_service/g{group}r{replica}s{stage}"), t1 - t0);
        }
    }

    /// Zero-width departure span plus the `departed` counter. End-to-end
    /// latency histograms are fed separately by the report-assembly merge
    /// sites ([`super::hist::pool_latencies`] + [`Recorder::observe_hist`]
    /// under `"latency"`), one bulk merge per replica instead of one lock
    /// round per item.
    pub fn depart(&self, group: u32, item: u64, replica: u32, at_s: f64) {
        if let Some(inner) = &self.inner {
            inner.spans.lock().unwrap().push(Span {
                group,
                item,
                replica,
                stage: 0,
                kind: SpanKind::Depart,
                t0: at_s,
                t1: at_s,
            });
            inner.metrics.inc("departed", 1);
        }
    }

    /// Counter increment (no-op when off).
    pub fn inc(&self, name: &str, by: u64) {
        if let Some(inner) = &self.inner {
            inner.metrics.inc(name, by);
        }
    }

    /// Gauge set (no-op when off).
    pub fn gauge_set(&self, name: &str, v: f64) {
        if let Some(inner) = &self.inner {
            inner.metrics.gauge_set(name, v);
        }
    }

    /// Gauge high-water mark (no-op when off).
    pub fn gauge_max(&self, name: &str, v: f64) {
        if let Some(inner) = &self.inner {
            inner.metrics.gauge_max(name, v);
        }
    }

    /// Single histogram observation (no-op when off). Prefer
    /// [`Recorder::observe_hist`] where a whole sample vector is in hand.
    pub fn observe(&self, name: &str, x: f64) {
        if let Some(inner) = &self.inner {
            inner.metrics.observe(name, x);
        }
    }

    /// Bulk histogram absorb (no-op when off) — the latency-merge sites'
    /// one-lock-per-replica path.
    pub fn observe_hist(&self, name: &str, h: &LogHist) {
        if let Some(inner) = &self.inner {
            inner.metrics.observe_hist(name, h);
        }
    }

    /// All recorded spans in canonical order (see module docs). Empty
    /// when disabled.
    pub fn spans_sorted(&self) -> Vec<Span> {
        match &self.inner {
            None => Vec::new(),
            Some(inner) => {
                let mut spans = inner.spans.lock().unwrap().clone();
                spans.sort_by(span_cmp);
                spans
            }
        }
    }

    /// Frozen registry state, `None` when disabled.
    pub fn snapshot(&self) -> Option<MetricsSnapshot> {
        self.inner.as_ref().map(|i| i.metrics.snapshot())
    }
}

/// Shared wall-clock epoch for the thread fleets: captured once before
/// stage threads start, then read lock-free from every thread. All wall
/// spans of one run share this basis, so cross-replica ordering on the
/// exported timeline is meaningful.
#[derive(Debug, Clone)]
pub struct WallClock {
    epoch: Arc<Instant>,
}

impl WallClock {
    /// Capture the epoch now.
    pub fn start() -> WallClock {
        WallClock { epoch: Arc::new(Instant::now()) }
    }

    /// Seconds since the epoch.
    pub fn now_s(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }
}

impl Default for WallClock {
    fn default() -> Self {
        WallClock::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_records_nothing() {
        let r = Recorder::off();
        assert!(!r.enabled());
        r.admit(0, 1, 0.0);
        r.stage(0, 1, 0, 0, 0.0, 0.5);
        r.depart(0, 1, 0, 0.5);
        r.inc("admitted", 10);
        assert!(r.spans_sorted().is_empty());
        assert!(r.snapshot().is_none());
    }

    #[test]
    fn full_chain_counts_and_histograms() {
        let r = Recorder::on();
        r.admit(0, 0, 0.0);
        r.stage(0, 0, 0, 0, 0.0, 0.1);
        r.stage(0, 0, 0, 1, 0.1, 0.3);
        r.depart(0, 0, 0, 0.3);
        r.shed(0, 1, 0.05);
        let spans = r.spans_sorted();
        assert_eq!(spans.len(), 5);
        let s = r.snapshot().expect("enabled");
        assert_eq!(s.counter("admitted"), 1);
        assert_eq!(s.counter("shed"), 1);
        assert_eq!(s.counter("departed"), 1);
        assert_eq!(s.hist("stage_service/g0r0s0").map(|h| h.count()), Some(1));
        assert_eq!(s.hist("stage_service/g0r0s1").map(|h| h.count()), Some(1));
    }

    #[test]
    fn clones_share_one_buffer() {
        let r = Recorder::on();
        let r2 = r.clone();
        r2.admit(0, 7, 1.0);
        assert_eq!(r.spans_sorted().len(), 1);
    }

    #[test]
    fn sorted_spans_do_not_depend_on_recording_order() {
        let a = Recorder::on();
        a.admit(0, 0, 0.0);
        a.admit(0, 1, 1.0);
        let b = Recorder::on();
        b.admit(0, 1, 1.0);
        b.admit(0, 0, 0.0);
        assert_eq!(a.spans_sorted(), b.spans_sorted());
    }

    #[test]
    fn wall_clock_is_monotone() {
        let c = WallClock::start();
        let a = c.now_s();
        let b = c.now_s();
        assert!(b >= a && a >= 0.0);
    }
}
