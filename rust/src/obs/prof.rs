//! DES engine self-profiling: how much machinery one simulated run cost.
//!
//! The ROADMAP's event-engine rewrite (10–100× target) needs a measured
//! baseline before it can gate against regressions. [`EngineProf`] is
//! that baseline's instrument: each DES twin accumulates its own cheap
//! counters — events processed, event-heap pushes/pops and peak size,
//! departure-ring peak occupancy, front-door scan iterations — and
//! flushes them into the run's metrics registry under the
//! `prof/{engine}/` namespace, next to the serving metrics the registry
//! already carries. The bench runner's recorded rep then lands them in
//! `BENCH_*.json`, so `pipeit bench history` can plot engine cost over
//! time (DESIGN.md §14).
//!
//! Counter catalog, per engine (`pipeline` / `tenancy` / `cluster`):
//!
//! * counters — `prof/{engine}/events` (simulation events processed),
//!   `prof/{engine}/heap_pushes`, `prof/{engine}/heap_pops`,
//!   `prof/{engine}/scan_iters` (front-door linear-scan iterations),
//!   `prof/{engine}/wall_ns` (host wall time; a counter so repeated
//!   flushes add, matching [`MetricsSnapshot::merge`] semantics)
//! * gauges — `prof/{engine}/heap_peak`, `prof/{engine}/ring_peak`
//!   (high-water marks; `gauge_max` so merges keep the max),
//!   `prof/{engine}/events_per_s` (simulation events per host
//!   wall-second — the headline number the rewrite must beat)
//!
//! Engines without a heap (the recurrence-based pipeline twin) report
//! zero pushes and a zero peak: an honest "no heap to speed up".
//!
//! Profiling costs nothing when the recorder is off: `start` captures no
//! timestamp and `flush` is a no-op, preserving the disabled-recorder
//! invariance the harness conformance suite pins.
//!
//! [`MetricsSnapshot::merge`]: super::metrics::MetricsSnapshot::merge

use std::time::Instant;

use super::recorder::Recorder;

/// One engine run's profile accumulator (module docs). Counters are
/// plain fields the engine bumps inline or computes post-hoc; [`flush`]
/// publishes them. Inactive (recorder off) instances never read the
/// clock.
///
/// [`flush`]: EngineProf::flush
#[derive(Debug)]
pub struct EngineProf {
    engine: &'static str,
    start: Option<Instant>,
    /// Simulation events processed (arrivals + per-stage completions).
    pub events: u64,
    pub heap_pushes: u64,
    pub heap_pops: u64,
    /// Event-heap high-water mark.
    pub heap_peak: u64,
    /// Departure-ring high-water mark.
    pub ring_peak: u64,
    /// Front-door waiting-count work. Since the event-core rewrite
    /// (DESIGN.md §15) this counts heap pops — at most one per admitted
    /// item, so it is linear in events; CI's bench-smoke gate asserts
    /// `scan_iters <= 2 * events` on the 1M-arrival stress scenario to
    /// keep the historical O(n²) linear scan from regressing back in.
    pub scan_iters: u64,
}

impl EngineProf {
    /// Start profiling `engine` — active (clock captured) only when the
    /// recorder is on.
    pub fn start(engine: &'static str, rec: &Recorder) -> EngineProf {
        EngineProf {
            engine,
            start: rec.enabled().then(Instant::now),
            events: 0,
            heap_pushes: 0,
            heap_pops: 0,
            heap_peak: 0,
            ring_peak: 0,
            scan_iters: 0,
        }
    }

    /// Whether this run is being profiled. Engines may branch on this
    /// once to skip accumulation entirely.
    pub fn active(&self) -> bool {
        self.start.is_some()
    }

    /// Publish the accumulated counters into the registry (no-op when
    /// inactive).
    pub fn flush(&self, rec: &Recorder) {
        let Some(start) = self.start else { return };
        let e = self.engine;
        rec.inc(&format!("prof/{e}/events"), self.events);
        rec.inc(&format!("prof/{e}/heap_pushes"), self.heap_pushes);
        rec.inc(&format!("prof/{e}/heap_pops"), self.heap_pops);
        rec.inc(&format!("prof/{e}/scan_iters"), self.scan_iters);
        let elapsed = start.elapsed().as_secs_f64();
        rec.inc(&format!("prof/{e}/wall_ns"), (elapsed * 1e9) as u64);
        rec.gauge_max(&format!("prof/{e}/heap_peak"), self.heap_peak as f64);
        rec.gauge_max(&format!("prof/{e}/ring_peak"), self.ring_peak as f64);
        // Clamp away a zero-resolution clock so the headline gauge is
        // always present on profiled runs.
        rec.gauge_max(
            &format!("prof/{e}/events_per_s"),
            self.events as f64 / elapsed.max(1e-9),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inactive_when_recorder_off_and_flush_is_noop() {
        let rec = Recorder::off();
        let mut p = EngineProf::start("pipeline", &rec);
        assert!(!p.active());
        p.events = 100;
        p.flush(&rec);
        assert!(rec.snapshot().is_none());
    }

    #[test]
    fn flush_publishes_the_counter_catalog() {
        let rec = Recorder::on();
        let mut p = EngineProf::start("cluster", &rec);
        assert!(p.active());
        p.events = 1000;
        p.heap_pushes = 400;
        p.heap_pops = 390;
        p.heap_peak = 12;
        p.ring_peak = 3;
        p.scan_iters = 50;
        p.flush(&rec);
        let s = rec.snapshot().expect("enabled");
        assert_eq!(s.counter("prof/cluster/events"), 1000);
        assert_eq!(s.counter("prof/cluster/heap_pushes"), 400);
        assert_eq!(s.counter("prof/cluster/heap_pops"), 390);
        assert_eq!(s.counter("prof/cluster/scan_iters"), 50);
        assert_eq!(s.gauge("prof/cluster/heap_peak"), Some(12.0));
        assert_eq!(s.gauge("prof/cluster/ring_peak"), Some(3.0));
        assert!(s.gauge("prof/cluster/events_per_s").expect("present") > 0.0);
        assert!(s.counters.contains_key("prof/cluster/wall_ns"));
    }

    #[test]
    fn repeated_flushes_accumulate_counters_and_max_gauges() {
        let rec = Recorder::on();
        for peak in [5u64, 3] {
            let mut p = EngineProf::start("tenancy", &rec);
            p.events = 10;
            p.heap_peak = peak;
            p.flush(&rec);
        }
        let s = rec.snapshot().expect("enabled");
        assert_eq!(s.counter("prof/tenancy/events"), 20);
        assert_eq!(s.gauge("prof/tenancy/heap_peak"), Some(5.0));
    }
}
