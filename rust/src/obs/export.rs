//! Trace exporters and the span-chain auditor.
//!
//! Two on-disk forms (DESIGN.md §13):
//!
//! * **JSONL** (`--trace-out`): a schema-versioned header line followed
//!   by one span per line in canonical order — byte-identical across
//!   same-seed DES runs, diff- and grep-friendly.
//! * **Chrome trace JSON** (`pipeit trace convert`): the
//!   `{"traceEvents": [...]}` shape Perfetto and `chrome://tracing`
//!   open directly. Groups (boards/tenants) become processes, `(replica,
//!   stage)` pairs become named threads, stage service becomes complete
//!   (`"X"`) events and admissions/sheds/departures become instant
//!   events on a per-group `front-door` track — a cluster run renders as
//!   one timeline of boards → replicas → stages.
//!
//! [`audit_chains`] is the conservation checker behind the
//! `obs_tracing` suite: every admitted item must own exactly one
//! complete chain (admit → stages in pipeline order → depart), every
//! shed item exactly one shed span.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use super::recorder::Recorder;
use super::span::{Span, SpanKind};
use crate::util::json::Json;

/// Trace schema version written in the JSONL header and required back
/// by [`parse_trace`].
pub const TRACE_VERSION: usize = 1;

fn span_to_json(s: &Span) -> Json {
    Json::obj(vec![
        ("group", Json::num(s.group as f64)),
        ("item", Json::num(s.item as f64)),
        ("kind", Json::str(s.kind.name())),
        ("replica", Json::num(s.replica as f64)),
        ("stage", Json::num(s.stage as f64)),
        ("t0", Json::num(s.t0)),
        ("t1", Json::num(s.t1)),
    ])
}

fn span_from_json(j: &Json) -> Result<Span> {
    let kind = SpanKind::parse(
        j.req("kind")?.as_str().context("span kind must be a string")?,
    )
    .context("unknown span kind")?;
    Ok(Span {
        group: j.req("group")?.as_usize().context("group")? as u32,
        item: j.req("item")?.as_usize().context("item")? as u64,
        replica: j.req("replica")?.as_usize().context("replica")? as u32,
        stage: j.req("stage")?.as_usize().context("stage")? as u32,
        kind,
        t0: j.req("t0")?.as_f64().context("t0")?,
        t1: j.req("t1")?.as_f64().context("t1")?,
    })
}

/// Serialize a recorder's spans as schema-versioned JSONL (header line
/// then one span per line, canonical order). `clock` names the time
/// basis: `"sim"` for DES twins, `"wall"` for thread fleets.
pub fn trace_to_jsonl(rec: &Recorder, clock: &str) -> String {
    let header = Json::obj(vec![
        ("schema", Json::str("pipeit-trace")),
        ("version", Json::num(TRACE_VERSION as f64)),
        ("clock", Json::str(clock)),
    ]);
    let mut out = header.to_string();
    out.push('\n');
    for span in rec.spans_sorted() {
        out.push_str(&span_to_json(&span).to_string());
        out.push('\n');
    }
    out
}

/// Write [`trace_to_jsonl`] to `path`.
pub fn write_trace(rec: &Recorder, clock: &str, path: &Path) -> Result<()> {
    std::fs::write(path, trace_to_jsonl(rec, clock))
        .with_context(|| format!("writing trace to {}", path.display()))
}

/// Parse a JSONL trace back: `(clock, spans)`. Rejects missing or
/// mismatched schema versions by name.
pub fn parse_trace(s: &str) -> Result<(String, Vec<Span>)> {
    let mut lines = s.lines().filter(|l| !l.trim().is_empty());
    let header = Json::parse(lines.next().context("empty trace file")?)
        .map_err(|e| anyhow::anyhow!("trace header is not JSON: {e:?}"))?;
    let schema = header.req("schema")?.as_str().context("schema")?.to_string();
    ensure!(schema == "pipeit-trace", "unknown trace schema {schema:?}");
    let version = header.req("version")?.as_usize().context("version")?;
    ensure!(
        version == TRACE_VERSION,
        "trace version {version} unsupported (expected {TRACE_VERSION})"
    );
    let clock = header.req("clock")?.as_str().context("clock")?.to_string();
    let mut spans = Vec::new();
    for (i, line) in lines.enumerate() {
        let j = Json::parse(line)
            .map_err(|e| anyhow::anyhow!("trace line {}: {e:?}", i + 2))?;
        spans.push(span_from_json(&j).with_context(|| format!("trace line {}", i + 2))?);
    }
    Ok((clock, spans))
}

/// Load and parse a JSONL trace file.
pub fn load_trace(path: &Path) -> Result<(String, Vec<Span>)> {
    let s = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    parse_trace(&s)
}

/// Convert parsed spans to the Chrome trace JSON object (see module
/// docs). Timestamps scale to microseconds, the format's native unit.
pub fn chrome_trace(spans: &[Span]) -> Json {
    const US: f64 = 1e6;
    // Track layout: per group (pid), tid 0 is the front door; stage
    // tracks are 1 + replica * 64 + stage (64 stages per replica is far
    // above any pipeline here).
    let tid_of = |s: &Span| 1 + s.replica as f64 * 64.0 + s.stage as f64;
    let mut events = Vec::new();
    let mut groups: BTreeMap<u32, BTreeMap<u64, (u32, u32)>> = BTreeMap::new();
    for s in spans {
        match s.kind {
            SpanKind::Stage => {
                groups
                    .entry(s.group)
                    .or_default()
                    .insert((s.replica as u64) << 32 | s.stage as u64, (s.replica, s.stage));
                events.push(Json::obj(vec![
                    ("name", Json::str(&format!("r{}s{}", s.replica, s.stage))),
                    ("cat", Json::str("stage")),
                    ("ph", Json::str("X")),
                    ("ts", Json::num(s.t0 * US)),
                    ("dur", Json::num((s.t1 - s.t0) * US)),
                    ("pid", Json::num(s.group as f64)),
                    ("tid", Json::num(tid_of(s))),
                    (
                        "args",
                        Json::obj(vec![("item", Json::num(s.item as f64))]),
                    ),
                ]));
            }
            SpanKind::Admit | SpanKind::Shed | SpanKind::Depart => {
                groups.entry(s.group).or_default();
                events.push(Json::obj(vec![
                    ("name", Json::str(s.kind.name())),
                    ("cat", Json::str("item")),
                    ("ph", Json::str("i")),
                    ("s", Json::str("t")),
                    ("ts", Json::num(s.t0 * US)),
                    ("pid", Json::num(s.group as f64)),
                    ("tid", Json::num(0.0)),
                    (
                        "args",
                        Json::obj(vec![("item", Json::num(s.item as f64))]),
                    ),
                ]));
            }
        }
    }
    // Metadata events naming processes and threads, emitted after the
    // data events in deterministic (group, tid) order.
    for (&g, tracks) in &groups {
        events.push(Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(g as f64)),
            (
                "args",
                Json::obj(vec![("name", Json::str(&format!("group {g}")))]),
            ),
        ]));
        events.push(Json::obj(vec![
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(g as f64)),
            ("tid", Json::num(0.0)),
            ("args", Json::obj(vec![("name", Json::str("front-door"))])),
        ]));
        for &(r, s) in tracks.values() {
            events.push(Json::obj(vec![
                ("name", Json::str("thread_name")),
                ("ph", Json::str("M")),
                ("pid", Json::num(g as f64)),
                ("tid", Json::num(1.0 + r as f64 * 64.0 + s as f64)),
                (
                    "args",
                    Json::obj(vec![(
                        "name",
                        Json::str(&format!("replica {r} stage {s}")),
                    )]),
                ),
            ]));
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

/// CLI entry: read a JSONL trace, write Chrome trace JSON.
pub fn convert_trace(input: &Path, output: &Path) -> Result<usize> {
    let (_clock, spans) = load_trace(input)?;
    let n = spans.len();
    std::fs::write(output, chrome_trace(&spans).to_string())
        .with_context(|| format!("writing Chrome trace to {}", output.display()))?;
    Ok(n)
}

/// What [`audit_chains`] found: one complete chain per admitted item,
/// one lone shed span per shed item.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChainAudit {
    /// Items with a complete admit → stages → depart chain.
    pub complete: usize,
    /// Items with exactly one shed span.
    pub shed: usize,
    /// Total stage spans across all chains.
    pub stage_spans: usize,
}

/// Verify span-chain conservation over canonically-sorted spans (as
/// returned by [`Recorder::spans_sorted`] or [`load_trace`]). Errors
/// name the first offending (group, item).
pub fn audit_chains(spans: &[Span]) -> Result<ChainAudit> {
    let mut by_item: BTreeMap<(u32, u64), Vec<&Span>> = BTreeMap::new();
    for s in spans {
        by_item.entry((s.group, s.item)).or_default().push(s);
    }
    let mut audit = ChainAudit { complete: 0, shed: 0, stage_spans: 0 };
    for ((g, i), chain) in &by_item {
        let ctx = || format!("group {g} item {i}");
        if chain[0].kind == SpanKind::Shed {
            ensure!(
                chain.len() == 1,
                "{}: shed item has {} extra spans",
                ctx(),
                chain.len() - 1
            );
            audit.shed += 1;
            continue;
        }
        ensure!(
            chain[0].kind == SpanKind::Admit,
            "{}: chain starts with {:?}, not an admission",
            ctx(),
            chain[0].kind
        );
        ensure!(chain.len() >= 3, "{}: chain too short ({})", ctx(), chain.len());
        let last = chain[chain.len() - 1];
        ensure!(
            last.kind == SpanKind::Depart,
            "{}: chain ends with {:?}, not a departure",
            ctx(),
            last.kind
        );
        let stages = &chain[1..chain.len() - 1];
        let replica = stages[0].replica;
        let mut prev_end = chain[0].t0;
        for (idx, s) in stages.iter().enumerate() {
            match s.kind {
                SpanKind::Stage => {}
                other => bail!("{}: {other:?} span inside the stage run", ctx()),
            }
            ensure!(
                s.replica == replica,
                "{}: stage run crosses replicas ({} vs {replica})",
                ctx(),
                s.replica
            );
            ensure!(
                s.stage as usize == idx,
                "{}: stage {} out of pipeline order (expected {idx})",
                ctx(),
                s.stage
            );
            ensure!(
                s.t0 >= prev_end - 1e-9,
                "{}: stage {} starts before its predecessor ends",
                ctx(),
                s.stage
            );
            prev_end = s.t1;
        }
        ensure!(
            last.t0 >= prev_end - 1e-9,
            "{}: departure precedes the last stage's end",
            ctx()
        );
        audit.stage_spans += stages.len();
        audit.complete += 1;
    }
    Ok(audit)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_recorder() -> Recorder {
        let r = Recorder::on();
        r.admit(0, 0, 0.0);
        r.stage(0, 0, 0, 0, 0.0, 0.1);
        r.stage(0, 0, 0, 1, 0.1, 0.25);
        r.depart(0, 0, 0, 0.25);
        r.shed(0, 1, 0.02);
        r
    }

    #[test]
    fn jsonl_round_trips_and_is_stable() {
        let r = demo_recorder();
        let text = trace_to_jsonl(&r, "sim");
        let (clock, spans) = parse_trace(&text).expect("parses");
        assert_eq!(clock, "sim");
        assert_eq!(spans, r.spans_sorted());
        // Re-serializing parsed spans reproduces the original bytes.
        let r2 = Recorder::on();
        for s in &spans {
            r2.span(*s);
        }
        assert_eq!(trace_to_jsonl(&r2, "sim"), text);
    }

    #[test]
    fn parse_rejects_wrong_version() {
        let text = "{\"clock\":\"sim\",\"schema\":\"pipeit-trace\",\"version\":99}\n";
        let err = parse_trace(text).unwrap_err().to_string();
        assert!(err.contains("version 99"), "unhelpful error: {err}");
    }

    #[test]
    fn audit_accepts_the_demo_chain() {
        let r = demo_recorder();
        let audit = audit_chains(&r.spans_sorted()).expect("conserved");
        assert_eq!(audit, ChainAudit { complete: 1, shed: 1, stage_spans: 2 });
    }

    #[test]
    fn audit_rejects_missing_departure() {
        let r = Recorder::on();
        r.admit(0, 0, 0.0);
        r.stage(0, 0, 0, 0, 0.0, 0.1);
        let err = audit_chains(&r.spans_sorted()).unwrap_err().to_string();
        assert!(err.contains("not a departure"), "unhelpful error: {err}");
    }

    #[test]
    fn audit_rejects_out_of_order_stages() {
        let r = Recorder::on();
        r.admit(0, 0, 0.0);
        r.stage(0, 0, 0, 1, 0.0, 0.1);
        r.stage(0, 0, 0, 0, 0.1, 0.2);
        r.depart(0, 0, 0, 0.2);
        let err = audit_chains(&r.spans_sorted()).unwrap_err().to_string();
        assert!(err.contains("out of pipeline order"), "unhelpful error: {err}");
    }

    #[test]
    fn chrome_trace_has_events_and_metadata() {
        let r = demo_recorder();
        let j = chrome_trace(&r.spans_sorted());
        let events = j.req("traceEvents").unwrap().as_arr().unwrap();
        // 5 data events + process_name + front-door + 2 stage tracks.
        assert_eq!(events.len(), 9);
        let complete = events
            .iter()
            .filter(|e| e.req("ph").unwrap().as_str() == Some("X"))
            .count();
        assert_eq!(complete, 2, "one X event per stage span");
        assert!(j.to_string().contains("\"displayTimeUnit\":\"ms\""));
    }
}
