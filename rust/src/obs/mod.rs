//! Unified observability: per-item span tracing plus a metrics registry,
//! shared by the DES twins and the wall-clock thread fleets.
//!
//! Until now the only visibility into *why* a run misses its Eq. 12
//! prediction was the aggregate report tables: per-replica utilization
//! and latency percentiles, with the per-item story discarded inside the
//! recurrences and stage threads. This module is the instrument panel
//! (DESIGN.md §13):
//!
//! * [`Recorder`] — one cheaply-clonable handle threaded through every
//!   serving path. Disabled ([`Recorder::off`]) it is a single branch on
//!   the hot path with no allocation; enabled ([`Recorder::on`]) it
//!   buffers [`Span`]s and feeds a [`MetricsRegistry`].
//! * [`Span`]/[`SpanKind`] — the per-item event model: admission, shed,
//!   per-stage service, departure, stamped with sim-time in the DES and
//!   the shared [`WallClock`] on the thread paths.
//! * [`LogHist`] — mergeable log-bucketed histograms (8 buckets per
//!   octave) with nearest-rank quantiles exact to one bucket width;
//!   [`pool_latencies`] is the one latency-merge loop fleet, tenancy and
//!   cluster report assembly now share.
//! * [`MetricsSnapshot`] — the frozen counters/gauges/histograms embedded
//!   in `ServeReport`/`MultiServeReport`/`ClusterServeReport` and in
//!   `BENCH_*.json` scenario entries.
//! * Exporters — schema-versioned JSONL ([`write_trace`], `--trace-out`)
//!   and Chrome-trace/Perfetto JSON ([`convert_trace`], `pipeit trace
//!   convert`); [`audit_chains`] checks span-chain conservation.
//! * [`attribute`]/[`AttribReport`] — the explanation layer (DESIGN.md
//!   §14): decompose each chain's end-to-end latency into front-door
//!   wait + queue wait + per-stage service (conserving exactly) and
//!   report per-stage residuals against the plan's Eq. 10 predictions;
//!   [`attrib_for`] embeds the result in the serving reports.
//! * [`EngineProf`] — DES engine self-profiling (events processed, heap
//!   pushes/pops/peak, ring occupancy, events per wall-second) under the
//!   `prof/{engine}/` metric namespace, the measured baseline the
//!   planned event-engine rewrite gates against.
//!
//! Determinism contract: on the DES twins, recording adds no state the
//! recurrence reads back, and the exporter sorts spans by the canonical
//! key — same seed, same bytes. The `obs_tracing` suite pins both
//! properties plus report-invariance under a disabled recorder.

pub mod attrib;
pub mod export;
pub mod hist;
pub mod metrics;
pub mod prof;
pub mod recorder;
pub mod span;

pub use attrib::{attrib_for, attribute, AttribReport, PredictedTimes, StageAttrib};
pub use export::{
    audit_chains, chrome_trace, convert_trace, load_trace, parse_trace, trace_to_jsonl,
    write_trace, ChainAudit, TRACE_VERSION,
};
pub use hist::{pool_latencies, LogHist, BUCKETS_PER_OCTAVE};
pub use metrics::{MetricsRegistry, MetricsSnapshot};
pub use prof::EngineProf;
pub use recorder::{Recorder, WallClock};
pub use span::{Span, SpanKind};
