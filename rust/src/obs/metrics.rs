//! The metrics registry: named counters, gauges and log-bucketed
//! histograms behind one lock, plus the immutable [`MetricsSnapshot`]
//! that reports embed and `BENCH_*.json` artifacts carry.
//!
//! Naming convention (DESIGN.md §13): flat slash-separated keys, ordered
//! lexicographically by the underlying `BTreeMap` so serialization is
//! deterministic. The serving paths use:
//!
//! * counters — `admitted`, `shed`, `departed`
//! * gauges — `occupancy/g{g}r{r}s{s}` (per-stage busy fraction),
//!   `queue_depth_peak/g{g}` (front-door high-water mark), `wall_s`
//! * histograms — `latency` (end-to-end, pooled across replicas),
//!   `stage_service/g{g}r{r}s{s}` (per-stage service times)
//!
//! Where a dimension does not apply (single-plan serving has one group)
//! the index is still written, so keys stay parseable and sortable.

use std::collections::BTreeMap;
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::hist::LogHist;
use crate::util::json::Json;

#[derive(Debug, Default)]
struct RegistryInner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, LogHist>,
}

/// Thread-safe named-metric store. All methods take `&self`; cloning the
/// owning [`super::Recorder`] shares one registry.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    inner: Mutex<RegistryInner>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Add `by` to counter `name` (creating it at zero).
    pub fn inc(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Set gauge `name` to `v` unconditionally.
    pub fn gauge_set(&self, name: &str, v: f64) {
        let mut g = self.inner.lock().unwrap();
        g.gauges.insert(name.to_string(), v);
    }

    /// Raise gauge `name` to `v` if `v` is larger (high-water marks like
    /// peak queue depth).
    pub fn gauge_max(&self, name: &str, v: f64) {
        let mut g = self.inner.lock().unwrap();
        let e = g.gauges.entry(name.to_string()).or_insert(f64::NEG_INFINITY);
        if v > *e {
            *e = v;
        }
    }

    /// Record `v` into histogram `name`.
    pub fn observe(&self, name: &str, v: f64) {
        let mut g = self.inner.lock().unwrap();
        g.hists.entry(name.to_string()).or_default().record(v);
    }

    /// Absorb a whole pre-built histogram into `name` (the bulk path the
    /// latency-merge sites use — one lock round per replica, not per
    /// sample).
    pub fn observe_hist(&self, name: &str, h: &LogHist) {
        let mut g = self.inner.lock().unwrap();
        g.hists.entry(name.to_string()).or_default().merge(h);
    }

    /// Immutable copy of the current state.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        MetricsSnapshot {
            counters: g.counters.clone(),
            gauges: g.gauges.clone(),
            hists: g.hists.clone(),
        }
    }
}

/// Frozen registry state: what reports embed under `"metrics"` and the
/// bench artifact stores per scenario. Round-trips losslessly through
/// [`MetricsSnapshot::to_json`] / [`MetricsSnapshot::from_json`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub hists: BTreeMap<String, LogHist>,
}

impl MetricsSnapshot {
    /// Counter value (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Gauge value, if set.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Histogram by name, if present.
    pub fn hist(&self, name: &str) -> Option<&LogHist> {
        self.hists.get(name)
    }

    /// Gauges whose key starts with `prefix`, in key order.
    pub fn gauges_with_prefix(&self, prefix: &str) -> Vec<(&str, f64)> {
        self.gauges
            .iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .map(|(k, &v)| (k.as_str(), v))
            .collect()
    }

    /// Merge another snapshot: counters add, gauges take the max (they
    /// are high-water marks or identical run constants), histograms merge.
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        for (k, &v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, &v) in &other.gauges {
            let e = self.gauges.entry(k.clone()).or_insert(f64::NEG_INFINITY);
            if v > *e {
                *e = v;
            }
        }
        for (k, h) in &other.hists {
            self.hists.entry(k.clone()).or_default().merge(h);
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "counters",
                Json::Obj(
                    self.counters
                        .iter()
                        .map(|(k, &v)| (k.clone(), Json::num(v as f64)))
                        .collect(),
                ),
            ),
            (
                "gauges",
                Json::Obj(
                    self.gauges
                        .iter()
                        .map(|(k, &v)| (k.clone(), Json::num(v)))
                        .collect(),
                ),
            ),
            (
                "hists",
                Json::Obj(
                    self.hists
                        .iter()
                        .map(|(k, h)| (k.clone(), h.to_json()))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<MetricsSnapshot> {
        let obj = |key: &str| -> Result<&BTreeMap<String, Json>> {
            match j.req(key)? {
                Json::Obj(m) => Ok(m),
                _ => anyhow::bail!("metrics field {key} must be an object"),
            }
        };
        let mut s = MetricsSnapshot::default();
        for (k, v) in obj("counters")? {
            s.counters.insert(
                k.clone(),
                v.as_usize().with_context(|| format!("counter {k}"))? as u64,
            );
        }
        for (k, v) in obj("gauges")? {
            s.gauges
                .insert(k.clone(), v.as_f64().with_context(|| format!("gauge {k}"))?);
        }
        for (k, v) in obj("hists")? {
            s.hists.insert(
                k.clone(),
                LogHist::from_json(v).with_context(|| format!("histogram {k}"))?,
            );
        }
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_hists_accumulate() {
        let r = MetricsRegistry::new();
        r.inc("admitted", 3);
        r.inc("admitted", 2);
        r.gauge_max("queue_depth_peak/g0", 2.0);
        r.gauge_max("queue_depth_peak/g0", 1.0);
        r.observe("latency", 0.02);
        r.observe("latency", 0.04);
        let s = r.snapshot();
        assert_eq!(s.counter("admitted"), 5);
        assert_eq!(s.counter("missing"), 0);
        assert_eq!(s.gauge("queue_depth_peak/g0"), Some(2.0));
        assert_eq!(s.hist("latency").map(|h| h.count()), Some(2));
    }

    #[test]
    fn snapshot_round_trips_through_json() {
        let r = MetricsRegistry::new();
        r.inc("departed", 7);
        r.gauge_set("wall_s", 1.25);
        r.observe("stage_service/g0r0s0", 0.003);
        let s = r.snapshot();
        let j = s.to_json();
        let back = MetricsSnapshot::from_json(&j).expect("deserializes");
        assert_eq!(s, back);
        assert_eq!(j.to_string(), back.to_json().to_string());
    }

    #[test]
    fn snapshot_merge_adds_counters_and_merges_hists() {
        let a = MetricsRegistry::new();
        a.inc("admitted", 2);
        a.observe("latency", 0.1);
        let b = MetricsRegistry::new();
        b.inc("admitted", 3);
        b.observe("latency", 0.2);
        b.gauge_max("queue_depth_peak/g0", 4.0);
        let mut s = a.snapshot();
        s.merge(&b.snapshot());
        assert_eq!(s.counter("admitted"), 5);
        assert_eq!(s.hist("latency").map(|h| h.count()), Some(2));
        assert_eq!(s.gauge("queue_depth_peak/g0"), Some(4.0));
    }

    #[test]
    fn prefix_query_is_sorted_and_filtered() {
        let r = MetricsRegistry::new();
        r.gauge_set("occupancy/g0r0s1", 0.5);
        r.gauge_set("occupancy/g0r0s0", 0.9);
        r.gauge_set("wall_s", 3.0);
        let s = r.snapshot();
        let occ = s.gauges_with_prefix("occupancy/");
        assert_eq!(
            occ,
            vec![("occupancy/g0r0s0", 0.9), ("occupancy/g0r0s1", 0.5)]
        );
    }
}
