//! The per-item span model: what one recorded event means.
//!
//! A span is a `Copy` struct of indices and two timestamps — no strings,
//! no allocation — so recording one on the stage hot path is a bounds
//! check and a `Vec::push` under a mutex when tracing is on, and a single
//! branch when it is off (DESIGN.md §13).
//!
//! Item lifecycle, per (group, item):
//!
//! ```text
//! Admit ──► Stage(0) ──► Stage(1) ──► … ──► Stage(P-1) ──► Depart
//!   └──► (nothing else)                      when the item was Shed
//! ```
//!
//! `group` is the board index on the cluster paths, the tenant index on
//! the multi-tenant paths, and `0` for single-plan serving. `item` is
//! unique within its group; the DES twins use the arrival index (so
//! same-seed traces are bit-identical), the wall twins use
//! `replica << 32 | sequence` (FIFO order through a replica's stages
//! makes the per-stage sequence number a stable item identity).

/// What a [`Span`] records. The discriminant order is the canonical sort
/// order inside one item's chain: admission, then sheds, then stage
/// service in pipeline order, then departure.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// Item arrived at the front door / dispatcher and was accepted.
    Admit,
    /// Item arrived and was turned away (admission queue full). A shed
    /// item's chain is this single span.
    Shed,
    /// One stage's service on one replica (`replica`/`stage` are set).
    Stage,
    /// Item left the last stage — end-to-end latency is
    /// `depart.t1 - admit.t0`.
    Depart,
}

impl SpanKind {
    /// Stable lowercase name used by the JSONL exporter.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Admit => "admit",
            SpanKind::Shed => "shed",
            SpanKind::Stage => "stage",
            SpanKind::Depart => "depart",
        }
    }

    /// Inverse of [`SpanKind::name`].
    pub fn parse(s: &str) -> Option<SpanKind> {
        match s {
            "admit" => Some(SpanKind::Admit),
            "shed" => Some(SpanKind::Shed),
            "stage" => Some(SpanKind::Stage),
            "depart" => Some(SpanKind::Depart),
            _ => None,
        }
    }
}

/// One recorded event (see module docs for field semantics). Timestamps
/// are seconds on the twin's own clock: simulated time in the DES,
/// elapsed time on the shared wall clock in the thread fleets.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    /// Board index (cluster), tenant index (multi-tenant), else 0.
    pub group: u32,
    /// Item identity, unique within `group`.
    pub item: u64,
    /// Replica that served the item (0 when unknown/not applicable).
    pub replica: u32,
    /// Stage index for [`SpanKind::Stage`] spans; 0 otherwise.
    pub stage: u32,
    pub kind: SpanKind,
    /// Span start (s). Zero-width spans (Admit/Shed/Depart) set `t1 == t0`.
    pub t0: f64,
    /// Span end (s).
    pub t1: f64,
}

impl Span {
    /// Canonical ordering key: group, then item, then time, then kind —
    /// this is the order the exporter writes, which makes same-seed DES
    /// dumps byte-identical regardless of recording interleavings.
    pub fn sort_key(&self) -> (u32, u64, f64, SpanKind, u32) {
        (self.group, self.item, self.t0, self.kind, self.stage)
    }
}

/// Total-order comparison of two span sort keys (`f64` compared with
/// `total_cmp`, so the sort is deterministic even for equal timestamps).
pub fn span_cmp(a: &Span, b: &Span) -> std::cmp::Ordering {
    let (ag, ai, at, ak, asg) = a.sort_key();
    let (bg, bi, bt, bk, bsg) = b.sort_key();
    ag.cmp(&bg)
        .then(ai.cmp(&bi))
        .then(at.total_cmp(&bt))
        .then(ak.cmp(&bk))
        .then(asg.cmp(&bsg))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_round_trip() {
        for k in [SpanKind::Admit, SpanKind::Shed, SpanKind::Stage, SpanKind::Depart] {
            assert_eq!(SpanKind::parse(k.name()), Some(k));
        }
        assert_eq!(SpanKind::parse("bogus"), None);
    }

    #[test]
    fn sort_orders_one_item_chain_admit_stages_depart() {
        let item = |kind, stage, t0: f64| Span {
            group: 0,
            item: 4,
            replica: 1,
            stage,
            kind,
            t0,
            t1: t0,
        };
        let mut spans = vec![
            item(SpanKind::Depart, 0, 3.0),
            item(SpanKind::Stage, 1, 2.0),
            item(SpanKind::Admit, 0, 0.0),
            item(SpanKind::Stage, 0, 1.0),
        ];
        spans.sort_by(span_cmp);
        let kinds: Vec<SpanKind> = spans.iter().map(|s| s.kind).collect();
        assert_eq!(
            kinds,
            vec![SpanKind::Admit, SpanKind::Stage, SpanKind::Stage, SpanKind::Depart]
        );
    }
}
