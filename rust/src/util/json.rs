//! Minimal JSON parser/serializer (offline environment vendors no serde
//! facade). Full JSON grammar minus exotic number forms; enough for the
//! artifact manifests and config files this repo reads and writes.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing required key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn usize_arr(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn f64_arr(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl fmt::Display for Json {
    /// Compact serialization (round-trips through `Json::parse`).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes.
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"name":"pipenet","layers":[{"gemm":{"k":27,"m":16,"n":1024}}],"ok":true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn usize_accessors() {
        let v = Json::parse(r#"{"n": 42, "f": 1.5, "neg": -1, "shape": [3, 4]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(42));
        assert_eq!(v.get("f").unwrap().as_usize(), None);
        assert_eq!(v.get("neg").unwrap().as_usize(), None);
        assert_eq!(v.get("shape").unwrap().usize_arr(), Some(vec![3, 4]));
    }

    #[test]
    fn f64_arr_accessor() {
        let v = Json::parse(r#"{"xs": [1.5, -2, 3e2], "bad": [1, "x"]}"#).unwrap();
        assert_eq!(v.get("xs").unwrap().f64_arr(), Some(vec![1.5, -2.0, 300.0]));
        assert_eq!(v.get("bad").unwrap().f64_arr(), None);
        assert_eq!(Json::parse("[]").unwrap().f64_arr(), Some(Vec::new()));
    }
}
