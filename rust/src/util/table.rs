//! Plain-text table rendering for the paper-table benches and CLI reports.

/// Column-aligned text table. Rows are strings; numeric formatting is the
/// caller's responsibility.
#[derive(Debug, Default, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncols)
                .map(|i| format!(" {:<width$} ", cells[i], width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a float with `d` decimals — sugar for table cells.
pub fn f(x: f64, d: usize) -> String {
    format!("{x:.d$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["CNN", "Imgs/s"]);
        t.row(vec!["AlexNet".into(), "8.1".into()]);
        t.row(vec!["X".into(), "12.75".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.contains("AlexNet"));
        // All data lines equally wide.
        let lines: Vec<&str> = s.lines().skip(1).collect();
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    #[should_panic]
    fn rejects_ragged_rows() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
