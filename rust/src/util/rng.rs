//! Deterministic pseudo-random number generation (SplitMix64).
//!
//! The offline environment vendors no `rand` facade, so the repo carries its
//! own small PRNG. SplitMix64 is statistically solid for workload generation,
//! stream synthesis and property tests, and a single `u64` seed keeps every
//! experiment reproducible.

/// SplitMix64 PRNG (Steele et al., "Fast splittable pseudorandom number
/// generators", OOPSLA 2014). Deterministic, seedable, `Copy`-cheap.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [lo, hi] (inclusive).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.next_u64() % (hi - lo + 1)
    }

    /// Uniform usize in [0, n) — convenient for indexing.
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }

    /// Fill a vector with iid uniform [lo, hi) f32 values (image synthesis).
    pub fn f32_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.range_f64(lo as f64, hi as f64) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn range_u64_inclusive_bounds() {
        let mut r = Rng::new(17);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range_u64(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }
}
