//! Tiny property-testing helper (proptest is not in the offline vendor set).
//!
//! `check(cases, f)` runs `f` against `cases` independently-seeded RNGs and
//! reports the failing seed so a failure reproduces with `check_seed`.

use crate::util::rng::Rng;

/// Run a property `f(rng)` for `cases` random cases. `f` returns
/// `Err(description)` on violation; panics with the offending seed.
pub fn check<F>(cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for seed in 0..cases {
        let mut rng = Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E37_79B9));
        if let Err(msg) = f(&mut rng) {
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

/// Re-run a single seed (debugging aid for failures reported by `check`).
pub fn check_seed<F>(seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E37_79B9));
    if let Err(msg) = f(&mut rng) {
        panic!("property failed at seed {seed}: {msg}");
    }
}

/// Assert helper returning the Result shape `check` expects.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check(25, |rng| {
            n += 1;
            let x = rng.uniform();
            prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
            Ok(())
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property failed at seed")]
    fn failing_property_panics_with_seed() {
        check(10, |rng| {
            let x = rng.uniform();
            prop_assert!(x < 0.5, "got {x}");
            Ok(())
        });
    }
}
