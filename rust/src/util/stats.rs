//! Summary statistics and a fixed-capacity latency histogram for metrics.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation on a sorted copy. `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Mean absolute percentage error — the paper's Table III metric.
pub fn mape(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let s: f64 = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| ((p - t) / t).abs())
        .sum();
    100.0 * s / pred.len() as f64
}

/// Simple streaming summary used by coordinator metrics.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        mean(&self.samples)
    }

    pub fn p50(&self) -> f64 {
        percentile(&self.samples, 50.0)
    }

    pub fn p95(&self) -> f64 {
        percentile(&self.samples, 95.0)
    }

    pub fn p99(&self) -> f64 {
        percentile(&self.samples, 99.0)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Absorb another summary's samples (fleet-level report merging: the
    /// percentile queries then answer over the union of all replicas).
    pub fn merge(&mut self, other: &Summary) {
        self.samples.extend_from_slice(&other.samples);
    }

    /// Raw samples, in recording order.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [9.0, 1.0, 5.0];
        assert_eq!(percentile(&xs, 50.0), 5.0);
    }

    #[test]
    fn mape_basic() {
        let truth = [10.0, 20.0];
        let pred = [11.0, 18.0];
        // (10% + 10%) / 2 = 10%
        assert!((mape(&pred, &truth) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn summary_merge_unions_samples() {
        let mut a = Summary::new();
        a.record(1.0);
        a.record(2.0);
        let mut b = Summary::new();
        b.record(10.0);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max(), 10.0);
        assert_eq!(a.samples(), &[1.0, 2.0, 10.0]);
    }

    #[test]
    fn summary_percentiles() {
        let mut s = Summary::new();
        for i in 1..=100 {
            s.record(i as f64);
        }
        assert_eq!(s.count(), 100);
        assert!((s.p50() - 50.5).abs() < 1.0);
        assert!(s.p95() > 90.0 && s.p99() > 95.0);
        assert_eq!(s.max(), 100.0);
    }
}
