//! Summary statistics for metrics and the benchmark harness: percentiles,
//! and the robust trio the bench runner gates regressions on — median,
//! MAD-based outlier rejection ([`mad_filter`]), and a seeded bootstrap
//! confidence interval of the median ([`bootstrap_ci_median`]).
//!
//! Every helper is total on empty and single-element inputs (no panics, no
//! indexing past the end): empty slices yield 0.0-style neutral values and
//! singletons yield the element itself. The latency-report builders in
//! [`crate::api`] rely on this — a fully-shed tenant produces an empty
//! latency set.

use crate::util::rng::Rng;

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile on an ALREADY ascending-sorted slice via linear
/// interpolation; `q` in [0, 100]. 0.0 for empty input; the single element
/// for singletons. Monotone in `q` by construction (the interpolant of a
/// sorted sequence is nondecreasing), so p50 <= p95 <= p99 always holds.
pub fn percentile_sorted(v: &[f64], q: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Percentile via linear interpolation on a sorted copy. `q` in [0, 100].
/// Use [`percentile_sorted`] to amortize the sort across several queries.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    percentile_sorted(&v, q)
}

/// Median (p50). 0.0 for empty input.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Median absolute deviation (raw, unscaled). 0.0 for empty input.
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = median(xs);
    let dev: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&dev)
}

/// Consistency factor making MAD comparable to a standard deviation under
/// normality (1 / Phi^-1(3/4)).
pub const MAD_NORMAL_SCALE: f64 = 1.4826;

/// MAD-based outlier rejection, iterated to a fixpoint: repeatedly drop
/// points with `|x - median| > k * 1.4826 * MAD` until a pass removes
/// nothing. Requires `k >= 1` so every pass keeps at least the half of the
/// sample whose deviations are at or below the MAD — the filter can never
/// empty a non-empty sample, and the fixpoint makes it exactly idempotent
/// (`mad_filter(&mad_filter(xs, k), k)` returns its input unchanged).
///
/// Samples with fewer than 3 points, or a zero MAD (majority already at
/// the median), are returned unchanged — there is no robust scale to
/// reject against.
pub fn mad_filter(xs: &[f64], k: f64) -> Vec<f64> {
    assert!(k >= 1.0, "mad_filter needs k >= 1 (got {k})");
    let mut cur = xs.to_vec();
    loop {
        if cur.len() < 3 {
            return cur;
        }
        let m = median(&cur);
        let d = mad(&cur);
        if d <= 0.0 {
            return cur;
        }
        let bound = k * MAD_NORMAL_SCALE * d;
        let next: Vec<f64> =
            cur.iter().copied().filter(|x| (x - m).abs() <= bound).collect();
        if next.len() == cur.len() {
            return cur;
        }
        cur = next;
    }
}

/// Seeded percentile-bootstrap confidence interval of the MEDIAN:
/// `resamples` bootstrap resamples (drawn with the deterministic SplitMix64
/// stream of `seed`), interval = the central `confidence` mass of the
/// resampled medians, widened if necessary to contain the sample median
/// (the point estimate is always inside its own interval). Returns
/// `(0.0, 0.0)` for empty input and a degenerate `(m, m)` for singletons.
pub fn bootstrap_ci_median(
    xs: &[f64],
    confidence: f64,
    resamples: usize,
    seed: u64,
) -> (f64, f64) {
    assert!(
        confidence > 0.0 && confidence < 1.0,
        "confidence must be in (0, 1), got {confidence}"
    );
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let m = median(xs);
    if xs.len() == 1 || resamples == 0 {
        return (m, m);
    }
    let mut rng = Rng::new(seed);
    let mut meds: Vec<f64> = (0..resamples)
        .map(|_| {
            let resample: Vec<f64> =
                (0..xs.len()).map(|_| xs[rng.index(xs.len())]).collect();
            median(&resample)
        })
        .collect();
    meds.sort_by(|a, b| a.total_cmp(b));
    let alpha = (1.0 - confidence) / 2.0;
    let lo = percentile_sorted(&meds, 100.0 * alpha);
    let hi = percentile_sorted(&meds, 100.0 * (1.0 - alpha));
    (lo.min(m), hi.max(m))
}

/// Mean absolute percentage error — the paper's Table III metric.
pub fn mape(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let s: f64 = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| ((p - t) / t).abs())
        .sum();
    100.0 * s / pred.len() as f64
}

/// Simple streaming summary used by coordinator metrics.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        mean(&self.samples)
    }

    pub fn p50(&self) -> f64 {
        percentile(&self.samples, 50.0)
    }

    pub fn p95(&self) -> f64 {
        percentile(&self.samples, 95.0)
    }

    pub fn p99(&self) -> f64 {
        percentile(&self.samples, 99.0)
    }

    /// Largest recorded sample; 0.0 for an empty summary (a report that
    /// never saw an item must stay printable, not `-inf`).
    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(0.0, f64::max)
    }

    /// Wrap an already-pooled sample vector (what
    /// [`crate::obs::pool_latencies`] returns) — the constructor fleet
    /// report assembly uses now that the per-replica merge loop lives in
    /// one place.
    pub fn from_samples(samples: Vec<f64>) -> Summary {
        Summary { samples }
    }

    /// Absorb another summary's samples (fleet-level report merging: the
    /// percentile queries then answer over the union of all replicas).
    pub fn merge(&mut self, other: &Summary) {
        self.samples.extend_from_slice(&other.samples);
    }

    /// Raw samples, in recording order.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [9.0, 1.0, 5.0];
        assert_eq!(percentile(&xs, 50.0), 5.0);
    }

    #[test]
    fn mape_basic() {
        let truth = [10.0, 20.0];
        let pred = [11.0, 18.0];
        // (10% + 10%) / 2 = 10%
        assert!((mape(&pred, &truth) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn summary_merge_unions_samples() {
        let mut a = Summary::new();
        a.record(1.0);
        a.record(2.0);
        let mut b = Summary::new();
        b.record(10.0);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max(), 10.0);
        assert_eq!(a.samples(), &[1.0, 2.0, 10.0]);
    }

    #[test]
    fn percentile_empty_and_single_are_well_defined() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[], 99.0), 0.0);
        assert_eq!(percentile(&[7.5], 50.0), 7.5);
        assert_eq!(percentile(&[7.5], 99.0), 7.5);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(mad(&[]), 0.0);
        assert_eq!(mad(&[3.0]), 0.0);
        assert_eq!(bootstrap_ci_median(&[], 0.95, 100, 1), (0.0, 0.0));
        assert_eq!(bootstrap_ci_median(&[4.0], 0.95, 100, 1), (4.0, 4.0));
    }

    #[test]
    fn median_of_known_samples() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert!((median(&[1.0, 2.0, 3.0, 4.0]) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn mad_of_known_sample() {
        // median 2, deviations [1, 0, 1, 2, 7] -> sorted [0,1,1,2,7], MAD 1.
        assert_eq!(mad(&[1.0, 2.0, 3.0, 4.0, 9.0]), 1.0);
    }

    #[test]
    fn mad_filter_drops_the_gross_outlier() {
        let xs = [10.0, 10.1, 9.9, 10.05, 9.95, 1000.0];
        let kept = mad_filter(&xs, 3.5);
        assert_eq!(kept.len(), 5);
        assert!(!kept.contains(&1000.0));
    }

    #[test]
    fn mad_filter_keeps_constant_and_tiny_samples() {
        assert_eq!(mad_filter(&[5.0, 5.0, 5.0, 9.0], 1.0), vec![5.0, 5.0, 5.0, 9.0]);
        assert_eq!(mad_filter(&[1.0, 100.0], 1.0), vec![1.0, 100.0]);
        assert_eq!(mad_filter(&[], 1.0), Vec::<f64>::new());
    }

    #[test]
    fn bootstrap_ci_is_deterministic_by_seed() {
        let xs: Vec<f64> = (0..40).map(|i| (i as f64 * 0.37).sin() + 10.0).collect();
        let a = bootstrap_ci_median(&xs, 0.95, 300, 42);
        let b = bootstrap_ci_median(&xs, 0.95, 300, 42);
        assert_eq!(a, b);
        assert!(a.0 <= a.1);
    }

    /// Satellite property: percentiles are monotone in q (p50 <= p95 <= p99)
    /// on arbitrary samples, pinned seeds via `util::proptest`.
    #[test]
    fn property_percentile_monotone_in_q() {
        use crate::util::proptest::check;
        check(200, |rng| {
            let n = 1 + rng.index(50);
            let xs: Vec<f64> = (0..n).map(|_| rng.range_f64(-50.0, 50.0)).collect();
            let p50 = percentile(&xs, 50.0);
            let p95 = percentile(&xs, 95.0);
            let p99 = percentile(&xs, 99.0);
            crate::prop_assert!(
                p50 <= p95 && p95 <= p99,
                "percentiles not monotone: p50={p50} p95={p95} p99={p99} on {xs:?}"
            );
            let lo = xs.iter().copied().fold(f64::INFINITY, f64::min);
            let hi = xs.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            crate::prop_assert!(
                p50 >= lo - 1e-12 && p99 <= hi + 1e-12,
                "percentiles escape the sample range"
            );
            Ok(())
        });
    }

    /// Satellite property: the bootstrap CI always contains the sample
    /// median, at every sample size >= 1.
    #[test]
    fn property_bootstrap_ci_contains_sample_median() {
        use crate::util::proptest::check;
        check(150, |rng| {
            let n = 1 + rng.index(30);
            let xs: Vec<f64> =
                (0..n).map(|_| rng.normal_with(5.0, 2.0)).collect();
            let m = median(&xs);
            let (lo, hi) = bootstrap_ci_median(&xs, 0.95, 120, rng.next_u64());
            crate::prop_assert!(
                lo <= m && m <= hi,
                "CI [{lo}, {hi}] misses the sample median {m} (n={n})"
            );
            Ok(())
        });
    }

    /// Satellite property: MAD outlier rejection is idempotent and never
    /// empties a non-empty sample.
    #[test]
    fn property_mad_filter_idempotent_never_empty() {
        use crate::util::proptest::check;
        check(150, |rng| {
            let n = 1 + rng.index(40);
            let mut xs: Vec<f64> =
                (0..n).map(|_| rng.normal_with(20.0, 1.0)).collect();
            // Mix in occasional gross outliers.
            for _ in 0..rng.index(4) {
                xs.push(rng.range_f64(-500.0, 500.0));
            }
            let k = 1.0 + rng.range_f64(0.0, 4.0);
            let once = mad_filter(&xs, k);
            crate::prop_assert!(
                !once.is_empty(),
                "filter emptied a {}-point sample (k={k})",
                xs.len()
            );
            crate::prop_assert!(
                once.len() <= xs.len(),
                "filter grew the sample"
            );
            let twice = mad_filter(&once, k);
            crate::prop_assert!(
                once == twice,
                "filter not idempotent: {once:?} vs {twice:?} (k={k})"
            );
            Ok(())
        });
    }

    #[test]
    fn empty_summary_is_well_defined() {
        let s = Summary::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p50(), 0.0);
        assert_eq!(s.p99(), 0.0);
        assert_eq!(s.max(), 0.0, "empty summary must not report -inf");
    }

    #[test]
    fn summary_percentiles() {
        let mut s = Summary::new();
        for i in 1..=100 {
            s.record(i as f64);
        }
        assert_eq!(s.count(), 100);
        assert!((s.p50() - 50.5).abs() < 1.0);
        assert!(s.p95() > 90.0 && s.p99() > 95.0);
        assert_eq!(s.max(), 100.0);
    }
}
