//! Small dense linear algebra for the performance-model regressions.
//!
//! The paper fits Eq. (5)–(8) with ordinary least squares; the design
//! matrices here are tiny (8 features), so a plain normal-equation solve with
//! partial-pivot Gaussian elimination is exact enough and dependency-free.

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub a: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, a: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Mat {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        Mat { rows: r, cols: c, a: rows.iter().flatten().copied().collect() }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        &mut self.a[i * self.cols + j]
    }

    /// self^T * self  (Gram matrix).
    pub fn gram(&self) -> Mat {
        let mut g = Mat::zeros(self.cols, self.cols);
        for i in 0..self.cols {
            for j in i..self.cols {
                let mut s = 0.0;
                for r in 0..self.rows {
                    s += self.at(r, i) * self.at(r, j);
                }
                *g.at_mut(i, j) = s;
                *g.at_mut(j, i) = s;
            }
        }
        g
    }

    /// self^T * y.
    pub fn t_vec(&self, y: &[f64]) -> Vec<f64> {
        assert_eq!(y.len(), self.rows);
        let mut out = vec![0.0; self.cols];
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[c] += self.at(r, c) * y[r];
            }
        }
        out
    }
}

/// Solve A x = b via Gaussian elimination with partial pivoting.
/// Returns `None` for (numerically) singular systems.
pub fn solve(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    assert_eq!(a.rows, a.cols);
    assert_eq!(b.len(), a.rows);
    let n = a.rows;
    let mut m = a.clone();
    let mut x = b.to_vec();

    for col in 0..n {
        // Pivot.
        let (piv, piv_val) = (col..n)
            .map(|r| (r, m.at(r, col).abs()))
            .max_by(|p, q| p.1.total_cmp(&q.1))?;
        if piv_val < 1e-12 {
            return None;
        }
        if piv != col {
            for j in 0..n {
                let tmp = m.at(col, j);
                *m.at_mut(col, j) = m.at(piv, j);
                *m.at_mut(piv, j) = tmp;
            }
            x.swap(col, piv);
        }
        // Eliminate below.
        for r in col + 1..n {
            let f = m.at(r, col) / m.at(col, col);
            if f == 0.0 {
                continue;
            }
            for j in col..n {
                *m.at_mut(r, j) -= f * m.at(col, j);
            }
            x[r] -= f * x[col];
        }
    }
    // Back-substitute.
    for col in (0..n).rev() {
        x[col] /= m.at(col, col);
        for r in 0..col {
            let f = m.at(r, col);
            x[r] -= f * x[col];
            *m.at_mut(r, col) = 0.0;
        }
    }
    Some(x)
}

/// Ordinary least squares: minimize ||X beta - y||^2.
/// Adds a tiny ridge (1e-9 * trace/n) for numerical robustness on
/// near-collinear designs (e.g. interaction terms over a coarse grid).
pub fn ols(x: &Mat, y: &[f64]) -> Option<Vec<f64>> {
    let mut g = x.gram();
    let trace: f64 = (0..g.rows).map(|i| g.at(i, i)).sum();
    let ridge = 1e-9 * trace / g.rows.max(1) as f64;
    for i in 0..g.rows {
        *g.at_mut(i, i) += ridge;
    }
    let xty = x.t_vec(y);
    solve(&g, &xty)
}

/// Weighted least squares: minimize sum_i w_i^2 (x_i . beta - y_i)^2.
/// With `w_i = 1 / y_i` this minimizes *relative* error, which is the
/// objective the paper's percentage-error metric implies (measurements span
/// five orders of magnitude across the micro-benchmark grid).
pub fn wls(x: &Mat, y: &[f64], w: &[f64]) -> Option<Vec<f64>> {
    assert_eq!(x.rows, y.len());
    assert_eq!(x.rows, w.len());
    let mut xs = x.clone();
    let mut ys = y.to_vec();
    for r in 0..x.rows {
        for c in 0..x.cols {
            *xs.at_mut(r, c) *= w[r];
        }
        ys[r] *= w[r];
    }
    ols(&xs, &ys)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn solve_identity() {
        let a = Mat::from_rows(&[vec![1.0, 0.0], vec![0.0, 1.0]]);
        assert_eq!(solve(&a, &[3.0, 4.0]).unwrap(), vec![3.0, 4.0]);
    }

    #[test]
    fn solve_requires_pivoting() {
        // Leading zero forces a row swap.
        let a = Mat::from_rows(&[vec![0.0, 2.0], vec![3.0, 1.0]]);
        let x = solve(&a, &[4.0, 5.0]).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn solve_singular_is_none() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(solve(&a, &[1.0, 2.0]).is_none());
    }

    #[test]
    fn ols_recovers_known_coefficients() {
        // y = 2 + 3*x1 - 0.5*x2 with noise-free data.
        let mut rng = Rng::new(5);
        let mut rows = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..50 {
            let x1 = rng.range_f64(0.0, 10.0);
            let x2 = rng.range_f64(-5.0, 5.0);
            rows.push(vec![1.0, x1, x2]);
            ys.push(2.0 + 3.0 * x1 - 0.5 * x2);
        }
        let beta = ols(&Mat::from_rows(&rows), &ys).unwrap();
        assert!((beta[0] - 2.0).abs() < 1e-6);
        assert!((beta[1] - 3.0).abs() < 1e-6);
        assert!((beta[2] + 0.5).abs() < 1e-6);
    }

    #[test]
    fn ols_with_noise_is_close() {
        let mut rng = Rng::new(9);
        let mut rows = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..500 {
            let x1 = rng.range_f64(0.0, 10.0);
            rows.push(vec![1.0, x1]);
            ys.push(1.0 + 4.0 * x1 + rng.normal_with(0.0, 0.1));
        }
        let beta = ols(&Mat::from_rows(&rows), &ys).unwrap();
        assert!((beta[0] - 1.0).abs() < 0.1);
        assert!((beta[1] - 4.0).abs() < 0.05);
    }
}
