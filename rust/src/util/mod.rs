//! In-tree substrates: the offline build environment vendors only the `xla`
//! crate's dependency closure, so JSON, RNG, linear algebra, CLI parsing,
//! the bench harness and property testing are implemented here.

pub mod bench;
pub mod cli;
pub mod json;
pub mod linalg;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
