//! In-tree substrates: the offline build environment vendors only the `xla`
//! crate's dependency closure, so JSON, RNG, linear algebra, CLI parsing
//! and property testing are implemented here. (Micro-benchmark timing
//! moved into [`crate::harness`], which owns all benchmark machinery.)

pub mod cli;
pub mod json;
pub mod linalg;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
