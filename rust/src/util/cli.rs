//! Minimal command-line argument parsing (no clap in the offline vendor set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments, which covers the whole `pipeit` CLI surface.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    /// Last occurrence of each option (the lookup map behind [`Args::get`]).
    pub options: BTreeMap<String, String>,
    /// Every `(key, value)` occurrence in argv order — what repeatable
    /// options like `--tenant` read through [`Args::get_all`].
    pub occurrences: Vec<(String, String)>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    /// `bool_flags` lists option names that take no value; every other
    /// `--name` must be followed by a value (or written `--name=value`).
    /// A missing value — end of argv, or a next token that itself starts
    /// with `--` — is a parse error, so a typo like `--net --replicas 2`
    /// fails loudly instead of silently degrading `--net` to a flag.
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        bool_flags: &[&str],
    ) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.occurrences.push((k.to_string(), v.to_string()));
                    out.options.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else {
                    match it.peek() {
                        Some(v) if !v.starts_with("--") => {
                            let v = it.next().expect("peeked value");
                            out.occurrences.push((body.to_string(), v.clone()));
                            out.options.insert(body.to_string(), v);
                        }
                        Some(v) => anyhow::bail!(
                            "option --{body} expects a value, found {v:?} \
                             (write --{body}=VALUE if the value starts with '--')"
                        ),
                        None => anyhow::bail!("option --{body} expects a value"),
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Comma-separated list form of an option: `--throttle 1:2,5:0.5` →
    /// `["1:2", "5:0.5"]`. A missing key yields an empty list; empty items
    /// (trailing commas) are dropped.
    pub fn get_list(&self, key: &str) -> Vec<&str> {
        self.get(key)
            .map(|v| v.split(',').filter(|s| !s.is_empty()).collect())
            .unwrap_or_default()
    }

    /// Every occurrence of a repeatable option, in argv order — the form
    /// `pipeit plan-multi --tenant ... --tenant ...` reads. [`Args::get`]
    /// keeps only the last occurrence; this returns them all.
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.occurrences
            .iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from), &["measured", "verbose"])
            .expect("well-formed args")
    }

    #[test]
    fn positional_and_options() {
        let a = parse("explore --net resnet50 --images 50");
        assert_eq!(a.positional, vec!["explore"]);
        assert_eq!(a.get("net"), Some("resnet50"));
        assert_eq!(a.get_usize("images", 0).unwrap(), 50);
    }

    #[test]
    fn equals_form_and_flags() {
        let a = parse("simulate --net=alexnet --measured --pipeline B4-s4");
        assert_eq!(a.get("net"), Some("alexnet"));
        assert!(a.has_flag("measured"));
        assert_eq!(a.get("pipeline"), Some("B4-s4"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --verbose");
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("x --images many");
        assert!(a.get_usize("images", 1).is_err());
    }

    #[test]
    fn equals_form_value_may_start_with_dashes() {
        let a = parse("x --note=--weird");
        assert_eq!(a.get("note"), Some("--weird"));
    }

    #[test]
    fn missing_trailing_value_is_an_error() {
        let err = Args::parse(["--net".to_string()].into_iter(), &[])
            .expect_err("trailing --net must not parse");
        assert!(err.to_string().contains("--net expects a value"), "{err}");
    }

    #[test]
    fn option_swallowing_another_option_is_an_error() {
        // The typo this used to hide: `--net --replicas 2` degraded --net
        // to a flag and silently dropped the network.
        let raw = ["--net", "--replicas", "2"].map(String::from);
        let err = Args::parse(raw.into_iter(), &[])
            .expect_err("--net without a value must not parse");
        assert!(err.to_string().contains("--net expects a value"), "{err}");
        assert!(err.to_string().contains("--replicas"), "{err}");
    }

    #[test]
    fn declared_bool_flag_never_consumes_a_value() {
        let a = parse("x --measured --images 5");
        assert!(a.has_flag("measured"));
        assert_eq!(a.get_usize("images", 0).unwrap(), 5);
    }

    #[test]
    fn get_all_returns_every_occurrence_in_order() {
        let a = parse("plan-multi --tenant net=alexnet,rate=30 --tenant net=squeezenet,rate=60");
        assert_eq!(
            a.get_all("tenant"),
            vec!["net=alexnet,rate=30", "net=squeezenet,rate=60"]
        );
        // `get` keeps the last occurrence, as before.
        assert_eq!(a.get("tenant"), Some("net=squeezenet,rate=60"));
        assert_eq!(a.get_all("missing"), Vec::<&str>::new());
    }

    #[test]
    fn get_all_mixes_equals_and_space_forms() {
        let a = parse("x --t=first --other 1 --t second --t=third");
        assert_eq!(a.get_all("t"), vec!["first", "second", "third"]);
        assert_eq!(a.get("other"), Some("1"));
    }

    #[test]
    fn get_all_single_occurrence_matches_get() {
        let a = parse("x --net alexnet");
        assert_eq!(a.get_all("net"), vec!["alexnet"]);
        assert_eq!(a.get("net"), Some("alexnet"));
    }

    #[test]
    fn get_list_splits_on_commas() {
        let a = parse("x --throttle 1:2:big,5:0.5");
        assert_eq!(a.get_list("throttle"), vec!["1:2:big", "5:0.5"]);
        assert_eq!(a.get_list("missing"), Vec::<&str>::new());
        let b = parse("x --throttle 1:2,");
        assert_eq!(b.get_list("throttle"), vec!["1:2"]);
    }
}
