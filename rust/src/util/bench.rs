//! Criterion-style micro-bench harness (criterion is not in the offline
//! vendor set). Warmup + timed iterations, reports mean/p50/p95 per bench,
//! used by the `cargo bench` targets (`harness = false`).

use std::time::{Duration, Instant};

use crate::util::stats;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        format!(
            "bench {:<44} iters={:<6} mean={:>12?} p50={:>12?} p95={:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p95
        )
    }
}

/// Bench runner: calibrates an iteration count to roughly hit the time
/// budget, then measures per-iteration latency.
pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    max_iters: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(100),
            budget: Duration::from_millis(600),
            max_iters: 10_000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new(warmup: Duration, budget: Duration, max_iters: usize) -> Self {
        Bencher { warmup, budget, max_iters, results: Vec::new() }
    }

    /// Quick harness for unit-ish benches in CI: tiny budget.
    pub fn quick() -> Self {
        Bencher::new(Duration::from_millis(10), Duration::from_millis(80), 1000)
    }

    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup and single-shot calibration.
        let cal_start = Instant::now();
        let mut warm_iters = 0usize;
        while cal_start.elapsed() < self.warmup {
            f();
            warm_iters += 1;
            if warm_iters >= self.max_iters {
                break;
            }
        }
        let per_iter = cal_start.elapsed().as_secs_f64() / warm_iters.max(1) as f64;
        let iters = ((self.budget.as_secs_f64() / per_iter.max(1e-9)) as usize)
            .clamp(5, self.max_iters);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        let res = BenchResult {
            name: name.to_string(),
            iters,
            mean: Duration::from_secs_f64(stats::mean(&samples)),
            p50: Duration::from_secs_f64(stats::percentile(&samples, 50.0)),
            p95: Duration::from_secs_f64(stats::percentile(&samples, 95.0)),
        };
        println!("{}", res.report_line());
        self.results.push(res);
        self.results.last().unwrap()
    }
}

/// Opaque value sink that defeats dead-code elimination (std black_box is
/// stable since 1.66; wrapped here so bench code reads uniformly).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        let mut b = Bencher::quick();
        let r = b.bench("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 5);
        assert!(r.mean.as_nanos() > 0);
        assert_eq!(b.results.len(), 1);
    }

    #[test]
    fn slower_code_measures_slower() {
        let mut b = Bencher::quick();
        let fast = b.bench("fast", || {
            black_box((0..10u64).sum::<u64>());
        }).mean;
        let slow = b.bench("slow", || {
            // black_box on the bound + accumulator defeats const-folding
            // in release builds.
            let n = black_box(200_000u64);
            let mut acc = 0u64;
            for i in 0..n {
                acc = acc.wrapping_add(black_box(i).wrapping_mul(3));
            }
            black_box(acc);
        }).mean;
        assert!(slow > fast);
    }
}
