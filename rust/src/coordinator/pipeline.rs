//! The real (wall-clock) pipeline executor: one OS thread per stage,
//! bounded queues between stages, graceful drain, full metrics.
//!
//! Stage functions are built *inside* their thread from a `Send` factory:
//! the PJRT client (`xla::PjRtClient`) is `Rc`-based and must never cross
//! threads, so each stage owns a private client + compiled executables
//! (DESIGN.md §1). On the paper's board this corresponds to pinning each
//! stage's ARM-CL thread pool to its cluster cores. The high-level entry
//! point is the plan facade ([`crate::api::Plan::deploy`]); [`RunReport`]
//! converts into the unified [`crate::api::ServeReport`] shape.

use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Instant;

use crate::util::stats::Summary;

use super::metrics::{RunReport, StageMetrics, StageObserver};
use super::queue::{bounded, Receiver};

/// Optional per-item service-time tap for a pipeline run: the observer plus
/// the replica index it should be reported under (0 for standalone
/// pipelines; [`crate::coordinator::run_fleet_observed`] passes each
/// replica's position).
pub type PipelineObserver = Option<(Arc<dyn StageObserver>, usize)>;

/// Readiness latch for stage setup (also used fleet-wide by
/// `coordinator::fleet`). Unlike `std::sync::Barrier`, it can be poisoned:
/// when a stage factory panics before reaching the rendezvous,
/// [`Ready::fail`] (via a drop guard) releases every waiter so the feeder
/// skips the stream and the panic propagates through `join` instead of the
/// whole pipeline deadlocking on a barrier that can never complete.
pub(super) struct Ready {
    state: Mutex<ReadyState>,
    cv: Condvar,
}

struct ReadyState {
    pending: usize,
    failed: bool,
}

impl Ready {
    pub(super) fn new(participants: usize) -> Arc<Ready> {
        Arc::new(Ready {
            state: Mutex::new(ReadyState { pending: participants, failed: false }),
            cv: Condvar::new(),
        })
    }

    /// Mark one participant's setup complete.
    pub(super) fn done(&self) {
        let mut st = self.state.lock().unwrap();
        st.pending -= 1;
        if st.pending == 0 {
            self.cv.notify_all();
        }
    }

    /// Poison the latch (a participant died during setup).
    pub(super) fn fail(&self) {
        let mut st = self.state.lock().unwrap();
        st.failed = true;
        self.cv.notify_all();
    }

    /// Block until every participant is ready or the latch is poisoned.
    /// Returns `true` when the pipeline may start.
    pub(super) fn wait(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.pending > 0 && !st.failed {
            st = self.cv.wait(st).unwrap();
        }
        !st.failed
    }
}

/// Poisons the latch if dropped while still armed (i.e. during unwinding
/// from a stage-factory panic).
pub(super) struct SetupFailGuard {
    pub(super) ready: Arc<Ready>,
    pub(super) armed: bool,
}

impl Drop for SetupFailGuard {
    fn drop(&mut self) {
        if self.armed {
            self.ready.fail();
        }
    }
}

/// Factory that constructs the per-thread stage function.
pub type StageFactory<T> = Box<dyn FnOnce() -> Box<dyn FnMut(T) -> T> + Send>;

/// One pipeline stage: display name + function factory.
pub struct StageSpec<T> {
    pub name: String,
    pub factory: StageFactory<T>,
}

impl<T> StageSpec<T> {
    pub fn new(name: &str, factory: StageFactory<T>) -> StageSpec<T> {
        StageSpec { name: name.to_string(), factory }
    }
}

struct Tagged<T> {
    item: T,
    admitted: Instant,
}

/// Run `source` items through the stages; returns the processed items (in
/// order) and the run report. `queue_cap` bounds every inter-stage buffer
/// (backpressure).
///
/// # Example
///
/// ```
/// use pipeit::coordinator::{run_pipeline, StageSpec};
///
/// let stages = vec![
///     StageSpec::new("double", Box::new(|| Box::new(|x: u32| x * 2))),
///     StageSpec::new("inc", Box::new(|| Box::new(|x: u32| x + 1))),
/// ];
/// let (out, report) = run_pipeline(stages, 2, 0..4u32);
/// assert_eq!(out, vec![1, 3, 5, 7]);
/// assert_eq!(report.images, 4);
/// assert_eq!(report.stages.len(), 2);
/// ```
pub fn run_pipeline<T, I>(
    stages: Vec<StageSpec<T>>,
    queue_cap: usize,
    source: I,
) -> (Vec<T>, RunReport)
where
    T: Send + 'static,
    I: IntoIterator<Item = T>,
{
    run_pipeline_observed(stages, queue_cap, source, None)
}

/// [`run_pipeline`] with a per-item service-time tap: after each processed
/// item, the stage worker reports the item's measured service time to the
/// observer (`observer.0`) under replica index `observer.1`. This is how
/// the online-adaptation telemetry ([`crate::adapt::Telemetry`]) sees the
/// live per-stage times without the executor knowing anything about
/// adaptation. `None` behaves exactly like [`run_pipeline`].
pub fn run_pipeline_observed<T, I>(
    stages: Vec<StageSpec<T>>,
    queue_cap: usize,
    source: I,
    observer: PipelineObserver,
) -> (Vec<T>, RunReport)
where
    T: Send + 'static,
    I: IntoIterator<Item = T>,
{
    assert!(!stages.is_empty());
    let n = stages.len();

    // Readiness latch: stage setup (PJRT client creation + executable
    // compilation) happens inside each thread; the clock starts and the
    // source begins feeding only once every stage is ready, so reported
    // throughput/latency are steady-state, not compile-time. A setup panic
    // poisons the latch so the run aborts (propagating the panic) instead
    // of deadlocking on a rendezvous that can never complete.
    let ready = Ready::new(n);

    // Queues: source -> s0 -> s1 -> ... -> sink.
    let (src_tx, mut prev_rx) = bounded::<Tagged<T>>(queue_cap);
    let mut handles = Vec::with_capacity(n);
    let mut sink_rx: Option<Receiver<Tagged<T>>> = None;

    for (i, stage) in stages.into_iter().enumerate() {
        let (tx, rx_next) = bounded::<Tagged<T>>(queue_cap);
        let rx_in: Receiver<Tagged<T>> = prev_rx;
        let is_last = i == n - 1;
        let ready = ready.clone();
        let obs = observer.clone();
        let handle = thread::spawn(move || -> StageMetrics {
            let mut guard = SetupFailGuard { ready: ready.clone(), armed: true };
            let mut f = (stage.factory)();
            guard.armed = false;
            ready.done();
            ready.wait();
            let mut m = StageMetrics { name: stage.name, ..Default::default() };
            loop {
                let t0 = Instant::now();
                let Some(tagged) = rx_in.recv() else { break };
                m.idle_in += t0.elapsed();

                let t1 = Instant::now();
                let out = f(tagged.item);
                let service = t1.elapsed();
                m.busy += service;
                m.items += 1;
                if let Some((o, replica)) = &obs {
                    o.on_item(*replica, i, service.as_secs_f64());
                }

                let t2 = Instant::now();
                if tx.send(Tagged { item: out, admitted: tagged.admitted }).is_err() {
                    break; // downstream closed (abort)
                }
                m.blocked_out += t2.elapsed();
            }
            tx.close();
            m
        });
        handles.push(handle);
        if is_last {
            sink_rx = Some(rx_next.clone());
        }
        prev_rx = rx_next;
    }
    let sink_rx = sink_rx.expect("at least one stage");
    drop(prev_rx);

    // Sink thread collects results + latencies.
    let collector = thread::spawn(move || {
        let mut out = Vec::new();
        let mut lat = Summary::new();
        while let Some(t) = sink_rx.recv() {
            lat.record(t.admitted.elapsed().as_secs_f64());
            out.push(t.item);
        }
        (out, lat)
    });

    // Wait for every stage to finish setup, then start the clock and feed.
    // If a stage factory panicked, skip the stream: the closes below drain
    // the surviving stages and the join propagates the panic.
    let setup_ok = ready.wait();
    let start = Instant::now();
    if setup_ok {
        for item in source {
            if src_tx.send(Tagged { item, admitted: Instant::now() }).is_err() {
                break;
            }
        }
    }
    src_tx.close();

    let stages_metrics: Vec<StageMetrics> =
        handles.into_iter().map(|h| h.join().expect("stage panicked")).collect();
    let (items, latencies) = collector.join().expect("collector panicked");
    let wall = start.elapsed();

    let report = RunReport { images: items.len(), wall, latencies, stages: stages_metrics };
    (items, report)
}

/// Serial baseline: the same stage functions composed in one thread (the
/// kernel-level analogue — one image at a time through the whole network).
pub fn run_serial<T, I>(stages: Vec<StageSpec<T>>, source: I) -> (Vec<T>, RunReport)
where
    T: Send + 'static,
    I: IntoIterator<Item = T>,
{
    let names: Vec<String> = stages.iter().map(|s| s.name.clone()).collect();
    let mut fns: Vec<Box<dyn FnMut(T) -> T>> =
        stages.into_iter().map(|s| (s.factory)()).collect();
    let start = Instant::now();
    let mut out = Vec::new();
    let mut lat = Summary::new();
    let mut busy = vec![std::time::Duration::ZERO; fns.len()];
    for item in source {
        let t0 = Instant::now();
        let mut x = item;
        for (f, b) in fns.iter_mut().zip(busy.iter_mut()) {
            let t = Instant::now();
            x = f(x);
            *b += t.elapsed();
        }
        lat.record(t0.elapsed().as_secs_f64());
        out.push(x);
    }
    let wall = start.elapsed();
    let stages = names
        .into_iter()
        .zip(busy)
        .map(|(name, b)| StageMetrics { name, items: out.len(), busy: b, ..Default::default() })
        .collect();
    let report = RunReport { images: out.len(), wall, latencies: lat, stages };
    (out, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn sleep_stage(name: &str, ms: u64) -> StageSpec<u64> {
        StageSpec::new(
            name,
            Box::new(move || {
                Box::new(move |x: u64| {
                    thread::sleep(Duration::from_millis(ms));
                    x + 1
                })
            }),
        )
    }

    #[test]
    fn preserves_order_and_applies_stages() {
        let stages = vec![sleep_stage("a", 1), sleep_stage("b", 1)];
        let (out, report) = run_pipeline(stages, 2, 0..20u64);
        assert_eq!(out, (2..22u64).collect::<Vec<_>>());
        assert_eq!(report.images, 20);
        assert_eq!(report.stages.len(), 2);
        assert_eq!(report.stages[0].items, 20);
    }

    #[test]
    fn pipeline_overlaps_stages() {
        // Two 5 ms stages, 30 items: serial = ~300 ms, pipelined ~= 155 ms.
        let mk = || vec![sleep_stage("a", 5), sleep_stage("b", 5)];
        let (_, piped) = run_pipeline(mk(), 2, 0..30u64);
        let (_, serial) = run_serial(mk(), 0..30u64);
        assert!(
            piped.wall.as_secs_f64() < 0.75 * serial.wall.as_secs_f64(),
            "piped={:?} serial={:?}",
            piped.wall,
            serial.wall
        );
    }

    #[test]
    fn bottleneck_stage_has_highest_utilization() {
        let stages = vec![sleep_stage("fast", 1), sleep_stage("slow", 6)];
        let (_, report) = run_pipeline(stages, 2, 0..25u64);
        let u0 = report.stages[0].utilization(report.wall);
        let u1 = report.stages[1].utilization(report.wall);
        assert!(u1 > u0, "u0={u0} u1={u1}");
    }

    #[test]
    fn latency_at_least_service_time() {
        let stages = vec![sleep_stage("a", 2), sleep_stage("b", 2)];
        let (_, report) = run_pipeline(stages, 2, 0..10u64);
        assert!(report.latencies.p50() >= 0.004);
    }

    #[test]
    fn single_stage_works() {
        let (out, report) = run_pipeline(vec![sleep_stage("only", 0)], 1, 0..5u64);
        assert_eq!(out.len(), 5);
        assert_eq!(report.stages.len(), 1);
    }

    #[test]
    fn empty_source_is_clean() {
        let (out, report) = run_pipeline(vec![sleep_stage("a", 1)], 1, Vec::<u64>::new());
        assert!(out.is_empty());
        assert_eq!(report.images, 0);
    }

    #[test]
    fn observer_sees_every_item_on_every_stage() {
        use super::super::metrics::StageObserver;
        use std::sync::atomic::{AtomicUsize, Ordering};

        struct Counter(Vec<AtomicUsize>);
        impl StageObserver for Counter {
            fn on_item(&self, replica: usize, stage: usize, service_s: f64) {
                assert_eq!(replica, 3);
                assert!(service_s >= 0.0);
                self.0[stage].fetch_add(1, Ordering::SeqCst);
            }
        }

        let counter = Arc::new(Counter(vec![AtomicUsize::new(0), AtomicUsize::new(0)]));
        let obs: Arc<dyn StageObserver> = counter.clone();
        let stages = vec![sleep_stage("a", 0), sleep_stage("b", 0)];
        let (_, report) = run_pipeline_observed(stages, 2, 0..12u64, Some((obs, 3)));
        assert_eq!(report.images, 12);
        assert_eq!(counter.0[0].load(Ordering::SeqCst), 12);
        assert_eq!(counter.0[1].load(Ordering::SeqCst), 12);
    }

    #[test]
    #[should_panic(expected = "stage panicked")]
    fn stage_setup_panic_propagates_instead_of_deadlocking() {
        // A factory that dies (e.g. PJRT executable compilation failing)
        // must poison the readiness latch and surface as a panic — not
        // leave the feeder blocked on a rendezvous that never completes.
        let stages: Vec<StageSpec<u64>> = vec![
            sleep_stage("ok", 0),
            StageSpec::new("bad", Box::new(|| panic!("factory boom"))),
        ];
        run_pipeline(stages, 1, 0..4u64);
    }
}
