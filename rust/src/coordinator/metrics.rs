//! Run metrics: throughput, per-image latency distribution, per-stage
//! utilization — what the paper reports per experiment (§VII) — plus the
//! [`StageObserver`] hook that streams per-item service times out of the
//! stage workers (consumed by the online-adaptation telemetry,
//! [`crate::adapt::Telemetry`]) and JSON serialization for all report
//! shapes (`serve --metrics-out`).

use std::time::Duration;

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Observer of per-item stage service times, called by the stage worker
/// thread after each processed item. Implementations must be cheap and
/// non-blocking relative to stage service times — the call sits on the
/// pipeline's hot path ([`crate::coordinator::run_pipeline_observed`]).
pub trait StageObserver: Send + Sync {
    /// `service_s` is the item's measured service time in seconds on stage
    /// `stage` of replica `replica` (0 for single-pipeline runs).
    fn on_item(&self, replica: usize, stage: usize, service_s: f64);
}

/// The observability registry (DESIGN.md §13) as a stage observer:
/// per-item service times land in the `stage_service/g0r{replica}s{stage}`
/// log-bucketed histogram — the same metric names the traced DES and
/// synthetic paths emit, so registry consumers need not care which hook
/// fed them. A disabled [`Recorder`](crate::obs::Recorder) makes this a
/// one-branch no-op, keeping the stage hot path untouched.
impl StageObserver for crate::obs::Recorder {
    fn on_item(&self, replica: usize, stage: usize, service_s: f64) {
        if self.enabled() {
            self.observe(&format!("stage_service/g0r{replica}s{stage}"), service_s);
        }
    }
}

/// Fans one stream of stage observations out to several observers —
/// [`crate::coordinator::run_fleet_observed`] takes a single observer
/// slot, and the adaptive controller wants both its drift telemetry and
/// the metrics registry fed from it.
pub struct FanoutObserver {
    observers: Vec<std::sync::Arc<dyn StageObserver>>,
}

impl FanoutObserver {
    pub fn new(observers: Vec<std::sync::Arc<dyn StageObserver>>) -> FanoutObserver {
        FanoutObserver { observers }
    }
}

impl StageObserver for FanoutObserver {
    fn on_item(&self, replica: usize, stage: usize, service_s: f64) {
        for o in &self.observers {
            o.on_item(replica, stage, service_s);
        }
    }
}

/// JSON shape for a latency [`Summary`]: `{count}` when empty, otherwise
/// `{count, mean, p50, p95, p99, max}` (seconds).
pub fn summary_to_json(s: &Summary) -> Json {
    if s.count() == 0 {
        return Json::obj(vec![("count", Json::num(0.0))]);
    }
    Json::obj(vec![
        ("count", Json::num(s.count() as f64)),
        ("mean", Json::num(s.mean())),
        ("p50", Json::num(s.p50())),
        ("p95", Json::num(s.p95())),
        ("p99", Json::num(s.p99())),
        ("max", Json::num(s.max())),
    ])
}

/// Per-stage accounting, filled by the stage worker thread.
#[derive(Debug, Clone, Default)]
pub struct StageMetrics {
    pub name: String,
    pub items: usize,
    pub busy: Duration,
    /// Time spent blocked on the input queue (starvation).
    pub idle_in: Duration,
    /// Time spent blocked pushing downstream (backpressure).
    pub blocked_out: Duration,
}

impl StageMetrics {
    pub fn utilization(&self, wall: Duration) -> f64 {
        if wall.is_zero() {
            return 0.0;
        }
        self.busy.as_secs_f64() / wall.as_secs_f64()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("items", Json::num(self.items as f64)),
            ("busy_s", Json::num(self.busy.as_secs_f64())),
            ("idle_in_s", Json::num(self.idle_in.as_secs_f64())),
            ("blocked_out_s", Json::num(self.blocked_out.as_secs_f64())),
        ])
    }
}

/// Whole-run report.
#[derive(Debug)]
pub struct RunReport {
    pub images: usize,
    pub wall: Duration,
    pub latencies: Summary,
    pub stages: Vec<StageMetrics>,
}

impl RunReport {
    pub fn throughput(&self) -> f64 {
        self.images as f64 / self.wall.as_secs_f64()
    }

    pub fn to_json(&self) -> Json {
        let tp = if self.wall.is_zero() { 0.0 } else { self.throughput() };
        Json::obj(vec![
            ("images", Json::num(self.images as f64)),
            ("wall_s", Json::num(self.wall.as_secs_f64())),
            ("throughput", Json::num(tp)),
            ("latency", summary_to_json(&self.latencies)),
            (
                "stages",
                Json::Arr(self.stages.iter().map(StageMetrics::to_json).collect()),
            ),
        ])
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "images={} wall={:.3}s throughput={:.2} imgs/s\n",
            self.images,
            self.wall.as_secs_f64(),
            self.throughput()
        ));
        s.push_str(&format!(
            "latency p50={:.1}ms p95={:.1}ms p99={:.1}ms\n",
            self.latencies.p50() * 1e3,
            self.latencies.p95() * 1e3,
            self.latencies.p99() * 1e3,
        ));
        for st in &self.stages {
            s.push_str(&format!(
                "  stage {:<14} items={:<6} busy={:>8.3}s util={:>5.1}% starve={:>7.3}s backpress={:>7.3}s\n",
                st.name,
                st.items,
                st.busy.as_secs_f64(),
                100.0 * st.utilization(self.wall),
                st.idle_in.as_secs_f64(),
                st.blocked_out.as_secs_f64(),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recorder_observer_feeds_stage_service_hist() {
        use std::sync::Arc;

        let rec = crate::obs::Recorder::on();
        let fan = FanoutObserver::new(vec![Arc::new(rec.clone()) as Arc<dyn StageObserver>]);
        fan.on_item(1, 2, 0.25);
        fan.on_item(1, 2, 0.26);
        let snap = rec.snapshot().expect("enabled");
        let h = snap.hist("stage_service/g0r1s2").expect("hist registered");
        assert_eq!(h.count(), 2);
        // The disabled recorder stays a no-op through the same hook.
        let off = crate::obs::Recorder::off();
        StageObserver::on_item(&off, 0, 0, 0.1);
        assert!(off.snapshot().is_none());
    }

    #[test]
    fn utilization_math() {
        let m = StageMetrics {
            name: "s0".into(),
            items: 10,
            busy: Duration::from_millis(500),
            ..Default::default()
        };
        assert!((m.utilization(Duration::from_secs(1)) - 0.5).abs() < 1e-9);
        assert_eq!(m.utilization(Duration::ZERO), 0.0);
    }

    #[test]
    fn report_renders() {
        let mut lat = Summary::new();
        lat.record(0.010);
        lat.record(0.020);
        let r = RunReport {
            images: 2,
            wall: Duration::from_secs(1),
            latencies: lat,
            stages: vec![StageMetrics { name: "stage0".into(), items: 2, ..Default::default() }],
        };
        let s = r.render();
        assert!(s.contains("throughput=2.00"));
        assert!(s.contains("stage0"));
    }

    #[test]
    fn run_report_serializes_to_parseable_json() {
        let mut lat = Summary::new();
        lat.record(0.010);
        let r = RunReport {
            images: 1,
            wall: Duration::from_secs(2),
            latencies: lat,
            stages: vec![StageMetrics {
                name: "s0".into(),
                items: 1,
                busy: Duration::from_millis(10),
                ..Default::default()
            }],
        };
        let text = r.to_json().to_string();
        let j = Json::parse(&text).expect("report JSON reparses");
        assert_eq!(j.req("images").unwrap().as_usize(), Some(1));
        assert!((j.req("wall_s").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-12);
        assert_eq!(j.req("latency").unwrap().req("count").unwrap().as_usize(), Some(1));
        let stages = j.req("stages").unwrap().as_arr().unwrap();
        assert_eq!(stages[0].req("name").unwrap().as_str(), Some("s0"));
    }

    #[test]
    fn zero_wall_report_serializes_finite_numbers() {
        let r = RunReport {
            images: 0,
            wall: Duration::ZERO,
            latencies: Summary::new(),
            stages: Vec::new(),
        };
        let j = r.to_json();
        assert_eq!(j.req("throughput").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.req("latency").unwrap().req("count").unwrap().as_usize(), Some(0));
        // An empty-latency summary must not leak non-finite stats (inf/nan
        // are not representable in JSON).
        assert!(Json::parse(&j.to_string()).is_ok());
    }
}
