//! Run metrics: throughput, per-image latency distribution, per-stage
//! utilization — what the paper reports per experiment (§VII).

use std::time::Duration;

use crate::util::stats::Summary;

/// Per-stage accounting, filled by the stage worker thread.
#[derive(Debug, Clone, Default)]
pub struct StageMetrics {
    pub name: String,
    pub items: usize,
    pub busy: Duration,
    /// Time spent blocked on the input queue (starvation).
    pub idle_in: Duration,
    /// Time spent blocked pushing downstream (backpressure).
    pub blocked_out: Duration,
}

impl StageMetrics {
    pub fn utilization(&self, wall: Duration) -> f64 {
        if wall.is_zero() {
            return 0.0;
        }
        self.busy.as_secs_f64() / wall.as_secs_f64()
    }
}

/// Whole-run report.
#[derive(Debug)]
pub struct RunReport {
    pub images: usize,
    pub wall: Duration,
    pub latencies: Summary,
    pub stages: Vec<StageMetrics>,
}

impl RunReport {
    pub fn throughput(&self) -> f64 {
        self.images as f64 / self.wall.as_secs_f64()
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "images={} wall={:.3}s throughput={:.2} imgs/s\n",
            self.images,
            self.wall.as_secs_f64(),
            self.throughput()
        ));
        s.push_str(&format!(
            "latency p50={:.1}ms p95={:.1}ms p99={:.1}ms\n",
            self.latencies.p50() * 1e3,
            self.latencies.p95() * 1e3,
            self.latencies.p99() * 1e3,
        ));
        for st in &self.stages {
            s.push_str(&format!(
                "  stage {:<14} items={:<6} busy={:>8.3}s util={:>5.1}% starve={:>7.3}s backpress={:>7.3}s\n",
                st.name,
                st.items,
                st.busy.as_secs_f64(),
                100.0 * st.utilization(self.wall),
                st.idle_in.as_secs_f64(),
                st.blocked_out.as_secs_f64(),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_math() {
        let m = StageMetrics {
            name: "s0".into(),
            items: 10,
            busy: Duration::from_millis(500),
            ..Default::default()
        };
        assert!((m.utilization(Duration::from_secs(1)) - 0.5).abs() < 1e-9);
        assert_eq!(m.utilization(Duration::ZERO), 0.0);
    }

    #[test]
    fn report_renders() {
        let mut lat = Summary::new();
        lat.record(0.010);
        lat.record(0.020);
        let r = RunReport {
            images: 2,
            wall: Duration::from_secs(1),
            latencies: lat,
            stages: vec![StageMetrics { name: "stage0".into(), items: 2, ..Default::default() }],
        };
        let s = r.render();
        assert!(s.contains("throughput=2.00"));
        assert!(s.contains("stage0"));
    }
}
