//! Serving glue: manifest + pipeline allocation -> real multi-threaded
//! pipeline over PJRT (the end-to-end path proving all three layers
//! compose: Pallas kernels -> JAX layers -> HLO artifacts -> Rust stages).
//! [`serve_fleet`] replicates that pipeline R times behind the shared
//! admission queue of [`run_fleet`]. All entry points here require the
//! `pjrt` feature at runtime (DESIGN.md §6); the simulated serving path
//! (`pipeit serve --net`) works in every build.

use anyhow::Result;

use crate::dse::Allocation;
use crate::runtime::executor::{pjrt_available, StageRunnerSpec};
use crate::runtime::manifest::Manifest;
use crate::runtime::tensor::Tensor;

use super::batcher::{Batcher, Job};
use super::fleet::{run_fleet, FleetReport};
use super::metrics::RunReport;
use super::pipeline::{run_pipeline, run_serial, StageSpec};
use super::stream::ImageStream;

/// Fail fast (instead of panicking inside a stage thread) when the binary
/// was built without the `pjrt` feature — see DESIGN.md §6.
fn ensure_pjrt() -> Result<()> {
    anyhow::ensure!(
        pjrt_available(),
        "PJRT serving requires `--features pjrt` (the `xla` crate); this \
         build only supports the simulated serving paths — see DESIGN.md §6"
    );
    Ok(())
}

/// Build the per-stage factories for a layer allocation. Each factory,
/// executed inside its stage thread, creates a private PJRT client and
/// compiles the stage's layer modules (batch-1 + any exported batch sizes).
fn stage_specs(
    manifest: &Manifest,
    alloc: &Allocation,
    batch_sizes: &[usize],
) -> Result<Vec<StageSpec<Job>>> {
    let mut specs = Vec::new();
    for (i, &(lo, hi)) in alloc.ranges.iter().enumerate() {
        if lo >= hi {
            continue;
        }
        let runner_spec = StageRunnerSpec::from_manifest(manifest, lo, hi, batch_sizes)?;
        let name = format!("stage{}[{}..{}]", i, lo + 1, hi);
        specs.push(StageSpec::new(
            &name,
            Box::new(move || {
                let runner = runner_spec.build().expect("stage runner build");
                Box::new(move |mut job: Job| {
                    let tensors = std::mem::take(&mut job.tensors);
                    job.tensors = runner.run_batch_owned(tensors).expect("stage exec");
                    job
                })
            }),
        ));
    }
    Ok(specs)
}

/// Serve `images` synthetic images through the pipelined configuration.
/// Returns the run report (throughput, latency, per-stage utilization).
pub fn serve_pipelined(
    manifest: &Manifest,
    alloc: &Allocation,
    images: usize,
    batch: usize,
    queue_cap: usize,
    seed: u64,
) -> Result<(Vec<Job>, RunReport)> {
    ensure_pjrt()?;
    let batch_sizes: Vec<usize> = if batch > 1 { vec![1, batch] } else { vec![1] };
    let specs = stage_specs(manifest, alloc, &batch_sizes)?;
    let stream = ImageStream::new(&manifest.input_shape, images, seed)
        .map(|im| Tensor::new(im.shape, im.data));
    let jobs = Batcher::new(stream, batch_sizes);
    Ok(run_pipeline(specs, queue_cap, jobs))
}

/// Serve through the whole-network single module on one thread — the
/// kernel-level baseline analogue.
pub fn serve_serial(
    manifest: &Manifest,
    images: usize,
    batch: usize,
    seed: u64,
) -> Result<(Vec<Job>, RunReport)> {
    ensure_pjrt()?;
    let batch_sizes: Vec<usize> = if batch > 1 { vec![1, batch] } else { vec![1] };
    let runner_spec = StageRunnerSpec::full_network(manifest, &batch_sizes)?;
    let spec = StageSpec::new(
        "full-net",
        Box::new(move || {
            let runner = runner_spec.build().expect("full-net runner build");
            Box::new(move |mut job: Job| {
                let tensors = std::mem::take(&mut job.tensors);
                job.tensors = runner.run_batch_owned(tensors).expect("full-net exec");
                job
            })
        }),
    );
    let stream = ImageStream::new(&manifest.input_shape, images, seed)
        .map(|im| Tensor::new(im.shape, im.data));
    let jobs = Batcher::new(stream, batch_sizes);
    Ok(run_serial(vec![spec], jobs))
}

/// Serve per-layer modules chained on one thread — used to verify that the
/// per-layer chain is numerically identical to the full-network module.
pub fn serve_layerwise_serial(
    manifest: &Manifest,
    images: usize,
    seed: u64,
) -> Result<(Vec<Job>, RunReport)> {
    ensure_pjrt()?;
    let alloc = Allocation { ranges: vec![(0, manifest.num_layers())] };
    let specs = stage_specs(manifest, &alloc, &[1])?;
    let stream = ImageStream::new(&manifest.input_shape, images, seed)
        .map(|im| Tensor::new(im.shape, im.data));
    let jobs = Batcher::new(stream, vec![1]);
    Ok(run_serial(specs, jobs))
}

/// Profile per-layer execution times on this host by running `samples`
/// images through a serial chain with one stage per layer and reading each
/// stage's busy time. This is the launcher's analogue of the paper's
/// "measured layer timings" (Table VI) for the real PJRT substrate.
pub fn profile_layer_times(manifest: &Manifest, samples: usize, seed: u64) -> Result<Vec<f64>> {
    ensure_pjrt()?;
    let w = manifest.num_layers();
    let alloc = Allocation { ranges: (0..w).map(|i| (i, i + 1)).collect() };
    let specs = stage_specs(manifest, &alloc, &[1])?;
    let stream = ImageStream::new(&manifest.input_shape, samples, seed)
        .map(|im| Tensor::new(im.shape, im.data));
    let jobs = Batcher::new(stream, vec![1]);
    let (_, report) = run_serial(specs, jobs);
    Ok(report
        .stages
        .iter()
        .map(|s| s.busy.as_secs_f64() / s.items.max(1) as f64)
        .collect())
}

/// Replicated PJRT serving: `replicas` copies of the same manifest pipeline
/// (one private PJRT client + executable set per stage thread per replica),
/// fed from one shared admission queue with least-outstanding-work dispatch
/// ([`run_fleet`]). On a big.LITTLE board each replica's stages would be
/// pinned to that replica's core budget; on this host the replicas share
/// the CPU and the fleet demonstrates the coordinator's scale-out path.
pub fn serve_fleet(
    manifest: &Manifest,
    alloc: &Allocation,
    replicas: usize,
    images: usize,
    batch: usize,
    queue_cap: usize,
    seed: u64,
) -> Result<(Vec<Job>, FleetReport)> {
    ensure_pjrt()?;
    anyhow::ensure!(replicas >= 1, "need at least one replica");
    let batch_sizes: Vec<usize> = if batch > 1 { vec![1, batch] } else { vec![1] };
    let mut fleet = Vec::with_capacity(replicas);
    for _ in 0..replicas {
        fleet.push(stage_specs(manifest, alloc, &batch_sizes)?);
    }
    let stream = ImageStream::new(&manifest.input_shape, images, seed)
        .map(|im| Tensor::new(im.shape, im.data));
    let jobs = Batcher::new(stream, batch_sizes);
    Ok(run_fleet(fleet, queue_cap, 2 * replicas, jobs))
}

/// Balance `times` (seconds per layer) into `k` contiguous stages — greedy
/// front-fill against the ideal per-stage share (profile-guided launcher).
pub fn balance_by_times(times: &[f64], k: usize) -> Allocation {
    let w = times.len();
    let k = k.clamp(1, w.max(1));
    let total: f64 = times.iter().sum();
    let target = total / k as f64;
    let mut ranges = Vec::with_capacity(k);
    let mut lo = 0;
    let mut acc = 0.0;
    for (i, t) in times.iter().enumerate() {
        acc += t;
        let stages_left = k - ranges.len();
        let layers_left = w - i - 1;
        if (acc >= target && stages_left > 1 && layers_left >= stages_left - 1)
            || layers_left + 1 == stages_left
        {
            ranges.push((lo, i + 1));
            lo = i + 1;
            acc = 0.0;
        }
    }
    if lo < w {
        ranges.push((lo, w));
    }
    Allocation { ranges }
}

/// Balance manifest layers into `k` contiguous stages by MAC count — the
/// static fallback when no profile is available (the serving host is a
/// symmetric CPU, so MACs are the balancing proxy).
pub fn balance_by_macs(manifest: &Manifest, k: usize) -> Allocation {
    let macs: Vec<f64> = manifest.layers.iter().map(|l| l.macs as f64).collect();
    balance_by_times(&macs, k)
}
