//! The Pipe-it L3 coordinator: bounded inter-stage queues, the real
//! multi-threaded pipeline executor, the replicated-pipeline fleet, dynamic
//! batcher, image-stream source, metrics, and the PJRT serving glue.
//!
//! Two serving shapes share one stage abstraction ([`StageSpec`]):
//!
//! * [`run_pipeline`] — ONE pipeline, one OS thread per stage, bounded
//!   queues between stages (the paper's design).
//! * [`run_fleet`] — R replicated pipelines on disjoint core budgets behind
//!   one shared bounded admission queue with least-outstanding-work
//!   dispatch (DESIGN.md §4; the scaling lever beyond a balanced single
//!   pipeline).
//!
//! The *simulated* pipeline (for the paper's experiments) lives in
//! [`crate::simulator::pipeline_sim`]; this module is the wall-clock twin
//! used by the end-to-end serving example and the `serve` subcommand.

pub mod batcher;
pub mod fleet;
pub mod metrics;
pub mod pipeline;
pub mod queue;
pub mod server;
pub mod stream;

pub use batcher::{Batcher, Job};
pub use fleet::{
    run_fleet, run_fleet_observed, synthetic_fleet, synthetic_fleet_recorded, FleetReport,
};
pub use metrics::{summary_to_json, FanoutObserver, RunReport, StageMetrics, StageObserver};
pub use pipeline::{
    run_pipeline, run_pipeline_observed, run_serial, PipelineObserver, StageFactory,
    StageSpec,
};
pub use server::{
    balance_by_macs, balance_by_times, profile_layer_times, serve_fleet,
    serve_layerwise_serial, serve_pipelined, serve_serial,
};
pub use stream::{Image, ImageStream};
