//! The Pipe-it L3 coordinator: bounded inter-stage queues, the real
//! multi-threaded pipeline executor, dynamic batcher, image-stream source,
//! metrics, and the PJRT serving glue. The *simulated* pipeline (for the
//! paper's experiments) lives in `simulator::pipeline_sim`; this module is
//! the wall-clock twin used by the end-to-end serving example.

pub mod batcher;
pub mod metrics;
pub mod pipeline;
pub mod queue;
pub mod server;
pub mod stream;

pub use batcher::{Batcher, Job};
pub use metrics::{RunReport, StageMetrics};
pub use pipeline::{run_pipeline, run_serial, StageFactory, StageSpec};
pub use server::{
    balance_by_times, profile_layer_times, serve_layerwise_serial, serve_pipelined,
    serve_serial,
};
pub use stream::{Image, ImageStream};
