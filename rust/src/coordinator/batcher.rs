//! Dynamic batcher: groups a stream of images into jobs whose batch size
//! matches an AOT-exported executable (HLO shapes are static, so only the
//! exported batch sizes are admissible).

use std::collections::VecDeque;

use crate::runtime::tensor::Tensor;

/// A batch of images travelling through the pipeline as one unit.
#[derive(Debug, Clone)]
pub struct Job {
    /// Sequence number of the first image in the batch.
    pub seq: usize,
    pub tensors: Vec<Tensor>,
}

/// Greedy batcher over an image iterator: emits the largest exported batch
/// size the remaining stream can fill exactly, falling back to smaller
/// exported sizes (ultimately batch-1) at the stream tail. `sizes` must
/// contain 1.
pub struct Batcher<I: Iterator<Item = Tensor>> {
    inner: I,
    /// Exported batch sizes, descending.
    sizes: Vec<usize>,
    pending: VecDeque<Tensor>,
    next_seq: usize,
    exhausted: bool,
}

impl<I: Iterator<Item = Tensor>> Batcher<I> {
    pub fn new(inner: I, mut sizes: Vec<usize>) -> Batcher<I> {
        assert!(sizes.contains(&1), "batch sizes must include 1");
        sizes.sort_unstable_by(|a, b| b.cmp(a));
        Batcher { inner, sizes, pending: VecDeque::new(), next_seq: 0, exhausted: false }
    }

    fn refill(&mut self, want: usize) {
        while !self.exhausted && self.pending.len() < want {
            match self.inner.next() {
                Some(t) => self.pending.push_back(t),
                None => self.exhausted = true,
            }
        }
    }
}

impl<I: Iterator<Item = Tensor>> Iterator for Batcher<I> {
    type Item = Job;

    fn next(&mut self) -> Option<Job> {
        let max = self.sizes[0];
        self.refill(max);
        if self.pending.is_empty() {
            return None;
        }
        // Largest exported size we can fill exactly.
        let take = *self
            .sizes
            .iter()
            .find(|&&s| s <= self.pending.len())
            .expect("sizes contains 1");
        let tensors: Vec<Tensor> = self.pending.drain(..take).collect();
        let job = Job { seq: self.next_seq, tensors };
        self.next_seq += take;
        Some(job)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn imgs(n: usize) -> Vec<Tensor> {
        (0..n).map(|i| Tensor::new(vec![2], vec![i as f32, 0.0])).collect()
    }

    #[test]
    fn batches_greedily_with_singleton_tail() {
        let jobs: Vec<Job> = Batcher::new(imgs(10).into_iter(), vec![1, 4]).collect();
        let sizes: Vec<usize> = jobs.iter().map(|j| j.tensors.len()).collect();
        assert_eq!(sizes, vec![4, 4, 1, 1]);
        assert_eq!(jobs[0].seq, 0);
        assert_eq!(jobs[1].seq, 4);
        assert_eq!(jobs[2].seq, 8);
        assert_eq!(jobs[3].seq, 9);
    }

    #[test]
    fn batch1_only() {
        let jobs: Vec<Job> = Batcher::new(imgs(3).into_iter(), vec![1]).collect();
        assert_eq!(jobs.len(), 3);
        assert!(jobs.iter().all(|j| j.tensors.len() == 1));
    }

    #[test]
    fn intermediate_sizes_used_at_tail() {
        let jobs: Vec<Job> = Batcher::new(imgs(7).into_iter(), vec![1, 2, 4]).collect();
        let sizes: Vec<usize> = jobs.iter().map(|j| j.tensors.len()).collect();
        assert_eq!(sizes, vec![4, 2, 1]);
    }

    #[test]
    fn preserves_image_order() {
        let jobs: Vec<Job> = Batcher::new(imgs(9).into_iter(), vec![1, 4]).collect();
        let flat: Vec<f32> = jobs
            .iter()
            .flat_map(|j| j.tensors.iter().map(|t| t.data[0]))
            .collect();
        assert_eq!(flat, (0..9).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn empty_stream_yields_nothing() {
        let jobs: Vec<Job> = Batcher::new(imgs(0).into_iter(), vec![1, 4]).collect();
        assert!(jobs.is_empty());
        // And stays empty: the iterator is fused in practice.
        let mut b = Batcher::new(imgs(0).into_iter(), vec![1, 4]);
        assert!(b.next().is_none());
        assert!(b.next().is_none());
    }

    #[test]
    fn schedule_exhaustion_falls_through_every_exported_size() {
        // 11 images against sizes {8, 4, 1}: one 8, then the remaining 3
        // exhaust both 8 and 4 and must fall through to singletons.
        let jobs: Vec<Job> = Batcher::new(imgs(11).into_iter(), vec![8, 4, 1]).collect();
        let sizes: Vec<usize> = jobs.iter().map(|j| j.tensors.len()).collect();
        assert_eq!(sizes, vec![8, 1, 1, 1]);
        assert_eq!(jobs.iter().map(|j| j.seq).collect::<Vec<_>>(), vec![0, 8, 9, 10]);
    }

    #[test]
    fn remainder_batch_uses_largest_size_that_fits_exactly() {
        // 6 left at the tail with sizes {4, 2, 1}: remainder is 4 + 2, and
        // the seq numbering stays contiguous across the remainder batches.
        let jobs: Vec<Job> = Batcher::new(imgs(14).into_iter(), vec![1, 2, 4]).collect();
        let sizes: Vec<usize> = jobs.iter().map(|j| j.tensors.len()).collect();
        assert_eq!(sizes, vec![4, 4, 4, 2]);
        let seqs: Vec<usize> = jobs.iter().map(|j| j.seq).collect();
        assert_eq!(seqs, vec![0, 4, 8, 12]);
    }

    #[test]
    fn exact_multiple_has_no_remainder() {
        let jobs: Vec<Job> = Batcher::new(imgs(8).into_iter(), vec![1, 4]).collect();
        let sizes: Vec<usize> = jobs.iter().map(|j| j.tensors.len()).collect();
        assert_eq!(sizes, vec![4, 4]);
    }

    #[test]
    #[should_panic(expected = "batch sizes must include 1")]
    fn schedule_without_batch1_is_rejected() {
        // Without size 1 the tail could strand images; construction fails
        // loudly instead.
        let _ = Batcher::new(imgs(3).into_iter(), vec![2, 4]);
    }
}
