//! Synthetic image-stream source (substitute for the paper's 50-image video
//! stream — DESIGN.md §1: throughput is content-agnostic).

use crate::util::rng::Rng;

/// A single image tensor (HWC f32) with a stream sequence number.
#[derive(Debug, Clone)]
pub struct Image {
    pub seq: usize,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// Deterministic stream of `count` random images of the given shape.
pub struct ImageStream {
    rng: Rng,
    shape: Vec<usize>,
    next: usize,
    count: usize,
}

impl ImageStream {
    pub fn new(shape: &[usize], count: usize, seed: u64) -> ImageStream {
        ImageStream { rng: Rng::new(seed), shape: shape.to_vec(), next: 0, count }
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

impl Iterator for ImageStream {
    type Item = Image;

    fn next(&mut self) -> Option<Image> {
        if self.next >= self.count {
            return None;
        }
        let seq = self.next;
        self.next += 1;
        let n = self.elems();
        Some(Image { seq, shape: self.shape.clone(), data: self.rng.f32_vec(n, 0.0, 1.0) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn yields_count_images_with_shape() {
        let s = ImageStream::new(&[16, 16, 3], 5, 42);
        let imgs: Vec<Image> = s.collect();
        assert_eq!(imgs.len(), 5);
        assert!(imgs.iter().enumerate().all(|(i, im)| im.seq == i));
        assert!(imgs.iter().all(|im| im.data.len() == 16 * 16 * 3));
    }

    #[test]
    fn deterministic_by_seed() {
        let a: Vec<Image> = ImageStream::new(&[4, 4, 1], 3, 7).collect();
        let b: Vec<Image> = ImageStream::new(&[4, 4, 1], 3, 7).collect();
        assert_eq!(a[2].data, b[2].data);
        let c: Vec<Image> = ImageStream::new(&[4, 4, 1], 3, 8).collect();
        assert_ne!(a[0].data, c[0].data);
    }

    #[test]
    fn values_in_unit_range() {
        let s = ImageStream::new(&[8, 8, 3], 2, 1);
        for im in s {
            assert!(im.data.iter().all(|v| (0.0..1.0).contains(v)));
        }
    }
}
