//! Replicated-pipeline serving: R independent pipelines fed from ONE shared
//! bounded admission queue by a least-outstanding-work dispatcher.
//!
//! A single latency-balanced pipeline is throughput-bound by its bottleneck
//! stage (Eq. 12). The next lever — following PICO (arXiv 2206.08662) and
//! pipeline-parallel hierarchical serving (arXiv 2109.13356) — is to run
//! several *whole* pipelines side by side on disjoint core budgets and
//! balance admission across them. Replicas process complete images, so they
//! pay no layer-granularity quantization loss; the fleet's steady-state
//! rate is the *sum* of replica rates.
//!
//! Topology (DESIGN.md §4):
//!
//! ```text
//! source -> [admission queue] -> dispatcher -> [feed q, cap 1] -> replica 0
//!                 (bounded)     (least          [feed q, cap 1] -> replica 1
//!                                outstanding    ...
//!                                work)          [feed q, cap 1] -> replica R-1
//! ```
//!
//! The high-level entry point is the plan facade: a replicated
//! [`crate::api::Plan`] deploys onto this fleet via
//! [`crate::api::Plan::deploy`], and [`FleetReport`] converts into the
//! unified [`crate::api::ServeReport`] shape.
//!
//! Each replica is an ordinary [`run_pipeline`](crate::coordinator::run_pipeline) chain built from the same
//! [`StageSpec`] machinery as single-pipeline serving; the dispatcher
//! tracks per-replica outstanding items (dispatched minus completed, the
//! completion observed by wrapping the replica's last stage) and routes
//! every admitted item to the replica with the fewest. Feed queues have
//! capacity 1 so `outstanding` stays an honest in-flight count and
//! backpressure propagates to the shared admission queue.
//!
//! # Example
//!
//! ```
//! use pipeit::coordinator::{run_fleet, StageSpec};
//!
//! // Two single-stage replicas that negate their input.
//! let replica = || {
//!     vec![StageSpec::new(
//!         "negate",
//!         Box::new(|| Box::new(|x: i64| -x)),
//!     )]
//! };
//! let (out, report) = run_fleet(vec![replica(), replica()], 2, 4, 1..=10i64);
//! assert_eq!(report.images, 10);
//! assert_eq!(report.dispatched.iter().sum::<usize>(), 10);
//! let mut sorted = out.clone();
//! sorted.sort();
//! assert_eq!(sorted, (-10..=-1).collect::<Vec<_>>());
//! ```

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::obs::{pool_latencies, Recorder, WallClock};
use crate::util::json::Json;
use crate::util::stats::Summary;

use super::metrics::{summary_to_json, RunReport, StageObserver};
use super::pipeline::{run_pipeline_observed, Ready, SetupFailGuard, StageSpec};
use super::queue::bounded;

/// Fleet-level run report: merged aggregates plus the per-replica
/// [`RunReport`]s they were derived from.
#[derive(Debug)]
pub struct FleetReport {
    /// Total items that completed across all replicas.
    pub images: usize,
    /// Wall-clock time from when every replica finished stage setup (PJRT
    /// client creation + executable compilation is excluded, exactly as in
    /// [`run_pipeline`](crate::coordinator::run_pipeline)'s report) until every replica drained.
    pub wall: Duration,
    /// Per-image latencies merged across replicas. Each latency is measured
    /// from the moment the item entered its replica's pipeline; time spent
    /// queued upstream of that point — in the shared admission queue under
    /// backpressure, plus at most one item's wait in the cap-1 feed queue —
    /// is not counted (DESIGN.md §4).
    pub latencies: Summary,
    /// Per-replica reports, in replica order.
    pub replicas: Vec<RunReport>,
    /// Items dispatched to each replica by the least-outstanding-work policy.
    pub dispatched: Vec<usize>,
}

impl FleetReport {
    /// Aggregate throughput: completed items over the fleet wall clock.
    pub fn throughput(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.images as f64 / self.wall.as_secs_f64()
    }

    /// Per-replica throughputs against each replica's own wall clock.
    pub fn replica_throughputs(&self) -> Vec<f64> {
        self.replicas.iter().map(|r| r.throughput()).collect()
    }

    /// Per-replica utilization: busiest stage's busy time over the fleet
    /// wall clock (1.0 = the replica's bottleneck never idled).
    pub fn utilization(&self) -> Vec<f64> {
        self.replicas
            .iter()
            .map(|r| {
                r.stages
                    .iter()
                    .map(|s| s.utilization(self.wall))
                    .fold(0.0, f64::max)
            })
            .collect()
    }

    /// JSON shape of the fleet report (aggregates plus nested per-replica
    /// [`RunReport::to_json`] blocks) — what `serve --metrics-out` captures.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("images", Json::num(self.images as f64)),
            ("wall_s", Json::num(self.wall.as_secs_f64())),
            ("throughput", Json::num(self.throughput())),
            (
                "dispatched",
                Json::Arr(self.dispatched.iter().map(|&d| Json::num(d as f64)).collect()),
            ),
            ("latency", summary_to_json(&self.latencies)),
            (
                "replicas",
                Json::Arr(self.replicas.iter().map(RunReport::to_json).collect()),
            ),
        ])
    }

    /// Human-readable fleet summary followed by indented per-replica blocks.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "fleet: {} replicas, images={} wall={:.3}s aggregate={:.2} imgs/s\n",
            self.replicas.len(),
            self.images,
            self.wall.as_secs_f64(),
            self.throughput()
        ));
        s.push_str(&format!(
            "latency p50={:.1}ms p95={:.1}ms p99={:.1}ms\n",
            self.latencies.p50() * 1e3,
            self.latencies.p95() * 1e3,
            self.latencies.p99() * 1e3,
        ));
        let util = self.utilization();
        for (i, rep) in self.replicas.iter().enumerate() {
            s.push_str(&format!(
                "replica {i}: dispatched={} throughput={:.2} imgs/s util={:.0}%\n",
                self.dispatched[i],
                rep.throughput(),
                100.0 * util[i],
            ));
            for line in rep.render().lines() {
                s.push_str("  ");
                s.push_str(line);
                s.push('\n');
            }
        }
        s
    }
}

/// Wrap a replica's last stage so item completion decrements the replica's
/// outstanding-work counter (read by the dispatcher).
fn instrument_completion<T: Send + 'static>(
    mut stages: Vec<StageSpec<T>>,
    outstanding: Arc<Vec<AtomicUsize>>,
    idx: usize,
) -> Vec<StageSpec<T>> {
    let last = stages.pop().expect("replica has at least one stage");
    let name = last.name;
    let factory = last.factory;
    stages.push(StageSpec {
        name,
        factory: Box::new(move || {
            let mut f = factory();
            Box::new(move |x: T| {
                let y = f(x);
                outstanding[idx].fetch_sub(1, Ordering::SeqCst);
                y
            })
        }),
    });
    stages
}

/// Build a synthetic fleet whose stage functions sleep for the given
/// per-stage service times multiplied by `scale` — the simulated-time
/// serving backend of `pipeit serve --net` and the harness the integration
/// tests use to race wall-clock fleets against
/// [`crate::simulator::pipeline_sim::simulate_replicated`].
pub fn synthetic_fleet(times: &[Vec<f64>], scale: f64) -> Vec<Vec<StageSpec<usize>>> {
    synthetic_fleet_recorded(times, scale, &Recorder::off(), &WallClock::start())
}

/// [`synthetic_fleet`] with span recording: each stage emits its service
/// span on the shared [`WallClock`] (group 0, the item's stream index as
/// its trace id), stage 0 additionally emits the admission span and the
/// last stage the departure span — the wall-clock twin of the span chains
/// [`crate::simulator::pipeline_sim::simulate_recorded`] produces. With
/// [`Recorder::off`] the closures take the exact original path: one
/// branch, no timestamp capture.
pub fn synthetic_fleet_recorded(
    times: &[Vec<f64>],
    scale: f64,
    rec: &Recorder,
    clock: &WallClock,
) -> Vec<Vec<StageSpec<usize>>> {
    times
        .iter()
        .enumerate()
        .map(|(r, stage_times)| {
            let p = stage_times.len();
            stage_times
                .iter()
                .enumerate()
                .map(|(s, &t)| {
                    let dt = Duration::from_secs_f64(t * scale);
                    let last = s + 1 == p;
                    let rec = rec.clone();
                    let clock = clock.clone();
                    StageSpec::new(
                        &format!("r{r}s{s}"),
                        Box::new(move || {
                            let rec = rec.clone();
                            let clock = clock.clone();
                            Box::new(move |x: usize| {
                                if rec.enabled() {
                                    let t0 = clock.now_s();
                                    thread::sleep(dt);
                                    let t1 = clock.now_s();
                                    if s == 0 {
                                        rec.admit(0, x as u64, t0);
                                    }
                                    rec.stage(0, x as u64, r as u32, s as u32, t0, t1);
                                    if last {
                                        rec.depart(0, x as u64, r as u32, t1);
                                    }
                                } else {
                                    thread::sleep(dt);
                                }
                                x
                            })
                        }),
                    )
                })
                .collect()
        })
        .collect()
}

/// Wrap every stage factory so it reports setup completion to the
/// fleet-wide latch and then holds the stage at the fleet-wide start line:
/// the fleet clock, the stream, AND every replica's internal run clock all
/// begin only once the whole fleet is built, so `FleetReport` aggregates
/// and the per-replica `RunReport`s share one steady-state time basis
/// (fast-compiling replicas don't book the wait for slow ones as idle).
/// A factory panic poisons the latch via the guard, releasing the held
/// siblings so the abort cascade (§queue drop-close) can run.
fn instrument_setup<T: Send + 'static>(
    stages: Vec<StageSpec<T>>,
    setup: &Arc<Ready>,
) -> Vec<StageSpec<T>> {
    stages
        .into_iter()
        .map(|spec| {
            let setup = setup.clone();
            let factory = spec.factory;
            StageSpec {
                name: spec.name,
                factory: Box::new(move || {
                    let mut guard = SetupFailGuard { ready: setup.clone(), armed: true };
                    let f = factory();
                    guard.armed = false;
                    setup.done();
                    setup.wait();
                    f
                }),
            }
        })
        .collect()
}

/// Run `source` items through a fleet of replicated pipelines.
///
/// * `replicas` — one stage list per replica (each spec's factory runs
///   inside its own stage thread, exactly as in [`run_pipeline`](crate::coordinator::run_pipeline)).
/// * `queue_cap` — inter-stage buffer capacity inside every replica.
/// * `admission_cap` — capacity of the shared admission queue; when every
///   replica is saturated this bounds how much work the fleet accepts
///   before blocking the caller (admission control).
///
/// Returns every processed item (grouped by replica, stream order within a
/// replica; cross-replica completion order is not defined) and the merged
/// [`FleetReport`].
///
/// # Panics
///
/// Panics if `replicas` is empty, any replica has no stages, or a stage
/// thread panics (mirroring [`run_pipeline`](crate::coordinator::run_pipeline)).
pub fn run_fleet<T, I>(
    replicas: Vec<Vec<StageSpec<T>>>,
    queue_cap: usize,
    admission_cap: usize,
    source: I,
) -> (Vec<T>, FleetReport)
where
    T: Send + 'static,
    I: IntoIterator<Item = T>,
{
    run_fleet_observed(replicas, queue_cap, admission_cap, source, None)
}

/// [`run_fleet`] with a per-item service-time tap: every stage worker of
/// every replica reports each item's measured service time to the observer
/// under its replica index, exactly as in
/// [`run_pipeline_observed`](crate::coordinator::run_pipeline_observed).
/// `None` behaves exactly like [`run_fleet`].
pub fn run_fleet_observed<T, I>(
    replicas: Vec<Vec<StageSpec<T>>>,
    queue_cap: usize,
    admission_cap: usize,
    source: I,
    observer: Option<Arc<dyn StageObserver>>,
) -> (Vec<T>, FleetReport)
where
    T: Send + 'static,
    I: IntoIterator<Item = T>,
{
    assert!(!replicas.is_empty(), "fleet needs at least one replica");
    assert!(admission_cap >= 1);
    let r = replicas.len();

    let outstanding: Arc<Vec<AtomicUsize>> =
        Arc::new((0..r).map(|_| AtomicUsize::new(0)).collect());

    // Fleet-wide setup latch: one slot per stage across all replicas. The
    // clock starts and the stream begins only once every stage is built; a
    // replica dying during setup poisons the latch (via its thread guard)
    // so the fleet aborts instead of waiting forever.
    let total_stages: usize = replicas.iter().map(|stages| stages.len()).sum();
    let setup = Ready::new(total_stages);

    // Replica threads, each an independent run_pipeline fed from a cap-1
    // queue (see module docs for why cap 1).
    let mut feed_txs = Vec::with_capacity(r);
    let mut handles = Vec::with_capacity(r);
    for (i, stages) in replicas.into_iter().enumerate() {
        assert!(!stages.is_empty(), "replica {i} has no stages");
        let (tx, rx) = bounded::<T>(1);
        feed_txs.push(tx);
        let stages = instrument_setup(
            instrument_completion(stages, outstanding.clone(), i),
            &setup,
        );
        let setup = setup.clone();
        let obs = observer.clone().map(|o| (o, i));
        let handle = thread::spawn(move || {
            let mut guard = SetupFailGuard { ready: setup, armed: true };
            let result = run_pipeline_observed(
                stages,
                queue_cap,
                std::iter::from_fn(move || rx.recv()),
                obs,
            );
            // run_pipeline returning means every stage completed setup.
            guard.armed = false;
            result
        });
        handles.push(handle);
    }

    // Dispatcher: admission queue -> least-outstanding-work replica.
    let (adm_tx, adm_rx) = bounded::<T>(admission_cap);
    let dispatcher = {
        let outstanding = outstanding.clone();
        thread::spawn(move || {
            let mut dispatched = vec![0usize; r];
            while let Some(item) = adm_rx.recv() {
                // Least outstanding work; ties break to the lowest index.
                let mut pick = 0;
                let mut least = usize::MAX;
                for i in 0..r {
                    let o = outstanding[i].load(Ordering::SeqCst);
                    if o < least {
                        least = o;
                        pick = i;
                    }
                }
                outstanding[pick].fetch_add(1, Ordering::SeqCst);
                if feed_txs[pick].send(item).is_err() {
                    // Replica feed closed underneath us — stop serving.
                    outstanding[pick].fetch_sub(1, Ordering::SeqCst);
                    break;
                }
                dispatched[pick] += 1;
            }
            for tx in &feed_txs {
                tx.close();
            }
            dispatched
        })
    };

    // Mirror run_pipeline: wait out stage setup (PJRT compiles) before the
    // clock starts and the stream flows; on a poisoned latch skip the
    // stream and let the joins below propagate the replica's panic.
    let setup_ok = setup.wait();
    let start = Instant::now();
    if setup_ok {
        for item in source {
            if adm_tx.send(item).is_err() {
                break;
            }
        }
    }
    adm_tx.close();

    let dispatched = dispatcher.join().expect("dispatcher panicked");
    let mut outputs = Vec::new();
    let mut reports = Vec::with_capacity(r);
    for h in handles {
        let (out, rep) = h.join().expect("replica pipeline panicked");
        outputs.extend(out);
        reports.push(rep);
    }
    // One latency-merge loop for every backend: the same pool the DES and
    // cluster report assembly use ([`crate::obs::pool_latencies`]).
    let (pooled, _) =
        pool_latencies(reports.iter().map(|rep| rep.latencies.samples()));
    let latencies = Summary::from_samples(pooled);
    let wall = start.elapsed();
    let images = reports.iter().map(|rep| rep.images).sum();

    (
        outputs,
        FleetReport { images, wall, latencies, replicas: reports, dispatched },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sleep_stage(name: &str, ms: u64) -> StageSpec<u64> {
        StageSpec::new(
            name,
            Box::new(move || {
                Box::new(move |x: u64| {
                    thread::sleep(Duration::from_millis(ms));
                    x + 1
                })
            }),
        )
    }

    #[test]
    fn fleet_processes_every_item_exactly_once() {
        let replicas = vec![
            vec![sleep_stage("a", 1), sleep_stage("b", 1)],
            vec![sleep_stage("a", 1), sleep_stage("b", 1)],
        ];
        let (out, report) = run_fleet(replicas, 2, 4, 0..40u64);
        let mut sorted = out.clone();
        sorted.sort();
        assert_eq!(sorted, (2..42u64).collect::<Vec<_>>());
        assert_eq!(report.images, 40);
        assert_eq!(report.dispatched.iter().sum::<usize>(), 40);
        assert_eq!(report.latencies.count(), 40);
        assert_eq!(
            report.replicas.iter().map(|r| r.images).collect::<Vec<_>>(),
            report.dispatched
        );
    }

    #[test]
    fn least_outstanding_work_prefers_the_faster_replica() {
        let replicas = vec![
            vec![sleep_stage("fast", 1)],
            vec![sleep_stage("slow", 12)],
        ];
        let (_, report) = run_fleet(replicas, 1, 2, 0..40u64);
        assert_eq!(report.images, 40);
        assert!(
            report.dispatched[0] > report.dispatched[1],
            "fast replica should receive more work: {:?}",
            report.dispatched
        );
    }

    #[test]
    fn identical_replicas_share_work_roughly_evenly() {
        let replicas = vec![
            vec![sleep_stage("a", 3)],
            vec![sleep_stage("a", 3)],
        ];
        let (_, report) = run_fleet(replicas, 1, 2, 0..30u64);
        let (d0, d1) = (report.dispatched[0] as f64, report.dispatched[1] as f64);
        assert!(
            d0 > 0.25 * d1 && d1 > 0.25 * d0,
            "grossly unbalanced dispatch: {:?}",
            report.dispatched
        );
    }

    #[test]
    fn two_replicas_beat_one_on_the_same_load() {
        // 30 items through one 6 ms replica ~ 180 ms; through two ~ 90 ms.
        let one = vec![vec![sleep_stage("s", 6)]];
        let two = vec![vec![sleep_stage("s", 6)], vec![sleep_stage("s", 6)]];
        let (_, rep1) = run_fleet(one, 1, 2, 0..30u64);
        let (_, rep2) = run_fleet(two, 1, 2, 0..30u64);
        assert!(
            rep2.wall.as_secs_f64() < 0.8 * rep1.wall.as_secs_f64(),
            "two replicas {:?} should beat one {:?}",
            rep2.wall,
            rep1.wall
        );
    }

    #[test]
    fn single_replica_fleet_matches_run_pipeline_semantics() {
        let (out, report) =
            run_fleet(vec![vec![sleep_stage("only", 0)]], 1, 1, 0..5u64);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
        assert_eq!(report.images, 5);
        assert_eq!(report.dispatched, vec![5]);
        assert_eq!(report.replicas.len(), 1);
        assert_eq!(report.replicas[0].stages.len(), 1);
    }

    #[test]
    fn empty_source_is_clean() {
        let replicas = vec![vec![sleep_stage("a", 1)], vec![sleep_stage("b", 1)]];
        let (out, report) = run_fleet(replicas, 1, 1, Vec::<u64>::new());
        assert!(out.is_empty());
        assert_eq!(report.images, 0);
        assert_eq!(report.dispatched, vec![0, 0]);
        assert_eq!(report.throughput(), 0.0);
    }

    #[test]
    #[should_panic(expected = "replica pipeline panicked")]
    fn replica_setup_panic_propagates_instead_of_hanging() {
        // If a replica's stage factory dies (bad artifact, missing PJRT),
        // its feed queue closes on unwind, the dispatcher stops, and the
        // panic propagates — the fleet must not deadlock.
        let bad: Vec<StageSpec<u64>> =
            vec![StageSpec::new("bad", Box::new(|| panic!("factory boom")))];
        run_fleet(vec![bad], 1, 1, 0..4u64);
    }

    #[test]
    fn report_renders_aggregate_and_replicas() {
        let replicas = vec![vec![sleep_stage("st", 1)], vec![sleep_stage("st", 1)]];
        let (_, report) = run_fleet(replicas, 1, 2, 0..8u64);
        let s = report.render();
        assert!(s.contains("fleet: 2 replicas"));
        assert!(s.contains("replica 0:"));
        assert!(s.contains("replica 1:"));
        assert!(s.contains("aggregate="));
    }

    #[test]
    fn aggregate_throughput_tracks_sum_of_replica_rates() {
        // Two 4 ms single-stage replicas: steady-state sum = 500 imgs/s.
        // Accept a broad band — scheduling jitter on shared CI hosts.
        let replicas = vec![vec![sleep_stage("s", 4)], vec![sleep_stage("s", 4)]];
        let (_, report) = run_fleet(replicas, 1, 2, 0..60u64);
        let tp = report.throughput();
        assert!(
            tp > 150.0 && tp < 650.0,
            "aggregate {tp:.0} imgs/s far from the ~500 imgs/s rate sum"
        );
    }
}
