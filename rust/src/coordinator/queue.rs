//! Bounded blocking queue — the inter-stage buffer of the real pipeline.
//!
//! Built on `Mutex<VecDeque>` + two `Condvar`s (the offline vendor set has
//! no crossbeam-channel). Provides close semantics for graceful drain and a
//! depth gauge for backpressure introspection.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

struct Inner<T> {
    q: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Sending half (clonable; the queue is MPMC).
pub struct Sender<T>(Arc<Inner<T>>);

/// Receiving half (clonable).
pub struct Receiver<T>(Arc<Inner<T>>);

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        Sender(self.0.clone())
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        Receiver(self.0.clone())
    }
}

/// Create a bounded queue with capacity `cap` (>= 1).
pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    assert!(cap >= 1);
    let inner = Arc::new(Inner {
        q: Mutex::new(State { items: VecDeque::with_capacity(cap), closed: false }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
        cap,
    });
    (Sender(inner.clone()), Receiver(inner))
}

/// Error returned when sending into a closed queue.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

impl<T> Sender<T> {
    /// Blocking send; applies backpressure when the buffer is full.
    /// Returns the item back if the queue was closed.
    pub fn send(&self, item: T) -> Result<(), SendError<T>> {
        let mut st = self.0.q.lock().unwrap();
        loop {
            if st.closed {
                return Err(SendError(item));
            }
            if st.items.len() < self.0.cap {
                st.items.push_back(item);
                self.0.not_empty.notify_one();
                return Ok(());
            }
            st = self.0.not_full.wait(st).unwrap();
        }
    }

    /// Close the queue: receivers drain remaining items then see `None`.
    pub fn close(&self) {
        let mut st = self.0.q.lock().unwrap();
        st.closed = true;
        self.0.not_empty.notify_all();
        self.0.not_full.notify_all();
    }

    /// Current depth (diagnostic).
    pub fn len(&self) -> usize {
        self.0.q.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `None` when the queue is closed AND drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.0.q.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.0.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.0.not_empty.wait(st).unwrap();
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.0.q.lock().unwrap();
        let item = st.items.pop_front();
        if item.is_some() {
            self.0.not_full.notify_one();
        }
        item
    }

    /// Drain up to `max` immediately-available items after a first blocking
    /// receive — the dynamic batcher's collection primitive.
    pub fn recv_batch(&self, max: usize) -> Vec<T> {
        let mut out = Vec::new();
        if max == 0 {
            return out;
        }
        match self.recv() {
            None => return out,
            Some(x) => out.push(x),
        }
        while out.len() < max {
            match self.try_recv() {
                Some(x) => out.push(x),
                None => break,
            }
        }
        out
    }

    pub fn len(&self) -> usize {
        self.0.q.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn fifo_order() {
        let (tx, rx) = bounded(4);
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        tx.close();
        let got: Vec<i32> = std::iter::from_fn(|| rx.recv()).collect();
        assert_eq!(got, vec![0, 1, 2, 3]);
    }

    #[test]
    fn backpressure_blocks_until_consumed() {
        let (tx, rx) = bounded(1);
        tx.send(1).unwrap();
        let t = thread::spawn(move || {
            tx.send(2).unwrap(); // blocks until rx.recv
            tx.close();
        });
        thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), None);
        t.join().unwrap();
    }

    #[test]
    fn close_unblocks_receiver() {
        let (tx, rx) = bounded::<i32>(2);
        let t = thread::spawn(move || rx.recv());
        thread::sleep(Duration::from_millis(20));
        tx.close();
        assert_eq!(t.join().unwrap(), None);
    }

    #[test]
    fn send_after_close_returns_item() {
        let (tx, _rx) = bounded(2);
        tx.close();
        assert_eq!(tx.send(42), Err(SendError(42)));
    }

    #[test]
    fn recv_batch_groups_available() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        let batch = rx.recv_batch(4);
        assert_eq!(batch, vec![0, 1, 2, 3]);
        let rest = rx.recv_batch(4);
        assert_eq!(rest, vec![4]);
    }

    #[test]
    fn mpmc_all_items_delivered_once() {
        let (tx, rx) = bounded(4);
        let mut senders = Vec::new();
        for s in 0..3 {
            let tx = tx.clone();
            senders.push(thread::spawn(move || {
                for i in 0..100 {
                    tx.send(s * 100 + i).unwrap();
                }
            }));
        }
        let mut receivers = Vec::new();
        for _ in 0..2 {
            let rx = rx.clone();
            receivers.push(thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(x) = rx.recv() {
                    got.push(x);
                }
                got
            }));
        }
        for s in senders {
            s.join().unwrap();
        }
        tx.close();
        let mut all: Vec<i32> = receivers
            .into_iter()
            .flat_map(|r| r.join().unwrap())
            .collect();
        all.sort();
        let want: Vec<i32> = (0..3).flat_map(|s| (0..100).map(move |i| s * 100 + i)).collect();
        assert_eq!(all, want);
    }
}
