//! Bounded blocking queue — the inter-stage buffer of the real pipeline.
//!
//! Built on `Mutex<VecDeque>` + two `Condvar`s (the offline vendor set has
//! no crossbeam-channel). Provides close semantics for graceful drain and a
//! depth gauge for backpressure introspection.
//!
//! Endpoints are ref-counted: dropping the LAST `Sender` or the LAST
//! `Receiver` closes the queue, exactly like explicit [`Sender::close`].
//! This is what keeps a panicking stage or replica thread from deadlocking
//! its neighbors — when the panicking side's endpoint unwinds away, blocked
//! peers observe the close (senders get `SendError`, receivers drain then
//! see `None`) and the shutdown cascades instead of hanging.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

struct Inner<T> {
    q: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
    senders: usize,
    receivers: usize,
}

impl<T> Inner<T> {
    fn close_locked(&self, st: &mut State<T>) {
        st.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

/// Sending half (clonable; the queue is MPMC).
pub struct Sender<T>(Arc<Inner<T>>);

/// Receiving half (clonable).
pub struct Receiver<T>(Arc<Inner<T>>);

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.0.q.lock().unwrap().senders += 1;
        Sender(self.0.clone())
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.0.q.lock().unwrap().receivers += 1;
        Receiver(self.0.clone())
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.0.q.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 && !st.closed {
            // No producer left: receivers drain what's buffered, then None.
            self.0.close_locked(&mut st);
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut st = self.0.q.lock().unwrap();
        st.receivers -= 1;
        if st.receivers == 0 && !st.closed {
            // No consumer left: blocked senders must see SendError, not hang.
            self.0.close_locked(&mut st);
        }
    }
}

/// Create a bounded queue with capacity `cap` (>= 1).
///
/// # Example
///
/// ```
/// use pipeit::coordinator::queue::bounded;
///
/// let (tx, rx) = bounded(2);
/// tx.send(1).unwrap();
/// tx.send(2).unwrap();
/// tx.close();
/// assert_eq!(rx.recv(), Some(1));
/// assert_eq!(rx.recv(), Some(2));
/// assert_eq!(rx.recv(), None); // closed and drained
/// ```
pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    assert!(cap >= 1);
    let inner = Arc::new(Inner {
        q: Mutex::new(State {
            items: VecDeque::with_capacity(cap),
            closed: false,
            senders: 1,
            receivers: 1,
        }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
        cap,
    });
    (Sender(inner.clone()), Receiver(inner))
}

/// Error returned when sending into a closed queue.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

/// Error returned by [`Sender::try_send`]: the queue was full (shed the
/// item) or closed (stop producing). Either way the item comes back.
#[derive(Debug, PartialEq, Eq)]
pub enum TrySendError<T> {
    Full(T),
    Closed(T),
}

impl<T> Sender<T> {
    /// Blocking send; applies backpressure when the buffer is full.
    /// Returns the item back if the queue was closed.
    pub fn send(&self, item: T) -> Result<(), SendError<T>> {
        let mut st = self.0.q.lock().unwrap();
        loop {
            if st.closed {
                return Err(SendError(item));
            }
            if st.items.len() < self.0.cap {
                st.items.push_back(item);
                self.0.not_empty.notify_one();
                return Ok(());
            }
            st = self.0.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking send: `Ok` when the item was enqueued, `Err` with the
    /// item back when the buffer is full or the queue is closed — the
    /// admission primitive behind shed-on-full front doors
    /// ([`crate::tenancy::deploy_multi`]): a full queue means the tenant is
    /// over its admission budget and the item is dropped (counted), never
    /// blocking the shared arrival thread.
    pub fn try_send(&self, item: T) -> Result<(), TrySendError<T>> {
        let mut st = self.0.q.lock().unwrap();
        if st.closed {
            return Err(TrySendError::Closed(item));
        }
        if st.items.len() >= self.0.cap {
            return Err(TrySendError::Full(item));
        }
        st.items.push_back(item);
        self.0.not_empty.notify_one();
        Ok(())
    }

    /// Close the queue: receivers drain remaining items then see `None`.
    pub fn close(&self) {
        let mut st = self.0.q.lock().unwrap();
        st.closed = true;
        self.0.not_empty.notify_all();
        self.0.not_full.notify_all();
    }

    /// Current depth (diagnostic).
    pub fn len(&self) -> usize {
        self.0.q.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `None` when the queue is closed AND drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.0.q.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.0.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.0.not_empty.wait(st).unwrap();
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.0.q.lock().unwrap();
        let item = st.items.pop_front();
        if item.is_some() {
            self.0.not_full.notify_one();
        }
        item
    }

    /// Drain up to `max` immediately-available items after a first blocking
    /// receive — the dynamic batcher's collection primitive.
    pub fn recv_batch(&self, max: usize) -> Vec<T> {
        let mut out = Vec::new();
        if max == 0 {
            return out;
        }
        match self.recv() {
            None => return out,
            Some(x) => out.push(x),
        }
        while out.len() < max {
            match self.try_recv() {
                Some(x) => out.push(x),
                None => break,
            }
        }
        out
    }

    pub fn len(&self) -> usize {
        self.0.q.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn fifo_order() {
        let (tx, rx) = bounded(4);
        for i in 0..4 {
            tx.send(i).unwrap();
        }
        tx.close();
        let got: Vec<i32> = std::iter::from_fn(|| rx.recv()).collect();
        assert_eq!(got, vec![0, 1, 2, 3]);
    }

    #[test]
    fn backpressure_blocks_until_consumed() {
        let (tx, rx) = bounded(1);
        tx.send(1).unwrap();
        let t = thread::spawn(move || {
            tx.send(2).unwrap(); // blocks until rx.recv
            tx.close();
        });
        thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), None);
        t.join().unwrap();
    }

    #[test]
    fn close_unblocks_receiver() {
        let (tx, rx) = bounded::<i32>(2);
        let t = thread::spawn(move || rx.recv());
        thread::sleep(Duration::from_millis(20));
        tx.close();
        assert_eq!(t.join().unwrap(), None);
    }

    #[test]
    fn send_after_close_returns_item() {
        let (tx, _rx) = bounded(2);
        tx.close();
        assert_eq!(tx.send(42), Err(SendError(42)));
    }

    #[test]
    fn try_send_sheds_on_full_and_reports_close() {
        let (tx, rx) = bounded(2);
        assert_eq!(tx.try_send(1), Ok(()));
        assert_eq!(tx.try_send(2), Ok(()));
        // Full: the item comes straight back, nothing blocks.
        assert_eq!(tx.try_send(3), Err(TrySendError::Full(3)));
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(tx.try_send(3), Ok(()));
        tx.close();
        assert_eq!(tx.try_send(4), Err(TrySendError::Closed(4)));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), Some(3));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn recv_batch_groups_available() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        let batch = rx.recv_batch(4);
        assert_eq!(batch, vec![0, 1, 2, 3]);
        let rest = rx.recv_batch(4);
        assert_eq!(rest, vec![4]);
    }

    #[test]
    fn dropping_last_receiver_unblocks_senders() {
        let (tx, rx) = bounded(1);
        tx.send(1).unwrap();
        drop(rx);
        // Receiver gone: a blocked/full send must error, not hang.
        assert_eq!(tx.send(2), Err(SendError(2)));
    }

    #[test]
    fn dropping_last_sender_closes_for_receivers() {
        let (tx, rx) = bounded(4);
        tx.send(7).unwrap();
        let t = thread::spawn(move || {
            thread::sleep(Duration::from_millis(20));
            drop(tx);
        });
        // Buffered item still delivered, then a clean close.
        assert_eq!(rx.recv(), Some(7));
        assert_eq!(rx.recv(), None);
        t.join().unwrap();
    }

    #[test]
    fn clones_keep_the_queue_open() {
        let (tx, rx) = bounded(2);
        let tx2 = tx.clone();
        drop(tx);
        tx2.send(1).unwrap(); // one sender left: still open
        let rx2 = rx.clone();
        drop(rx);
        assert_eq!(rx2.recv(), Some(1));
        drop(tx2);
        assert_eq!(rx2.recv(), None);
    }

    #[test]
    fn mpmc_all_items_delivered_once() {
        let (tx, rx) = bounded(4);
        let mut senders = Vec::new();
        for s in 0..3 {
            let tx = tx.clone();
            senders.push(thread::spawn(move || {
                for i in 0..100 {
                    tx.send(s * 100 + i).unwrap();
                }
            }));
        }
        let mut receivers = Vec::new();
        for _ in 0..2 {
            let rx = rx.clone();
            receivers.push(thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(x) = rx.recv() {
                    got.push(x);
                }
                got
            }));
        }
        for s in senders {
            s.join().unwrap();
        }
        tx.close();
        let mut all: Vec<i32> = receivers
            .into_iter()
            .flat_map(|r| r.join().unwrap())
            .collect();
        all.sort();
        let want: Vec<i32> = (0..3).flat_map(|s| (0..100).map(move |i| s * 100 + i)).collect();
        assert_eq!(all, want);
    }
}
