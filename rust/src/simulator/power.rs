//! Cluster power model — substitute for the paper's whole-board socket
//! measurement (§VII-C, Table VII).
//!
//! The paper measures board power with an external supply and subtracts an
//! idle baseline, so the reported "active power" covers cores + memory +
//! coherency traffic. We model: per-core dynamic power scaled by
//! utilization, per-cluster static power while the cluster is powered, a
//! memory-activity term, and an extra coherency term when both clusters are
//! active simultaneously (the paper attributes Pipe-it's efficiency drop to
//! exactly this cross-cluster memory/coherency power).

use crate::simulator::platform::CoreType;

/// Power coefficients (Watts), default-calibrated to Table VII's bands.
#[derive(Debug, Clone)]
pub struct PowerModel {
    /// Dynamic power of one fully-busy core.
    pub big_core_w: f64,
    pub small_core_w: f64,
    /// Static/uncore power while a cluster is powered on at all.
    pub big_static_w: f64,
    pub small_static_w: f64,
    /// Memory-system active power at full streaming utilization.
    pub mem_w: f64,
    /// Extra coherency/CCI power when both clusters are concurrently active.
    pub cci_w: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        PowerModel {
            big_core_w: 0.85,
            small_core_w: 0.17,
            big_static_w: 0.35,
            small_static_w: 0.12,
            mem_w: 0.55,
            cci_w: 0.45,
        }
    }
}

/// Activity of one cluster during a run.
#[derive(Debug, Clone, Copy, Default)]
pub struct ClusterActivity {
    /// Busy cores (may be fractional: core-utilization-weighted).
    pub busy_cores: f64,
    /// Whether the cluster is powered at all (paper powers off the unused
    /// cluster for homogeneous runs).
    pub powered: bool,
    /// Memory intensity in [0,1] — fraction of time spent streaming.
    pub mem_intensity: f64,
}

impl PowerModel {
    /// Average active power (Watts) for the given cluster activities,
    /// mirroring the paper's `P_A = P - P_idle` board measurement.
    pub fn active_power(&self, big: ClusterActivity, small: ClusterActivity) -> f64 {
        let mut p = 0.0;
        if big.powered {
            p += self.big_static_w + self.big_core_w * big.busy_cores;
        }
        if small.powered {
            p += self.small_static_w + self.small_core_w * small.busy_cores;
        }
        let mem = big.mem_intensity.max(small.mem_intensity);
        p += self.mem_w * mem;
        if big.powered && small.powered && big.busy_cores > 0.0 && small.busy_cores > 0.0 {
            p += self.cci_w;
        }
        p
    }

    /// Homogeneous-run power: `h` busy cores on one cluster, other cluster
    /// powered off (paper §VII-C methodology).
    pub fn homogeneous_power(&self, core: CoreType, h: usize, mem_intensity: f64) -> f64 {
        let act = ClusterActivity { busy_cores: h as f64, powered: true, mem_intensity };
        match core {
            CoreType::Big => self.active_power(act, ClusterActivity::default()),
            CoreType::Small => self.active_power(ClusterActivity::default(), act),
        }
    }

    /// Power efficiency in images per Joule.
    pub fn efficiency(throughput_imgs_s: f64, power_w: f64) -> f64 {
        throughput_imgs_s / power_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_bands_match_table7() {
        let m = PowerModel::default();
        // Big cluster fully busy: paper reports 3.8-4.9 W.
        let pb = m.homogeneous_power(CoreType::Big, 4, 0.7);
        assert!((3.2..5.2).contains(&pb), "big={pb}");
        // Small cluster fully busy: paper reports 0.7-1.3 W.
        let ps = m.homogeneous_power(CoreType::Small, 4, 0.7);
        assert!((0.6..1.6).contains(&ps), "small={ps}");
    }

    #[test]
    fn pipeline_power_exceeds_each_cluster() {
        let m = PowerModel::default();
        let both = m.active_power(
            ClusterActivity { busy_cores: 4.0, powered: true, mem_intensity: 0.8 },
            ClusterActivity { busy_cores: 4.0, powered: true, mem_intensity: 0.8 },
        );
        let big_only = m.homogeneous_power(CoreType::Big, 4, 0.8);
        let small_only = m.homogeneous_power(CoreType::Small, 4, 0.8);
        assert!(both > big_only && both > small_only);
        // Coherency term: more than the plain sum of independent runs minus
        // the double-counted memory term.
        assert!(both > big_only + small_only - m.mem_w - 1e-9);
    }

    #[test]
    fn powered_off_cluster_draws_nothing() {
        let m = PowerModel::default();
        let p = m.active_power(
            ClusterActivity { busy_cores: 2.0, powered: true, mem_intensity: 0.0 },
            ClusterActivity::default(),
        );
        assert!((m.big_static_w + 2.0 * m.big_core_w - p).abs() < 1e-12);
    }

    #[test]
    fn efficiency_math() {
        assert!((PowerModel::efficiency(8.9, 5.1) - 1.745).abs() < 0.01);
    }
}
