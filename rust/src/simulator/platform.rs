//! Platform description: a two-cluster single-ISA heterogeneous multi-core
//! (ARM big.LITTLE), default-calibrated to the paper's HiKey 970 testbed
//! (Hi3670: 4x Cortex-A73 @2.4 GHz + 2 MB L2, 4x Cortex-A53 @1.8 GHz +
//! 1 MB L2, CCI-coherent).
//!
//! The GEMM cost coefficients are calibrated so that whole-network
//! throughputs on the homogeneous clusters land near the paper's Table IV
//! (see `simulator::gemm` tests and EXPERIMENTS.md); the *microarchitectural
//! mechanisms* (L2 spill, SCU-scaling concavity, CCI inter-cluster penalty)
//! are modelled structurally, not fitted per-network.

/// Core type of a cluster (the paper's B / s notation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum CoreType {
    Big,
    Small,
}

impl CoreType {
    pub fn letter(self) -> char {
        match self {
            CoreType::Big => 'B',
            CoreType::Small => 's',
        }
    }

    pub fn parse(c: char) -> Option<CoreType> {
        match c {
            'B' | 'b' => Some(CoreType::Big),
            's' | 'S' => Some(CoreType::Small),
            _ => None,
        }
    }
}

/// One homogeneous cluster and its cost coefficients.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub core_type: CoreType,
    pub cores: usize,
    pub freq_ghz: f64,
    /// Shared L2 capacity (bytes) — drives the working-set spill term.
    pub l2_bytes: usize,
    /// Effective ns per MAC per core in the GEMM inner loop (includes the
    /// achievable NEON efficiency, i.e. not theoretical peak).
    pub mac_ns: f64,
    /// Effective ns per byte for operand streaming (im2col + GEMM traffic).
    pub mem_ns_per_byte: f64,
    /// Extra ns per byte once the GEMM working set spills past L2.
    pub spill_ns_per_byte: f64,
    /// Fixed kernel dispatch overhead (us) per major layer.
    pub dispatch_us: f64,
    /// Per-extra-thread fork/join cost (us) of the ARM-CL thread pool.
    pub sync_us: f64,
    /// Intra-cluster memory contention per extra active core (SCU pressure):
    /// multiplies the memory component by `1 + contention*(H-1)`.
    pub contention: f64,
}

/// Whole platform: Big + Small clusters and the CCI interconnect.
#[derive(Debug, Clone)]
pub struct Platform {
    pub name: String,
    pub big: ClusterSpec,
    pub small: ClusterSpec,
    /// Peak multiplicative inflation of execution time when a single kernel
    /// straddles both clusters (conflict misses served over CCI). Applied as
    /// `1 + cci_factor * 4r(1-r)` where `r` is the Big-cluster work share.
    pub cci_factor: f64,
    /// Fixed per-kernel cross-cluster coordination cost (us).
    pub cci_fixed_us: f64,
    /// ARM-CL GEMM row-tile size `ts` (rows of the image matrix per
    /// iteration); `n_iter = ceil(N / ts)`.
    pub tile_rows: usize,
    /// Deterministic "microarchitectural ruggedness" amplitude: per-shape
    /// effects (alignment, TLB, cache conflicts) that a dimension-linear
    /// model cannot capture. 0.10 ≈ the paper's observed ~11-13% residual.
    pub ruggedness: f64,
}

impl Platform {
    /// The paper's testbed.
    pub fn hikey970() -> Platform {
        Platform {
            name: "hikey970".to_string(),
            big: ClusterSpec {
                core_type: CoreType::Big,
                cores: 4,
                freq_ghz: 2.4,
                l2_bytes: 2 * 1024 * 1024,
                // A73: ~9.6 GMAC/s peak/core; ~45% achievable in ARM-CL
                // GEMM => ~0.23 ns/MAC.
                mac_ns: 0.23,
                mem_ns_per_byte: 0.11,
                spill_ns_per_byte: 0.55,
                dispatch_us: 30.0,
                sync_us: 18.0,
                contention: 0.045,
            },
            small: ClusterSpec {
                core_type: CoreType::Small,
                cores: 4,
                freq_ghz: 1.8,
                l2_bytes: 1024 * 1024,
                // A53 in-order, dual-issue NEON: ~3.6 GMAC/s peak/core,
                // lower achievable efficiency => ~0.48 ns/MAC. The memory
                // system is proportionally much weaker than the compute
                // (half the L2, slimmer interconnect ports), which is what
                // makes the FC-heavy AlexNet collapse on this cluster
                // (paper Table IV: 1.5 imgs/s, the largest Big/Small gap).
                mac_ns: 0.48,
                mem_ns_per_byte: 0.40,
                spill_ns_per_byte: 2.6,
                dispatch_us: 40.0,
                sync_us: 25.0,
                contention: 0.06,
            },
            cci_factor: 0.65,
            cci_fixed_us: 150.0,
            tile_rows: 16,
            ruggedness: 0.06,
        }
    }

    pub fn cluster(&self, t: CoreType) -> &ClusterSpec {
        match t {
            CoreType::Big => &self.big,
            CoreType::Small => &self.small,
        }
    }

    pub fn total_cores(&self) -> usize {
        self.big.cores + self.small.cores
    }

    /// All homogeneous stage configurations: (B,1)..(B,H_B), (s,1)..(s,H_s)
    /// — the paper's `H_B + H_s` possible pipeline-stage configs.
    pub fn stage_configs(&self) -> Vec<(CoreType, usize)> {
        let mut v = Vec::new();
        for n in 1..=self.big.cores {
            v.push((CoreType::Big, n));
        }
        for n in 1..=self.small.cores {
            v.push((CoreType::Small, n));
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hikey_shape() {
        let p = Platform::hikey970();
        assert_eq!(p.total_cores(), 8);
        assert_eq!(p.big.l2_bytes, 2 * p.small.l2_bytes);
        assert!(p.big.mac_ns < p.small.mac_ns);
        assert_eq!(p.stage_configs().len(), 8);
    }

    #[test]
    fn core_type_letters() {
        assert_eq!(CoreType::Big.letter(), 'B');
        assert_eq!(CoreType::parse('s'), Some(CoreType::Small));
        assert_eq!(CoreType::parse('x'), None);
    }
}
