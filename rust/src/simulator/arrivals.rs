//! Open-loop (arrival-driven) pipeline simulation — extension beyond the
//! paper's saturated-stream evaluation, for serving scenarios where frames
//! arrive at a camera rate and the metric is latency/SLO attainment rather
//! than peak throughput (the paper's §I continuous-vision motivation).

use crate::util::rng::Rng;
use crate::util::stats;

/// Result of an open-loop simulation.
#[derive(Debug, Clone)]
pub struct OpenLoopReport {
    pub served: usize,
    pub makespan: f64,
    pub p50_latency: f64,
    pub p95_latency: f64,
    pub p99_latency: f64,
    pub max_queue_wait: f64,
    /// Fraction of images whose end-to-end latency met the deadline.
    pub slo_attainment: f64,
}

/// Deterministic-rate arrivals: one image every `1/rate` seconds.
pub fn uniform_arrivals(rate_hz: f64, count: usize) -> Vec<f64> {
    (0..count).map(|i| i as f64 / rate_hz).collect()
}

/// Poisson arrivals at `rate_hz` (exponential inter-arrival gaps).
pub fn poisson_arrivals(rate_hz: f64, count: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..count)
        .map(|_| {
            t += -rng.uniform().max(1e-12).ln() / rate_hz;
            t
        })
        .collect()
}

/// Simulate arrival-driven execution through deterministic stages with
/// infinite admission queue and bounded inter-stage buffers (`cap`).
/// `deadline` is the per-image end-to-end latency SLO.
pub fn simulate_open_loop(
    stage_times: &[f64],
    arrivals: &[f64],
    cap: usize,
    deadline: f64,
) -> OpenLoopReport {
    assert!(!stage_times.is_empty());
    assert!(cap >= 1);
    let p = stage_times.len();
    let n = arrivals.len();
    assert!(n >= 1);

    let mut dep = vec![vec![0.0f64; n]; p];
    for i in 0..n {
        for s in 0..p {
            let ready = if s == 0 {
                let prev = if i == 0 { 0.0 } else { dep[0][i - 1] };
                arrivals[i].max(prev)
            } else {
                let upstream = dep[s - 1][i];
                let prev = if i == 0 { 0.0 } else { dep[s][i - 1] };
                upstream.max(prev)
            };
            let unblock = if s + 1 < p && i > cap {
                dep[s + 1][i - cap - 1]
            } else {
                0.0
            };
            dep[s][i] = ready.max(unblock) + stage_times[s];
        }
    }

    let latencies: Vec<f64> = (0..n).map(|i| dep[p - 1][i] - arrivals[i]).collect();
    let service: f64 = stage_times.iter().sum();
    let waits: Vec<f64> = latencies.iter().map(|l| l - service).collect();
    let met = latencies.iter().filter(|l| **l <= deadline).count();

    OpenLoopReport {
        served: n,
        makespan: dep[p - 1][n - 1],
        p50_latency: stats::percentile(&latencies, 50.0),
        p95_latency: stats::percentile(&latencies, 95.0),
        p99_latency: stats::percentile(&latencies, 99.0),
        max_queue_wait: waits.iter().copied().fold(0.0, f64::max),
        slo_attainment: met as f64 / n as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn underloaded_pipeline_has_service_latency() {
        // Arrivals far slower than the bottleneck: latency == service time.
        let times = [0.01, 0.02];
        let arr = uniform_arrivals(5.0, 100); // bottleneck supports 50/s
        let r = simulate_open_loop(&times, &arr, 2, 0.1);
        assert!((r.p50_latency - 0.03).abs() < 1e-9);
        assert!((r.slo_attainment - 1.0).abs() < 1e-12);
        assert!(r.max_queue_wait < 1e-9);
    }

    #[test]
    fn overloaded_pipeline_builds_queue() {
        // Arrivals at 2x the bottleneck rate: latency grows unboundedly.
        let times = [0.02];
        let arr = uniform_arrivals(100.0, 400);
        let r = simulate_open_loop(&times, &arr, 2, 0.1);
        assert!(r.p99_latency > r.p50_latency);
        assert!(r.slo_attainment < 0.5);
        assert!(r.max_queue_wait > 1.0);
    }

    #[test]
    fn poisson_mean_rate_matches() {
        let arr = poisson_arrivals(50.0, 20_000, 3);
        let rate = arr.len() as f64 / arr.last().unwrap();
        assert!((rate - 50.0).abs() < 2.0, "rate {rate}");
    }

    #[test]
    fn property_latency_at_least_service() {
        check(100, |rng| {
            let p = 1 + rng.index(4);
            let times: Vec<f64> = (0..p).map(|_| rng.range_f64(0.001, 0.02)).collect();
            let service: f64 = times.iter().sum();
            let rate = rng.range_f64(5.0, 200.0);
            let arr = poisson_arrivals(rate, 50 + rng.index(100), rng.next_u64());
            let r = simulate_open_loop(&times, &arr, 1 + rng.index(3), 1.0);
            crate::prop_assert!(
                r.p50_latency >= service - 1e-12,
                "latency below service time"
            );
            crate::prop_assert!(r.makespan >= *arr.last().unwrap(), "makespan too small");
            Ok(())
        });
    }

    #[test]
    fn property_slo_monotone_in_deadline() {
        check(50, |rng| {
            let times = [rng.range_f64(0.005, 0.02), rng.range_f64(0.005, 0.02)];
            let arr = poisson_arrivals(60.0, 200, rng.next_u64());
            let loose = simulate_open_loop(&times, &arr, 2, 1.0).slo_attainment;
            let tight = simulate_open_loop(&times, &arr, 2, 0.03).slo_attainment;
            crate::prop_assert!(loose >= tight, "looser deadline must not hurt SLO");
            Ok(())
        });
    }
}
