//! Open-loop (arrival-driven) pipeline simulation — extension beyond the
//! paper's saturated-stream evaluation, for serving scenarios where frames
//! arrive at a camera rate and the metric is latency/SLO attainment rather
//! than peak throughput (the paper's §I continuous-vision motivation).

use std::fmt;

use crate::util::rng::Rng;
use crate::util::stats;

/// Result of an open-loop simulation.
#[derive(Debug, Clone)]
pub struct OpenLoopReport {
    pub served: usize,
    pub makespan: f64,
    pub p50_latency: f64,
    pub p95_latency: f64,
    pub p99_latency: f64,
    pub max_queue_wait: f64,
    /// Fraction of images whose end-to-end latency met the deadline.
    pub slo_attainment: f64,
}

/// A parsed `--arrival` CLI spec: which arrival process drives an open-loop
/// run, at what rate, and (for Poisson) under which stream seed — so
/// open-loop serve/simulate runs are reproducible from the command line.
///
/// Grammar: `poisson:RATE[:SEED]` or `uniform:RATE` (RATE in images/s).
/// A Poisson spec without an explicit seed falls back to the run's
/// `--seed` through [`ArrivalSpec::generate`]'s `default_seed`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalSpec {
    /// One image every `1/rate_hz` seconds ([`uniform_arrivals`]).
    Uniform { rate_hz: f64 },
    /// Exponential inter-arrival gaps at `rate_hz` ([`poisson_arrivals`]).
    Poisson { rate_hz: f64, seed: Option<u64> },
}

impl ArrivalSpec {
    /// Parse `poisson:RATE[:SEED]` / `uniform:RATE`.
    pub fn parse(s: &str) -> anyhow::Result<ArrivalSpec> {
        let parts: Vec<&str> = s.split(':').collect();
        let bad = || {
            anyhow::anyhow!(
                "bad arrival spec {s:?} (expected poisson:RATE[:SEED] or uniform:RATE)"
            )
        };
        let rate = |txt: &str| -> anyhow::Result<f64> {
            let r: f64 = txt.parse().map_err(|_| bad())?;
            anyhow::ensure!(r.is_finite() && r > 0.0, "arrival rate must be positive, got {txt:?}");
            Ok(r)
        };
        match parts.as_slice() {
            ["uniform", r] => Ok(ArrivalSpec::Uniform { rate_hz: rate(*r)? }),
            ["poisson", r] => Ok(ArrivalSpec::Poisson { rate_hz: rate(*r)?, seed: None }),
            ["poisson", r, seed] => Ok(ArrivalSpec::Poisson {
                rate_hz: rate(*r)?,
                seed: Some(seed.parse().map_err(|_| bad())?),
            }),
            _ => Err(bad()),
        }
    }

    /// The spec's arrival rate in images/s.
    pub fn rate_hz(&self) -> f64 {
        match self {
            ArrivalSpec::Uniform { rate_hz } | ArrivalSpec::Poisson { rate_hz, .. } => *rate_hz,
        }
    }

    /// Materialize `count` arrival times. Poisson specs without their own
    /// seed use `default_seed` (the CLI's `--seed`), so runs stay
    /// reproducible either way.
    pub fn generate(&self, count: usize, default_seed: u64) -> Vec<f64> {
        match self {
            ArrivalSpec::Uniform { rate_hz } => uniform_arrivals(*rate_hz, count),
            ArrivalSpec::Poisson { rate_hz, seed } => {
                poisson_arrivals(*rate_hz, count, seed.unwrap_or(default_seed))
            }
        }
    }
}

impl fmt::Display for ArrivalSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArrivalSpec::Uniform { rate_hz } => write!(f, "uniform:{rate_hz}"),
            ArrivalSpec::Poisson { rate_hz, seed: None } => write!(f, "poisson:{rate_hz}"),
            ArrivalSpec::Poisson { rate_hz, seed: Some(s) } => {
                write!(f, "poisson:{rate_hz}:{s}")
            }
        }
    }
}

/// Deterministic-rate arrivals: one image every `1/rate` seconds.
pub fn uniform_arrivals(rate_hz: f64, count: usize) -> Vec<f64> {
    (0..count).map(|i| i as f64 / rate_hz).collect()
}

/// Poisson arrivals at `rate_hz` (exponential inter-arrival gaps).
pub fn poisson_arrivals(rate_hz: f64, count: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..count)
        .map(|_| {
            t += -rng.uniform().max(1e-12).ln() / rate_hz;
            t
        })
        .collect()
}

/// Simulate arrival-driven execution through deterministic stages with
/// infinite admission queue and bounded inter-stage buffers (`cap`).
/// `deadline` is the per-image end-to-end latency SLO.
pub fn simulate_open_loop(
    stage_times: &[f64],
    arrivals: &[f64],
    cap: usize,
    deadline: f64,
) -> OpenLoopReport {
    assert!(!stage_times.is_empty());
    assert!(cap >= 1);
    let p = stage_times.len();
    let n = arrivals.len();
    assert!(n >= 1);

    let mut dep = vec![vec![0.0f64; n]; p];
    for i in 0..n {
        for s in 0..p {
            let ready = if s == 0 {
                let prev = if i == 0 { 0.0 } else { dep[0][i - 1] };
                arrivals[i].max(prev)
            } else {
                let upstream = dep[s - 1][i];
                let prev = if i == 0 { 0.0 } else { dep[s][i - 1] };
                upstream.max(prev)
            };
            let unblock = if s + 1 < p && i > cap {
                dep[s + 1][i - cap - 1]
            } else {
                0.0
            };
            dep[s][i] = ready.max(unblock) + stage_times[s];
        }
    }

    let latencies: Vec<f64> = (0..n).map(|i| dep[p - 1][i] - arrivals[i]).collect();
    let service: f64 = stage_times.iter().sum();
    let waits: Vec<f64> = latencies.iter().map(|l| l - service).collect();
    let met = latencies.iter().filter(|l| **l <= deadline).count();

    OpenLoopReport {
        served: n,
        makespan: dep[p - 1][n - 1],
        p50_latency: stats::percentile(&latencies, 50.0),
        p95_latency: stats::percentile(&latencies, 95.0),
        p99_latency: stats::percentile(&latencies, 99.0),
        max_queue_wait: waits.iter().copied().fold(0.0, f64::max),
        slo_attainment: met as f64 / n as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn underloaded_pipeline_has_service_latency() {
        // Arrivals far slower than the bottleneck: latency == service time.
        let times = [0.01, 0.02];
        let arr = uniform_arrivals(5.0, 100); // bottleneck supports 50/s
        let r = simulate_open_loop(&times, &arr, 2, 0.1);
        assert!((r.p50_latency - 0.03).abs() < 1e-9);
        assert!((r.slo_attainment - 1.0).abs() < 1e-12);
        assert!(r.max_queue_wait < 1e-9);
    }

    #[test]
    fn overloaded_pipeline_builds_queue() {
        // Arrivals at 2x the bottleneck rate: latency grows unboundedly.
        let times = [0.02];
        let arr = uniform_arrivals(100.0, 400);
        let r = simulate_open_loop(&times, &arr, 2, 0.1);
        assert!(r.p99_latency > r.p50_latency);
        assert!(r.slo_attainment < 0.5);
        assert!(r.max_queue_wait > 1.0);
    }

    #[test]
    fn arrival_spec_parses_and_generates() {
        let p = ArrivalSpec::parse("poisson:30").unwrap();
        assert_eq!(p, ArrivalSpec::Poisson { rate_hz: 30.0, seed: None });
        let ps = ArrivalSpec::parse("poisson:30:123").unwrap();
        assert_eq!(ps, ArrivalSpec::Poisson { rate_hz: 30.0, seed: Some(123) });
        let u = ArrivalSpec::parse("uniform:12.5").unwrap();
        assert_eq!(u, ArrivalSpec::Uniform { rate_hz: 12.5 });
        assert_eq!(u.rate_hz(), 12.5);
        // The spec's own seed wins; the default only fills the gap.
        assert_eq!(ps.generate(50, 7), poisson_arrivals(30.0, 50, 123));
        assert_eq!(p.generate(50, 7), poisson_arrivals(30.0, 50, 7));
        assert_eq!(u.generate(4, 0), uniform_arrivals(12.5, 4));
        assert_eq!(format!("{ps}"), "poisson:30:123");
    }

    #[test]
    fn arrival_spec_rejects_malformed_input() {
        for bad in ["", "poisson", "poisson:", "poisson:0", "poisson:-3",
                    "uniform:abc", "uniform:30:1", "burst:9", "poisson:30:x"] {
            assert!(ArrivalSpec::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn poisson_mean_rate_matches() {
        let arr = poisson_arrivals(50.0, 20_000, 3);
        let rate = arr.len() as f64 / arr.last().unwrap();
        assert!((rate - 50.0).abs() < 2.0, "rate {rate}");
    }

    #[test]
    fn property_latency_at_least_service() {
        check(100, |rng| {
            let p = 1 + rng.index(4);
            let times: Vec<f64> = (0..p).map(|_| rng.range_f64(0.001, 0.02)).collect();
            let service: f64 = times.iter().sum();
            let rate = rng.range_f64(5.0, 200.0);
            let arr = poisson_arrivals(rate, 50 + rng.index(100), rng.next_u64());
            let r = simulate_open_loop(&times, &arr, 1 + rng.index(3), 1.0);
            crate::prop_assert!(
                r.p50_latency >= service - 1e-12,
                "latency below service time"
            );
            crate::prop_assert!(r.makespan >= *arr.last().unwrap(), "makespan too small");
            Ok(())
        });
    }

    #[test]
    fn property_slo_monotone_in_deadline() {
        check(50, |rng| {
            let times = [rng.range_f64(0.005, 0.02), rng.range_f64(0.005, 0.02)];
            let arr = poisson_arrivals(60.0, 200, rng.next_u64());
            let loose = simulate_open_loop(&times, &arr, 2, 1.0).slo_attainment;
            let tight = simulate_open_loop(&times, &arr, 2, 0.03).slo_attainment;
            crate::prop_assert!(loose >= tight, "looser deadline must not hurt SLO");
            Ok(())
        });
    }
}
