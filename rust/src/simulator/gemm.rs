//! Ground-truth GEMM execution-time model — the simulated "hardware".
//!
//! This plays the role of the physical HiKey 970 board: the performance
//! *predictor* in `perfmodel` (the paper's contribution) is fit against
//! measurements taken from this module, exactly as the paper fits its
//! regression against board measurements.
//!
//! Mechanisms modelled (all referenced to paper sections):
//! * im2col + GEMM cost split into compute + operand-streaming + L2-spill
//!   components (§V-A: "compute time of GEMM is a complex function of the
//!   memory accesses, arithmetic computations, ...").
//! * ARM-CL row-chunk dispatch: `n_iter = ceil(N / ts)` iterations dealt to
//!   `H` threads; quantization + fork/join sync + SCU contention produce the
//!   speedup concavity of Fig. 11.
//! * Cross-cluster HMP execution: equal-per-thread (Fig. 3) or ratio-split
//!   (Fig. 5) distribution with a CCI conflict-miss penalty `1 + f*4r(1-r)`.
//! * Deterministic per-shape "ruggedness" — alignment/TLB/cache-conflict
//!   texture that a dimension-linear regression cannot capture, sized to
//!   reproduce the paper's ~11-13% Table III residuals.

use crate::cnn::layer::{Layer, LayerKind};
use crate::simulator::platform::{ClusterSpec, CoreType, Platform};

/// Deterministic pseudo-random factor in [1-amp, 1+amp] keyed on the GEMM
/// shape. Uses SplitMix64-style mixing so it is smooth-free (rugged) but
/// perfectly reproducible.
fn ruggedness_factor(n: usize, k: usize, m: usize, core: CoreType, amp: f64) -> f64 {
    let mut z = (n as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add((k as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9))
        .wrapping_add((m as u64).wrapping_mul(0x94D0_49BB_1331_11EB))
        .wrapping_add(if core == CoreType::Big { 17 } else { 91 });
    z ^= z >> 30;
    z = z.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z ^= z >> 27;
    let unit = (z >> 11) as f64 / (1u64 << 53) as f64; // [0,1)
    1.0 + amp * (2.0 * unit - 1.0)
}

/// Number of ARM-CL iterations for a layer's GEMM (row chunks of the image
/// matrix). FC layers have N = 1, where ARM-CL parallelizes the GEMV along
/// the output dimension instead — modelled as chunks of M.
pub fn n_iterations(layer: &Layer, tile_rows: usize) -> usize {
    let g = layer.gemm();
    let rows = if layer.kind == LayerKind::Fc { g.m } else { g.n };
    rows.div_ceil(tile_rows).max(1)
}

/// Single-core execution time (seconds) of one major layer on a cluster's
/// core type. This is "the board measurement" for 1 core.
pub fn layer_time_1core(platform: &Platform, layer: &Layer, core: CoreType) -> f64 {
    let c = platform.cluster(core);
    let g = layer.gemm();

    let compute_ns = g.macs() as f64 * c.mac_ns;

    // Operand traffic: image matrix is produced by im2col (read input, write
    // N*K), filter matrix streamed, result written back (col2im).
    let bytes = (g.n * g.k + g.k * g.m + 2 * g.n * g.m) as f64 * 4.0
        + layer.input_bytes() as f64;
    let mem_ns = bytes * c.mem_ns_per_byte;

    // Working-set spill past the cluster L2: the portion that cannot be
    // kept resident is re-streamed at far-memory cost.
    let ws = layer.gemm_bytes() as f64;
    let l2 = c.l2_bytes as f64;
    let spill_ns = if ws > l2 { (ws - l2) * c.spill_ns_per_byte } else { 0.0 };

    // Depthwise layers run many tiny GEMMs: poor NEON utilization, extra
    // per-channel dispatch (§II; MobileNet's DW nodes are known to be
    // inefficient in ARM-CL v18).
    let kind_factor = match layer.kind {
        LayerKind::DwConv => 2.2,
        LayerKind::Fc | LayerKind::Conv => 1.0,
    };

    let rug = ruggedness_factor(g.n, g.k, g.m, core, platform.ruggedness);
    let work_ns = (compute_ns + mem_ns + spill_ns) * kind_factor * rug;
    (work_ns + c.dispatch_us * 1e3) * 1e-9
}

/// Multi-core (intra-cluster) execution time (seconds) of one layer using
/// `h` homogeneous cores: ARM-CL deals `n_iter` row chunks to `h` threads.
pub fn layer_time(platform: &Platform, layer: &Layer, core: CoreType, h: usize) -> f64 {
    assert!(h >= 1, "need at least one core");
    let c = platform.cluster(core);
    assert!(h <= c.cores, "{h} cores requested on a {}-core cluster", c.cores);
    if h == 1 {
        return layer_time_1core(platform, layer, core);
    }

    let t1 = layer_time_1core(platform, layer, core);
    let dispatch_s = c.dispatch_us * 1e-6;
    let work = t1 - dispatch_s; // parallelizable portion

    let n_iter = n_iterations(layer, platform.tile_rows);
    let per_iter = work / n_iter as f64;
    // Slowest thread gets ceil(n_iter / h) chunks (equal static dealing).
    let chunks = n_iter.div_ceil(h) as f64;
    // SCU pressure: parallel L2 access contention grows with active cores.
    let contention = 1.0 + c.contention * (h as f64 - 1.0);
    let sync_s = c.sync_us * 1e-6 * (h as f64 - 1.0).sqrt();

    dispatch_s + per_iter * chunks * contention + sync_s
}

/// Execution time of a whole set of layers on one stage config (seconds)
/// — the paper's `T_{L_i}^{P_i}` (Eq. 10).
pub fn layers_time(
    platform: &Platform,
    layers: &[Layer],
    core: CoreType,
    h: usize,
) -> f64 {
    layers.iter().map(|l| layer_time(platform, l, core, h)).sum()
}

/// Kernel-level Heterogeneous Multi-Processing: one kernel split across
/// `hb` Big + `hs` Small cores with *equal* per-thread chunks (Fig. 3).
/// Cross-cluster conflict misses are served over CCI, inflating the time by
/// `1 + cci_factor * 4 r (1-r)` where `r` is the Big-side share of work.
pub fn layer_time_hmp(platform: &Platform, layer: &Layer, hb: usize, hs: usize) -> f64 {
    assert!(hb + hs >= 1);
    if hs == 0 {
        return layer_time(platform, layer, CoreType::Big, hb);
    }
    if hb == 0 {
        return layer_time(platform, layer, CoreType::Small, hs);
    }

    let n_iter = n_iterations(layer, platform.tile_rows);
    // Fractional chunk accounting: averaged over a whole network the
    // per-kernel ceil() quantization washes out, and fractional dealing
    // keeps the Fig. 3 recovery monotone as Small cores are added.
    let chunks_each = n_iter as f64 / (hb + hs) as f64;

    let t1b = layer_time_1core(platform, layer, CoreType::Big);
    let t1s = layer_time_1core(platform, layer, CoreType::Small);
    let per_iter_b = (t1b - platform.big.dispatch_us * 1e-6) / n_iter as f64;
    let per_iter_s = (t1s - platform.small.dispatch_us * 1e-6) / n_iter as f64;

    // Equal dealing => Big share of the work r = hb/(hb+hs).
    let r = hb as f64 / (hb + hs) as f64;
    let cci = 1.0 + platform.cci_factor * 4.0 * r * (1.0 - r);

    let cont_b = 1.0 + platform.big.contention * (hb as f64 - 1.0);
    let cont_s = 1.0 + platform.small.contention * (hs as f64 - 1.0);
    let tb = per_iter_b * chunks_each * cont_b;
    let ts = per_iter_s * chunks_each * cont_s;

    let dispatch = platform.big.dispatch_us.max(platform.small.dispatch_us) * 1e-6;
    let sync = (platform.big.sync_us + platform.small.sync_us) * 1e-6;
    dispatch + tb.max(ts) * cci + sync + platform.cci_fixed_us * 1e-6
}

/// Kernel-level HMP with a *disproportionate* iteration split (Fig. 5):
/// fraction `ratio` of iterations to the Big cluster (dealt over its `hb`
/// cores), remainder to Small.
pub fn layer_time_hmp_ratio(
    platform: &Platform,
    layer: &Layer,
    hb: usize,
    hs: usize,
    ratio: f64,
) -> f64 {
    assert!((0.0..=1.0).contains(&ratio));
    if ratio >= 1.0 || hs == 0 {
        return layer_time(platform, layer, CoreType::Big, hb);
    }
    if ratio <= 0.0 || hb == 0 {
        return layer_time(platform, layer, CoreType::Small, hs);
    }

    let n_iter = n_iterations(layer, platform.tile_rows) as f64;
    let t1b = layer_time_1core(platform, layer, CoreType::Big);
    let t1s = layer_time_1core(platform, layer, CoreType::Small);
    let per_iter_b = (t1b - platform.big.dispatch_us * 1e-6) / n_iter;
    let per_iter_s = (t1s - platform.small.dispatch_us * 1e-6) / n_iter;

    let iters_b = n_iter * ratio / hb as f64;
    let iters_s = n_iter * (1.0 - ratio) / hs as f64;
    let cont_b = 1.0 + platform.big.contention * (hb as f64 - 1.0);
    let cont_s = 1.0 + platform.small.contention * (hs as f64 - 1.0);

    let cci = 1.0 + platform.cci_factor * 4.0 * ratio * (1.0 - ratio);
    let dispatch = platform.big.dispatch_us.max(platform.small.dispatch_us) * 1e-6;
    let sync = (platform.big.sync_us + platform.small.sync_us) * 1e-6;
    dispatch
        + (per_iter_b * iters_b * cont_b).max(per_iter_s * iters_s * cont_s) * cci
        + sync
        + platform.cci_fixed_us * 1e-6
}

/// Per-image forward-pass time (seconds) of a whole network with
/// kernel-level splitting on a homogeneous cluster (the paper's baseline).
pub fn network_time(platform: &Platform, layers: &[Layer], core: CoreType, h: usize) -> f64 {
    layers_time(platform, layers, core, h)
}

/// Per-image forward-pass time with kernel-level HMP over both clusters.
pub fn network_time_hmp(platform: &Platform, layers: &[Layer], hb: usize, hs: usize) -> f64 {
    layers.iter().map(|l| layer_time_hmp(platform, l, hb, hs)).sum()
}

/// Convenience: throughput (images/s) from a per-image time.
pub fn throughput(t_image: f64) -> f64 {
    1.0 / t_image
}

/// Capability ordering check helper (paper Eq. 11): mean layer time over a
/// network for a stage config — smaller is more capable.
pub fn mean_layer_time(
    platform: &Platform,
    layers: &[Layer],
    core: CoreType,
    h: usize,
) -> f64 {
    layers_time(platform, layers, core, h) / layers.len() as f64
}

#[allow(dead_code)]
fn cluster_of(platform: &Platform, core: CoreType) -> &ClusterSpec {
    platform.cluster(core)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::zoo;

    fn plat() -> Platform {
        Platform::hikey970()
    }

    fn big_conv() -> Layer {
        Layer::conv("c", 56, 56, 64, 3, 64, 1, 1)
    }

    #[test]
    fn more_cores_is_faster_within_cluster() {
        let p = plat();
        let l = big_conv();
        for core in [CoreType::Big, CoreType::Small] {
            let mut prev = f64::INFINITY;
            for h in 1..=4 {
                let t = layer_time(&p, &l, core, h);
                assert!(t < prev, "{core:?} h={h}: {t} !< {prev}");
                prev = t;
            }
        }
    }

    #[test]
    fn speedup_is_concave() {
        // Fig. 11: speedup gains shrink with each added core.
        let p = plat();
        let l = big_conv();
        let t1 = layer_time(&p, &l, CoreType::Big, 1);
        let s: Vec<f64> = (1..=4)
            .map(|h| t1 / layer_time(&p, &l, CoreType::Big, h))
            .collect();
        let d1 = s[1] - s[0];
        let d2 = s[2] - s[1];
        let d3 = s[3] - s[2];
        assert!(d1 > d2 && d2 > d3, "increments {d1} {d2} {d3}");
        assert!(s[3] < 4.0, "superlinear speedup is wrong");
    }

    #[test]
    fn big_faster_than_small() {
        let p = plat();
        let l = big_conv();
        for h in 1..=4 {
            assert!(
                layer_time(&p, &l, CoreType::Big, h)
                    < layer_time(&p, &l, CoreType::Small, h)
            );
        }
    }

    #[test]
    fn eq11_capability_ordering() {
        // T^(B,4) < T^(B,3) < T^(B,2) <~ T^(s,4) < T^(s,3) < T^(s,2) <~
        // T^(B,1) < T^(s,1) — checked as mean layer time over ResNet50.
        let p = plat();
        let net = zoo::resnet50();
        let t = |c, h| mean_layer_time(&p, &net.layers, c, h);
        assert!(t(CoreType::Big, 4) < t(CoreType::Big, 3));
        assert!(t(CoreType::Big, 3) < t(CoreType::Big, 2));
        assert!(t(CoreType::Small, 4) < t(CoreType::Small, 3));
        assert!(t(CoreType::Small, 3) < t(CoreType::Small, 2));
        assert!(t(CoreType::Small, 2) < t(CoreType::Big, 1) * 1.6); // <~
        assert!(t(CoreType::Big, 1) < t(CoreType::Small, 1));
    }

    #[test]
    fn fig3_hmp_collapse() {
        // Adding the first Small core to a 4-Big kernel-level split must
        // REDUCE throughput; 4B+4s must not beat 4B.
        let p = plat();
        for net in zoo::all_networks() {
            let t_4b = network_time(&p, &net.layers, CoreType::Big, 4);
            let t_4b1s = network_time_hmp(&p, &net.layers, 4, 1);
            let t_4b4s = network_time_hmp(&p, &net.layers, 4, 4);
            assert!(t_4b1s > t_4b, "{}: 4B+1s should drop", net.name);
            assert!(t_4b4s > t_4b * 0.99, "{}: 4B+4s should not beat 4B", net.name);
        }
    }

    #[test]
    fn fig5_no_ratio_beats_big_only() {
        let p = plat();
        for net in zoo::all_networks() {
            let t_big: f64 = network_time(&p, &net.layers, CoreType::Big, 4);
            let best_ratio = (1..20)
                .map(|i| {
                    let r = i as f64 / 20.0;
                    net.layers
                        .iter()
                        .map(|l| layer_time_hmp_ratio(&p, l, 4, 4, r))
                        .sum::<f64>()
                })
                .fold(f64::INFINITY, f64::min);
            assert!(
                best_ratio > t_big * 0.97,
                "{}: some ratio beats Big-only materially",
                net.name
            );
        }
    }

    #[test]
    fn ruggedness_is_deterministic_and_bounded() {
        let f1 = ruggedness_factor(100, 200, 300, CoreType::Big, 0.1);
        let f2 = ruggedness_factor(100, 200, 300, CoreType::Big, 0.1);
        assert_eq!(f1, f2);
        assert!((0.9..=1.1).contains(&f1));
        let g = ruggedness_factor(101, 200, 300, CoreType::Big, 0.1);
        assert_ne!(f1, g);
    }

    #[test]
    fn fc_layers_parallelize_along_m() {
        let p = plat();
        let fc = Layer::fc("fc6", 9216, 4096);
        assert!(n_iterations(&fc, p.tile_rows) > 1);
        assert!(
            layer_time(&p, &fc, CoreType::Big, 4) < layer_time(&p, &fc, CoreType::Big, 1)
        );
    }

    #[test]
    fn table4_homogeneous_calibration_shape() {
        // Big-cluster throughput ordering must match Table IV:
        // MobileNet > SqueezeNet > AlexNet ~ GoogLeNet > ResNet50,
        // and Big/Small ratios in the paper's 2-5.5x range.
        let p = plat();
        let tp = |name: &str, c, h| {
            let net = zoo::by_name(name).unwrap();
            throughput(network_time(&p, &net.layers, c, h))
        };
        let b = |n: &str| tp(n, CoreType::Big, 4);
        let s = |n: &str| tp(n, CoreType::Small, 4);
        assert!(b("mobilenet") > b("squeezenet"));
        assert!(b("squeezenet") > b("alexnet"));
        assert!(b("alexnet") > b("resnet50"));
        assert!(b("googlenet") > b("resnet50"));
        for n in ["alexnet", "googlenet", "mobilenet", "resnet50", "squeezenet"] {
            let ratio = b(n) / s(n);
            assert!(
                (1.8..6.5).contains(&ratio),
                "{n}: Big/Small ratio {ratio:.2} out of the paper's band"
            );
        }
    }
}
