//! The shared DES event core (DESIGN.md §15): the data structures and the
//! one tandem-recurrence step that all three event engines —
//! [`pipeline_sim`](crate::simulator::pipeline_sim),
//! [`tenancy::cosim`](crate::tenancy), [`cluster::cosim`](crate::cluster)
//! — are built on.
//!
//! * [`EventHeap`] — a binary min-heap of event times with write-only
//!   profiler tallies. Its [`live_after`](EventHeap::live_after) query is
//!   the O(log n) front door: because arrival times are non-decreasing,
//!   an event popped at one arrival can never be live at a later one, so
//!   counting "admitted items still waiting" costs amortized O(log n) per
//!   arrival instead of the reference engine's O(n) linear scan.
//! * [`RingArena`] — arena-allocated bounded departure rings: every ring
//!   of a run lives in ONE contiguous `Vec<f64>`, each a fixed-capacity
//!   circular window of the last `queue_cap + 1` departures per stage —
//!   exactly the window the blocking recurrence reads. State is
//!   O(stages · queue_cap), independent of stream length.
//! * [`tandem_step`] / [`tandem_step_with`] — one admitted item moved
//!   through the blocking tandem-queue recurrence
//!   `d[i][s] = max(d[i][s-1], d[i-1][s], d[i-cap-1][s+1]) + T_s`
//!   over the rings. Float-operation order is identical to the historical
//!   full-history engines, so results are bit-identical (the differential
//!   suite in `tests/engine_core.rs` enforces this).
//! * [`stationary`] — detection of bitwise-periodic steady-state segments,
//!   powering the closed-form fast path
//!   ([`simulate_stationary`](crate::simulator::pipeline_sim::simulate_stationary)).
//!
//! All counters here are write-only for the recurrence: instrumentation
//! cannot perturb simulation results.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Total-order f64 wrapper so event times can live in a [`BinaryHeap`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct F(pub f64);

impl Eq for F {}

impl PartialOrd for F {
    fn partial_cmp(&self, other: &F) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for F {
    fn cmp(&self, other: &F) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// A min-heap of event times: push instants, then discard everything at or
/// before "now" — the live count is what remains. The `pushes`/`pops`/
/// `peak` tallies are write-only profiler counters (DESIGN.md §14): the
/// recurrence never reads them, so instrumentation cannot perturb results.
///
/// [`live_after`](EventHeap::live_after) is only a valid waiting-count when
/// queried at non-decreasing `now` values (events dropped at one query can
/// never be live at a later one) — exactly the arrival-time discipline of
/// every engine here.
#[derive(Debug, Default)]
pub struct EventHeap {
    heap: BinaryHeap<Reverse<F>>,
    /// Write-only tally of pushes.
    pub pushes: u64,
    /// Write-only tally of pops (events retired by `live_after`).
    pub pops: u64,
    /// Write-only high-water mark of heap size.
    pub peak: u64,
}

impl EventHeap {
    /// Push an event time.
    pub fn push(&mut self, t: f64) {
        self.heap.push(Reverse(F(t)));
        self.pushes += 1;
        self.peak = self.peak.max(self.heap.len() as u64);
    }

    /// Drop every event at or before `now`, then return the live count.
    pub fn live_after(&mut self, now: f64) -> usize {
        while let Some(&Reverse(F(t))) = self.heap.peek() {
            if t <= now {
                self.heap.pop();
                self.pops += 1;
            } else {
                break;
            }
        }
        self.heap.len()
    }

    /// Live events currently in the heap.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether the heap holds no live events.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// Handle to one ring inside a [`RingArena`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RingId(usize);

#[derive(Debug)]
struct RingMeta {
    base: usize,
    cap: usize,
    head: usize,
    len: usize,
}

/// Arena of fixed-capacity circular f64 rings: one contiguous buffer backs
/// every departure ring of a run, so per-stage state allocation is a slice
/// extension, not a per-ring heap allocation. `peak` is the write-only
/// high-water mark of any ring's occupancy (the profiler's `ring_peak`).
#[derive(Debug, Default)]
pub struct RingArena {
    buf: Vec<f64>,
    rings: Vec<RingMeta>,
    peak: u64,
}

impl RingArena {
    pub fn new() -> RingArena {
        RingArena::default()
    }

    /// Allocate a ring holding at most `cap` values (`cap >= 1`).
    pub fn alloc(&mut self, cap: usize) -> RingId {
        assert!(cap >= 1, "ring capacity must be >= 1");
        let base = self.buf.len();
        self.buf.resize(base + cap, 0.0);
        self.rings.push(RingMeta { base, cap, head: 0, len: 0 });
        RingId(self.rings.len() - 1)
    }

    /// Newest value in the ring, if any.
    pub fn back(&self, id: RingId) -> Option<f64> {
        let r = &self.rings[id.0];
        if r.len == 0 {
            return None;
        }
        Some(self.buf[r.base + (r.head + r.len - 1) % r.cap])
    }

    /// Oldest value in the ring, if any.
    pub fn front(&self, id: RingId) -> Option<f64> {
        let r = &self.rings[id.0];
        if r.len == 0 {
            return None;
        }
        Some(self.buf[r.base + r.head])
    }

    /// Current occupancy.
    pub fn len(&self, id: RingId) -> usize {
        self.rings[id.0].len
    }

    /// Whether the ring holds no values.
    pub fn is_empty(&self, id: RingId) -> bool {
        self.rings[id.0].len == 0
    }

    /// Whether the ring is at capacity (the recurrence's "downstream buffer
    /// is full, blocking applies" test).
    pub fn is_full(&self, id: RingId) -> bool {
        let r = &self.rings[id.0];
        r.len == r.cap
    }

    /// Push `v` at the back, evicting the oldest value when full — the
    /// bounded window the recurrence needs (`dep[k-1]` at the back,
    /// `dep[k-cap]` at the front once full).
    pub fn push_bounded(&mut self, id: RingId, v: f64) {
        let r = &mut self.rings[id.0];
        if r.len == r.cap {
            self.buf[r.base + r.head] = v;
            r.head = (r.head + 1) % r.cap;
        } else {
            self.buf[r.base + (r.head + r.len) % r.cap] = v;
            r.len += 1;
            self.peak = self.peak.max(r.len as u64);
        }
    }

    /// High-water mark of any ring's occupancy (profiler's `ring_peak`).
    pub fn peak(&self) -> u64 {
        self.peak
    }
}

/// Write-only event-core tallies an engine run accumulates for
/// [`EngineProf`](crate::obs::EngineProf).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CoreCounters {
    pub heap_pushes: u64,
    pub heap_pops: u64,
    pub heap_peak: u64,
    pub ring_peak: u64,
}

/// Advance one item through the blocking tandem recurrence over
/// `stage_rings` (one ring per stage, capacity `queue_cap + 1`), with a
/// per-stage service-time source: `service(stage, start)` returns the
/// (possibly disturbed) service time for this item at this stage.
///
/// `a` is the item's availability at stage 0 — an arrival time for timed
/// sources, `0.0` for a saturated source (the `max` against the previous
/// departure then reproduces the saturated recurrence bit-for-bit, since
/// departure times are never negative).
///
/// `on_stage(stage, start, service, departure)` fires once per stage after
/// the ring update, in stage order — the hook engines use for span
/// recording, front-door bookkeeping and busy-time accounting. Returns the
/// item's final-stage departure time.
pub fn tandem_step_with(
    arena: &mut RingArena,
    stage_rings: &[RingId],
    a: f64,
    mut service: impl FnMut(usize, f64) -> f64,
    mut on_stage: impl FnMut(usize, f64, f64, f64),
) -> f64 {
    let p = stage_rings.len();
    debug_assert!(p >= 1);
    let mut prev_stage_dep = 0.0;
    for s in 0..p {
        let prev_same = arena.back(stage_rings[s]).unwrap_or(0.0);
        let arrive =
            if s == 0 { a.max(prev_same) } else { prev_stage_dep.max(prev_same) };
        // Blocking: stage s cannot release until the downstream buffer has
        // space, i.e. the item `queue_cap + 1` back has left stage s+1.
        let unblock = if s + 1 < p && arena.is_full(stage_rings[s + 1]) {
            arena.front(stage_rings[s + 1]).expect("full ring")
        } else {
            0.0
        };
        let start = arrive.max(unblock);
        let svc = service(s, start);
        prev_stage_dep = start + svc;
        arena.push_bounded(stage_rings[s], prev_stage_dep);
        on_stage(s, start, svc, prev_stage_dep);
    }
    prev_stage_dep
}

/// [`tandem_step_with`] for fixed per-stage service times.
pub fn tandem_step(
    arena: &mut RingArena,
    stage_rings: &[RingId],
    times: &[f64],
    a: f64,
    mut on_stage: impl FnMut(usize, f64, f64, f64),
) -> f64 {
    tandem_step_with(arena, stage_rings, a, |s, _| times[s], &mut on_stage)
}

/// Stationary-segment detection (DESIGN.md §15): once the per-stage
/// departure increments of a disturbance-free run repeat *bitwise* for a
/// full dependence window, the float recurrence has entered a periodic
/// orbit and remaining items can be advanced analytically.
pub mod stationary {
    /// Watches per-item departure vectors for bitwise-identical per-stage
    /// increments over `need` consecutive items. The dependence depth of
    /// the blocking recurrence is `queue_cap + 1` items (the downstream
    /// unblock term reaches that far back), so callers use
    /// `need = queue_cap + 2` to cover the whole window.
    #[derive(Debug)]
    pub struct PeriodDetector {
        prev: Vec<f64>,
        delta: Vec<f64>,
        streak: usize,
        need: usize,
        primed: bool,
    }

    impl PeriodDetector {
        pub fn new(stages: usize, need: usize) -> PeriodDetector {
            PeriodDetector {
                prev: vec![0.0; stages],
                delta: vec![0.0; stages],
                streak: 0,
                need: need.max(1),
                primed: false,
            }
        }

        /// Feed the departure vector of the item just stepped; returns
        /// true when the increments have been bitwise-stable for `need`
        /// consecutive items.
        pub fn observe(&mut self, deps: &[f64]) -> bool {
            debug_assert_eq!(deps.len(), self.prev.len());
            if !self.primed {
                self.prev.copy_from_slice(deps);
                self.primed = true;
                return false;
            }
            let mut same = true;
            for s in 0..deps.len() {
                let d = deps[s] - self.prev[s];
                if d.to_bits() != self.delta[s].to_bits() {
                    same = false;
                    self.delta[s] = d;
                }
            }
            self.prev.copy_from_slice(deps);
            self.streak = if same { self.streak + 1 } else { 1 };
            self.streak >= self.need
        }

        /// The common per-item increment, when every stage advances by the
        /// same (bitwise) delta — the steady-state cycle time. `None` when
        /// stages still drift relative to each other.
        pub fn uniform_delta(&self) -> Option<f64> {
            let first = self.delta.first()?;
            self.delta
                .iter()
                .all(|d| d.to_bits() == first.to_bits())
                .then_some(*first)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_heap_counts_live_events_like_a_linear_scan() {
        // The heap's live_after must equal the reference linear scan
        // `count(t > now)` for any non-decreasing query sequence.
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..50 {
            let n = 1 + rng.index(80);
            let mut times: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 10.0)).collect();
            let mut heap = EventHeap::default();
            let mut all: Vec<f64> = Vec::new();
            let mut now = 0.0;
            times.sort_by(f64::total_cmp);
            for t in times {
                now = now.max(t * 0.7); // non-decreasing query points
                for _ in 0..rng.index(3) {
                    let ev = now + rng.range_f64(0.0, 5.0);
                    heap.push(ev);
                    all.push(ev);
                }
                let reference = all.iter().filter(|&&e| e > now).count();
                assert_eq!(heap.live_after(now), reference);
            }
            assert_eq!(heap.pushes, all.len() as u64);
            assert!(heap.pops <= heap.pushes);
        }
    }

    #[test]
    fn ring_arena_is_a_bounded_fifo_window() {
        let mut arena = RingArena::new();
        let r = arena.alloc(3);
        assert!(arena.is_empty(r));
        assert_eq!(arena.back(r), None);
        for i in 1..=7 {
            arena.push_bounded(r, i as f64);
            assert_eq!(arena.back(r), Some(i as f64));
            assert_eq!(arena.len(r), i.min(3));
            // The front is always the oldest retained value.
            let expected_front = if i <= 3 { 1.0 } else { (i - 2) as f64 };
            assert_eq!(arena.front(r), Some(expected_front));
        }
        assert!(arena.is_full(r));
        assert_eq!(arena.peak(), 3);
        // A second ring shares the buffer but not the window.
        let r2 = arena.alloc(2);
        arena.push_bounded(r2, 42.0);
        assert_eq!(arena.front(r2), Some(42.0));
        assert_eq!(arena.front(r), Some(5.0));
    }

    #[test]
    fn tandem_step_matches_the_full_history_recurrence() {
        // Bit-identity against a direct transcription of the historical
        // full-history recurrence, over random tandem workloads.
        let mut rng = crate::util::rng::Rng::new(11);
        for _ in 0..40 {
            let p = 1 + rng.index(4);
            let times: Vec<f64> = (0..p).map(|_| rng.range_f64(0.001, 0.05)).collect();
            let cap = 1 + rng.index(3);
            let n = 5 + rng.index(60);
            let mut t = 0.0;
            let arrivals: Vec<f64> = (0..n)
                .map(|_| {
                    t += rng.range_f64(0.0, 0.03);
                    t
                })
                .collect();

            // Reference: full history.
            let mut dep = vec![Vec::<f64>::new(); p];
            let mut ref_finals = Vec::new();
            for (k, &a) in arrivals.iter().enumerate() {
                let mut prev_stage_dep = 0.0;
                for s in 0..p {
                    let prev = if k == 0 { 0.0 } else { dep[s][k - 1] };
                    let arrive =
                        if s == 0 { a.max(prev) } else { prev_stage_dep.max(prev) };
                    let unblock =
                        if s + 1 < p && k > cap { dep[s + 1][k - cap - 1] } else { 0.0 };
                    prev_stage_dep = arrive.max(unblock) + times[s];
                    dep[s].push(prev_stage_dep);
                }
                ref_finals.push(prev_stage_dep);
            }

            // Event core: bounded rings.
            let mut arena = RingArena::new();
            let rings: Vec<RingId> = (0..p).map(|_| arena.alloc(cap + 1)).collect();
            for (k, &a) in arrivals.iter().enumerate() {
                let got = tandem_step(&mut arena, &rings, &times, a, |_, _, _, _| {});
                assert_eq!(
                    got.to_bits(),
                    ref_finals[k].to_bits(),
                    "item {k} diverged: {got} vs {}",
                    ref_finals[k]
                );
            }
        }
    }

    #[test]
    fn period_detector_fires_on_dyadic_steady_state_only_after_the_window() {
        let mut d = stationary::PeriodDetector::new(2, 3);
        // Increments stabilize at (0.25, 0.25) from the second item on.
        let seq = [
            [0.5, 0.75],
            [0.75, 1.0],
            [1.0, 1.25],
            [1.25, 1.5],
            [1.5, 1.75],
        ];
        let fired: Vec<bool> = seq.iter().map(|v| d.observe(v)).collect();
        assert_eq!(fired, vec![false, false, false, true, true]);
        assert_eq!(d.uniform_delta(), Some(0.25));
    }

    #[test]
    fn period_detector_rejects_drifting_stages() {
        let mut d = stationary::PeriodDetector::new(2, 2);
        assert!(!d.observe(&[1.0, 2.0]));
        assert!(!d.observe(&[2.0, 3.5])); // deltas 1.0 / 1.5
        assert!(!d.observe(&[3.0, 5.0]));
        assert!(d.observe(&[4.0, 6.5]));
        assert_eq!(d.uniform_delta(), None, "stages advance by different deltas");
    }
}
