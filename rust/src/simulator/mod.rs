//! big.LITTLE hardware substrate (DESIGN.md §1): calibrated analytical GEMM
//! cost model, cluster/CCI platform description, power model, and a
//! discrete-event pipeline simulator. This module plays the role of the
//! paper's HiKey 970 board — `perfmodel` (the paper's predictor) is fit
//! against "measurements" taken from here.

pub mod arrivals;
pub mod engine;
pub mod gemm;
pub mod pipeline_sim;
pub mod platform;
pub mod power;

pub use arrivals::{
    poisson_arrivals, simulate_open_loop, uniform_arrivals, ArrivalSpec, OpenLoopReport,
};
pub use gemm::{
    layer_time, layer_time_1core, layer_time_hmp, layer_time_hmp_ratio, layers_time,
    mean_layer_time, network_time, network_time_hmp, throughput,
};
pub use pipeline_sim::{
    simulate, simulate_replicated, simulate_stationary, steady_state_throughput, FleetSimReport,
    SimReport,
};
pub use platform::{ClusterSpec, CoreType, Platform};
pub use power::{ClusterActivity, PowerModel};
