//! Discrete-event simulation of a layer-level pipeline over an image stream.
//!
//! Stages have deterministic service times (from `simulator::gemm`); images
//! flow through bounded inter-stage buffers. Steady-state throughput must
//! converge to `1 / max_i T_{L_i}^{P_i}` (paper Eq. 12); the simulator also
//! reports fill/drain transients, per-stage utilization and per-image
//! latency, which the closed form does not give.
//!
//! The recurrence runs on the shared event core
//! ([`crate::simulator::engine`], DESIGN.md §15): bounded departure rings
//! replace the historical full per-item history, so recurrence state is
//! O(stages · queue_cap) regardless of stream length, and scripted
//! disturbances are resolved through precomputed per-stage
//! `FactorTimeline`s (a monotone cursor instead of an O(events) product
//! per item per stage). The historical engine is retained as
//! `simulate_disturbed_reference` — the oracle the differential suite
//! holds this engine bit-identical against.
//!
//! [`simulate_stationary`] adds the opt-in closed-form fast path: step
//! exactly until the departure increments repeat bitwise over a full
//! dependence window, then advance the remaining items analytically from
//! the tandem recurrence's steady-state cycle time.
//!
//! [`simulate_replicated`] extends the same model to a *fleet* of
//! replicated pipelines behind a shared least-outstanding-work dispatcher,
//! mirroring [`crate::coordinator::run_fleet`] so that design-time
//! predictions and wall-clock fleet runs stay comparable.
//!
//! The *disturbance layer* ([`ThrottleEvent`], [`simulate_disturbed`],
//! [`simulate_replicated_disturbed`]) injects scripted service-time shifts
//! — e.g. a thermal throttle scaling one cluster's stages by 2× at time `t`
//! — so the online-adaptation control loop ([`crate::adapt`]) is testable
//! deterministically in the DES before it ever touches wall-clock threads.
//!
//! The *recorded* variants ([`simulate_recorded`],
//! [`simulate_replicated_recorded`], [`simulate_disturbed_recorded`])
//! additionally emit per-item spans — admit, per-stage service, depart,
//! stamped with simulation time — into an [`crate::obs::Recorder`]. The
//! recurrence never reads recorder state back, so a disabled recorder is
//! bit-identical to the plain variants and same-seed traced runs produce
//! byte-identical span streams (DESIGN.md §13).

use crate::obs::Recorder;
use crate::simulator::engine::{stationary, tandem_step, tandem_step_with, RingArena, RingId};

/// Result of simulating a stream through a pipeline.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Total wall-clock time to process all images (s).
    pub makespan: f64,
    /// Average throughput over the whole run (imgs/s) including transients.
    pub throughput: f64,
    /// Steady-state throughput (imgs/s): inverse of the bottleneck stage.
    pub steady_state_throughput: f64,
    /// Index of the bottleneck stage.
    pub bottleneck: usize,
    /// Per-stage busy fraction.
    pub utilization: Vec<f64>,
    /// Per-image end-to-end latency (s).
    pub latencies: Vec<f64>,
}

/// Simulate `images` items through stages with deterministic per-item
/// service times `stage_times` and inter-stage buffer capacity `queue_cap`
/// (>= 1). Uses the exact recurrence for tandem queues with finite buffers
/// and blocking-after-service:
///
///   d[i][s] = max(d[i][s-1], d[i-1][s], d[i-cap-1][s+1]) + T_s
///
/// where `d[i][s]` is the departure time of item `i` from stage `s`.
pub fn simulate(stage_times: &[f64], images: usize, queue_cap: usize) -> SimReport {
    // The undisturbed run is exactly the disturbed recurrence with no
    // events active (the empty factor product is 1.0 and `t * 1.0 == t`
    // bitwise), so one implementation serves both.
    simulate_disturbed(stage_times, images, queue_cap, &[], 0.0, 0, |_, _| {})
}

/// Closed-form steady-state throughput (paper Eq. 12).
pub fn steady_state_throughput(stage_times: &[f64]) -> f64 {
    1.0 / stage_times.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// A scripted service-time disturbance: from simulation time `at` onward,
/// the service times of the stages in `scope` are multiplied by `factor`.
/// Events compose multiplicatively (two active 2× events make 4×); a
/// `factor < 1.0` models a throttle being lifted or a frequency boost.
#[derive(Debug, Clone, PartialEq)]
pub struct ThrottleEvent {
    /// Absolute simulation time (s) at which the factor takes effect. An
    /// item's service time is scaled iff the item *starts* the stage at or
    /// after `at` (service is not preempted mid-item, matching how DVFS
    /// transitions land between kernel invocations on the board).
    pub at: f64,
    /// Multiplier applied to affected service times from `at` onward.
    pub factor: f64,
    /// Affected `(replica, stage)` pairs; an empty scope means every stage
    /// of every replica (a machine-wide disturbance).
    pub scope: Vec<(usize, usize)>,
}

impl ThrottleEvent {
    fn applies(&self, replica: usize, stage: usize) -> bool {
        self.scope.is_empty() || self.scope.contains(&(replica, stage))
    }
}

/// Combined multiplier over `events` active at absolute time `t` for stage
/// `stage` of replica `replica`.
fn disturbance_factor(events: &[ThrottleEvent], replica: usize, stage: usize, t: f64) -> f64 {
    events
        .iter()
        .filter(|e| e.at <= t && e.applies(replica, stage))
        .map(|e| e.factor)
        .product()
}

/// One stage's disturbance factor as a step function of time, precomputed
/// from the event script (DESIGN.md §15): at each distinct activation
/// threshold the full slice-order product of the then-active events, so a
/// lookup is a cursor advance instead of an O(events) scan — and, because
/// the product at each threshold is recomputed over the events slice in
/// its original order, bit-identical to [`disturbance_factor`].
///
/// Queries must come at non-decreasing times; per-stage start times are
/// non-decreasing in item index (an item's start is at least its
/// predecessor's departure from the same stage), so the recurrence
/// satisfies this by construction.
struct FactorTimeline {
    /// Distinct activation times, ascending. Events with a NaN `at` never
    /// activate under `at <= t` and are dropped at build time.
    thresholds: Vec<f64>,
    /// `products[j]`: slice-order factor product of events with
    /// `at <= thresholds[j]`.
    products: Vec<f64>,
    /// Cursor: thresholds `< idx` have activated.
    idx: usize,
}

impl FactorTimeline {
    fn new(events: &[ThrottleEvent], replica: usize, stage: usize) -> FactorTimeline {
        let mut thresholds: Vec<f64> = events
            .iter()
            .filter(|e| e.applies(replica, stage) && !e.at.is_nan())
            .map(|e| e.at)
            .collect();
        thresholds.sort_by(f64::total_cmp);
        thresholds.dedup_by(|a, b| a == b);
        let products = thresholds
            .iter()
            .map(|&at| {
                events
                    .iter()
                    .filter(|e| e.at <= at && e.applies(replica, stage))
                    .map(|e| e.factor)
                    .product()
            })
            .collect();
        FactorTimeline { thresholds, products, idx: 0 }
    }

    /// Factor active at time `t` (`t` non-decreasing across calls).
    fn factor_at(&mut self, t: f64) -> f64 {
        while self.idx < self.thresholds.len() && self.thresholds[self.idx] <= t {
            self.idx += 1;
        }
        if self.idx == 0 {
            1.0
        } else {
            self.products[self.idx - 1]
        }
    }
}

/// [`simulate`] with scripted disturbances: the pipeline starts at absolute
/// simulation time `t0` (events carry absolute times, so chunked callers
/// can resume mid-script) and item service times are scaled by the events
/// active when the item starts its stage. `replica` selects which scope
/// entries apply (0 for a standalone pipeline). `on_service(stage,
/// service_s)` is called once per item per stage with the *disturbed*
/// service time — the DES analogue of
/// [`crate::coordinator::StageObserver`], feeding adaptation telemetry.
///
/// With no events this reproduces [`simulate`] exactly. `bottleneck` and
/// `steady_state_throughput` in the report are computed from the *base*
/// times (the design-time belief); `utilization` reflects actual disturbed
/// busy time.
pub fn simulate_disturbed(
    stage_times: &[f64],
    images: usize,
    queue_cap: usize,
    events: &[ThrottleEvent],
    t0: f64,
    replica: usize,
    on_service: impl FnMut(usize, f64),
) -> SimReport {
    simulate_disturbed_recorded(
        stage_times,
        images,
        queue_cap,
        events,
        t0,
        replica,
        &Recorder::off(),
        0,
        None,
        on_service,
    )
}

/// [`simulate`] with span recording: admit/stage/depart spans for every
/// item land in `rec` under `group`, stamped with simulation time.
pub fn simulate_recorded(
    stage_times: &[f64],
    images: usize,
    queue_cap: usize,
    rec: &Recorder,
    group: u32,
) -> SimReport {
    simulate_disturbed_recorded(
        stage_times,
        images,
        queue_cap,
        &[],
        0.0,
        0,
        rec,
        group,
        None,
        |_, _| {},
    )
}

/// [`simulate_disturbed`] with span recording (the recurrence both
/// variants share). `ids` maps the local item index to a trace item id —
/// fleet dispatch passes global arrival indices so cross-replica traces
/// stay disjoint; `None` uses the local index. The recorder is write-only
/// for the recurrence: with `Recorder::off()` this is exactly
/// [`simulate_disturbed`].
///
/// Runs on the event core's bounded rings: a saturated source is the
/// timed recurrence at availability `0.0` (departures are never negative,
/// so the `max` is the identity on the previous departure), and per-stage
/// `FactorTimeline` cursors resolve disturbances — float-for-float the
/// recurrence of `simulate_disturbed_reference`.
#[allow(clippy::too_many_arguments)]
pub fn simulate_disturbed_recorded(
    stage_times: &[f64],
    images: usize,
    queue_cap: usize,
    events: &[ThrottleEvent],
    t0: f64,
    replica: usize,
    rec: &Recorder,
    group: u32,
    ids: Option<&[u64]>,
    mut on_service: impl FnMut(usize, f64),
) -> SimReport {
    assert!(!stage_times.is_empty());
    assert!(queue_cap >= 1);
    assert!(images >= 1);
    let p = stage_times.len();

    let mut arena = RingArena::new();
    let rings: Vec<RingId> = (0..p).map(|_| arena.alloc(queue_cap + 1)).collect();
    let mut timelines: Vec<FactorTimeline> =
        (0..p).map(|s| FactorTimeline::new(events, replica, s)).collect();
    let mut busy = vec![0.0f64; p];
    // Final-stage departures are kept per item: the latency vector and the
    // post-run admit/depart span emission (in the reference's order) need
    // them. Everything else is O(stages · queue_cap) ring state.
    let mut final_deps = Vec::with_capacity(images);
    let mut latencies = Vec::with_capacity(images);
    // Stage-0 departure/service of the previous item (latency entry point).
    let mut prev_dep0 = 0.0f64;
    let mut prev_svc0 = 0.0f64;
    for i in 0..images {
        let mut dep0 = 0.0f64;
        let mut svc0 = 0.0f64;
        let out = tandem_step_with(
            &mut arena,
            &rings,
            0.0,
            |s, start| stage_times[s] * timelines[s].factor_at(t0 + start),
            |s, start, service, dep| {
                if s == 0 {
                    svc0 = service;
                    dep0 = dep;
                }
                busy[s] += service;
                on_service(s, service);
                if rec.enabled() {
                    let id = ids.map_or(i as u64, |m| m[i]);
                    rec.stage(group, id, replica as u32, s as u32, t0 + start, t0 + dep);
                }
            },
        );
        // Entry into the pipe: when the previous item started stage 0 (its
        // departure minus its service), clamped to the stream start.
        let enter = if i == 0 { 0.0 } else { prev_dep0 - prev_svc0 };
        latencies.push(out - enter.max(0.0));
        final_deps.push(out);
        prev_dep0 = dep0;
        prev_svc0 = svc0;
    }

    let makespan = final_deps[images - 1];
    if rec.enabled() {
        for i in 0..images {
            let id = ids.map_or(i as u64, |m| m[i]);
            let out = final_deps[i];
            rec.admit(group, id, t0 + out - latencies[i]);
            rec.depart(group, id, replica as u32, t0 + out);
        }
        rec.observe_hist("latency", &crate::obs::LogHist::of(&latencies));
    }
    let utilization: Vec<f64> = busy.iter().map(|b| b / makespan).collect();
    let (bottleneck, bt) = stage_times
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, t)| (i, *t))
        .unwrap();

    SimReport {
        makespan,
        throughput: images as f64 / makespan,
        steady_state_throughput: 1.0 / bt,
        bottleneck,
        utilization,
        latencies,
    }
}

/// The historical full-history recurrence, retained verbatim as the
/// differential oracle for the event core (DESIGN.md §15): O(images)
/// state per stage, O(events) disturbance scan per (item, stage), but the
/// exact float-operation order [`simulate_disturbed_recorded`] must
/// reproduce bit-for-bit. Not for production use.
#[doc(hidden)]
#[allow(clippy::too_many_arguments)]
pub fn simulate_disturbed_reference(
    stage_times: &[f64],
    images: usize,
    queue_cap: usize,
    events: &[ThrottleEvent],
    t0: f64,
    replica: usize,
    rec: &Recorder,
    group: u32,
    ids: Option<&[u64]>,
    mut on_service: impl FnMut(usize, f64),
) -> SimReport {
    assert!(!stage_times.is_empty());
    assert!(queue_cap >= 1);
    assert!(images >= 1);
    let p = stage_times.len();

    // dep[s] holds departure times per stage; full history kept.
    let mut dep = vec![vec![0.0f64; images]; p];
    let mut svc0 = vec![0.0f64; images];
    let mut busy = vec![0.0f64; p];
    for i in 0..images {
        for s in 0..p {
            let arrive = if s == 0 {
                // Saturated source: image available immediately.
                if i == 0 { 0.0 } else { dep[0][i - 1] }
            } else {
                let upstream = dep[s - 1][i];
                let prev_here = if i == 0 { 0.0 } else { dep[s][i - 1] };
                upstream.max(prev_here)
            };
            // Blocking: stage s cannot release item i until the downstream
            // buffer has space, i.e. item (i - queue_cap - 1) has left s+1.
            let unblock = if s + 1 < p && i > queue_cap {
                dep[s + 1][i - queue_cap - 1]
            } else {
                0.0
            };
            let start = arrive.max(unblock);
            let service =
                stage_times[s] * disturbance_factor(events, replica, s, t0 + start);
            if s == 0 {
                svc0[i] = service;
            }
            busy[s] += service;
            on_service(s, service);
            dep[s][i] = start + service;
            if rec.enabled() {
                let id = ids.map_or(i as u64, |m| m[i]);
                rec.stage(group, id, replica as u32, s as u32, t0 + start, t0 + dep[s][i]);
            }
        }
    }

    let makespan = dep[p - 1][images - 1];
    let latencies: Vec<f64> = (0..images)
        .map(|i| {
            let enter = if i == 0 { 0.0 } else { dep[0][i - 1] - svc0[i - 1] };
            dep[p - 1][i] - enter.max(0.0)
        })
        .collect();
    if rec.enabled() {
        for i in 0..images {
            let id = ids.map_or(i as u64, |m| m[i]);
            let out = dep[p - 1][i];
            rec.admit(group, id, t0 + out - latencies[i]);
            rec.depart(group, id, replica as u32, t0 + out);
        }
        rec.observe_hist("latency", &crate::obs::LogHist::of(&latencies));
    }
    let utilization: Vec<f64> = busy.iter().map(|b| b / makespan).collect();
    let (bottleneck, bt) = stage_times
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, t)| (i, *t))
        .unwrap();

    SimReport {
        makespan,
        throughput: images as f64 / makespan,
        steady_state_throughput: 1.0 / bt,
        bottleneck,
        utilization,
        latencies,
    }
}

/// The closed-form stationary fast path (DESIGN.md §15): step the exact
/// recurrence only until the per-stage departure increments repeat
/// *bitwise* for a full dependence window (`queue_cap + 2` consecutive
/// items) with one common increment Δ — the steady-state cycle time — then
/// advance the remaining items analytically: final departures grow by Δ
/// per item, every remaining latency equals the current steady-state
/// latency, and busy time accrues one service per stage per item.
///
/// Returns the report plus `Some(items_stepped)` when the analytic path
/// engaged (`None` means the run never stabilized and was stepped
/// exactly — the always-correct fallback). Disturbance-free runs only;
/// with stage times exactly representable as small dyadic multiples the
/// result is bit-identical to [`simulate`] (a property test pins this),
/// otherwise it agrees to float-rounding accuracy (≲1e-9 relative) since
/// float addition is not exactly translation-invariant across binades.
/// The default engines therefore never call this: it is an opt-in
/// accelerator for long stationary sweeps.
pub fn simulate_stationary(
    stage_times: &[f64],
    images: usize,
    queue_cap: usize,
) -> (SimReport, Option<usize>) {
    assert!(!stage_times.is_empty());
    assert!(queue_cap >= 1);
    assert!(images >= 1);
    let p = stage_times.len();
    let mut arena = RingArena::new();
    let rings: Vec<RingId> = (0..p).map(|_| arena.alloc(queue_cap + 1)).collect();
    let mut detector = stationary::PeriodDetector::new(p, queue_cap + 2);
    let mut busy = vec![0.0f64; p];
    let mut latencies = Vec::with_capacity(images);
    let mut deps_now = vec![0.0f64; p];
    let mut prev_dep0 = 0.0f64;
    let mut makespan = 0.0f64;
    let mut engaged = None;
    let mut i = 0usize;
    while i < images {
        let out = tandem_step(&mut arena, &rings, stage_times, 0.0, |s, _start, svc, dep| {
            deps_now[s] = dep;
            busy[s] += svc;
        });
        let enter = if i == 0 { 0.0 } else { prev_dep0 - stage_times[0] };
        latencies.push(out - enter.max(0.0));
        prev_dep0 = deps_now[0];
        makespan = out;
        i += 1;
        if i < images && detector.observe(&deps_now) {
            if let Some(delta) = detector.uniform_delta() {
                if delta.is_finite() && delta > 0.0 {
                    // Stationary segment: close the remaining stream in
                    // O(1). Item i..images-1 departures are out + k·Δ.
                    let remaining = (images - i) as f64;
                    makespan = out + remaining * delta;
                    let lat = (out + delta) - (deps_now[0] - stage_times[0]).max(0.0);
                    latencies.resize(images, lat);
                    for (s, b) in busy.iter_mut().enumerate() {
                        *b += remaining * stage_times[s];
                    }
                    engaged = Some(i);
                    break;
                }
            }
        }
    }
    let utilization: Vec<f64> = busy.iter().map(|b| b / makespan).collect();
    let (bottleneck, bt) = stage_times
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, t)| (i, *t))
        .unwrap();
    (
        SimReport {
            makespan,
            throughput: images as f64 / makespan,
            steady_state_throughput: 1.0 / bt,
            bottleneck,
            utilization,
            latencies,
        },
        engaged,
    )
}

/// Result of simulating a stream through a *replicated* fleet of pipelines
/// (the DES twin of [`crate::coordinator::run_fleet`]).
#[derive(Debug, Clone)]
pub struct FleetSimReport {
    /// Wall-clock time until the slowest replica drains (s).
    pub makespan: f64,
    /// Aggregate average throughput over the whole run (imgs/s).
    pub throughput: f64,
    /// Sum of per-replica Eq. 12 steady-state rates (imgs/s).
    pub steady_state_throughput: f64,
    /// Images routed to each replica by least-outstanding-work dispatch.
    pub dispatched: Vec<usize>,
    /// Per-replica simulation reports (a zeroed report for replicas that
    /// received no images).
    pub per_replica: Vec<SimReport>,
}

impl FleetSimReport {
    /// Per-image latencies merged across replicas (replica order, stream
    /// order within a replica) — what the unified
    /// [`crate::api::ServeReport`] computes its percentiles from.
    pub fn merged_latencies(&self) -> Vec<f64> {
        let mut out = Vec::new();
        for r in &self.per_replica {
            out.extend_from_slice(&r.latencies);
        }
        out
    }

    /// Per-replica bottleneck utilization: each replica's busiest stage's
    /// busy fraction over its own makespan.
    pub fn replica_utilization(&self) -> Vec<f64> {
        self.per_replica
            .iter()
            .map(|r| r.utilization.iter().copied().fold(0.0, f64::max))
            .collect()
    }
}

fn idle_sim_report(stage_times: &[f64]) -> SimReport {
    let (bottleneck, _) = stage_times
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .expect("nonempty stage times");
    SimReport {
        makespan: 0.0,
        throughput: 0.0,
        steady_state_throughput: steady_state_throughput(stage_times),
        bottleneck,
        utilization: vec![0.0; stage_times.len()],
        latencies: Vec::new(),
    }
}

/// Simulate `images` items through a fleet of replicated pipelines with a
/// saturated shared source and least-outstanding-work dispatch — the DES
/// analogue of [`crate::coordinator::run_fleet`], so predicted and
/// wall-clock fleet numbers stay comparable.
///
/// `replica_stage_times[r]` gives replica `r`'s deterministic per-stage
/// service times. Dispatch assigns each image to the replica whose
/// outstanding work plus marginal cycle time is smallest (cycle time = the
/// replica's bottleneck stage time), which converges to rate-proportional
/// routing; each replica's stream is then simulated exactly with
/// [`simulate`]. The fleet's makespan is the slowest replica's makespan
/// (replicas run concurrently), and for long streams the aggregate
/// throughput approaches `steady_state_throughput` — the sum of replica
/// rates.
pub fn simulate_replicated(
    replica_stage_times: &[Vec<f64>],
    images: usize,
    queue_cap: usize,
) -> FleetSimReport {
    // As with `simulate`, the undisturbed fleet is the disturbed one with
    // no events active — one dispatch + recurrence implementation.
    simulate_replicated_disturbed(replica_stage_times, images, queue_cap, &[], 0.0, |_, _, _| {})
}

/// [`simulate_replicated`] with scripted disturbances — the DES testbed of
/// the online-adaptation loop ([`crate::adapt::simulate_adaptive`]).
///
/// Dispatch uses the *base* cycle times (the dispatcher has no oracle view
/// of future throttles, matching the wall-clock fleet's
/// least-outstanding-work policy); each replica's stream is then simulated
/// with [`simulate_disturbed`] starting at absolute time `t0`.
/// `on_service(replica, stage, service_s)` is called once per item per
/// stage with the disturbed service time. With no events this reproduces
/// [`simulate_replicated`] exactly.
pub fn simulate_replicated_disturbed(
    replica_stage_times: &[Vec<f64>],
    images: usize,
    queue_cap: usize,
    events: &[ThrottleEvent],
    t0: f64,
    on_service: impl FnMut(usize, usize, f64),
) -> FleetSimReport {
    simulate_replicated_recorded(
        replica_stage_times,
        images,
        queue_cap,
        events,
        t0,
        &Recorder::off(),
        0,
        0,
        on_service,
    )
}

/// [`simulate_replicated_disturbed`] with span recording: each item's
/// trace id is its global dispatch index offset by `id_base` (chunked
/// adaptive runs pass the number of images already served, so ids stay
/// unique across chunks), and every item's admit/stage/depart chain lands
/// in `rec` under `group`.
#[allow(clippy::too_many_arguments)]
pub fn simulate_replicated_recorded(
    replica_stage_times: &[Vec<f64>],
    images: usize,
    queue_cap: usize,
    events: &[ThrottleEvent],
    t0: f64,
    rec: &Recorder,
    group: u32,
    id_base: u64,
    mut on_service: impl FnMut(usize, usize, f64),
) -> FleetSimReport {
    assert!(!replica_stage_times.is_empty());
    assert!(images >= 1);
    let mut prof = crate::obs::EngineProf::start("pipeline", rec);
    let r = replica_stage_times.len();
    let cycles: Vec<f64> = replica_stage_times
        .iter()
        .map(|t| t.iter().copied().fold(f64::NEG_INFINITY, f64::max))
        .collect();
    assert!(cycles.iter().all(|c| c.is_finite() && *c > 0.0));

    let mut work = vec![0.0f64; r];
    let mut dispatched = vec![0usize; r];
    let mut ids: Vec<Vec<u64>> = vec![Vec::new(); r];
    for g in 0..images {
        let pick = (0..r)
            .min_by(|&a, &b| (work[a] + cycles[a]).total_cmp(&(work[b] + cycles[b])))
            .expect("nonempty fleet");
        work[pick] += cycles[pick];
        dispatched[pick] += 1;
        ids[pick].push(id_base + g as u64);
    }

    let per_replica: Vec<SimReport> = replica_stage_times
        .iter()
        .zip(&dispatched)
        .enumerate()
        .map(|(i, (times, &n))| {
            if n == 0 {
                idle_sim_report(times)
            } else {
                simulate_disturbed_recorded(
                    times,
                    n,
                    queue_cap,
                    events,
                    t0,
                    i,
                    rec,
                    group,
                    Some(&ids[i]),
                    |s, dt| on_service(i, s, dt),
                )
            }
        })
        .collect();

    // Engine profile (DESIGN.md §14): the recurrence twin processes one
    // event per (item, stage) over bounded rings and keeps no event heap —
    // an honest zero for the heap counters.
    if prof.active() {
        prof.events = replica_stage_times
            .iter()
            .zip(&dispatched)
            .map(|(times, &n)| n as u64 * times.len() as u64)
            .sum();
        prof.flush(rec);
    }

    let makespan = per_replica.iter().map(|s| s.makespan).fold(0.0, f64::max);
    FleetSimReport {
        makespan,
        throughput: images as f64 / makespan,
        steady_state_throughput: cycles.iter().map(|c| 1.0 / c).sum(),
        dispatched,
        per_replica,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn single_stage_is_serial() {
        let r = simulate(&[0.1], 50, 1);
        assert!((r.makespan - 5.0).abs() < 1e-9);
        assert!((r.throughput - 10.0).abs() < 1e-6);
        assert_eq!(r.bottleneck, 0);
    }

    #[test]
    fn converges_to_eq12() {
        let times = [0.03, 0.05, 0.02];
        let r = simulate(&times, 2000, 4);
        let ss = steady_state_throughput(&times);
        assert!((r.throughput - ss).abs() / ss < 0.01, "tp={} ss={ss}", r.throughput);
        assert_eq!(r.bottleneck, 1);
    }

    #[test]
    fn bottleneck_utilization_is_highest() {
        let times = [0.03, 0.05, 0.02];
        let r = simulate(&times, 500, 2);
        assert!(r.utilization[1] > r.utilization[0]);
        assert!(r.utilization[1] > r.utilization[2]);
        assert!(r.utilization[1] <= 1.0 + 1e-9);
    }

    #[test]
    fn pipeline_beats_serial_execution() {
        // Total serial time per image = 0.1; balanced 2-stage pipeline
        // should approach 2x the serial throughput.
        let serial = simulate(&[0.1], 400, 1).throughput;
        let piped = simulate(&[0.05, 0.05], 400, 1).throughput;
        assert!(piped > serial * 1.8, "piped={piped} serial={serial}");
    }

    #[test]
    fn tiny_buffer_still_correct() {
        // With cap=1 the recurrence must still respect Eq. 12 up to
        // blocking stalls; for a dominant bottleneck blocking changes
        // nothing in steady state.
        let times = [0.01, 0.08, 0.01];
        let r = simulate(&times, 1000, 1);
        assert!((r.throughput - 12.5).abs() < 0.2, "tp={}", r.throughput);
    }

    #[test]
    fn latencies_nondecreasing_sane() {
        let r = simulate(&[0.02, 0.04], 100, 2);
        // Every latency at least the sum of service times.
        for l in &r.latencies {
            assert!(*l >= 0.06 - 1e-12);
        }
    }

    #[test]
    fn property_throughput_bounded_by_eq12() {
        check(200, |rng| {
            let p = 1 + rng.index(5);
            let times: Vec<f64> = (0..p).map(|_| rng.range_f64(0.001, 0.1)).collect();
            let images = 10 + rng.index(300);
            let cap = 1 + rng.index(4);
            let r = simulate(&times, images, cap);
            let ss = steady_state_throughput(&times);
            crate::prop_assert!(
                r.throughput <= ss * (1.0 + 1e-9),
                "throughput {} exceeds steady-state bound {}",
                r.throughput,
                ss
            );
            let serial: f64 = times.iter().sum();
            crate::prop_assert!(
                r.throughput * serial <= p as f64 + 1e-9,
                "speedup over serial exceeds stage count"
            );
            Ok(())
        });
    }

    #[test]
    fn property_more_images_approach_steady_state() {
        check(50, |rng| {
            let times: Vec<f64> = (0..3).map(|_| rng.range_f64(0.01, 0.05)).collect();
            let small = simulate(&times, 20, 2).throughput;
            let large = simulate(&times, 2000, 2).throughput;
            let ss = steady_state_throughput(&times);
            crate::prop_assert!(
                (large - ss).abs() <= (small - ss).abs() + 1e-9,
                "longer run should be closer to steady state"
            );
            Ok(())
        });
    }

    /// The event-core contract (DESIGN.md §15): the ring engine is
    /// bit-identical to the retained full-history reference — makespan,
    /// every latency, every utilization — including under scripted
    /// throttles (scoped and machine-wide) and nonzero `t0`.
    #[test]
    fn property_ring_engine_is_bit_identical_to_reference() {
        check(60, |rng| {
            let p = 1 + rng.index(5);
            let times: Vec<f64> = (0..p).map(|_| rng.range_f64(0.001, 0.1)).collect();
            let images = 10 + rng.index(300);
            let cap = 1 + rng.index(4);
            let n_events = rng.index(4);
            let horizon = times.iter().sum::<f64>() * images as f64;
            let events: Vec<ThrottleEvent> = (0..n_events)
                .map(|_| ThrottleEvent {
                    at: rng.range_f64(0.0, horizon.max(0.01)),
                    factor: rng.range_f64(0.5, 3.0),
                    scope: if rng.index(2) == 0 {
                        Vec::new()
                    } else {
                        vec![(0, rng.index(p))]
                    },
                })
                .collect();
            let t0 = if rng.index(2) == 0 { 0.0 } else { rng.range_f64(0.0, 5.0) };
            let fast =
                simulate_disturbed(&times, images, cap, &events, t0, 0, |_, _| {});
            let slow = simulate_disturbed_reference(
                &times,
                images,
                cap,
                &events,
                t0,
                0,
                &Recorder::off(),
                0,
                None,
                |_, _| {},
            );
            crate::prop_assert!(
                fast.makespan.to_bits() == slow.makespan.to_bits(),
                "makespan diverged: {} vs {}",
                fast.makespan,
                slow.makespan
            );
            for (i, (f, s)) in fast.latencies.iter().zip(&slow.latencies).enumerate() {
                crate::prop_assert!(
                    f.to_bits() == s.to_bits(),
                    "latency {i} diverged: {f} vs {s}"
                );
            }
            for (f, s) in fast.utilization.iter().zip(&slow.utilization) {
                crate::prop_assert!(
                    f.to_bits() == s.to_bits(),
                    "utilization diverged: {f} vs {s}"
                );
            }
            Ok(())
        });
    }

    /// Stationary fast path, exact domain: with stage times that are small
    /// dyadic multiples every float op is exact, so the analytic
    /// continuation must equal exact stepping bit-for-bit — and it must
    /// actually engage.
    #[test]
    fn stationary_path_is_bitwise_exact_on_dyadic_times() {
        check(40, |rng| {
            let p = 1 + rng.index(4);
            // Dyadic stage times: k·2⁻⁷ for small integer k.
            let times: Vec<f64> =
                (0..p).map(|_| (1 + rng.index(16)) as f64 * 0.0078125).collect();
            let images = 200 + rng.index(400);
            let cap = 1 + rng.index(3);
            let exact = simulate(&times, images, cap);
            let (fast, engaged) = simulate_stationary(&times, images, cap);
            crate::prop_assert!(
                engaged.is_some(),
                "stationary path must engage on constant times"
            );
            crate::prop_assert!(
                fast.makespan.to_bits() == exact.makespan.to_bits(),
                "makespan diverged: {} vs {}",
                fast.makespan,
                exact.makespan
            );
            crate::prop_assert!(fast.latencies.len() == exact.latencies.len());
            for (i, (f, e)) in fast.latencies.iter().zip(&exact.latencies).enumerate() {
                crate::prop_assert!(
                    f.to_bits() == e.to_bits(),
                    "latency {i} diverged: {f} vs {e}"
                );
            }
            for (f, e) in fast.utilization.iter().zip(&exact.utilization) {
                crate::prop_assert!(
                    f.to_bits() == e.to_bits(),
                    "utilization diverged: {f} vs {e}"
                );
            }
            Ok(())
        });
    }

    /// Stationary fast path, general domain: arbitrary stage times agree
    /// with exact stepping to float-rounding accuracy, and the fast path
    /// steps only a prefix.
    #[test]
    fn stationary_path_matches_stepping_on_general_times() {
        check(40, |rng| {
            let p = 1 + rng.index(4);
            let times: Vec<f64> = (0..p).map(|_| rng.range_f64(0.001, 0.1)).collect();
            let images = 500 + rng.index(500);
            let cap = 1 + rng.index(3);
            let exact = simulate(&times, images, cap);
            let (fast, engaged) = simulate_stationary(&times, images, cap);
            if let Some(stepped) = engaged {
                crate::prop_assert!(
                    stepped < images,
                    "engaging must save work ({stepped}/{images})"
                );
            }
            let rel = (fast.makespan - exact.makespan).abs() / exact.makespan;
            crate::prop_assert!(
                rel < 1e-9,
                "makespan off by {rel:e}: {} vs {}",
                fast.makespan,
                exact.makespan
            );
            for (f, e) in fast.latencies.iter().zip(&exact.latencies) {
                crate::prop_assert!(
                    (f - e).abs() <= 1e-9 * e.max(1.0),
                    "latency diverged: {f} vs {e}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn replicated_single_replica_matches_simulate() {
        let times = vec![0.03, 0.05, 0.02];
        let fleet = simulate_replicated(&[times.clone()], 500, 2);
        let solo = simulate(&times, 500, 2);
        assert_eq!(fleet.dispatched, vec![500]);
        assert!((fleet.makespan - solo.makespan).abs() < 1e-12);
        assert!((fleet.throughput - solo.throughput).abs() < 1e-9);
    }

    #[test]
    fn merged_latencies_cover_every_dispatched_image() {
        let fleet = simulate_replicated(&[vec![0.01, 0.02], vec![0.03]], 200, 2);
        let merged = fleet.merged_latencies();
        assert_eq!(merged.len(), 200);
        assert!(merged.iter().all(|l| *l > 0.0));
        let util = fleet.replica_utilization();
        assert_eq!(util.len(), 2);
        assert!(util.iter().all(|u| *u > 0.0 && *u <= 1.0 + 1e-9));
    }

    #[test]
    fn replicated_dispatch_is_rate_proportional() {
        // Replica 0 is 3x faster: it should receive ~3x the images.
        let fleet =
            simulate_replicated(&[vec![0.01], vec![0.03]], 400, 2);
        let share = fleet.dispatched[0] as f64 / fleet.dispatched[1] as f64;
        assert!(
            (2.5..3.5).contains(&share),
            "dispatch ratio {share:.2} should be ~3 ({:?})",
            fleet.dispatched
        );
    }

    #[test]
    fn two_identical_replicas_double_throughput() {
        let times = vec![0.02, 0.04];
        let solo = simulate(&times, 1000, 2).throughput;
        let fleet =
            simulate_replicated(&[times.clone(), times.clone()], 2000, 2).throughput;
        assert!(
            (fleet / solo - 2.0).abs() < 0.05,
            "fleet {fleet:.2} vs solo {solo:.2}"
        );
    }

    #[test]
    fn recorded_run_conserves_chains_and_matches_plain() {
        use crate::obs::{audit_chains, Recorder};
        let times = vec![vec![0.02, 0.04], vec![0.03]];
        let plain = simulate_replicated(&times, 120, 2);
        let rec = Recorder::on();
        let traced = simulate_replicated_recorded(
            &times, 120, 2, &[], 0.0, &rec, 0, 0, |_, _, _| {},
        );
        // Recording must not perturb the simulation.
        assert_eq!(plain.dispatched, traced.dispatched);
        assert!((plain.makespan - traced.makespan).abs() < 1e-12);
        // Every image has a complete admit -> stages -> depart chain.
        let audit = audit_chains(&rec.spans_sorted()).expect("conserved");
        assert_eq!(audit.complete, 120);
        assert_eq!(audit.shed, 0);
        assert_eq!(
            audit.stage_spans,
            traced.dispatched[0] * 2 + traced.dispatched[1]
        );
        // Busy time in the recorder's histograms equals the report's.
        let snap = rec.snapshot().unwrap();
        let hist_busy: f64 = (0..2)
            .flat_map(|r| (0..2).map(move |s| (r, s)))
            .filter_map(|(r, s)| snap.hist(&format!("stage_service/g0r{r}s{s}")))
            .map(|h| h.sum())
            .sum();
        let report_busy: f64 = traced
            .per_replica
            .iter()
            .map(|p| {
                p.utilization.iter().sum::<f64>() * p.makespan
            })
            .sum();
        assert!(
            (hist_busy - report_busy).abs() < 1e-6 * report_busy.max(1.0),
            "hist busy {hist_busy} vs report busy {report_busy}"
        );
    }

    #[test]
    fn disturbed_without_events_matches_simulate_exactly() {
        let times = [0.03, 0.05, 0.02];
        let plain = simulate(&times, 300, 2);
        let mut observed = 0usize;
        let disturbed =
            simulate_disturbed(&times, 300, 2, &[], 0.0, 0, |_, _| observed += 1);
        assert!((plain.makespan - disturbed.makespan).abs() < 1e-12);
        assert!((plain.throughput - disturbed.throughput).abs() < 1e-12);
        assert_eq!(plain.bottleneck, disturbed.bottleneck);
        assert_eq!(observed, 300 * 3, "one observation per item per stage");
        for (a, b) in plain.latencies.iter().zip(&disturbed.latencies) {
            assert!((a - b).abs() < 1e-12);
        }
        for (a, b) in plain.utilization.iter().zip(&disturbed.utilization) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn throttle_from_time_zero_halves_throughput() {
        let ev = ThrottleEvent { at: 0.0, factor: 2.0, scope: Vec::new() };
        let r = simulate_disturbed(&[0.01], 500, 1, &[ev], 0.0, 0, |_, _| {});
        assert!((r.throughput - 50.0).abs() < 0.5, "tp={}", r.throughput);
    }

    #[test]
    fn mid_run_throttle_lands_between_bounds() {
        // 2x throttle halfway: makespan must sit between the undisturbed
        // and the fully-throttled runs.
        let times = [0.02, 0.04];
        let clean = simulate(&times, 400, 2).makespan;
        let full = simulate(&[0.04, 0.08], 400, 2).makespan;
        let ev = ThrottleEvent { at: clean / 2.0, factor: 2.0, scope: Vec::new() };
        let mid = simulate_disturbed(&times, 400, 2, &[ev], 0.0, 0, |_, _| {}).makespan;
        assert!(mid > clean && mid < full, "clean={clean} mid={mid} full={full}");
    }

    #[test]
    fn throttle_scope_spares_other_replicas_and_stages() {
        // Slow only replica 1's stage 0; replica 0 keeps its clean rate.
        let replicas = vec![vec![0.02], vec![0.02]];
        let ev = ThrottleEvent { at: 0.0, factor: 3.0, scope: vec![(1, 0)] };
        let fleet =
            simulate_replicated_disturbed(&replicas, 600, 2, &[ev], 0.0, |_, _, _| {});
        // Dispatch was based on base cycles (even split), so the throttled
        // replica's makespan is ~3x the clean one's.
        let m0 = fleet.per_replica[0].makespan;
        let m1 = fleet.per_replica[1].makespan;
        assert!(m1 > 2.5 * m0, "m0={m0} m1={m1}");
    }

    #[test]
    fn chunked_disturbed_runs_respect_absolute_event_time() {
        // An event at t=1.0 must not affect a chunk simulated at t0=2.0 the
        // same way it affects one at t0=0.0 (the factor is already active).
        let times = [0.01];
        let ev = ThrottleEvent { at: 1.0, factor: 2.0, scope: Vec::new() };
        let early = simulate_disturbed(&times, 50, 1, &[ev.clone()], 0.0, 0, |_, _| {});
        let late = simulate_disturbed(&times, 50, 1, &[ev], 2.0, 0, |_, _| {});
        // At t0=0 the event is in the future: clean 0.5 s makespan.
        assert!((early.makespan - 0.5).abs() < 1e-9, "{}", early.makespan);
        // At t0=2 the event is already active: 1.0 s makespan.
        assert!((late.makespan - 1.0).abs() < 1e-9, "{}", late.makespan);
    }

    #[test]
    fn disturbed_fleet_without_events_matches_replicated() {
        let replicas = vec![vec![0.01, 0.02], vec![0.03]];
        let plain = simulate_replicated(&replicas, 300, 2);
        let disturbed =
            simulate_replicated_disturbed(&replicas, 300, 2, &[], 0.0, |_, _, _| {});
        assert_eq!(plain.dispatched, disturbed.dispatched);
        assert!((plain.makespan - disturbed.makespan).abs() < 1e-12);
        assert!((plain.throughput - disturbed.throughput).abs() < 1e-12);
    }

    /// The satellite property: fleet aggregate throughput equals the sum of
    /// replica steady-state throughputs within tolerance (the transient
    /// fill/drain shrinks as the stream grows).
    #[test]
    fn property_fleet_throughput_is_sum_of_replica_rates() {
        check(100, |rng| {
            let r = 1 + rng.index(4);
            let replicas: Vec<Vec<f64>> = (0..r)
                .map(|_| {
                    let p = 1 + rng.index(4);
                    (0..p).map(|_| rng.range_f64(0.002, 0.05)).collect()
                })
                .collect();
            let cap = 1 + rng.index(3);
            let fleet = simulate_replicated(&replicas, 3000, cap);
            let sum_rates: f64 = replicas
                .iter()
                .map(|t| steady_state_throughput(t))
                .sum();
            crate::prop_assert!(
                fleet.throughput <= sum_rates * (1.0 + 1e-9),
                "aggregate {} exceeds the rate-sum bound {}",
                fleet.throughput,
                sum_rates
            );
            let rel = (fleet.throughput - sum_rates).abs() / sum_rates;
            crate::prop_assert!(
                rel < 0.05,
                "aggregate {} not within 5% of rate sum {} (rel {rel:.3})",
                fleet.throughput,
                sum_rates
            );
            crate::prop_assert!(
                fleet.dispatched.iter().sum::<usize>() == 3000,
                "dispatch lost images: {:?}",
                fleet.dispatched
            );
            Ok(())
        });
    }
}
