//! The five benchmark CNNs of the paper (Table I), described at the
//! major-layer (ARM-CL node) granularity:
//!
//! | CNN        | major nodes |
//! |------------|-------------|
//! | AlexNet    | 11  (8 conv nodes — conv2/4/5 are two grouped nodes each — + 3 FC) |
//! | GoogLeNet  | 58  (3 conv + 9 inception x 6 conv + 1 FC) |
//! | MobileNet  | 28  (14 conv + 13 depthwise conv + 1 FC) |
//! | ResNet50   | 54  (53 conv incl. 4 projection shortcuts + 1 FC) |
//! | SqueezeNet | 26  (2 conv + 8 fire x 3 conv) |

use super::network::{NetBuilder, Network};

/// AlexNet (Krizhevsky et al. 2012), ARM-CL node view: the three grouped
/// convolutions (conv2, conv4, conv5) are two nodes each => 11 major nodes.
pub fn alexnet() -> Network {
    NetBuilder::new("alexnet", 227, 227, 3)
        .conv("conv1", 11, 96, 4, 0) // 55x55x96
        .pool(3, 2, 0) // 27x27
        .conv_node("conv2a", 48, 5, 128, 1, 2)
        .conv_node("conv2b", 48, 5, 128, 1, 2)
        .set_c(256)
        .pool(3, 2, 0) // 13x13
        .conv("conv3", 3, 384, 1, 1)
        .conv_node("conv4a", 192, 3, 192, 1, 1)
        .conv_node("conv4b", 192, 3, 192, 1, 1)
        .set_c(384)
        .conv_node("conv5a", 192, 3, 128, 1, 1)
        .conv_node("conv5b", 192, 3, 128, 1, 1)
        .set_c(256)
        .pool(3, 2, 0) // 6x6x256
        .fc("fc6", 4096)
        .fc("fc7", 4096)
        .fc("fc8", 1000)
        .build()
}

/// One inception module: 6 conv nodes (1x1, 3x3-reduce, 3x3, 5x5-reduce,
/// 5x5, pool-proj); output channels are the concat of the four branch
/// outputs. `conv_node` records a layer without advancing the tracked dims,
/// so every branch sees the module's input dims.
fn inception(
    b: NetBuilder,
    tag: &str,
    c1: usize,
    c3r: usize,
    c3: usize,
    c5r: usize,
    c5: usize,
    pp: usize,
) -> NetBuilder {
    let (h, w, cin) = b.dims();
    b.conv_node(&format!("{tag}_1x1"), cin, 1, c1, 1, 0)
        .conv_node(&format!("{tag}_3x3r"), cin, 1, c3r, 1, 0)
        .conv_node(&format!("{tag}_3x3"), c3r, 3, c3, 1, 1)
        .conv_node(&format!("{tag}_5x5r"), cin, 1, c5r, 1, 0)
        .conv_node(&format!("{tag}_5x5"), c5r, 5, c5, 1, 2)
        .conv_node(&format!("{tag}_pp"), cin, 1, pp, 1, 0)
        .set_dims(h, w, c1 + c3 + c5 + pp)
}

/// GoogLeNet (Szegedy et al. 2015): 3 conv + 9 inception x 6 + 1 FC = 58.
pub fn googlenet() -> Network {
    let b = NetBuilder::new("googlenet", 224, 224, 3)
        .conv("conv1", 7, 64, 2, 3) // 112x112x64
        .pool(3, 2, 1) // 56x56
        .conv("conv2r", 1, 64, 1, 0)
        .conv("conv2", 3, 192, 1, 1)
        .pool(3, 2, 1); // 28x28x192
    let b = inception(b, "3a", 64, 96, 128, 16, 32, 32); // -> 256
    let b = inception(b, "3b", 128, 128, 192, 32, 96, 64); // -> 480
    let b = b.pool(3, 2, 1); // 14x14
    let b = inception(b, "4a", 192, 96, 208, 16, 48, 64); // -> 512
    let b = inception(b, "4b", 160, 112, 224, 24, 64, 64);
    let b = inception(b, "4c", 128, 128, 256, 24, 64, 64);
    let b = inception(b, "4d", 112, 144, 288, 32, 64, 64); // -> 528
    let b = inception(b, "4e", 256, 160, 320, 32, 128, 128); // -> 832
    let b = b.pool(3, 2, 1); // 7x7
    let b = inception(b, "5a", 256, 160, 320, 32, 128, 128); // -> 832
    let b = inception(b, "5b", 384, 192, 384, 48, 128, 128); // -> 1024
    b.global_pool().fc("fc", 1000).build()
}

/// MobileNet v1 (Howard et al. 2017): 14 conv + 13 dw + 1 FC = 28.
pub fn mobilenet() -> Network {
    let mut b = NetBuilder::new("mobilenet", 224, 224, 3).conv("conv1", 3, 32, 2, 1); // 112x112x32
    // (stride, cout-of-pointwise) per dw/pw pair.
    let cfg: [(usize, usize); 13] = [
        (1, 64),
        (2, 128),
        (1, 128),
        (2, 256),
        (1, 256),
        (2, 512),
        (1, 512),
        (1, 512),
        (1, 512),
        (1, 512),
        (1, 512),
        (2, 1024),
        (1, 1024),
    ];
    for (i, (s, pw_out)) in cfg.iter().enumerate() {
        b = b
            .dw(&format!("dw{}", i + 1), 3, *s, 1)
            .conv(&format!("pw{}", i + 1), 1, *pw_out, 1, 0);
    }
    b.global_pool().fc("fc", 1000).build()
}

/// ResNet50 (He et al. 2016): conv1 + 16 bottlenecks x 3 + 4 projections
/// + FC = 54 major nodes.
pub fn resnet50() -> Network {
    let mut b = NetBuilder::new("resnet50", 224, 224, 3).conv("conv1", 7, 64, 2, 3); // 112
    b = b.pool(3, 2, 1); // 56x56x64
    // (blocks, mid_channels, out_channels, first_stride)
    let stages: [(usize, usize, usize, usize); 4] = [
        (3, 64, 256, 1),
        (4, 128, 512, 2),
        (6, 256, 1024, 2),
        (3, 512, 2048, 2),
    ];
    for (si, (blocks, mid, out, s0)) in stages.iter().enumerate() {
        for blk in 0..*blocks {
            let s = if blk == 0 { *s0 } else { 1 };
            let (h, w, cin) = b.dims();
            let tag = format!("s{}b{}", si + 2, blk + 1);
            if blk == 0 {
                // Projection shortcut (counted as a major node).
                b = b.conv_node(&format!("{tag}_proj"), cin, 1, *out, s, 0);
            }
            // 1x1 reduce (carries the stride, torchvision-style), 3x3, 1x1 expand.
            b = b.set_dims(h, w, cin);
            b = b.conv(&format!("{tag}_a"), 1, *mid, s, 0);
            b = b.conv(&format!("{tag}_b"), 3, *mid, 1, 1);
            b = b.conv(&format!("{tag}_c"), 1, *out, 1, 0);
        }
    }
    b.global_pool().fc("fc", 1000).build()
}

/// SqueezeNet v1.0 (Iandola et al. 2016): conv1 + 8 fire x 3 + conv10 = 26.
pub fn squeezenet() -> Network {
    fn fire(b: NetBuilder, tag: &str, sq: usize, e1: usize, e3: usize) -> NetBuilder {
        let b = b.conv(&format!("{tag}_squeeze"), 1, sq, 1, 0);
        let (h, w, _) = b.dims();
        let b = b
            .conv_node(&format!("{tag}_e1x1"), sq, 1, e1, 1, 0)
            .conv_node(&format!("{tag}_e3x3"), sq, 3, e3, 1, 1);
        b.set_dims(h, w, e1 + e3)
    }
    let b = NetBuilder::new("squeezenet", 224, 224, 3)
        .conv("conv1", 7, 96, 2, 0) // 109x109x96
        .pool(3, 2, 0); // 54x54
    let b = fire(b, "fire2", 16, 64, 64);
    let b = fire(b, "fire3", 16, 64, 64);
    let b = fire(b, "fire4", 32, 128, 128);
    let b = b.pool(3, 2, 0); // 26x26
    let b = fire(b, "fire5", 32, 128, 128);
    let b = fire(b, "fire6", 48, 192, 192);
    let b = fire(b, "fire7", 48, 192, 192);
    let b = fire(b, "fire8", 64, 256, 256);
    let b = b.pool(3, 2, 0); // 12x12
    let b = fire(b, "fire9", 64, 256, 256);
    b.conv("conv10", 1, 1000, 1, 0).global_pool().build()
}

/// All five benchmark networks, in the paper's order.
pub fn all_networks() -> Vec<Network> {
    vec![alexnet(), googlenet(), mobilenet(), resnet50(), squeezenet()]
}

/// Look up one network by (lowercase) name.
pub fn by_name(name: &str) -> Option<Network> {
    match name {
        "alexnet" => Some(alexnet()),
        "googlenet" => Some(googlenet()),
        "mobilenet" => Some(mobilenet()),
        "resnet50" => Some(resnet50()),
        "squeezenet" => Some(squeezenet()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::layer::LayerKind;

    /// Table I node counts are the ground truth for the whole design space.
    #[test]
    fn table1_major_node_counts() {
        assert_eq!(alexnet().num_layers(), 11);
        assert_eq!(googlenet().num_layers(), 58);
        assert_eq!(mobilenet().num_layers(), 28);
        assert_eq!(resnet50().num_layers(), 54);
        assert_eq!(squeezenet().num_layers(), 26);
    }

    #[test]
    fn mobilenet_kind_mix() {
        let net = mobilenet();
        let dw = net.layers.iter().filter(|l| l.kind == LayerKind::DwConv).count();
        let conv = net.layers.iter().filter(|l| l.kind == LayerKind::Conv).count();
        let fc = net.layers.iter().filter(|l| l.kind == LayerKind::Fc).count();
        assert_eq!((conv, dw, fc), (14, 13, 1));
    }

    #[test]
    fn alexnet_fc_dominates_weights() {
        // The paper notes AlexNet is FC-heavy (Fig. 6 discussion).
        let net = alexnet();
        let fc_bytes: usize = net
            .layers
            .iter()
            .filter(|l| l.kind == LayerKind::Fc)
            .map(|l| l.weight_bytes())
            .sum();
        assert!(fc_bytes * 2 > net.total_weight_bytes());
    }

    #[test]
    fn resnet_total_macs_plausible() {
        // ResNet50 is ~4 GMACs at 224x224 in the standard accounting.
        let g = resnet50().total_macs() as f64 / 1e9;
        assert!((2.0..6.0).contains(&g), "got {g} GMACs");
    }

    #[test]
    fn googlenet_macs_plausible() {
        // ~1.5 GMACs nominal.
        let g = googlenet().total_macs() as f64 / 1e9;
        assert!((0.8..2.5).contains(&g), "got {g} GMACs");
    }

    #[test]
    fn mobilenet_macs_plausible() {
        // ~0.57 GMACs nominal.
        let g = mobilenet().total_macs() as f64 / 1e9;
        assert!((0.3..0.9).contains(&g), "got {g} GMACs");
    }

    #[test]
    fn front_layers_have_bigger_gemm_n() {
        // Fig. 7 premise: early conv layers operate on bigger inputs.
        for net in all_networks() {
            let convs: Vec<_> = net
                .layers
                .iter()
                .filter(|l| l.kind != LayerKind::Fc)
                .collect();
            let first_n = convs.first().unwrap().gemm().n;
            let last_n = convs.last().unwrap().gemm().n;
            assert!(
                first_n > last_n,
                "{}: first N={first_n} last N={last_n}",
                net.name
            );
        }
    }

    #[test]
    fn layer_dims_chain_is_consistent() {
        // Every layer's input dims must be realizable from some predecessor:
        // here we just sanity-check all dims are nonzero and strides valid.
        for net in all_networks() {
            for l in &net.layers {
                assert!(l.ih > 0 && l.iw > 0 && l.cin > 0 && l.cout > 0, "{}", l.name);
                let (oh, ow) = l.out_hw();
                assert!(oh > 0 && ow > 0, "{} produced empty output", l.name);
            }
        }
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["alexnet", "googlenet", "mobilenet", "resnet50", "squeezenet"] {
            assert_eq!(by_name(n).unwrap().name, n);
        }
        assert!(by_name("vgg").is_none());
    }
}
