//! Network container + a builder that threads spatial dims through the
//! stack (pools and other non-weighted ops adjust dims but create no major
//! layer, matching the paper's node accounting).

use super::layer::{Layer, LayerKind};

#[derive(Debug, Clone)]
pub struct Network {
    pub name: String,
    pub layers: Vec<Layer>,
}

impl Network {
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn total_macs(&self) -> usize {
        self.layers.iter().map(|l| l.gemm().macs()).sum()
    }

    pub fn total_weight_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.weight_bytes()).sum()
    }

    pub fn conv_layer_count(&self) -> usize {
        self.layers
            .iter()
            .filter(|l| l.kind != LayerKind::Fc)
            .count()
    }
}

/// Builder that tracks the current activation dims (h, w, c).
pub struct NetBuilder {
    name: String,
    h: usize,
    w: usize,
    c: usize,
    layers: Vec<Layer>,
}

impl NetBuilder {
    pub fn new(name: &str, h: usize, w: usize, c: usize) -> NetBuilder {
        NetBuilder { name: name.to_string(), h, w, c, layers: Vec::new() }
    }

    pub fn dims(&self) -> (usize, usize, usize) {
        (self.h, self.w, self.c)
    }

    /// Standard convolution; updates the tracked dims.
    pub fn conv(mut self, name: &str, f: usize, cout: usize, s: usize, p: usize) -> Self {
        let l = Layer::conv(name, self.h, self.w, self.c, f, cout, s, p);
        let (oh, ow) = l.out_hw();
        self.h = oh;
        self.w = ow;
        self.c = cout;
        self.layers.push(l);
        self
    }

    /// Convolution on an explicit input-channel count (grouped-conv nodes,
    /// e.g. AlexNet conv2/4/5 where each node sees half the channels) that
    /// does NOT advance the tracked dims; combine with `set_c` afterwards.
    pub fn conv_node(mut self, name: &str, cin: usize, f: usize, cout: usize, s: usize, p: usize) -> Self {
        let l = Layer::conv(name, self.h, self.w, cin, f, cout, s, p);
        self.layers.push(l);
        self
    }

    /// Depthwise convolution.
    pub fn dw(mut self, name: &str, f: usize, s: usize, p: usize) -> Self {
        let l = Layer::dw_conv(name, self.h, self.w, self.c, f, s, p);
        let (oh, ow) = l.out_hw();
        self.h = oh;
        self.w = ow;
        self.layers.push(l);
        self
    }

    /// Non-weighted pool: adjusts dims only (folded into the previous major
    /// layer for timing, per the paper).
    pub fn pool(mut self, f: usize, s: usize, p: usize) -> Self {
        self.h = (self.h + 2 * p - f) / s + 1;
        self.w = (self.w + 2 * p - f) / s + 1;
        self
    }

    pub fn global_pool(mut self) -> Self {
        self.h = 1;
        self.w = 1;
        self
    }

    /// Advance dims after a channel-concat (inception / fire / grouped conv).
    pub fn set_dims(mut self, h: usize, w: usize, c: usize) -> Self {
        self.h = h;
        self.w = w;
        self.c = c;
        self
    }

    pub fn set_c(mut self, c: usize) -> Self {
        self.c = c;
        self
    }

    pub fn fc(mut self, name: &str, cout: usize) -> Self {
        let cin = self.h * self.w * self.c;
        self.layers.push(Layer::fc(name, cin, cout));
        self.h = 1;
        self.w = 1;
        self.c = cout;
        self
    }

    pub fn build(self) -> Network {
        Network { name: self.name, layers: self.layers }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_threads_dims() {
        let net = NetBuilder::new("t", 32, 32, 3)
            .conv("c1", 3, 16, 1, 1)
            .pool(2, 2, 0)
            .conv("c2", 3, 32, 1, 1)
            .global_pool()
            .fc("fc", 10)
            .build();
        assert_eq!(net.num_layers(), 3);
        assert_eq!(net.layers[1].ih, 16);
        assert_eq!(net.layers[2].cin, 32);
        assert_eq!(net.conv_layer_count(), 2);
    }

    #[test]
    fn conv_node_does_not_advance() {
        let net = NetBuilder::new("t", 27, 27, 96)
            .conv_node("c2a", 48, 5, 128, 1, 2)
            .conv_node("c2b", 48, 5, 128, 1, 2)
            .set_c(256)
            .conv("c3", 3, 384, 1, 1)
            .build();
        assert_eq!(net.layers[0].cin, 48);
        assert_eq!(net.layers[2].cin, 256);
        assert_eq!(net.layers[2].ih, 27);
    }
}
