//! CNN descriptor substrate: major-layer descriptors (paper Table II /
//! Fig. 10), the network container/builder, and the five benchmark networks
//! of Table I.

pub mod layer;
pub mod network;
pub mod zoo;

pub use layer::{GemmDims, Layer, LayerKind};
pub use network::{NetBuilder, Network};
