//! Major-layer descriptors — the paper's Fig. 10 / Table II view of a CNN.
//!
//! A *major layer* is a weighted ARM-CL node: convolutional, depthwise
//! convolutional, or fully-connected. Non-weighted kernels (pool, ReLU,
//! concat, norm) are folded into the preceding major layer, exactly as the
//! paper does ("all kernels from the non-convolutional layers are considered
//! part of the previous convolutional layers").

/// Kind of weighted node.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    Conv,
    /// Depthwise convolution (MobileNet): one filter per input channel.
    DwConv,
    Fc,
}

/// GEMM dimensions of the lowered convolution (paper Eq. 4):
/// image matrix `[N x K]` times filter matrix `[K x M]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmDims {
    pub n: usize,
    pub k: usize,
    pub m: usize,
}

impl GemmDims {
    /// Total multiply-accumulate operations (paper: "total arithmetic
    /// operations is N*K*M").
    pub fn macs(&self) -> usize {
        self.n * self.k * self.m
    }
}

/// One major layer with its static descriptors (paper Table II parameters).
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    /// Input tensor dims {Iw, Ih, Id}; for FC, `ih = iw = 1`, `cin` = inputs.
    pub ih: usize,
    pub iw: usize,
    pub cin: usize,
    /// Filter dims {Fw, Fh}; `cout` = Ofm.
    pub fh: usize,
    pub fw: usize,
    pub cout: usize,
    pub stride: usize,
    pub pad: usize,
}

impl Layer {
    pub fn conv(
        name: &str,
        ih: usize,
        iw: usize,
        cin: usize,
        fh: usize,
        cout: usize,
        stride: usize,
        pad: usize,
    ) -> Layer {
        Layer {
            name: name.to_string(),
            kind: LayerKind::Conv,
            ih,
            iw,
            cin,
            fh,
            fw: fh,
            cout,
            stride,
            pad,
        }
    }

    pub fn dw_conv(
        name: &str,
        ih: usize,
        iw: usize,
        c: usize,
        fh: usize,
        stride: usize,
        pad: usize,
    ) -> Layer {
        Layer {
            name: name.to_string(),
            kind: LayerKind::DwConv,
            ih,
            iw,
            cin: c,
            fh,
            fw: fh,
            cout: c,
            stride,
            pad,
        }
    }

    pub fn fc(name: &str, cin: usize, cout: usize) -> Layer {
        Layer {
            name: name.to_string(),
            kind: LayerKind::Fc,
            ih: 1,
            iw: 1,
            cin,
            fh: 1,
            fw: 1,
            cout,
            stride: 1,
            pad: 0,
        }
    }

    /// Output spatial dims, paper Eq. (3): `O = floor((I - F + 2*Pad)/S) + 1`.
    pub fn out_hw(&self) -> (usize, usize) {
        if self.kind == LayerKind::Fc {
            return (1, 1);
        }
        let oh = (self.ih + 2 * self.pad - self.fh) / self.stride + 1;
        let ow = (self.iw + 2 * self.pad - self.fw) / self.stride + 1;
        (oh, ow)
    }

    /// GEMM dims, paper Eq. (4): `N = Ow*Oh, K = Fw*Fh*Fd, M = Ofm`.
    ///
    /// Depthwise convolutions execute one small per-channel GEMM; mapping
    /// them to `(N=Oh*Ow, K=Fh*Fw, M=C)` preserves both the MAC count
    /// (`N*K*M = Oh*Ow*Fh*Fw*C`) and the operand-size terms the performance
    /// model uses.
    pub fn gemm(&self) -> GemmDims {
        let (oh, ow) = self.out_hw();
        match self.kind {
            LayerKind::Conv => GemmDims {
                n: oh * ow,
                k: self.fh * self.fw * self.cin,
                m: self.cout,
            },
            LayerKind::DwConv => GemmDims { n: oh * ow, k: self.fh * self.fw, m: self.cout },
            LayerKind::Fc => GemmDims { n: 1, k: self.cin, m: self.cout },
        }
    }

    /// Weight bytes (f32), used by the cache model.
    pub fn weight_bytes(&self) -> usize {
        4 * match self.kind {
            LayerKind::Conv => self.fh * self.fw * self.cin * self.cout + self.cout,
            LayerKind::DwConv => self.fh * self.fw * self.cout + self.cout,
            LayerKind::Fc => self.cin * self.cout + self.cout,
        }
    }

    pub fn input_bytes(&self) -> usize {
        4 * self.ih * self.iw * self.cin
    }

    pub fn output_bytes(&self) -> usize {
        let (oh, ow) = self.out_hw();
        4 * oh * ow * self.cout
    }

    /// Working set of the lowered GEMM: image matrix + filter matrix +
    /// result matrix, in bytes (drives the L2-capacity term of the cost
    /// model).
    pub fn gemm_bytes(&self) -> usize {
        let g = self.gemm();
        4 * (g.n * g.k + g.k * g.m + g.n * g.m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq3_output_dims() {
        // AlexNet conv1: 227x227, 11x11, s4, pad0 -> 55x55.
        let l = Layer::conv("c1", 227, 227, 3, 11, 96, 4, 0);
        assert_eq!(l.out_hw(), (55, 55));
        // 3x3 pad1 s1 preserves dims.
        let l = Layer::conv("c", 56, 56, 64, 3, 64, 1, 1);
        assert_eq!(l.out_hw(), (56, 56));
        // floor behaviour: 7x7 s2 pad3 on 224 -> 112.
        let l = Layer::conv("c", 224, 224, 3, 7, 64, 2, 3);
        assert_eq!(l.out_hw(), (112, 112));
    }

    #[test]
    fn eq4_gemm_dims() {
        let l = Layer::conv("c1", 227, 227, 3, 11, 96, 4, 0);
        let g = l.gemm();
        assert_eq!(g, GemmDims { n: 55 * 55, k: 11 * 11 * 3, m: 96 });
        assert_eq!(g.macs(), 55 * 55 * 363 * 96);
    }

    #[test]
    fn depthwise_macs_preserved() {
        let l = Layer::dw_conv("dw", 112, 112, 32, 3, 1, 1);
        let g = l.gemm();
        assert_eq!(g.macs(), 112 * 112 * 9 * 32);
    }

    #[test]
    fn fc_dims() {
        let l = Layer::fc("fc6", 9216, 4096);
        assert_eq!(l.gemm(), GemmDims { n: 1, k: 9216, m: 4096 });
        assert_eq!(l.weight_bytes(), 4 * (9216 * 4096 + 4096));
    }

    #[test]
    fn byte_accounting() {
        let l = Layer::conv("c", 56, 56, 64, 3, 64, 1, 1);
        assert_eq!(l.input_bytes(), 4 * 56 * 56 * 64);
        assert_eq!(l.output_bytes(), 4 * 56 * 56 * 64);
        assert_eq!(l.weight_bytes(), 4 * (3 * 3 * 64 * 64 + 64));
        let g = l.gemm();
        assert_eq!(l.gemm_bytes(), 4 * (g.n * g.k + g.k * g.m + g.n * g.m));
    }
}
