//! Board and cluster descriptions: which heterogeneous big.LITTLE boards
//! make up the fleet, and which workloads it serves.
//!
//! A [`BoardSpec`] names one board's core configuration — inline
//! (`cores=4+4`) or via a platform config file (`platform=configs/f.json`,
//! whose TimeMatrix parameters then describe that board's silicon) — plus
//! an optional pinned arrival-stream seed. The CLI form is a repeatable
//! `--board key=value,...` option parsed by [`BoardSpec::parse`], mirroring
//! `--tenant`.

use anyhow::{Context, Result};

use crate::config::Config;
use crate::tenancy::TenantSpec;

/// One board of the cluster: a big.LITTLE core configuration with its own
/// TimeMatrix source (via the platform config) and arrival-stream seed.
#[derive(Debug, Clone, PartialEq)]
pub struct BoardSpec {
    /// Display name; defaults to the `BIG+SMALL` core display
    /// (auto-suffixed `#k` when several boards share a configuration).
    pub name: String,
    /// Big-cluster cores.
    pub big: usize,
    /// Small-cluster cores.
    pub small: usize,
    /// Optional platform config file: silicon parameters (frequencies,
    /// MAC/memory costs, …) beyond the core counts.
    pub platform: Option<String>,
    /// Pinned base seed for this board's arrival streams; `None` derives
    /// one from the run's `--seed` and the board index.
    pub seed: Option<u64>,
}

impl BoardSpec {
    /// A board on the default platform with the given core budget.
    pub fn new(big: usize, small: usize) -> BoardSpec {
        BoardSpec {
            name: format!("{big}+{small}"),
            big,
            small,
            platform: None,
            seed: None,
        }
    }

    fn default_name(&self) -> String {
        format!("{}+{}", self.big, self.small)
    }

    /// Parse one `--board` value: comma-separated `key=value` pairs.
    ///
    /// Keys: `cores=BIG+SMALL` and/or `platform=FILE` (at least one; when
    /// both are given, `cores=` overrides the file's core counts),
    /// `seed=N`, `name=LABEL`.
    pub fn parse(s: &str) -> Result<BoardSpec> {
        let mut cores: Option<(usize, usize)> = None;
        let mut platform: Option<String> = None;
        let mut seed = None;
        let mut name: Option<String> = None;
        for part in s.split(',').filter(|p| !p.is_empty()) {
            let (k, v) = part
                .split_once('=')
                .with_context(|| format!("bad board field {part:?} (expected key=value)"))?;
            match k {
                "cores" => {
                    let (b, sm) = v.split_once('+').with_context(|| {
                        format!("bad board cores {v:?} (expected BIG+SMALL, e.g. 4+4)")
                    })?;
                    let big: usize =
                        b.parse().map_err(|_| anyhow::anyhow!("bad big-core count {b:?}"))?;
                    let small: usize = sm
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad small-core count {sm:?}"))?;
                    anyhow::ensure!(
                        big >= 1 && small >= 1,
                        "board needs at least one core per cluster, got {v:?}"
                    );
                    cores = Some((big, small));
                }
                "platform" => platform = Some(v.to_string()),
                "seed" => {
                    let n: u64 = v
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad board seed {v:?}"))?;
                    // ClusterPlan serializes seeds as JSON numbers (f64):
                    // anything past 2^53 would round silently on save/load.
                    anyhow::ensure!(
                        n < (1u64 << 53),
                        "board seed {n} exceeds 2^53 and would lose precision \
                         in the plan artifact"
                    );
                    seed = Some(n);
                }
                "name" => name = Some(v.to_string()),
                other => anyhow::bail!(
                    "unknown board field {other:?} (cores|platform|seed|name)"
                ),
            }
        }
        let (big, small) = match (cores, &platform) {
            (Some(c), _) => c,
            (None, Some(p)) => {
                let cfg = Config::load(std::path::Path::new(p))?;
                (cfg.platform.big.cores, cfg.platform.small.cores)
            }
            (None, None) => anyhow::bail!(
                "board spec {s:?} needs cores=BIG+SMALL or platform=FILE"
            ),
        };
        let mut spec = BoardSpec { name: String::new(), big, small, platform, seed };
        spec.name = name.unwrap_or_else(|| spec.default_name());
        Ok(spec)
    }

    /// Parse every `--board` occurrence, de-duplicating default names
    /// (`4+4`, `4+4#2`, …). Explicitly colliding `name=` labels are an
    /// error.
    pub fn parse_all(values: &[&str]) -> Result<Vec<BoardSpec>> {
        anyhow::ensure!(!values.is_empty(), "need at least one --board spec");
        let mut out: Vec<BoardSpec> = Vec::with_capacity(values.len());
        for v in values {
            let mut spec = BoardSpec::parse(v)?;
            let explicit = spec.name != spec.default_name();
            let mut k = 1;
            while out.iter().any(|b| b.name == spec.name) {
                anyhow::ensure!(
                    !explicit,
                    "duplicate board name {:?} (give each board a unique name=)",
                    spec.name
                );
                k += 1;
                spec.name = format!("{}#{k}", spec.default_name());
            }
            out.push(spec);
        }
        Ok(out)
    }

    /// The board's full [`Config`]: its platform file (or the run's base
    /// config) with this board's core counts applied on top.
    pub fn config(&self, base: &Config) -> Result<Config> {
        let mut cfg = match &self.platform {
            Some(p) => Config::load(std::path::Path::new(p))
                .with_context(|| format!("board {:?} platform", self.name))?,
            None => base.clone(),
        };
        cfg.platform.big.cores = self.big;
        cfg.platform.small.cores = self.small;
        Ok(cfg)
    }
}

/// The whole fleet: N heterogeneous boards serving a common set of
/// workloads. One workload per cluster is the PICO-style "shard one
/// network's traffic" shape; several workloads co-serve on *every* board
/// through per-board [`MultiPlan`](crate::tenancy::MultiPlan)s.
///
/// Workload `rate_hz` values are *cluster-wide* offered rates; the
/// cluster DSE ([`ClusterPlan::compile`](crate::cluster::ClusterPlan::compile))
/// splits them across boards by capacity share.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub boards: Vec<BoardSpec>,
    pub workloads: Vec<TenantSpec>,
    /// Per-fleet replica cap inside each board's search.
    pub max_replicas: usize,
}

impl ClusterSpec {
    pub fn new(boards: Vec<BoardSpec>, workloads: Vec<TenantSpec>) -> ClusterSpec {
        ClusterSpec { boards, workloads, max_replicas: 4 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_examples() {
        let b = BoardSpec::parse("cores=4+4").unwrap();
        assert_eq!((b.big, b.small), (4, 4));
        assert_eq!(b.name, "4+4");
        assert_eq!(b.seed, None);

        let b = BoardSpec::parse("cores=2+6,seed=11,name=edge-east").unwrap();
        assert_eq!((b.big, b.small), (2, 6));
        assert_eq!(b.name, "edge-east");
        assert_eq!(b.seed, Some(11));
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(BoardSpec::parse("").is_err(), "no cores");
        assert!(BoardSpec::parse("cores=4x4").is_err(), "bad separator");
        assert!(BoardSpec::parse("cores=0+4").is_err(), "zero cores");
        assert!(BoardSpec::parse("cores=4+4,turbo=1").is_err(), "unknown key");
        assert!(BoardSpec::parse("seed=5").is_err(), "seed without cores/platform");
        // The f64-JSON seed cap, enforced at parse time.
        let err = BoardSpec::parse(&format!("cores=4+4,seed={}", 1u64 << 53)).unwrap_err();
        assert!(err.to_string().contains("2^53"), "{err}");
        assert!(BoardSpec::parse(&format!("cores=4+4,seed={}", (1u64 << 53) - 1)).is_ok());
    }

    #[test]
    fn parse_all_suffixes_duplicate_default_names() {
        let boards = BoardSpec::parse_all(&["cores=4+4", "cores=4+4", "cores=2+6"]).unwrap();
        assert_eq!(boards[0].name, "4+4");
        assert_eq!(boards[1].name, "4+4#2");
        assert_eq!(boards[2].name, "2+6");
        let err = BoardSpec::parse_all(&["cores=4+4,name=x", "cores=2+6,name=x"]).unwrap_err();
        assert!(err.to_string().contains("duplicate board name"), "{err}");
    }

    #[test]
    fn config_overrides_core_counts_on_the_base_platform() {
        let base = Config::default();
        let cfg = BoardSpec::parse("cores=2+6").unwrap().config(&base).unwrap();
        assert_eq!(cfg.platform.big.cores, 2);
        assert_eq!(cfg.platform.small.cores, 6);
        // Everything else inherits the base platform.
        assert_eq!(cfg.platform.name, base.platform.name);
        assert!(BoardSpec::parse("cores=4+4,platform=/nonexistent.json")
            .unwrap()
            .config(&base)
            .is_err());
    }
}
