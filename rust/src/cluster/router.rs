//! The front-door router: pluggable dispatch policies that pick, for every
//! arrival, an ordered preference list of boards. Admission itself stays
//! with the per-board bounded queues — the router only *orders* boards, so
//! one shared fallback scan ("walk the preference list, admit at the first
//! board with admission-queue space, shed only when every up board is
//! full") gives every policy the same no-needless-shed guarantee, in both
//! execution twins.
//!
//! All policies reason about *drain time* — outstanding items divided by
//! the board's Eq. 12 capacity — rather than raw counts, so a 2+6 board
//! half as fast as its 4+4 neighbour is treated as twice as loaded at the
//! same queue depth. Only [`DispatchPolicy::PowerOfTwo`] is randomized; its
//! stream comes from a dedicated SplitMix64 RNG seeded by the run seed
//! XOR [`DISPATCH_SALT`], so it can never collide with (or perturb) the
//! per-board arrival streams.

use anyhow::Result;

use crate::util::rng::Rng;

/// XORed into the run seed for the router's sampling stream, keeping
/// dispatch randomness distinct from every `base + 7919·i` arrival seed.
pub const DISPATCH_SALT: u64 = 0x636c_7573_7465_72; // "cluster"

/// How the front door orders boards for each arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Rotate the first choice across up boards; fallback continues the
    /// rotation. The baseline the smarter policies are measured against.
    RoundRobin,
    /// Least outstanding *work*: ascending estimated drain time
    /// (outstanding / capacity), ties to the lower board index.
    LeastOutstanding,
    /// Weighted power-of-two-choices: sample two distinct boards with
    /// probability proportional to capacity, keep the one with less drain
    /// time; the loser and the remaining boards (by drain) follow as
    /// fallbacks.
    PowerOfTwo,
}

impl DispatchPolicy {
    /// Parse the CLI form: `round-robin`, `least-outstanding`, or `p2c`.
    pub fn parse(s: &str) -> Result<DispatchPolicy> {
        match s {
            "round-robin" | "rr" => Ok(DispatchPolicy::RoundRobin),
            "least-outstanding" | "low" => Ok(DispatchPolicy::LeastOutstanding),
            "p2c" | "power-of-two" => Ok(DispatchPolicy::PowerOfTwo),
            other => anyhow::bail!(
                "unknown dispatch policy {other:?} (round-robin|least-outstanding|p2c)"
            ),
        }
    }

    /// Stable display key (also what reports serialize).
    pub fn name(self) -> &'static str {
        match self {
            DispatchPolicy::RoundRobin => "round-robin",
            DispatchPolicy::LeastOutstanding => "least-outstanding",
            DispatchPolicy::PowerOfTwo => "p2c",
        }
    }
}

/// Per-run router state: the policy, each board's capacity weight, the
/// round-robin cursor, and the dispatch RNG. Both execution twins drive an
/// identical `Router` in arrival order, so the p2c sampling stream lines up
/// between DES and wall-clock runs.
#[derive(Debug, Clone)]
pub struct Router {
    policy: DispatchPolicy,
    /// Per-board Eq. 12 capacity (imgs/s); the drain-time denominator and
    /// the p2c sampling weight.
    weights: Vec<f64>,
    rr_next: usize,
    rng: Rng,
}

impl Router {
    pub fn new(policy: DispatchPolicy, weights: Vec<f64>, run_seed: u64) -> Result<Router> {
        anyhow::ensure!(!weights.is_empty(), "router needs at least one board");
        anyhow::ensure!(
            weights.iter().all(|w| w.is_finite() && *w > 0.0),
            "board capacity weights must be positive, got {weights:?}"
        );
        Ok(Router {
            policy,
            weights,
            rr_next: 0,
            rng: Rng::new(run_seed ^ DISPATCH_SALT),
        })
    }

    pub fn policy(&self) -> DispatchPolicy {
        self.policy
    }

    /// Estimated seconds of queued work at board `i`.
    fn drain(&self, outstanding: &[f64], i: usize) -> f64 {
        outstanding[i] / self.weights[i]
    }

    /// Sample one index from `pool` with probability proportional to its
    /// capacity weight (pool is never empty).
    fn weighted_pick(&mut self, pool: &[usize]) -> usize {
        let total: f64 = pool.iter().map(|&i| self.weights[i]).sum();
        let mut r = self.rng.uniform() * total;
        for &i in pool {
            r -= self.weights[i];
            if r < 0.0 {
                return i;
            }
        }
        *pool.last().expect("nonempty pool")
    }

    /// The full preference order over up boards for one arrival: the
    /// policy's primary choice first, then the fallback order the shared
    /// admission scan walks. Down boards never appear. Returns an empty
    /// order when no board is up (the caller decides what a dead cluster
    /// means).
    ///
    /// `outstanding[i]` is board `i`'s in-flight item count (admitted but
    /// not yet completed) at the arrival instant.
    pub fn preference(&mut self, outstanding: &[f64], up: &[bool]) -> Vec<usize> {
        let n = self.weights.len();
        debug_assert_eq!(outstanding.len(), n);
        debug_assert_eq!(up.len(), n);
        let mut ups: Vec<usize> = (0..n).filter(|&i| up[i]).collect();
        if ups.is_empty() {
            return ups;
        }
        match self.policy {
            DispatchPolicy::RoundRobin => {
                let start = self.rr_next;
                let order: Vec<usize> =
                    (0..n).map(|k| (start + k) % n).filter(|&i| up[i]).collect();
                self.rr_next = (order[0] + 1) % n;
                order
            }
            DispatchPolicy::LeastOutstanding => {
                ups.sort_by(|&a, &b| {
                    self.drain(outstanding, a)
                        .total_cmp(&self.drain(outstanding, b))
                        .then(a.cmp(&b))
                });
                ups
            }
            DispatchPolicy::PowerOfTwo => {
                if ups.len() < 2 {
                    return ups;
                }
                let a = self.weighted_pick(&ups);
                let rest: Vec<usize> = ups.iter().copied().filter(|&i| i != a).collect();
                let b = self.weighted_pick(&rest);
                let (win, lose) = if self
                    .drain(outstanding, b)
                    .total_cmp(&self.drain(outstanding, a))
                    .then(b.cmp(&a))
                    .is_lt()
                {
                    (b, a)
                } else {
                    (a, b)
                };
                let mut order = vec![win, lose];
                let mut tail: Vec<usize> =
                    ups.into_iter().filter(|&i| i != win && i != lose).collect();
                tail.sort_by(|&x, &y| {
                    self.drain(outstanding, x)
                        .total_cmp(&self.drain(outstanding, y))
                        .then(x.cmp(&y))
                });
                order.extend(tail);
                order
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full_order(r: &mut Router, outstanding: &[f64], up: &[bool]) -> Vec<usize> {
        let o = r.preference(outstanding, up);
        assert_eq!(o.len(), up.iter().filter(|&&u| u).count(), "order covers every up board");
        let mut sorted = o.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), o.len(), "no duplicate boards in {o:?}");
        o
    }

    #[test]
    fn parse_roundtrips_and_rejects_garbage() {
        for p in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::LeastOutstanding,
            DispatchPolicy::PowerOfTwo,
        ] {
            assert_eq!(DispatchPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(DispatchPolicy::parse("random").is_err());
    }

    #[test]
    fn round_robin_rotates_and_skips_down_boards() {
        let mut r = Router::new(DispatchPolicy::RoundRobin, vec![1.0; 3], 7).unwrap();
        let up = [true, true, true];
        assert_eq!(full_order(&mut r, &[0.0; 3], &up), vec![0, 1, 2]);
        assert_eq!(full_order(&mut r, &[0.0; 3], &up), vec![1, 2, 0]);
        assert_eq!(full_order(&mut r, &[0.0; 3], &up), vec![2, 0, 1]);
        // Board 0 down: the rotation continues over the survivors.
        let up = [false, true, true];
        assert_eq!(full_order(&mut r, &[0.0; 3], &up), vec![1, 2]);
    }

    #[test]
    fn least_outstanding_normalizes_by_capacity() {
        // Board 1 has half the queue but a tenth of the capacity: more
        // drain time, so board 0 must come first.
        let mut r =
            Router::new(DispatchPolicy::LeastOutstanding, vec![100.0, 10.0], 7).unwrap();
        assert_eq!(full_order(&mut r, &[10.0, 5.0], &[true, true]), vec![0, 1]);
        // Ties break to the lower index.
        assert_eq!(full_order(&mut r, &[10.0, 1.0], &[true, true]), vec![0, 1]);
    }

    #[test]
    fn p2c_prefers_less_drained_of_its_two_samples() {
        let mut r =
            Router::new(DispatchPolicy::PowerOfTwo, vec![50.0, 50.0, 50.0], 7).unwrap();
        // Board 2 is massively backlogged: whichever pair is sampled, it can
        // only win against an even worse board — with the others empty it
        // must never be the primary choice.
        for _ in 0..200 {
            let o = full_order(&mut r, &[0.0, 0.0, 1000.0], &[true, true, true]);
            assert_ne!(o[0], 2, "backlogged board became primary: {o:?}");
        }
    }

    #[test]
    fn p2c_sampling_is_capacity_weighted() {
        let mut r =
            Router::new(DispatchPolicy::PowerOfTwo, vec![80.0, 10.0, 10.0], 7).unwrap();
        // Equal drain everywhere: the drain tie breaks to the lower index,
        // so board 0 leads exactly when it is in the sampled pair. Weighted
        // sampling puts it there ~98% of the time; uniform sampling only
        // ~67% — the threshold separates the two.
        let mut lead0 = 0;
        for _ in 0..1000 {
            if full_order(&mut r, &[0.0; 3], &[true; 3])[0] == 0 {
                lead0 += 1;
            }
        }
        assert!(lead0 > 900, "big board led only {lead0}/1000");
    }

    #[test]
    fn deterministic_given_seed_and_degenerate_inputs() {
        let mut a = Router::new(DispatchPolicy::PowerOfTwo, vec![3.0, 2.0, 1.0], 42).unwrap();
        let mut b = Router::new(DispatchPolicy::PowerOfTwo, vec![3.0, 2.0, 1.0], 42).unwrap();
        for k in 0..100 {
            let out = [k as f64, 2.0, 5.0];
            assert_eq!(
                a.preference(&out, &[true, true, true]),
                b.preference(&out, &[true, true, true])
            );
        }
        // One board up: every policy returns just that board.
        for p in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::LeastOutstanding,
            DispatchPolicy::PowerOfTwo,
        ] {
            let mut r = Router::new(p, vec![1.0, 1.0], 7).unwrap();
            assert_eq!(r.preference(&[0.0, 0.0], &[false, true]), vec![1]);
        }
        // No board up: empty order, the caller's problem.
        let mut r = Router::new(DispatchPolicy::RoundRobin, vec![1.0], 7).unwrap();
        assert!(r.preference(&[0.0], &[false]).is_empty());
        // Bad weights are rejected at construction.
        assert!(Router::new(DispatchPolicy::RoundRobin, vec![], 7).is_err());
        assert!(Router::new(DispatchPolicy::RoundRobin, vec![0.0], 7).is_err());
    }
}
