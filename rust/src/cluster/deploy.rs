//! Wall-clock cluster serving: every up board runs its workload fleets as
//! real [`crate::coordinator::run_fleet`] thread pipelines over synthetic
//! sleep stages, all behind a *single* router thread that paces the merged
//! arrival schedule and walks the same
//! [`Router`](super::router::Router) preference order as the DES twin.
//!
//! Topology:
//!
//! ```text
//! merged schedule ──▶ router thread ──try_send──▶ [board 0 · fleet q's] ─▶ run_fleet × W
//!  (per-board Poisson    (policy order,           [board 1 · fleet q's] ─▶ run_fleet × W
//!   components, sorted)   shed when all full)     ...
//! ```
//!
//! Each (board, workload) fleet keeps its own bounded admission queue
//! ([`crate::coordinator::queue::bounded`] with `admission_cap`); the
//! router's view of per-board load is an atomic in-flight counter bumped on
//! admission and dropped by the fleet's last stage — the live analogue of
//! the DES completion heap. Latencies, throughputs, and the horizon are
//! normalized back by `time_scale`, so a wall report compares directly
//! with its DES twin.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::queue::{bounded, TrySendError};
use crate::coordinator::{run_fleet, StageSpec};
use crate::obs::{Recorder, WallClock};

use super::cosim::{assemble_report, cluster_arrivals, BoardStats};
use super::plan::ClusterPlan;
use super::report::{ClusterServeMode, ClusterServeOptions, ClusterServeReport};
use super::router::Router;

/// Per-item completion record: (completion time since run start, admission
/// → completion latency), both in scaled wall seconds.
type Sink = Arc<Mutex<Vec<(f64, f64)>>>;

/// Build one fleet's synthetic stages: each sleeps for its Eq. 10 service
/// time scaled by `scale`; the last stage of each replica records the
/// item's completion into `sink` and releases the board's in-flight slot.
/// When `rec` is enabled each stage also emits a service span on the
/// shared [`WallClock`] (group = board, replica id offset by `rep_base`
/// so ids stay flat across a board's workload fleets, matching the DES
/// twin), and the last stage emits the departure span; when disabled the
/// closures take the exact original path.
#[allow(clippy::too_many_arguments)]
fn board_stages(
    replica_times: &[Vec<f64>],
    scale: f64,
    sink: &Sink,
    outstanding: &Arc<AtomicUsize>,
    run_start: Instant,
    rec: &Recorder,
    clock: &WallClock,
    group: u32,
    rep_base: u32,
) -> Vec<Vec<StageSpec<(usize, Instant)>>> {
    replica_times
        .iter()
        .enumerate()
        .map(|(r, times)| {
            let p = times.len();
            times
                .iter()
                .enumerate()
                .map(|(s, &t)| {
                    let dt = Duration::from_secs_f64(t * scale);
                    let last = s + 1 == p;
                    let sink = sink.clone();
                    let outstanding = outstanding.clone();
                    let rec = rec.clone();
                    let clock = clock.clone();
                    StageSpec::new(
                        &format!("r{r}s{s}"),
                        Box::new(move || {
                            let rec = rec.clone();
                            let clock = clock.clone();
                            Box::new(move |x: (usize, Instant)| {
                                if rec.enabled() {
                                    let t0 = clock.now_s();
                                    thread::sleep(dt);
                                    let t1 = clock.now_s();
                                    let rid = rep_base + r as u32;
                                    rec.stage(group, x.0 as u64, rid, s as u32, t0, t1);
                                    if last {
                                        sink.lock().unwrap().push((
                                            run_start.elapsed().as_secs_f64(),
                                            x.1.elapsed().as_secs_f64(),
                                        ));
                                        outstanding.fetch_sub(1, Ordering::Relaxed);
                                        rec.depart(group, x.0 as u64, rid, t1);
                                    }
                                } else {
                                    thread::sleep(dt);
                                    if last {
                                        sink.lock().unwrap().push((
                                            run_start.elapsed().as_secs_f64(),
                                            x.1.elapsed().as_secs_f64(),
                                        ));
                                        outstanding.fetch_sub(1, Ordering::Relaxed);
                                    }
                                }
                                x
                            })
                        }),
                    )
                })
                .collect()
        })
        .collect()
}

/// Deploy a [`ClusterPlan`] on real threads. See the module docs for the
/// topology; shed/offered accounting matches the DES twin (first-choice
/// board charged, shed only when every up board's queue refuses the item).
pub fn deploy_cluster(
    cp: &ClusterPlan,
    opts: &ClusterServeOptions,
) -> Result<ClusterServeReport> {
    deploy_cluster_recorded(cp, opts, &Recorder::off())
}

/// [`deploy_cluster`] with span recording: board `b` traces under group
/// `b` on the shared [`WallClock`] — the router emits admit/shed spans,
/// stage threads emit service and departure spans — and the assembled
/// report carries the frozen registry snapshot.
pub fn deploy_cluster_recorded(
    cp: &ClusterPlan,
    opts: &ClusterServeOptions,
    rec: &Recorder,
) -> Result<ClusterServeReport> {
    anyhow::ensure!(opts.images >= 1, "need at least one image per workload");
    anyhow::ensure!(opts.queue_cap >= 1, "queue capacity must be >= 1");
    anyhow::ensure!(opts.admission_cap >= 1, "admission capacity must be >= 1");
    anyhow::ensure!(opts.time_scale > 0.0, "time_scale must be positive");
    for d in &opts.disabled {
        anyhow::ensure!(
            cp.boards.iter().any(|b| &b.name == d),
            "cannot disable unknown board {d:?}"
        );
    }
    let up: Vec<bool> =
        cp.boards.iter().map(|b| !opts.disabled.contains(&b.name)).collect();
    anyhow::ensure!(up.iter().any(|&u| u), "every board is disabled");

    let n = cp.boards.len();
    let weights: Vec<f64> = cp.boards.iter().map(|b| b.plan.capacity()).collect();
    let mut router = Router::new(opts.policy, weights, opts.seed)?;
    let schedule = cluster_arrivals(cp, opts);

    // Per-board plumbing: one (queue → run_fleet thread) pair per workload
    // fleet, one in-flight counter and completion sink per board. Down
    // boards get no threads — `None` queues the router can never pick.
    let run_start = Instant::now();
    let clock = WallClock::start();
    let mut outstanding: Vec<Arc<AtomicUsize>> = Vec::with_capacity(n);
    let mut sinks: Vec<Sink> = Vec::with_capacity(n);
    let mut txs = Vec::with_capacity(n);
    let mut handles = Vec::with_capacity(n);
    for (b, (entry, &up)) in cp.boards.iter().zip(&up).enumerate() {
        let inflight = Arc::new(AtomicUsize::new(0));
        let sink: Sink = Arc::new(Mutex::new(Vec::new()));
        let mut board_txs = Vec::new();
        let mut board_handles = Vec::new();
        let mut rep_base = 0u32;
        for times in entry.plan.fleet_stage_times() {
            let fleet_reps = times.len() as u32;
            if !up {
                board_txs.push(None);
                rep_base += fleet_reps;
                continue;
            }
            let stages = board_stages(
                &times,
                opts.time_scale,
                &sink,
                &inflight,
                run_start,
                rec,
                &clock,
                b as u32,
                rep_base,
            );
            rep_base += fleet_reps;
            let (tx, rx) = bounded::<(usize, Instant)>(opts.admission_cap);
            let queue_cap = opts.queue_cap;
            board_txs.push(Some(tx));
            board_handles.push(thread::spawn(move || {
                run_fleet(stages, queue_cap, 1, std::iter::from_fn(move || rx.recv()))
            }));
        }
        outstanding.push(inflight);
        sinks.push(sink);
        txs.push(board_txs);
        handles.push(board_handles);
    }

    // The router thread: pace the merged schedule in scaled real time and
    // walk the policy's preference order, shedding only when every up
    // board's fleet queue refuses the item.
    let mut offered = vec![0usize; n];
    let mut shed = vec![0usize; n];
    let mut load = vec![0.0f64; n];
    for (seq, &(a, t)) in schedule.iter().enumerate() {
        let at = a * opts.time_scale;
        let now = run_start.elapsed().as_secs_f64();
        if at > now {
            thread::sleep(Duration::from_secs_f64(at - now));
        }
        for (l, o) in load.iter_mut().zip(&outstanding) {
            *l = o.load(Ordering::Relaxed) as f64;
        }
        let prefs = router.preference(&load, &up);
        let first = prefs[0];
        offered[first] += 1;
        // Front-door timestamp taken BEFORE the enqueue: once the item is
        // in a board's queue a stage thread may stamp its service span,
        // and the admission must sort before it in the item's chain.
        let at_s = if rec.enabled() { clock.now_s() } else { 0.0 };
        let mut admitted = false;
        for &b in &prefs {
            let Some(tx) = &txs[b][t] else { continue };
            match tx.try_send((seq, Instant::now())) {
                Ok(()) => {
                    outstanding[b].fetch_add(1, Ordering::Relaxed);
                    rec.admit(b as u32, seq as u64, at_s);
                    admitted = true;
                    break;
                }
                Err(TrySendError::Full(_)) => {}
                Err(TrySendError::Closed(_)) => txs[b][t] = None, // fleet died
            }
        }
        if !admitted {
            shed[first] += 1;
            rec.shed(first as u32, seq as u64, at_s);
        }
    }
    drop(txs); // closes every fleet queue; fleets drain and finish

    // Join the fleets and fold each board's tallies into model time.
    let mut stats = Vec::with_capacity(n);
    for (((board_handles, sink), &offered), &shed) in
        handles.into_iter().zip(&sinks).zip(&offered).zip(&shed)
    {
        let mut admitted = 0usize;
        let mut max_busy = 0.0f64;
        for handle in board_handles {
            let (_, fleet) = handle.join().expect("board fleet panicked");
            admitted += fleet.images;
            for rep in &fleet.replicas {
                for stage in &rep.stages {
                    max_busy = max_busy.max(stage.busy.as_secs_f64());
                }
            }
        }
        let completions = sink.lock().unwrap();
        anyhow::ensure!(
            completions.len() == admitted,
            "board lost completions: {} recorded vs {admitted} served",
            completions.len()
        );
        let horizon = completions.iter().map(|c| c.0).fold(0.0, f64::max);
        stats.push(BoardStats {
            offered,
            admitted,
            shed,
            makespan: horizon / opts.time_scale,
            latencies: completions.iter().map(|c| c.1 / opts.time_scale).collect(),
            utilization: if horizon > 0.0 { max_busy / horizon } else { 0.0 },
        });
    }
    let served: usize = stats.iter().map(|s| s.admitted).sum();
    let lost: usize = stats.iter().map(|s| s.shed).sum();
    anyhow::ensure!(
        served + lost == schedule.len(),
        "front door lost items: {served} served + {lost} shed != {} offered",
        schedule.len()
    );

    Ok(assemble_report(
        cp,
        &up,
        stats,
        ClusterServeMode::Synthetic { time_scale: opts.time_scale },
        opts.policy,
        rec,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::spec::{BoardSpec, ClusterSpec};
    use crate::config::Config;
    use crate::tenancy::TenantSpec;

    fn small_plan() -> ClusterPlan {
        let spec = ClusterSpec {
            boards: vec![BoardSpec::new(4, 4), BoardSpec::new(2, 6)],
            workloads: vec![TenantSpec::new("alexnet", 30.0)],
            max_replicas: 2,
        };
        ClusterPlan::compile(&spec, &Config::default()).unwrap()
    }

    #[test]
    fn deploy_conserves_arrivals_across_the_cluster() {
        let cp = small_plan();
        let opts = ClusterServeOptions {
            images: 16,
            time_scale: 0.02,
            ..Default::default()
        };
        let report = cp.deploy(&opts).unwrap();
        assert_eq!(report.boards.len(), 2);
        assert_eq!(report.images + report.shed, 16);
        let offered: usize = report.boards.iter().map(|b| b.offered).sum();
        assert_eq!(offered, 16);
        assert!(report.wall_s > 0.0);
        assert!(report.throughput > 0.0);
    }

    #[test]
    fn disabling_a_board_routes_everything_to_the_survivor() {
        let cp = small_plan();
        let opts = ClusterServeOptions {
            images: 12,
            time_scale: 0.02,
            admission_cap: 16,
            disabled: vec![cp.boards[0].name.clone()],
            ..Default::default()
        };
        let report = cp.deploy(&opts).unwrap();
        let down = &report.boards[0];
        assert!(!down.up);
        assert_eq!(down.admitted + down.offered + down.shed, 0);
        assert_eq!(report.boards[1].admitted + report.boards[1].shed, 12);
    }
}
