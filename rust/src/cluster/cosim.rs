//! Deterministic DES co-simulation of a whole cluster: seeded per-board
//! arrival streams merged at the front door, routed by a
//! [`Router`](super::router::Router) over per-board bounded admission
//! queues, each board running the exact blocking tandem-queue recurrence of
//! [`crate::tenancy::simulate_tenant_fleet`].
//!
//! The per-board engine runs in *streaming* form on the shared event core
//! ([`crate::simulator::engine`], DESIGN.md §15) — arena-allocated bounded
//! departure rings plus admission/completion heaps instead of full
//! per-item history — so state is O(boards · stages · queue_cap) and a run
//! costs O(arrivals · log) time. That is what makes ≥1M-arrival cluster
//! runs practical where a full-history engine's O(n²) front-door scan is
//! not; a unit test pins this engine to bit-identical results against the
//! tenancy engine on a single board, and the differential suite
//! (`tests/engine_core.rs`) pins both against the retained reference
//! recurrences.

use anyhow::Result;

use crate::api::LatencyReport;
use crate::obs::{attrib_for, pool_latencies, EngineProf, PredictedTimes, Recorder};
use crate::simulator::arrivals::{poisson_arrivals, uniform_arrivals};
use crate::simulator::engine::{tandem_step, EventHeap, RingArena, RingId};

use super::plan::ClusterPlan;
use super::report::{
    BoardServeReport, ClusterServeMode, ClusterServeOptions, ClusterServeReport,
};
use super::router::{DispatchPolicy, Router};

/// Per-workload seed stride for a board's component arrival streams:
/// `7919²`, the square of the per-board stride
/// ([`ClusterServeOptions::board_seed`]), so `run_seed + r` (harness
/// reps), `+ 7919·b` (boards) and `+ 7919²·t` (workloads) form a
/// mixed-radix encoding — pairwise distinct for `r, b < 7919` and any
/// workload count below `2⁶⁴/7919²` (a unit test pins this scheme).
pub(crate) const WORKLOAD_SEED_STRIDE: u64 = 7919 * 7919;

/// One (board, workload) fleet: per-replica departure rings (one
/// [`RingId`] per stage into the run's shared arena) plus the fleet's
/// bounded front-door admission queue (stage-0 start times of admitted
/// items).
#[derive(Debug)]
struct FleetState {
    replicas: Vec<Vec<RingId>>,
    waiting: EventHeap,
}

/// What one board did during a cluster DES run.
#[derive(Debug, Clone, PartialEq)]
pub struct BoardSimOutcome {
    /// Arrivals whose first-choice board was this one.
    pub offered: usize,
    /// Items served here (first-choice or fallback admissions).
    pub admitted: usize,
    /// Sheds charged here (first choice here, every up board full).
    pub shed: usize,
    /// Last departure on this board (0.0 when idle all run).
    pub makespan: f64,
    /// Per-admitted-item end-to-end latency, in admission order.
    pub latencies: Vec<f64>,
    /// Items dispatched to each `[fleet][replica]`.
    pub dispatched: Vec<Vec<usize>>,
}

/// Run the cluster DES over an explicit merged arrival schedule.
///
/// * `board_fleets[b][f][r]` — board `b`, workload-fleet `f`, replica `r`'s
///   per-stage service times (what [`ClusterPlan`]'s `fleet_stage_times`
///   yields; every board must carry the same number of fleets).
/// * `weights[b]` — board capacities (router drain denominators / p2c
///   sampling weights).
/// * `up[b]` — boards in rotation; down boards never receive work.
/// * `arrivals` — the merged schedule: `(time, workload)` pairs in
///   non-decreasing time order.
/// * `run_seed` — the run seed; only the router's p2c sampling stream draws
///   from it (XOR [`super::router::DISPATCH_SALT`]).
///
/// Admission walks the router's preference order and admits at the first
/// board whose fleet-`t` admission queue has space; an arrival is shed only
/// when every up board is full, and the shed is charged to the first-choice
/// board. Exposed (not just an internal of [`simulate_cluster`]) so tests
/// can drive synthetic service-time matrices directly.
#[allow(clippy::too_many_arguments)]
pub fn simulate_cluster_streams(
    board_fleets: &[Vec<Vec<Vec<f64>>>],
    weights: &[f64],
    up: &[bool],
    arrivals: &[(f64, usize)],
    policy: DispatchPolicy,
    queue_cap: usize,
    admission_cap: usize,
    run_seed: u64,
) -> Result<Vec<BoardSimOutcome>> {
    simulate_cluster_streams_recorded(
        board_fleets,
        weights,
        up,
        arrivals,
        policy,
        queue_cap,
        admission_cap,
        run_seed,
        &Recorder::off(),
    )
}

/// [`simulate_cluster_streams`] with span recording: arrival `i` (its
/// index in the merged schedule) traces under the board that settled it —
/// group = board index, so a cluster trace renders as one timeline of
/// boards → replicas → stages. Replica ids are flattened across a board's
/// workload fleets (fleet 0's replicas first), keeping per-item stage
/// chains consecutive for [`crate::obs::audit_chains`]. Sheds are charged
/// to the first-choice board, mirroring the report. With
/// [`Recorder::off`] this is exactly [`simulate_cluster_streams`].
#[allow(clippy::too_many_arguments)]
pub fn simulate_cluster_streams_recorded(
    board_fleets: &[Vec<Vec<Vec<f64>>>],
    weights: &[f64],
    up: &[bool],
    arrivals: &[(f64, usize)],
    policy: DispatchPolicy,
    queue_cap: usize,
    admission_cap: usize,
    run_seed: u64,
    rec: &Recorder,
) -> Result<Vec<BoardSimOutcome>> {
    let n = board_fleets.len();
    anyhow::ensure!(n >= 1, "cluster DES needs at least one board");
    anyhow::ensure!(weights.len() == n && up.len() == n, "board vectors disagree on length");
    anyhow::ensure!(up.iter().any(|&u| u), "cluster DES needs at least one board up");
    anyhow::ensure!(queue_cap >= 1, "queue_cap must be >= 1");
    anyhow::ensure!(admission_cap >= 1, "admission_cap must be >= 1");
    let fleets = board_fleets[0].len();
    for (b, bf) in board_fleets.iter().enumerate() {
        anyhow::ensure!(
            bf.len() == fleets,
            "board {b} has {} fleets, board 0 has {fleets}",
            bf.len()
        );
        for (f, reps) in bf.iter().enumerate() {
            anyhow::ensure!(!reps.is_empty(), "board {b} fleet {f} has no replicas");
            anyhow::ensure!(
                reps.iter().all(|t| !t.is_empty()),
                "board {b} fleet {f} has an empty stage-time vector"
            );
        }
    }

    let mut prof = EngineProf::start("cluster", rec);
    let mut router = Router::new(policy, weights.to_vec(), run_seed)?;
    // One arena backs every departure ring of the run (DESIGN.md §15).
    let mut arena = RingArena::new();
    let mut boards: Vec<Vec<FleetState>> = board_fleets
        .iter()
        .map(|bf| {
            bf.iter()
                .map(|reps| FleetState {
                    replicas: reps
                        .iter()
                        .map(|t| t.iter().map(|_| arena.alloc(queue_cap + 1)).collect())
                        .collect(),
                    waiting: EventHeap::default(),
                })
                .collect()
        })
        .collect();
    let mut completions: Vec<EventHeap> = (0..n).map(|_| EventHeap::default()).collect();
    let mut out: Vec<BoardSimOutcome> = board_fleets
        .iter()
        .map(|bf| BoardSimOutcome {
            offered: 0,
            admitted: 0,
            shed: 0,
            makespan: 0.0,
            latencies: Vec::new(),
            dispatched: bf.iter().map(|reps| vec![0usize; reps.len()]).collect(),
        })
        .collect();
    let mut outstanding = vec![0.0f64; n];
    // Flattened replica ids per (board, fleet): fleet f's replica q traces
    // as replica `rep_base[b][f] + q`.
    let rep_base: Vec<Vec<u32>> = board_fleets
        .iter()
        .map(|bf| {
            let mut off = 0u32;
            bf.iter()
                .map(|reps| {
                    let base = off;
                    off += reps.len() as u32;
                    base
                })
                .collect()
        })
        .collect();

    for (i, &(a, t)) in arrivals.iter().enumerate() {
        anyhow::ensure!(t < fleets, "arrival for workload {t}, cluster has {fleets}");
        for (b, heap) in completions.iter_mut().enumerate() {
            outstanding[b] = heap.live_after(a) as f64;
        }
        let prefs = router.preference(&outstanding, up);
        let first = prefs[0];
        out[first].offered += 1;

        let admit = prefs
            .iter()
            .copied()
            .find(|&b| boards[b][t].waiting.live_after(a) < admission_cap);
        let Some(b) = admit else {
            out[first].shed += 1;
            rec.shed(first as u32, i as u64, a);
            continue;
        };

        // Join-earliest-start dispatch within the chosen fleet, then the
        // exact blocking recurrence of `simulate_tenant_fleet` over the
        // bounded departure rings.
        let FleetState { replicas, waiting } = &mut boards[b][t];
        if rec.enabled() {
            rec.admit(b as u32, i as u64, a);
            let depth = waiting.live_after(a) as f64;
            rec.gauge_max(&format!("queue_depth_peak/g{b}"), depth);
        }
        let q = (0..replicas.len())
            .min_by(|&x, &y| {
                let ex = arena.back(replicas[x][0]).unwrap_or(0.0).max(a);
                let ey = arena.back(replicas[y][0]).unwrap_or(0.0).max(a);
                ex.total_cmp(&ey)
            })
            .expect("nonempty fleet");
        let dep = tandem_step(
            &mut arena,
            &replicas[q],
            &board_fleets[b][t][q],
            a,
            |s, start, _svc, dep| {
                if s == 0 {
                    waiting.push(start);
                }
                if rec.enabled() {
                    let rid = rep_base[b][t] + q as u32;
                    rec.stage(b as u32, i as u64, rid, s as u32, start, dep);
                }
            },
        );
        rec.depart(b as u32, i as u64, rep_base[b][t] + q as u32, dep);
        out[b].dispatched[t][q] += 1;
        out[b].admitted += 1;
        out[b].latencies.push(dep - a);
        out[b].makespan = out[b].makespan.max(dep);
        completions[b].push(dep);
    }

    debug_assert_eq!(
        out.iter().map(|o| o.admitted + o.shed).sum::<usize>(),
        arrivals.len(),
        "cluster DES lost items"
    );
    // Engine profile (DESIGN.md §14): one event per front-door decision
    // plus one per (item, stage) executed; heap traffic comes from the
    // write-only tallies on the admission/completion heaps, and ring
    // occupancy from the arena's high-water mark.
    if prof.active() {
        prof.events = arrivals.len() as u64;
        for (b, bf) in board_fleets.iter().enumerate() {
            for (t, reps) in bf.iter().enumerate() {
                for (q, times) in reps.iter().enumerate() {
                    prof.events += out[b].dispatched[t][q] as u64 * times.len() as u64;
                }
            }
        }
        for (fleets, comp) in boards.iter().zip(&completions) {
            for fleet in fleets {
                prof.heap_pushes += fleet.waiting.pushes;
                prof.heap_pops += fleet.waiting.pops;
                prof.heap_peak = prof.heap_peak.max(fleet.waiting.peak);
            }
            prof.heap_pushes += comp.pushes;
            prof.heap_pops += comp.pops;
            prof.heap_peak = prof.heap_peak.max(comp.peak);
        }
        prof.ring_peak = arena.peak();
        prof.flush(rec);
    }
    Ok(out)
}

/// Integer apportionment by largest remainder: split `total` across
/// `shares` (summing to ~1) so the parts sum to exactly `total`.
fn apportion(total: usize, shares: &[f64]) -> Vec<usize> {
    let mut parts: Vec<usize> = shares.iter().map(|s| (total as f64 * s) as usize).collect();
    let mut order: Vec<usize> = (0..shares.len()).collect();
    order.sort_by(|&x, &y| {
        let fx = total as f64 * shares[x] - parts[x] as f64;
        let fy = total as f64 * shares[y] - parts[y] as f64;
        fy.total_cmp(&fx).then(x.cmp(&y))
    });
    let assigned: usize = parts.iter().sum();
    for &i in order.iter().take(total.saturating_sub(assigned)) {
        parts[i] += 1;
    }
    parts
}

/// The cluster's merged front-door schedule: per workload, one seeded
/// Poisson component stream per board at `rate · share_b` (their
/// superposition is again Poisson at the full rate), merged and sorted.
/// Board `b`'s workload-`t` component draws from
/// `board_seed(b) + 7919²·t` (`WORKLOAD_SEED_STRIDE`) — a mixed-radix
/// extension of the tenant/board scheme, collision-free against both the
/// per-board `7919·b` stride and the harness's per-rep `+r` offsets for
/// all in-range indices (the old `+t` offset collided with rep `r = t`).
/// Disabled boards still contribute their components: taking a board out
/// of rotation must not change the offered traffic.
pub fn cluster_arrivals(cp: &ClusterPlan, opts: &ClusterServeOptions) -> Vec<(f64, usize)> {
    let shares: Vec<f64> = cp.boards.iter().map(|b| b.rate_share).collect();
    let mut merged: Vec<(f64, usize)> = Vec::with_capacity(opts.images * cp.workloads.len());
    for (t, w) in cp.workloads.iter().enumerate() {
        let counts = apportion(opts.images, &shares);
        for (b, (entry, &count)) in cp.boards.iter().zip(&counts).enumerate() {
            if count == 0 {
                continue;
            }
            let rate = w.rate_hz * shares[b];
            let stream = if opts.uniform_arrivals {
                uniform_arrivals(rate, count)
            } else {
                let seed = opts
                    .board_seed(entry.seed, b)
                    .wrapping_add(WORKLOAD_SEED_STRIDE.wrapping_mul(t as u64));
                poisson_arrivals(rate, count, seed)
            };
            merged.extend(stream.into_iter().map(|a| (a, t)));
        }
    }
    merged.sort_by(|x, y| x.0.total_cmp(&y.0).then(x.1.cmp(&y.1)));
    merged
}

/// DES-serve a [`ClusterPlan`]: generate the merged seeded schedule, run
/// the streaming engine, and assemble the unified [`ClusterServeReport`].
pub fn simulate_cluster(
    cp: &ClusterPlan,
    opts: &ClusterServeOptions,
) -> Result<ClusterServeReport> {
    simulate_cluster_recorded(cp, opts, &Recorder::off())
}

/// [`simulate_cluster`] with span recording (see
/// [`simulate_cluster_streams_recorded`] for the span model) plus the
/// registry's metric vocabulary: per-stage `occupancy` gauges (busy time
/// over the board's horizon — their per-board max equals the report's
/// utilization column) and the pooled `latency` histogram.
pub fn simulate_cluster_recorded(
    cp: &ClusterPlan,
    opts: &ClusterServeOptions,
    rec: &Recorder,
) -> Result<ClusterServeReport> {
    anyhow::ensure!(opts.images >= 1, "need at least one image per workload");
    for d in &opts.disabled {
        anyhow::ensure!(
            cp.boards.iter().any(|b| &b.name == d),
            "cannot disable unknown board {d:?}"
        );
    }
    let up: Vec<bool> =
        cp.boards.iter().map(|b| !opts.disabled.contains(&b.name)).collect();
    anyhow::ensure!(up.iter().any(|&u| u), "every board is disabled");

    let board_fleets: Vec<Vec<Vec<Vec<f64>>>> =
        cp.boards.iter().map(|b| b.plan.fleet_stage_times()).collect();
    let weights: Vec<f64> = cp.boards.iter().map(|b| b.plan.capacity()).collect();
    let arrivals = cluster_arrivals(cp, opts);
    let outcomes = simulate_cluster_streams_recorded(
        &board_fleets,
        &weights,
        &up,
        &arrivals,
        opts.policy,
        opts.queue_cap,
        opts.admission_cap,
        opts.seed,
        rec,
    )?;

    let stats = outcomes
        .into_iter()
        .zip(&board_fleets)
        .enumerate()
        .map(|(b, (o, fleets))| {
            // Busiest stage's busy fraction over this board's horizon: each
            // stage's busy time is its dispatch count times its Eq. 10
            // service time.
            let utilization = if o.makespan > 0.0 {
                fleets
                    .iter()
                    .zip(&o.dispatched)
                    .flat_map(|(reps, counts)| {
                        reps.iter().zip(counts).flat_map(|(times, &count)| {
                            times.iter().map(move |t| t * count as f64 / o.makespan)
                        })
                    })
                    .fold(0.0, f64::max)
            } else {
                0.0
            };
            if rec.enabled() && o.makespan > 0.0 {
                let mut rid = 0u32;
                for (reps, counts) in fleets.iter().zip(&o.dispatched) {
                    for (times, &count) in reps.iter().zip(counts) {
                        for (s, t) in times.iter().enumerate() {
                            let occ = t * count as f64 / o.makespan;
                            rec.gauge_set(&format!("occupancy/g{b}r{rid}s{s}"), occ);
                        }
                        rid += 1;
                    }
                }
            }
            BoardStats {
                offered: o.offered,
                admitted: o.admitted,
                shed: o.shed,
                makespan: o.makespan,
                latencies: o.latencies,
                utilization,
            }
        })
        .collect();
    Ok(assemble_report(cp, &up, stats, ClusterServeMode::Des, opts.policy, rec))
}

/// Backend-neutral per-board tallies, all in *model* seconds (the wall
/// twin normalizes by `time_scale` before assembly).
pub(crate) struct BoardStats {
    pub offered: usize,
    pub admitted: usize,
    pub shed: usize,
    pub makespan: f64,
    pub latencies: Vec<f64>,
    pub utilization: f64,
}

/// Shared report assembly for both execution twins: merge per-board
/// tallies over the cluster horizon into one [`ClusterServeReport`]. The
/// cluster-wide latency pool is built by [`pool_latencies`] — one merge
/// shared with fleet and tenancy assembly — and, when `rec` is enabled,
/// its histogram lands in the registry under `"latency"` and the frozen
/// snapshot in the report.
pub(crate) fn assemble_report(
    cp: &ClusterPlan,
    up: &[bool],
    stats: Vec<BoardStats>,
    mode: ClusterServeMode,
    policy: DispatchPolicy,
    rec: &Recorder,
) -> ClusterServeReport {
    let wall_s = stats.iter().map(|o| o.makespan).fold(0.0, f64::max);
    let rate = |count: usize| if wall_s > 0.0 { count as f64 / wall_s } else { 0.0 };
    let (all_latencies, latency_hist) =
        pool_latencies(stats.iter().map(|o| o.latencies.as_slice()));
    if rec.enabled() {
        rec.observe_hist("latency", &latency_hist);
        rec.gauge_set("wall_s", wall_s);
    }
    let boards: Vec<BoardServeReport> = cp
        .boards
        .iter()
        .zip(up)
        .zip(stats)
        .map(|((entry, &up), o)| {
            BoardServeReport {
                name: entry.name.clone(),
                platform: entry.plan.platform().to_string(),
                budget: entry.plan.budget_display(),
                pipeline: entry.plan.partition_display(),
                capacity: entry.plan.capacity(),
                rate_share: entry.rate_share,
                up,
                offered: o.offered,
                admitted: o.admitted,
                shed: o.shed,
                throughput: rate(o.admitted),
                latency: LatencyReport::from_latencies(&o.latencies),
                utilization: o.utilization,
            }
        })
        .collect();

    let images: usize = boards.iter().map(|b| b.admitted).sum();
    let shed: usize = boards.iter().map(|b| b.shed).sum();
    // Attribution (DESIGN.md §14) is a DES-twin feature: spans are in model
    // seconds there, directly comparable to Eq. 10. Wall-twin traces carry
    // scaled sleep times; `pipeit attrib --trace` decomposes them offline.
    let attrib = if matches!(mode, ClusterServeMode::Des) && rec.enabled() {
        let mut pred = PredictedTimes::new();
        for (b, entry) in cp.boards.iter().enumerate() {
            let mut rid = 0u32;
            for reps in entry.plan.fleet_stage_times() {
                for times in reps {
                    pred.insert(b as u32, rid, times);
                    rid += 1;
                }
            }
        }
        attrib_for(rec, &pred, Vec::new())
    } else {
        None
    };
    ClusterServeReport {
        mode,
        policy,
        wall_s,
        images,
        shed,
        throughput: rate(images),
        capacity: cp.capacity(),
        latency: LatencyReport::from_latencies(&all_latencies),
        boards,
        metrics: rec.snapshot(),
        attrib,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tenancy::simulate_tenant_fleet;

    /// One board, one fleet: `[replica][stage]` times.
    fn single_board(reps: Vec<Vec<f64>>) -> Vec<Vec<Vec<Vec<f64>>>> {
        vec![vec![reps]]
    }

    #[test]
    fn single_board_matches_the_tenancy_reference_engine_bit_for_bit() {
        // A loaded two-replica fleet with unequal stages, arrivals fast
        // enough to exercise blocking, waiting, and shedding.
        let reps = vec![vec![0.03, 0.01], vec![0.05]];
        let arrivals: Vec<f64> = poisson_arrivals(60.0, 500, 42);
        let reference = simulate_tenant_fleet(&reps, &arrivals, 2, 3);
        let schedule: Vec<(f64, usize)> = arrivals.iter().map(|&a| (a, 0)).collect();
        let outcomes = simulate_cluster_streams(
            &single_board(reps),
            &[30.0],
            &[true],
            &schedule,
            DispatchPolicy::LeastOutstanding,
            2,
            3,
            7,
        )
        .unwrap();
        let o = &outcomes[0];
        assert_eq!(o.admitted, reference.admitted);
        assert_eq!(o.shed, reference.shed);
        assert_eq!(o.latencies, reference.latencies, "recurrences diverged");
        assert_eq!(o.dispatched[0], reference.dispatched);
        assert_eq!(o.makespan, reference.makespan);
        assert!(o.shed > 0, "test should exercise the admission bound");
    }

    #[test]
    fn same_seed_runs_are_bit_identical_and_conserve_items() {
        let boards = vec![
            vec![vec![vec![0.02, 0.01]]],
            vec![vec![vec![0.04]]],
            vec![vec![vec![0.03, 0.03]]],
        ];
        let arrivals: Vec<(f64, usize)> =
            poisson_arrivals(90.0, 2_000, 11).into_iter().map(|a| (a, 0)).collect();
        let run = || {
            simulate_cluster_streams(
                &boards,
                &[33.0, 25.0, 16.0],
                &[true; 3],
                &arrivals,
                DispatchPolicy::PowerOfTwo,
                2,
                4,
                7,
            )
            .unwrap()
        };
        let a = run();
        assert_eq!(a, run(), "same-seed cluster DES must be bit-identical");
        let offered: usize = a.iter().map(|o| o.offered).sum();
        let settled: usize = a.iter().map(|o| o.admitted + o.shed).sum();
        assert_eq!(offered, arrivals.len());
        assert_eq!(settled, arrivals.len());
    }

    #[test]
    fn fallback_admission_sheds_only_when_every_up_board_is_full() {
        // Burst of simultaneous arrivals: board 0 is glacial (everything
        // past the first item waits), so arrivals spill to board 1; sheds
        // start only once both admission queues are exhausted.
        let boards = vec![vec![vec![vec![100.0]]], vec![vec![vec![100.0]]]];
        let cap = 3;
        let burst: Vec<(f64, usize)> = (0..10).map(|_| (0.0, 0)).collect();
        let outcomes = simulate_cluster_streams(
            &boards,
            &[1.0, 1.0],
            &[true, true],
            &burst,
            DispatchPolicy::LeastOutstanding,
            1,
            cap,
            7,
        )
        .unwrap();
        // Per board: `cap` waiting items plus the one in service.
        assert_eq!(outcomes[0].admitted, cap + 1);
        assert_eq!(outcomes[1].admitted, cap + 1);
        assert_eq!(outcomes.iter().map(|o| o.shed).sum::<usize>(), 10 - 2 * (cap + 1));
    }

    #[test]
    fn down_boards_never_receive_work() {
        let boards = vec![vec![vec![vec![0.01]]], vec![vec![vec![0.01]]]];
        let arrivals: Vec<(f64, usize)> =
            poisson_arrivals(50.0, 300, 3).into_iter().map(|a| (a, 0)).collect();
        let outcomes = simulate_cluster_streams(
            &boards,
            &[100.0, 100.0],
            &[false, true],
            &arrivals,
            DispatchPolicy::RoundRobin,
            2,
            8,
            7,
        )
        .unwrap();
        assert_eq!(outcomes[0].admitted + outcomes[0].offered + outcomes[0].shed, 0);
        assert_eq!(outcomes[1].admitted + outcomes[1].shed, 300);
    }

    #[test]
    fn apportion_is_exact_and_remainder_aware() {
        assert_eq!(apportion(10, &[0.5, 0.5]), vec![5, 5]);
        assert_eq!(apportion(10, &[0.55, 0.45]), vec![6, 4]);
        assert_eq!(apportion(1, &[0.4, 0.6]), vec![0, 1]);
        let parts = apportion(997, &[0.21, 0.33, 0.46]);
        assert_eq!(parts.iter().sum::<usize>(), 997);
    }

    #[test]
    fn merged_schedule_is_sorted_and_complete_regardless_of_disabling() {
        use crate::cluster::spec::{BoardSpec, ClusterSpec};
        use crate::config::Config;
        use crate::tenancy::TenantSpec;

        let spec = ClusterSpec::new(
            vec![BoardSpec::new(4, 4), BoardSpec::new(2, 6)],
            vec![TenantSpec::new("alexnet", 40.0)],
        );
        let cp = ClusterPlan::compile(&spec, &Config::default()).unwrap();
        let opts = ClusterServeOptions { images: 501, ..Default::default() };
        let schedule = cluster_arrivals(&cp, &opts);
        assert_eq!(schedule.len(), 501);
        assert!(schedule.windows(2).all(|w| w[0].0 <= w[1].0), "unsorted schedule");
        // Disabling is a router-side decision: offered traffic is identical.
        let drilled = ClusterServeOptions {
            disabled: vec![cp.boards[0].name.clone()],
            ..opts
        };
        assert_eq!(schedule, cluster_arrivals(&cp, &drilled));
    }
}
