//! The cluster serving artifact: a schema-versioned, serializable
//! [`ClusterPlan`] embedding one ordinary per-board plan — a single-network
//! [`Plan`] or a co-serving [`MultiPlan`] — per board, plus the planner's
//! traffic shares. Like its per-board constituents, a saved artifact
//! reloads and behaves identically: save → load → simulate is lossless and
//! bit-identical, and the DES / wall-clock twins
//! ([`ClusterPlan::simulate`] / [`ClusterPlan::deploy`]) read only what the
//! artifact carries.

use std::path::Path;

use anyhow::{Context, Result};

use crate::api::{Plan, PlanSpec, Strategy};
use crate::config::Config;
use crate::tenancy::{MultiPlan, TenantSpec};
use crate::util::json::Json;

use super::report::{ClusterServeOptions, ClusterServeReport};
use super::spec::ClusterSpec;

/// ClusterPlan schema version written by [`ClusterPlan::save`] and required
/// by [`ClusterPlan::load`].
pub const CLUSTER_PLAN_VERSION: usize = 1;

/// One workload served by the cluster: a zoo network with its cluster-wide
/// offered arrival rate.
#[derive(Debug, Clone, PartialEq)]
pub struct Workload {
    pub name: String,
    pub network: String,
    /// Cluster-wide offered Poisson rate (images/s), split across boards by
    /// each board's [`BoardEntry::rate_share`].
    pub rate_hz: f64,
}

/// The per-board design inside a [`ClusterPlan`]: an ordinary single-network
/// [`Plan`] when the cluster serves one workload, or a [`MultiPlan`] when
/// every board co-serves several.
#[derive(Debug, Clone, PartialEq)]
pub enum BoardPlan {
    Single(Plan),
    Multi(MultiPlan),
}

impl BoardPlan {
    /// The board's planned Eq. 12 capacity (imgs/s, summed over fleets).
    pub fn capacity(&self) -> f64 {
        match self {
            BoardPlan::Single(p) => p.throughput,
            BoardPlan::Multi(mp) => mp.tenants.iter().map(|t| t.plan.throughput).sum(),
        }
    }

    /// Platform name the board was compiled for.
    pub fn platform(&self) -> &str {
        match self {
            BoardPlan::Single(p) => &p.platform,
            BoardPlan::Multi(mp) => &mp.platform,
        }
    }

    /// `4B+4s` display of the board's core budget.
    pub fn budget_display(&self) -> String {
        match self {
            BoardPlan::Single(p) => format!("{}B+{}s", p.big, p.small),
            BoardPlan::Multi(mp) => format!("{}B+{}s", mp.big, mp.small),
        }
    }

    /// `B2-s1 | s3` display of the board's fleet(s), ` / `-joined for
    /// multi-workload boards.
    pub fn partition_display(&self) -> String {
        match self {
            BoardPlan::Single(p) => p.partition_display(),
            BoardPlan::Multi(mp) => {
                let parts: Vec<String> =
                    mp.tenants.iter().map(|t| t.partition_display()).collect();
                parts.join(" / ")
            }
        }
    }

    /// One fleet per workload (in workload order); each fleet is its
    /// replicas' Eq. 10 stage-time vectors — everything the execution twins
    /// need.
    pub fn fleet_stage_times(&self) -> Vec<Vec<Vec<f64>>> {
        let of_plan = |p: &Plan| -> Vec<Vec<f64>> {
            p.replicas.iter().map(|r| r.stage_times.clone()).collect()
        };
        match self {
            BoardPlan::Single(p) => vec![of_plan(p)],
            BoardPlan::Multi(mp) => mp.tenants.iter().map(|t| of_plan(&t.plan)).collect(),
        }
    }

    /// Every embedded single-network [`Plan`], in workload order.
    fn plans(&self) -> Vec<&Plan> {
        match self {
            BoardPlan::Single(p) => vec![p],
            BoardPlan::Multi(mp) => mp.tenants.iter().map(|t| &t.plan).collect(),
        }
    }

    fn to_json(&self) -> (&'static str, Json) {
        match self {
            BoardPlan::Single(p) => ("plan", p.to_json()),
            BoardPlan::Multi(mp) => ("multi", mp.to_json()),
        }
    }
}

/// One board's slot in a [`ClusterPlan`].
#[derive(Debug, Clone, PartialEq)]
pub struct BoardEntry {
    /// Unique board name (router, reports, `--disable-board`).
    pub name: String,
    /// Pinned base seed for this board's arrival streams, if any.
    pub seed: Option<u64>,
    /// The planner's traffic share for this board: its capacity over the
    /// cluster capacity. Shares sum to 1 across boards.
    pub rate_share: f64,
    /// The board's compiled design.
    pub plan: BoardPlan,
}

/// A compiled, serializable cluster serving plan: N heterogeneous boards,
/// each with an ordinary per-board plan produced by the *per-board* search
/// (`dse::explore_replicated` via [`PlanSpec`], or `tenancy::explore_joint`
/// via [`MultiPlan::compile`]), plus capacity-proportional traffic shares —
/// ready to [`simulate`](ClusterPlan::simulate) (DES) or
/// [`deploy`](ClusterPlan::deploy) (wall-clock fleets behind one router
/// thread).
///
/// # Example
///
/// ```
/// use pipeit::cluster::{BoardSpec, ClusterPlan, ClusterSpec};
/// use pipeit::config::Config;
/// use pipeit::tenancy::TenantSpec;
///
/// let spec = ClusterSpec::new(
///     vec![BoardSpec::new(4, 4), BoardSpec::new(2, 6)],
///     vec![TenantSpec::new("alexnet", 40.0)],
/// );
/// let cp = ClusterPlan::compile(&spec, &Config::default()).unwrap();
/// assert_eq!(cp.boards.len(), 2);
/// let path = std::env::temp_dir().join("pipeit_doc_clusterplan.json");
/// cp.save(&path).unwrap();
/// let loaded = ClusterPlan::load(&path).unwrap();
/// assert_eq!(cp, loaded); // the artifact round-trips losslessly
/// std::fs::remove_file(&path).ok();
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterPlan {
    pub workloads: Vec<Workload>,
    pub boards: Vec<BoardEntry>,
}

impl ClusterPlan {
    /// Run the per-board searches over `spec` and compose the results.
    ///
    /// Two passes: pass 1 compiles each board unscaled and measures its
    /// Eq. 12 capacity, fixing the capacity-proportional traffic shares;
    /// pass 2 (multi-workload only) recompiles each board's joint plan
    /// against its *share* of every workload's cluster-wide rate, so the
    /// per-board SLA/served predictions describe the traffic the board will
    /// actually see.
    pub fn compile(spec: &ClusterSpec, base: &Config) -> Result<ClusterPlan> {
        anyhow::ensure!(!spec.boards.is_empty(), "cluster needs at least one board");
        anyhow::ensure!(!spec.workloads.is_empty(), "cluster needs at least one workload");
        anyhow::ensure!(spec.max_replicas >= 1, "max_replicas must be >= 1");

        // Pass 1: per-board capacity under the unscaled workload mix.
        let mut configs = Vec::with_capacity(spec.boards.len());
        let mut pass1 = Vec::with_capacity(spec.boards.len());
        for b in &spec.boards {
            let cfg = b.config(base)?;
            let plan = compile_board(&spec.workloads, &cfg, spec.max_replicas)
                .with_context(|| format!("board {:?}", b.name))?;
            configs.push(cfg);
            pass1.push(plan);
        }
        let total: f64 = pass1.iter().map(BoardPlan::capacity).sum();
        anyhow::ensure!(total > 0.0, "cluster has zero planned capacity");

        // Pass 2: fix shares; multi-workload boards recompile against their
        // shared slice of the offered rates.
        let mut boards = Vec::with_capacity(spec.boards.len());
        for ((b, cfg), plan) in spec.boards.iter().zip(&configs).zip(pass1) {
            let rate_share = plan.capacity() / total;
            let plan = if spec.workloads.len() > 1 {
                let scaled: Vec<TenantSpec> = spec
                    .workloads
                    .iter()
                    .map(|w| TenantSpec { rate_hz: w.rate_hz * rate_share, ..w.clone() })
                    .collect();
                BoardPlan::Multi(
                    MultiPlan::compile(&scaled, cfg, spec.max_replicas)
                        .with_context(|| format!("board {:?} (rate-scaled pass)", b.name))?,
                )
            } else {
                plan
            };
            boards.push(BoardEntry { name: b.name.clone(), seed: b.seed, rate_share, plan });
        }

        let cp = ClusterPlan {
            workloads: spec
                .workloads
                .iter()
                .map(|w| Workload {
                    name: w.name.clone(),
                    network: w.network.clone(),
                    rate_hz: w.rate_hz,
                })
                .collect(),
            boards,
        };
        cp.validate()?;
        Ok(cp)
    }

    pub fn num_boards(&self) -> usize {
        self.boards.len()
    }

    /// Σ of per-board planned Eq. 12 capacities (imgs/s).
    pub fn capacity(&self) -> f64 {
        self.boards.iter().map(|b| b.plan.capacity()).sum()
    }

    /// Structural invariants shared by [`ClusterPlan::compile`] results and
    /// loaded artifacts: unique names, serializable seeds, shares that sum
    /// to one, and per-board plans that match the workload list and are
    /// simulable (stage-time profiles present, no artifact bindings).
    fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.boards.is_empty(), "cluster plan has no boards");
        anyhow::ensure!(!self.workloads.is_empty(), "cluster plan has no workloads");
        for (t, w) in self.workloads.iter().enumerate() {
            anyhow::ensure!(
                w.rate_hz.is_finite() && w.rate_hz > 0.0,
                "workload {t} ({}): rate must be positive",
                w.name
            );
            anyhow::ensure!(
                self.workloads.iter().skip(t + 1).all(|o| o.name != w.name),
                "duplicate workload name {:?}",
                w.name
            );
        }
        let mut share_sum = 0.0;
        for (i, b) in self.boards.iter().enumerate() {
            anyhow::ensure!(
                self.boards.iter().skip(i + 1).all(|o| o.name != b.name),
                "duplicate board name {:?}",
                b.name
            );
            if let Some(seed) = b.seed {
                anyhow::ensure!(
                    seed < (1u64 << 53),
                    "board {i} ({}): seed {seed} exceeds 2^53 and cannot \
                     round-trip through the JSON artifact losslessly",
                    b.name
                );
            }
            anyhow::ensure!(
                b.rate_share.is_finite() && b.rate_share > 0.0 && b.rate_share <= 1.0,
                "board {i} ({}): rate share {} is not in (0, 1]",
                b.name,
                b.rate_share
            );
            share_sum += b.rate_share;
            let plans = b.plan.plans();
            anyhow::ensure!(
                plans.len() == self.workloads.len(),
                "board {i} ({}): {} fleets for {} workloads",
                b.name,
                plans.len(),
                self.workloads.len()
            );
            for (w, p) in self.workloads.iter().zip(plans) {
                anyhow::ensure!(
                    p.network == w.network,
                    "board {i} ({}): fleet serves {:?} but workload {:?} is {:?}",
                    b.name,
                    p.network,
                    w.name,
                    w.network
                );
                anyhow::ensure!(
                    p.artifacts.is_none(),
                    "board {i} ({}): artifact-bound plans cannot be cluster-served",
                    b.name
                );
                for (r, rep) in p.replicas.iter().enumerate() {
                    anyhow::ensure!(
                        !rep.stage_times.is_empty(),
                        "board {i} ({}): workload {:?} replica {r} carries no \
                         stage-time profile",
                        b.name,
                        w.name
                    );
                }
            }
        }
        anyhow::ensure!(
            (share_sum - 1.0).abs() < 1e-6,
            "board rate shares sum to {share_sum}, not 1"
        );
        Ok(())
    }

    // ---- serialization ---------------------------------------------------

    pub fn to_json(&self) -> Json {
        let workloads = Json::Arr(
            self.workloads
                .iter()
                .map(|w| {
                    Json::obj(vec![
                        ("name", Json::str(&w.name)),
                        ("network", Json::str(&w.network)),
                        ("rate_hz", Json::num(w.rate_hz)),
                    ])
                })
                .collect(),
        );
        let boards = Json::Arr(
            self.boards
                .iter()
                .map(|b| {
                    let (kind, plan) = b.plan.to_json();
                    Json::obj(vec![
                        ("name", Json::str(&b.name)),
                        ("seed", b.seed.map_or(Json::Null, |s| Json::num(s as f64))),
                        ("rate_share", Json::num(b.rate_share)),
                        ("kind", Json::str(kind)),
                        ("plan", plan),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("version", Json::num(CLUSTER_PLAN_VERSION as f64)),
            ("workloads", workloads),
            ("boards", boards),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ClusterPlan> {
        let version = j.req("version")?.as_usize().context("version")?;
        anyhow::ensure!(
            version == CLUSTER_PLAN_VERSION,
            "cluster-plan schema version {version} is not supported (field \
             \"version\"; this build reads version {CLUSTER_PLAN_VERSION})"
        );
        let mut workloads = Vec::new();
        for (t, wj) in j.req("workloads")?.as_arr().context("workloads array")?.iter().enumerate()
        {
            workloads.push(Workload {
                name: wj
                    .req("name")?
                    .as_str()
                    .with_context(|| format!("workload {t} name"))?
                    .to_string(),
                network: wj
                    .req("network")?
                    .as_str()
                    .with_context(|| format!("workload {t} network"))?
                    .to_string(),
                rate_hz: wj
                    .req("rate_hz")?
                    .as_f64()
                    .with_context(|| format!("workload {t} rate_hz"))?,
            });
        }
        let mut boards = Vec::new();
        for (i, bj) in j.req("boards")?.as_arr().context("boards array")?.iter().enumerate() {
            let seed = match bj.req("seed")? {
                Json::Null => None,
                v => Some(v.as_usize().with_context(|| format!("board {i} seed"))? as u64),
            };
            let kind = bj.req("kind")?.as_str().with_context(|| format!("board {i} kind"))?;
            let pj = bj.req("plan")?;
            let plan = match kind {
                "plan" => BoardPlan::Single(
                    Plan::from_json(pj).with_context(|| format!("board {i} embedded plan"))?,
                ),
                "multi" => BoardPlan::Multi(
                    MultiPlan::from_json(pj)
                        .with_context(|| format!("board {i} embedded multi-plan"))?,
                ),
                other => anyhow::bail!("board {i}: unknown plan kind {other:?} (plan|multi)"),
            };
            boards.push(BoardEntry {
                name: bj
                    .req("name")?
                    .as_str()
                    .with_context(|| format!("board {i} name"))?
                    .to_string(),
                seed,
                rate_share: bj
                    .req("rate_share")?
                    .as_f64()
                    .with_context(|| format!("board {i} rate_share"))?,
                plan,
            });
        }
        let cp = ClusterPlan { workloads, boards };
        cp.validate()?;
        Ok(cp)
    }

    /// Write the cluster plan as a JSON artifact.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Load a cluster plan saved by [`ClusterPlan::save`].
    pub fn load(path: &Path) -> Result<ClusterPlan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        ClusterPlan::from_json(&j)
            .with_context(|| format!("parsing cluster plan {}", path.display()))
    }

    // ---- display ---------------------------------------------------------

    /// Human-readable plan description (the `pipeit plan-cluster` output).
    pub fn summary(&self) -> String {
        let mut s = String::new();
        let loads: Vec<String> = self
            .workloads
            .iter()
            .map(|w| format!("{} @ {:.1}/s", w.name, w.rate_hz))
            .collect();
        s.push_str(&format!(
            "cluster    : {} boards serving {}\n",
            self.boards.len(),
            loads.join(", ")
        ));
        for b in &self.boards {
            let seed = match b.seed {
                Some(n) => format!("  seed={n}"),
                None => String::new(),
            };
            s.push_str(&format!(
                "board {:<12} {} {:<6} {}  share={:.2}  cap {:.2}/s{seed}\n",
                b.name,
                b.plan.platform(),
                b.plan.budget_display(),
                b.plan.partition_display(),
                b.rate_share,
                b.plan.capacity(),
            ));
        }
        s.push_str(&format!(
            "capacity   : {:.2} imgs/s Σ eq12 across the fleet\n",
            self.capacity()
        ));
        s
    }

    // ---- execution backends ---------------------------------------------

    /// DES co-simulation of the whole cluster: seeded per-board arrival
    /// streams merged at the front door, policy-routed over the per-board
    /// bounded admission queues — the design-time twin of
    /// [`ClusterPlan::deploy`].
    pub fn simulate(&self, opts: &ClusterServeOptions) -> Result<ClusterServeReport> {
        super::cosim::simulate_cluster(self, opts)
    }

    /// [`ClusterPlan::simulate`] with observability: span chains and the
    /// metrics registry land in `rec` (DESIGN.md §13).
    pub fn simulate_recorded(
        &self,
        opts: &ClusterServeOptions,
        rec: &crate::obs::Recorder,
    ) -> Result<ClusterServeReport> {
        super::cosim::simulate_cluster_recorded(self, opts, rec)
    }

    /// Wall-clock cluster serving: one thread fleet per (board, workload)
    /// behind a single router thread pacing the merged arrival schedule.
    pub fn deploy(&self, opts: &ClusterServeOptions) -> Result<ClusterServeReport> {
        super::deploy::deploy_cluster(self, opts)
    }

    /// [`ClusterPlan::deploy`] with observability (wall-clock spans).
    pub fn deploy_recorded(
        &self,
        opts: &ClusterServeOptions,
        rec: &crate::obs::Recorder,
    ) -> Result<ClusterServeReport> {
        super::deploy::deploy_cluster_recorded(self, opts, rec)
    }
}

/// Pass-1 board compile: the ordinary per-board search for the workload
/// mix — `dse::explore_replicated` (via the [`PlanSpec`] facade) for one
/// workload, the joint DSE (via [`MultiPlan::compile`]) for several.
fn compile_board(
    workloads: &[TenantSpec],
    cfg: &Config,
    max_replicas: usize,
) -> Result<BoardPlan> {
    if workloads.len() == 1 {
        let w = &workloads[0];
        let plan = PlanSpec::new(&w.network)
            .platform(cfg.clone())
            .strategy(Strategy::Replicated { max_replicas, exact: false })
            .time_source(w.time_source)
            .compile()?;
        Ok(BoardPlan::Single(plan))
    } else {
        Ok(BoardPlan::Multi(MultiPlan::compile(workloads, cfg, max_replicas)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::spec::BoardSpec;

    fn two_board_spec() -> ClusterSpec {
        ClusterSpec::new(
            vec![BoardSpec::new(4, 4), BoardSpec::new(2, 6)],
            vec![TenantSpec::new("alexnet", 40.0)],
        )
    }

    fn roundtrip(cp: &ClusterPlan) -> ClusterPlan {
        let text = cp.to_json().to_string();
        let j = Json::parse(&text).expect("cluster-plan JSON reparses");
        ClusterPlan::from_json(&j).expect("cluster-plan JSON deserializes")
    }

    #[test]
    fn compiled_single_workload_plan_roundtrips_through_json() {
        let cp = ClusterPlan::compile(&two_board_spec(), &Config::default()).unwrap();
        assert_eq!(cp.boards.len(), 2);
        assert!(cp.capacity() > 0.0);
        let shares: f64 = cp.boards.iter().map(|b| b.rate_share).sum();
        assert!((shares - 1.0).abs() < 1e-9, "shares sum to {shares}");
        assert_eq!(cp, roundtrip(&cp));
    }

    #[test]
    fn compiled_multi_workload_plan_roundtrips_through_json() {
        let spec = ClusterSpec {
            boards: vec![BoardSpec::new(4, 4), BoardSpec::new(4, 4)],
            workloads: vec![
                TenantSpec::new("alexnet", 20.0),
                TenantSpec::new("squeezenet", 40.0),
            ],
            max_replicas: 2,
        };
        let cp = ClusterPlan::compile(&spec, &Config::default()).unwrap();
        for b in &cp.boards {
            assert!(matches!(b.plan, BoardPlan::Multi(_)));
            assert_eq!(b.plan.fleet_stage_times().len(), 2);
        }
        assert_eq!(cp, roundtrip(&cp));
    }

    #[test]
    fn heterogeneous_boards_get_capacity_proportional_shares() {
        let cp = ClusterPlan::compile(&two_board_spec(), &Config::default()).unwrap();
        let caps: Vec<f64> = cp.boards.iter().map(|b| b.plan.capacity()).collect();
        for (b, cap) in cp.boards.iter().zip(&caps) {
            let expect = cap / caps.iter().sum::<f64>();
            assert!(
                (b.rate_share - expect).abs() < 1e-9,
                "{}: share {} vs capacity fraction {expect}",
                b.name,
                b.rate_share
            );
        }
    }

    #[test]
    fn from_json_rejects_schema_and_structure_violations() {
        let cp = ClusterPlan::compile(&two_board_spec(), &Config::default()).unwrap();
        let good = cp.to_json();

        // Wrong version names the field.
        let mut j = good.clone();
        if let Json::Obj(m) = &mut j {
            m.insert("version".to_string(), Json::num(99.0));
        }
        let err = ClusterPlan::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("\"version\"") && err.contains("99"), "{err}");

        // An oversized seed cannot round-trip and is rejected at load.
        let mut j = good.clone();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Arr(bs)) = m.get_mut("boards") {
                if let Json::Obj(b0) = &mut bs[0] {
                    b0.insert("seed".to_string(), Json::num((1u64 << 53) as f64));
                }
            }
        }
        let err = format!("{:?}", ClusterPlan::from_json(&j).unwrap_err());
        assert!(err.contains("2^53"), "{err}");

        // Duplicate board names are rejected.
        let mut j = good.clone();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Arr(bs)) = m.get_mut("boards") {
                let name = bs[0].req("name").unwrap().as_str().unwrap().to_string();
                if let Json::Obj(b1) = &mut bs[1] {
                    b1.insert("name".to_string(), Json::str(&name));
                }
            }
        }
        let err = format!("{:?}", ClusterPlan::from_json(&j).unwrap_err());
        assert!(err.contains("duplicate board name"), "{err}");

        // Shares must still sum to 1.
        let mut j = good;
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Arr(bs)) = m.get_mut("boards") {
                if let Json::Obj(b0) = &mut bs[0] {
                    b0.insert("rate_share".to_string(), Json::num(0.9));
                }
            }
        }
        let err = format!("{:?}", ClusterPlan::from_json(&j).unwrap_err());
        assert!(err.contains("sum to"), "{err}");
    }

    #[test]
    fn summary_names_every_board_and_the_fleet_capacity() {
        let cp = ClusterPlan::compile(&two_board_spec(), &Config::default()).unwrap();
        let s = cp.summary();
        assert!(s.contains("cluster    : 2 boards serving alexnet @ 40.0/s"), "{s}");
        assert!(s.contains("board 4+4"), "{s}");
        assert!(s.contains("board 2+6"), "{s}");
        assert!(s.contains("capacity   :"), "{s}");
    }
}
