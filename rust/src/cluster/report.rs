//! The unified cluster serving report: one shape for the DES co-simulation
//! ([`crate::cluster::simulate_cluster`]) and the wall-clock fleet deploy
//! ([`crate::cluster::deploy_cluster`]), rendered by one path
//! ([`crate::reports::render_cluster`]) and serialized for `--metrics-out`.

use anyhow::{Context, Result};

use crate::api::LatencyReport;
use crate::obs::{AttribReport, MetricsSnapshot};
use crate::util::json::Json;

use super::router::DispatchPolicy;

/// Runtime knobs shared by both cluster execution backends; the
/// [`ClusterPlan`](crate::cluster::ClusterPlan) itself fixes every design
/// decision (board configs, per-board plans, rate shares).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterServeOptions {
    /// Arrivals generated per workload across the whole cluster.
    pub images: usize,
    /// Inter-stage queue capacity inside each replica.
    pub queue_cap: usize,
    /// Admission queue capacity per (board, workload) fleet; arrivals that
    /// find every up board's queue full are shed, counted against their
    /// first-choice board.
    pub admission_cap: usize,
    /// Base run seed. Board `i` without a pinned seed draws its arrival
    /// streams from `seed + 7919·i` (the same distinct-stream scheme as
    /// tenant seeds); the router's p2c sampling uses
    /// `seed ^ `[`DISPATCH_SALT`](crate::cluster::DISPATCH_SALT).
    pub seed: u64,
    /// Wall-clock deploys sleep for `stage_time * time_scale` per item
    /// (ignored by the DES).
    pub time_scale: f64,
    /// Replace every Poisson component stream with a deterministic uniform
    /// stream at the same rate.
    pub uniform_arrivals: bool,
    /// Front-door dispatch policy.
    pub policy: DispatchPolicy,
    /// Board names taken out of rotation (failure drill / graceful
    /// degradation): their component arrival streams still arrive at the
    /// front door, but the router never offers them work.
    pub disabled: Vec<String>,
}

impl Default for ClusterServeOptions {
    fn default() -> ClusterServeOptions {
        ClusterServeOptions {
            images: 600,
            queue_cap: 2,
            admission_cap: 8,
            seed: 7,
            time_scale: 0.05,
            uniform_arrivals: false,
            policy: DispatchPolicy::LeastOutstanding,
            disabled: Vec::new(),
        }
    }
}

impl ClusterServeOptions {
    /// Base arrival seed for board `idx`: its pinned seed, or a
    /// deterministic derivation from the run seed that keeps per-board
    /// streams distinct. Workload `t` on that board then uses
    /// `board_seed + 7919²·t` (`cosim::WORKLOAD_SEED_STRIDE`): harness
    /// reps add `+rep`, boards add `+7919·idx`, workloads add `+7919²·t`,
    /// so for `rep, idx < 7919` the three offsets are mixed-radix digits
    /// and every (rep, board, workload) triple gets a distinct SplitMix64
    /// stream (the old `+t` workload offset collided with rep `r = t`;
    /// seed-stream audit, DESIGN.md §15).
    pub fn board_seed(&self, pinned: Option<u64>, idx: usize) -> u64 {
        pinned.unwrap_or_else(|| self.seed.wrapping_add(7919 * idx as u64))
    }
}

/// Which backend produced a [`ClusterServeReport`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ClusterServeMode {
    /// Discrete-event co-simulation.
    Des,
    /// Wall-clock thread fleets over synthetic sleep stages; latencies and
    /// throughputs are normalized back by `time_scale` so they compare
    /// directly with the DES twin.
    Synthetic { time_scale: f64 },
}

/// One board's slice of a cluster serving run.
#[derive(Debug, Clone, PartialEq)]
pub struct BoardServeReport {
    pub name: String,
    /// Platform name of the board's config.
    pub platform: String,
    /// `4B+4s` display of the board's core budget.
    pub budget: String,
    /// `B2-s1 | s3` display of the board's fleet(s).
    pub pipeline: String,
    /// The board's planned Eq. 12 capacity (imgs/s, summed over fleets).
    pub capacity: f64,
    /// The planner's traffic share for this board (Σ over boards = 1).
    pub rate_share: f64,
    /// Whether the board was in rotation for this run.
    pub up: bool,
    /// Arrivals whose *first choice* was this board. Admission may land an
    /// arrival elsewhere via fallback, so per-board `offered` does not
    /// equal `admitted + shed`; the cluster-wide sums do.
    pub offered: usize,
    /// Arrivals served by this board (first-choice or fallback).
    pub admitted: usize,
    /// Sheds charged to this board (it was the first choice and every up
    /// board was full).
    pub shed: usize,
    /// Served rate over the cluster horizon (imgs/s).
    pub throughput: f64,
    /// End-to-end latency percentiles of items served here; `None` when
    /// nothing was admitted.
    pub latency: Option<LatencyReport>,
    /// Busiest stage's busy fraction over the board's busy horizon.
    pub utilization: f64,
}

/// Unified result of serving a [`ClusterPlan`](crate::cluster::ClusterPlan)
/// through either backend.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterServeReport {
    pub mode: ClusterServeMode,
    pub policy: DispatchPolicy,
    /// Cluster horizon in (model) seconds: last completion anywhere.
    pub wall_s: f64,
    /// Items served across all boards.
    pub images: usize,
    /// Items shed across all boards.
    pub shed: usize,
    /// Aggregate served rate (imgs/s) over the cluster horizon — the
    /// headline metric, compared against [`ClusterServeReport::capacity`].
    pub throughput: f64,
    /// Σ of per-board planned Eq. 12 capacities (imgs/s), down boards
    /// included — degradation shows up as throughput/capacity, not as a
    /// moving target.
    pub capacity: f64,
    /// Merged end-to-end latency percentiles across every served item.
    pub latency: Option<LatencyReport>,
    pub boards: Vec<BoardServeReport>,
    /// Frozen observability registry (DESIGN.md §13) when the run was
    /// recorded; `None` under a disabled [`crate::obs::Recorder`], keeping
    /// unrecorded report bytes unchanged.
    pub metrics: Option<MetricsSnapshot>,
    /// Prediction-error attribution over the recorded spans (DESIGN.md
    /// §14): where each admitted item's latency went, and how each stage's
    /// observed service compares to its Eq. 10 prediction. `None` when the
    /// run was not recorded (or used the wall-clock twin).
    pub attrib: Option<AttribReport>,
}

impl ClusterServeReport {
    /// JSON shape of the report — what `serve-cluster --metrics-out`
    /// captures.
    pub fn to_json(&self) -> Json {
        let mode = match self.mode {
            ClusterServeMode::Des => Json::obj(vec![("kind", Json::str("des"))]),
            ClusterServeMode::Synthetic { time_scale } => Json::obj(vec![
                ("kind", Json::str("synthetic")),
                ("time_scale", Json::num(time_scale)),
            ]),
        };
        let latency_json = |l: &Option<LatencyReport>| match l {
            None => Json::Null,
            Some(l) => Json::obj(vec![
                ("p50", Json::num(l.p50)),
                ("p95", Json::num(l.p95)),
                ("p99", Json::num(l.p99)),
            ]),
        };
        let boards = Json::Arr(
            self.boards
                .iter()
                .map(|b| {
                    Json::obj(vec![
                        ("name", Json::str(&b.name)),
                        ("platform", Json::str(&b.platform)),
                        ("budget", Json::str(&b.budget)),
                        ("pipeline", Json::str(&b.pipeline)),
                        ("capacity", Json::num(b.capacity)),
                        ("rate_share", Json::num(b.rate_share)),
                        ("up", Json::Bool(b.up)),
                        ("offered", Json::num(b.offered as f64)),
                        ("admitted", Json::num(b.admitted as f64)),
                        ("shed", Json::num(b.shed as f64)),
                        ("throughput", Json::num(b.throughput)),
                        ("latency", latency_json(&b.latency)),
                        ("utilization", Json::num(b.utilization)),
                    ])
                })
                .collect(),
        );
        let mut fields = vec![
            ("mode", mode),
            ("policy", Json::str(self.policy.name())),
            ("wall_s", Json::num(self.wall_s)),
            ("images", Json::num(self.images as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("throughput", Json::num(self.throughput)),
            ("capacity", Json::num(self.capacity)),
            ("latency", latency_json(&self.latency)),
            ("boards", boards),
        ];
        if let Some(m) = &self.metrics {
            fields.push(("metrics", m.to_json()));
        }
        if let Some(a) = &self.attrib {
            fields.push(("attrib", a.to_json()));
        }
        Json::obj(fields)
    }

    /// Inverse of [`ClusterServeReport::to_json`] — what makes
    /// `--metrics-out` files load-backable like the other report shapes
    /// flowing through [`crate::util::json`]. Round-trips every field,
    /// including the optional `metrics` snapshot.
    pub fn from_json(j: &Json) -> Result<ClusterServeReport> {
        let mode_j = j.req("mode")?;
        let mode = match mode_j.req("kind")?.as_str() {
            Some("des") => ClusterServeMode::Des,
            Some("synthetic") => ClusterServeMode::Synthetic {
                time_scale: mode_j
                    .req("time_scale")?
                    .as_f64()
                    .context("mode.time_scale must be a number")?,
            },
            other => anyhow::bail!("unknown cluster serve mode {other:?}"),
        };
        let policy = DispatchPolicy::parse(
            j.req("policy")?.as_str().context("policy must be a string")?,
        )?;
        let boards = j
            .req("boards")?
            .as_arr()
            .context("boards must be an array")?
            .iter()
            .enumerate()
            .map(|(i, b)| {
                BoardServeReport::from_json(b).with_context(|| format!("board {i}"))
            })
            .collect::<Result<Vec<_>>>()?;
        let metrics = match j.get("metrics") {
            None => None,
            Some(m) => Some(MetricsSnapshot::from_json(m).context("metrics")?),
        };
        let attrib = match j.get("attrib") {
            None => None,
            Some(a) => Some(AttribReport::from_json(a).context("attrib")?),
        };
        Ok(ClusterServeReport {
            mode,
            policy,
            wall_s: j.req("wall_s")?.as_f64().context("wall_s")?,
            images: j.req("images")?.as_usize().context("images")?,
            shed: j.req("shed")?.as_usize().context("shed")?,
            throughput: j.req("throughput")?.as_f64().context("throughput")?,
            capacity: j.req("capacity")?.as_f64().context("capacity")?,
            latency: latency_from_json(j.req("latency")?)?,
            boards,
            metrics,
            attrib,
        })
    }
}

/// Parse an optional `{p50, p95, p99}` object (the shape both report
/// serializers emit for latency percentiles).
fn latency_from_json(j: &Json) -> Result<Option<LatencyReport>> {
    if j == &Json::Null {
        return Ok(None);
    }
    Ok(Some(LatencyReport {
        p50: j.req("p50")?.as_f64().context("latency.p50")?,
        p95: j.req("p95")?.as_f64().context("latency.p95")?,
        p99: j.req("p99")?.as_f64().context("latency.p99")?,
    }))
}

impl BoardServeReport {
    /// Inverse of the board entry in [`ClusterServeReport::to_json`].
    pub fn from_json(j: &Json) -> Result<BoardServeReport> {
        Ok(BoardServeReport {
            name: j.req("name")?.as_str().context("name")?.to_string(),
            platform: j.req("platform")?.as_str().context("platform")?.to_string(),
            budget: j.req("budget")?.as_str().context("budget")?.to_string(),
            pipeline: j.req("pipeline")?.as_str().context("pipeline")?.to_string(),
            capacity: j.req("capacity")?.as_f64().context("capacity")?,
            rate_share: j.req("rate_share")?.as_f64().context("rate_share")?,
            up: j.req("up")?.as_bool().context("up")?,
            offered: j.req("offered")?.as_usize().context("offered")?,
            admitted: j.req("admitted")?.as_usize().context("admitted")?,
            shed: j.req("shed")?.as_usize().context("shed")?,
            throughput: j.req("throughput")?.as_f64().context("throughput")?,
            latency: latency_from_json(j.req("latency")?)?,
            utilization: j.req("utilization")?.as_f64().context("utilization")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn board_seed_derivation_matches_the_tenancy_scheme() {
        let opts = ClusterServeOptions { seed: 100, ..Default::default() };
        assert_eq!(opts.board_seed(None, 0), 100);
        assert_eq!(opts.board_seed(None, 2), 100 + 2 * 7919);
        assert_eq!(opts.board_seed(Some(5), 2), 5, "pinned seeds win");
    }

    #[test]
    fn report_json_is_parseable() {
        let report = ClusterServeReport {
            mode: ClusterServeMode::Des,
            policy: DispatchPolicy::PowerOfTwo,
            wall_s: 12.0,
            images: 900,
            shed: 100,
            throughput: 75.0,
            capacity: 80.0,
            latency: Some(LatencyReport { p50: 0.02, p95: 0.04, p99: 0.05 }),
            boards: vec![BoardServeReport {
                name: "4+4".into(),
                platform: "hikey970".into(),
                budget: "4B+4s".into(),
                pipeline: "B2-s1 | B2-s3".into(),
                capacity: 50.0,
                rate_share: 0.625,
                up: true,
                offered: 600,
                admitted: 580,
                shed: 20,
                throughput: 48.3,
                latency: None,
                utilization: 0.91,
            }],
            metrics: None,
            attrib: None,
        };
        let text = report.to_json().to_string();
        let j = Json::parse(&text).expect("cluster report JSON reparses");
        assert_eq!(j.req("policy").unwrap().as_str(), Some("p2c"));
        assert_eq!(j.req("mode").unwrap().req("kind").unwrap().as_str(), Some("des"));
        let b = &j.req("boards").unwrap().as_arr().unwrap()[0];
        assert_eq!(b.req("up").unwrap().as_bool(), Some(true));
        assert_eq!(b.req("shed").unwrap().as_usize(), Some(20));
        assert_eq!(b.req("latency").unwrap(), &Json::Null);
    }

    #[test]
    fn report_json_loads_back_field_for_field() {
        let mut report = ClusterServeReport {
            mode: ClusterServeMode::Synthetic { time_scale: 0.05 },
            policy: DispatchPolicy::LeastOutstanding,
            wall_s: 9.5,
            images: 450,
            shed: 12,
            throughput: 47.4,
            capacity: 55.0,
            latency: Some(LatencyReport { p50: 0.03, p95: 0.06, p99: 0.08 }),
            boards: vec![BoardServeReport {
                name: "2+6".into(),
                platform: "hikey970".into(),
                budget: "2B+6s".into(),
                pipeline: "B1-s2 | s4".into(),
                capacity: 30.0,
                rate_share: 0.375,
                up: false,
                offered: 200,
                admitted: 180,
                shed: 20,
                throughput: 18.9,
                latency: Some(LatencyReport { p50: 0.04, p95: 0.07, p99: 0.09 }),
                utilization: 0.66,
            }],
            metrics: None,
            attrib: None,
        };
        let back = ClusterServeReport::from_json(
            &Json::parse(&report.to_json().to_string()).unwrap(),
        )
        .expect("round-trip without metrics");
        assert_eq!(back, report);

        // And with an embedded registry snapshot.
        let rec = crate::obs::Recorder::on();
        rec.admit(0, 0, 0.1);
        rec.stage(0, 0, 0, 0, 0.1, 0.2);
        rec.depart(0, 0, 0, 0.2);
        rec.gauge_set("wall_s", 9.5);
        report.metrics = rec.snapshot();
        let back = ClusterServeReport::from_json(
            &Json::parse(&report.to_json().to_string()).unwrap(),
        )
        .expect("round-trip with metrics");
        assert_eq!(back, report);
    }
}
