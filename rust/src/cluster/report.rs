//! The unified cluster serving report: one shape for the DES co-simulation
//! ([`crate::cluster::simulate_cluster`]) and the wall-clock fleet deploy
//! ([`crate::cluster::deploy_cluster`]), rendered by one path
//! ([`crate::reports::render_cluster`]) and serialized for `--metrics-out`.

use crate::api::LatencyReport;
use crate::util::json::Json;

use super::router::DispatchPolicy;

/// Runtime knobs shared by both cluster execution backends; the
/// [`ClusterPlan`](crate::cluster::ClusterPlan) itself fixes every design
/// decision (board configs, per-board plans, rate shares).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterServeOptions {
    /// Arrivals generated per workload across the whole cluster.
    pub images: usize,
    /// Inter-stage queue capacity inside each replica.
    pub queue_cap: usize,
    /// Admission queue capacity per (board, workload) fleet; arrivals that
    /// find every up board's queue full are shed, counted against their
    /// first-choice board.
    pub admission_cap: usize,
    /// Base run seed. Board `i` without a pinned seed draws its arrival
    /// streams from `seed + 7919·i` (the same distinct-stream scheme as
    /// tenant seeds); the router's p2c sampling uses
    /// `seed ^ `[`DISPATCH_SALT`](crate::cluster::DISPATCH_SALT).
    pub seed: u64,
    /// Wall-clock deploys sleep for `stage_time * time_scale` per item
    /// (ignored by the DES).
    pub time_scale: f64,
    /// Replace every Poisson component stream with a deterministic uniform
    /// stream at the same rate.
    pub uniform_arrivals: bool,
    /// Front-door dispatch policy.
    pub policy: DispatchPolicy,
    /// Board names taken out of rotation (failure drill / graceful
    /// degradation): their component arrival streams still arrive at the
    /// front door, but the router never offers them work.
    pub disabled: Vec<String>,
}

impl Default for ClusterServeOptions {
    fn default() -> ClusterServeOptions {
        ClusterServeOptions {
            images: 600,
            queue_cap: 2,
            admission_cap: 8,
            seed: 7,
            time_scale: 0.05,
            uniform_arrivals: false,
            policy: DispatchPolicy::LeastOutstanding,
            disabled: Vec::new(),
        }
    }
}

impl ClusterServeOptions {
    /// Base arrival seed for board `idx`: its pinned seed, or a
    /// deterministic derivation from the run seed that keeps per-board
    /// streams distinct. Workload `t` on that board then uses
    /// `board_seed + t` — collision-free across boards because the
    /// workload count is far below the 7919 stride.
    pub fn board_seed(&self, pinned: Option<u64>, idx: usize) -> u64 {
        pinned.unwrap_or_else(|| self.seed.wrapping_add(7919 * idx as u64))
    }
}

/// Which backend produced a [`ClusterServeReport`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ClusterServeMode {
    /// Discrete-event co-simulation.
    Des,
    /// Wall-clock thread fleets over synthetic sleep stages; latencies and
    /// throughputs are normalized back by `time_scale` so they compare
    /// directly with the DES twin.
    Synthetic { time_scale: f64 },
}

/// One board's slice of a cluster serving run.
#[derive(Debug, Clone, PartialEq)]
pub struct BoardServeReport {
    pub name: String,
    /// Platform name of the board's config.
    pub platform: String,
    /// `4B+4s` display of the board's core budget.
    pub budget: String,
    /// `B2-s1 | s3` display of the board's fleet(s).
    pub pipeline: String,
    /// The board's planned Eq. 12 capacity (imgs/s, summed over fleets).
    pub capacity: f64,
    /// The planner's traffic share for this board (Σ over boards = 1).
    pub rate_share: f64,
    /// Whether the board was in rotation for this run.
    pub up: bool,
    /// Arrivals whose *first choice* was this board. Admission may land an
    /// arrival elsewhere via fallback, so per-board `offered` does not
    /// equal `admitted + shed`; the cluster-wide sums do.
    pub offered: usize,
    /// Arrivals served by this board (first-choice or fallback).
    pub admitted: usize,
    /// Sheds charged to this board (it was the first choice and every up
    /// board was full).
    pub shed: usize,
    /// Served rate over the cluster horizon (imgs/s).
    pub throughput: f64,
    /// End-to-end latency percentiles of items served here; `None` when
    /// nothing was admitted.
    pub latency: Option<LatencyReport>,
    /// Busiest stage's busy fraction over the board's busy horizon.
    pub utilization: f64,
}

/// Unified result of serving a [`ClusterPlan`](crate::cluster::ClusterPlan)
/// through either backend.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterServeReport {
    pub mode: ClusterServeMode,
    pub policy: DispatchPolicy,
    /// Cluster horizon in (model) seconds: last completion anywhere.
    pub wall_s: f64,
    /// Items served across all boards.
    pub images: usize,
    /// Items shed across all boards.
    pub shed: usize,
    /// Aggregate served rate (imgs/s) over the cluster horizon — the
    /// headline metric, compared against [`ClusterServeReport::capacity`].
    pub throughput: f64,
    /// Σ of per-board planned Eq. 12 capacities (imgs/s), down boards
    /// included — degradation shows up as throughput/capacity, not as a
    /// moving target.
    pub capacity: f64,
    /// Merged end-to-end latency percentiles across every served item.
    pub latency: Option<LatencyReport>,
    pub boards: Vec<BoardServeReport>,
}

impl ClusterServeReport {
    /// JSON shape of the report — what `serve-cluster --metrics-out`
    /// captures.
    pub fn to_json(&self) -> Json {
        let mode = match self.mode {
            ClusterServeMode::Des => Json::obj(vec![("kind", Json::str("des"))]),
            ClusterServeMode::Synthetic { time_scale } => Json::obj(vec![
                ("kind", Json::str("synthetic")),
                ("time_scale", Json::num(time_scale)),
            ]),
        };
        let latency_json = |l: &Option<LatencyReport>| match l {
            None => Json::Null,
            Some(l) => Json::obj(vec![
                ("p50", Json::num(l.p50)),
                ("p95", Json::num(l.p95)),
                ("p99", Json::num(l.p99)),
            ]),
        };
        let boards = Json::Arr(
            self.boards
                .iter()
                .map(|b| {
                    Json::obj(vec![
                        ("name", Json::str(&b.name)),
                        ("platform", Json::str(&b.platform)),
                        ("budget", Json::str(&b.budget)),
                        ("pipeline", Json::str(&b.pipeline)),
                        ("capacity", Json::num(b.capacity)),
                        ("rate_share", Json::num(b.rate_share)),
                        ("up", Json::Bool(b.up)),
                        ("offered", Json::num(b.offered as f64)),
                        ("admitted", Json::num(b.admitted as f64)),
                        ("shed", Json::num(b.shed as f64)),
                        ("throughput", Json::num(b.throughput)),
                        ("latency", latency_json(&b.latency)),
                        ("utilization", Json::num(b.utilization)),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("mode", mode),
            ("policy", Json::str(self.policy.name())),
            ("wall_s", Json::num(self.wall_s)),
            ("images", Json::num(self.images as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("throughput", Json::num(self.throughput)),
            ("capacity", Json::num(self.capacity)),
            ("latency", latency_json(&self.latency)),
            ("boards", boards),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn board_seed_derivation_matches_the_tenancy_scheme() {
        let opts = ClusterServeOptions { seed: 100, ..Default::default() };
        assert_eq!(opts.board_seed(None, 0), 100);
        assert_eq!(opts.board_seed(None, 2), 100 + 2 * 7919);
        assert_eq!(opts.board_seed(Some(5), 2), 5, "pinned seeds win");
    }

    #[test]
    fn report_json_is_parseable() {
        let report = ClusterServeReport {
            mode: ClusterServeMode::Des,
            policy: DispatchPolicy::PowerOfTwo,
            wall_s: 12.0,
            images: 900,
            shed: 100,
            throughput: 75.0,
            capacity: 80.0,
            latency: Some(LatencyReport { p50: 0.02, p95: 0.04, p99: 0.05 }),
            boards: vec![BoardServeReport {
                name: "4+4".into(),
                platform: "hikey970".into(),
                budget: "4B+4s".into(),
                pipeline: "B2-s1 | B2-s3".into(),
                capacity: 50.0,
                rate_share: 0.625,
                up: true,
                offered: 600,
                admitted: 580,
                shed: 20,
                throughput: 48.3,
                latency: None,
                utilization: 0.91,
            }],
        };
        let text = report.to_json().to_string();
        let j = Json::parse(&text).expect("cluster report JSON reparses");
        assert_eq!(j.req("policy").unwrap().as_str(), Some("p2c"));
        assert_eq!(j.req("mode").unwrap().req("kind").unwrap().as_str(), Some("des"));
        let b = &j.req("boards").unwrap().as_arr().unwrap()[0];
        assert_eq!(b.req("up").unwrap().as_bool(), Some(true));
        assert_eq!(b.req("shed").unwrap().as_usize(), Some(20));
        assert_eq!(b.req("latency").unwrap(), &Json::Null);
    }
}
