//! Cluster-scale serving (DESIGN.md §12): shard traffic across a fleet of
//! heterogeneous big.LITTLE boards behind one front door.
//!
//! Pipe-it plans one board; the serving tier composes many. Per-board
//! designs stay exactly what the existing layers produce — an ordinary
//! [`Plan`](crate::api::Plan) (replicated-pipeline DSE) or
//! [`MultiPlan`](crate::tenancy::MultiPlan) (joint co-serving DSE) — and
//! the cluster layer adds the two decisions that only exist above a single
//! board: *how much* traffic each board should plan for, and *where* each
//! live request goes (PICO, arXiv 2206.08662; edge-intelligence
//! distribution, arXiv 2107.05828):
//!
//! * [`BoardSpec`] / [`ClusterSpec`] — the fleet description: N boards with
//!   mixed core configs (`cores=4+4`, `cores=2+6`), each with its own
//!   platform file (TimeMatrix source) and optional pinned seed.
//! * [`ClusterPlan`] — the schema-versioned serializable artifact from
//!   [`ClusterPlan::compile`]: per-board embedded plans plus
//!   capacity-proportional rate shares; save → load → simulate is lossless
//!   and bit-identical.
//! * [`Router`] / [`DispatchPolicy`] — the front door: round-robin
//!   (baseline), least-outstanding-work, and capacity-weighted
//!   power-of-two-choices, all over per-board bounded admission queues
//!   with shed-on-full counted per board.
//! * [`simulate_cluster`] / [`deploy_cluster`] — the execution twins: a
//!   streaming deterministic DES built for ≥1M-arrival runs, and a
//!   wall-clock deploy (one [`crate::coordinator::run_fleet`] per board
//!   fleet behind a single router thread). Both return one
//!   [`ClusterServeReport`], rendered by
//!   [`crate::reports::render_cluster`].
//!
//! The CLI surface is `pipeit plan-cluster / serve-cluster /
//! simulate-cluster`.
//!
//! # Example
//!
//! ```
//! use pipeit::cluster::{BoardSpec, ClusterPlan, ClusterServeOptions, ClusterSpec};
//! use pipeit::config::Config;
//! use pipeit::tenancy::TenantSpec;
//!
//! let spec = ClusterSpec::new(
//!     vec![BoardSpec::new(4, 4), BoardSpec::new(2, 6)],
//!     vec![TenantSpec::new("alexnet", 60.0)],
//! );
//! let cp = ClusterPlan::compile(&spec, &Config::default()).unwrap();
//! let report = cp
//!     .simulate(&ClusterServeOptions { images: 300, ..Default::default() })
//!     .unwrap();
//! assert_eq!(report.boards.len(), 2);
//! assert_eq!(report.images + report.shed, 300);
//! ```

pub mod cosim;
pub mod deploy;
pub mod plan;
pub mod report;
pub mod router;
pub mod spec;

pub use cosim::{
    cluster_arrivals, simulate_cluster, simulate_cluster_recorded,
    simulate_cluster_streams, simulate_cluster_streams_recorded, BoardSimOutcome,
};
pub use deploy::{deploy_cluster, deploy_cluster_recorded};
pub use plan::{BoardEntry, BoardPlan, ClusterPlan, Workload, CLUSTER_PLAN_VERSION};
pub use report::{
    BoardServeReport, ClusterServeMode, ClusterServeOptions, ClusterServeReport,
};
pub use router::{DispatchPolicy, Router, DISPATCH_SALT};
pub use spec::{BoardSpec, ClusterSpec};
