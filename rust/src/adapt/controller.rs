//! The closed-loop adaptation controller: telemetry → drift detection →
//! recalibration → re-plan → hot-swap.
//!
//! Serving proceeds in control periods of [`AdaptOptions::interval`] items.
//! After each period the controller snapshots telemetry and asks the
//! [`DriftDetector`] whether the deployed plan is still believable. On a
//! confirmed disturbance it (1) lowers the classification into a
//! [`Calibration`] and applies it to its working copy of the
//! [`TimeMatrix`], (2) re-runs the plan's own strategy search on the
//! calibrated matrix via [`Plan::replan_on_matrix`], and (3) hot-swaps the
//! fleet to the new stage partition at the period boundary — the running
//! pipelines drain fully (no item is lost or reordered) and the next
//! period is built from the new plan's [`StageSpec`](crate::coordinator::StageSpec)s,
//! reusing the executor's readiness latch so the clock never charges
//! rebuild time as serving time unfairly. Every swap is recorded as an
//! [`AdaptationEvent`] in the final [`ServeReport`].
//!
//! Two backends share the loop:
//!
//! * [`simulate_adaptive`] — the deterministic DES testbed. Ground truth is
//!   `base matrix × scripted throttle events`
//!   ([`crate::simulator::pipeline_sim::simulate_replicated_disturbed`]);
//!   the whole loop runs without threads or wall-clock time, so the
//!   throttle-recovery acceptance test is exact and repeatable.
//! * [`deploy_adaptive`] — the wall-clock twin on the real thread fleet
//!   ([`crate::coordinator::run_fleet_observed`]) over synthetic sleep
//!   stages, with the same scripted disturbances applied via a shared
//!   clock (`pipeit serve --net N --adapt`).
//!
//! In both, the *belief* (detector expectations, re-planned stage times)
//! comes from the calibrated matrix, while the *truth* (executed service
//! times) comes from the base matrix times the active throttle factors —
//! the loop is closed precisely when belief catches up with truth.

use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::api::{
    AdaptationEvent, DeployOptions, LatencyReport, Plan, ReplicaReport, ServeMode,
    ServeReport, StageReport,
};
use crate::coordinator::{run_fleet_observed, StageObserver, StageSpec};
use crate::dse::{self, Allocation, PipelineConfig};
use crate::perfmodel::TimeMatrix;
use crate::simulator::pipeline_sim::{self, ThrottleEvent};
use crate::simulator::platform::CoreType;
use crate::simulator::power::PowerModel;

use super::calibrate::Calibration;
use super::drift::{DriftConfig, DriftDetector, DriftStatus};
use super::telemetry::{Telemetry, TelemetrySnapshot};

/// A scripted cluster-level disturbance: from time `at` (simulated seconds
/// for the DES, wall seconds from serving start for deploys), every
/// configuration of `core`'s cluster runs `factor`× slower. Events compose
/// multiplicatively.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterThrottle {
    pub at: f64,
    pub core: CoreType,
    pub factor: f64,
}

impl ClusterThrottle {
    /// Parse the CLI's `AT:FACTOR[:big|small]` form (cluster defaults to
    /// `big`, the cluster that actually throttles on boards).
    ///
    /// # Example
    ///
    /// ```
    /// use pipeit::adapt::ClusterThrottle;
    /// use pipeit::simulator::platform::CoreType;
    ///
    /// let t = ClusterThrottle::parse("1.5:2.0:big").unwrap();
    /// assert_eq!(t.at, 1.5);
    /// assert_eq!(t.factor, 2.0);
    /// assert_eq!(t.core, CoreType::Big);
    /// assert!(ClusterThrottle::parse("1.5:0").is_err());
    /// ```
    pub fn parse(spec: &str) -> Result<ClusterThrottle> {
        let parts: Vec<&str> = spec.split(':').collect();
        anyhow::ensure!(
            parts.len() == 2 || parts.len() == 3,
            "throttle spec {spec:?} is not AT:FACTOR[:big|small]"
        );
        let at: f64 = parts[0]
            .parse()
            .map_err(|_| anyhow::anyhow!("bad throttle time in {spec:?}"))?;
        let factor: f64 = parts[1]
            .parse()
            .map_err(|_| anyhow::anyhow!("bad throttle factor in {spec:?}"))?;
        anyhow::ensure!(at >= 0.0 && at.is_finite(), "throttle time must be >= 0");
        anyhow::ensure!(
            factor.is_finite() && factor > 0.0,
            "throttle factor must be positive"
        );
        let core = match parts.get(2).copied().unwrap_or("big") {
            "big" | "B" | "b" => CoreType::Big,
            "small" | "s" | "S" => CoreType::Small,
            other => anyhow::bail!("unknown cluster {other:?} in {spec:?} (big|small)"),
        };
        Ok(ClusterThrottle { at, core, factor })
    }
}

/// Adaptation-loop tuning.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptOptions {
    /// Items per control period: telemetry is inspected and swaps happen at
    /// these item boundaries.
    pub interval: usize,
    /// Telemetry ring capacity per stage (recent-window length).
    pub window: usize,
    /// Drift-detector tuning.
    pub drift: DriftConfig,
}

impl Default for AdaptOptions {
    fn default() -> AdaptOptions {
        AdaptOptions { interval: 50, window: 40, drift: DriftConfig::default() }
    }
}

/// Outcome of an adaptive serve: the unified report (whole-run totals,
/// final-partition replica detail, adaptation log), the plan the fleet
/// ended on, post-swap sustained-throughput accounting, and the final
/// telemetry snapshot (persisted by `serve --metrics-out`).
#[derive(Debug, Clone)]
pub struct AdaptiveServe {
    pub report: ServeReport,
    pub final_plan: Plan,
    /// Items completed since the last swap (the whole run when no swap).
    pub post_swap_images: usize,
    /// Serving seconds since the last swap (same clock as `report.wall_s`).
    pub post_swap_wall_s: f64,
    pub final_snapshot: TelemetrySnapshot,
}

impl AdaptiveServe {
    /// Sustained throughput after the last swap (imgs/s; equals the
    /// whole-run throughput when no swap happened).
    pub fn post_swap_throughput(&self) -> f64 {
        if self.post_swap_wall_s <= 0.0 {
            return 0.0;
        }
        self.post_swap_images as f64 / self.post_swap_wall_s
    }
}

/// Per-replica pipeline + allocation structure of a plan, validated against
/// a time matrix (every stage config must exist in the matrix and the
/// allocation must cover its layers).
fn replica_structures(
    plan: &Plan,
    tm: &TimeMatrix,
) -> Result<Vec<(PipelineConfig, Allocation)>> {
    anyhow::ensure!(
        plan.artifacts.is_none(),
        "adaptation needs a big.LITTLE plan with Eq. 10 stage times"
    );
    anyhow::ensure!(
        tm.net_name == plan.network,
        "time matrix describes {:?} but the plan serves {:?}",
        tm.net_name,
        plan.network
    );
    let w = tm.num_layers();
    let mut out = Vec::with_capacity(plan.replicas.len());
    for (i, r) in plan.replicas.iter().enumerate() {
        let p = PipelineConfig::parse(&r.pipeline)
            .with_context(|| format!("replica {i} pipeline {:?}", r.pipeline))?;
        for sc in &p.stages {
            anyhow::ensure!(
                tm.config_index(sc.core, sc.count).is_some(),
                "replica {i}: stage config {sc} is not in the time matrix \
                 (platform mismatch?)"
            );
        }
        let a = plan.allocation_of(i);
        anyhow::ensure!(
            a.is_partition(w),
            "replica {i}: allocation does not cover the matrix's {w} layers"
        );
        out.push((p, a));
    }
    Ok(out)
}

/// True (disturbance-free) per-stage service times of every replica under
/// `base` — what the hardware actually delivers before throttle factors.
fn truth_times(structures: &[(PipelineConfig, Allocation)], base: &TimeMatrix) -> Vec<Vec<f64>> {
    structures
        .iter()
        .map(|(p, a)| dse::stage_times(base, p, a))
        .collect()
}

/// Lower cluster-level throttles into DES stage-scoped events for the
/// current partition.
fn lower_script(
    script: &[ClusterThrottle],
    structures: &[(PipelineConfig, Allocation)],
) -> Vec<ThrottleEvent> {
    script
        .iter()
        .map(|t| {
            let scope = structures
                .iter()
                .enumerate()
                .flat_map(|(r, (p, _))| {
                    p.stages
                        .iter()
                        .enumerate()
                        .filter(|(_, sc)| sc.core == t.core)
                        .map(move |(s, _)| (r, s))
                        .collect::<Vec<_>>()
                })
                .collect();
            ThrottleEvent { at: t.at, factor: t.factor, scope }
        })
        .collect()
}

/// Accumulated per-epoch (since last swap) replica accounting.
struct EpochStats {
    start_t: f64,
    images: usize,
    dispatched: Vec<usize>,
    /// Per replica, per stage busy seconds.
    busy: Vec<Vec<f64>>,
    /// Last-seen bottleneck index per replica (DES only).
    bottleneck: Vec<Option<usize>>,
}

impl EpochStats {
    fn new(plan: &Plan, start_t: f64) -> EpochStats {
        EpochStats {
            start_t,
            images: 0,
            dispatched: vec![0; plan.num_replicas()],
            busy: plan.replicas.iter().map(|r| vec![0.0; r.allocation.len()]).collect(),
            bottleneck: vec![None; plan.num_replicas()],
        }
    }

    fn replica_reports(&self, plan: &Plan, epoch_wall: f64) -> Vec<ReplicaReport> {
        plan.replicas
            .iter()
            .enumerate()
            .map(|(i, pr)| {
                let stages: Vec<StageReport> = self.busy[i]
                    .iter()
                    .enumerate()
                    .map(|(j, &busy_s)| StageReport {
                        name: format!("stage{j}"),
                        items: self.dispatched[i],
                        busy_s,
                        utilization: if epoch_wall > 0.0 { busy_s / epoch_wall } else { 0.0 },
                    })
                    .collect();
                let util = stages.iter().map(|s| s.utilization).fold(0.0, f64::max);
                ReplicaReport {
                    pipeline: pr.pipeline.clone(),
                    allocation: plan.allocation_of(i).display_1based(),
                    dispatched: self.dispatched[i],
                    throughput: if epoch_wall > 0.0 {
                        self.dispatched[i] as f64 / epoch_wall
                    } else {
                        0.0
                    },
                    utilization: util,
                    bottleneck: self.bottleneck[i],
                    stages,
                }
            })
            .collect()
    }
}

fn latency_report(latencies: &[f64]) -> Option<LatencyReport> {
    LatencyReport::from_latencies(latencies)
}

/// Closed-loop adaptive serving in the discrete-event simulator.
///
/// * `plan` — the deployed design (compiled on `base`).
/// * `base` — the undisturbed time matrix; ground-truth service times are
///   `base × active throttle factors` from `script`.
/// * `power` — power model for [`crate::api::Strategy::Energy`] re-plans.
/// * `images` / `queue_cap` — stream length and per-replica buffer size.
///
/// Returns the whole-run [`ServeReport`] (mode [`ServeMode::Des`]) with the
/// adaptation log, plus post-swap sustained-throughput accounting for
/// recovery checks.
pub fn simulate_adaptive(
    plan: &Plan,
    base: &TimeMatrix,
    power: &PowerModel,
    script: &[ClusterThrottle],
    opts: &AdaptOptions,
    images: usize,
    queue_cap: usize,
) -> Result<AdaptiveServe> {
    simulate_adaptive_recorded(
        plan,
        base,
        power,
        script,
        opts,
        images,
        queue_cap,
        &crate::obs::Recorder::off(),
    )
}

/// [`simulate_adaptive`] with observability (DESIGN.md §13): each served
/// item's admit/stage/depart chain lands in `rec` under group 0 with
/// stream-global item ids (unique across control periods), per-stage
/// service times feed `stage_service/g0r{r}s{s}` histograms, end-to-end
/// latencies feed the `latency` histogram, and the final registry snapshot
/// is embedded in the report.
#[allow(clippy::too_many_arguments)]
pub fn simulate_adaptive_recorded(
    plan: &Plan,
    base: &TimeMatrix,
    power: &PowerModel,
    script: &[ClusterThrottle],
    opts: &AdaptOptions,
    images: usize,
    queue_cap: usize,
    rec: &crate::obs::Recorder,
) -> Result<AdaptiveServe> {
    anyhow::ensure!(images >= 1, "need at least one image");
    anyhow::ensure!(queue_cap >= 1, "queue capacity must be >= 1");
    anyhow::ensure!(opts.interval >= 1, "adapt interval must be >= 1");

    let mut current = plan.clone();
    let mut structures = replica_structures(&current, base)?;
    let mut calibrated = base.clone();
    let mut detector = DriftDetector::for_plan(&current, opts.drift)?;
    let mut telemetry = Telemetry::for_plan(&current, opts.window);

    let mut t_abs = 0.0f64;
    let mut done = 0usize;
    let mut adaptations: Vec<AdaptationEvent> = Vec::new();
    let mut all_latencies: Vec<f64> = Vec::new();
    let mut epoch = EpochStats::new(&current, 0.0);

    while done < images {
        let n = opts.interval.min(images - done);
        let times = truth_times(&structures, base);
        let events = lower_script(script, &structures);
        let sim = pipeline_sim::simulate_replicated_recorded(
            &times,
            n,
            queue_cap,
            &events,
            t_abs,
            rec,
            0,
            done as u64,
            |r, s, dt| telemetry.record(r, s, dt),
        );
        let chunk_wall = sim.makespan;
        t_abs += chunk_wall;
        done += n;
        epoch.images += n;
        all_latencies.extend(sim.merged_latencies());
        for (i, sr) in sim.per_replica.iter().enumerate() {
            epoch.dispatched[i] += sim.dispatched[i];
            epoch.bottleneck[i] = Some(sr.bottleneck);
            for (j, &u) in sr.utilization.iter().enumerate() {
                // utilization is busy/makespan of the replica's own chunk
                // run; convert back to busy seconds.
                epoch.busy[i][j] += u * sr.makespan;
            }
        }

        if done >= images {
            break;
        }
        let status = detector.observe(&telemetry.snapshot());
        // Fresh window per control period: without this, a replica whose
        // per-period dispatch share is smaller than the ring would judge
        // (and calibrate from) windows still holding pre-disturbance
        // samples.
        telemetry.clear_windows();
        if let DriftStatus::Confirmed(d) = status {
            Calibration::from_disturbance(&d).apply(&mut calibrated)?;
            let next = current.replan_on_matrix(&calibrated, power)?;
            adaptations.push(AdaptationEvent {
                at_s: t_abs,
                after_images: done,
                disturbance: d.to_string(),
                from: current.partition_display(),
                to: next.partition_display(),
                predicted_throughput: next.throughput,
            });
            current = next;
            structures = replica_structures(&current, base)?;
            detector = DriftDetector::for_plan(&current, opts.drift)?;
            telemetry = Telemetry::for_plan(&current, opts.window);
            epoch = EpochStats::new(&current, t_abs);
        }
    }

    let epoch_wall = t_abs - epoch.start_t;
    // `latency` / `stage_service` histograms were fed chunk-wise by the
    // recorded fleet sim; only the run-level gauge remains.
    rec.gauge_set("wall_s", t_abs);
    // Attribution (DESIGN.md §14): residuals compare against the FINAL
    // plan's Eq. 10 times — pre-swap epochs aggregate under it and show up
    // as excess, which is exactly the drift the controller reacted to. The
    // adaptation timeline rides along as annotations so the reader can tell
    // calibration-lag excess from a genuinely mispredicted stage.
    let annotations: Vec<String> = adaptations
        .iter()
        .map(|e| {
            format!(
                "t={:.2}s after {} imgs: {} {} -> {} (pred {:.2} imgs/s)",
                e.at_s, e.after_images, e.disturbance, e.from, e.to, e.predicted_throughput
            )
        })
        .collect();
    let attrib = if rec.enabled() {
        let mut pred = crate::obs::PredictedTimes::new();
        let planned: Vec<Vec<f64>> =
            current.replicas.iter().map(|r| r.stage_times.clone()).collect();
        pred.insert_replicas(0, &planned);
        crate::obs::attrib_for(rec, &pred, annotations)
    } else {
        None
    };
    let report = ServeReport {
        mode: ServeMode::Des,
        network: current.network.clone(),
        images: done,
        wall_s: t_abs,
        throughput: if t_abs > 0.0 { done as f64 / t_abs } else { 0.0 },
        predicted_throughput: current.throughput,
        latency: latency_report(&all_latencies),
        replicas: epoch.replica_reports(&current, epoch_wall),
        adaptations,
        metrics: rec.snapshot(),
        attrib,
    };
    Ok(AdaptiveServe {
        final_snapshot: telemetry.snapshot(),
        post_swap_images: epoch.images,
        post_swap_wall_s: epoch_wall,
        final_plan: current,
        report,
    })
}

// ---- wall-clock backend ---------------------------------------------------

/// Shared disturbance clock for wall-clock deploys: throttle factors are a
/// function of elapsed time since [`deploy_adaptive`] started. The same
/// `start` instant stamps [`AdaptationEvent::at_s`], so scripted `at`
/// times and reported swap times live on ONE clock (which, unlike the
/// summed serving walls, also ticks through inter-period fleet rebuilds).
/// `factor` is lock-free — it runs on every stage's hot path.
struct WallEnv {
    script: Vec<ClusterThrottle>,
    start: Instant,
}

impl WallEnv {
    fn factor(&self, core: CoreType) -> f64 {
        let t = self.start.elapsed().as_secs_f64();
        self.script
            .iter()
            .filter(|e| e.core == core && e.at <= t)
            .map(|e| e.factor)
            .product()
    }
}

/// Synthetic sleep-stage fleet whose per-item sleep is
/// `true_time × time_scale × active throttle factor` — the wall-clock twin
/// of the DES disturbance layer.
fn disturbed_synthetic_fleet(
    times: &[Vec<f64>],
    cores: &[Vec<CoreType>],
    scale: f64,
    env: Arc<WallEnv>,
) -> Vec<Vec<StageSpec<usize>>> {
    times
        .iter()
        .zip(cores)
        .enumerate()
        .map(|(r, (stage_times, stage_cores))| {
            stage_times
                .iter()
                .zip(stage_cores)
                .enumerate()
                .map(|(s, (&t, &core))| {
                    let env = env.clone();
                    StageSpec::new(
                        &format!("r{r}s{s}"),
                        Box::new(move || {
                            Box::new(move |x: usize| {
                                let dt = t * scale * env.factor(core);
                                thread::sleep(Duration::from_secs_f64(dt));
                                x
                            })
                        }),
                    )
                })
                .collect()
        })
        .collect()
}

/// Normalizes observed wall-clock service times back to unscaled simulated
/// seconds before they reach the telemetry (detector expectations are
/// unscaled Eq. 10 times).
struct ScaledObserver {
    inner: Arc<Telemetry>,
    inv_scale: f64,
}

impl StageObserver for ScaledObserver {
    fn on_item(&self, replica: usize, stage: usize, service_s: f64) {
        self.inner.record(replica, stage, service_s * self.inv_scale);
    }
}

/// Closed-loop adaptive serving on the real thread fleet over synthetic
/// sleep stages — the wall-clock twin of [`simulate_adaptive`], backing
/// `pipeit serve --net N --adapt`.
///
/// Each control period runs the current plan's partition as a
/// [`run_fleet_observed`] fleet (shared admission queue, least-outstanding-
/// work dispatch, readiness latch); at period boundaries the fleet drains
/// fully, telemetry is inspected, and on confirmed drift the next period is
/// rebuilt from the re-planned partition — items are never lost or
/// reordered across a swap. Throttle times in `script` are wall seconds
/// from deploy start, on the same clock that stamps
/// [`AdaptationEvent::at_s`] (it keeps ticking through inter-period fleet
/// rebuilds; `report.wall_s` counts only serving periods). Telemetry is
/// normalized by `1/time_scale` so the detector compares against unscaled
/// Eq. 10 expectations; reports use mode [`ServeMode::Synthetic`].
pub fn deploy_adaptive(
    plan: &Plan,
    base: &TimeMatrix,
    power: &PowerModel,
    script: &[ClusterThrottle],
    opts: &AdaptOptions,
    deploy: &DeployOptions,
) -> Result<AdaptiveServe> {
    deploy_adaptive_recorded(plan, base, power, script, opts, deploy, &crate::obs::Recorder::off())
}

/// [`deploy_adaptive`] with observability: the per-period stage observer
/// fans out ([`crate::coordinator::FanoutObserver`]) to both the drift
/// telemetry (normalized service times) and the metrics registry (raw
/// wall-second `stage_service/g0r{r}s{s}` histograms, matching the other
/// wall paths), end-to-end wall latencies feed the `latency` histogram,
/// and the final snapshot is embedded in the report. No spans are emitted
/// on this path — the adaptive wall twin is metrics-only.
pub fn deploy_adaptive_recorded(
    plan: &Plan,
    base: &TimeMatrix,
    power: &PowerModel,
    script: &[ClusterThrottle],
    opts: &AdaptOptions,
    deploy: &DeployOptions,
    rec: &crate::obs::Recorder,
) -> Result<AdaptiveServe> {
    anyhow::ensure!(deploy.images >= 1, "need at least one image");
    anyhow::ensure!(deploy.queue_cap >= 1, "queue capacity must be >= 1");
    anyhow::ensure!(deploy.time_scale > 0.0, "time_scale must be positive");
    anyhow::ensure!(opts.interval >= 1, "adapt interval must be >= 1");

    let serve_start = Instant::now();
    let env = Arc::new(WallEnv { script: script.to_vec(), start: serve_start });
    let mut current = plan.clone();
    let mut structures = replica_structures(&current, base)?;
    let mut calibrated = base.clone();
    let mut detector = DriftDetector::for_plan(&current, opts.drift)?;
    let mut telemetry = Arc::new(Telemetry::for_plan(&current, opts.window));

    let mut wall_total = 0.0f64;
    let mut done = 0usize;
    let mut adaptations: Vec<AdaptationEvent> = Vec::new();
    let mut all_latencies: Vec<f64> = Vec::new();
    let mut epoch = EpochStats::new(&current, 0.0);

    while done < deploy.images {
        let n = opts.interval.min(deploy.images - done);
        let times = truth_times(&structures, base);
        let cores: Vec<Vec<CoreType>> = structures
            .iter()
            .map(|(p, _)| p.stages.iter().map(|sc| sc.core).collect())
            .collect();
        let fleet =
            disturbed_synthetic_fleet(&times, &cores, deploy.time_scale, env.clone());
        let scaled: Arc<dyn StageObserver> = Arc::new(ScaledObserver {
            inner: telemetry.clone(),
            inv_scale: 1.0 / deploy.time_scale,
        });
        let observer: Arc<dyn StageObserver> = if rec.enabled() {
            Arc::new(crate::coordinator::FanoutObserver::new(vec![
                scaled,
                Arc::new(rec.clone()),
            ]))
        } else {
            scaled
        };
        let (_, rep) = run_fleet_observed(
            fleet,
            deploy.queue_cap,
            2 * times.len(),
            done..done + n,
            Some(observer),
        );
        wall_total += rep.wall.as_secs_f64();
        done += rep.images;
        epoch.images += rep.images;
        all_latencies.extend_from_slice(rep.latencies.samples());
        for (i, rr) in rep.replicas.iter().enumerate() {
            epoch.dispatched[i] += rep.dispatched[i];
            for (j, st) in rr.stages.iter().enumerate() {
                epoch.busy[i][j] += st.busy.as_secs_f64();
            }
        }

        if done >= deploy.images {
            break;
        }
        let status = detector.observe(&telemetry.snapshot());
        // Fresh window per control period — see simulate_adaptive.
        telemetry.clear_windows();
        if let DriftStatus::Confirmed(d) = status {
            Calibration::from_disturbance(&d).apply(&mut calibrated)?;
            let next = current.replan_on_matrix(&calibrated, power)?;
            adaptations.push(AdaptationEvent {
                // Same clock as the throttle script (see WallEnv), so
                // reported swap times are comparable with scripted `at`s.
                at_s: serve_start.elapsed().as_secs_f64(),
                after_images: done,
                disturbance: d.to_string(),
                from: current.partition_display(),
                to: next.partition_display(),
                predicted_throughput: next.throughput,
            });
            current = next;
            structures = replica_structures(&current, base)?;
            detector = DriftDetector::for_plan(&current, opts.drift)?;
            telemetry = Arc::new(Telemetry::for_plan(&current, opts.window));
            epoch = EpochStats::new(&current, wall_total);
        }
    }

    let epoch_wall = wall_total - epoch.start_t;
    if rec.enabled() {
        rec.observe_hist("latency", &crate::obs::LogHist::of(&all_latencies));
        rec.gauge_set("wall_s", wall_total);
    }
    let report = ServeReport {
        mode: ServeMode::Synthetic { time_scale: deploy.time_scale },
        network: current.network.clone(),
        images: done,
        wall_s: wall_total,
        throughput: if wall_total > 0.0 { done as f64 / wall_total } else { 0.0 },
        predicted_throughput: current.throughput,
        latency: latency_report(&all_latencies),
        replicas: epoch.replica_reports(&current, epoch_wall),
        adaptations,
        metrics: rec.snapshot(),
        // Wall-clock stage spans are on the sleep-scaled clock, so Eq. 10
        // residuals would be off-scale; `pipeit attrib --trace` handles
        // wall traces offline.
        attrib: None,
    };
    Ok(AdaptiveServe {
        final_snapshot: telemetry.snapshot(),
        post_swap_images: epoch.images,
        post_swap_wall_s: epoch_wall,
        final_plan: current,
        report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{PlanSpec, Strategy};
    use crate::cnn::zoo;
    use crate::config::Config;

    fn setup(net: &str, strategy: Strategy) -> (Config, TimeMatrix, Plan) {
        let cfg = Config::default();
        let network = zoo::by_name(net).unwrap();
        let tm = TimeMatrix::measured(&cfg.platform, &network);
        let plan = PlanSpec::new(net).strategy(strategy).compile().unwrap();
        (cfg, tm, plan)
    }

    #[test]
    fn throttle_spec_parsing() {
        let t = ClusterThrottle::parse("2.5:3").unwrap();
        assert_eq!(t.core, CoreType::Big);
        assert!((t.factor - 3.0).abs() < 1e-12);
        let t = ClusterThrottle::parse("0:0.5:small").unwrap();
        assert_eq!(t.core, CoreType::Small);
        assert!(ClusterThrottle::parse("").is_err());
        assert!(ClusterThrottle::parse("1:2:3:4").is_err());
        assert!(ClusterThrottle::parse("x:2").is_err());
        assert!(ClusterThrottle::parse("1:-2").is_err());
        assert!(ClusterThrottle::parse("1:2:medium").is_err());
    }

    #[test]
    fn stable_conditions_never_trigger_a_swap() {
        let (cfg, tm, plan) = setup("squeezenet", Strategy::Pipeline);
        // interval 100 keeps the per-period fill/drain transient small
        // enough that the DES tracks Eq. 12 closely even for deep pipelines.
        let opts = AdaptOptions { interval: 100, ..AdaptOptions::default() };
        let out = simulate_adaptive(&plan, &tm, &cfg.power, &[], &opts, 300, 2)
            .unwrap();
        assert!(out.report.adaptations.is_empty(), "{:?}", out.report.adaptations);
        assert_eq!(out.report.images, 300);
        assert_eq!(out.post_swap_images, 300);
        // Without disturbance the DES tracks the plan's Eq. 12 prediction.
        let rel = (out.report.throughput - plan.throughput).abs() / plan.throughput;
        assert!(rel < 0.1, "throughput {} vs predicted {}", out.report.throughput, plan.throughput);
        assert_eq!(out.final_plan, plan);
    }

    #[test]
    fn small_cluster_throttle_on_big_only_plan_is_invisible() {
        // A serial B4 plan never touches the small cluster: a small-cluster
        // throttle must neither drift nor swap.
        let (cfg, tm, plan) = setup("alexnet", Strategy::Serial);
        let script = [ClusterThrottle { at: 0.0, core: CoreType::Small, factor: 4.0 }];
        let out = simulate_adaptive(
            &plan,
            &tm,
            &cfg.power,
            &script,
            &AdaptOptions::default(),
            200,
            2,
        )
        .unwrap();
        assert!(out.report.adaptations.is_empty());
        let rel = (out.report.throughput - plan.throughput).abs() / plan.throughput;
        assert!(rel < 0.05, "{} vs {}", out.report.throughput, plan.throughput);
    }

    #[test]
    fn adaptive_wall_clock_deploy_processes_every_item() {
        // Threshold far above any scheduler jitter: the loop must pass
        // items through untouched with zero adaptations.
        let (cfg, tm, plan) = setup("squeezenet", Strategy::Pipeline);
        let opts = AdaptOptions {
            interval: 8,
            drift: DriftConfig { threshold: 50.0, ..DriftConfig::default() },
            ..AdaptOptions::default()
        };
        let deploy = DeployOptions {
            images: 24,
            time_scale: 0.02,
            ..DeployOptions::default()
        };
        let out =
            deploy_adaptive(&plan, &tm, &cfg.power, &[], &opts, &deploy).unwrap();
        assert_eq!(out.report.images, 24);
        assert!(out.report.adaptations.is_empty());
        assert!(out.report.throughput > 0.0);
        assert_eq!(out.report.replicas.len(), plan.num_replicas());
    }
}
