//! Drift detection: compare observed per-stage service times against the
//! plan's stored Eq. 10 predictions with an EWMA-smoothed ratio, a relative
//! threshold, and hysteresis, then classify the disturbance.
//!
//! The detector answers two questions the controller needs:
//!
//! 1. **Is the deployed plan still believable?** Per stage, the ratio
//!    `observed window mean / expected stage time` is smoothed with an EWMA
//!    across snapshots; a stage drifts when the smoothed ratio leaves the
//!    `1 ± threshold` band. Drift must persist for `hysteresis` consecutive
//!    snapshots before it is confirmed — a single noisy window (GC pause,
//!    scheduler hiccup) never triggers a re-plan.
//! 2. **What kind of disturbance is it?** If every stage running on one
//!    cluster drifted by a common factor it is a whole-cluster slowdown
//!    (thermal throttling / DVFS) and the calibrator should rescale *all*
//!    of that cluster's configurations — including counts the current
//!    pipeline does not use, so the re-plan sees the cluster as uniformly
//!    slower. Otherwise it is per-stage skew (e.g. a co-runner pinned to
//!    specific cores) and only the observed configurations are rescaled.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::Result;

use crate::api::Plan;
use crate::dse::{PipelineConfig, StageConfig};
use crate::simulator::platform::CoreType;

use super::telemetry::TelemetrySnapshot;

/// Detector tuning. Defaults suit the DES and the synthetic wall-clock
/// fleet; raise `threshold` on noisy shared hosts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftConfig {
    /// Relative deviation `|ratio - 1|` of the smoothed observed/expected
    /// ratio that counts as drift.
    pub threshold: f64,
    /// Consecutive drifting snapshots required of a *single stage* before
    /// its drift is confirmed (>= 1) — transient spikes on different
    /// stages in successive snapshots never add up to a confirmation.
    pub hysteresis: usize,
    /// Window samples a stage must hold at snapshot time to be judged.
    pub min_samples: u64,
    /// EWMA weight of the newest snapshot's window-mean ratio.
    pub ewma_alpha: f64,
    /// Max relative spread of per-stage factors still classified as one
    /// whole-cluster slowdown.
    pub cluster_spread: f64,
}

impl Default for DriftConfig {
    fn default() -> DriftConfig {
        DriftConfig {
            threshold: 0.35,
            hysteresis: 2,
            min_samples: 8,
            ewma_alpha: 0.5,
            cluster_spread: 0.25,
        }
    }
}

/// Classified disturbance, produced on confirmation.
#[derive(Debug, Clone, PartialEq)]
pub enum Disturbance {
    /// Every stage on `core`'s cluster drifted by a common factor.
    ClusterSlowdown { core: CoreType, factor: f64 },
    /// Individual stage configurations drifted by distinct factors:
    /// `(core, count, factor)` per affected configuration.
    StageSkew { configs: Vec<(CoreType, usize, f64)> },
}

impl fmt::Display for Disturbance {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Disturbance::ClusterSlowdown { core, factor } => {
                let name = match core {
                    CoreType::Big => "big",
                    CoreType::Small => "small",
                };
                write!(f, "{name}-cluster slowdown x{factor:.2}")
            }
            Disturbance::StageSkew { configs } => {
                write!(f, "stage skew")?;
                for (core, count, factor) in configs {
                    write!(f, " {}{count}x{factor:.2}", core.letter())?;
                }
                Ok(())
            }
        }
    }
}

/// Detector verdict for one snapshot.
#[derive(Debug, Clone, PartialEq)]
pub enum DriftStatus {
    /// No stage has accumulated `min_samples` yet.
    Cold,
    /// Every judged stage is inside the threshold band.
    Stable,
    /// Drift observed but not yet persistent enough to act on.
    Drifting { strikes: usize },
    /// Drift persisted for `hysteresis` snapshots — recalibrate and re-plan.
    Confirmed(Disturbance),
}

/// EWMA + threshold + hysteresis drift detector over a plan's expected
/// stage times.
#[derive(Debug)]
pub struct DriftDetector {
    cfg: DriftConfig,
    /// Expected per-stage service times (the plan's Eq. 10 predictions),
    /// indexed `[replica][stage]`.
    expected: Vec<Vec<f64>>,
    /// Stage configurations, same indexing (for disturbance classification).
    stages: Vec<Vec<StageConfig>>,
    /// Smoothed observed/expected ratio per stage.
    ewma: Vec<Vec<Option<f64>>>,
    /// Consecutive drifting snapshots per stage (hysteresis is per stage:
    /// a one-off spike on stage A followed by one on stage B must not sum
    /// to a confirmation no single stage sustained).
    strikes: Vec<Vec<usize>>,
}

impl DriftDetector {
    /// Build from explicit expectations. `expected[r][s]` must be a finite
    /// positive time for stage `s` of replica `r`, and `stages` must have
    /// the same shape.
    pub fn new(
        expected: Vec<Vec<f64>>,
        stages: Vec<Vec<StageConfig>>,
        cfg: DriftConfig,
    ) -> Result<DriftDetector> {
        anyhow::ensure!(cfg.hysteresis >= 1, "hysteresis must be >= 1");
        anyhow::ensure!(
            cfg.threshold.is_finite() && cfg.threshold > 0.0,
            "drift threshold must be positive"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&cfg.ewma_alpha) && cfg.ewma_alpha > 0.0,
            "ewma_alpha must be in (0, 1]"
        );
        anyhow::ensure!(
            expected.len() == stages.len()
                && expected.iter().zip(&stages).all(|(e, s)| e.len() == s.len()),
            "expected times and stage configs must have the same shape"
        );
        anyhow::ensure!(
            expected.iter().flatten().all(|t| t.is_finite() && *t > 0.0),
            "expected stage times must be finite and positive"
        );
        anyhow::ensure!(
            !expected.is_empty() && expected.iter().all(|e| !e.is_empty()),
            "detector needs at least one stage per replica"
        );
        let ewma = expected.iter().map(|e| vec![None; e.len()]).collect();
        let strikes = expected.iter().map(|e| vec![0; e.len()]).collect();
        Ok(DriftDetector { cfg, expected, stages, ewma, strikes })
    }

    /// Build from a deployed plan: expectations are the plan's stored
    /// Eq. 10 stage times, stage configurations come from parsing the
    /// replica pipelines. Errors for artifact/host plans (no `B4-s2-s2`
    /// structure to classify drift against).
    pub fn for_plan(plan: &Plan, cfg: DriftConfig) -> Result<DriftDetector> {
        anyhow::ensure!(
            plan.artifacts.is_none(),
            "drift detection needs a big.LITTLE plan with Eq. 10 stage times \
             (artifact plans have no cluster structure)"
        );
        let mut expected = Vec::with_capacity(plan.replicas.len());
        let mut stages = Vec::with_capacity(plan.replicas.len());
        for (i, r) in plan.replicas.iter().enumerate() {
            anyhow::ensure!(
                !r.stage_times.is_empty(),
                "replica {i} carries no stage-time profile"
            );
            let p = PipelineConfig::parse(&r.pipeline)?;
            anyhow::ensure!(
                p.num_stages() == r.stage_times.len(),
                "replica {i}: pipeline {} has {} stages but {} stage times",
                r.pipeline,
                p.num_stages(),
                r.stage_times.len()
            );
            expected.push(r.stage_times.clone());
            stages.push(p.stages.clone());
        }
        DriftDetector::new(expected, stages, cfg)
    }

    /// Ingest one telemetry snapshot and report the drift status. Stages
    /// whose window holds fewer than `min_samples` samples are skipped; a
    /// snapshot where no stage qualifies returns [`DriftStatus::Cold`]
    /// without touching any stage's hysteresis strikes.
    pub fn observe(&mut self, snap: &TelemetrySnapshot) -> DriftStatus {
        let mut any_ready = false;
        // (replica, stage, freshest window ratio) per drifted stage. The
        // EWMA decides *whether* a stage drifted; the latest window mean
        // (recent samples only) estimates *how much*, so the calibration
        // factor is not diluted by pre-disturbance history.
        let mut drifted: Vec<(usize, usize, f64)> = Vec::new();
        for r in 0..self.expected.len() {
            for s in 0..self.expected[r].len() {
                let Some(w) = snap.per_replica.get(r).and_then(|x| x.get(s)) else {
                    continue;
                };
                if (w.recent.len() as u64) < self.cfg.min_samples {
                    continue;
                }
                any_ready = true;
                let ratio = w.mean / self.expected[r][s];
                let e = match self.ewma[r][s] {
                    None => {
                        self.ewma[r][s] = Some(ratio);
                        ratio
                    }
                    Some(prev) => {
                        let e = self.cfg.ewma_alpha * ratio
                            + (1.0 - self.cfg.ewma_alpha) * prev;
                        self.ewma[r][s] = Some(e);
                        e
                    }
                };
                if (e - 1.0).abs() > self.cfg.threshold {
                    self.strikes[r][s] += 1;
                    drifted.push((r, s, ratio));
                } else {
                    self.strikes[r][s] = 0;
                }
            }
        }
        if !any_ready {
            return DriftStatus::Cold;
        }
        if drifted.is_empty() {
            return DriftStatus::Stable;
        }
        let max_strikes = drifted
            .iter()
            .map(|&(r, s, _)| self.strikes[r][s])
            .max()
            .unwrap_or(0);
        if max_strikes < self.cfg.hysteresis {
            return DriftStatus::Drifting { strikes: max_strikes };
        }
        // At least one stage sustained its drift for `hysteresis`
        // snapshots. Classification considers every currently-drifting
        // stage (a simultaneous cluster disturbance strikes them in step).
        let disturbance = self.classify(&drifted);
        self.reset();
        DriftStatus::Confirmed(disturbance)
    }

    /// Forget smoothing state and strikes (used after a plan swap; the
    /// controller normally builds a fresh detector for the new plan).
    pub fn reset(&mut self) {
        for row in &mut self.strikes {
            for k in row {
                *k = 0;
            }
        }
        for row in &mut self.ewma {
            for e in row {
                *e = None;
            }
        }
    }

    fn classify(&self, drifted: &[(usize, usize, f64)]) -> Disturbance {
        // Whole-cluster slowdown: every drifted stage sits on one cluster,
        // every stage of that cluster drifted, and the factors agree.
        let cores: Vec<CoreType> =
            drifted.iter().map(|&(r, s, _)| self.stages[r][s].core).collect();
        let first = cores[0];
        if cores.iter().all(|&c| c == first) {
            let present = self
                .stages
                .iter()
                .flatten()
                .filter(|sc| sc.core == first)
                .count();
            let ratios: Vec<f64> = drifted.iter().map(|&(_, _, f)| f).collect();
            let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
            let max = ratios.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            let min = ratios.iter().copied().fold(f64::INFINITY, f64::min);
            if drifted.len() == present && (max - min) / mean <= self.cfg.cluster_spread
            {
                return Disturbance::ClusterSlowdown { core: first, factor: mean };
            }
        }
        // Per-stage skew: average the ratios per distinct configuration.
        let mut groups: BTreeMap<(CoreType, usize), Vec<f64>> = BTreeMap::new();
        for &(r, s, ratio) in drifted {
            let sc = self.stages[r][s];
            groups.entry((sc.core, sc.count)).or_default().push(ratio);
        }
        Disturbance::StageSkew {
            configs: groups
                .into_iter()
                .map(|((core, count), ratios)| {
                    (core, count, ratios.iter().sum::<f64>() / ratios.len() as f64)
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapt::telemetry::{StageWindow, TelemetrySnapshot};
    use crate::util::proptest::check;

    /// Snapshot where each stage's window holds `count` copies of `mean`.
    fn snap(windows: &[&[(u64, f64)]]) -> TelemetrySnapshot {
        TelemetrySnapshot {
            per_replica: windows
                .iter()
                .map(|stages| {
                    stages
                        .iter()
                        .map(|&(count, mean)| StageWindow {
                            count,
                            mean,
                            recent: vec![mean; count as usize],
                        })
                        .collect()
                })
                .collect(),
        }
    }

    fn detector(expected: Vec<Vec<f64>>, pipes: &[&str], cfg: DriftConfig) -> DriftDetector {
        let stages = pipes
            .iter()
            .map(|p| PipelineConfig::parse(p).unwrap().stages)
            .collect();
        DriftDetector::new(expected, stages, cfg).unwrap()
    }

    #[test]
    fn cold_until_min_samples() {
        let mut d =
            detector(vec![vec![0.1]], &["B4"], DriftConfig::default());
        assert_eq!(d.observe(&snap(&[&[(3, 0.5)]])), DriftStatus::Cold);
        assert_eq!(d.observe(&snap(&[&[(8, 0.1)]])), DriftStatus::Stable);
    }

    #[test]
    fn confirms_cluster_slowdown_after_hysteresis() {
        let cfg = DriftConfig { hysteresis: 2, ..DriftConfig::default() };
        let mut d = detector(vec![vec![0.1, 0.05]], &["B4-s4"], cfg);
        // Big stage doubled, small stage nominal.
        let s = snap(&[&[(20, 0.2), (20, 0.05)]]);
        assert_eq!(d.observe(&s), DriftStatus::Drifting { strikes: 1 });
        match d.observe(&s) {
            DriftStatus::Confirmed(Disturbance::ClusterSlowdown { core, factor }) => {
                assert_eq!(core, CoreType::Big);
                assert!((factor - 2.0).abs() < 1e-9, "factor={factor}");
            }
            other => panic!("expected confirmed cluster slowdown, got {other:?}"),
        }
    }

    #[test]
    fn partial_cluster_drift_is_stage_skew() {
        // Two big stages, only one drifted: not a whole-cluster story.
        let cfg = DriftConfig { hysteresis: 1, ..DriftConfig::default() };
        let mut d = detector(vec![vec![0.1, 0.1, 0.05]], &["B2-B2-s4"], cfg);
        let s = snap(&[&[(20, 0.3), (20, 0.1), (20, 0.05)]]);
        match d.observe(&s) {
            DriftStatus::Confirmed(Disturbance::StageSkew { configs }) => {
                assert_eq!(configs.len(), 1);
                let (core, count, factor) = configs[0];
                assert_eq!(core, CoreType::Big);
                assert_eq!(count, 2);
                assert!((factor - 3.0).abs() < 1e-9, "factor={factor}");
            }
            other => panic!("expected stage skew, got {other:?}"),
        }
    }

    #[test]
    fn recovery_resets_strikes() {
        // ewma_alpha 1.0 isolates the hysteresis logic from smoothing.
        let cfg =
            DriftConfig { hysteresis: 3, ewma_alpha: 1.0, ..DriftConfig::default() };
        let mut d = detector(vec![vec![0.1]], &["B4"], cfg);
        let bad = snap(&[&[(20, 0.25)]]);
        let good = snap(&[&[(20, 0.1)]]);
        assert_eq!(d.observe(&bad), DriftStatus::Drifting { strikes: 1 });
        assert_eq!(d.observe(&bad), DriftStatus::Drifting { strikes: 2 });
        // A clean window: stable again, strikes gone — the next drift
        // starts its count from scratch.
        assert_eq!(d.observe(&good), DriftStatus::Stable);
        assert_eq!(d.observe(&bad), DriftStatus::Drifting { strikes: 1 });
    }

    #[test]
    fn spikes_on_different_stages_never_sum_to_a_confirmation() {
        // Hysteresis is per stage: a one-off spike on stage A followed by a
        // one-off spike on stage B is two transients, not persistent drift.
        let cfg =
            DriftConfig { hysteresis: 2, ewma_alpha: 1.0, ..DriftConfig::default() };
        let mut d = detector(vec![vec![0.1, 0.1]], &["B2-B2"], cfg);
        let spike_a = snap(&[&[(20, 0.3), (20, 0.1)]]);
        let spike_b = snap(&[&[(20, 0.1), (20, 0.3)]]);
        assert_eq!(d.observe(&spike_a), DriftStatus::Drifting { strikes: 1 });
        // Stage A recovered, stage B spikes: B's own strike count is 1.
        assert_eq!(d.observe(&spike_b), DriftStatus::Drifting { strikes: 1 });
        // Only when ONE stage sustains its drift does confirmation fire.
        assert!(matches!(d.observe(&spike_b), DriftStatus::Confirmed(_)));
    }

    #[test]
    fn speedup_drift_is_detected_too() {
        // A throttle being lifted (ratio < 1) is also a reason to re-plan.
        let cfg = DriftConfig { hysteresis: 1, ..DriftConfig::default() };
        let mut d = detector(vec![vec![0.1]], &["B4"], cfg);
        match d.observe(&snap(&[&[(20, 0.04)]])) {
            DriftStatus::Confirmed(Disturbance::ClusterSlowdown { core, factor }) => {
                assert_eq!(core, CoreType::Big);
                assert!(factor < 0.5);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn for_plan_rejects_artifact_plans() {
        use crate::api::{ArtifactBinding, PlanSpec};
        let mut plan = PlanSpec::new("alexnet").compile().unwrap();
        plan.artifacts =
            Some(ArtifactBinding { dir: "x".into(), num_layers: 3 });
        assert!(DriftDetector::for_plan(&plan, DriftConfig::default()).is_err());
    }

    #[test]
    fn for_plan_tracks_every_replica() {
        use crate::api::{PlanSpec, Strategy};
        let plan = PlanSpec::new("alexnet")
            .strategy(Strategy::Replicated { max_replicas: 2, exact: true })
            .compile()
            .unwrap();
        let mut d = DriftDetector::for_plan(&plan, DriftConfig::default()).unwrap();
        // A snapshot exactly matching the plan's expectations is stable.
        let s = TelemetrySnapshot {
            per_replica: plan
                .replicas
                .iter()
                .map(|r| {
                    r.stage_times
                        .iter()
                        .map(|&t| StageWindow { count: 50, mean: t, recent: vec![t; 50] })
                        .collect()
                })
                .collect(),
        };
        assert_eq!(d.observe(&s), DriftStatus::Stable);
    }

    /// Satellite property: stationary noise strictly inside the threshold
    /// band never confirms drift — the EWMA of in-band ratios stays in
    /// band, so no false-positive re-plans on noisy-but-honest telemetry.
    #[test]
    fn property_no_false_positive_on_stationary_noise() {
        check(150, |rng| {
            let threshold = rng.range_f64(0.1, 0.6);
            let cfg = DriftConfig {
                threshold,
                hysteresis: 1 + rng.index(3),
                min_samples: 4,
                ewma_alpha: rng.range_f64(0.2, 1.0),
                cluster_spread: 0.25,
            };
            let p = 1 + rng.index(3);
            let expected: Vec<f64> =
                (0..p).map(|_| rng.range_f64(0.01, 0.2)).collect();
            let pipe = vec![StageConfig::new(CoreType::Big, 1); p];
            let mut d =
                DriftDetector::new(vec![expected.clone()], vec![pipe], cfg).unwrap();
            for _ in 0..25 {
                let windows: Vec<StageWindow> = expected
                    .iter()
                    .map(|&t| {
                        // Noise bounded strictly inside the band.
                        let noise = rng.range_f64(-0.9 * threshold, 0.9 * threshold);
                        let mean = t * (1.0 + noise);
                        StageWindow { count: 50, mean, recent: vec![mean; 50] }
                    })
                    .collect();
                let status = d.observe(&TelemetrySnapshot {
                    per_replica: vec![windows],
                });
                crate::prop_assert!(
                    !matches!(status, DriftStatus::Confirmed(_)),
                    "false positive at threshold {threshold}: {status:?}"
                );
            }
            Ok(())
        });
    }
}
