//! Online recalibration: turn a confirmed [`Disturbance`] into multiplicative
//! corrections of the [`TimeMatrix`] the planner searches over.
//!
//! The key design choice (per the issue and the dynamic-distribution line of
//! work, arXiv 2107.05828): do **not** refit the Eq. 5–8 regression betas at
//! runtime. The fitted model's *structure* (relative layer costs, scaling
//! across core counts) is still right under a throttle — what moved is a
//! per-configuration scale. So calibration rescales the affected
//! `(core type, count)` columns of the matrix by the observed/expected
//! ratio and leaves everything else untouched; a whole-cluster slowdown
//! rescales every column of that cluster, including counts the running
//! pipeline never observed, so the re-plan sees the cluster as uniformly
//! slower rather than concluding that unobserved configurations became
//! relatively fast.
//!
//! Calibrations compose: applying a second correction on an
//! already-calibrated matrix multiplies the factors, which is exactly what
//! the detector produces (its expectations always come from the *current*
//! plan, i.e. the current matrix).

use anyhow::Result;

use crate::perfmodel::TimeMatrix;
use crate::simulator::platform::CoreType;

use super::drift::Disturbance;

/// One multiplicative correction of the time matrix.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ConfigScale {
    /// Scale every configuration of `core`'s cluster.
    Cluster { core: CoreType, factor: f64 },
    /// Scale the single `(core, count)` configuration.
    Config { core: CoreType, count: usize, factor: f64 },
}

/// A set of matrix corrections derived from one confirmed disturbance.
#[derive(Debug, Clone, PartialEq)]
pub struct Calibration {
    pub scales: Vec<ConfigScale>,
}

impl Calibration {
    /// Lower a classified disturbance into matrix corrections.
    pub fn from_disturbance(d: &Disturbance) -> Calibration {
        let scales = match d {
            Disturbance::ClusterSlowdown { core, factor } => {
                vec![ConfigScale::Cluster { core: *core, factor: *factor }]
            }
            Disturbance::StageSkew { configs } => configs
                .iter()
                .map(|&(core, count, factor)| ConfigScale::Config { core, count, factor })
                .collect(),
        };
        Calibration { scales }
    }

    /// Apply the corrections to `tm` in place. Errors (without partial
    /// application) on non-positive factors or unknown configurations.
    pub fn apply(&self, tm: &mut TimeMatrix) -> Result<()> {
        for s in &self.scales {
            let factor = match s {
                ConfigScale::Cluster { factor, .. } => *factor,
                ConfigScale::Config { factor, .. } => *factor,
            };
            anyhow::ensure!(
                factor.is_finite() && factor > 0.0,
                "calibration factor {factor} is not a positive finite number"
            );
            if let ConfigScale::Config { core, count, .. } = s {
                anyhow::ensure!(
                    tm.config_index(*core, *count).is_some(),
                    "time matrix has no ({}{count}) configuration to calibrate",
                    core.letter()
                );
            }
        }
        for s in &self.scales {
            match *s {
                ConfigScale::Cluster { core, factor } => tm.scale_core(core, factor),
                ConfigScale::Config { core, count, factor } => {
                    tm.scale_config(core, count, factor);
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapt::drift::{DriftConfig, DriftDetector, DriftStatus};
    use crate::adapt::telemetry::{StageWindow, TelemetrySnapshot};
    use crate::cnn::zoo;
    use crate::config::Config;
    use crate::dse::{self, PipelineConfig};
    use crate::perfmodel::TimeMatrix;
    use crate::util::proptest::check;

    #[test]
    fn cluster_calibration_scales_every_cluster_config() {
        let cfg = Config::default();
        let net = zoo::squeezenet();
        let base = TimeMatrix::measured(&cfg.platform, &net);
        let mut tm = base.clone();
        let cal = Calibration::from_disturbance(&Disturbance::ClusterSlowdown {
            core: CoreType::Big,
            factor: 2.0,
        });
        cal.apply(&mut tm).unwrap();
        for j in 0..base.num_layers() {
            for (ci, &(core, _)) in base.configs.iter().enumerate() {
                let f = if core == CoreType::Big { 2.0 } else { 1.0 };
                assert!((tm.layer(j, ci) - f * base.layer(j, ci)).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn skew_calibration_touches_only_named_configs() {
        let cfg = Config::default();
        let net = zoo::alexnet();
        let base = TimeMatrix::measured(&cfg.platform, &net);
        let mut tm = base.clone();
        let cal = Calibration::from_disturbance(&Disturbance::StageSkew {
            configs: vec![(CoreType::Small, 2, 1.7)],
        });
        cal.apply(&mut tm).unwrap();
        let s2 = base.config_index(CoreType::Small, 2).unwrap();
        for j in 0..base.num_layers() {
            for ci in 0..base.configs.len() {
                let f = if ci == s2 { 1.7 } else { 1.0 };
                assert!((tm.layer(j, ci) - f * base.layer(j, ci)).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn bad_calibrations_are_rejected_without_partial_application() {
        let cfg = Config::default();
        let base = TimeMatrix::measured(&cfg.platform, &zoo::mobilenet());
        let mut tm = base.clone();
        // Valid first entry + invalid second: nothing may change.
        let cal = Calibration {
            scales: vec![
                ConfigScale::Cluster { core: CoreType::Big, factor: 2.0 },
                ConfigScale::Config { core: CoreType::Big, count: 99, factor: 2.0 },
            ],
        };
        assert!(cal.apply(&mut tm).is_err());
        for j in 0..base.num_layers() {
            for ci in 0..base.configs.len() {
                assert_eq!(tm.layer(j, ci), base.layer(j, ci));
            }
        }
        let nan = Calibration {
            scales: vec![ConfigScale::Cluster { core: CoreType::Big, factor: f64::NAN }],
        };
        assert!(nan.apply(&mut tm).is_err());
    }

    /// Satellite property: detector + calibrator close the loop. Inject a
    /// known cluster slowdown into the "observed" times; the calibrated
    /// matrix must reproduce the injected factor within tolerance on every
    /// affected configuration and leave the other cluster untouched.
    #[test]
    fn property_calibrated_matrix_reproduces_injected_slowdown() {
        let cfg = Config::default();
        let nets = ["alexnet", "squeezenet", "mobilenet"];
        check(60, |rng| {
            let net = zoo::by_name(rng.choose(&nets)).unwrap();
            let base = TimeMatrix::measured(&cfg.platform, &net);
            let factor = rng.range_f64(1.5, 4.0);
            let core =
                if rng.index(2) == 0 { CoreType::Big } else { CoreType::Small };
            let mut truth = base.clone();
            truth.scale_core(core, factor);

            // A pipeline that uses both clusters observes the disturbance.
            let pipe = PipelineConfig::parse("B4-s2-s2").unwrap();
            let w = base.num_layers();
            let alloc = dse::work_flow(&base, &pipe, w);
            let expected = dse::stage_times(&base, &pipe, &alloc);
            let observed = dse::stage_times(&truth, &pipe, &alloc);

            let dcfg = DriftConfig { hysteresis: 1, ..DriftConfig::default() };
            let mut det = DriftDetector::new(
                vec![expected],
                vec![pipe.stages.clone()],
                dcfg,
            )
            .unwrap();
            let snap = TelemetrySnapshot {
                per_replica: vec![observed
                    .iter()
                    .map(|&t| StageWindow { count: 50, mean: t, recent: vec![t; 50] })
                    .collect()],
            };
            let status = det.observe(&snap);
            let DriftStatus::Confirmed(d) = status else {
                return Err(format!(
                    "factor {factor} on {core:?} not confirmed: {status:?}"
                ));
            };
            let mut calibrated = base.clone();
            Calibration::from_disturbance(&d).apply(&mut calibrated).unwrap();

            for j in 0..base.num_layers() {
                for (ci, &(c, _)) in base.configs.iter().enumerate() {
                    let want = truth.layer(j, ci);
                    let got = calibrated.layer(j, ci);
                    let tol = if c == core { 0.02 * want } else { 1e-12 };
                    crate::prop_assert!(
                        (got - want).abs() <= tol,
                        "config {ci} layer {j}: calibrated {got} vs truth {want} \
                         (factor {factor}, core {core:?})"
                    );
                }
            }
            Ok(())
        });
    }
}
