//! Per-stage service-time telemetry: lock-light ring buffers fed by the
//! stage workers, snapshotted as serializable [`TelemetrySnapshot`]s.
//!
//! [`Telemetry`] plugs into the executors through the
//! [`StageObserver`](crate::coordinator::StageObserver) hook
//! ([`crate::coordinator::run_pipeline_observed`] /
//! [`crate::coordinator::run_fleet_observed`]) and into the DES through the
//! `on_service` callback of
//! [`crate::simulator::pipeline_sim::simulate_replicated_disturbed`], so
//! the drift detector ([`crate::adapt::DriftDetector`]) sees the same
//! snapshot shape regardless of backend.
//!
//! The observability registry (DESIGN.md §13) taps the same hooks: a
//! [`Recorder`](crate::obs::Recorder) is itself a `StageObserver` feeding
//! `stage_service/*` histograms, and the adaptive controller fans one
//! observation stream out to both sinks with
//! [`FanoutObserver`](crate::coordinator::FanoutObserver) — telemetry
//! keeps its windowed rings for drift decisions; the registry keeps
//! whole-run mergeable histograms for reports and traces.
//!
//! Lock discipline: one mutex per `(replica, stage)` ring. Each ring is
//! written by exactly one stage worker and read only by the (infrequent)
//! control-loop snapshot, so the locks are effectively uncontended — no
//! global lock sits on the pipeline hot path.

use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::api::Plan;
use crate::coordinator::StageObserver;
use crate::util::json::Json;

/// Fixed-capacity ring of the most recent service-time samples.
#[derive(Debug)]
struct Ring {
    cap: usize,
    buf: Vec<f64>,
    /// Next write position (== oldest sample once the ring is full).
    next: usize,
    /// Samples ever recorded (not capped).
    total: u64,
}

impl Ring {
    fn new(cap: usize) -> Ring {
        Ring { cap: cap.max(1), buf: Vec::new(), next: 0, total: 0 }
    }

    fn push(&mut self, x: f64) {
        if self.buf.len() < self.cap {
            self.buf.push(x);
        } else {
            self.buf[self.next] = x;
        }
        self.next = (self.next + 1) % self.cap;
        self.total += 1;
    }

    fn mean(&self) -> f64 {
        if self.buf.is_empty() {
            0.0
        } else {
            self.buf.iter().sum::<f64>() / self.buf.len() as f64
        }
    }

    /// Window samples oldest → newest.
    fn ordered(&self) -> Vec<f64> {
        if self.buf.len() < self.cap {
            self.buf.clone()
        } else {
            let mut v = Vec::with_capacity(self.cap);
            v.extend_from_slice(&self.buf[self.next..]);
            v.extend_from_slice(&self.buf[..self.next]);
            v
        }
    }
}

/// Live telemetry store: one ring per `(replica, stage)`. Shape is fixed at
/// construction (it mirrors the deployed plan's partition); out-of-range
/// records are dropped, which makes stale observers harmless across a
/// drain-and-rebuild plan swap.
#[derive(Debug)]
pub struct Telemetry {
    rings: Vec<Vec<Mutex<Ring>>>,
}

impl Telemetry {
    /// `stages_per_replica[r]` is the stage count of replica `r`; `window`
    /// is the per-stage ring capacity.
    pub fn new(stages_per_replica: &[usize], window: usize) -> Telemetry {
        Telemetry {
            rings: stages_per_replica
                .iter()
                .map(|&p| (0..p).map(|_| Mutex::new(Ring::new(window))).collect())
                .collect(),
        }
    }

    /// Telemetry shaped after a plan's replica partition.
    pub fn for_plan(plan: &Plan, window: usize) -> Telemetry {
        let shape: Vec<usize> =
            plan.replicas.iter().map(|r| r.allocation.len()).collect();
        Telemetry::new(&shape, window)
    }

    /// Record one item's service time (seconds) on a stage. Unknown
    /// `(replica, stage)` coordinates are ignored.
    pub fn record(&self, replica: usize, stage: usize, service_s: f64) {
        if let Some(ring) = self.rings.get(replica).and_then(|r| r.get(stage)) {
            ring.lock().unwrap().push(service_s);
        }
    }

    /// Drop every ring's window samples, keeping cumulative counts. The
    /// controller calls this after each control-period snapshot so a
    /// window never mixes samples from different periods — crucial when a
    /// replica's per-period dispatch share is smaller than the ring, where
    /// stale pre-disturbance samples would otherwise dilute the estimated
    /// drift factor (and can demote a cluster slowdown to stage skew).
    pub fn clear_windows(&self) {
        for replica in &self.rings {
            for ring in replica {
                let mut r = ring.lock().unwrap();
                r.buf.clear();
                r.next = 0;
            }
        }
    }

    /// Point-in-time copy of every ring — what the drift detector consumes
    /// and what `serve --metrics-out` can persist.
    pub fn snapshot(&self) -> TelemetrySnapshot {
        TelemetrySnapshot {
            per_replica: self
                .rings
                .iter()
                .map(|replica| {
                    replica
                        .iter()
                        .map(|ring| {
                            let r = ring.lock().unwrap();
                            StageWindow {
                                count: r.total,
                                mean: r.mean(),
                                recent: r.ordered(),
                            }
                        })
                        .collect()
                })
                .collect(),
        }
    }
}

impl StageObserver for Telemetry {
    fn on_item(&self, replica: usize, stage: usize, service_s: f64) {
        self.record(replica, stage, service_s);
    }
}

/// One stage's telemetry window at snapshot time.
#[derive(Debug, Clone, PartialEq)]
pub struct StageWindow {
    /// Samples ever recorded on this stage (not capped by the window).
    pub count: u64,
    /// Mean of the `recent` window (0.0 when empty).
    pub mean: f64,
    /// The window samples, oldest → newest.
    pub recent: Vec<f64>,
}

/// Serializable snapshot of the whole telemetry store, indexed
/// `[replica][stage]`.
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetrySnapshot {
    pub per_replica: Vec<Vec<StageWindow>>,
}

impl TelemetrySnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "replicas",
            Json::Arr(
                self.per_replica
                    .iter()
                    .map(|stages| {
                        Json::Arr(
                            stages
                                .iter()
                                .map(|w| {
                                    Json::obj(vec![
                                        ("count", Json::num(w.count as f64)),
                                        ("mean", Json::num(w.mean)),
                                        (
                                            "recent",
                                            Json::Arr(
                                                w.recent
                                                    .iter()
                                                    .map(|&x| Json::num(x))
                                                    .collect(),
                                            ),
                                        ),
                                    ])
                                })
                                .collect(),
                        )
                    })
                    .collect(),
            ),
        )])
    }

    pub fn from_json(j: &Json) -> Result<TelemetrySnapshot> {
        let mut per_replica = Vec::new();
        for rj in j.req("replicas")?.as_arr().context("replicas array")? {
            let mut stages = Vec::new();
            for wj in rj.as_arr().context("stage array")? {
                let mut recent = Vec::new();
                for x in wj.req("recent")?.as_arr().context("recent array")? {
                    recent.push(x.as_f64().context("recent sample")?);
                }
                stages.push(StageWindow {
                    count: wj.req("count")?.as_usize().context("count")? as u64,
                    mean: wj.req("mean")?.as_f64().context("mean")?,
                    recent,
                });
            }
            per_replica.push(stages);
        }
        Ok(TelemetrySnapshot { per_replica })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_fleet_observed, StageSpec};
    use std::sync::Arc;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn ring_keeps_the_newest_window() {
        let mut r = Ring::new(3);
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            r.push(x);
        }
        assert_eq!(r.total, 5);
        assert_eq!(r.ordered(), vec![3.0, 4.0, 5.0]);
        assert!((r.mean() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn ring_partial_fill_is_in_order() {
        let mut r = Ring::new(8);
        r.push(0.5);
        r.push(1.5);
        assert_eq!(r.ordered(), vec![0.5, 1.5]);
        assert!((r.mean() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn out_of_range_records_are_dropped() {
        let t = Telemetry::new(&[2], 4);
        t.record(0, 0, 1.0);
        t.record(0, 5, 9.0); // no such stage
        t.record(7, 0, 9.0); // no such replica
        let snap = t.snapshot();
        assert_eq!(snap.per_replica.len(), 1);
        assert_eq!(snap.per_replica[0].len(), 2);
        assert_eq!(snap.per_replica[0][0].count, 1);
        assert_eq!(snap.per_replica[0][1].count, 0);
    }

    #[test]
    fn clear_windows_keeps_counts_but_drops_samples() {
        let t = Telemetry::new(&[1], 4);
        for x in [1.0, 2.0, 3.0] {
            t.record(0, 0, x);
        }
        t.clear_windows();
        let w = &t.snapshot().per_replica[0][0];
        assert_eq!(w.count, 3, "cumulative count survives the clear");
        assert!(w.recent.is_empty());
        assert_eq!(w.mean, 0.0);
        // The ring fills cleanly again afterwards.
        t.record(0, 0, 5.0);
        let w = &t.snapshot().per_replica[0][0];
        assert_eq!(w.recent, vec![5.0]);
        assert_eq!(w.count, 4);
    }

    #[test]
    fn snapshot_json_roundtrips() {
        let t = Telemetry::new(&[2, 1], 4);
        t.record(0, 0, 0.010);
        t.record(0, 1, 0.020);
        t.record(1, 0, 0.030);
        t.record(1, 0, 0.032);
        let snap = t.snapshot();
        let text = snap.to_json().to_string();
        let back =
            TelemetrySnapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(snap, back);
    }

    #[test]
    fn fleet_observer_fills_every_stage_ring() {
        let telemetry = Arc::new(Telemetry::new(&[1, 1], 16));
        let mk = || {
            vec![StageSpec::new(
                "st",
                Box::new(|| {
                    Box::new(|x: u64| {
                        thread::sleep(Duration::from_millis(1));
                        x
                    })
                }),
            )]
        };
        let obs: Arc<dyn StageObserver> = telemetry.clone();
        let (_, report) =
            run_fleet_observed(vec![mk(), mk()], 1, 2, 0..20u64, Some(obs));
        assert_eq!(report.images, 20);
        let snap = telemetry.snapshot();
        let total: u64 = snap.per_replica.iter().flatten().map(|w| w.count).sum();
        assert_eq!(total, 20, "every item recorded exactly once");
        for w in snap.per_replica.iter().flatten() {
            if w.count > 0 {
                assert!(w.mean >= 0.001, "sleep-stage service below 1ms: {}", w.mean);
            }
        }
    }
}
