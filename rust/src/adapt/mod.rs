//! Online adaptation (DESIGN.md §9): telemetry → drift detection → model
//! recalibration → live re-plan.
//!
//! Pipe-it's performance predictor (paper §V) is fit offline, but on real
//! big.LITTLE silicon the fitted times drift at runtime — thermal
//! throttling, DVFS governors, and co-runner contention skew cluster
//! service times and unbalance the pipeline (the failure mode the
//! dynamic-distribution line of work targets, arXiv 2107.05828 /
//! 2206.08662). This module closes the predict→plan→deploy loop that the
//! [`crate::api`] facade opens:
//!
//! * [`Telemetry`] — lock-light per-stage ring buffers of recent per-item
//!   service times, fed by the stage workers through the
//!   [`StageObserver`](crate::coordinator::StageObserver) hook and
//!   snapshotted as serializable [`TelemetrySnapshot`]s.
//! * [`DriftDetector`] — EWMA + threshold + hysteresis comparison of
//!   observed times against the deployed [`Plan`](crate::api::Plan)'s
//!   Eq. 10 predictions, classifying disturbances as whole-cluster
//!   slowdowns vs. per-stage skew ([`Disturbance`]).
//! * [`Calibration`] — rescales the affected `(core type, count)` columns
//!   of the [`TimeMatrix`](crate::perfmodel::TimeMatrix) from observed
//!   ratios, reusing the fitted model's structure instead of refitting
//!   betas at runtime.
//! * [`simulate_adaptive`] / [`deploy_adaptive`] — the control loop:
//!   re-runs the plan's strategy search on the calibrated matrix
//!   ([`Plan::replan_on_matrix`](crate::api::Plan::replan_on_matrix)) and
//!   hot-swaps the fleet at an item boundary, logging every switch as an
//!   [`AdaptationEvent`](crate::api::AdaptationEvent).
//!
//! The DES backend plus the scripted disturbance layer
//! ([`crate::simulator::pipeline_sim::ThrottleEvent`]) make the whole loop
//! testable deterministically (`tests/adapt_loop.rs` holds the
//! throttle-recovery acceptance test); the wall-clock backend powers
//! `pipeit serve --net N --adapt`.
//!
//! # Example
//!
//! ```
//! use pipeit::adapt::{simulate_adaptive, AdaptOptions, ClusterThrottle};
//! use pipeit::api::PlanSpec;
//! use pipeit::cnn::zoo;
//! use pipeit::config::Config;
//! use pipeit::perfmodel::TimeMatrix;
//! use pipeit::simulator::platform::CoreType;
//!
//! let cfg = Config::default();
//! let net = zoo::by_name("squeezenet").unwrap();
//! let tm = TimeMatrix::measured(&cfg.platform, &net);
//! let plan = PlanSpec::new("squeezenet").compile().unwrap();
//! // Big cluster throttles 2x shortly into the run…
//! let script = [ClusterThrottle { at: 0.5, core: CoreType::Big, factor: 2.0 }];
//! let out = simulate_adaptive(
//!     &plan, &tm, &cfg.power, &script, &AdaptOptions::default(), 400, 2,
//! ).unwrap();
//! // …the controller notices, recalibrates, and re-partitions the fleet.
//! assert_eq!(out.report.images, 400);
//! ```

pub mod calibrate;
pub mod controller;
pub mod drift;
pub mod telemetry;

pub use calibrate::{Calibration, ConfigScale};
pub use controller::{
    deploy_adaptive, deploy_adaptive_recorded, simulate_adaptive, simulate_adaptive_recorded,
    AdaptOptions, AdaptiveServe, ClusterThrottle,
};
pub use drift::{Disturbance, DriftConfig, DriftDetector, DriftStatus};
pub use telemetry::{StageWindow, Telemetry, TelemetrySnapshot};
