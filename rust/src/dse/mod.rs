//! Design-space exploration (paper §IV & §VI): pipeline configurations and
//! allocations, design-space counting (Eq. 1–2), the Pipe-it heuristic
//! (Algorithms 1–3), the exhaustive baseline for small spaces, the
//! energy-aware variant, and the replicated-pipeline extension
//! ([`replicated`]) that partitions the core budget across R independent
//! pipelines served as a fleet.
//!
//! # Example
//!
//! ```
//! use pipeit::cnn::zoo;
//! use pipeit::dse;
//! use pipeit::perfmodel::TimeMatrix;
//! use pipeit::simulator::platform::Platform;
//!
//! let platform = Platform::hikey970();
//! let tm = TimeMatrix::measured(&platform, &zoo::squeezenet());
//! let point = dse::explore(&tm, 4, 4);
//! assert!(point.pipeline.is_valid(4, 4));
//! assert!(point.allocation.is_partition(tm.num_layers()));
//! assert!(point.throughput > 0.0);
//! ```

pub mod algorithms;
pub mod config;
pub mod count;
pub mod energy;
pub mod exhaustive;
pub mod replicated;

pub use algorithms::{
    all_pipelines, explore, find_split, merge_stage, merge_stage_eq14, point_stage_times,
    work_flow, DsePoint,
};
pub use config::{
    pipeline_throughput, stage_times, Allocation, PipelineConfig, StageConfig,
};
pub use count::{binom, design_points, pipelines_with_p_stages, total_pipelines};
pub use energy::{explore_energy, pipeline_power, EnergyPoint};
pub use replicated::{
    explore_budget, explore_exact, explore_replicated, CoreBudget, ReplicaDesign,
    ReplicatedDesign,
};
