//! Design-space exploration (paper §IV & §VI): pipeline configurations and
//! allocations, design-space counting (Eq. 1–2), the Pipe-it heuristic
//! (Algorithms 1–3) and the exhaustive baseline for small spaces.

pub mod algorithms;
pub mod config;
pub mod count;
pub mod energy;
pub mod exhaustive;

pub use algorithms::{
    all_pipelines, explore, find_split, merge_stage, merge_stage_eq14, point_stage_times,
    work_flow, DsePoint,
};
pub use config::{
    pipeline_throughput, stage_times, Allocation, PipelineConfig, StageConfig,
};
pub use energy::{explore_energy, pipeline_power, EnergyPoint};
pub use count::{binom, design_points, pipelines_with_p_stages, total_pipelines};
