//! Design-space size (paper §IV-B, Eq. 1–2).

/// Binomial coefficient as u128 (overflow-safe for this domain).
pub fn binom(n: usize, k: usize) -> u128 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut acc: u128 = 1;
    for i in 0..k {
        acc = acc * (n - i) as u128 / (i + 1) as u128;
    }
    acc
}

/// Eq. (1): number of distinct pipelines with exactly `p` stages on an
/// `(hb + hs)`-core platform, stages homogeneous, Big stages before Small.
pub fn pipelines_with_p_stages(hb: usize, hs: usize, p: usize) -> u128 {
    if p < 2 {
        return 0;
    }
    let lo = 1.max(p.saturating_sub(hs));
    let hi = hb.min(p - 1);
    let mut total = 0u128;
    for pb in lo..=hi {
        let ps = p - pb;
        if ps < 1 || ps > hs {
            continue;
        }
        total += binom(hb - 1, pb - 1) * binom(hs - 1, ps - 1);
    }
    total
}

/// Total number of pipelines over all stage counts (p = 2..=hb+hs).
/// For the 4+4 prototype this is the paper's "64 possible pipelines".
pub fn total_pipelines(hb: usize, hs: usize) -> u128 {
    (2..=hb + hs).map(|p| pipelines_with_p_stages(hb, hs, p)).sum()
}

/// Eq. (2): total design points for a CNN with `w` major layers:
/// `D_W = sum_p C(W-1, p-1) * C_p`.
///
/// Note: the paper quotes 5,379,616 for MobileNet (W = 28) on the 4+4
/// platform; Eq. (2) as printed gives 4,272,048 — the paper's figure
/// corresponds to `C(W, p-1)` (equivalently W = 29). Both are exposed; the
/// Table/bench output reports the discrepancy.
pub fn design_points(w: usize, hb: usize, hs: usize) -> u128 {
    (2..=hb + hs)
        .map(|p| binom(w - 1, p - 1) * pipelines_with_p_stages(hb, hs, p))
        .sum()
}

/// The variant matching the paper's quoted MobileNet figure (split points
/// drawn from `C(W, p-1)` — one allocation may be empty).
pub fn design_points_paper_variant(w: usize, hb: usize, hs: usize) -> u128 {
    (2..=hb + hs)
        .map(|p| binom(w, p - 1) * pipelines_with_p_stages(hb, hs, p))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binom_basics() {
        assert_eq!(binom(5, 0), 1);
        assert_eq!(binom(5, 5), 1);
        assert_eq!(binom(5, 2), 10);
        assert_eq!(binom(27, 7), 888_030);
        assert_eq!(binom(3, 4), 0);
    }

    #[test]
    fn eq1_prototype_counts() {
        // Hand-computed for the 4+4 platform.
        let c: Vec<u128> = (2..=8).map(|p| pipelines_with_p_stages(4, 4, p)).collect();
        assert_eq!(c, vec![1, 6, 15, 20, 15, 6, 1]);
    }

    #[test]
    fn paper_64_pipelines() {
        // §IV-B: "there are in total 64 possible pipelines (with p=2 to 8)".
        assert_eq!(total_pipelines(4, 4), 64);
    }

    #[test]
    fn eq2_mobilenet_design_points() {
        // Eq. (2) as printed, W = 28 conv layers:
        assert_eq!(design_points(28, 4, 4), 4_272_048);
        // The paper's quoted figure (see doc comment):
        assert_eq!(design_points_paper_variant(28, 4, 4), 5_379_616);
    }

    #[test]
    fn design_space_grows_with_layers() {
        let mut prev = 0;
        for w in [11, 26, 28, 54, 58] {
            let d = design_points(w, 4, 4);
            assert!(d > prev);
            prev = d;
        }
        // ResNet50/GoogLeNet spaces are in the hundreds of millions —
        // exhaustive search at ~10 s per point would indeed take
        // "hundreds of days" (paper §VII-A).
        assert!(design_points(54, 4, 4) > 100_000_000);
    }

    #[test]
    fn asymmetric_platforms() {
        // 2 big + 4 small: p ranges 2..=6.
        let total = total_pipelines(2, 4);
        let by_hand: u128 = (2..=6).map(|p| pipelines_with_p_stages(2, 4, p)).sum();
        assert_eq!(total, by_hand);
        assert!(total > 0);
        // Degenerate single-cluster "platform" still well-defined.
        assert_eq!(pipelines_with_p_stages(4, 0, 2), 0);
    }
}
