//! Design-space size (paper §IV-B, Eq. 1–2).

/// Binomial coefficient as u128 (overflow-safe for this domain).
pub fn binom(n: usize, k: usize) -> u128 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut acc: u128 = 1;
    for i in 0..k {
        acc = acc * (n - i) as u128 / (i + 1) as u128;
    }
    acc
}

/// Eq. (1): number of distinct pipelines with exactly `p` stages on an
/// `(hb + hs)`-core platform, stages homogeneous, Big stages before Small.
pub fn pipelines_with_p_stages(hb: usize, hs: usize, p: usize) -> u128 {
    if p < 2 {
        return 0;
    }
    let lo = 1.max(p.saturating_sub(hs));
    let hi = hb.min(p - 1);
    let mut total = 0u128;
    for pb in lo..=hi {
        let ps = p - pb;
        if ps < 1 || ps > hs {
            continue;
        }
        total += binom(hb - 1, pb - 1) * binom(hs - 1, ps - 1);
    }
    total
}

/// Total number of pipelines over all stage counts (p = 2..=hb+hs).
/// For the 4+4 prototype this is the paper's "64 possible pipelines".
pub fn total_pipelines(hb: usize, hs: usize) -> u128 {
    (2..=hb + hs).map(|p| pipelines_with_p_stages(hb, hs, p)).sum()
}

/// Eq. (2): total design points for a CNN with `w` major layers:
/// `D_W = sum_p C(W-1, p-1) * C_p`.
///
/// Note: the paper quotes 5,379,616 for MobileNet (W = 28) on the 4+4
/// platform; Eq. (2) as printed gives 4,272,048 — the paper's figure
/// corresponds to `C(W, p-1)` (equivalently W = 29). Both are exposed; the
/// Table/bench output reports the discrepancy.
pub fn design_points(w: usize, hb: usize, hs: usize) -> u128 {
    (2..=hb + hs)
        .map(|p| binom(w - 1, p - 1) * pipelines_with_p_stages(hb, hs, p))
        .sum()
}

/// The variant matching the paper's quoted MobileNet figure (split points
/// drawn from `C(W, p-1)` — one allocation may be empty).
pub fn design_points_paper_variant(w: usize, hb: usize, hs: usize) -> u128 {
    (2..=hb + hs)
        .map(|p| binom(w, p - 1) * pipelines_with_p_stages(hb, hs, p))
        .sum()
}

/// Pipeline configurations available to ONE replica owning exactly `(b, s)`
/// cores: every composition of each cluster into stages, including
/// single-cluster and single-stage pipelines (a replica may be just `B4`).
/// Compositions of `n` cores number `2^(n-1)`, so this is
/// `2^(b-1) * 2^(s-1)` when both clusters are present.
pub fn budget_pipelines(b: usize, s: usize) -> u128 {
    if b == 0 && s == 0 {
        return 0;
    }
    let per_cluster = |n: usize| -> u128 {
        if n == 0 {
            1
        } else {
            1u128 << (n - 1)
        }
    };
    per_cluster(b) * per_cluster(s)
}

/// Number of distinct ways to partition `(hb, hs)` cores into at most
/// `max_replicas` disjoint non-empty replica budgets (order-free — budget
/// multisets). This is the outer factor of the replicated design space;
/// the enumeration itself lives in [`super::replicated::partitions`] (the
/// spaces are tiny — at most a few thousand partitions on real platforms).
pub fn core_partitions(hb: usize, hs: usize, max_replicas: usize) -> u128 {
    super::replicated::partitions(hb, hs, max_replicas).len() as u128
}

/// Multiset coefficient `C(m + k - 1, k)`: unordered selections of `k`
/// pipelines (with repetition) from `m` options — what `k` replicas with
/// identical budgets can jointly run.
fn multichoose(m: u128, k: usize) -> u128 {
    let mut acc: u128 = 1;
    for i in 1..=k as u128 {
        acc = acc * (m - 1 + i) / i;
    }
    acc
}

/// Total replicated fleet configurations, order-free (matching the
/// [`core_partitions`] convention): over every core partition, the product
/// across *runs of equal budgets* of `C(m + k - 1, k)` unordered pipeline
/// choices, where `m` is the run's [`budget_pipelines`] and `k` its
/// multiplicity — replicas are interchangeable, so `{B2, B1-B1}` and
/// `{B1-B1, B2}` are one fleet. (Layer allocations multiply on top exactly
/// as in Eq. 2, independently per replica — the full replicated design
/// space the `work_flow` heuristic collapses.)
pub fn replicated_pipelines(hb: usize, hs: usize, max_replicas: usize) -> u128 {
    let mut total = 0u128;
    for part in super::replicated::partitions(hb, hs, max_replicas) {
        // Partitions are canonically sorted, so equal budgets are adjacent.
        let mut prod: u128 = 1;
        let mut i = 0;
        while i < part.len() {
            let mut j = i;
            while j < part.len() && part[j] == part[i] {
                j += 1;
            }
            let m = budget_pipelines(part[i].big, part[i].small);
            prod *= multichoose(m, j - i);
            i = j;
        }
        total += prod;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binom_basics() {
        assert_eq!(binom(5, 0), 1);
        assert_eq!(binom(5, 5), 1);
        assert_eq!(binom(5, 2), 10);
        assert_eq!(binom(27, 7), 888_030);
        assert_eq!(binom(3, 4), 0);
    }

    #[test]
    fn eq1_prototype_counts() {
        // Hand-computed for the 4+4 platform.
        let c: Vec<u128> = (2..=8).map(|p| pipelines_with_p_stages(4, 4, p)).collect();
        assert_eq!(c, vec![1, 6, 15, 20, 15, 6, 1]);
    }

    #[test]
    fn paper_64_pipelines() {
        // §IV-B: "there are in total 64 possible pipelines (with p=2 to 8)".
        assert_eq!(total_pipelines(4, 4), 64);
    }

    #[test]
    fn eq2_mobilenet_design_points() {
        // Eq. (2) as printed, W = 28 conv layers:
        assert_eq!(design_points(28, 4, 4), 4_272_048);
        // The paper's quoted figure (see doc comment):
        assert_eq!(design_points_paper_variant(28, 4, 4), 5_379_616);
    }

    #[test]
    fn design_space_grows_with_layers() {
        let mut prev = 0;
        for w in [11, 26, 28, 54, 58] {
            let d = design_points(w, 4, 4);
            assert!(d > prev);
            prev = d;
        }
        // ResNet50/GoogLeNet spaces are in the hundreds of millions —
        // exhaustive search at ~10 s per point would indeed take
        // "hundreds of days" (paper §VII-A).
        assert!(design_points(54, 4, 4) > 100_000_000);
    }

    #[test]
    fn budget_pipelines_matches_eq1_on_the_full_budget() {
        // Both-cluster budgets reproduce the Eq. 1 count (64 on 4+4); the
        // single-cluster extension counts plain compositions.
        assert_eq!(budget_pipelines(4, 4), total_pipelines(4, 4));
        assert_eq!(budget_pipelines(4, 0), 8);
        assert_eq!(budget_pipelines(0, 4), 8);
        assert_eq!(budget_pipelines(1, 0), 1);
        assert_eq!(budget_pipelines(0, 0), 0);
    }

    #[test]
    fn core_partitions_small_cases() {
        // (1,1): [(1,1)] and [(1,0),(0,1)].
        assert_eq!(core_partitions(1, 1, 2), 2);
        assert_eq!(core_partitions(1, 1, 1), 1);
        // R capped at 1 always yields exactly the full-budget partition.
        assert_eq!(core_partitions(4, 4, 1), 1);
        // (2,0): [(2,0)] and [(1,0),(1,0)].
        assert_eq!(core_partitions(2, 0, 2), 2);
        // Degenerate inputs.
        assert_eq!(core_partitions(0, 0, 3), 0);
        assert_eq!(core_partitions(4, 4, 0), 0);
        // More replicas allowed -> at least as many partitions.
        let mut prev = 0;
        for r in 1..=8 {
            let c = core_partitions(4, 4, r);
            assert!(c >= prev);
            prev = c;
        }
        // No partition can have more than hb+hs non-empty budgets.
        assert_eq!(core_partitions(4, 4, 8), core_partitions(4, 4, 9));
    }

    #[test]
    fn replicated_space_contains_the_single_pipeline_space() {
        // R = 1 contributes budget_pipelines(4,4) = 64; more replicas only add.
        assert_eq!(replicated_pipelines(4, 4, 1), 64);
        assert!(replicated_pipelines(4, 4, 2) > 64);
        assert!(replicated_pipelines(4, 4, 4) > replicated_pipelines(4, 4, 2));
        // Hand check (1,1): [(1,1)] -> 1 pipeline; [(1,0),(0,1)] -> 1*1.
        assert_eq!(replicated_pipelines(1, 1, 2), 2);
    }

    #[test]
    fn replicated_fleets_with_equal_budgets_count_multisets() {
        // (4,0) into <=2 replicas: [(4,0)] -> 8 pipelines; [(3,0),(1,0)] ->
        // 4*1; [(2,0),(2,0)] -> unordered pairs over {B2, B1-B1} =
        // C(2+2-1, 2) = 3 (NOT 2^2 = 4: {B2,B1B1} and {B1B1,B2} are one
        // fleet). Total 8 + 4 + 3 = 15.
        assert_eq!(replicated_pipelines(4, 0, 2), 15);
        // (3,0) into <=3: [(3,0)] -> 4; [(2,0),(1,0)] -> 2*1; the three
        // identical (1,0) budgets have exactly one fleet. Total 7.
        assert_eq!(replicated_pipelines(3, 0, 3), 4 + 2 + 1);
    }

    #[test]
    fn asymmetric_platforms() {
        // 2 big + 4 small: p ranges 2..=6.
        let total = total_pipelines(2, 4);
        let by_hand: u128 = (2..=6).map(|p| pipelines_with_p_stages(2, 4, p)).sum();
        assert_eq!(total, by_hand);
        assert!(total > 0);
        // Degenerate single-cluster "platform" still well-defined.
        assert_eq!(pipelines_with_p_stages(4, 0, 2), 0);
    }
}
