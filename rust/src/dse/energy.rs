//! Energy-aware design-space exploration — extension motivated by the
//! paper's §VII-E DeepX comparison (DeepX minimizes energy under a latency
//! budget; Pipe-it maximizes throughput). This module closes the loop:
//! pick the pipeline that maximizes imgs/J subject to a throughput floor.

use crate::perfmodel::TimeMatrix;
use crate::simulator::platform::CoreType;
use crate::simulator::power::{ClusterActivity, PowerModel};

use super::algorithms::{all_pipelines, work_flow, DsePoint};
use super::config::{pipeline_throughput, stage_times, Allocation, PipelineConfig};

/// An energy-annotated design point.
#[derive(Debug, Clone)]
pub struct EnergyPoint {
    pub point: DsePoint,
    /// Average active power (W) from utilization-weighted busy cores.
    pub power_w: f64,
    /// imgs/J.
    pub efficiency: f64,
}

/// Power of a pipeline + allocation under a time matrix: each stage is busy
/// for `stage_time / bottleneck` of the steady-state cycle.
pub fn pipeline_power(
    tm: &TimeMatrix,
    power: &PowerModel,
    p: &PipelineConfig,
    alloc: &Allocation,
    mem_intensity: f64,
) -> f64 {
    let times = stage_times(tm, p, alloc);
    let bottleneck = times.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let (mut busy_b, mut busy_s) = (0.0, 0.0);
    for (stage, t) in p.stages.iter().zip(&times) {
        let util = if bottleneck > 0.0 { t / bottleneck } else { 0.0 };
        match stage.core {
            CoreType::Big => busy_b += util * stage.count as f64,
            CoreType::Small => busy_s += util * stage.count as f64,
        }
    }
    power.active_power(
        ClusterActivity {
            busy_cores: busy_b,
            powered: busy_b > 0.0,
            mem_intensity,
        },
        ClusterActivity {
            busy_cores: busy_s,
            powered: busy_s > 0.0,
            mem_intensity,
        },
    )
}

/// Energy-aware exploration: among all Eq. 1 pipelines (allocated by
/// `work_flow`), return the one with the best imgs/J whose throughput is at
/// least `min_throughput` (imgs/s). Returns `None` when no configuration
/// meets the floor.
pub fn explore_energy(
    tm: &TimeMatrix,
    power: &PowerModel,
    hb: usize,
    hs: usize,
    min_throughput: f64,
    mem_intensity: f64,
) -> Option<EnergyPoint> {
    let w = tm.num_layers();
    let mut best: Option<EnergyPoint> = None;
    for p in all_pipelines(tm, hb, hs) {
        let alloc = work_flow(tm, &p, w);
        let tp = pipeline_throughput(tm, &p, &alloc);
        if tp < min_throughput {
            continue;
        }
        let pw = pipeline_power(tm, power, &p, &alloc, mem_intensity);
        let eff = tp / pw;
        if best.as_ref().map_or(true, |b| eff > b.efficiency) {
            best = Some(EnergyPoint {
                point: DsePoint { pipeline: p, allocation: alloc, throughput: tp },
                power_w: pw,
                efficiency: eff,
            });
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::zoo;
    use crate::dse::explore;
    use crate::perfmodel::TimeMatrix;
    use crate::simulator::platform::Platform;

    fn setup(net: &str) -> (TimeMatrix, PowerModel) {
        let p = Platform::hikey970();
        (
            TimeMatrix::measured(&p, &zoo::by_name(net).unwrap()),
            PowerModel::default(),
        )
    }

    #[test]
    fn unconstrained_energy_point_is_most_efficient() {
        let (tm, pw) = setup("mobilenet");
        let e = explore_energy(&tm, &pw, 4, 4, 0.0, 0.6).unwrap();
        let t = explore(&tm, 4, 4);
        let t_power = pipeline_power(&tm, &pw, &t.pipeline, &t.allocation, 0.6);
        let t_eff = t.throughput / t_power;
        assert!(
            e.efficiency >= t_eff - 1e-9,
            "energy point {:.3} must beat throughput point {:.3} imgs/J",
            e.efficiency,
            t_eff
        );
    }

    #[test]
    fn throughput_floor_is_respected() {
        let (tm, pw) = setup("resnet50");
        let t = explore(&tm, 4, 4);
        let floor = 0.9 * t.throughput;
        let e = explore_energy(&tm, &pw, 4, 4, floor, 0.6).unwrap();
        assert!(e.point.throughput >= floor);
        // Infeasible floor -> None.
        assert!(explore_energy(&tm, &pw, 4, 4, t.throughput * 1.5, 0.6).is_none());
    }

    #[test]
    fn efficiency_decreases_as_floor_tightens() {
        let (tm, pw) = setup("squeezenet");
        let t = explore(&tm, 4, 4);
        let loose = explore_energy(&tm, &pw, 4, 4, 0.2 * t.throughput, 0.6).unwrap();
        let tight = explore_energy(&tm, &pw, 4, 4, 0.98 * t.throughput, 0.6).unwrap();
        assert!(loose.efficiency >= tight.efficiency - 1e-9);
    }

    #[test]
    fn power_between_cluster_bounds() {
        let (tm, pw) = setup("googlenet");
        let e = explore_energy(&tm, &pw, 4, 4, 0.0, 0.6).unwrap();
        assert!(e.power_w > 0.2, "implausibly low power");
        let all_on = pw.active_power(
            ClusterActivity { busy_cores: 4.0, powered: true, mem_intensity: 1.0 },
            ClusterActivity { busy_cores: 4.0, powered: true, mem_intensity: 1.0 },
        );
        assert!(e.power_w <= all_on + 1e-9);
    }
}
