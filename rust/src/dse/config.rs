//! Pipeline configurations and layer allocations (paper Table II notation).
//!
//! A pipeline `P = {P_1..P_p}` is a sequence of homogeneous stage configs
//! `(core_type, core_count)`; its layer allocation `L = {L_1..L_p}` assigns
//! a contiguous, in-order range of major layers to each stage (the CNN is a
//! chain, so allocations are always contiguous ranges).

use std::fmt;

use crate::perfmodel::TimeMatrix;
use crate::simulator::platform::CoreType;

/// One pipeline stage: `(core_type, core_count)` — e.g. `(B,3)`, written B3.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct StageConfig {
    pub core: CoreType,
    pub count: usize,
}

impl StageConfig {
    pub fn new(core: CoreType, count: usize) -> StageConfig {
        StageConfig { core, count }
    }
}

impl fmt::Display for StageConfig {
    /// The paper's `B3` / `s4` shorthand.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}{}", self.core.letter(), self.count)
    }
}

/// A pipeline configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PipelineConfig {
    pub stages: Vec<StageConfig>,
}

impl PipelineConfig {
    pub fn new(stages: Vec<StageConfig>) -> PipelineConfig {
        PipelineConfig { stages }
    }

    /// Parse the paper's `B4-s2-s2` notation.
    ///
    /// # Example
    ///
    /// ```
    /// use pipeit::dse::PipelineConfig;
    ///
    /// let p = PipelineConfig::parse("B4-s2-s2").unwrap();
    /// assert_eq!(p.num_stages(), 3);
    /// assert!(p.is_valid(4, 4));
    /// assert_eq!(p.to_string(), "B4-s2-s2");
    /// assert!(PipelineConfig::parse("X9").is_err());
    /// ```
    pub fn parse(s: &str) -> anyhow::Result<PipelineConfig> {
        let mut stages = Vec::new();
        for part in s.split('-') {
            let mut chars = part.chars();
            let c = chars
                .next()
                .and_then(CoreType::parse)
                .ok_or_else(|| anyhow::anyhow!("bad stage {part:?} in {s:?}"))?;
            let count: usize = chars
                .as_str()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad core count in {part:?}"))?;
            if count == 0 {
                anyhow::bail!("stage with zero cores in {s:?}");
            }
            stages.push(StageConfig::new(c, count));
        }
        if stages.is_empty() {
            anyhow::bail!("empty pipeline spec");
        }
        Ok(PipelineConfig::new(stages))
    }

    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    pub fn cores_used(&self, t: CoreType) -> usize {
        self.stages.iter().filter(|s| s.core == t).map(|s| s.count).sum()
    }

    /// Validity on a platform with `hb` Big and `hs` Small cores: per-type
    /// core budgets respected, every stage nonempty and homogeneous (by
    /// construction of `StageConfig`).
    pub fn is_valid(&self, hb: usize, hs: usize) -> bool {
        !self.stages.is_empty()
            && self.cores_used(CoreType::Big) <= hb
            && self.cores_used(CoreType::Small) <= hs
    }
}

impl fmt::Display for PipelineConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let parts: Vec<String> = self.stages.iter().map(|s| s.to_string()).collect();
        write!(f, "{}", parts.join("-"))
    }
}

/// Layer allocation: contiguous in-order ranges `[lo, hi)` per stage
/// (`lo == hi` means the stage is idle, the paper's `L_i = ∅`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allocation {
    pub ranges: Vec<(usize, usize)>,
}

impl Allocation {
    /// All `w` layers on stage 0, the rest empty (work_flow's initial state).
    pub fn all_on_first(p: usize, w: usize) -> Allocation {
        let mut ranges = vec![(w, w); p];
        ranges[0] = (0, w);
        Allocation { ranges }
    }

    /// Check the partition invariant: ranges are contiguous, ordered, and
    /// cover exactly `[0, w)`.
    pub fn is_partition(&self, w: usize) -> bool {
        let mut next = 0;
        for &(lo, hi) in &self.ranges {
            if lo > hi || lo != next {
                return false;
            }
            next = hi;
        }
        next == w
    }

    /// Count of non-empty stages.
    pub fn active_stages(&self) -> usize {
        self.ranges.iter().filter(|(lo, hi)| lo < hi).count()
    }

    /// The paper's `[a,b] - [c,d]` 1-based display (Table V/VI).
    pub fn display_1based(&self) -> String {
        self.ranges
            .iter()
            .filter(|(lo, hi)| lo < hi)
            .map(|&(lo, hi)| format!("[{},{}]", lo + 1, hi))
            .collect::<Vec<_>>()
            .join(" - ")
    }
}

/// Stage service times `T_{L_i}^{P_i}` (Eq. 10) for a pipeline + allocation
/// under a time matrix.
pub fn stage_times(tm: &TimeMatrix, p: &PipelineConfig, l: &Allocation) -> Vec<f64> {
    assert_eq!(p.num_stages(), l.ranges.len());
    p.stages
        .iter()
        .zip(&l.ranges)
        .map(|(s, &(lo, hi))| {
            let ci = tm
                .config_index(s.core, s.count)
                .unwrap_or_else(|| panic!("config {s} not in time matrix"));
            tm.range(lo, hi, ci)
        })
        .collect()
}

/// Pipeline throughput (Eq. 12): `1 / max_i T_{L_i}^{P_i}`.
pub fn pipeline_throughput(tm: &TimeMatrix, p: &PipelineConfig, l: &Allocation) -> f64 {
    let times = stage_times(tm, p, l);
    1.0 / times.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_display_roundtrip() {
        for s in ["B4-s4", "B4-s2-s2", "B2-B2-s3-s1", "B1-B1-B1-B1-s1-s1-s1-s1"] {
            let p = PipelineConfig::parse(s).unwrap();
            assert_eq!(p.to_string(), s);
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(PipelineConfig::parse("").is_err());
        assert!(PipelineConfig::parse("X4").is_err());
        assert!(PipelineConfig::parse("B0-s4").is_err());
        assert!(PipelineConfig::parse("B4-s").is_err());
    }

    #[test]
    fn validity_checks_core_budget() {
        let p = PipelineConfig::parse("B4-s2-s2").unwrap();
        assert!(p.is_valid(4, 4));
        assert!(!p.is_valid(3, 4));
        let p = PipelineConfig::parse("B2-B2-s3-s1").unwrap();
        assert!(p.is_valid(4, 4));
        assert_eq!(p.cores_used(CoreType::Big), 4);
        assert_eq!(p.cores_used(CoreType::Small), 4);
    }

    #[test]
    fn allocation_partition_invariant() {
        let a = Allocation { ranges: vec![(0, 25), (25, 54)] };
        assert!(a.is_partition(54));
        assert!(!a.is_partition(55));
        let gap = Allocation { ranges: vec![(0, 10), (11, 54)] };
        assert!(!gap.is_partition(54));
        let init = Allocation::all_on_first(8, 54);
        assert!(init.is_partition(54));
        assert_eq!(init.active_stages(), 1);
    }

    #[test]
    fn display_matches_paper_notation() {
        let a = Allocation { ranges: vec![(0, 35), (35, 44), (44, 54)] };
        assert_eq!(a.display_1based(), "[1,35] - [36,44] - [45,54]");
    }
}
