//! The Pipe-it design-space exploration (paper §VI, Algorithms 1–3).
//!
//! * `find_split` (Alg. 1) balances a contiguous workload between two
//!   adjacent stages by flowing layers from the faster front stage to the
//!   slower back stage while the front remains the bottleneck.
//! * `work_flow` (Alg. 2) sweeps `find_split` over all adjacent pairs until
//!   the allocation stabilizes ("workload as water flowing down").
//! * `merge_stage` (Alg. 3) starts from the all-single-core pipeline and
//!   greedily merges adjacent same-type stages while the Eq. 14 test says
//!   the merged stage beats the bottleneck of the pair, re-running
//!   `work_flow` after every merge.

use crate::perfmodel::TimeMatrix;
use crate::simulator::platform::CoreType;

use super::config::{pipeline_throughput, stage_times, Allocation, PipelineConfig, StageConfig};

/// Result of a design-space exploration.
#[derive(Debug, Clone)]
pub struct DsePoint {
    pub pipeline: PipelineConfig,
    pub allocation: Allocation,
    /// Predicted throughput (Eq. 12) under the time matrix used to search.
    pub throughput: f64,
}

/// Algorithm 1: split the contiguous layer range `[lo, hi)` between two
/// adjacent stages with time-matrix config indices `ci` (front) and `cj`
/// (back). Returns the split point `k`: front gets `[lo, k)`, back `[k, hi)`.
///
/// Layers flow from the back of the front stage while the front remains the
/// bottleneck after the move (`T_i - T_lj > T_j + T_lj`).
pub fn find_split(tm: &TimeMatrix, lo: usize, hi: usize, ci: usize, cj: usize) -> usize {
    let mut k = hi; // front owns everything (L_i = L_wl, L_{i+1} = ∅)
    let mut t_front = tm.range(lo, hi, ci);
    let mut t_back = 0.0;
    while k > lo {
        let l = k - 1; // last layer currently on the front stage
        let t_new_front = t_front - tm.layer(l, ci);
        let t_new_back = t_back + tm.layer(l, cj);
        // Move while it reduces the pair's bottleneck. This is the paper's
        // "front remains bottleneck" rule plus acceptance of the final
        // boundary move when the flipped bottleneck is still lower — a
        // strict improvement over the literal Alg. 1 exit condition.
        if t_new_front.max(t_new_back) < t_front.max(t_back) {
            t_front = t_new_front;
            t_back = t_new_back;
            k = l;
        } else {
            break; // further flow would just grow the new bottleneck
        }
    }
    k
}

/// Algorithm 2: allocate `w` layers over the pipeline by iterating
/// `find_split` over adjacent stage pairs until stable.
pub fn work_flow(tm: &TimeMatrix, pipeline: &PipelineConfig, w: usize) -> Allocation {
    let p = pipeline.num_stages();
    let cfg_idx: Vec<usize> = pipeline
        .stages
        .iter()
        .map(|s| {
            tm.config_index(s.core, s.count)
                .unwrap_or_else(|| panic!("stage {s} missing from time matrix"))
        })
        .collect();

    let mut alloc = Allocation::all_on_first(p, w);
    // First stage starts at 0; fix up the "empty" tail ranges to be
    // contiguous at w (all_on_first already guarantees this).
    let mut prev = Allocation { ranges: Vec::new() };
    let mut guard = 0;
    while alloc != prev {
        prev = alloc.clone();
        for i in 0..p.saturating_sub(1) {
            let (lo, _) = alloc.ranges[i];
            let (_, hi) = alloc.ranges[i + 1];
            let k = find_split(tm, lo, hi, cfg_idx[i], cfg_idx[i + 1]);
            alloc.ranges[i] = (lo, k);
            alloc.ranges[i + 1] = (k, hi);
        }
        guard += 1;
        assert!(guard < 10_000, "work_flow failed to converge");
    }
    debug_assert!(alloc.is_partition(w));
    alloc
}

/// Eq. 14 merge test: does the merged stage `P_i'` process `L_i ∪ L_{i+1}`
/// faster than the slower of the two current stages?
fn merge_helpful(
    tm: &TimeMatrix,
    merged: StageConfig,
    a: (StageConfig, (usize, usize)),
    b: (StageConfig, (usize, usize)),
) -> bool {
    let ci_merged = match tm.config_index(merged.core, merged.count) {
        Some(i) => i,
        None => return false, // would exceed the cluster size
    };
    let (sa, (lo_a, hi_a)) = a;
    let (sb, (lo_b, hi_b)) = b;
    let ca = tm.config_index(sa.core, sa.count).unwrap();
    let cb = tm.config_index(sb.core, sb.count).unwrap();
    let t_merged = tm.range(lo_a, hi_a, ci_merged) + tm.range(lo_b, hi_b, ci_merged);
    let t_max = tm.range(lo_a, hi_a, ca).max(tm.range(lo_b, hi_b, cb));
    t_merged < t_max
}

/// Order stages by compute capability (Eq. 11): ascending mean layer time,
/// so the most capable stage leads and workload flows one way.
pub(crate) fn sort_by_capability(tm: &TimeMatrix, stages: &mut [StageConfig]) {
    let means = tm.mean_per_config();
    stages.sort_by(|a, b| {
        let ta = means[tm.config_index(a.core, a.count).unwrap()];
        let tb = means[tm.config_index(b.core, b.count).unwrap()];
        ta.total_cmp(&tb)
    });
}

/// Initial pipeline: one single-core stage per core, capability-ordered.
fn initial_pipeline(tm: &TimeMatrix, hb: usize, hs: usize) -> PipelineConfig {
    let mut stages: Vec<StageConfig> = Vec::new();
    for _ in 0..hb {
        stages.push(StageConfig::new(CoreType::Big, 1));
    }
    for _ in 0..hs {
        stages.push(StageConfig::new(CoreType::Small, 1));
    }
    sort_by_capability(tm, &mut stages);
    PipelineConfig::new(stages)
}

/// Finalize a DSE point: drop idle stages (the paper reports only populated
/// stages, e.g. AlexNet's B4-s4 rather than B4-s4-...-∅) and close the
/// partition.
pub(crate) fn finalize(tm: &TimeMatrix, pipeline: PipelineConfig, alloc: Allocation) -> DsePoint {
    let w = tm.num_layers();
    let keep: Vec<usize> = (0..pipeline.num_stages())
        .filter(|&i| alloc.ranges[i].0 < alloc.ranges[i].1)
        .collect();
    let pipeline = PipelineConfig::new(keep.iter().map(|&i| pipeline.stages[i]).collect());
    let mut ranges: Vec<(usize, usize)> = keep.iter().map(|&i| alloc.ranges[i]).collect();
    let mut next = 0;
    for r in &mut ranges {
        r.0 = next;
        next = r.1.max(next);
        r.1 = next;
    }
    if let Some(last) = ranges.last_mut() {
        last.1 = w;
    }
    let alloc = Allocation { ranges };
    debug_assert!(alloc.is_partition(w));
    let throughput = pipeline_throughput(tm, &pipeline, &alloc);
    DsePoint { pipeline, allocation: alloc, throughput }
}

/// Algorithm 3 (Pipe-it default): greedy stage merging driven by the
/// *global* objective. Starting from the all-single-core pipeline, evaluate
/// every adjacent same-type merge by re-running `work_flow` and comparing
/// Eq. 12 throughput; apply the best improving merge; stop when none
/// improves. This subsumes the paper's Eq. 14 local test (kept as
/// [`merge_stage_eq14`] for the ablation bench): Eq. 14 implies a global
/// improvement whenever the merged pair contains the bottleneck, but misses
/// merges whose payoff appears only after reallocation.
pub fn merge_stage(tm: &TimeMatrix, hb: usize, hs: usize) -> DsePoint {
    let w = tm.num_layers();
    let mut pipeline = initial_pipeline(tm, hb, hs);
    let mut alloc = work_flow(tm, &pipeline, w);
    let mut tp = pipeline_throughput(tm, &pipeline, &alloc);

    loop {
        let mut best: Option<(f64, PipelineConfig, Allocation)> = None;
        for i in 0..pipeline.num_stages() - 1 {
            let (sa, sb) = (pipeline.stages[i], pipeline.stages[i + 1]);
            if sa.core != sb.core {
                continue;
            }
            let merged = StageConfig::new(sa.core, sa.count + sb.count);
            if tm.config_index(merged.core, merged.count).is_none() {
                continue; // exceeds cluster size
            }
            let mut stages = pipeline.stages.clone();
            stages[i] = merged;
            stages.remove(i + 1);
            sort_by_capability(tm, &mut stages);
            let cand = PipelineConfig::new(stages);
            let cand_alloc = work_flow(tm, &cand, w);
            let cand_tp = pipeline_throughput(tm, &cand, &cand_alloc);
            if cand_tp > tp && best.as_ref().map_or(true, |(b, _, _)| cand_tp > *b) {
                best = Some((cand_tp, cand, cand_alloc));
            }
        }
        match best {
            Some((btp, bp, ba)) => {
                tp = btp;
                pipeline = bp;
                alloc = ba;
            }
            None => break,
        }
    }

    finalize(tm, pipeline, alloc)
}

/// Algorithm 3 as printed in the paper: Eq. 14 local merge test, Big
/// cluster first then Small, retry the same position after a successful
/// merge, advance on failure. Kept for the ablation bench.
pub fn merge_stage_eq14(tm: &TimeMatrix, hb: usize, hs: usize) -> DsePoint {
    let w = tm.num_layers();
    let mut pipeline = initial_pipeline(tm, hb, hs);
    let mut alloc = work_flow(tm, &pipeline, w);

    for cluster in [CoreType::Big, CoreType::Small] {
        let mut i = match pipeline.stages.iter().position(|s| s.core == cluster) {
            Some(i) => i,
            None => continue,
        };
        loop {
            if i + 1 >= pipeline.num_stages() {
                break;
            }
            let (sa, sb) = (pipeline.stages[i], pipeline.stages[i + 1]);
            if sa.core != cluster || sb.core != cluster {
                break;
            }
            let merged = StageConfig::new(cluster, sa.count + sb.count);
            if tm.config_index(merged.core, merged.count).is_some()
                && merge_helpful(
                    tm,
                    merged,
                    (sa, alloc.ranges[i]),
                    (sb, alloc.ranges[i + 1]),
                )
            {
                let mut stages = pipeline.stages.clone();
                stages[i] = merged;
                stages.remove(i + 1);
                sort_by_capability(tm, &mut stages);
                pipeline = PipelineConfig::new(stages);
                alloc = work_flow(tm, &pipeline, w);
                i = pipeline
                    .stages
                    .iter()
                    .position(|s| *s == merged)
                    .unwrap_or(i)
                    .min(pipeline.num_stages().saturating_sub(2));
            } else {
                // Concavity (Fig. 11): a more capable merge of the same
                // stages would not help either — advance.
                i += 1;
            }
        }
    }

    finalize(tm, pipeline, alloc)
}

/// Convenience: stage times of a DSE point (for reports and the simulator).
pub fn point_stage_times(tm: &TimeMatrix, pt: &DsePoint) -> Vec<f64> {
    stage_times(tm, &pt.pipeline, &pt.allocation)
}

/// Positive-integer compositions of `n` into `parts` parts (ordered).
/// There are `C(n-1, parts-1)` of them — exactly the per-cluster factor in
/// the paper's Eq. 1.
pub(crate) fn compositions(n: usize, parts: usize) -> Vec<Vec<usize>> {
    fn rec(n: usize, parts: usize, cur: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
        if parts == 1 {
            cur.push(n);
            out.push(cur.clone());
            cur.pop();
            return;
        }
        for first in 1..=n - (parts - 1) {
            cur.push(first);
            rec(n - first, parts - 1, cur, out);
            cur.pop();
        }
    }
    let mut out = Vec::new();
    if parts >= 1 && n >= parts {
        rec(n, parts, &mut Vec::new(), &mut out);
    }
    out
}

/// All valid pipeline configurations on an `(hb + hs)` platform (the
/// paper's Eq. 1 space — 64 pipelines for 4+4), each capability-ordered.
pub fn all_pipelines(tm: &TimeMatrix, hb: usize, hs: usize) -> Vec<PipelineConfig> {
    let mut out = Vec::new();
    for pb in 1..=hb {
        for ps in 1..=hs {
            for big in compositions(hb, pb) {
                for small in compositions(hs, ps) {
                    let mut stages: Vec<StageConfig> = big
                        .iter()
                        .map(|&c| StageConfig::new(CoreType::Big, c))
                        .chain(small.iter().map(|&c| StageConfig::new(CoreType::Small, c)))
                        .collect();
                    sort_by_capability(tm, &mut stages);
                    out.push(PipelineConfig::new(stages));
                }
            }
        }
    }
    out
}

/// Pipe-it's default search: enumerate the Eq. 1 pipeline space (64 configs
/// on the 4+4 prototype — the *allocation* space is what explodes, and
/// `work_flow` collapses it), allocate each with `work_flow`, keep the
/// best. Strictly dominates greedy merging and is still sub-millisecond;
/// `merge_stage`/`merge_stage_eq14` remain as the paper-faithful ablations.
pub fn explore(tm: &TimeMatrix, hb: usize, hs: usize) -> DsePoint {
    let w = tm.num_layers();
    let mut best: Option<(f64, PipelineConfig, Allocation)> = None;
    for p in all_pipelines(tm, hb, hs) {
        let a = work_flow(tm, &p, w);
        let tp = pipeline_throughput(tm, &p, &a);
        if best.as_ref().map_or(true, |(b, _, _)| tp > *b) {
            best = Some((tp, p, a));
        }
    }
    let (_, p, a) = best.expect("nonempty pipeline space");
    finalize(tm, p, a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::zoo;
    use crate::perfmodel::{PerfModel, TimeMatrix};
    use crate::simulator::platform::Platform;
    use crate::util::proptest::check;
    use once_cell::sync::Lazy;

    static SETUP: Lazy<(Platform, PerfModel)> = Lazy::new(|| {
        let p = Platform::hikey970();
        let m = PerfModel::fit(&p);
        (p, m)
    });

    fn measured(net: &str) -> TimeMatrix {
        let (p, _) = &*SETUP;
        TimeMatrix::measured(p, &zoo::by_name(net).unwrap())
    }

    #[test]
    fn find_split_balances_two_identical_stages() {
        let tm = measured("squeezenet");
        let ci = tm.config_index(CoreType::Big, 2).unwrap();
        let k = find_split(&tm, 0, tm.num_layers(), ci, ci);
        // Identical configs: the split should land near the middle of the
        // cumulative-time curve — both sides within 2x of each other.
        let front = tm.range(0, k, ci);
        let back = tm.range(k, tm.num_layers(), ci);
        assert!(k > 0 && k < tm.num_layers());
        assert!(front < 2.0 * back && back < 2.0 * front, "front={front} back={back}");
    }

    #[test]
    fn find_split_front_remains_at_least_as_loaded() {
        // With a faster front stage, the front keeps the bigger share.
        let tm = measured("resnet50");
        let b4 = tm.config_index(CoreType::Big, 4).unwrap();
        let s4 = tm.config_index(CoreType::Small, 4).unwrap();
        let k = find_split(&tm, 0, tm.num_layers(), b4, s4);
        assert!(k > tm.num_layers() / 2, "B4 front should hold most layers, k={k}");
    }

    #[test]
    fn work_flow_produces_valid_partition() {
        let tm = measured("googlenet");
        let p = PipelineConfig::parse("B4-s2-s1-s1").unwrap();
        let a = work_flow(&tm, &p, tm.num_layers());
        assert!(a.is_partition(tm.num_layers()));
    }

    #[test]
    fn work_flow_beats_all_on_one_stage() {
        let tm = measured("resnet50");
        let p = PipelineConfig::parse("B4-s2-s2").unwrap();
        let a = work_flow(&tm, &p, tm.num_layers());
        let tp = pipeline_throughput(&tm, &p, &a);
        let all_first = Allocation::all_on_first(3, tm.num_layers());
        let tp0 = pipeline_throughput(&tm, &p, &all_first);
        assert!(tp > tp0, "balanced {tp} should beat unbalanced {tp0}");
    }

    #[test]
    fn explore_resnet50_shape() {
        // Paper Table IV/VI: ResNet50 uses all 8 cores with a multi-stage
        // pipeline; throughput must beat both homogeneous clusters.
        let tm = measured("resnet50");
        let pt = explore(&tm, 4, 4);
        assert!(pt.allocation.is_partition(tm.num_layers()));
        assert!(pt.pipeline.is_valid(4, 4));
        assert!(pt.pipeline.num_stages() >= 2);
        let b4 = tm.config_index(CoreType::Big, 4).unwrap();
        let tp_b4 = 1.0 / tm.range(0, tm.num_layers(), b4);
        assert!(
            pt.throughput > tp_b4,
            "pipe-it {:.2} must beat B4 {:.2}",
            pt.throughput,
            tp_b4
        );
    }

    #[test]
    fn explore_uses_both_clusters() {
        for net in ["alexnet", "googlenet", "mobilenet", "resnet50", "squeezenet"] {
            let tm = measured(net);
            let pt = explore(&tm, 4, 4);
            assert!(pt.pipeline.cores_used(CoreType::Big) >= 1, "{net}");
            assert!(pt.pipeline.cores_used(CoreType::Small) >= 1, "{net}");
        }
    }

    #[test]
    fn all_pipelines_matches_eq1_count() {
        let tm = measured("alexnet");
        // 64 pipelines on the 4+4 prototype (§IV-B) — compositions include
        // order, so the enumeration matches Eq. 1 exactly.
        assert_eq!(all_pipelines(&tm, 4, 4).len(), 64);
        for p in all_pipelines(&tm, 4, 4) {
            assert!(p.is_valid(4, 4));
        }
    }

    #[test]
    fn explore_dominates_merge_variants() {
        for net in ["alexnet", "googlenet", "mobilenet", "resnet50", "squeezenet"] {
            let tm = measured(net);
            let e = explore(&tm, 4, 4);
            let m = merge_stage(&tm, 4, 4);
            let m14 = merge_stage_eq14(&tm, 4, 4);
            assert!(e.throughput >= m.throughput - 1e-9, "{net}: explore < merge");
            assert!(e.throughput >= m14.throughput - 1e-9, "{net}: explore < eq14");
        }
    }

    #[test]
    fn explore_on_predicted_times_close_to_measured() {
        // §VII-B: configurations from predicted timings give within a few
        // percent of configurations from measured timings (paper: ~4%).
        let (p, model) = &*SETUP;
        for net in zoo::all_networks() {
            let tm_meas = TimeMatrix::measured(p, &net);
            let tm_pred = TimeMatrix::predicted(p, model, &net);
            let pt_pred = explore(&tm_pred, 4, 4);
            let pt_meas = explore(&tm_meas, 4, 4);
            // Evaluate BOTH points under measured times (what the board
            // would deliver).
            let tp_of = |pt: &DsePoint| {
                let a = work_flow(&tm_meas, &pt.pipeline, tm_meas.num_layers());
                pipeline_throughput(&tm_meas, &pt.pipeline, &a)
            };
            let a = tp_of(&pt_pred);
            let b = tp_of(&pt_meas);
            assert!(
                a > 0.80 * b,
                "{}: predicted-config {a:.2} vs measured-config {b:.2}",
                net.name
            );
        }
    }

    #[test]
    fn property_dse_output_always_valid() {
        let (p, _) = &*SETUP;
        let nets = zoo::all_networks();
        check(30, |rng| {
            let net = &nets[rng.index(nets.len())];
            // Randomly perturbed platform keeps the DSE honest.
            let mut plat = p.clone();
            plat.ruggedness = rng.range_f64(0.0, 0.25);
            plat.big.mac_ns = rng.range_f64(0.1, 0.5);
            plat.small.mac_ns = plat.big.mac_ns * rng.range_f64(1.2, 4.0);
            let tm = TimeMatrix::measured(&plat, net);
            for pt in [explore(&tm, 4, 4), merge_stage(&tm, 4, 4), merge_stage_eq14(&tm, 4, 4)]
            {
                crate::prop_assert!(
                    pt.allocation.is_partition(tm.num_layers()),
                    "{}: allocation not a partition",
                    net.name
                );
                crate::prop_assert!(pt.pipeline.is_valid(4, 4), "core budget violated");
                crate::prop_assert!(
                    pt.pipeline.num_stages() == pt.allocation.ranges.len(),
                    "stage/range length mismatch"
                );
                crate::prop_assert!(
                    pt.throughput.is_finite() && pt.throughput > 0.0,
                    "bad tp"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn property_work_flow_never_leaves_front_underloaded() {
        // One-way flow: for every adjacent pair, moving the boundary layer
        // backward must not reduce the bottleneck (local optimality).
        let tm = measured("mobilenet");
        let p = PipelineConfig::parse("B2-B2-s3-s1").unwrap();
        let a = work_flow(&tm, &p, tm.num_layers());
        let times = stage_times(&tm, &p, &a);
        let bottleneck = times.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        for i in 0..p.num_stages() - 1 {
            let (lo, hi) = a.ranges[i];
            if lo >= hi {
                continue;
            }
            // Move last layer of stage i to i+1 and recompute.
            let mut b = a.clone();
            b.ranges[i].1 -= 1;
            b.ranges[i + 1].0 -= 1;
            let t2 = stage_times(&tm, &p, &b);
            let new_bottleneck = t2.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            assert!(
                new_bottleneck >= bottleneck - 1e-12,
                "stage {i}: flowing one more layer would improve bottleneck"
            );
        }
    }
}
