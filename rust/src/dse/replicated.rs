//! Replicated-pipeline design-space exploration.
//!
//! The single-pipeline DSE ([`crate::dse::explore`]) picks ONE pipeline
//! spanning both clusters; its throughput is capped by the bottleneck stage
//! plus layer-granularity quantization (a stage boundary can only sit on a
//! layer boundary). Replication sidesteps both: partition the core budget
//! into R disjoint per-replica budgets, give each replica its own pipeline
//! over the *whole* network, and serve them behind one shared admission
//! queue ([`crate::coordinator::run_fleet`]). A replica processes complete
//! images, so the fleet's steady-state rate is the sum of replica rates.
//!
//! The searched space is therefore: every core partition into at most
//! `max_replicas` budgets ([`partitions`]), times the per-budget pipeline
//! space ([`explore_budget`]) — which, unlike the paper's Eq. 1 space, also
//! contains single-cluster and single-stage pipelines, because a replica
//! may own just `B4`. `R = 1` with the full budget reproduces the classic
//! space, so the replicated optimum never loses to [`crate::dse::explore`].
//! All designs are scored by the same Eq. 10/12 performance model and can
//! be cross-checked with
//! [`crate::simulator::pipeline_sim::simulate_replicated`].
//!
//! # Example
//!
//! ```
//! use pipeit::cnn::zoo;
//! use pipeit::dse;
//! use pipeit::perfmodel::TimeMatrix;
//! use pipeit::simulator::platform::Platform;
//!
//! let platform = Platform::hikey970();
//! let tm = TimeMatrix::measured(&platform, &zoo::alexnet());
//! let single = dse::explore(&tm, 4, 4);
//! let fleet = dse::explore_replicated(&tm, 4, 4, 4);
//! assert!(fleet.throughput >= single.throughput - 1e-9);
//! assert!(fleet.num_replicas() >= 1);
//! ```

use std::collections::HashMap;
use std::fmt;

use crate::perfmodel::TimeMatrix;

use super::algorithms::{compositions, finalize, sort_by_capability, work_flow, DsePoint};
use super::config::{pipeline_throughput, stage_times, PipelineConfig, StageConfig};
use crate::simulator::platform::CoreType;

/// Per-replica core budget: how many Big and Small cores the replica owns.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CoreBudget {
    pub big: usize,
    pub small: usize,
}

impl CoreBudget {
    pub fn new(big: usize, small: usize) -> CoreBudget {
        CoreBudget { big, small }
    }

    pub fn cores(&self) -> usize {
        self.big + self.small
    }
}

impl fmt::Display for CoreBudget {
    /// The CLI's `2B+1s` shorthand.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}B+{}s", self.big, self.small)
    }
}

/// One replica of a replicated design: its core budget and the pipeline
/// the per-budget DSE chose for it.
#[derive(Debug, Clone)]
pub struct ReplicaDesign {
    pub budget: CoreBudget,
    pub point: DsePoint,
}

/// A replicated serving design: R pipelines on disjoint core budgets.
#[derive(Debug, Clone)]
pub struct ReplicatedDesign {
    /// Replicas in budget-descending order (the [`partitions`] order).
    pub replicas: Vec<ReplicaDesign>,
    /// Aggregate predicted throughput: the sum of replica Eq. 12 rates.
    pub throughput: f64,
}

impl ReplicatedDesign {
    /// Wrap a single-pipeline design point as a one-replica design, so the
    /// plan facade ([`crate::api`]) can treat every strategy's result as a
    /// (possibly singleton) fleet.
    pub fn single(budget: CoreBudget, point: DsePoint) -> ReplicatedDesign {
        let throughput = point.throughput;
        ReplicatedDesign { replicas: vec![ReplicaDesign { budget, point }], throughput }
    }

    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// `B4 | s2-s2` style display: replica pipelines joined with `|`.
    pub fn partition_display(&self) -> String {
        self.replicas
            .iter()
            .map(|r| r.point.pipeline.to_string())
            .collect::<Vec<_>>()
            .join(" | ")
    }

    /// Per-replica stage service times under `tm` — the input to
    /// [`crate::simulator::pipeline_sim::simulate_replicated`] and to the
    /// synthetic-stage fleet built by `pipeit serve --net`.
    pub fn stage_times(&self, tm: &TimeMatrix) -> Vec<Vec<f64>> {
        self.replicas
            .iter()
            .map(|r| stage_times(tm, &r.point.pipeline, &r.point.allocation))
            .collect()
    }
}

/// All ways to split `(hb, hs)` cores into 1..=`max_replicas` disjoint,
/// exhaustive budgets: every core is assigned, every budget is non-empty,
/// and budgets are non-increasing (lexicographically on `(big, small)`) to
/// skip permutations of the same multiset.
pub fn partitions(hb: usize, hs: usize, max_replicas: usize) -> Vec<Vec<CoreBudget>> {
    fn rec(
        hb: usize,
        hs: usize,
        left: usize,
        max_budget: CoreBudget,
        cur: &mut Vec<CoreBudget>,
        out: &mut Vec<Vec<CoreBudget>>,
    ) {
        if hb == 0 && hs == 0 {
            if !cur.is_empty() {
                out.push(cur.clone());
            }
            return;
        }
        if left == 0 {
            return;
        }
        for b in (0..=hb).rev() {
            for s in (0..=hs).rev() {
                if b + s == 0 {
                    continue;
                }
                let budget = CoreBudget::new(b, s);
                if budget > max_budget {
                    continue;
                }
                cur.push(budget);
                rec(hb - b, hs - s, left - 1, budget, cur, out);
                cur.pop();
            }
        }
    }

    let mut out = Vec::new();
    if hb + hs > 0 && max_replicas > 0 {
        let mut cur = Vec::new();
        rec(hb, hs, max_replicas, CoreBudget::new(hb, hs), &mut cur, &mut out);
    }
    out
}

/// Best pipeline within one replica's (possibly single-cluster) core
/// budget. The space is every capability-ordered pipeline using *exactly*
/// the budget's cores: all compositions of `budget.big` Big cores into
/// 1..=big stages crossed with all compositions of `budget.small` — so
/// single-cluster budgets yield single-cluster pipelines and `B4` alone is
/// a valid (single-stage) pipeline, neither of which the paper's Eq. 1
/// space contains. Allocation is by `work_flow`, scoring by Eq. 12.
/// Returns `None` only for the empty budget.
pub fn explore_budget(tm: &TimeMatrix, budget: CoreBudget) -> Option<DsePoint> {
    if budget.cores() == 0 {
        return None;
    }
    let w = tm.num_layers();

    let cluster_options = |cores: usize, core: CoreType| -> Vec<Vec<StageConfig>> {
        if cores == 0 {
            return vec![Vec::new()];
        }
        let mut opts = Vec::new();
        for parts in 1..=cores {
            for comp in compositions(cores, parts) {
                opts.push(comp.iter().map(|&c| StageConfig::new(core, c)).collect());
            }
        }
        opts
    };
    let big_opts = cluster_options(budget.big, CoreType::Big);
    let small_opts = cluster_options(budget.small, CoreType::Small);

    let mut best: Option<(f64, PipelineConfig, super::config::Allocation)> = None;
    for bo in &big_opts {
        for so in &small_opts {
            let mut stages: Vec<StageConfig> = bo.iter().chain(so.iter()).copied().collect();
            if stages.is_empty() {
                continue;
            }
            sort_by_capability(tm, &mut stages);
            let p = PipelineConfig::new(stages);
            let a = work_flow(tm, &p, w);
            let tp = pipeline_throughput(tm, &p, &a);
            if best.as_ref().map_or(true, |(b, _, _)| tp > *b) {
                best = Some((tp, p, a));
            }
        }
    }
    best.map(|(_, p, a)| finalize(tm, p, a))
}

/// Search the replicated design space: every core partition into at most
/// `max_replicas` budgets, each budget's pipeline chosen by
/// [`explore_budget`], scored by the aggregate Eq. 12 rate sum. `R = 1`
/// is part of the space, so the result never loses to
/// [`crate::dse::explore`].
pub fn explore_replicated(
    tm: &TimeMatrix,
    hb: usize,
    hs: usize,
    max_replicas: usize,
) -> ReplicatedDesign {
    explore_partitions(tm, hb, hs, 1, max_replicas).expect("nonempty replicated design space")
}

/// Best design with *exactly* `replicas` pipelines (CLI `serve --replicas
/// R`). `None` when the core budget cannot host that many non-empty
/// replicas.
pub fn explore_exact(
    tm: &TimeMatrix,
    hb: usize,
    hs: usize,
    replicas: usize,
) -> Option<ReplicatedDesign> {
    explore_partitions(tm, hb, hs, replicas, replicas)
}

fn explore_partitions(
    tm: &TimeMatrix,
    hb: usize,
    hs: usize,
    r_min: usize,
    r_max: usize,
) -> Option<ReplicatedDesign> {
    let mut cache: HashMap<CoreBudget, Option<DsePoint>> = HashMap::new();
    let mut best: Option<ReplicatedDesign> = None;
    for part in partitions(hb, hs, r_max) {
        if part.len() < r_min {
            continue;
        }
        let mut replicas = Vec::with_capacity(part.len());
        let mut total = 0.0;
        let mut feasible = true;
        for &budget in &part {
            let point = cache
                .entry(budget)
                .or_insert_with(|| explore_budget(tm, budget))
                .clone();
            match point {
                Some(p) => {
                    total += p.throughput;
                    replicas.push(ReplicaDesign { budget, point: p });
                }
                None => {
                    feasible = false;
                    break;
                }
            }
        }
        if !feasible {
            continue;
        }
        if best.as_ref().map_or(true, |b| total > b.throughput) {
            best = Some(ReplicatedDesign { replicas, throughput: total });
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::zoo;
    use crate::dse::{count, explore};
    use crate::simulator::pipeline_sim;
    use crate::simulator::platform::Platform;
    use crate::util::proptest::check;

    fn measured(net: &str) -> TimeMatrix {
        TimeMatrix::measured(&Platform::hikey970(), &zoo::by_name(net).unwrap())
    }

    #[test]
    fn partitions_are_exhaustive_disjoint_and_canonical() {
        for (hb, hs, max_r) in [(4, 4, 4), (2, 6, 3), (4, 4, 1), (1, 1, 2)] {
            let parts = partitions(hb, hs, max_r);
            assert!(!parts.is_empty());
            for p in &parts {
                assert!(p.len() <= max_r);
                assert_eq!(p.iter().map(|b| b.big).sum::<usize>(), hb, "{p:?}");
                assert_eq!(p.iter().map(|b| b.small).sum::<usize>(), hs, "{p:?}");
                assert!(p.iter().all(|b| b.cores() >= 1));
                assert!(p.windows(2).all(|w| w[0] >= w[1]), "not canonical: {p:?}");
            }
        }
    }

    #[test]
    fn partitions_small_cases_by_hand() {
        // (1,1) into <=2: [(1,1)] and [(1,0),(0,1)].
        assert_eq!(partitions(1, 1, 2).len(), 2);
        // max_replicas = 1: only the full budget.
        assert_eq!(partitions(4, 4, 1), vec![vec![CoreBudget::new(4, 4)]]);
        // Counting helper agrees with the enumeration.
        for (hb, hs, r) in [(4, 4, 4), (2, 6, 3), (1, 1, 2), (3, 2, 5)] {
            assert_eq!(
                count::core_partitions(hb, hs, r),
                partitions(hb, hs, r).len() as u128
            );
        }
    }

    #[test]
    fn explore_budget_single_cluster_and_single_stage() {
        let tm = measured("alexnet");
        let pt = explore_budget(&tm, CoreBudget::new(4, 0)).unwrap();
        assert_eq!(pt.pipeline.cores_used(CoreType::Big), 4);
        assert_eq!(pt.pipeline.cores_used(CoreType::Small), 0);
        assert!(pt.allocation.is_partition(tm.num_layers()));
        // A pure-B4 single-stage pipeline is in the space, so the chosen
        // point is at least as fast as serial B4.
        let b4 = tm.config_index(CoreType::Big, 4).unwrap();
        let tp_b4 = 1.0 / tm.range(0, tm.num_layers(), b4);
        assert!(pt.throughput >= tp_b4 - 1e-12);
        assert!(explore_budget(&tm, CoreBudget::new(0, 0)).is_none());
    }

    #[test]
    fn full_budget_matches_or_beats_classic_explore() {
        // explore_budget(4,4) covers the Eq. 1 space (plus single-stage
        // configs the classic space lacks), so it can only be >=.
        for net in ["alexnet", "mobilenet", "resnet50"] {
            let tm = measured(net);
            let classic = explore(&tm, 4, 4);
            let budget = explore_budget(&tm, CoreBudget::new(4, 4)).unwrap();
            assert!(
                budget.throughput >= classic.throughput - 1e-9,
                "{net}: budget {:.3} < classic {:.3}",
                budget.throughput,
                classic.throughput
            );
        }
    }

    #[test]
    fn replicated_never_loses_to_single_pipeline() {
        for net in zoo::all_networks() {
            let tm = TimeMatrix::measured(&Platform::hikey970(), &net);
            let single = explore(&tm, 4, 4);
            let fleet = explore_replicated(&tm, 4, 4, 4);
            assert!(
                fleet.throughput >= single.throughput - 1e-9,
                "{}: fleet {:.3} < single {:.3}",
                net.name,
                fleet.throughput,
                single.throughput
            );
        }
    }

    #[test]
    fn replication_beats_the_best_single_pipeline_somewhere() {
        // The Pipe-it+fleet headline: for at least one network, splitting
        // the 4+4 budget into replicas beats the best single pipeline.
        let mut any_gain = false;
        for net in zoo::all_networks() {
            let tm = TimeMatrix::measured(&Platform::hikey970(), &net);
            let single = explore(&tm, 4, 4);
            let fleet = explore_replicated(&tm, 4, 4, 4);
            if fleet.throughput > single.throughput * 1.001 && fleet.num_replicas() > 1 {
                any_gain = true;
            }
        }
        assert!(any_gain, "no network benefits from replication");
    }

    #[test]
    fn exact_replica_count_is_honoured() {
        let tm = measured("mobilenet");
        for r in 1..=3 {
            let d = explore_exact(&tm, 4, 4, r).unwrap();
            assert_eq!(d.num_replicas(), r);
        }
        // 9 replicas cannot each own a core on an 8-core platform.
        assert!(explore_exact(&tm, 4, 4, 9).is_none());
    }

    #[test]
    fn design_is_internally_consistent_and_simulable() {
        let tm = measured("resnet50");
        let fleet = explore_replicated(&tm, 4, 4, 4);
        let sum: f64 = fleet.replicas.iter().map(|r| r.point.throughput).sum();
        assert!((fleet.throughput - sum).abs() < 1e-9);
        let times = fleet.stage_times(&tm);
        assert_eq!(times.len(), fleet.num_replicas());
        let sim = pipeline_sim::simulate_replicated(&times, 2000, 2);
        let rel = (sim.throughput - fleet.throughput).abs() / fleet.throughput;
        assert!(
            rel < 0.05,
            "DES {:.3} vs Eq. 12 aggregate {:.3} (rel {rel:.3})",
            sim.throughput,
            fleet.throughput
        );
    }

    #[test]
    fn property_replicated_design_always_valid() {
        let nets = zoo::all_networks();
        check(20, |rng| {
            let net = &nets[rng.index(nets.len())];
            let tm = TimeMatrix::measured(&Platform::hikey970(), net);
            let max_r = 1 + rng.index(4);
            let fleet = explore_replicated(&tm, 4, 4, max_r);
            crate::prop_assert!(
                fleet.num_replicas() >= 1 && fleet.num_replicas() <= max_r,
                "replica count {} outside 1..={max_r}",
                fleet.num_replicas()
            );
            let big: usize =
                fleet.replicas.iter().map(|r| r.budget.big).sum();
            let small: usize =
                fleet.replicas.iter().map(|r| r.budget.small).sum();
            crate::prop_assert!(big == 4 && small == 4, "budgets not a partition");
            for r in &fleet.replicas {
                crate::prop_assert!(
                    r.point.allocation.is_partition(tm.num_layers()),
                    "replica allocation not a partition"
                );
                crate::prop_assert!(
                    r.point.pipeline.cores_used(CoreType::Big) <= r.budget.big
                        && r.point.pipeline.cores_used(CoreType::Small) <= r.budget.small,
                    "replica exceeds its budget"
                );
                crate::prop_assert!(
                    r.point.throughput.is_finite() && r.point.throughput > 0.0,
                    "bad replica throughput"
                );
            }
            Ok(())
        });
    }
}
