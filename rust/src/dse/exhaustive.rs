//! Exhaustive search over layer allocations for a *fixed* pipeline config —
//! used to generate Fig. 8 (two-stage split sweep) and Fig. 9 (three-stage
//! split surface), and to validate the heuristic on small design spaces.

use crate::perfmodel::TimeMatrix;

use super::config::{pipeline_throughput, Allocation, PipelineConfig};

/// Fig. 8: throughput of a two-stage pipeline for every split point
/// `X = 1..W-1`. Returns `(x, throughput)` pairs.
pub fn two_stage_sweep(tm: &TimeMatrix, p: &PipelineConfig) -> Vec<(usize, f64)> {
    assert_eq!(p.num_stages(), 2);
    let w = tm.num_layers();
    (1..w)
        .map(|x| {
            let a = Allocation { ranges: vec![(0, x), (x, w)] };
            (x, pipeline_throughput(tm, p, &a))
        })
        .collect()
}

/// Fig. 9: throughput surface of a three-stage pipeline over split points
/// `(x1, x2)` with `1 <= x1 < x2 < W`. Returns `(x1, x2, throughput)`.
pub fn three_stage_surface(tm: &TimeMatrix, p: &PipelineConfig) -> Vec<(usize, usize, f64)> {
    assert_eq!(p.num_stages(), 3);
    let w = tm.num_layers();
    let mut out = Vec::new();
    for x1 in 1..w - 1 {
        for x2 in x1 + 1..w {
            let a = Allocation { ranges: vec![(0, x1), (x1, x2), (x2, w)] };
            out.push((x1, x2, pipeline_throughput(tm, p, &a)));
        }
    }
    out
}

/// Exhaustive best allocation for a fixed pipeline (all
/// `C(W-1, p-1)` split-point combinations). Exponential in stages — only
/// for validation and the figure benches.
pub fn best_allocation(tm: &TimeMatrix, p: &PipelineConfig) -> (Allocation, f64) {
    let w = tm.num_layers();
    let stages = p.num_stages();
    assert!(stages >= 1 && stages <= 5, "exhaustive search limited to <=5 stages");

    let mut best: Option<(Allocation, f64)> = None;
    let mut splits = vec![0usize; stages - 1];

    fn rec(
        tm: &TimeMatrix,
        p: &PipelineConfig,
        w: usize,
        splits: &mut Vec<usize>,
        depth: usize,
        start: usize,
        best: &mut Option<(Allocation, f64)>,
    ) {
        if depth == splits.len() {
            let mut ranges = Vec::with_capacity(splits.len() + 1);
            let mut lo = 0;
            for &s in splits.iter() {
                ranges.push((lo, s));
                lo = s;
            }
            ranges.push((lo, w));
            let a = Allocation { ranges };
            let tp = pipeline_throughput(tm, p, &a);
            if best.as_ref().map_or(true, |(_, b)| tp > *b) {
                *best = Some((a, tp));
            }
            return;
        }
        for s in start..w - (splits.len() - depth - 1) {
            splits[depth] = s;
            rec(tm, p, w, splits, depth + 1, s + 1, best);
        }
    }

    rec(tm, p, w, &mut splits, 0, 1, &mut best);
    best.expect("nonempty design space")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::zoo;
    use crate::dse::algorithms::work_flow;
    use crate::perfmodel::TimeMatrix;
    use crate::simulator::platform::Platform;

    fn tm(net: &str) -> TimeMatrix {
        TimeMatrix::measured(&Platform::hikey970(), &zoo::by_name(net).unwrap())
    }

    #[test]
    fn fig8_optimum_in_paper_band() {
        // Paper: optimal two-stage split ratio X/W ranges 0.60-0.90.
        for net in ["alexnet", "googlenet", "mobilenet", "resnet50", "squeezenet"] {
            let t = tm(net);
            let p = PipelineConfig::parse("B4-s4").unwrap();
            let sweep = two_stage_sweep(&t, &p);
            let (best_x, _) = sweep
                .iter()
                .max_by(|a, b| a.1.total_cmp(&b.1))
                .map(|(x, tp)| (*x, *tp))
                .unwrap();
            let ratio = best_x as f64 / t.num_layers() as f64;
            assert!(
                (0.5..0.95).contains(&ratio),
                "{net}: optimal split ratio {ratio:.2} outside the paper band"
            );
        }
    }

    #[test]
    fn fig9_three_stage_beats_two_stage_for_resnet() {
        // Paper: ResNet50 three-stage (B4-s2-s2) gains ~7% over two-stage.
        let t = tm("resnet50");
        let p2 = PipelineConfig::parse("B4-s4").unwrap();
        let p3 = PipelineConfig::parse("B4-s2-s2").unwrap();
        let best2 = two_stage_sweep(&t, &p2)
            .into_iter()
            .map(|(_, tp)| tp)
            .fold(f64::NEG_INFINITY, f64::max);
        let best3 = three_stage_surface(&t, &p3)
            .into_iter()
            .map(|(_, _, tp)| tp)
            .fold(f64::NEG_INFINITY, f64::max);
        assert!(
            best3 > best2 * 1.0,
            "three-stage {best3:.3} should be at least two-stage {best2:.3}"
        );
    }

    #[test]
    fn work_flow_matches_exhaustive_on_two_stages() {
        // The heuristic should land within 2% of the exhaustive optimum for
        // the simple two-stage pipeline.
        for net in ["alexnet", "squeezenet", "mobilenet"] {
            let t = tm(net);
            let p = PipelineConfig::parse("B4-s4").unwrap();
            let a = work_flow(&t, &p, t.num_layers());
            let tp_heur = pipeline_throughput(&t, &p, &a);
            let (_, tp_best) = best_allocation(&t, &p);
            assert!(
                tp_heur >= 0.98 * tp_best,
                "{net}: heuristic {tp_heur:.3} vs exhaustive {tp_best:.3}"
            );
        }
    }

    #[test]
    fn work_flow_near_exhaustive_three_stages() {
        let t = tm("resnet50");
        let p = PipelineConfig::parse("B4-s2-s2").unwrap();
        let a = work_flow(&t, &p, t.num_layers());
        let tp_heur = pipeline_throughput(&t, &p, &a);
        let (_, tp_best) = best_allocation(&t, &p);
        assert!(
            tp_heur >= 0.95 * tp_best,
            "heuristic {tp_heur:.3} vs exhaustive {tp_best:.3}"
        );
    }

    #[test]
    fn surface_size() {
        let t = tm("alexnet"); // W = 11
        let p = PipelineConfig::parse("B4-s2-s2").unwrap();
        let surface = three_stage_surface(&t, &p);
        // C(10, 2) = 45 points.
        assert_eq!(surface.len(), 45);
    }
}
