//! # Pipe-it: high-throughput CNN inference on ARM big.LITTLE multi-cores
//!
//! Reproduction of Wang et al., *High-Throughput CNN Inference on Embedded
//! ARM big.LITTLE Multi-Core Processors* (IEEE TCAD 2019) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the Pipe-it coordinator: per-layer performance
//!   prediction ([`perfmodel`]), design-space exploration ([`dse`]) — now
//!   including the replicated-pipeline space ([`dse::replicated`]) — the
//!   pipelined executor and replicated-serving fleet ([`coordinator`]), the
//!   big.LITTLE hardware substrate ([`simulator`]), baselines
//!   ([`baselines`]), and a PJRT runtime ([`runtime`]) that executes
//!   AOT-lowered per-layer HLO modules.
//! * **L2 (python/compile/model.py)** — CNN forward pass in JAX, lowered
//!   once to HLO text per major layer (`make artifacts`).
//! * **L1 (python/compile/kernels/)** — Pallas tiled im2col+GEMM kernels.
//!
//! Python never runs on the request path: the Rust binary loads
//! `artifacts/<net>/*.hlo.txt` and serves an image stream through a
//! multi-threaded pipeline, one stage per homogeneous core group — or
//! through R replicated pipelines behind one shared admission queue
//! ([`coordinator::run_fleet`]) when a single balanced pipeline stops
//! scaling.
//!
//! The whole lifecycle — predict, explore, execute — is exposed through the
//! [`api`] facade: a [`api::PlanSpec`] compiles to a serializable
//! [`api::Plan`] artifact that can be simulated ([`api::Plan::simulate`])
//! or deployed ([`api::Plan::deploy`]) anywhere, and the CLI subcommands
//! (`pipeit plan / serve / simulate`) are thin wrappers over it. At
//! runtime the [`adapt`] subsystem closes the loop: per-stage telemetry
//! from the running fleet feeds a drift detector that recalibrates the
//! time matrix and hot-swaps the partition when the hardware stops
//! behaving like the model (`pipeit serve --adapt`). The [`tenancy`]
//! subsystem co-serves several networks on one board: a joint cross-network
//! DSE splits the core budget across tenants and a shared SLA-aware front
//! door admits (or sheds) each tenant's Poisson arrivals
//! (`pipeit plan-multi / serve-multi / simulate-multi`).
//!
//! The [`cluster`] subsystem scales past one board: a fleet of
//! heterogeneous big.LITTLE boards (mixed core configs, each with its own
//! TimeMatrix source) behind a single front-door router. The cluster DSE
//! reuses the per-board searches and composes the results into a
//! serializable [`cluster::ClusterPlan`]; pluggable dispatch policies
//! (round-robin, least-outstanding-work, weighted power-of-two-choices)
//! route live traffic over per-board bounded admission queues, in both a
//! streaming deterministic DES and a wall-clock multi-fleet deploy
//! (`pipeit plan-cluster / serve-cluster / simulate-cluster`).
//!
//! The [`harness`] subsystem keeps all of the above measurable: a scenario
//! registry spanning every serving mode (each in its DES and wall-clock
//! twin), robust statistics, and a schema-versioned `BENCH_<n>.json`
//! artifact with a CI-overlap regression gate (`pipeit bench`) — and,
//! longitudinally, [`harness::BenchHistory`] reads a directory of those
//! artifacts as one per-scenario trajectory (`pipeit bench history`).
//!
//! The [`obs`] subsystem is the instrument panel shared by every serving
//! path: a [`obs::Recorder`] captures per-item spans (admit → stages →
//! depart, or shed) on both execution twins, feeds a metrics registry of
//! counters, gauges and mergeable log-bucketed latency histograms, and
//! exports schema-versioned JSONL traces (`--trace-out`) convertible to
//! Chrome-trace/Perfetto JSON (`pipeit trace convert`). On top of the
//! spans sits the explanation layer ([`obs::attrib`]): every recorded DES
//! run decomposes item latency into front-door wait + queue wait + stage
//! service and ranks each stage's residual against its Eq. 10 prediction
//! ([`obs::AttribReport`], `pipeit attrib`), while the DES engines
//! self-profile (event counts, heap/ring peaks, events per wall-second)
//! through [`obs::EngineProf`] into the same registry.
//!
//! Architecture details live in `DESIGN.md`; the quickstart and the
//! paper-to-module map live in `README.md`.

#![deny(rustdoc::broken_intra_doc_links)]

pub mod adapt;
pub mod api;
pub mod baselines;
pub mod cluster;
pub mod cnn;
pub mod config;
pub mod coordinator;
pub mod dse;
pub mod harness;
pub mod obs;
pub mod perfmodel;
pub mod reports;
pub mod runtime;
pub mod simulator;
pub mod tenancy;
pub mod util;
