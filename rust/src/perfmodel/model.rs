//! The paper's layer-level performance predictor (§V, Eq. 5–8).
//!
//! Single-core (Eq. 5): linear regression over the GEMM dims with
//! interaction terms,
//!
//!   T = b1*N + b2*K + b3*M + b4*NK + b5*KM + b6*NM + b7*NMK + b8
//!
//! Multi-core (Eq. 6–8): ARM-CL deals `n_iter = N/ts` row chunks to `H`
//! threads,
//!
//!   T_iter  = (T - a1)/n_iter + a2                     (6)
//!   T_multi = max_t(T_iter * iter_t) + a3              (7)
//!           = (T - a1)/H + a2 * N/(ts*H) + a3          (8, equal split)
//!
//! The alphas are fit per core type by OLS on multi-threaded micro-bench
//! measurements; the betas per (core type, layer kind-class) on single-core
//! measurements.

use crate::cnn::layer::{GemmDims, Layer, LayerKind};
use crate::simulator::platform::{CoreType, Platform};
use crate::util::linalg::{self, Mat};

use super::microbench::{self, Measurement};

/// Kind-class of the regression: dense GEMM (conv + fc) vs depthwise.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KindClass {
    Gemm,
    Depthwise,
}

impl KindClass {
    pub fn of(kind: LayerKind) -> KindClass {
        match kind {
            LayerKind::DwConv => KindClass::Depthwise,
            LayerKind::Conv | LayerKind::Fc => KindClass::Gemm,
        }
    }
}

/// Eq. 5 feature vector for a GEMM shape.
pub fn features(g: GemmDims) -> [f64; 8] {
    let (n, k, m) = (g.n as f64, g.k as f64, g.m as f64);
    [n, k, m, n * k, k * m, n * m, n * m * k, 1.0]
}

/// Fitted predictor for one core type.
#[derive(Debug, Clone)]
pub struct CoreModel {
    pub core: CoreType,
    /// Eq. 5 betas for dense GEMM layers.
    pub beta_gemm: [f64; 8],
    /// Eq. 5 betas for depthwise layers.
    pub beta_dw: [f64; 8],
    /// Eq. 6–8 alphas (a1, a2, a3).
    pub alpha: [f64; 3],
    /// ARM-CL row-tile size `ts` used for `n_iter`.
    pub tile_rows: usize,
}

impl CoreModel {
    fn beta(&self, kc: KindClass) -> &[f64; 8] {
        match kc {
            KindClass::Gemm => &self.beta_gemm,
            KindClass::Depthwise => &self.beta_dw,
        }
    }

    /// Eq. 5: single-core prediction (seconds).
    pub fn predict_1core(&self, layer: &Layer) -> f64 {
        let x = features(layer.gemm());
        let b = self.beta(KindClass::of(layer.kind));
        x.iter().zip(b).map(|(xi, bi)| xi * bi).sum::<f64>().max(1e-7)
    }

    /// Iteration count (paper: `n_iter = N / ts`; FC parallelizes along M).
    pub fn n_iterations(&self, layer: &Layer) -> usize {
        let g = layer.gemm();
        let rows = if layer.kind == LayerKind::Fc { g.m } else { g.n };
        rows.div_ceil(self.tile_rows).max(1)
    }

    /// Eq. 8: multi-core prediction (seconds) for `h` homogeneous cores.
    pub fn predict(&self, layer: &Layer, h: usize) -> f64 {
        let t1 = self.predict_1core(layer);
        if h == 1 {
            return t1;
        }
        let n_iter = self.n_iterations(layer) as f64;
        let [a1, a2, a3] = self.alpha;
        ((t1 - a1) / h as f64 + a2 * n_iter / h as f64 + a3).max(1e-7)
    }
}

/// Fit Eq. 5 betas by weighted least squares against single-core
/// measurements. Weights `1/T` minimize relative error — the micro-bench
/// grid spans five orders of magnitude in layer time, and the paper's
/// quality metric (Table III) is percentage error.
fn fit_betas(ms: &[&Measurement]) -> Option<[f64; 8]> {
    let rows: Vec<Vec<f64>> = ms
        .iter()
        .map(|m| features(m.layer.gemm()).to_vec())
        .collect();
    let y: Vec<f64> = ms.iter().map(|m| m.seconds).collect();
    let w: Vec<f64> = y.iter().map(|t| 1.0 / t.max(1e-9)).collect();
    let beta = linalg::wls(&Mat::from_rows(&rows), &y, &w)?;
    let mut out = [0.0; 8];
    out.copy_from_slice(&beta);
    Some(out)
}

/// Fit Eq. 8 alphas by WLS: `y - T1/H = a1*(-1/H) + a2*(n_iter/H) + a3`,
/// weighted `1/y` for relative-error minimization.
fn fit_alphas(
    ms: &[&Measurement],
    predict_1core: impl Fn(&Layer) -> f64,
    tile_rows: usize,
) -> Option<[f64; 3]> {
    let mut rows = Vec::new();
    let mut ys = Vec::new();
    let mut ws = Vec::new();
    for m in ms {
        if m.cores < 2 {
            continue;
        }
        let h = m.cores as f64;
        let t1 = predict_1core(&m.layer);
        let g = m.layer.gemm();
        let rows_dim = if m.layer.kind == LayerKind::Fc { g.m } else { g.n };
        let n_iter = rows_dim.div_ceil(tile_rows).max(1) as f64;
        rows.push(vec![-1.0 / h, n_iter / h, 1.0]);
        ys.push(m.seconds - t1 / h);
        ws.push(1.0 / m.seconds.max(1e-9));
    }
    let a = linalg::wls(&Mat::from_rows(&rows), &ys, &ws)?;
    let mut out = [0.0; 3];
    out.copy_from_slice(&a);
    Some(out)
}

/// Fit the full predictor for one core type from micro-bench measurements
/// taken on the (simulated) board.
pub fn fit_core_model(platform: &Platform, core: CoreType) -> CoreModel {
    let tile_rows = platform.tile_rows;

    let mut conv_ms = microbench::run_grid(platform, &microbench::conv_grid(), core);
    conv_ms.extend(microbench::run_grid(platform, &microbench::fc_grid(), core));
    let dw_ms = microbench::run_grid(platform, &microbench::dw_grid(), core);

    let conv_1: Vec<&Measurement> = conv_ms.iter().filter(|m| m.cores == 1).collect();
    let dw_1: Vec<&Measurement> = dw_ms.iter().filter(|m| m.cores == 1).collect();
    let beta_gemm = fit_betas(&conv_1).expect("conv beta fit");
    let beta_dw = fit_betas(&dw_1).expect("dw beta fit");

    // Alphas are fit on the dense-GEMM multi-core measurements, using the
    // Eq. 5 prediction as T (the paper derives Eq. 6 from the Eq. 5 T).
    let predict1 = |l: &Layer| {
        let x = features(l.gemm());
        let b = match KindClass::of(l.kind) {
            KindClass::Gemm => &beta_gemm,
            KindClass::Depthwise => &beta_dw,
        };
        x.iter().zip(b).map(|(xi, bi)| xi * bi).sum::<f64>().max(1e-7)
    };
    let all_multi: Vec<&Measurement> =
        conv_ms.iter().chain(dw_ms.iter()).filter(|m| m.cores >= 2).collect();
    let alpha = fit_alphas(&all_multi, predict1, tile_rows).expect("alpha fit");

    CoreModel { core, beta_gemm, beta_dw, alpha, tile_rows }
}

/// The paper's full predictor: one fitted model per core type.
#[derive(Debug, Clone)]
pub struct PerfModel {
    pub big: CoreModel,
    pub small: CoreModel,
}

impl PerfModel {
    /// Fit both core types from micro-benchmarks on the given platform.
    pub fn fit(platform: &Platform) -> PerfModel {
        PerfModel {
            big: fit_core_model(platform, CoreType::Big),
            small: fit_core_model(platform, CoreType::Small),
        }
    }

    pub fn core(&self, t: CoreType) -> &CoreModel {
        match t {
            CoreType::Big => &self.big,
            CoreType::Small => &self.small,
        }
    }

    /// Predicted time of one layer on a (core type, count) stage config.
    pub fn layer_time(&self, layer: &Layer, core: CoreType, h: usize) -> f64 {
        self.core(core).predict(layer, h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::zoo;
    use crate::simulator::gemm;
    use crate::util::stats;
    use once_cell::sync::Lazy;

    static MODEL: Lazy<(Platform, PerfModel)> = Lazy::new(|| {
        let p = Platform::hikey970();
        let m = PerfModel::fit(&p);
        (p, m)
    });

    #[test]
    fn features_shape() {
        let f = features(GemmDims { n: 2, k: 3, m: 5 });
        assert_eq!(f, [2.0, 3.0, 5.0, 6.0, 15.0, 10.0, 30.0, 1.0]);
    }

    #[test]
    fn predictions_positive_and_ordered() {
        let (_, model) = &*MODEL;
        let l = Layer::conv("c", 56, 56, 64, 3, 64, 1, 1);
        for core in [CoreType::Big, CoreType::Small] {
            let mut prev = f64::INFINITY;
            for h in 1..=4 {
                let t = model.layer_time(&l, core, h);
                assert!(t > 0.0);
                assert!(t < prev, "{core:?} h={h}");
                prev = t;
            }
        }
    }

    #[test]
    fn single_core_fit_quality_on_grid() {
        // In-sample MAPE of the Eq. 5 fit should be within the ruggedness
        // the linear form cannot express (~10%) plus model-form error.
        let (p, model) = &*MODEL;
        let grid = microbench::conv_grid();
        let (mut pred, mut truth) = (Vec::new(), Vec::new());
        for l in &grid {
            pred.push(model.big.predict_1core(l));
            truth.push(gemm::layer_time_1core(p, l, CoreType::Big));
        }
        let err = stats::mape(&pred, &truth);
        assert!(err < 25.0, "in-sample MAPE {err:.1}%");
    }

    /// Table III: per-config MAPE over the five CNNs' layers, for every
    /// homogeneous core allocation, should land in the paper's band
    /// (averages 13.2% Big / 11.4% Small; per-net up to ~21%).
    #[test]
    fn table3_prediction_error_band() {
        let (p, model) = &*MODEL;
        let mut big_errs = Vec::new();
        let mut small_errs = Vec::new();
        for net in zoo::all_networks() {
            for core in [CoreType::Big, CoreType::Small] {
                for h in 1..=4 {
                    let (mut pred, mut truth) = (Vec::new(), Vec::new());
                    for l in &net.layers {
                        pred.push(model.layer_time(l, core, h));
                        truth.push(gemm::layer_time(p, l, core, h));
                    }
                    let err = stats::mape(&pred, &truth);
                    assert!(
                        err < 45.0,
                        "{} {core:?}{h}: MAPE {err:.1}% is way off",
                        net.name
                    );
                    match core {
                        CoreType::Big => big_errs.push(err),
                        CoreType::Small => small_errs.push(err),
                    }
                }
            }
        }
        let big_avg = stats::mean(&big_errs);
        let small_avg = stats::mean(&small_errs);
        assert!(
            (4.0..22.0).contains(&big_avg),
            "Big avg MAPE {big_avg:.1}% outside plausible band"
        );
        assert!(
            (4.0..22.0).contains(&small_avg),
            "Small avg MAPE {small_avg:.1}% outside plausible band"
        );
    }

    #[test]
    fn relative_ordering_preserved_for_dse() {
        // §VII-B: what matters is that the predictor preserves the
        // relations between configs. Check Big-4 is predicted fastest and
        // Small-1 slowest for every ResNet50 layer.
        let (_, model) = &*MODEL;
        for l in &zoo::resnet50().layers {
            let b4 = model.layer_time(l, CoreType::Big, 4);
            let s1 = model.layer_time(l, CoreType::Small, 1);
            assert!(b4 < s1, "layer {}", l.name);
        }
    }
}
