//! Micro-benchmark generator (paper §V-B).
//!
//! The paper measures representative convolutional layers over a grid of
//! input/filter dimensions and fits the regression to those measurements:
//!
//!   Iw = Ih in {7, 14, 28, 56, 112}
//!   Fw = Fh in {1, 3, 5, 7, 11}
//!   Id = Fd in {32, 64, 92, 128, 192, 256}
//!   Ofm     in {32, 64, 92, 128, 192, 256}
//!
//! On this substrate the "board" is `simulator::gemm`; `run_grid` takes the
//! measurements the fit consumes.

use crate::cnn::layer::Layer;
use crate::simulator::platform::{CoreType, Platform};
use crate::simulator::gemm;

pub const IW: [usize; 5] = [7, 14, 28, 56, 112];
pub const F: [usize; 5] = [1, 3, 5, 7, 11];
pub const ID: [usize; 6] = [32, 64, 92, 128, 192, 256];
pub const OFM: [usize; 6] = [32, 64, 92, 128, 192, 256];

/// The §V-B grid of representative convolutional layers. Points whose
/// filter exceeds the input (f > iw) are invalid and skipped. To bound the
/// fit cost the depth axes are swept jointly, as the paper's grid implies
/// (Id = Fd) — `stride = 1`, `pad = f/2` (SAME-style), square inputs.
pub fn conv_grid() -> Vec<Layer> {
    let mut out = Vec::new();
    for &iw in &IW {
        for &f in &F {
            if f > iw {
                continue;
            }
            for &id in &ID {
                for &ofm in &OFM {
                    out.push(Layer::conv(
                        &format!("mb_{iw}x{iw}x{id}_f{f}_o{ofm}"),
                        iw,
                        iw,
                        id,
                        f,
                        ofm,
                        1,
                        f / 2,
                    ));
                }
            }
        }
    }
    out
}

/// Fully-connected micro-benchmarks ("representative layers" in §V-B):
/// GEMV-shaped N = 1 points covering the classifier-head sizes, without
/// which the Eq. 5 fit extrapolates badly on AlexNet's 9216x4096 FC.
pub fn fc_grid() -> Vec<Layer> {
    let mut out = Vec::new();
    for &cin in &[256usize, 1024, 2048, 4096, 6144, 9216] {
        for &cout in &[256usize, 1000, 2048, 4096] {
            out.push(Layer::fc(&format!("mbfc_{cin}x{cout}"), cin, cout));
        }
    }
    out
}

/// Depthwise micro-benchmarks (MobileNet's DW nodes need their own fit —
/// their per-channel mini-GEMMs behave nothing like dense GEMM).
pub fn dw_grid() -> Vec<Layer> {
    let mut out = Vec::new();
    for &iw in &IW {
        for &f in &[3usize, 5] {
            if f > iw {
                continue;
            }
            for &c in &ID {
                out.push(Layer::dw_conv(
                    &format!("mbdw_{iw}x{iw}x{c}_f{f}"),
                    iw,
                    iw,
                    c,
                    f,
                    1,
                    f / 2,
                ));
            }
        }
    }
    out
}

/// A single measurement: layer descriptor + measured time on (core, h).
#[derive(Debug, Clone)]
pub struct Measurement {
    pub layer: Layer,
    pub core: CoreType,
    pub cores: usize,
    pub seconds: f64,
}

/// Run a grid on the simulated board for every core count of one cluster.
pub fn run_grid(platform: &Platform, layers: &[Layer], core: CoreType) -> Vec<Measurement> {
    let max_h = platform.cluster(core).cores;
    let mut out = Vec::with_capacity(layers.len() * max_h);
    for l in layers {
        for h in 1..=max_h {
            out.push(Measurement {
                layer: l.clone(),
                core,
                cores: h,
                seconds: gemm::layer_time(platform, l, core, h),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_size_and_validity() {
        let g = conv_grid();
        // 7x7 input excludes f=11 => (5*5 - 1) * 36 = 864 points.
        assert_eq!(g.len(), 864);
        for l in &g {
            assert!(l.fh <= l.ih);
            let (oh, ow) = l.out_hw();
            assert!(oh > 0 && ow > 0);
        }
    }

    #[test]
    fn dw_grid_nonempty() {
        let g = dw_grid();
        assert!(g.len() >= 50);
    }

    #[test]
    fn measurements_cover_all_core_counts() {
        let p = Platform::hikey970();
        let small_grid = &conv_grid()[..10];
        let m = run_grid(&p, small_grid, CoreType::Big);
        assert_eq!(m.len(), 40);
        assert!(m.iter().all(|x| x.seconds > 0.0));
    }
}
