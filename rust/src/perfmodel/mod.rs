//! Layer-level performance estimation (paper §V): micro-benchmark
//! generation, the Eq. 5–8 regression predictor, and the time matrix `T`
//! consumed by the design-space exploration.

pub mod microbench;
pub mod model;
pub mod time_matrix;

pub use model::{features, fit_core_model, CoreModel, KindClass, PerfModel};
pub use time_matrix::TimeMatrix;
