//! The paper's time matrix `T` (§VI-A): execution time of every layer on
//! every possible homogeneous stage configuration. Built either from the
//! fitted predictor (Tables IV/V "predicted") or from board measurements —
//! here the simulator ground truth (Table VI "measured").

use crate::cnn::network::Network;
use crate::simulator::gemm;
use crate::simulator::platform::{CoreType, Platform};

use super::model::PerfModel;

/// `T[layer][config]` in seconds; configs are the platform's
/// `(core_type, count)` stage configurations in `Platform::stage_configs`
/// order.
#[derive(Debug, Clone)]
pub struct TimeMatrix {
    pub net_name: String,
    pub layer_names: Vec<String>,
    pub configs: Vec<(CoreType, usize)>,
    t: Vec<Vec<f64>>,
}

impl TimeMatrix {
    /// Build from the fitted performance predictor.
    pub fn predicted(platform: &Platform, model: &PerfModel, net: &Network) -> TimeMatrix {
        Self::build(platform, net, |l, core, h| model.layer_time(l, core, h))
    }

    /// Build from simulated board measurements.
    pub fn measured(platform: &Platform, net: &Network) -> TimeMatrix {
        Self::build(platform, net, |l, core, h| gemm::layer_time(platform, l, core, h))
    }

    fn build(
        platform: &Platform,
        net: &Network,
        f: impl Fn(&crate::cnn::layer::Layer, CoreType, usize) -> f64,
    ) -> TimeMatrix {
        let configs = platform.stage_configs();
        let t = net
            .layers
            .iter()
            .map(|l| configs.iter().map(|(c, h)| f(l, *c, *h)).collect())
            .collect();
        TimeMatrix {
            net_name: net.name.clone(),
            layer_names: net.layers.iter().map(|l| l.name.clone()).collect(),
            configs,
            t,
        }
    }

    pub fn num_layers(&self) -> usize {
        self.t.len()
    }

    pub fn config_index(&self, core: CoreType, h: usize) -> Option<usize> {
        self.configs.iter().position(|&(c, n)| c == core && n == h)
    }

    /// `T_{l_j}^{P_i}`: time of layer `j` on config index `ci`.
    pub fn layer(&self, j: usize, ci: usize) -> f64 {
        self.t[j][ci]
    }

    /// `T_{L_i}^{P_i}` (Eq. 10): summed time of the contiguous layer range
    /// `[lo, hi)` on config index `ci`.
    pub fn range(&self, lo: usize, hi: usize, ci: usize) -> f64 {
        (lo..hi).map(|j| self.t[j][ci]).sum()
    }

    /// Mean layer time per config — the Eq. 11 capability metric.
    pub fn mean_per_config(&self) -> Vec<f64> {
        (0..self.configs.len())
            .map(|ci| self.range(0, self.num_layers(), ci) / self.num_layers() as f64)
            .collect()
    }

    // ---- online recalibration (crate::adapt) ----------------------------

    /// Multiply every layer's time on the `(core, count)` configuration by
    /// `factor` — online recalibration of a single stage configuration from
    /// observed service times ([`crate::adapt::Calibration`]). Returns
    /// `false` (and changes nothing) when the platform has no such config.
    pub fn scale_config(&mut self, core: CoreType, count: usize, factor: f64) -> bool {
        assert!(factor.is_finite() && factor > 0.0, "scale factor must be positive");
        let Some(ci) = self.config_index(core, count) else {
            return false;
        };
        for row in &mut self.t {
            row[ci] *= factor;
        }
        true
    }

    /// Multiply every layer's time on every `core`-cluster configuration by
    /// `factor` — a whole-cluster disturbance (thermal throttling, DVFS
    /// governor) observed at runtime, or the injected ground truth in
    /// throttle-recovery tests.
    pub fn scale_core(&mut self, core: CoreType, factor: f64) {
        assert!(factor.is_finite() && factor > 0.0, "scale factor must be positive");
        let cols: Vec<usize> = self
            .configs
            .iter()
            .enumerate()
            .filter(|(_, &(c, _))| c == core)
            .map(|(ci, _)| ci)
            .collect();
        for row in &mut self.t {
            for &ci in &cols {
                row[ci] *= factor;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::zoo;
    use once_cell::sync::Lazy;

    static SETUP: Lazy<(Platform, PerfModel)> = Lazy::new(|| {
        let p = Platform::hikey970();
        let m = PerfModel::fit(&p);
        (p, m)
    });

    #[test]
    fn dimensions() {
        let (p, m) = &*SETUP;
        let net = zoo::squeezenet();
        let tm = TimeMatrix::predicted(p, m, &net);
        assert_eq!(tm.num_layers(), 26);
        assert_eq!(tm.configs.len(), 8);
        assert_eq!(tm.config_index(CoreType::Big, 4), Some(3));
        assert_eq!(tm.config_index(CoreType::Small, 1), Some(4));
    }

    #[test]
    fn range_is_sum_of_layers() {
        let (p, _) = &*SETUP;
        let net = zoo::alexnet();
        let tm = TimeMatrix::measured(p, &net);
        let manual: f64 = (2..5).map(|j| tm.layer(j, 0)).sum();
        assert!((tm.range(2, 5, 0) - manual).abs() < 1e-15);
        assert_eq!(tm.range(3, 3, 0), 0.0);
    }

    #[test]
    fn scale_config_touches_only_that_column() {
        let (p, _) = &*SETUP;
        let net = zoo::squeezenet();
        let mut tm = TimeMatrix::measured(p, &net);
        let base = tm.clone();
        assert!(tm.scale_config(CoreType::Big, 2, 1.5));
        let b2 = tm.config_index(CoreType::Big, 2).unwrap();
        for j in 0..tm.num_layers() {
            for ci in 0..tm.configs.len() {
                let expect = if ci == b2 { 1.5 * base.layer(j, ci) } else { base.layer(j, ci) };
                assert!((tm.layer(j, ci) - expect).abs() < 1e-15);
            }
        }
        // Unknown config: untouched, reported.
        assert!(!tm.scale_config(CoreType::Big, 99, 2.0));
    }

    #[test]
    fn scale_core_scales_every_cluster_column() {
        let (p, _) = &*SETUP;
        let net = zoo::alexnet();
        let mut tm = TimeMatrix::measured(p, &net);
        let base = tm.clone();
        tm.scale_core(CoreType::Small, 2.0);
        for j in 0..tm.num_layers() {
            for (ci, &(core, _)) in base.configs.iter().enumerate() {
                let f = if core == CoreType::Small { 2.0 } else { 1.0 };
                assert!((tm.layer(j, ci) - f * base.layer(j, ci)).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn measured_matches_simulator() {
        let (p, _) = &*SETUP;
        let net = zoo::mobilenet();
        let tm = TimeMatrix::measured(p, &net);
        let ci = tm.config_index(CoreType::Big, 4).unwrap();
        let direct = gemm::layers_time(p, &net.layers, CoreType::Big, 4);
        assert!((tm.range(0, net.layers.len(), ci) - direct).abs() < 1e-12);
    }
}
