//! Config system: platform and power-model descriptions loaded from JSON
//! files (see `configs/`), with the HiKey 970 defaults built in. Every CLI
//! subcommand accepts `--platform <file>` to retarget the whole framework
//! (simulator, predictor, DSE) at a different big.LITTLE configuration.

use std::path::Path;

use anyhow::{Context, Result};

use crate::simulator::platform::{ClusterSpec, CoreType, Platform};
use crate::simulator::power::PowerModel;
use crate::util::json::Json;

/// Full run configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub platform: Platform,
    pub power: PowerModel,
}

impl Default for Config {
    fn default() -> Self {
        Config { platform: Platform::hikey970(), power: PowerModel::default() }
    }
}

fn f64_or(j: &Json, key: &str, default: f64) -> f64 {
    j.get(key).and_then(Json::as_f64).unwrap_or(default)
}

fn usize_or(j: &Json, key: &str, default: usize) -> usize {
    j.get(key).and_then(Json::as_usize).unwrap_or(default)
}

fn cluster_from(j: &Json, base: &ClusterSpec) -> ClusterSpec {
    ClusterSpec {
        core_type: base.core_type,
        cores: usize_or(j, "cores", base.cores),
        freq_ghz: f64_or(j, "freq_ghz", base.freq_ghz),
        l2_bytes: usize_or(j, "l2_kb", base.l2_bytes / 1024) * 1024,
        mac_ns: f64_or(j, "mac_ns", base.mac_ns),
        mem_ns_per_byte: f64_or(j, "mem_ns_per_byte", base.mem_ns_per_byte),
        spill_ns_per_byte: f64_or(j, "spill_ns_per_byte", base.spill_ns_per_byte),
        dispatch_us: f64_or(j, "dispatch_us", base.dispatch_us),
        sync_us: f64_or(j, "sync_us", base.sync_us),
        contention: f64_or(j, "contention", base.contention),
    }
}

impl Config {
    /// Load from a JSON file; unspecified fields inherit HiKey 970
    /// defaults, so config files only state what differs.
    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let base = Config::default();

        let mut platform = base.platform.clone();
        if let Some(name) = j.get("name").and_then(Json::as_str) {
            platform.name = name.to_string();
        }
        if let Some(big) = j.get("big") {
            platform.big = cluster_from(big, &base.platform.big);
        }
        if let Some(small) = j.get("small") {
            platform.small = cluster_from(small, &base.platform.small);
        }
        platform.cci_factor = f64_or(&j, "cci_factor", base.platform.cci_factor);
        platform.cci_fixed_us = f64_or(&j, "cci_fixed_us", base.platform.cci_fixed_us);
        platform.tile_rows = usize_or(&j, "tile_rows", base.platform.tile_rows);
        platform.ruggedness = f64_or(&j, "ruggedness", base.platform.ruggedness);
        anyhow::ensure!(
            platform.big.cores >= 1 && platform.small.cores >= 1,
            "both clusters need at least one core"
        );
        anyhow::ensure!(platform.big.core_type == CoreType::Big);

        let mut power = base.power.clone();
        if let Some(pj) = j.get("power") {
            power.big_core_w = f64_or(pj, "big_core_w", power.big_core_w);
            power.small_core_w = f64_or(pj, "small_core_w", power.small_core_w);
            power.big_static_w = f64_or(pj, "big_static_w", power.big_static_w);
            power.small_static_w = f64_or(pj, "small_static_w", power.small_static_w);
            power.mem_w = f64_or(pj, "mem_w", power.mem_w);
            power.cci_w = f64_or(pj, "cci_w", power.cci_w);
        }

        Ok(Config { platform, power })
    }

    /// Load from an optional path, defaulting to HiKey 970.
    pub fn load_or_default(path: Option<&str>) -> Result<Config> {
        match path {
            Some(p) => Config::load(Path::new(p)),
            None => Ok(Config::default()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_hikey() {
        let c = Config::default();
        assert_eq!(c.platform.name, "hikey970");
        assert_eq!(c.platform.total_cores(), 8);
    }

    #[test]
    fn partial_override() {
        let dir = std::env::temp_dir().join("pipeit_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("plat.json");
        std::fs::write(
            &p,
            r#"{"name": "exynos-like", "big": {"cores": 2, "freq_ghz": 2.0},
                "small": {"cores": 6}, "cci_factor": 0.4,
                "power": {"big_core_w": 1.2}}"#,
        )
        .unwrap();
        let c = Config::load(&p).unwrap();
        assert_eq!(c.platform.name, "exynos-like");
        assert_eq!(c.platform.big.cores, 2);
        assert_eq!(c.platform.small.cores, 6);
        // Inherited defaults survive.
        assert_eq!(c.platform.small.l2_bytes, 1024 * 1024);
        assert!((c.platform.cci_factor - 0.4).abs() < 1e-12);
        assert!((c.power.big_core_w - 1.2).abs() < 1e-12);
        assert!((c.power.mem_w - PowerModel::default().mem_w).abs() < 1e-12);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_zero_core_cluster() {
        let dir = std::env::temp_dir().join("pipeit_cfg_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.json");
        std::fs::write(&p, r#"{"big": {"cores": 0}}"#).unwrap();
        assert!(Config::load(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(Config::load(Path::new("/nonexistent/x.json")).is_err());
        assert!(Config::load_or_default(None).is_ok());
    }
}
