//! The unified serving report: one shape for single-pipeline runs, fleet
//! runs, and discrete-event simulations, so every execution backend of a
//! [`Plan`](crate::api::Plan) prints through the same renderer
//! ([`crate::reports::render_serve`]).
//!
//! A [`ServeReport`] always looks like a fleet — a single pipeline is a
//! one-replica fleet — which keeps downstream consumers (CLI, examples,
//! tests) free of per-backend match arms.

use crate::coordinator::{FleetReport, RunReport};
use crate::simulator::pipeline_sim::FleetSimReport;
use crate::util::stats::{self, Summary};

use super::plan::Plan;

/// Which backend produced a [`ServeReport`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ServeMode {
    /// Discrete-event simulation ([`Plan::simulate`]).
    Des,
    /// Wall-clock run of the real thread fleet over synthetic sleep stages
    /// scaled by `time_scale` ([`Plan::deploy`] without artifacts).
    Synthetic { time_scale: f64 },
    /// Real PJRT execution over AOT artifacts ([`Plan::deploy`] with an
    /// artifact binding); `serial` is the one-thread kernel-level analogue.
    Pjrt { serial: bool },
}

/// Latency percentiles in seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyReport {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Per-stage accounting within one replica.
#[derive(Debug, Clone, PartialEq)]
pub struct StageReport {
    pub name: String,
    pub items: usize,
    pub busy_s: f64,
    /// Busy fraction against the run's wall clock.
    pub utilization: f64,
}

/// One replica's slice of the run.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicaReport {
    /// The plan's pipeline shorthand (`B4-s2-s2`, `host-3`, `full-net`).
    pub pipeline: String,
    /// 1-based layer-allocation display (`[1,35] - [36,54]`).
    pub allocation: String,
    /// Items routed to this replica.
    pub dispatched: usize,
    /// Throughput against the replica's own clock (imgs/s).
    pub throughput: f64,
    /// Bottleneck-stage busy fraction (1.0 = never idle).
    pub utilization: f64,
    /// Bottleneck stage index, when the backend knows it (DES only).
    pub bottleneck: Option<usize>,
    pub stages: Vec<StageReport>,
}

/// Unified result of serving a [`Plan`] through any backend.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeReport {
    pub mode: ServeMode,
    /// Network (or artifact model) name from the plan.
    pub network: String,
    /// Items that completed across all replicas.
    pub images: usize,
    /// Wall-clock (or simulated-clock) duration in seconds.
    pub wall_s: f64,
    /// Aggregate throughput over `wall_s` (imgs/s).
    pub throughput: f64,
    /// The plan's predicted aggregate Eq. 12 throughput (0.0 = unknown,
    /// e.g. artifact plans balanced by MACs without profiling).
    pub predicted_throughput: f64,
    pub latency: Option<LatencyReport>,
    pub replicas: Vec<ReplicaReport>,
}

fn latency_from(s: &Summary) -> Option<LatencyReport> {
    if s.count() == 0 {
        return None;
    }
    Some(LatencyReport { p50: s.p50(), p95: s.p95(), p99: s.p99() })
}

impl ServeReport {
    /// Convert a wall-clock fleet run. `plan.replicas` and `fleet.replicas`
    /// must be index-aligned (they are, for reports produced by
    /// [`Plan::deploy`]).
    pub fn from_fleet(plan: &Plan, fleet: &FleetReport, mode: ServeMode) -> ServeReport {
        let util = fleet.utilization();
        let replicas = plan
            .replicas
            .iter()
            .zip(&fleet.replicas)
            .enumerate()
            .map(|(i, (pr, rr))| ReplicaReport {
                pipeline: pr.pipeline.clone(),
                allocation: plan.allocation_of(i).display_1based(),
                dispatched: fleet.dispatched[i],
                throughput: rr.throughput(),
                utilization: util[i],
                bottleneck: None,
                stages: rr
                    .stages
                    .iter()
                    .map(|s| StageReport {
                        name: s.name.clone(),
                        items: s.items,
                        busy_s: s.busy.as_secs_f64(),
                        utilization: s.utilization(fleet.wall),
                    })
                    .collect(),
            })
            .collect();
        ServeReport {
            mode,
            network: plan.network.clone(),
            images: fleet.images,
            wall_s: fleet.wall.as_secs_f64(),
            throughput: fleet.throughput(),
            predicted_throughput: plan.throughput,
            latency: latency_from(&fleet.latencies),
            replicas,
        }
    }

    /// Convert a single-pipeline (or serial) wall-clock run into a
    /// one-replica report.
    pub fn from_run(plan: &Plan, report: &RunReport, mode: ServeMode) -> ServeReport {
        let util = report
            .stages
            .iter()
            .map(|s| s.utilization(report.wall))
            .fold(0.0, f64::max);
        let replica = ReplicaReport {
            pipeline: plan
                .replicas
                .first()
                .map(|r| r.pipeline.clone())
                .unwrap_or_default(),
            allocation: plan.allocation_of(0).display_1based(),
            dispatched: report.images,
            throughput: if report.wall.is_zero() { 0.0 } else { report.throughput() },
            utilization: util,
            bottleneck: None,
            stages: report
                .stages
                .iter()
                .map(|s| StageReport {
                    name: s.name.clone(),
                    items: s.items,
                    busy_s: s.busy.as_secs_f64(),
                    utilization: s.utilization(report.wall),
                })
                .collect(),
        };
        ServeReport {
            mode,
            network: plan.network.clone(),
            images: report.images,
            wall_s: report.wall.as_secs_f64(),
            throughput: if report.wall.is_zero() { 0.0 } else { report.throughput() },
            predicted_throughput: plan.throughput,
            latency: latency_from(&report.latencies),
            replicas: vec![replica],
        }
    }

    /// Convert a replicated discrete-event simulation.
    pub fn from_des(plan: &Plan, sim: &FleetSimReport) -> ServeReport {
        let merged = sim.merged_latencies();
        let latency = if merged.is_empty() {
            None
        } else {
            Some(LatencyReport {
                p50: stats::percentile(&merged, 50.0),
                p95: stats::percentile(&merged, 95.0),
                p99: stats::percentile(&merged, 99.0),
            })
        };
        let util = sim.replica_utilization();
        let replicas = plan
            .replicas
            .iter()
            .zip(&sim.per_replica)
            .enumerate()
            .map(|(i, (pr, sr))| ReplicaReport {
                pipeline: pr.pipeline.clone(),
                allocation: plan.allocation_of(i).display_1based(),
                dispatched: sim.dispatched[i],
                throughput: sr.throughput,
                utilization: util[i],
                bottleneck: Some(sr.bottleneck),
                stages: sr
                    .utilization
                    .iter()
                    .enumerate()
                    .map(|(j, &u)| StageReport {
                        name: format!("stage{j}"),
                        items: sim.dispatched[i],
                        busy_s: pr.stage_times.get(j).copied().unwrap_or(0.0)
                            * sim.dispatched[i] as f64,
                        utilization: u,
                    })
                    .collect(),
            })
            .collect();
        ServeReport {
            mode: ServeMode::Des,
            network: plan.network.clone(),
            images: sim.dispatched.iter().sum(),
            wall_s: sim.makespan,
            throughput: sim.throughput,
            predicted_throughput: plan.throughput,
            latency,
            replicas,
        }
    }
}
