//! The unified serving report: one shape for single-pipeline runs, fleet
//! runs, and discrete-event simulations, so every execution backend of a
//! [`Plan`](crate::api::Plan) prints through the same renderer
//! ([`crate::reports::render_serve`]).
//!
//! A [`ServeReport`] always looks like a fleet — a single pipeline is a
//! one-replica fleet — which keeps downstream consumers (CLI, examples,
//! tests) free of per-backend match arms.

use crate::coordinator::{FleetReport, RunReport};
use crate::obs::{AttribReport, MetricsSnapshot};
use crate::simulator::pipeline_sim::FleetSimReport;
use crate::util::json::Json;
use crate::util::stats::{self, Summary};

use super::plan::Plan;

/// Which backend produced a [`ServeReport`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ServeMode {
    /// Discrete-event simulation ([`Plan::simulate`]).
    Des,
    /// Wall-clock run of the real thread fleet over synthetic sleep stages
    /// scaled by `time_scale` ([`Plan::deploy`] without artifacts).
    Synthetic { time_scale: f64 },
    /// Real PJRT execution over AOT artifacts ([`Plan::deploy`] with an
    /// artifact binding); `serial` is the one-thread kernel-level analogue.
    Pjrt { serial: bool },
}

/// Latency percentiles in seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyReport {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl LatencyReport {
    /// Summarize raw per-item latencies; `None` when nothing completed.
    /// The ONE percentile-triple builder shared by every backend (DES
    /// co-sim, wall-clock deploys, fleet summaries, the adaptation
    /// controller).
    ///
    /// Total on every input: an empty set (reachable — a tenant whose
    /// arrivals are all shed at the front door admits nothing) is `None`,
    /// never a panic or an index past the end; a single element yields
    /// `p50 == p95 == p99 == x`. The triple is always monotone
    /// (`p50 <= p95 <= p99`) because the percentiles interpolate one
    /// sorted copy.
    pub fn from_latencies(latencies: &[f64]) -> Option<LatencyReport> {
        if latencies.is_empty() {
            return None;
        }
        let mut sorted = latencies.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        Some(LatencyReport {
            p50: stats::percentile_sorted(&sorted, 50.0),
            p95: stats::percentile_sorted(&sorted, 95.0),
            p99: stats::percentile_sorted(&sorted, 99.0),
        })
    }
}

/// Per-stage accounting within one replica.
#[derive(Debug, Clone, PartialEq)]
pub struct StageReport {
    pub name: String,
    pub items: usize,
    pub busy_s: f64,
    /// Busy fraction against the run's wall clock.
    pub utilization: f64,
}

/// One replica's slice of the run.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicaReport {
    /// The plan's pipeline shorthand (`B4-s2-s2`, `host-3`, `full-net`).
    pub pipeline: String,
    /// 1-based layer-allocation display (`[1,35] - [36,54]`).
    pub allocation: String,
    /// Items routed to this replica.
    pub dispatched: usize,
    /// Throughput against the replica's own clock (imgs/s).
    pub throughput: f64,
    /// Bottleneck-stage busy fraction (1.0 = never idle).
    pub utilization: f64,
    /// Bottleneck stage index, when the backend knows it (DES only).
    pub bottleneck: Option<usize>,
    pub stages: Vec<StageReport>,
}

/// One plan hot-swap performed by the online-adaptation controller
/// ([`crate::adapt`]) during a serve: what drifted, when, and what the
/// fleet was rebalanced to.
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptationEvent {
    /// Clock time of the swap, seconds from serving start (simulated time
    /// for DES runs, wall time for synthetic deploys).
    pub at_s: f64,
    /// Items that had completed before the swap.
    pub after_images: usize,
    /// Human-readable disturbance classification from the drift detector
    /// (e.g. `big-cluster slowdown x2.00`).
    pub disturbance: String,
    /// Partition display before the swap (`B4-s2-s2`, `B4 | s4`, …).
    pub from: String,
    /// Partition display after the swap.
    pub to: String,
    /// The new plan's predicted aggregate Eq. 12 throughput (imgs/s) on the
    /// recalibrated time matrix.
    pub predicted_throughput: f64,
}

impl AdaptationEvent {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("at_s", Json::num(self.at_s)),
            ("after_images", Json::num(self.after_images as f64)),
            ("disturbance", Json::str(&self.disturbance)),
            ("from", Json::str(&self.from)),
            ("to", Json::str(&self.to)),
            ("predicted_throughput", Json::num(self.predicted_throughput)),
        ])
    }
}

/// Unified result of serving a [`Plan`] through any backend.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeReport {
    pub mode: ServeMode,
    /// Network (or artifact model) name from the plan.
    pub network: String,
    /// Items that completed across all replicas.
    pub images: usize,
    /// Wall-clock (or simulated-clock) duration in seconds.
    pub wall_s: f64,
    /// Aggregate throughput over `wall_s` (imgs/s).
    pub throughput: f64,
    /// The plan's predicted aggregate Eq. 12 throughput (0.0 = unknown,
    /// e.g. artifact plans balanced by MACs without profiling).
    pub predicted_throughput: f64,
    pub latency: Option<LatencyReport>,
    pub replicas: Vec<ReplicaReport>,
    /// Plan hot-swaps performed mid-run by the adaptation controller, in
    /// order; empty for non-adaptive serves. When non-empty, `replicas`
    /// describes the final (post-swap) partition while `images`/`wall_s`/
    /// `throughput` cover the whole run.
    pub adaptations: Vec<AdaptationEvent>,
    /// Frozen metrics-registry state when the run recorded one
    /// (`--trace-out` / an enabled [`crate::obs::Recorder`]); `None`
    /// otherwise.
    pub metrics: Option<MetricsSnapshot>,
    /// Latency attribution + Eq. 10 residual table
    /// ([`crate::obs::attrib`], DESIGN.md §14), present on recorded runs
    /// whose backend emits spans.
    pub attrib: Option<AttribReport>,
}

fn latency_from(s: &Summary) -> Option<LatencyReport> {
    LatencyReport::from_latencies(s.samples())
}

impl ServeReport {
    /// Convert a wall-clock fleet run. `plan.replicas` and `fleet.replicas`
    /// must be index-aligned (they are, for reports produced by
    /// [`Plan::deploy`]).
    pub fn from_fleet(plan: &Plan, fleet: &FleetReport, mode: ServeMode) -> ServeReport {
        let util = fleet.utilization();
        let replicas = plan
            .replicas
            .iter()
            .zip(&fleet.replicas)
            .enumerate()
            .map(|(i, (pr, rr))| ReplicaReport {
                pipeline: pr.pipeline.clone(),
                allocation: plan.allocation_of(i).display_1based(),
                dispatched: fleet.dispatched[i],
                throughput: rr.throughput(),
                utilization: util[i],
                bottleneck: None,
                stages: rr
                    .stages
                    .iter()
                    .map(|s| StageReport {
                        name: s.name.clone(),
                        items: s.items,
                        busy_s: s.busy.as_secs_f64(),
                        utilization: s.utilization(fleet.wall),
                    })
                    .collect(),
            })
            .collect();
        ServeReport {
            mode,
            network: plan.network.clone(),
            images: fleet.images,
            wall_s: fleet.wall.as_secs_f64(),
            throughput: fleet.throughput(),
            predicted_throughput: plan.throughput,
            latency: latency_from(&fleet.latencies),
            replicas,
            adaptations: Vec::new(),
            metrics: None,
            attrib: None,
        }
    }

    /// Convert a single-pipeline (or serial) wall-clock run into a
    /// one-replica report.
    pub fn from_run(plan: &Plan, report: &RunReport, mode: ServeMode) -> ServeReport {
        let util = report
            .stages
            .iter()
            .map(|s| s.utilization(report.wall))
            .fold(0.0, f64::max);
        let replica = ReplicaReport {
            pipeline: plan
                .replicas
                .first()
                .map(|r| r.pipeline.clone())
                .unwrap_or_default(),
            allocation: plan.allocation_of(0).display_1based(),
            dispatched: report.images,
            throughput: if report.wall.is_zero() { 0.0 } else { report.throughput() },
            utilization: util,
            bottleneck: None,
            stages: report
                .stages
                .iter()
                .map(|s| StageReport {
                    name: s.name.clone(),
                    items: s.items,
                    busy_s: s.busy.as_secs_f64(),
                    utilization: s.utilization(report.wall),
                })
                .collect(),
        };
        ServeReport {
            mode,
            network: plan.network.clone(),
            images: report.images,
            wall_s: report.wall.as_secs_f64(),
            throughput: if report.wall.is_zero() { 0.0 } else { report.throughput() },
            predicted_throughput: plan.throughput,
            latency: latency_from(&report.latencies),
            replicas: vec![replica],
            adaptations: Vec::new(),
            metrics: None,
            attrib: None,
        }
    }

    /// Convert a replicated discrete-event simulation.
    pub fn from_des(plan: &Plan, sim: &FleetSimReport) -> ServeReport {
        let latency = LatencyReport::from_latencies(&sim.merged_latencies());
        let util = sim.replica_utilization();
        let replicas = plan
            .replicas
            .iter()
            .zip(&sim.per_replica)
            .enumerate()
            .map(|(i, (pr, sr))| ReplicaReport {
                pipeline: pr.pipeline.clone(),
                allocation: plan.allocation_of(i).display_1based(),
                dispatched: sim.dispatched[i],
                throughput: sr.throughput,
                utilization: util[i],
                bottleneck: Some(sr.bottleneck),
                stages: sr
                    .utilization
                    .iter()
                    .enumerate()
                    .map(|(j, &u)| StageReport {
                        name: format!("stage{j}"),
                        items: sim.dispatched[i],
                        busy_s: pr.stage_times.get(j).copied().unwrap_or(0.0)
                            * sim.dispatched[i] as f64,
                        utilization: u,
                    })
                    .collect(),
            })
            .collect();
        ServeReport {
            mode: ServeMode::Des,
            network: plan.network.clone(),
            images: sim.dispatched.iter().sum(),
            wall_s: sim.makespan,
            throughput: sim.throughput,
            predicted_throughput: plan.throughput,
            latency,
            replicas,
            adaptations: Vec::new(),
            metrics: None,
            attrib: None,
        }
    }

    /// JSON shape of the unified report — what `serve --metrics-out`
    /// captures, including per-stage accounting and the adaptation log.
    pub fn to_json(&self) -> Json {
        let mode = match self.mode {
            ServeMode::Des => Json::obj(vec![("kind", Json::str("des"))]),
            ServeMode::Synthetic { time_scale } => Json::obj(vec![
                ("kind", Json::str("synthetic")),
                ("time_scale", Json::num(time_scale)),
            ]),
            ServeMode::Pjrt { serial } => Json::obj(vec![
                ("kind", Json::str("pjrt")),
                ("serial", Json::Bool(serial)),
            ]),
        };
        let latency = match &self.latency {
            None => Json::Null,
            Some(l) => Json::obj(vec![
                ("p50", Json::num(l.p50)),
                ("p95", Json::num(l.p95)),
                ("p99", Json::num(l.p99)),
            ]),
        };
        let replicas = Json::Arr(
            self.replicas
                .iter()
                .map(|r| {
                    let stages = Json::Arr(
                        r.stages
                            .iter()
                            .map(|s| {
                                Json::obj(vec![
                                    ("name", Json::str(&s.name)),
                                    ("items", Json::num(s.items as f64)),
                                    ("busy_s", Json::num(s.busy_s)),
                                    ("utilization", Json::num(s.utilization)),
                                ])
                            })
                            .collect(),
                    );
                    Json::obj(vec![
                        ("pipeline", Json::str(&r.pipeline)),
                        ("allocation", Json::str(&r.allocation)),
                        ("dispatched", Json::num(r.dispatched as f64)),
                        ("throughput", Json::num(r.throughput)),
                        ("utilization", Json::num(r.utilization)),
                        (
                            "bottleneck",
                            r.bottleneck.map_or(Json::Null, |b| Json::num(b as f64)),
                        ),
                        ("stages", stages),
                    ])
                })
                .collect(),
        );
        let mut fields = vec![
            ("mode", mode),
            ("network", Json::str(&self.network)),
            ("images", Json::num(self.images as f64)),
            ("wall_s", Json::num(self.wall_s)),
            ("throughput", Json::num(self.throughput)),
            ("predicted_throughput", Json::num(self.predicted_throughput)),
            ("latency", latency),
            ("replicas", replicas),
            (
                "adaptations",
                Json::Arr(self.adaptations.iter().map(AdaptationEvent::to_json).collect()),
            ),
        ];
        if let Some(m) = &self.metrics {
            fields.push(("metrics", m.to_json()));
        }
        if let Some(a) = &self.attrib {
            fields.push(("attrib", a.to_json()));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::PlanSpec;

    /// Regression (ISSUE 5 satellite): the percentile-triple builder must
    /// be total on empty and single-element latency sets — the empty case
    /// is reachable via a zero-admitted tenant under full shedding.
    #[test]
    fn from_latencies_empty_and_single_are_well_defined() {
        assert_eq!(LatencyReport::from_latencies(&[]), None);
        let one = LatencyReport::from_latencies(&[0.042]).unwrap();
        assert_eq!(one.p50, 0.042);
        assert_eq!(one.p95, 0.042);
        assert_eq!(one.p99, 0.042);
    }

    #[test]
    fn from_latencies_triple_is_monotone_on_unsorted_input() {
        let l = LatencyReport::from_latencies(&[0.9, 0.1, 0.5, 0.3, 0.7]).unwrap();
        assert!(l.p50 <= l.p95 && l.p95 <= l.p99, "{l:?}");
        assert_eq!(l.p50, 0.5);
        // p99 interpolates between the two largest samples: 0.7..0.9.
        assert!((l.p99 - 0.892).abs() < 1e-9, "interpolated tail, got {}", l.p99);
    }

    #[test]
    fn empty_summary_yields_no_latency_report() {
        let s = Summary::new();
        assert_eq!(latency_from(&s), None);
        let mut one = Summary::new();
        one.record(1.5);
        let l = latency_from(&one).unwrap();
        assert_eq!((l.p50, l.p95, l.p99), (1.5, 1.5, 1.5));
    }

    #[test]
    fn serve_report_json_is_parseable_and_complete() {
        let plan = PlanSpec::new("squeezenet").compile().unwrap();
        let mut report = plan.simulate(100, 2).unwrap();
        report.adaptations.push(AdaptationEvent {
            at_s: 1.5,
            after_images: 40,
            disturbance: "big-cluster slowdown x2.00".into(),
            from: "B4-s2-s2".into(),
            to: "B2-s4".into(),
            predicted_throughput: 12.0,
        });
        let text = report.to_json().to_string();
        let j = Json::parse(&text).expect("serve report JSON reparses");
        assert_eq!(j.req("network").unwrap().as_str(), Some("squeezenet"));
        assert_eq!(j.req("mode").unwrap().req("kind").unwrap().as_str(), Some("des"));
        let adap = j.req("adaptations").unwrap().as_arr().unwrap();
        assert_eq!(adap.len(), 1);
        assert_eq!(adap[0].req("to").unwrap().as_str(), Some("B2-s4"));
        assert!(!j.req("replicas").unwrap().as_arr().unwrap().is_empty());
        let rep = &j.req("replicas").unwrap().as_arr().unwrap()[0];
        assert!(rep.req("stages").unwrap().as_arr().is_some());
    }
}
