//! The unified **Plan → Deploy** facade (DESIGN.md §8).
//!
//! Pipe-it's lifecycle is *predict layer times → explore the design space →
//! run the chosen pipeline* (paper §IV–§VI). This module makes that
//! lifecycle a first-class API instead of a pile of free functions:
//!
//! * [`PlanSpec`] — builder describing *what* to plan: network (or AOT
//!   artifact directory), platform, [`TimeSource`], [`Strategy`].
//! * [`Plan`] — the compiled, **serializable** design artifact: pipelines,
//!   layer allocations, replica core budgets, predicted stage times and
//!   throughput. A plan explored once can be saved ([`Plan::save`]),
//!   shipped, reloaded ([`Plan::load`]) and executed anywhere with
//!   identical behavior — no search re-runs at deploy time.
//! * [`Plan::simulate`] — the discrete-event backend
//!   ([`crate::simulator::pipeline_sim`]).
//! * [`Plan::deploy`] — the wall-clock backend: the real thread fleet
//!   ([`crate::coordinator::run_fleet`]) over synthetic stages, or real
//!   PJRT serving for artifact-bound plans.
//! * [`ServeReport`] — one result shape for all of the above, rendered by
//!   [`crate::reports::render_serve`].
//!
//! The CLI (`pipeit plan / serve --plan / simulate --plan`) and every
//! example are thin wrappers over this module.
//!
//! # Example
//!
//! ```
//! use pipeit::api::{Plan, PlanSpec, Strategy};
//!
//! // Explore once, save the decision as an artifact…
//! let plan = PlanSpec::new("squeezenet")
//!     .strategy(Strategy::Replicated { max_replicas: 2, exact: false })
//!     .compile()
//!     .unwrap();
//! let json = plan.to_json().to_string();
//!
//! // …and anything that can read the artifact can run it.
//! let loaded = Plan::from_json(&pipeit::util::json::Json::parse(&json).unwrap()).unwrap();
//! assert_eq!(plan, loaded);
//! let report = loaded.simulate(500, 2).unwrap();
//! assert!(report.throughput > 0.0);
//! ```

pub mod plan;
pub mod report;

pub use plan::{
    ArtifactBinding, DeployOptions, Plan, PlanReplica, PlanSpec, Strategy, TimeSource,
    PLAN_VERSION,
};
pub use report::{
    AdaptationEvent, LatencyReport, ReplicaReport, ServeMode, ServeReport, StageReport,
};
