//! The serving plan artifact: [`PlanSpec`] (what to plan) compiles to a
//! [`Plan`] (the chosen design), which serializes to JSON and dispatches to
//! every execution backend — [`Plan::simulate`] (DES), [`Plan::deploy`]
//! (wall-clock thread fleet or real PJRT serving).
//!
//! The JSON schema is documented in `DESIGN.md` §8; the contract is that a
//! plan saved with [`Plan::save`] and reloaded with [`Plan::load`] behaves
//! identically — the artifact carries the pipeline, allocation, and stage
//! service times, so no search re-runs at deploy time.

use std::fmt;
use std::path::Path;

use anyhow::{Context, Result};

use crate::cnn::zoo;
use crate::config::Config;
use crate::coordinator::{self, run_fleet, synthetic_fleet_recorded, Job};
use crate::obs::{LogHist, Recorder, WallClock};
use crate::dse::{
    self, Allocation, CoreBudget, DsePoint, PipelineConfig, ReplicatedDesign, StageConfig,
};
use crate::perfmodel::{PerfModel, TimeMatrix};
use crate::runtime::Manifest;
use crate::simulator::pipeline_sim;
use crate::simulator::platform::CoreType;
use crate::simulator::power::PowerModel;
use crate::util::json::Json;

use super::report::{ServeMode, ServeReport};

/// Plan schema version written by [`Plan::save`] and required by
/// [`Plan::load`].
pub const PLAN_VERSION: usize = 1;

/// Where the layer times backing the plan come from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimeSource {
    /// Board measurements (here: the simulator ground truth) — the paper's
    /// Table VI setting. For artifact plans: MAC-proportional balancing
    /// (no timing available without a profiling run).
    Measured,
    /// The fitted Eq. 5–8 predictor — the paper's Table V setting.
    Predicted,
    /// Per-layer times profiled on this host by running a calibration
    /// stream through the AOT artifacts (artifact plans only; requires the
    /// `pjrt` feature at plan-compile time).
    ProfiledArtifacts,
}

impl fmt::Display for TimeSource {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TimeSource::Measured => write!(f, "measured"),
            TimeSource::Predicted => write!(f, "predicted"),
            TimeSource::ProfiledArtifacts => write!(f, "profiled"),
        }
    }
}

impl TimeSource {
    fn to_json(self) -> Json {
        Json::str(match self {
            TimeSource::Measured => "measured",
            TimeSource::Predicted => "predicted",
            TimeSource::ProfiledArtifacts => "profiled",
        })
    }

    fn from_json(j: &Json) -> Result<TimeSource> {
        match j.as_str().context("time_source string")? {
            "measured" => Ok(TimeSource::Measured),
            "predicted" => Ok(TimeSource::Predicted),
            "profiled" => Ok(TimeSource::ProfiledArtifacts),
            other => Err(anyhow::anyhow!(
                "unknown time source {other:?} (field \"time_source\"; expected \
                 measured|predicted|profiled)"
            )),
        }
    }
}

/// Which design-space search picks the plan's pipelines.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Strategy {
    /// No pipeline: the whole network on the Big cluster (the kernel-level
    /// baseline); for artifact plans, the one-thread whole-net module.
    Serial,
    /// The paper's single-pipeline DSE ([`dse::explore`], Eq. 1 space).
    Pipeline,
    /// Exhaustive single-pipeline search over the extended space that also
    /// contains single-cluster and single-stage pipelines
    /// ([`dse::explore_budget`] on the full core budget).
    Exhaustive,
    /// Replicated fleets on disjoint core partitions. `exact` demands
    /// exactly `max_replicas` pipelines ([`dse::explore_exact`]); otherwise
    /// the best design with 1..=`max_replicas` wins
    /// ([`dse::explore_replicated`]). Artifact plans deploy exactly
    /// `max_replicas` host replicas.
    Replicated { max_replicas: usize, exact: bool },
    /// Best imgs/J subject to a throughput floor ([`dse::explore_energy`]).
    Energy { min_throughput: f64, mem_intensity: f64 },
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Strategy::Serial => write!(f, "serial"),
            Strategy::Pipeline => write!(f, "pipeline"),
            Strategy::Exhaustive => write!(f, "exhaustive"),
            Strategy::Replicated { max_replicas, exact: true } => {
                write!(f, "replicated (R={max_replicas})")
            }
            Strategy::Replicated { max_replicas, exact: false } => {
                write!(f, "replicated (R<={max_replicas})")
            }
            Strategy::Energy { min_throughput, .. } => {
                write!(f, "energy (floor {min_throughput:.2} imgs/s)")
            }
        }
    }
}

impl Strategy {
    fn to_json(self) -> Json {
        match self {
            Strategy::Serial => Json::obj(vec![("kind", Json::str("serial"))]),
            Strategy::Pipeline => Json::obj(vec![("kind", Json::str("pipeline"))]),
            Strategy::Exhaustive => Json::obj(vec![("kind", Json::str("exhaustive"))]),
            Strategy::Replicated { max_replicas, exact } => Json::obj(vec![
                ("kind", Json::str("replicated")),
                ("max_replicas", Json::num(max_replicas as f64)),
                ("exact", Json::Bool(exact)),
            ]),
            Strategy::Energy { min_throughput, mem_intensity } => Json::obj(vec![
                ("kind", Json::str("energy")),
                ("min_throughput", Json::num(min_throughput)),
                ("mem_intensity", Json::num(mem_intensity)),
            ]),
        }
    }

    fn from_json(j: &Json) -> Result<Strategy> {
        let kind = j.req("kind")?.as_str().context("strategy kind")?;
        Ok(match kind {
            "serial" => Strategy::Serial,
            "pipeline" => Strategy::Pipeline,
            "exhaustive" => Strategy::Exhaustive,
            "replicated" => Strategy::Replicated {
                max_replicas: j.req("max_replicas")?.as_usize().context("max_replicas")?,
                exact: j.req("exact")?.as_bool().context("exact")?,
            },
            "energy" => Strategy::Energy {
                min_throughput: j
                    .req("min_throughput")?
                    .as_f64()
                    .context("min_throughput")?,
                mem_intensity: j.req("mem_intensity")?.as_f64().context("mem_intensity")?,
            },
            other => anyhow::bail!(
                "unknown strategy kind {other:?} (field \"strategy.kind\"; expected \
                 serial|pipeline|exhaustive|replicated|energy)"
            ),
        })
    }
}

/// One replica of a compiled plan.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanReplica {
    /// Big cores owned by this replica (0 for artifact/host plans).
    pub big: usize,
    /// Small cores owned by this replica (0 for artifact/host plans).
    pub small: usize,
    /// Pipeline shorthand: `B4-s2-s2` for big.LITTLE plans, `host-K` /
    /// `full-net` for artifact plans.
    pub pipeline: String,
    /// Contiguous `[lo, hi)` layer range per stage.
    pub allocation: Vec<(usize, usize)>,
    /// Predicted per-stage service times in seconds (Eq. 10). Empty for
    /// artifact plans balanced by MACs (no timing available).
    pub stage_times: Vec<f64>,
    /// Predicted replica throughput (Eq. 12); 0.0 = unknown.
    pub throughput: f64,
}

/// Binding of a plan to an AOT artifact directory.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactBinding {
    pub dir: String,
    /// Layer count at compile time — checked again at deploy time so a
    /// regenerated artifact set cannot silently invalidate the allocation.
    pub num_layers: usize,
}

/// A compiled, serializable serving plan: the design chosen by the
/// [`PlanSpec`] search, ready to [`simulate`](Plan::simulate) or
/// [`deploy`](Plan::deploy) anywhere.
///
/// # Example
///
/// ```
/// use pipeit::api::{Plan, PlanSpec};
///
/// let plan = PlanSpec::new("alexnet").compile().unwrap();
/// let path = std::env::temp_dir().join("pipeit_doc_plan.json");
/// plan.save(&path).unwrap();
/// let loaded = Plan::load(&path).unwrap();
/// assert_eq!(plan, loaded); // the artifact round-trips losslessly
/// std::fs::remove_file(&path).ok();
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct Plan {
    /// Network name (zoo) or artifact model name.
    pub network: String,
    /// Platform name the plan was compiled for (`host` for artifact plans).
    pub platform: String,
    /// Big-cluster core budget at compile time.
    pub big: usize,
    /// Small-cluster core budget at compile time.
    pub small: usize,
    pub time_source: TimeSource,
    pub strategy: Strategy,
    /// Predicted aggregate throughput: the sum of replica Eq. 12 rates
    /// (0.0 = unknown, e.g. MAC-balanced artifact plans).
    pub throughput: f64,
    pub replicas: Vec<PlanReplica>,
    /// Present only for artifact plans.
    pub artifacts: Option<ArtifactBinding>,
}

/// Runtime knobs for [`Plan::deploy`]; the plan itself fixes the design.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeployOptions {
    /// Images to stream through the fleet.
    pub images: usize,
    /// Inter-stage queue capacity inside each replica.
    pub queue_cap: usize,
    /// Synthetic deploys sleep for `stage_time * time_scale` per item.
    pub time_scale: f64,
    /// Batch size for PJRT artifact serving.
    pub batch: usize,
    /// Stream seed for PJRT artifact serving.
    pub seed: u64,
}

impl Default for DeployOptions {
    fn default() -> DeployOptions {
        DeployOptions { images: 60, queue_cap: 2, time_scale: 0.1, batch: 1, seed: 7 }
    }
}

impl Plan {
    /// The replica's layer allocation as a [`dse::Allocation`].
    pub fn allocation_of(&self, replica: usize) -> Allocation {
        Allocation { ranges: self.replicas[replica].allocation.clone() }
    }

    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// `B4 | s2-s2` style display: replica pipelines joined with `|`.
    pub fn partition_display(&self) -> String {
        self.replicas
            .iter()
            .map(|r| r.pipeline.clone())
            .collect::<Vec<_>>()
            .join(" | ")
    }

    // ---- serialization ---------------------------------------------------

    pub fn to_json(&self) -> Json {
        let replicas = Json::Arr(
            self.replicas
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        (
                            "budget",
                            Json::obj(vec![
                                ("big", Json::num(r.big as f64)),
                                ("small", Json::num(r.small as f64)),
                            ]),
                        ),
                        ("pipeline", Json::str(&r.pipeline)),
                        (
                            "allocation",
                            Json::Arr(
                                r.allocation
                                    .iter()
                                    .map(|&(lo, hi)| {
                                        Json::Arr(vec![
                                            Json::num(lo as f64),
                                            Json::num(hi as f64),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                        (
                            "stage_times",
                            Json::Arr(r.stage_times.iter().map(|&t| Json::num(t)).collect()),
                        ),
                        ("throughput", Json::num(r.throughput)),
                    ])
                })
                .collect(),
        );
        let mut fields = vec![
            ("version", Json::num(PLAN_VERSION as f64)),
            ("network", Json::str(&self.network)),
            (
                "platform",
                Json::obj(vec![
                    ("name", Json::str(&self.platform)),
                    ("big", Json::num(self.big as f64)),
                    ("small", Json::num(self.small as f64)),
                ]),
            ),
            ("time_source", self.time_source.to_json()),
            ("strategy", self.strategy.to_json()),
            ("throughput", Json::num(self.throughput)),
            ("replicas", replicas),
        ];
        if let Some(a) = &self.artifacts {
            fields.push((
                "artifacts",
                Json::obj(vec![
                    ("dir", Json::str(&a.dir)),
                    ("num_layers", Json::num(a.num_layers as f64)),
                ]),
            ));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<Plan> {
        let version = j.req("version")?.as_usize().context("version")?;
        anyhow::ensure!(
            version == PLAN_VERSION,
            "plan schema version {version} is not supported (field \"version\"; \
             this build reads version {PLAN_VERSION})"
        );
        let platform = j.req("platform")?;
        let mut replicas = Vec::new();
        for (i, rj) in j.req("replicas")?.as_arr().context("replicas array")?.iter().enumerate()
        {
            replicas.push(replica_from_json(i, rj)?);
        }
        anyhow::ensure!(!replicas.is_empty(), "plan has no replicas");
        for (i, r) in replicas.iter().enumerate() {
            anyhow::ensure!(!r.allocation.is_empty(), "replica {i}: empty allocation");
            let w = r.allocation.last().map(|&(_, hi)| hi).unwrap_or(0);
            let a = Allocation { ranges: r.allocation.clone() };
            anyhow::ensure!(
                a.is_partition(w),
                "replica {i}: allocation is not a contiguous layer partition"
            );
            anyhow::ensure!(
                r.stage_times.is_empty() || r.stage_times.len() == r.allocation.len(),
                "replica {i}: {} stage times for {} stages",
                r.stage_times.len(),
                r.allocation.len()
            );
        }
        let artifacts = match j.get("artifacts") {
            Some(a) => Some(ArtifactBinding {
                dir: a.req("dir")?.as_str().context("artifacts dir")?.to_string(),
                num_layers: a.req("num_layers")?.as_usize().context("num_layers")?,
            }),
            None => None,
        };
        Ok(Plan {
            network: j.req("network")?.as_str().context("network")?.to_string(),
            platform: platform.req("name")?.as_str().context("platform name")?.to_string(),
            big: platform.req("big")?.as_usize().context("platform big")?,
            small: platform.req("small")?.as_usize().context("platform small")?,
            time_source: TimeSource::from_json(j.req("time_source")?)?,
            strategy: Strategy::from_json(j.req("strategy")?)?,
            throughput: j.req("throughput")?.as_f64().context("throughput")?,
            replicas,
            artifacts,
        })
    }

    /// Write the plan as a JSON artifact.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Load a plan saved by [`Plan::save`].
    pub fn load(path: &Path) -> Result<Plan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Plan::from_json(&j).with_context(|| format!("parsing plan {}", path.display()))
    }

    // ---- display ---------------------------------------------------------

    /// The design lines only (no header) — used by `explore --replicated`.
    pub fn design_summary(&self) -> String {
        let mut s = String::new();
        if self.replicas.len() == 1 {
            let r = &self.replicas[0];
            s.push_str(&format!("pipeline   : {}\n", r.pipeline));
            s.push_str(&format!(
                "allocation : {}\n",
                self.allocation_of(0).display_1based()
            ));
            if self.throughput > 0.0 {
                s.push_str(&format!(
                    "throughput : {:.2} imgs/s (Eq. 12)\n",
                    self.throughput
                ));
            }
            // Stage labels come from the `B4-s2-s2` notation; artifact
            // plans use opaque names like `host-2` that must not be split.
            let names: Vec<&str> = r.pipeline.split('-').collect();
            let labeled = self.artifacts.is_none() && names.len() == r.stage_times.len();
            for (i, t) in r.stage_times.iter().enumerate() {
                if labeled {
                    s.push_str(&format!("  stage {i}: {}  {:.1} ms\n", names[i], t * 1e3));
                } else {
                    s.push_str(&format!("  stage {i}: {:.1} ms\n", t * 1e3));
                }
            }
        } else {
            s.push_str(&format!(
                "replicated : {} (R={})\n",
                self.partition_display(),
                self.replicas.len()
            ));
            for (i, r) in self.replicas.iter().enumerate() {
                let budget = format!("{}B+{}s", r.big, r.small);
                s.push_str(&format!(
                    "  replica {i}: {budget:<6} {}  alloc {}  {:.2} imgs/s\n",
                    r.pipeline,
                    self.allocation_of(i).display_1based(),
                    r.throughput
                ));
            }
            s.push_str(&format!(
                "aggregate  : {:.2} imgs/s (Eq. 12 sum)\n",
                self.throughput
            ));
        }
        s
    }

    /// Human-readable plan description (the `pipeit plan` output).
    pub fn summary(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("network    : {}\n", self.network));
        s.push_str(&format!(
            "platform   : {} ({}B+{}s)\n",
            self.platform, self.big, self.small
        ));
        s.push_str(&format!(
            "strategy   : {} ({} times)\n",
            self.strategy, self.time_source
        ));
        if let Some(a) = &self.artifacts {
            s.push_str(&format!("artifacts  : {} ({} layers)\n", a.dir, a.num_layers));
        }
        s.push_str(&self.design_summary());
        s
    }

    // ---- execution backends ---------------------------------------------

    fn stage_time_table(&self) -> Result<Vec<Vec<f64>>> {
        let times: Vec<Vec<f64>> =
            self.replicas.iter().map(|r| r.stage_times.clone()).collect();
        let ok = !times.is_empty()
            && times
                .iter()
                .all(|t| !t.is_empty() && t.iter().all(|x| x.is_finite() && *x > 0.0));
        anyhow::ensure!(
            ok,
            "plan for {:?} carries no stage-time profile (MAC-balanced artifact \
             plans cannot be simulated; recompile with TimeSource::ProfiledArtifacts)",
            self.network
        );
        Ok(times)
    }

    /// Discrete-event simulation of the plan's fleet over `images` items
    /// with per-replica queue capacity `queue_cap` — the design-time twin
    /// of [`Plan::deploy`].
    pub fn simulate(&self, images: usize, queue_cap: usize) -> Result<ServeReport> {
        self.simulate_recorded(images, queue_cap, &Recorder::off())
    }

    /// [`Plan::simulate`] with span recording: every item leaves an
    /// admit → stages → depart chain in `rec` (group 0, item id = arrival
    /// index, sim-time stamps), and the report carries the frozen registry
    /// snapshot — occupancy gauges per stage, pooled `latency` and
    /// per-stage `stage_service` histograms (DESIGN.md §13). With
    /// [`Recorder::off`] this is exactly [`Plan::simulate`] and the
    /// report's `metrics` stays `None`.
    pub fn simulate_recorded(
        &self,
        images: usize,
        queue_cap: usize,
        rec: &Recorder,
    ) -> Result<ServeReport> {
        anyhow::ensure!(images >= 1, "need at least one image");
        anyhow::ensure!(queue_cap >= 1, "queue capacity must be >= 1");
        let times = self.stage_time_table()?;
        let sim = pipeline_sim::simulate_replicated_recorded(
            &times,
            images,
            queue_cap,
            &[],
            0.0,
            rec,
            0,
            0,
            |_, _, _| {},
        );
        let mut report = ServeReport::from_des(self, &sim);
        if rec.enabled() {
            rec.gauge_set("wall_s", report.wall_s);
            for (r, rr) in report.replicas.iter().enumerate() {
                for (st, stage) in rr.stages.iter().enumerate() {
                    rec.gauge_set(&format!("occupancy/g0r{r}s{st}"), stage.utilization);
                }
            }
            report.metrics = rec.snapshot();
            // Attribution (DESIGN.md §14): the DES serves exactly the
            // Eq. 10 times it was given, so residuals here are the
            // conservation baseline every other backend is read against.
            let mut pred = crate::obs::PredictedTimes::new();
            pred.insert_replicas(0, &times);
            report.attrib = crate::obs::attrib_for(rec, &pred, Vec::new());
        }
        Ok(report)
    }

    /// Execute the plan: PJRT serving when the plan is bound to artifacts,
    /// otherwise the real thread fleet over synthetic sleep stages scaled
    /// by [`DeployOptions::time_scale`].
    pub fn deploy(&self, opts: &DeployOptions) -> Result<ServeReport> {
        self.deploy_recorded(opts, &Recorder::off())
    }

    /// [`Plan::deploy`] with span recording. The synthetic backend traces
    /// every item on the shared wall clock (see
    /// [`crate::coordinator::synthetic_fleet_recorded`]); the PJRT
    /// backends run untraced — real-artifact serving has no recorder
    /// plumbing yet, so their reports keep `metrics: None`.
    pub fn deploy_recorded(&self, opts: &DeployOptions, rec: &Recorder) -> Result<ServeReport> {
        if self.artifacts.is_some() {
            let (_, report) = self.deploy_collect(opts)?;
            Ok(report)
        } else {
            self.deploy_synthetic(opts, rec)
        }
    }

    /// Artifact-plan deploy that also returns the processed jobs (for
    /// functional-equivalence checks, e.g. the `e2e_serving` example).
    /// Errors for plans without an artifact binding — use [`Plan::deploy`].
    pub fn deploy_collect(&self, opts: &DeployOptions) -> Result<(Vec<Job>, ServeReport)> {
        let binding = self
            .artifacts
            .as_ref()
            .context("deploy_collect applies to artifact plans; use deploy()")?;
        let manifest = Manifest::load(Path::new(&binding.dir))?;
        anyhow::ensure!(
            manifest.num_layers() == binding.num_layers,
            "artifacts in {} changed since the plan was compiled: {} layers now, {} in the plan",
            binding.dir,
            manifest.num_layers(),
            binding.num_layers
        );
        let alloc = self.allocation_of(0);
        anyhow::ensure!(
            alloc.is_partition(manifest.num_layers()),
            "plan allocation covers layers {} but the artifacts have {} layers",
            alloc.display_1based(),
            manifest.num_layers()
        );
        match self.strategy {
            Strategy::Serial => {
                let (jobs, report) =
                    coordinator::serve_serial(&manifest, opts.images, opts.batch, opts.seed)?;
                Ok((jobs, ServeReport::from_run(self, &report, ServeMode::Pjrt { serial: true })))
            }
            _ if self.replicas.len() > 1 => {
                let (jobs, report) = coordinator::serve_fleet(
                    &manifest,
                    &alloc,
                    self.replicas.len(),
                    opts.images,
                    opts.batch,
                    opts.queue_cap,
                    opts.seed,
                )?;
                let mode = ServeMode::Pjrt { serial: false };
                Ok((jobs, ServeReport::from_fleet(self, &report, mode)))
            }
            _ => {
                let (jobs, report) = coordinator::serve_pipelined(
                    &manifest,
                    &alloc,
                    opts.images,
                    opts.batch,
                    opts.queue_cap,
                    opts.seed,
                )?;
                Ok((jobs, ServeReport::from_run(self, &report, ServeMode::Pjrt { serial: false })))
            }
        }
    }

    /// Re-run this plan's strategy search against `tm` — a (possibly
    /// recalibrated) time matrix for the same network and platform budget —
    /// keeping the plan's network/platform/time-source/strategy identity.
    ///
    /// This is the re-plan step of the online-adaptation loop
    /// ([`crate::adapt`]): after drift calibration rescales the matrix, the
    /// controller compiles a fresh partition from it and hot-swaps the
    /// fleet. A plan compiled from a pinned pipeline re-plans through its
    /// recorded strategy (the pin described a fixed design; under drift the
    /// whole point is to choose a new one).
    pub fn replan_on_matrix(&self, tm: &TimeMatrix, power: &PowerModel) -> Result<Plan> {
        anyhow::ensure!(
            self.artifacts.is_none(),
            "artifact plans have no big.LITTLE time matrix to re-plan from"
        );
        anyhow::ensure!(
            tm.net_name == self.network,
            "time matrix describes {:?} but the plan serves {:?}",
            tm.net_name,
            self.network
        );
        let design = search_design(tm, self.big, self.small, self.strategy, power)?;
        anyhow::ensure!(
            design.throughput.is_finite() && design.throughput > 0.0,
            "search produced a non-finite throughput"
        );
        Ok(Plan {
            network: self.network.clone(),
            platform: self.platform.clone(),
            big: self.big,
            small: self.small,
            time_source: self.time_source,
            strategy: self.strategy,
            throughput: design.throughput,
            replicas: replicas_from_design(tm, &design),
            artifacts: None,
        })
    }

    /// Build a plan directly from an already-searched design. This is the
    /// constructor the multi-tenant joint DSE ([`crate::tenancy`]) uses:
    /// it searches core *splits across networks*, so the per-tenant design
    /// arrives from outside [`PlanSpec::compile`]'s single-network
    /// dispatch, but the artifact it embeds must be an ordinary [`Plan`]
    /// (same schema, same simulate/deploy backends).
    #[allow(clippy::too_many_arguments)]
    pub fn from_design(
        network: &str,
        platform: &str,
        big: usize,
        small: usize,
        time_source: TimeSource,
        strategy: Strategy,
        tm: &TimeMatrix,
        design: &ReplicatedDesign,
    ) -> Plan {
        Plan {
            network: network.to_string(),
            platform: platform.to_string(),
            big,
            small,
            time_source,
            strategy,
            throughput: design.throughput,
            replicas: replicas_from_design(tm, design),
            artifacts: None,
        }
    }

    fn deploy_synthetic(&self, opts: &DeployOptions, rec: &Recorder) -> Result<ServeReport> {
        anyhow::ensure!(opts.images >= 1, "need at least one image");
        anyhow::ensure!(opts.queue_cap >= 1, "queue capacity must be >= 1");
        anyhow::ensure!(opts.time_scale > 0.0, "time_scale must be positive");
        let times = self.stage_time_table()?;
        let clock = WallClock::start();
        let fleet = synthetic_fleet_recorded(&times, opts.time_scale, rec, &clock);
        let (_, report) =
            run_fleet(fleet, opts.queue_cap, 2 * times.len(), 0..opts.images);
        let mut serve = ServeReport::from_fleet(
            self,
            &report,
            ServeMode::Synthetic { time_scale: opts.time_scale },
        );
        if rec.enabled() {
            rec.observe_hist("latency", &LogHist::of(report.latencies.samples()));
            rec.gauge_set("wall_s", serve.wall_s);
            for (r, rr) in serve.replicas.iter().enumerate() {
                for (st, stage) in rr.stages.iter().enumerate() {
                    rec.gauge_set(&format!("occupancy/g0r{r}s{st}"), stage.utilization);
                }
            }
            serve.metrics = rec.snapshot();
            // `serve.attrib` stays `None`: wall spans tick in sleep-scaled
            // seconds, so in-band Eq. 10 residuals would be off-scale.
            // `pipeit attrib --trace` decomposes wall traces offline.
        }
        Ok(serve)
    }
}

/// Run `strategy`'s design-space search against `tm` on an `hb`B + `hs`s
/// core budget — the strategy dispatch shared by [`PlanSpec::compile`],
/// [`Plan::replan_on_matrix`], and the multi-tenant joint DSE
/// ([`crate::tenancy`]) (DESIGN.md §8 table).
pub(crate) fn search_design(
    tm: &TimeMatrix,
    hb: usize,
    hs: usize,
    strategy: Strategy,
    power: &PowerModel,
) -> Result<ReplicatedDesign> {
    let w = tm.num_layers();
    let full = CoreBudget::new(hb, hs);
    Ok(match strategy {
        Strategy::Serial => {
            let p = PipelineConfig::new(vec![StageConfig::new(CoreType::Big, hb)]);
            let a = Allocation { ranges: vec![(0, w)] };
            let tp = dse::pipeline_throughput(tm, &p, &a);
            ReplicatedDesign::single(
                CoreBudget::new(hb, 0),
                DsePoint { pipeline: p, allocation: a, throughput: tp },
            )
        }
        Strategy::Pipeline => ReplicatedDesign::single(full, dse::explore(tm, hb, hs)),
        Strategy::Exhaustive => {
            let pt = dse::explore_budget(tm, full).context("empty pipeline design space")?;
            ReplicatedDesign::single(full, pt)
        }
        Strategy::Replicated { max_replicas, exact } => {
            anyhow::ensure!(max_replicas >= 1, "need at least one replica");
            if exact {
                dse::explore_exact(tm, hb, hs, max_replicas).with_context(|| {
                    format!("no {max_replicas}-replica design fits on {hb}B+{hs}s")
                })?
            } else {
                dse::explore_replicated(tm, hb, hs, max_replicas)
            }
        }
        Strategy::Energy { min_throughput, mem_intensity } => {
            let e = dse::explore_energy(tm, power, hb, hs, min_throughput, mem_intensity)
                .with_context(|| {
                    format!("no configuration reaches the {min_throughput:.2} imgs/s floor")
                })?;
            ReplicatedDesign::single(full, e.point)
        }
    })
}

/// Materialize a searched design's replicas with their Eq. 10 stage-time
/// profiles under `tm`.
pub(crate) fn replicas_from_design(tm: &TimeMatrix, design: &ReplicatedDesign) -> Vec<PlanReplica> {
    design
        .replicas
        .iter()
        .map(|r| PlanReplica {
            big: r.budget.big,
            small: r.budget.small,
            pipeline: r.point.pipeline.to_string(),
            allocation: r.point.allocation.ranges.clone(),
            stage_times: dse::stage_times(tm, &r.point.pipeline, &r.point.allocation),
            throughput: r.point.throughput,
        })
        .collect()
}

fn replica_from_json(i: usize, j: &Json) -> Result<PlanReplica> {
    let budget = j.req("budget")?;
    let alloc_json = j.req("allocation")?.as_arr().context("allocation array")?;
    let mut allocation = Vec::with_capacity(alloc_json.len());
    for pair in alloc_json {
        let p = pair
            .as_arr()
            .filter(|a| a.len() == 2)
            .with_context(|| format!("replica {i}: allocation entries are [lo, hi] pairs"))?;
        allocation.push((
            p[0].as_usize().context("allocation lo")?,
            p[1].as_usize().context("allocation hi")?,
        ));
    }
    let st_json = j.req("stage_times")?.as_arr().context("stage_times array")?;
    let mut stage_times = Vec::with_capacity(st_json.len());
    for t in st_json {
        stage_times.push(t.as_f64().context("stage time")?);
    }
    Ok(PlanReplica {
        big: budget.req("big")?.as_usize().context("budget big")?,
        small: budget.req("small")?.as_usize().context("budget small")?,
        pipeline: j.req("pipeline")?.as_str().context("pipeline")?.to_string(),
        allocation,
        stage_times,
        throughput: j.req("throughput")?.as_f64().context("throughput")?,
    })
}

/// Builder describing what to plan; [`PlanSpec::compile`] runs the chosen
/// search and produces the [`Plan`] artifact.
///
/// # Example
///
/// ```
/// use pipeit::api::{PlanSpec, Strategy, TimeSource};
///
/// let plan = PlanSpec::new("squeezenet")
///     .time_source(TimeSource::Measured)
///     .strategy(Strategy::Replicated { max_replicas: 2, exact: false })
///     .compile()
///     .unwrap();
/// assert!(plan.num_replicas() >= 1);
/// assert!(plan.throughput > 0.0);
/// let des = plan.simulate(200, 2).unwrap();
/// assert!(des.throughput > 0.0);
/// ```
#[derive(Debug)]
pub struct PlanSpec {
    network: Option<String>,
    artifacts: Option<String>,
    config: Config,
    time_source: TimeSource,
    strategy: Strategy,
    fixed_pipeline: Option<String>,
    stages: usize,
    profile_samples: usize,
    profile_seed: u64,
}

impl PlanSpec {
    /// Plan for a zoo network on the configured big.LITTLE platform.
    /// Defaults: HiKey 970, measured times, [`Strategy::Pipeline`].
    pub fn new(network: &str) -> PlanSpec {
        PlanSpec {
            network: Some(network.to_string()),
            artifacts: None,
            config: Config::default(),
            time_source: TimeSource::Measured,
            strategy: Strategy::Pipeline,
            fixed_pipeline: None,
            stages: 3,
            profile_samples: 16,
            profile_seed: 3,
        }
    }

    /// Plan over an AOT artifact directory (real PJRT serving on this
    /// host). Defaults: MAC-balanced 3-stage pipeline.
    pub fn from_artifacts(dir: &str) -> PlanSpec {
        let mut spec = PlanSpec::new("");
        spec.network = None;
        spec.artifacts = Some(dir.to_string());
        spec
    }

    /// Retarget the platform (and power model) the searches run against.
    pub fn platform(mut self, config: Config) -> PlanSpec {
        self.config = config;
        self
    }

    pub fn time_source(mut self, source: TimeSource) -> PlanSpec {
        self.time_source = source;
        self
    }

    pub fn strategy(mut self, strategy: Strategy) -> PlanSpec {
        self.strategy = strategy;
        self
    }

    /// Pin the pipeline to a `B4-s2-s2` spec instead of searching; the
    /// allocation is still balanced by `work_flow` and the compiled plan
    /// records the pinned pipeline in its replica. Zoo plans only.
    pub fn pipeline(mut self, spec: &str) -> PlanSpec {
        self.fixed_pipeline = Some(spec.to_string());
        self
    }

    /// Stage count for artifact plans (ignored for zoo plans).
    pub fn stages(mut self, k: usize) -> PlanSpec {
        self.stages = k;
        self
    }

    /// Calibration-stream length for [`TimeSource::ProfiledArtifacts`].
    pub fn profile_samples(mut self, samples: usize) -> PlanSpec {
        self.profile_samples = samples;
        self
    }

    /// Run the configured search and produce the serializable [`Plan`].
    pub fn compile(self) -> Result<Plan> {
        if self.artifacts.is_some() {
            self.compile_artifacts()
        } else {
            self.compile_network()
        }
    }

    fn compile_network(self) -> Result<Plan> {
        let name = self.network.clone().unwrap_or_default();
        let net = zoo::by_name(&name).with_context(|| format!("unknown network {name:?}"))?;
        let platform = &self.config.platform;
        let (hb, hs) = (platform.big.cores, platform.small.cores);
        let tm = match self.time_source {
            TimeSource::Measured => TimeMatrix::measured(platform, &net),
            TimeSource::Predicted => {
                let model = PerfModel::fit(platform);
                TimeMatrix::predicted(platform, &model, &net)
            }
            TimeSource::ProfiledArtifacts => anyhow::bail!(
                "TimeSource::ProfiledArtifacts applies to artifact plans \
                 (PlanSpec::from_artifacts)"
            ),
        };
        let w = tm.num_layers();

        let design = if let Some(spec) = &self.fixed_pipeline {
            let p = PipelineConfig::parse(spec)?;
            anyhow::ensure!(
                p.is_valid(hb, hs),
                "pipeline {p} exceeds platform core budget ({hb}B+{hs}s)"
            );
            let budget = CoreBudget::new(
                p.cores_used(CoreType::Big),
                p.cores_used(CoreType::Small),
            );
            let a = dse::work_flow(&tm, &p, w);
            let tp = dse::pipeline_throughput(&tm, &p, &a);
            ReplicatedDesign::single(
                budget,
                DsePoint { pipeline: p, allocation: a, throughput: tp },
            )
        } else {
            search_design(&tm, hb, hs, self.strategy, &self.config.power)?
        };
        anyhow::ensure!(
            design.throughput.is_finite() && design.throughput > 0.0,
            "search produced a non-finite throughput"
        );

        let replicas = replicas_from_design(&tm, &design);
        Ok(Plan {
            network: net.name.clone(),
            platform: platform.name.clone(),
            big: hb,
            small: hs,
            time_source: self.time_source,
            strategy: self.strategy,
            throughput: design.throughput,
            replicas,
            artifacts: None,
        })
    }

    fn compile_artifacts(self) -> Result<Plan> {
        let dir = self.artifacts.clone().unwrap_or_default();
        let manifest = Manifest::load(Path::new(&dir))?;
        let w = manifest.num_layers();
        anyhow::ensure!(
            self.fixed_pipeline.is_none(),
            "pipeline specs describe big.LITTLE stage configs; artifact plans \
             are balanced into --stages host stages"
        );
        let replicas_wanted = match self.strategy {
            Strategy::Serial | Strategy::Pipeline => 1,
            Strategy::Replicated { max_replicas, .. } => {
                anyhow::ensure!(max_replicas >= 1, "need at least one replica");
                max_replicas
            }
            Strategy::Exhaustive | Strategy::Energy { .. } => anyhow::bail!(
                "strategy {} needs a big.LITTLE time matrix; artifact plans \
                 support serial, pipeline, and replicated",
                self.strategy
            ),
        };
        let serial = matches!(self.strategy, Strategy::Serial);
        let k = if serial { 1 } else { self.stages.clamp(1, w) };

        let (alloc, stage_times, replica_tp) = match self.time_source {
            TimeSource::ProfiledArtifacts => {
                let layer_times = coordinator::profile_layer_times(
                    &manifest,
                    self.profile_samples,
                    self.profile_seed,
                )?;
                let alloc = coordinator::balance_by_times(&layer_times, k);
                let times: Vec<f64> = alloc
                    .ranges
                    .iter()
                    .map(|&(lo, hi)| layer_times[lo..hi].iter().sum())
                    .collect();
                let bottleneck = times.iter().copied().fold(f64::NEG_INFINITY, f64::max);
                anyhow::ensure!(
                    bottleneck.is_finite() && bottleneck > 0.0,
                    "profiling produced non-positive stage times"
                );
                (alloc, times, 1.0 / bottleneck)
            }
            TimeSource::Measured => {
                (coordinator::balance_by_macs(&manifest, k), Vec::new(), 0.0)
            }
            TimeSource::Predicted => anyhow::bail!(
                "TimeSource::Predicted applies to zoo networks; artifact plans \
                 use Measured (MAC-balanced) or ProfiledArtifacts"
            ),
        };

        let pipeline = if serial {
            "full-net".to_string()
        } else {
            format!("host-{}", alloc.active_stages())
        };
        let replica = PlanReplica {
            big: 0,
            small: 0,
            pipeline,
            allocation: alloc.ranges.clone(),
            stage_times,
            throughput: replica_tp,
        };
        Ok(Plan {
            network: manifest.name.clone(),
            platform: "host".to_string(),
            big: 0,
            small: 0,
            time_source: self.time_source,
            strategy: self.strategy,
            throughput: replica_tp * replicas_wanted as f64,
            replicas: vec![replica; replicas_wanted],
            artifacts: Some(ArtifactBinding { dir, num_layers: w }),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn roundtrip(plan: &Plan) -> Plan {
        let text = plan.to_json().to_string();
        let j = Json::parse(&text).expect("plan JSON reparses");
        Plan::from_json(&j).expect("plan JSON deserializes")
    }

    #[test]
    fn compiled_plan_roundtrips_through_json() {
        for strategy in [
            Strategy::Serial,
            Strategy::Pipeline,
            Strategy::Exhaustive,
            Strategy::Replicated { max_replicas: 3, exact: false },
            Strategy::Replicated { max_replicas: 2, exact: true },
            Strategy::Energy { min_throughput: 0.0, mem_intensity: 0.6 },
        ] {
            let plan = PlanSpec::new("squeezenet")
                .strategy(strategy)
                .compile()
                .unwrap_or_else(|e| panic!("compile {strategy}: {e}"));
            assert_eq!(plan, roundtrip(&plan), "{strategy} plan changed in round-trip");
        }
    }

    fn arbitrary_plan(rng: &mut Rng) -> Plan {
        let nets = ["alexnet", "squeezenet", "mobilenet"];
        let strategies = [
            Strategy::Serial,
            Strategy::Pipeline,
            Strategy::Exhaustive,
            Strategy::Replicated { max_replicas: 1 + rng.index(4), exact: rng.index(2) == 0 },
            Strategy::Energy {
                min_throughput: rng.range_f64(0.0, 10.0),
                mem_intensity: rng.range_f64(0.3, 0.95),
            },
        ];
        let replicas: Vec<PlanReplica> = (0..1 + rng.index(3))
            .map(|_| {
                let stages = 1 + rng.index(4);
                let mut allocation = Vec::new();
                let mut lo = 0;
                for _ in 0..stages {
                    let hi = lo + 1 + rng.index(9);
                    allocation.push((lo, hi));
                    lo = hi;
                }
                let stage_times: Vec<f64> =
                    (0..stages).map(|_| rng.range_f64(1e-4, 0.2)).collect();
                PlanReplica {
                    big: rng.index(5),
                    small: rng.index(5),
                    pipeline: format!("B{}-s{}", 1 + rng.index(4), 1 + rng.index(4)),
                    allocation,
                    stage_times,
                    throughput: rng.range_f64(0.1, 100.0),
                }
            })
            .collect();
        Plan {
            network: nets[rng.index(nets.len())].to_string(),
            platform: "hikey970".to_string(),
            big: 4,
            small: 4,
            time_source: [TimeSource::Measured, TimeSource::Predicted][rng.index(2)],
            strategy: strategies[rng.index(strategies.len())],
            throughput: rng.range_f64(0.1, 400.0),
            replicas,
            artifacts: if rng.index(2) == 0 {
                None
            } else {
                Some(ArtifactBinding {
                    dir: "artifacts/pipenet_tiny".to_string(),
                    num_layers: 1 + rng.index(20),
                })
            },
        }
    }

    /// The satellite property: Plan JSON round-trips losslessly, including
    /// every f64 (the serializer emits shortest round-trip reprs).
    #[test]
    fn property_plan_json_roundtrip_is_lossless() {
        check(200, |rng| {
            let plan = arbitrary_plan(rng);
            let back = roundtrip(&plan);
            crate::prop_assert!(
                plan == back,
                "round-trip changed the plan:\n{plan:?}\nvs\n{back:?}"
            );
            Ok(())
        });
    }

    #[test]
    fn pipeline_strategy_matches_classic_explore() {
        let cfg = Config::default();
        let net = zoo::by_name("resnet50").unwrap();
        let tm = TimeMatrix::measured(&cfg.platform, &net);
        let pt = dse::explore(&tm, 4, 4);
        let plan = PlanSpec::new("resnet50").compile().unwrap();
        assert_eq!(plan.replicas.len(), 1);
        assert_eq!(plan.replicas[0].pipeline, pt.pipeline.to_string());
        assert_eq!(plan.replicas[0].allocation, pt.allocation.ranges);
        assert!((plan.throughput - pt.throughput).abs() < 1e-12);
        assert_eq!(
            plan.replicas[0].stage_times,
            dse::stage_times(&tm, &pt.pipeline, &pt.allocation)
        );
    }

    #[test]
    fn replicated_strategy_matches_explore_replicated() {
        let cfg = Config::default();
        let net = zoo::by_name("alexnet").unwrap();
        let tm = TimeMatrix::measured(&cfg.platform, &net);
        let fleet = dse::explore_replicated(&tm, 4, 4, 4);
        let plan = PlanSpec::new("alexnet")
            .strategy(Strategy::Replicated { max_replicas: 4, exact: false })
            .compile()
            .unwrap();
        assert_eq!(plan.num_replicas(), fleet.num_replicas());
        assert!((plan.throughput - fleet.throughput).abs() < 1e-12);
        assert_eq!(
            plan.partition_display(),
            fleet.partition_display(),
            "plan must capture the explored partition"
        );
    }

    #[test]
    fn serial_strategy_is_the_big_cluster_baseline() {
        let cfg = Config::default();
        let net = zoo::by_name("mobilenet").unwrap();
        let tm = TimeMatrix::measured(&cfg.platform, &net);
        let b4 = tm.config_index(CoreType::Big, 4).unwrap();
        let tp = 1.0 / tm.range(0, tm.num_layers(), b4);
        let plan =
            PlanSpec::new("mobilenet").strategy(Strategy::Serial).compile().unwrap();
        assert_eq!(plan.replicas[0].pipeline, "B4");
        assert_eq!(plan.replicas[0].allocation, vec![(0, tm.num_layers())]);
        assert!((plan.throughput - tp).abs() < 1e-12);
    }

    #[test]
    fn strategy_ordering_exhaustive_never_loses() {
        // Exhaustive searches a superset of the Eq. 1 space; replicated a
        // superset of that. Serial is the floor.
        let compile = |s: Strategy| {
            PlanSpec::new("squeezenet").strategy(s).compile().unwrap().throughput
        };
        let serial = compile(Strategy::Serial);
        let pipeline = compile(Strategy::Pipeline);
        let exhaustive = compile(Strategy::Exhaustive);
        let replicated = compile(Strategy::Replicated { max_replicas: 4, exact: false });
        assert!(pipeline > serial, "pipelining must beat serial B4");
        assert!(exhaustive >= pipeline - 1e-9);
        assert!(replicated >= exhaustive - 1e-9);
    }

    #[test]
    fn energy_strategy_respects_the_floor() {
        let best = PlanSpec::new("googlenet").compile().unwrap().throughput;
        let plan = PlanSpec::new("googlenet")
            .strategy(Strategy::Energy { min_throughput: 0.9 * best, mem_intensity: 0.6 })
            .compile()
            .unwrap();
        assert!(plan.throughput >= 0.9 * best - 1e-9);
        // An impossible floor is a compile error, not a silent fallback.
        assert!(PlanSpec::new("googlenet")
            .strategy(Strategy::Energy { min_throughput: best * 10.0, mem_intensity: 0.6 })
            .compile()
            .is_err());
    }

    #[test]
    fn pinned_pipeline_is_recorded_and_validated() {
        let plan = PlanSpec::new("resnet50").pipeline("B4-s2-s2").compile().unwrap();
        assert_eq!(plan.replicas[0].pipeline, "B4-s2-s2");
        assert_eq!(plan.replicas[0].stage_times.len(), 3);
        let err = PlanSpec::new("resnet50").pipeline("B4-B1-s4").compile().unwrap_err();
        assert!(err.to_string().contains("core budget"), "{err}");
    }

    #[test]
    fn simulate_dispatches_to_the_des() {
        let plan = PlanSpec::new("alexnet")
            .strategy(Strategy::Replicated { max_replicas: 2, exact: true })
            .compile()
            .unwrap();
        let times: Vec<Vec<f64>> =
            plan.replicas.iter().map(|r| r.stage_times.clone()).collect();
        let direct = pipeline_sim::simulate_replicated(&times, 300, 2);
        let via_plan = plan.simulate(300, 2).unwrap();
        assert!((via_plan.throughput - direct.throughput).abs() < 1e-12);
        assert_eq!(via_plan.images, 300);
        assert_eq!(via_plan.replicas.len(), 2);
        assert!(via_plan.latency.is_some());
    }

    #[test]
    fn bad_inputs_are_compile_errors() {
        assert!(PlanSpec::new("vgg19").compile().is_err(), "unknown network");
        assert!(
            PlanSpec::new("alexnet")
                .time_source(TimeSource::ProfiledArtifacts)
                .compile()
                .is_err(),
            "profiled times need an artifact spec"
        );
        assert!(
            PlanSpec::new("alexnet")
                .strategy(Strategy::Replicated { max_replicas: 9, exact: true })
                .compile()
                .is_err(),
            "9 replicas cannot fit on 8 cores"
        );
    }

    #[test]
    fn replan_on_same_matrix_reproduces_the_design() {
        let cfg = Config::default();
        let net = zoo::by_name("squeezenet").unwrap();
        let tm = TimeMatrix::measured(&cfg.platform, &net);
        let plan = PlanSpec::new("squeezenet").compile().unwrap();
        let again = plan.replan_on_matrix(&tm, &cfg.power).unwrap();
        assert_eq!(plan, again, "replanning on the compile-time matrix must be a no-op");
    }

    #[test]
    fn replan_on_throttled_matrix_matches_a_fresh_search() {
        let cfg = Config::default();
        let net = zoo::by_name("alexnet").unwrap();
        let mut tm = TimeMatrix::measured(&cfg.platform, &net);
        tm.scale_core(CoreType::Big, 2.0);
        let plan = PlanSpec::new("alexnet").compile().unwrap();
        let replanned = plan.replan_on_matrix(&tm, &cfg.power).unwrap();
        let fresh = dse::explore(&tm, 4, 4);
        assert_eq!(replanned.replicas[0].pipeline, fresh.pipeline.to_string());
        assert_eq!(replanned.replicas[0].allocation, fresh.allocation.ranges);
        assert!((replanned.throughput - fresh.throughput).abs() < 1e-12);
        // The plan identity survives the re-plan.
        assert_eq!(replanned.network, plan.network);
        assert_eq!(replanned.strategy, plan.strategy);
        assert_eq!(replanned.time_source, plan.time_source);
    }

    #[test]
    fn replan_rejects_a_matrix_for_another_network() {
        let cfg = Config::default();
        let other = zoo::by_name("mobilenet").unwrap();
        let tm = TimeMatrix::measured(&cfg.platform, &other);
        let plan = PlanSpec::new("alexnet").compile().unwrap();
        let err = plan.replan_on_matrix(&tm, &cfg.power).unwrap_err();
        assert!(err.to_string().contains("alexnet"), "{err}");
    }

    #[test]
    fn load_names_the_offending_field_on_schema_mismatch() {
        let plan = PlanSpec::new("alexnet").compile().unwrap();
        let good = plan.to_json();

        // Schema-version mismatch must name the version field, not default.
        let mut j = good.clone();
        if let Json::Obj(m) = &mut j {
            m.insert("version".to_string(), Json::num(99.0));
        }
        let err = Plan::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("\"version\""), "{err}");
        assert!(err.contains("99"), "{err}");

        // Unknown strategy tag must name strategy.kind, not fall back to a
        // default strategy.
        let mut j = good.clone();
        if let Json::Obj(m) = &mut j {
            m.insert(
                "strategy".to_string(),
                Json::obj(vec![("kind", Json::str("magic"))]),
            );
        }
        let err = Plan::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("strategy.kind"), "{err}");
        assert!(err.contains("magic"), "{err}");

        // Unknown time-source tag must name its field too.
        let mut j = good;
        if let Json::Obj(m) = &mut j {
            m.insert("time_source".to_string(), Json::str("vibes"));
        }
        let err = Plan::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("time_source"), "{err}");
        assert!(err.contains("vibes"), "{err}");
    }

    #[test]
    fn from_json_rejects_malformed_plans() {
        let plan = PlanSpec::new("alexnet").compile().unwrap();
        let good = plan.to_json();

        // Wrong version.
        let mut j = good.clone();
        if let Json::Obj(m) = &mut j {
            m.insert("version".to_string(), Json::num(99.0));
        }
        assert!(Plan::from_json(&j).unwrap_err().to_string().contains("version"));

        // Missing strategy.
        let mut j = good.clone();
        if let Json::Obj(m) = &mut j {
            m.remove("strategy");
        }
        assert!(Plan::from_json(&j).is_err());

        // Non-contiguous allocation.
        let text = good.to_string().replace("[[0,", "[[1,");
        let j = Json::parse(&text).unwrap();
        let err = Plan::from_json(&j).unwrap_err();
        let shown = format!("{err:?}");
        assert!(shown.contains("partition"), "{shown}");
    }
}
