//! Multi-tenant co-serving (DESIGN.md §10): several CNNs sharing one
//! big.LITTLE board under joint planning and SLA-aware admission.
//!
//! Pipe-it plans one network per board, but a production edge node serves
//! several at once — e.g. a detector and a classifier sharing the same 4+4
//! cluster budget. Static per-network partitioning of heterogeneous
//! resources leaves throughput on the table whenever load or compute
//! efficiency is asymmetric (PICO, arXiv 2206.08662; dynamic distribution
//! of edge intelligence, arXiv 2107.05828). Because every candidate design
//! is scored by the same TimeMatrix-driven Eq. 10/12 predictions, the
//! joint partition search is analytic — no profiling loop required:
//!
//! * [`TenantSpec`] — one tenant's workload and contract: network (or an
//!   existing plan artifact), Poisson arrival rate, optional p99 SLA,
//!   weight.
//! * [`explore_joint`] — the joint DSE: enumerate core-budget splits
//!   across tenants ([`joint::splits`]), reuse the replicated-pipeline
//!   search ([`crate::dse::explore_replicated`]) inside each slice, rank
//!   by (SLAs met, weighted served rate, capacity).
//! * [`MultiPlan`] — the schema-versioned serializable artifact embedding
//!   one ordinary [`Plan`](crate::api::Plan) per tenant; save → load →
//!   simulate is lossless.
//! * [`simulate_multi`] / [`deploy_multi`] — the execution twins: a DES
//!   co-simulation of the merged Poisson streams with per-tenant bounded
//!   admission ([`simulate_tenant_fleet`]), and a wall-clock deploy running
//!   each tenant's fleet behind a shared shed-on-full front door. Both
//!   return one [`MultiServeReport`], rendered by
//!   [`crate::reports::render_multi_serve`].
//!
//! The CLI surface is `pipeit plan-multi / serve-multi / simulate-multi`.
//!
//! # Example
//!
//! ```
//! use pipeit::config::Config;
//! use pipeit::tenancy::{MultiPlan, MultiServeOptions, TenantSpec};
//!
//! let specs = [
//!     TenantSpec::new("alexnet", 5.0),
//!     TenantSpec::new("squeezenet", 10.0).with_sla(2.0),
//! ];
//! let mp = MultiPlan::compile(&specs, &Config::default(), 4).unwrap();
//! let report = mp
//!     .simulate(&MultiServeOptions { images: 200, ..Default::default() })
//!     .unwrap();
//! assert_eq!(report.tenants.len(), 2);
//! assert!(report.weighted_throughput > 0.0);
//! ```

pub mod cosim;
pub mod deploy;
pub mod joint;
pub mod multiplan;
pub mod report;
pub mod spec;

pub use cosim::{
    simulate_multi, simulate_multi_recorded, simulate_tenant_fleet,
    simulate_tenant_fleet_recorded, TenantSimOutcome,
};
pub use deploy::{deploy_multi, deploy_multi_recorded};
pub use joint::{explore_joint, predict_p99, JointDesign, TenantDesign};
pub use multiplan::{MultiPlan, TenantPlan, MULTI_PLAN_VERSION};
pub use report::{
    MultiServeMode, MultiServeOptions, MultiServeReport, TenantReport,
};
pub use spec::{parse_duration_s, TenantSpec};
