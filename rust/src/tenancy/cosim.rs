//! Discrete-event co-simulation of a multi-tenant board: merged per-tenant
//! Poisson arrival streams ([`crate::simulator::arrivals::poisson_arrivals`])
//! over each tenant's replicated-pipeline recurrence, with a bounded
//! per-tenant admission queue that sheds on overflow.
//!
//! Because the joint DSE assigns *disjoint* core slices, tenants never
//! contend for compute — the merged-stream co-simulation factorizes into
//! one exact open-loop simulation per tenant (this is precisely why the
//! planner partitions cores instead of time-sharing them). What remains
//! shared is the accounting: one clock, one report, one board-utilization
//! figure ([`MultiServeReport`]).
//!
//! The per-tenant engine ([`simulate_tenant_fleet`]) runs on the shared
//! event core ([`crate::simulator::engine`], DESIGN.md §15): bounded
//! departure rings carry the blocking tandem recurrence of
//! [`crate::simulator::pipeline_sim`] in O(stages · queue_cap) state, and
//! the front door counts waiting admissions with an [`EventHeap`] in
//! amortized O(log n) per arrival — replacing the historical O(n²)
//! linear scan over every admitted start time. That historical engine is
//! retained verbatim as `simulate_tenant_fleet_reference`, the oracle
//! the differential suite (`tests/engine_core.rs`) holds the fast engine
//! bit-identical against.
//!
//! Front-door semantics are unchanged: an arrival finding `admission_cap`
//! admitted-but-unstarted items ahead of it is shed (counted), exactly
//! mirroring the wall-clock front door's `try_send`
//! ([`crate::tenancy::deploy_multi`]).

use anyhow::{Context, Result};

use crate::obs::{attrib_for, EngineProf, LogHist, PredictedTimes, Recorder};
use crate::simulator::arrivals::{poisson_arrivals, uniform_arrivals};
use crate::simulator::engine::{tandem_step, CoreCounters, EventHeap, RingArena, RingId};

use crate::api::LatencyReport;

use super::multiplan::MultiPlan;
use super::report::{
    core_seconds, MultiServeMode, MultiServeOptions, MultiServeReport, TenantReport,
};

/// Raw result of one tenant's open-loop fleet simulation.
#[derive(Debug, Clone)]
pub struct TenantSimOutcome {
    /// Arrivals offered at the front door.
    pub offered: usize,
    /// Arrivals admitted (offered − shed); all admitted items complete.
    pub admitted: usize,
    /// Arrivals dropped because the admission queue was full.
    pub shed: usize,
    /// Time of the last departure (0.0 when nothing was admitted).
    pub makespan: f64,
    /// Per-admitted-item end-to-end latency (arrival → last departure).
    pub latencies: Vec<f64>,
    /// Items routed to each replica.
    pub dispatched: Vec<usize>,
    /// Per-replica per-stage busy seconds.
    pub busy: Vec<Vec<f64>>,
    /// Front-door scan work. The event-core engine retires each admitted
    /// start with one heap pop, so this is bounded by `admitted` — linear
    /// in events, the bound CI asserts (DESIGN.md §15). (The reference
    /// engine reports its historical O(n²) linear-scan count here.)
    pub scan_iters: u64,
    /// Event-core tallies (heap pushes/pops/peak, ring peak) for
    /// [`EngineProf`](crate::obs::EngineProf). Zero from the reference engine.
    pub core: CoreCounters,
}

/// Simulate one tenant's replicated fleet under timed arrivals with a
/// bounded front-door admission queue.
///
/// * `replica_stage_times[r]` — replica `r`'s deterministic per-stage
///   service times (Eq. 10).
/// * `arrivals` — non-decreasing arrival times (e.g. Poisson).
/// * `queue_cap` — inter-stage buffer capacity inside each replica.
/// * `admission_cap` — how many admitted items may wait for service
///   (admitted but not yet started at their replica's first stage) before
///   the front door sheds new arrivals.
///
/// Dispatch is join-earliest-start: each admitted arrival goes to the
/// replica whose first stage can take it soonest (ties to the lowest
/// index), the deterministic analogue of the wall-clock fleet's
/// least-outstanding-work policy. Each replica's stream then follows the
/// exact blocking tandem-queue recurrence of
/// [`crate::simulator::pipeline_sim::simulate`], with the item's arrival
/// time replacing the saturated source.
pub fn simulate_tenant_fleet(
    replica_stage_times: &[Vec<f64>],
    arrivals: &[f64],
    queue_cap: usize,
    admission_cap: usize,
) -> TenantSimOutcome {
    simulate_tenant_fleet_recorded(
        replica_stage_times,
        arrivals,
        queue_cap,
        admission_cap,
        &Recorder::off(),
        0,
    )
}

/// [`simulate_tenant_fleet`] with span recording: every arrival leaves a
/// chain in `rec` under `group` (the tenant index) — a lone shed span
/// when the front door turns it away, otherwise admit → per-stage service
/// → depart, all stamped with simulation time. The item id is the arrival
/// index, so same-seed traces are byte-identical. The recorder is
/// write-only: with [`Recorder::off`] this is exactly
/// [`simulate_tenant_fleet`].
pub fn simulate_tenant_fleet_recorded(
    replica_stage_times: &[Vec<f64>],
    arrivals: &[f64],
    queue_cap: usize,
    admission_cap: usize,
    rec: &Recorder,
    group: u32,
) -> TenantSimOutcome {
    assert!(!replica_stage_times.is_empty(), "tenant needs at least one replica");
    assert!(replica_stage_times.iter().all(|t| !t.is_empty()));
    assert!(queue_cap >= 1);
    assert!(admission_cap >= 1);
    debug_assert!(
        arrivals.windows(2).all(|w| w[0] <= w[1]),
        "front door requires non-decreasing arrivals"
    );
    let r = replica_stage_times.len();

    // Bounded state (DESIGN.md §15): one ring of the last `queue_cap + 1`
    // departures per (replica, stage) — exactly the window the blocking
    // recurrence reads — all arena-allocated in one buffer.
    let mut arena = RingArena::new();
    let rings: Vec<Vec<RingId>> = replica_stage_times
        .iter()
        .map(|t| t.iter().map(|_| arena.alloc(queue_cap + 1)).collect())
        .collect();
    // Front door: stage-0 start times of admitted items, in an event heap.
    // `live_after(a)` retires starts ≤ a (each popped at most once, so the
    // total scan work is ≤ admitted) and returns the waiting count — equal
    // to the reference engine's linear scan because arrivals never
    // decrease: a start retired at one arrival can be "> a" at no later
    // arrival.
    let mut door = EventHeap::default();
    let mut latencies = Vec::new();
    let mut dispatched = vec![0usize; r];
    // Per-replica final-stage departure of the newest item (for makespan,
    // folded in replica order to match the reference engine bit-for-bit).
    let mut last_final = vec![0.0f64; r];
    let mut shed = 0usize;

    for (i, &a) in arrivals.iter().enumerate() {
        // Front door: count admitted items still waiting to start service.
        let waiting = door.live_after(a);
        if rec.enabled() {
            rec.gauge_max(&format!("queue_depth_peak/g{group}"), waiting as f64);
        }
        if waiting >= admission_cap {
            shed += 1;
            rec.shed(group, i as u64, a);
            continue;
        }
        rec.admit(group, i as u64, a);
        // Join-earliest-start dispatch (estimate ignores downstream
        // blocking, which only delays starts further on loaded replicas).
        let pick = (0..r)
            .min_by(|&x, &y| {
                let ex = arena.back(rings[x][0]).unwrap_or(0.0).max(a);
                let ey = arena.back(rings[y][0]).unwrap_or(0.0).max(a);
                ex.total_cmp(&ey)
            })
            .expect("nonempty fleet");

        let out = tandem_step(
            &mut arena,
            &rings[pick],
            &replica_stage_times[pick],
            a,
            |s, start, _svc, dep| {
                if s == 0 {
                    door.push(start);
                }
                if rec.enabled() {
                    rec.stage(group, i as u64, pick as u32, s as u32, start, dep);
                }
            },
        );
        rec.depart(group, i as u64, pick as u32, out);
        last_final[pick] = out;
        latencies.push(out - a);
        dispatched[pick] += 1;
    }

    let makespan = last_final.iter().copied().fold(0.0, f64::max);
    let busy: Vec<Vec<f64>> = replica_stage_times
        .iter()
        .zip(&dispatched)
        .map(|(times, &n)| times.iter().map(|t| t * n as f64).collect())
        .collect();

    TenantSimOutcome {
        offered: arrivals.len(),
        admitted: latencies.len(),
        shed,
        makespan,
        latencies,
        dispatched,
        busy,
        scan_iters: door.pops,
        core: CoreCounters {
            heap_pushes: door.pushes,
            heap_pops: door.pops,
            heap_peak: door.peak,
            ring_peak: arena.peak(),
        },
    }
}

/// The historical full-history engine, retained verbatim as the
/// differential oracle for the event core (DESIGN.md §15): O(n) state and
/// an O(n²) front-door scan, but the exact float-operation order the fast
/// engine must reproduce bit-for-bit. Not for production use.
#[doc(hidden)]
pub fn simulate_tenant_fleet_reference(
    replica_stage_times: &[Vec<f64>],
    arrivals: &[f64],
    queue_cap: usize,
    admission_cap: usize,
) -> TenantSimOutcome {
    simulate_tenant_fleet_reference_recorded(
        replica_stage_times,
        arrivals,
        queue_cap,
        admission_cap,
        &Recorder::off(),
        0,
    )
}

/// Recorded form of `simulate_tenant_fleet_reference` (same span
/// vocabulary as the fast engine, for trace-level differential tests).
#[doc(hidden)]
pub fn simulate_tenant_fleet_reference_recorded(
    replica_stage_times: &[Vec<f64>],
    arrivals: &[f64],
    queue_cap: usize,
    admission_cap: usize,
    rec: &Recorder,
    group: u32,
) -> TenantSimOutcome {
    assert!(!replica_stage_times.is_empty(), "tenant needs at least one replica");
    assert!(replica_stage_times.iter().all(|t| !t.is_empty()));
    assert!(queue_cap >= 1);
    assert!(admission_cap >= 1);
    let r = replica_stage_times.len();

    // dep[q][s][k]: departure time of replica q's k-th item from stage s.
    let mut dep: Vec<Vec<Vec<f64>>> = replica_stage_times
        .iter()
        .map(|t| vec![Vec::new(); t.len()])
        .collect();
    // Stage-0 start times of every admitted item (front-door occupancy).
    let mut start0_all: Vec<f64> = Vec::new();
    let mut latencies = Vec::new();
    let mut dispatched = vec![0usize; r];
    let mut shed = 0usize;
    let mut scan_iters = 0u64;

    for (i, &a) in arrivals.iter().enumerate() {
        // Front door: the O(n²) linear scan the event core replaced.
        scan_iters += start0_all.len() as u64;
        let waiting = start0_all.iter().filter(|&&t| t > a).count();
        if rec.enabled() {
            rec.gauge_max(&format!("queue_depth_peak/g{group}"), waiting as f64);
        }
        if waiting >= admission_cap {
            shed += 1;
            rec.shed(group, i as u64, a);
            continue;
        }
        rec.admit(group, i as u64, a);
        let pick = (0..r)
            .min_by(|&x, &y| {
                let ex = dep[x][0].last().copied().unwrap_or(0.0).max(a);
                let ey = dep[y][0].last().copied().unwrap_or(0.0).max(a);
                ex.total_cmp(&ey)
            })
            .expect("nonempty fleet");

        let times = &replica_stage_times[pick];
        let p = times.len();
        let k = dep[pick][0].len();
        let mut prev_stage_dep = 0.0;
        for s in 0..p {
            let arrive = if s == 0 {
                let prev = if k == 0 { 0.0 } else { dep[pick][0][k - 1] };
                a.max(prev)
            } else {
                let prev = if k == 0 { 0.0 } else { dep[pick][s][k - 1] };
                prev_stage_dep.max(prev)
            };
            let unblock = if s + 1 < p && k > queue_cap {
                dep[pick][s + 1][k - queue_cap - 1]
            } else {
                0.0
            };
            let start = arrive.max(unblock);
            if s == 0 {
                start0_all.push(start);
            }
            prev_stage_dep = start + times[s];
            dep[pick][s].push(prev_stage_dep);
            if rec.enabled() {
                rec.stage(group, i as u64, pick as u32, s as u32, start, prev_stage_dep);
            }
        }
        rec.depart(group, i as u64, pick as u32, prev_stage_dep);
        latencies.push(prev_stage_dep - a);
        dispatched[pick] += 1;
    }

    let makespan = dep
        .iter()
        .map(|stages| stages.last().and_then(|d| d.last()).copied().unwrap_or(0.0))
        .fold(0.0, f64::max);
    let busy: Vec<Vec<f64>> = replica_stage_times
        .iter()
        .zip(&dispatched)
        .map(|(times, &n)| times.iter().map(|t| t * n as f64).collect())
        .collect();

    TenantSimOutcome {
        offered: arrivals.len(),
        admitted: latencies.len(),
        shed,
        makespan,
        latencies,
        dispatched,
        busy,
        scan_iters,
        core: CoreCounters::default(),
    }
}

/// One tenant's arrival stream under `opts`: Poisson by default (seeded by
/// [`MultiServeOptions::tenant_seed`]), uniform when the run asked for it.
/// Shared with the wall-clock front door so both twins pace identically.
pub(crate) fn tenant_arrivals(
    rate_hz: f64,
    pinned_seed: Option<u64>,
    idx: usize,
    opts: &MultiServeOptions,
) -> Vec<f64> {
    if opts.uniform_arrivals {
        uniform_arrivals(rate_hz, opts.images)
    } else {
        poisson_arrivals(rate_hz, opts.images, opts.tenant_seed(pinned_seed, idx))
    }
}

/// Tenant-level utilization: the busiest stage's busy fraction over the
/// tenant's makespan (0.0 for an idle tenant).
fn tenant_utilization(out: &TenantSimOutcome) -> f64 {
    if out.makespan <= 0.0 {
        return 0.0;
    }
    out.busy
        .iter()
        .flat_map(|stages| stages.iter())
        .fold(0.0f64, |m, b| m.max(b / out.makespan))
}

/// DES co-simulation of a compiled [`MultiPlan`]: generate each tenant's
/// Poisson stream, run the per-tenant fleet recurrence, and merge the
/// outcome into one [`MultiServeReport`].
pub fn simulate_multi(mp: &MultiPlan, opts: &MultiServeOptions) -> Result<MultiServeReport> {
    simulate_multi_recorded(mp, opts, &Recorder::off())
}

/// [`simulate_multi`] with span recording: tenant `i`'s items trace under
/// group `i`, and the recorder's registry picks up the shared metric
/// vocabulary (DESIGN.md §13) — `latency` pooled across tenants,
/// per-stage `stage_service`/`occupancy`, front-door `queue_depth_peak`.
pub fn simulate_multi_recorded(
    mp: &MultiPlan,
    opts: &MultiServeOptions,
    rec: &Recorder,
) -> Result<MultiServeReport> {
    anyhow::ensure!(opts.images >= 1, "need at least one arrival per tenant");
    anyhow::ensure!(opts.queue_cap >= 1, "queue capacity must be >= 1");
    anyhow::ensure!(opts.admission_cap >= 1, "admission capacity must be >= 1");

    let mut prof = EngineProf::start("tenancy", rec);
    let mut tenants = Vec::with_capacity(mp.tenants.len());
    let mut outcomes = Vec::with_capacity(mp.tenants.len());
    for (i, t) in mp.tenants.iter().enumerate() {
        let times: Vec<Vec<f64>> =
            t.plan.replicas.iter().map(|r| r.stage_times.clone()).collect();
        let arrivals = tenant_arrivals(t.rate_hz, t.seed, i, opts);
        let out = simulate_tenant_fleet_recorded(
            &times,
            &arrivals,
            opts.queue_cap,
            opts.admission_cap,
            rec,
            i as u32,
        );
        if rec.enabled() {
            rec.observe_hist("latency", &LogHist::of(&out.latencies));
        }
        let latency = LatencyReport::from_latencies(&out.latencies);
        let throughput =
            if out.makespan > 0.0 { out.admitted as f64 / out.makespan } else { 0.0 };
        tenants.push(TenantReport {
            name: t.name.clone(),
            network: t.plan.network.clone(),
            budget: format!("{}B+{}s", t.plan.big, t.plan.small),
            pipeline: t.partition_display(),
            rate_hz: t.rate_hz,
            weight: t.weight,
            offered: out.offered,
            admitted: out.admitted,
            shed: out.shed,
            throughput,
            capacity: t.plan.throughput,
            latency,
            p99_sla_s: t.p99_sla_s,
            sla_ok: t
                .p99_sla_s
                .map(|sla| latency.map_or(false, |l| l.p99 <= sla)),
            utilization: tenant_utilization(&out),
        });
        outcomes.push(out);
    }

    let wall_s = outcomes.iter().map(|o| o.makespan).fold(0.0, f64::max);
    let mut busy_core_s = 0.0;
    for (t, out) in mp.tenants.iter().zip(&outcomes) {
        busy_core_s += core_seconds(&t.plan, &out.busy)
            .with_context(|| format!("tenant {:?}", t.name))?;
    }
    let total_cores = (mp.big + mp.small) as f64;
    let board_utilization =
        if wall_s > 0.0 { busy_core_s / (total_cores * wall_s) } else { 0.0 };
    let weighted_throughput =
        tenants.iter().map(|t| t.weight * t.throughput).sum();
    if rec.enabled() {
        rec.gauge_set("wall_s", wall_s);
        for (i, out) in outcomes.iter().enumerate() {
            for (r, stages) in out.busy.iter().enumerate() {
                for (s, b) in stages.iter().enumerate() {
                    let occ = if wall_s > 0.0 { b / wall_s } else { 0.0 };
                    rec.gauge_set(&format!("occupancy/g{i}r{r}s{s}"), occ);
                }
            }
        }
    }

    // Engine profile (DESIGN.md §14/§15): one event per front-door decision
    // plus one per (item, stage) executed. The event-core engine's heap
    // carries the front door, so the heap counters are live — and
    // `scan_iters` (now heap pops) stays ≤ events, the linear bound the
    // bench-smoke CI job asserts.
    if prof.active() {
        for (t, out) in mp.tenants.iter().zip(&outcomes) {
            prof.events += out.offered as u64;
            for (r, rep) in t.plan.replicas.iter().enumerate() {
                prof.events += out.dispatched[r] as u64 * rep.stage_times.len() as u64;
            }
            prof.scan_iters += out.scan_iters;
            prof.heap_pushes += out.core.heap_pushes;
            prof.heap_pops += out.core.heap_pops;
            prof.heap_peak = prof.heap_peak.max(out.core.heap_peak);
            prof.ring_peak = prof.ring_peak.max(out.core.ring_peak);
        }
        prof.flush(rec);
    }
    let attrib = if rec.enabled() {
        let mut pred = PredictedTimes::new();
        for (i, t) in mp.tenants.iter().enumerate() {
            let times: Vec<Vec<f64>> =
                t.plan.replicas.iter().map(|r| r.stage_times.clone()).collect();
            pred.insert_replicas(i as u32, &times);
        }
        attrib_for(rec, &pred, Vec::new())
    } else {
        None
    };

    Ok(MultiServeReport {
        mode: MultiServeMode::Des,
        wall_s,
        images: tenants.iter().map(|t| t.admitted).sum(),
        shed: tenants.iter().map(|t| t.shed).sum(),
        weighted_throughput,
        board_utilization,
        tenants,
        metrics: rec.snapshot(),
        attrib,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::arrivals::uniform_arrivals;
    use crate::simulator::pipeline_sim;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;
    use crate::util::stats;

    #[test]
    fn underloaded_tenant_sheds_nothing_and_sees_service_latency() {
        // One 2-stage replica at 50/s capacity, offered 5/s: every item
        // admitted, latency == service time.
        let times = vec![vec![0.01, 0.02]];
        let arr = uniform_arrivals(5.0, 100);
        let out = simulate_tenant_fleet(&times, &arr, 2, 4);
        assert_eq!(out.shed, 0);
        assert_eq!(out.admitted, 100);
        for l in &out.latencies {
            assert!((l - 0.03).abs() < 1e-12, "latency {l}");
        }
    }

    #[test]
    fn overloaded_tenant_sheds_but_bounds_latency() {
        // Offered 4x capacity: the bounded front door sheds the excess and
        // admitted items wait at most ~cap service times.
        let times = vec![vec![0.02]];
        let arr = uniform_arrivals(200.0, 400);
        let out = simulate_tenant_fleet(&times, &arr, 2, 4);
        assert!(out.shed > 200, "shed {}", out.shed);
        assert_eq!(out.admitted + out.shed, 400);
        let worst = out.latencies.iter().copied().fold(0.0, f64::max);
        assert!(
            worst <= 0.02 * 6.0 + 1e-9,
            "bounded queue must bound latency, got {worst}"
        );
    }

    #[test]
    fn saturating_arrivals_reach_fleet_capacity() {
        // Arrivals far above capacity: served rate approaches the Eq. 12
        // sum of replica rates.
        let times = vec![vec![0.02, 0.01], vec![0.04]];
        let cap_rate = 1.0 / 0.02 + 1.0 / 0.04;
        let arr = uniform_arrivals(1000.0, 3000);
        let out = simulate_tenant_fleet(&times, &arr, 2, 8);
        let rate = out.admitted as f64 / out.makespan;
        assert!(
            (rate - cap_rate).abs() / cap_rate < 0.05,
            "served {rate:.1} vs capacity {cap_rate:.1}"
        );
    }

    #[test]
    fn single_replica_with_loose_door_matches_open_loop_recurrence() {
        // With an admission cap no arrival ever hits, the per-tenant engine
        // must reproduce the plain open-loop recurrence exactly.
        let times = [0.015, 0.03, 0.01];
        let arr = crate::simulator::arrivals::poisson_arrivals(20.0, 300, 5);
        let open = crate::simulator::arrivals::simulate_open_loop(&times, &arr, 2, 1.0);
        let out = simulate_tenant_fleet(&[times.to_vec()], &arr, 2, usize::MAX / 2);
        assert_eq!(out.shed, 0);
        let p50 = stats::percentile(&out.latencies, 50.0);
        let p99 = stats::percentile(&out.latencies, 99.0);
        assert!((p50 - open.p50_latency).abs() < 1e-9, "{p50} vs {}", open.p50_latency);
        assert!((p99 - open.p99_latency).abs() < 1e-9, "{p99} vs {}", open.p99_latency);
        assert!((out.makespan - open.makespan).abs() < 1e-9);
    }

    #[test]
    fn saturated_fleet_matches_closed_loop_steady_state() {
        // All arrivals at t=0 with a huge admission cap ~ the saturated
        // closed-loop fleet: throughput must match the Eq. 12 sum closely.
        let replicas = vec![vec![0.01, 0.02], vec![0.03]];
        let arr = vec![0.0; 2000];
        let out = simulate_tenant_fleet(&replicas, &arr, 2, usize::MAX / 2);
        let closed = pipeline_sim::simulate_replicated(&replicas, 2000, 2);
        let rate = out.admitted as f64 / out.makespan;
        let rel = (rate - closed.throughput).abs() / closed.throughput;
        assert!(rel < 0.05, "open {rate:.2} vs closed {:.2}", closed.throughput);
    }

    #[test]
    fn dispatch_is_rate_proportional_under_load() {
        let replicas = vec![vec![0.01], vec![0.03]];
        let arr = uniform_arrivals(500.0, 2000);
        let out = simulate_tenant_fleet(&replicas, &arr, 2, 6);
        let share = out.dispatched[0] as f64 / out.dispatched[1].max(1) as f64;
        assert!((2.0..4.5).contains(&share), "share {share:.2} ({:?})", out.dispatched);
    }

    #[test]
    fn property_conservation_and_latency_floor() {
        check(60, |rng| {
            let r = 1 + rng.index(3);
            let replicas: Vec<Vec<f64>> = (0..r)
                .map(|_| {
                    let p = 1 + rng.index(3);
                    (0..p).map(|_| rng.range_f64(0.002, 0.03)).collect()
                })
                .collect();
            let rate = rng.range_f64(5.0, 300.0);
            let n = 50 + rng.index(300);
            let arr = poisson_arrivals(rate, n, rng.next_u64());
            let cap = 1 + rng.index(3);
            let adm = 1 + rng.index(8);
            let out = simulate_tenant_fleet(&replicas, &arr, cap, adm);
            crate::prop_assert!(
                out.admitted + out.shed == n,
                "conservation: {} + {} != {n}",
                out.admitted,
                out.shed
            );
            crate::prop_assert!(
                out.dispatched.iter().sum::<usize>() == out.admitted,
                "dispatch mismatch"
            );
            let min_service: f64 = replicas
                .iter()
                .map(|t| t.iter().sum::<f64>())
                .fold(f64::INFINITY, f64::min);
            for l in &out.latencies {
                crate::prop_assert!(
                    *l >= min_service - 1e-9,
                    "latency {l} below fastest service path {min_service}"
                );
            }
            Ok(())
        });
    }

    /// The event-core contract (DESIGN.md §15): the fast engine is
    /// bit-identical to the retained reference engine on randomized
    /// workloads — every latency, the makespan, shed/dispatch counts.
    #[test]
    fn property_fast_engine_is_bit_identical_to_reference() {
        check(40, |rng| {
            let r = 1 + rng.index(3);
            let replicas: Vec<Vec<f64>> = (0..r)
                .map(|_| {
                    let p = 1 + rng.index(4);
                    (0..p).map(|_| rng.range_f64(0.002, 0.03)).collect()
                })
                .collect();
            let rate = rng.range_f64(5.0, 400.0);
            let n = 50 + rng.index(400);
            let arr = poisson_arrivals(rate, n, rng.next_u64());
            let cap = 1 + rng.index(3);
            let adm = 1 + rng.index(8);
            let fast = simulate_tenant_fleet(&replicas, &arr, cap, adm);
            let slow = simulate_tenant_fleet_reference(&replicas, &arr, cap, adm);
            crate::prop_assert!(fast.shed == slow.shed, "shed diverged");
            crate::prop_assert!(fast.dispatched == slow.dispatched, "dispatch diverged");
            crate::prop_assert!(
                fast.makespan.to_bits() == slow.makespan.to_bits(),
                "makespan diverged: {} vs {}",
                fast.makespan,
                slow.makespan
            );
            crate::prop_assert!(
                fast.latencies.len() == slow.latencies.len(),
                "admitted diverged"
            );
            for (i, (f, s)) in fast.latencies.iter().zip(&slow.latencies).enumerate() {
                crate::prop_assert!(
                    f.to_bits() == s.to_bits(),
                    "latency {i} diverged: {f} vs {s}"
                );
            }
            Ok(())
        });
    }

    /// The O(log n) front door retires each admitted start exactly once:
    /// scan work is linear in arrivals, not quadratic (the fixed bug).
    #[test]
    fn front_door_scan_work_is_linear_in_arrivals() {
        let replicas = vec![vec![0.01, 0.02]];
        let arr = uniform_arrivals(300.0, 4000);
        let out = simulate_tenant_fleet(&replicas, &arr, 2, 4);
        assert!(
            out.scan_iters <= out.offered as u64,
            "scan_iters {} must be ≤ offered {} (heap pops, each start once)",
            out.scan_iters,
            out.offered
        );
        assert_eq!(out.core.heap_pushes, out.admitted as u64);
        assert!(out.core.heap_pops <= out.core.heap_pushes);
        // The reference engine on the same stream really is quadratic-ish:
        // its scan count dwarfs the fast engine's.
        let slow = simulate_tenant_fleet_reference(&replicas, &arr, 2, 4);
        assert!(
            slow.scan_iters > 10 * out.scan_iters.max(1),
            "reference scanned {} vs fast {}",
            slow.scan_iters,
            out.scan_iters
        );
    }

    /// Regression (ISSUE 5 satellite): a tenant that admits nothing — the
    /// fully-shed extreme — must produce a well-defined outcome and a
    /// `None` latency report, not a panic or an index past the end.
    #[test]
    fn zero_admitted_tenant_is_well_defined() {
        let out = simulate_tenant_fleet(&[vec![0.01, 0.02]], &[], 2, 1);
        assert_eq!(out.offered, 0);
        assert_eq!(out.admitted, 0);
        assert_eq!(out.shed, 0);
        assert_eq!(out.makespan, 0.0);
        assert!(out.latencies.is_empty());
        assert_eq!(out.dispatched, vec![0]);
        assert_eq!(LatencyReport::from_latencies(&out.latencies), None);
        assert_eq!(tenant_utilization(&out), 0.0);
        let throughput =
            if out.makespan > 0.0 { out.admitted as f64 / out.makespan } else { 0.0 };
        assert_eq!(throughput, 0.0);
    }

    #[test]
    fn determinism_same_seed_same_outcome() {
        let mut rng = Rng::new(9);
        let replicas = vec![vec![0.01, 0.02]];
        let arr = poisson_arrivals(40.0, 500, rng.next_u64());
        let a = simulate_tenant_fleet(&replicas, &arr, 2, 4);
        let b = simulate_tenant_fleet(&replicas, &arr, 2, 4);
        assert_eq!(a.latencies, b.latencies);
        assert_eq!(a.shed, b.shed);
        assert_eq!(a.dispatched, b.dispatched);
    }
}
