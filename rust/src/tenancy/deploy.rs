//! Wall-clock multi-tenant co-serving: one real thread fleet per tenant on
//! its disjoint core slice, behind a shared front door that paces the
//! merged per-tenant Poisson arrival streams and applies per-tenant
//! admission control — a bounded queue per tenant, shed-on-full counted
//! per tenant ([`crate::coordinator::queue::Sender::try_send`]).
//!
//! Topology (DESIGN.md §10):
//!
//! ```text
//! merged arrival schedule ──▶ front door ──try_send──▶ [tenant 0 queue] ─▶ fleet 0
//!  (per-tenant Poisson,        (single thread,         [tenant 1 queue] ─▶ fleet 1
//!   sorted by time)             shed on full)          ...
//! ```
//!
//! Each tenant fleet is an ordinary [`crate::coordinator::run_fleet`] over
//! synthetic sleep stages scaled by `time_scale` (exactly like the
//! single-tenant `Plan::deploy` synthetic backend); items carry their
//! admission `Instant` so the final stage records true arrival→completion
//! latency, including front-door queueing. Reported latencies and
//! throughputs are normalized back by `time_scale` so they compare
//! directly with the DES twin ([`crate::tenancy::simulate_multi`]) and
//! with the declared SLAs.

use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::queue::{bounded, TrySendError};
use crate::coordinator::{run_fleet, StageSpec};

use crate::api::LatencyReport;
use crate::obs::{LogHist, Recorder, WallClock};

use super::multiplan::MultiPlan;
use super::report::{
    core_seconds, MultiServeMode, MultiServeOptions, MultiServeReport, TenantReport,
};

/// Build one tenant's synthetic fleet: every stage sleeps for its Eq. 10
/// service time scaled by `scale`; the last stage of each replica records
/// the item's arrival→completion latency into `sink`. When `rec` is
/// enabled each stage also emits a service span stamped with the shared
/// [`WallClock`] (raw wall seconds — the trace header says
/// `"clock":"wall"`), and the last stage emits the departure span; when
/// disabled the closures take the exact original path (one branch, no
/// timestamp capture).
fn tenant_stages(
    replica_times: &[Vec<f64>],
    scale: f64,
    sink: &Arc<Mutex<Vec<f64>>>,
    rec: &Recorder,
    clock: &WallClock,
    group: u32,
) -> Vec<Vec<StageSpec<(usize, Instant)>>> {
    replica_times
        .iter()
        .enumerate()
        .map(|(r, times)| {
            let p = times.len();
            times
                .iter()
                .enumerate()
                .map(|(s, &t)| {
                    let dt = Duration::from_secs_f64(t * scale);
                    let last = s + 1 == p;
                    let sink = sink.clone();
                    let rec = rec.clone();
                    let clock = clock.clone();
                    StageSpec::new(
                        &format!("r{r}s{s}"),
                        Box::new(move || {
                            let rec = rec.clone();
                            let clock = clock.clone();
                            Box::new(move |x: (usize, Instant)| {
                                if rec.enabled() {
                                    let t0 = clock.now_s();
                                    thread::sleep(dt);
                                    let t1 = clock.now_s();
                                    rec.stage(group, x.0 as u64, r as u32, s as u32, t0, t1);
                                    if last {
                                        sink.lock()
                                            .unwrap()
                                            .push(x.1.elapsed().as_secs_f64());
                                        rec.depart(group, x.0 as u64, r as u32, t1);
                                    }
                                } else {
                                    thread::sleep(dt);
                                    if last {
                                        sink.lock()
                                            .unwrap()
                                            .push(x.1.elapsed().as_secs_f64());
                                    }
                                }
                                x
                            })
                        }),
                    )
                })
                .collect()
        })
        .collect()
}

/// Deploy a [`MultiPlan`] on real threads: per-tenant fleets plus the
/// shared admission front door. See the module docs for the topology and
/// the normalization convention.
pub fn deploy_multi(mp: &MultiPlan, opts: &MultiServeOptions) -> Result<MultiServeReport> {
    deploy_multi_recorded(mp, opts, &Recorder::off())
}

/// [`deploy_multi`] with span recording: tenant `i` traces under group
/// `i`, the front door emits admit/shed spans, stage threads emit service
/// and departure spans on the shared [`WallClock`], and the registry gets
/// the common metric vocabulary (DESIGN.md §13) with latencies normalized
/// back by `time_scale` so snapshots compare with the DES twin.
pub fn deploy_multi_recorded(
    mp: &MultiPlan,
    opts: &MultiServeOptions,
    rec: &Recorder,
) -> Result<MultiServeReport> {
    anyhow::ensure!(opts.images >= 1, "need at least one arrival per tenant");
    anyhow::ensure!(opts.queue_cap >= 1, "queue capacity must be >= 1");
    anyhow::ensure!(opts.admission_cap >= 1, "admission capacity must be >= 1");
    anyhow::ensure!(opts.time_scale > 0.0, "time_scale must be positive");
    let n_tenants = mp.tenants.len();

    // Merged arrival schedule: (scaled arrival time, tenant), time-sorted.
    let mut schedule: Vec<(f64, usize)> = Vec::with_capacity(n_tenants * opts.images);
    let mut offered = vec![0usize; n_tenants];
    for (i, t) in mp.tenants.iter().enumerate() {
        for a in super::cosim::tenant_arrivals(t.rate_hz, t.seed, i, opts) {
            schedule.push((a * opts.time_scale, i));
        }
        offered[i] = opts.images;
    }
    schedule.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));

    // Per-tenant plumbing: shed queue -> fleet thread.
    let clock = WallClock::start();
    let mut front_txs = Vec::with_capacity(n_tenants);
    let mut sinks = Vec::with_capacity(n_tenants);
    let mut handles = Vec::with_capacity(n_tenants);
    for (i, t) in mp.tenants.iter().enumerate() {
        let times: Vec<Vec<f64>> =
            t.plan.replicas.iter().map(|r| r.stage_times.clone()).collect();
        let sink = Arc::new(Mutex::new(Vec::new()));
        let stages = tenant_stages(&times, opts.time_scale, &sink, rec, &clock, i as u32);
        let (tx, rx) = bounded::<(usize, Instant)>(opts.admission_cap);
        let queue_cap = opts.queue_cap;
        let handle = thread::spawn(move || {
            run_fleet(stages, queue_cap, 1, std::iter::from_fn(move || rx.recv()))
        });
        front_txs.push(tx);
        sinks.push(sink);
        handles.push(handle);
    }

    // Shared front door: pace the merged schedule in real (scaled) time;
    // a full tenant queue sheds the arrival, a closed one (fleet died)
    // stops feeding that tenant.
    let mut shed = vec![0usize; n_tenants];
    let mut alive = vec![true; n_tenants];
    let board_start = Instant::now();
    for (seq, &(at, tenant)) in schedule.iter().enumerate() {
        let now = board_start.elapsed().as_secs_f64();
        if at > now {
            thread::sleep(Duration::from_secs_f64(at - now));
        }
        if !alive[tenant] {
            shed[tenant] += 1;
            continue;
        }
        // Front-door timestamp taken BEFORE the enqueue: once the item is
        // in the queue a stage thread may stamp its service span, and the
        // admission must sort before it in the item's chain.
        let at_s = if rec.enabled() { clock.now_s() } else { 0.0 };
        match front_txs[tenant].try_send((seq, Instant::now())) {
            Ok(()) => rec.admit(tenant as u32, seq as u64, at_s),
            Err(TrySendError::Full(_)) => {
                shed[tenant] += 1;
                rec.shed(tenant as u32, seq as u64, at_s);
            }
            Err(TrySendError::Closed(_)) => {
                alive[tenant] = false;
                shed[tenant] += 1;
                rec.shed(tenant as u32, seq as u64, at_s);
            }
        }
    }
    drop(front_txs); // closes every tenant queue; fleets drain and finish

    let mut tenants = Vec::with_capacity(n_tenants);
    let mut busy_core_s = 0.0;
    for (i, (t, handle)) in mp.tenants.iter().zip(handles).enumerate() {
        let (_, fleet) = handle.join().expect("tenant fleet panicked");
        anyhow::ensure!(
            fleet.images + shed[i] == offered[i],
            "tenant {:?}: {} served + {} shed != {} offered",
            t.name,
            fleet.images,
            shed[i],
            offered[i]
        );
        // Normalize scaled wall-clock numbers back to model time.
        let latencies: Vec<f64> = sinks[i]
            .lock()
            .unwrap()
            .iter()
            .map(|l| l / opts.time_scale)
            .collect();
        if rec.enabled() {
            rec.observe_hist("latency", &LogHist::of(&latencies));
        }
        let latency = LatencyReport::from_latencies(&latencies);
        let throughput = fleet.throughput() * opts.time_scale;
        let busy: Vec<Vec<f64>> = fleet
            .replicas
            .iter()
            .map(|r| {
                r.stages
                    .iter()
                    .map(|s| s.busy.as_secs_f64() / opts.time_scale)
                    .collect()
            })
            .collect();
        busy_core_s += core_seconds(&t.plan, &busy)
            .with_context(|| format!("tenant {:?}", t.name))?;
        let wall = fleet.wall.as_secs_f64() / opts.time_scale;
        let utilization = if wall > 0.0 {
            busy.iter()
                .flat_map(|stages| stages.iter())
                .fold(0.0f64, |m, b| m.max(b / wall))
        } else {
            0.0
        };
        if rec.enabled() {
            for (r, stages) in busy.iter().enumerate() {
                for (st, b) in stages.iter().enumerate() {
                    let occ = if wall > 0.0 { b / wall } else { 0.0 };
                    rec.gauge_set(&format!("occupancy/g{i}r{r}s{st}"), occ);
                }
            }
        }
        tenants.push(TenantReport {
            name: t.name.clone(),
            network: t.plan.network.clone(),
            budget: format!("{}B+{}s", t.plan.big, t.plan.small),
            pipeline: t.partition_display(),
            rate_hz: t.rate_hz,
            weight: t.weight,
            offered: offered[i],
            admitted: fleet.images,
            shed: shed[i],
            throughput,
            capacity: t.plan.throughput,
            latency,
            p99_sla_s: t.p99_sla_s,
            sla_ok: t
                .p99_sla_s
                .map(|sla| latency.map_or(false, |l| l.p99 <= sla)),
            utilization,
        });
    }
    let wall_s = board_start.elapsed().as_secs_f64() / opts.time_scale;
    let total_cores = (mp.big + mp.small) as f64;
    let board_utilization =
        if wall_s > 0.0 { busy_core_s / (total_cores * wall_s) } else { 0.0 };
    let weighted_throughput: f64 =
        tenants.iter().map(|t| t.weight * t.throughput).sum();
    rec.gauge_set("wall_s", wall_s);

    Ok(MultiServeReport {
        mode: MultiServeMode::Synthetic { time_scale: opts.time_scale },
        wall_s,
        images: tenants.iter().map(|t| t.admitted).sum(),
        shed: tenants.iter().map(|t| t.shed).sum(),
        weighted_throughput,
        board_utilization,
        tenants,
        metrics: rec.snapshot(),
        // In-band attribution is a DES-twin feature: wall-clock spans carry
        // scaled sleep times, so residuals against Eq. 10 would be
        // off-scale. `pipeit attrib --trace` decomposes wall traces offline.
        attrib: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::tenancy::TenantSpec;

    fn small_multiplan(rate_a: f64, rate_b: f64) -> MultiPlan {
        let specs = [
            TenantSpec::new("alexnet", rate_a),
            TenantSpec::new("squeezenet", rate_b),
        ];
        MultiPlan::compile(&specs, &Config::default(), 2).unwrap()
    }

    #[test]
    fn deploy_conserves_arrivals_and_reports_both_tenants() {
        let mp = small_multiplan(4.0, 8.0);
        let opts = MultiServeOptions {
            images: 12,
            time_scale: 0.02,
            ..MultiServeOptions::default()
        };
        let report = mp.deploy(&opts).unwrap();
        assert_eq!(report.tenants.len(), 2);
        for t in &report.tenants {
            assert_eq!(t.offered, 12);
            assert_eq!(t.admitted + t.shed, t.offered);
        }
        assert_eq!(
            report.images + report.shed,
            24,
            "front door must account for every arrival"
        );
        assert!(report.wall_s > 0.0);
    }

    #[test]
    fn underloaded_deploy_sheds_nothing() {
        // Offered rates far below any slice capacity: nothing sheds and
        // every admitted item completes.
        let mp = small_multiplan(1.0, 2.0);
        let opts = MultiServeOptions {
            images: 6,
            admission_cap: 16,
            time_scale: 0.02,
            ..MultiServeOptions::default()
        };
        let report = mp.deploy(&opts).unwrap();
        assert_eq!(report.shed, 0, "{report:?}");
        assert_eq!(report.images, 12);
        for t in &report.tenants {
            assert!(t.latency.is_some());
        }
    }
}
