//! The multi-tenant serving artifact: a schema-versioned, serializable
//! [`MultiPlan`] embedding one ordinary [`Plan`] per tenant (via
//! [`Plan::to_json`] / [`Plan::from_json`]) plus each tenant's service
//! contract. Like the single-tenant [`Plan`], a saved artifact reloads and
//! behaves identically — no search re-runs at deploy time, and the DES /
//! wall-clock twins ([`MultiPlan::simulate`] / [`MultiPlan::deploy`]) read
//! only what the artifact carries.

use std::path::Path;

use anyhow::{Context, Result};

use crate::api::{Plan, Strategy};
use crate::config::Config;
use crate::util::json::Json;

use super::deploy::deploy_multi;
use super::joint::explore_joint;
use super::report::{MultiServeOptions, MultiServeReport};
use super::spec::TenantSpec;

/// MultiPlan schema version written by [`MultiPlan::save`] and required by
/// [`MultiPlan::load`].
pub const MULTI_PLAN_VERSION: usize = 1;

/// One tenant's slot in a [`MultiPlan`]: the embedded per-tenant [`Plan`]
/// (whose `big`/`small` are the tenant's disjoint core slice) plus the
/// service contract the joint DSE scored it against.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantPlan {
    pub name: String,
    /// Offered Poisson arrival rate (images/s).
    pub rate_hz: f64,
    /// Declared p99 end-to-end latency SLA in seconds, if any.
    pub p99_sla_s: Option<f64>,
    /// Weight in the joint objective.
    pub weight: f64,
    /// Pinned arrival-stream seed; `None` derives from the run seed.
    pub seed: Option<u64>,
    /// Predicted served rate `min(λ, μ)` at plan time (imgs/s).
    pub predicted_served: f64,
    /// Analytic p99 prediction at plan time; `None` when the slice cannot
    /// absorb the offered rate (infinite tail).
    pub predicted_p99: Option<f64>,
    /// The tenant's compiled design on its core slice.
    pub plan: Plan,
}

impl TenantPlan {
    /// `B2-s1 | s3` style display of the tenant's fleet.
    pub fn partition_display(&self) -> String {
        self.plan.partition_display()
    }
}

/// A compiled, serializable multi-tenant co-serving plan: disjoint core
/// slices, one replicated design per tenant, and the joint objective value
/// — ready to [`simulate`](MultiPlan::simulate) (DES co-simulation) or
/// [`deploy`](MultiPlan::deploy) (wall-clock fleets behind a shared
/// admission front door).
///
/// # Example
///
/// ```
/// use pipeit::config::Config;
/// use pipeit::tenancy::{MultiPlan, TenantSpec};
///
/// let specs = [TenantSpec::new("alexnet", 5.0), TenantSpec::new("squeezenet", 10.0)];
/// let mp = MultiPlan::compile(&specs, &Config::default(), 4).unwrap();
/// assert_eq!(mp.tenants.len(), 2);
/// let path = std::env::temp_dir().join("pipeit_doc_multiplan.json");
/// mp.save(&path).unwrap();
/// let loaded = MultiPlan::load(&path).unwrap();
/// assert_eq!(mp, loaded); // the artifact round-trips losslessly
/// std::fs::remove_file(&path).ok();
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct MultiPlan {
    /// Platform name the plan was compiled for.
    pub platform: String,
    /// Board-wide big-cluster core budget.
    pub big: usize,
    /// Board-wide small-cluster core budget.
    pub small: usize,
    /// The joint objective value: `Σ_t w_t · min(λ_t, μ_t)` (imgs/s).
    pub weighted_throughput: f64,
    pub tenants: Vec<TenantPlan>,
}

impl MultiPlan {
    /// Run the joint DSE ([`explore_joint`]) over `specs` and materialize
    /// the winning split as a serializable artifact. `max_replicas` caps
    /// the per-tenant replica count inside each slice.
    pub fn compile(specs: &[TenantSpec], cfg: &Config, max_replicas: usize) -> Result<MultiPlan> {
        let joint = explore_joint(specs, cfg, max_replicas)?;
        let mut tenants = Vec::with_capacity(specs.len());
        for (spec, td) in specs.iter().zip(&joint.tenants) {
            let tm = spec.time_matrix(cfg)?;
            let plan = Plan::from_design(
                &spec.network,
                &cfg.platform.name,
                td.budget.big,
                td.budget.small,
                spec.time_source,
                Strategy::Replicated { max_replicas, exact: false },
                &tm,
                &td.design,
            );
            tenants.push(TenantPlan {
                name: spec.name.clone(),
                rate_hz: spec.rate_hz,
                p99_sla_s: spec.p99_sla_s,
                weight: spec.weight,
                seed: spec.seed,
                predicted_served: td.served,
                predicted_p99: td.predicted_p99.is_finite().then_some(td.predicted_p99),
                plan,
            });
        }
        Ok(MultiPlan {
            platform: cfg.platform.name.clone(),
            big: cfg.platform.big.cores,
            small: cfg.platform.small.cores,
            weighted_throughput: joint.weighted_throughput,
            tenants,
        })
    }

    pub fn num_tenants(&self) -> usize {
        self.tenants.len()
    }

    /// Structural invariants shared by [`MultiPlan::compile`] results and
    /// loaded artifacts: tenant budgets partition the board, names are
    /// unique, contracts are sane, and every tenant plan is a simulable
    /// big.LITTLE plan (stage-time profiles present, no artifact binding).
    fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.tenants.is_empty(), "multi-plan has no tenants");
        let (mut big, mut small) = (0usize, 0usize);
        for (i, t) in self.tenants.iter().enumerate() {
            anyhow::ensure!(
                t.rate_hz.is_finite() && t.rate_hz > 0.0,
                "tenant {i} ({}): rate must be positive",
                t.name
            );
            anyhow::ensure!(
                t.weight.is_finite() && t.weight >= 0.0,
                "tenant {i} ({}): weight must be >= 0",
                t.name
            );
            if let Some(sla) = t.p99_sla_s {
                anyhow::ensure!(
                    sla.is_finite() && sla > 0.0,
                    "tenant {i} ({}): p99 SLA must be positive",
                    t.name
                );
            }
            if let Some(seed) = t.seed {
                anyhow::ensure!(
                    seed < (1u64 << 53),
                    "tenant {i} ({}): seed {seed} exceeds 2^53 and cannot \
                     round-trip through the JSON artifact losslessly",
                    t.name
                );
            }
            anyhow::ensure!(
                t.plan.artifacts.is_none(),
                "tenant {i} ({}): artifact-bound plans cannot be co-served",
                t.name
            );
            for (r, rep) in t.plan.replicas.iter().enumerate() {
                anyhow::ensure!(
                    !rep.stage_times.is_empty(),
                    "tenant {i} ({}): replica {r} carries no stage-time profile",
                    t.name
                );
            }
            anyhow::ensure!(
                self.tenants.iter().skip(i + 1).all(|o| o.name != t.name),
                "duplicate tenant name {:?}",
                t.name
            );
            big += t.plan.big;
            small += t.plan.small;
        }
        anyhow::ensure!(
            big == self.big && small == self.small,
            "tenant budgets ({big}B+{small}s) must partition the board \
             ({}B+{}s)",
            self.big,
            self.small
        );
        Ok(())
    }

    // ---- serialization ---------------------------------------------------

    pub fn to_json(&self) -> Json {
        let tenants = Json::Arr(
            self.tenants
                .iter()
                .map(|t| {
                    Json::obj(vec![
                        ("name", Json::str(&t.name)),
                        ("rate_hz", Json::num(t.rate_hz)),
                        (
                            "p99_sla_s",
                            t.p99_sla_s.map_or(Json::Null, Json::num),
                        ),
                        ("weight", Json::num(t.weight)),
                        ("seed", t.seed.map_or(Json::Null, |s| Json::num(s as f64))),
                        ("predicted_served", Json::num(t.predicted_served)),
                        (
                            "predicted_p99",
                            t.predicted_p99.map_or(Json::Null, Json::num),
                        ),
                        ("plan", t.plan.to_json()),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("version", Json::num(MULTI_PLAN_VERSION as f64)),
            (
                "platform",
                Json::obj(vec![
                    ("name", Json::str(&self.platform)),
                    ("big", Json::num(self.big as f64)),
                    ("small", Json::num(self.small as f64)),
                ]),
            ),
            ("weighted_throughput", Json::num(self.weighted_throughput)),
            ("tenants", tenants),
        ])
    }

    pub fn from_json(j: &Json) -> Result<MultiPlan> {
        let version = j.req("version")?.as_usize().context("version")?;
        anyhow::ensure!(
            version == MULTI_PLAN_VERSION,
            "multi-plan schema version {version} is not supported (field \
             \"version\"; this build reads version {MULTI_PLAN_VERSION})"
        );
        let platform = j.req("platform")?;
        let mut tenants = Vec::new();
        for (i, tj) in j.req("tenants")?.as_arr().context("tenants array")?.iter().enumerate() {
            let opt_num = |key: &str| -> Result<Option<f64>> {
                match tj.req(key)? {
                    Json::Null => Ok(None),
                    v => Ok(Some(v.as_f64().with_context(|| format!("tenant {i} {key}"))?)),
                }
            };
            let seed = match tj.req("seed")? {
                Json::Null => None,
                v => Some(v.as_usize().with_context(|| format!("tenant {i} seed"))? as u64),
            };
            tenants.push(TenantPlan {
                name: tj
                    .req("name")?
                    .as_str()
                    .with_context(|| format!("tenant {i} name"))?
                    .to_string(),
                rate_hz: tj
                    .req("rate_hz")?
                    .as_f64()
                    .with_context(|| format!("tenant {i} rate_hz"))?,
                p99_sla_s: opt_num("p99_sla_s")?,
                weight: tj
                    .req("weight")?
                    .as_f64()
                    .with_context(|| format!("tenant {i} weight"))?,
                seed,
                predicted_served: tj
                    .req("predicted_served")?
                    .as_f64()
                    .with_context(|| format!("tenant {i} predicted_served"))?,
                predicted_p99: opt_num("predicted_p99")?,
                plan: Plan::from_json(tj.req("plan")?)
                    .with_context(|| format!("tenant {i} embedded plan"))?,
            });
        }
        let mp = MultiPlan {
            platform: platform.req("name")?.as_str().context("platform name")?.to_string(),
            big: platform.req("big")?.as_usize().context("platform big")?,
            small: platform.req("small")?.as_usize().context("platform small")?,
            weighted_throughput: j
                .req("weighted_throughput")?
                .as_f64()
                .context("weighted_throughput")?,
            tenants,
        };
        mp.validate()?;
        Ok(mp)
    }

    /// Write the multi-plan as a JSON artifact.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Load a multi-plan saved by [`MultiPlan::save`].
    pub fn load(path: &Path) -> Result<MultiPlan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        MultiPlan::from_json(&j)
            .with_context(|| format!("parsing multi-plan {}", path.display()))
    }

    // ---- display ---------------------------------------------------------

    /// Human-readable plan description (the `pipeit plan-multi` output).
    pub fn summary(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "co-serving : {} tenants on {} ({}B+{}s)\n",
            self.tenants.len(),
            self.platform,
            self.big,
            self.small
        ));
        for t in &self.tenants {
            let sla = match t.p99_sla_s {
                Some(sla) => format!("  p99<={:.0}ms", sla * 1e3),
                None => String::new(),
            };
            let p99 = match t.predicted_p99 {
                Some(p) => format!("  pred p99 {:.1}ms", p * 1e3),
                None => "  pred p99 unbounded".to_string(),
            };
            s.push_str(&format!(
                "tenant {:<12} {}B+{}s  {}  rate={:.1}/s  w={:.1}  served {:.2}/s \
                 (cap {:.2}){sla}{p99}\n",
                t.name,
                t.plan.big,
                t.plan.small,
                t.partition_display(),
                t.rate_hz,
                t.weight,
                t.predicted_served,
                t.plan.throughput,
            ));
        }
        s.push_str(&format!(
            "objective  : {:.2} weighted imgs/s (Eq. 12, SLA-aware joint DSE)\n",
            self.weighted_throughput
        ));
        s
    }

    // ---- execution backends ---------------------------------------------

    /// DES co-simulation of the whole board: merged per-tenant Poisson
    /// streams, per-tenant bounded admission with shed-on-full, each
    /// tenant's replicated fleet on its disjoint slice — the design-time
    /// twin of [`MultiPlan::deploy`].
    pub fn simulate(&self, opts: &MultiServeOptions) -> Result<MultiServeReport> {
        super::cosim::simulate_multi(self, opts)
    }

    /// [`MultiPlan::simulate`] with observability: per-item span chains
    /// and the metrics registry land in `rec` (DESIGN.md §13).
    pub fn simulate_recorded(
        &self,
        opts: &MultiServeOptions,
        rec: &crate::obs::Recorder,
    ) -> Result<MultiServeReport> {
        super::cosim::simulate_multi_recorded(self, opts, rec)
    }

    /// Wall-clock co-serving: one real thread fleet per tenant plus a
    /// shared front door pacing the merged arrival streams with per-tenant
    /// shed-on-full admission.
    pub fn deploy(&self, opts: &MultiServeOptions) -> Result<MultiServeReport> {
        deploy_multi(self, opts)
    }

    /// [`MultiPlan::deploy`] with observability (wall-clock spans).
    pub fn deploy_recorded(
        &self,
        opts: &MultiServeOptions,
        rec: &crate::obs::Recorder,
    ) -> Result<MultiServeReport> {
        super::deploy::deploy_multi_recorded(self, opts, rec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_tenants() -> Vec<TenantSpec> {
        vec![
            TenantSpec::new("alexnet", 8.0),
            TenantSpec::new("squeezenet", 16.0).with_sla(0.5),
        ]
    }

    fn roundtrip(mp: &MultiPlan) -> MultiPlan {
        let text = mp.to_json().to_string();
        let j = Json::parse(&text).expect("multi-plan JSON reparses");
        MultiPlan::from_json(&j).expect("multi-plan JSON deserializes")
    }

    #[test]
    fn compiled_multiplan_roundtrips_through_json() {
        let mp = MultiPlan::compile(&two_tenants(), &Config::default(), 4).unwrap();
        assert_eq!(mp, roundtrip(&mp));
    }

    #[test]
    fn compile_assigns_every_core_once() {
        let mp = MultiPlan::compile(&two_tenants(), &Config::default(), 4).unwrap();
        let big: usize = mp.tenants.iter().map(|t| t.plan.big).sum();
        let small: usize = mp.tenants.iter().map(|t| t.plan.small).sum();
        assert_eq!((big, small), (mp.big, mp.small));
        assert!(mp.weighted_throughput > 0.0);
    }

    #[test]
    fn from_json_rejects_schema_and_structure_violations() {
        let mp = MultiPlan::compile(&two_tenants(), &Config::default(), 4).unwrap();
        let good = mp.to_json();

        // Wrong version names the field.
        let mut j = good.clone();
        if let Json::Obj(m) = &mut j {
            m.insert("version".to_string(), Json::num(99.0));
        }
        let err = MultiPlan::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("\"version\"") && err.contains("99"), "{err}");

        // A tenant budget that no longer partitions the board.
        let mut j = good.clone();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Arr(ts)) = m.get_mut("tenants") {
                if let Json::Obj(t0) = &mut ts[0] {
                    if let Some(Json::Obj(p)) = t0.get_mut("plan") {
                        if let Some(Json::Obj(pf)) = p.get_mut("platform") {
                            pf.insert("big".to_string(), Json::num(9.0));
                        }
                    }
                }
            }
        }
        let err = format!("{:?}", MultiPlan::from_json(&j).unwrap_err());
        assert!(err.contains("partition the board"), "{err}");

        // Duplicate tenant names are rejected.
        let mut j = good;
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Arr(ts)) = m.get_mut("tenants") {
                let name = ts[0].req("name").unwrap().as_str().unwrap().to_string();
                if let Json::Obj(t1) = &mut ts[1] {
                    t1.insert("name".to_string(), Json::str(&name));
                }
            }
        }
        let err = format!("{:?}", MultiPlan::from_json(&j).unwrap_err());
        assert!(err.contains("duplicate tenant name"), "{err}");
    }
}
