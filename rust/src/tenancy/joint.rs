//! Joint cross-network design-space exploration: split one big.LITTLE core
//! budget across several tenants, each of which then gets its own
//! replicated-pipeline search inside its slice.
//!
//! The single-network searches ([`crate::dse`]) answer "what is the best
//! design for THIS network on THIS budget"; co-serving adds the outer
//! question "how many cores does each network deserve". Static equal
//! splits leave throughput on the table whenever the tenants' load or
//! compute-efficiency is asymmetric (the PICO / dynamic-distribution
//! observation, arXiv 2206.08662 / 2107.05828). Because every candidate is
//! scored by the same Eq. 10/12 TimeMatrix predictions, the outer search
//! is fully analytic: enumerate every ordered split of `(hb, hs)` into one
//! non-empty slice per tenant ([`splits`]), reuse the replicated search
//! ([`crate::dse::explore_replicated`], i.e.
//! [`partitions`](crate::dse::replicated::partitions) ×
//! [`explore_budget`](crate::dse::explore_budget)) inside each slice, and
//! rank splits by the joint objective.
//!
//! **Objective** (DESIGN.md §10): lexicographic — (1) most declared p99
//! SLAs predicted feasible, (2) highest weighted served rate
//! `Σ_t w_t · min(λ_t, μ_t)` where `μ_t` is the slice's Eq. 12 aggregate
//! capacity, (3) highest capacity sum as the tie-break. SLA feasibility is
//! predicted with an M/D/1-style tail bound ([`predict_p99`]); the DES
//! co-simulation ([`crate::tenancy::simulate_multi`]) is the ground truth
//! the prediction is tested against.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::config::Config;
use crate::dse::{self, CoreBudget, ReplicatedDesign};
use crate::perfmodel::TimeMatrix;

use super::spec::TenantSpec;

/// Guard against planning a tenant at (or beyond) its slice's capacity:
/// above this utilization the queueing tail blows up and no finite p99 is
/// predicted.
pub const MAX_UTILIZATION: f64 = 0.95;

/// Hard ceiling on the number of ordered core splits the joint search will
/// enumerate. The split count grows combinatorially in cores × tenants
/// (ordered compositions of two core pools), so past this bound the outer
/// search would silently hang or exhaust memory materializing [`splits`];
/// [`splits_checked`] (and hence [`explore_joint`]) refuses with a named
/// error instead. 200k splits × a memoized inner search is comfortably a
/// sub-second design pass on the boards this targets.
pub const MAX_JOINT_SPLITS: u64 = 200_000;

/// The joint design space is too large to enumerate (see
/// [`MAX_JOINT_SPLITS`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SplitBudgetExceeded {
    /// Ordered splits the requested search would enumerate (saturating).
    pub splits: u64,
    /// The enforced ceiling ([`MAX_JOINT_SPLITS`]).
    pub limit: u64,
    pub big: usize,
    pub small: usize,
    pub tenants: usize,
}

impl std::fmt::Display for SplitBudgetExceeded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "joint design space of {}B+{}s across {} tenants has {} ordered \
             core splits, over the {}-split enumeration budget; reduce the \
             tenant count or search a smaller core budget",
            self.big, self.small, self.tenants, self.splits, self.limit
        )
    }
}

impl std::error::Error for SplitBudgetExceeded {}

/// Number of ordered splits [`splits`] would return, without materializing
/// them: a saturating counting DP over (slices, big, small) — every slice
/// non-empty, every core assigned — so the budget check in
/// [`splits_checked`] is O(tenants · hb² · hs²) arithmetic even when the
/// space itself is astronomically large.
pub fn count_splits(hb: usize, hs: usize, tenants: usize) -> u64 {
    if tenants == 0 || hb + hs < tenants {
        return 0;
    }
    // ways[b][s]: splits of exactly (b, s) cores into the slices so far.
    let mut ways = vec![vec![0u64; hs + 1]; hb + 1];
    ways[0][0] = 1;
    for _ in 0..tenants {
        let mut next = vec![vec![0u64; hs + 1]; hb + 1];
        for b in 0..=hb {
            for s in 0..=hs {
                if ways[b][s] == 0 {
                    continue;
                }
                for db in 0..=(hb - b) {
                    for ds in 0..=(hs - s) {
                        if db + ds == 0 {
                            continue;
                        }
                        next[b + db][s + ds] =
                            next[b + db][s + ds].saturating_add(ways[b][s]);
                    }
                }
            }
        }
        ways = next;
    }
    ways[hb][hs]
}

/// [`splits`] behind the enumeration budget: returns
/// [`SplitBudgetExceeded`] instead of hanging or exhausting memory when
/// the ordered-split count passes [`MAX_JOINT_SPLITS`].
pub fn splits_checked(
    hb: usize,
    hs: usize,
    tenants: usize,
) -> Result<Vec<Vec<CoreBudget>>, SplitBudgetExceeded> {
    let n = count_splits(hb, hs, tenants);
    if n > MAX_JOINT_SPLITS {
        return Err(SplitBudgetExceeded {
            splits: n,
            limit: MAX_JOINT_SPLITS,
            big: hb,
            small: hs,
            tenants,
        });
    }
    Ok(splits(hb, hs, tenants))
}

/// All ordered assignments of the full `(hb, hs)` budget to `tenants`
/// slices, every slice getting at least one core and every core being
/// assigned (more cores never hurt under the monotone Eq. 12 model).
/// Ordered, not canonical: tenants are distinct, so `(3B, 1B+4s)` and
/// `(1B+4s, 3B)` are different designs.
pub fn splits(hb: usize, hs: usize, tenants: usize) -> Vec<Vec<CoreBudget>> {
    fn rec(
        hb: usize,
        hs: usize,
        left: usize,
        cur: &mut Vec<CoreBudget>,
        out: &mut Vec<Vec<CoreBudget>>,
    ) {
        if left == 1 {
            if hb + hs >= 1 {
                cur.push(CoreBudget::new(hb, hs));
                out.push(cur.clone());
                cur.pop();
            }
            return;
        }
        for b in 0..=hb {
            for s in 0..=hs {
                if b + s == 0 {
                    continue;
                }
                if (hb - b) + (hs - s) < left - 1 {
                    continue; // not enough cores left for the remaining tenants
                }
                cur.push(CoreBudget::new(b, s));
                rec(hb - b, hs - s, left - 1, cur, out);
                cur.pop();
            }
        }
    }

    let mut out = Vec::new();
    if tenants >= 1 && hb + hs >= tenants {
        let mut cur = Vec::new();
        rec(hb, hs, tenants, &mut cur, &mut out);
    }
    out
}

/// Analytic p99 end-to-end latency of a replicated fleet under Poisson
/// arrivals at `rate_hz` — the feasibility predicate of the joint search.
///
/// Per replica: pipeline service latency (the sum of its Eq. 10 stage
/// times) plus an M/D/1-style queueing tail. Least-outstanding-work
/// dispatch routes arrivals rate-proportionally, so every replica sees the
/// same utilization `ρ = λ/μ`; the mean M/D/1 wait is `ρ·c/(2(1−ρ))` for
/// cycle time `c`, and the exponential-tail p99 multiplies it by `ln 100`.
/// Returns `f64::INFINITY` when `ρ ≥` [`MAX_UTILIZATION`] (no finite
/// prediction near saturation).
pub fn predict_p99(stage_times: &[Vec<f64>], capacity_hz: f64, rate_hz: f64) -> f64 {
    if capacity_hz <= 0.0 {
        return f64::INFINITY;
    }
    let rho = rate_hz / capacity_hz;
    if rho >= MAX_UTILIZATION {
        return f64::INFINITY;
    }
    let mut worst: f64 = 0.0;
    for times in stage_times {
        let service: f64 = times.iter().sum();
        let cycle = times.iter().copied().fold(0.0, f64::max);
        let wait_p99 = 100f64.ln() * rho * cycle / (2.0 * (1.0 - rho));
        worst = worst.max(service + wait_p99);
    }
    worst
}

/// One tenant's slice of a joint design.
#[derive(Debug, Clone)]
pub struct TenantDesign {
    /// Cores this tenant owns (disjoint from every other tenant's).
    pub budget: CoreBudget,
    /// The replicated design chosen inside the slice.
    pub design: ReplicatedDesign,
    /// Slice capacity: the design's Eq. 12 aggregate rate (imgs/s).
    pub capacity: f64,
    /// Predicted served rate `min(λ, μ)` (imgs/s).
    pub served: f64,
    /// Analytic p99 latency prediction ([`predict_p99`]); infinite when
    /// the slice cannot absorb the offered rate.
    pub predicted_p99: f64,
    /// `Some(feasible)` when the tenant declared an SLA, else `None`.
    pub sla_ok: Option<bool>,
}

/// The chosen joint design: one [`TenantDesign`] per tenant, in spec order.
#[derive(Debug, Clone)]
pub struct JointDesign {
    pub tenants: Vec<TenantDesign>,
    /// The objective value: `Σ_t w_t · min(λ_t, μ_t)`.
    pub weighted_throughput: f64,
    /// Declared SLAs predicted feasible / declared in total.
    pub sla_met: usize,
    pub sla_declared: usize,
}

fn tenant_design(
    spec: &TenantSpec,
    tm: &TimeMatrix,
    budget: CoreBudget,
    max_replicas: usize,
    memo: &mut HashMap<(usize, CoreBudget), ReplicatedDesign>,
    class: usize,
) -> TenantDesign {
    let design = memo
        .entry((class, budget))
        .or_insert_with(|| {
            let r = max_replicas.min(budget.cores()).max(1);
            dse::explore_replicated(tm, budget.big, budget.small, r)
        })
        .clone();
    let capacity = design.throughput;
    let served = spec.rate_hz.min(capacity);
    let predicted_p99 = predict_p99(&design.stage_times(tm), capacity, spec.rate_hz);
    let sla_ok = spec.p99_sla_s.map(|sla| predicted_p99 <= sla);
    TenantDesign { budget, design, capacity, served, predicted_p99, sla_ok }
}

/// Search every core split of the platform across `specs` and return the
/// joint design maximizing the lexicographic objective (SLAs met, weighted
/// served rate, capacity). `max_replicas` caps the per-tenant replica
/// count inside each slice.
///
/// # Example
///
/// ```
/// use pipeit::config::Config;
/// use pipeit::tenancy::{explore_joint, TenantSpec};
///
/// let specs = [TenantSpec::new("alexnet", 10.0), TenantSpec::new("squeezenet", 20.0)];
/// let joint = explore_joint(&specs, &Config::default(), 4).unwrap();
/// assert_eq!(joint.tenants.len(), 2);
/// let cores: usize = joint.tenants.iter().map(|t| t.budget.cores()).sum();
/// assert_eq!(cores, 8); // every core assigned
/// ```
pub fn explore_joint(
    specs: &[TenantSpec],
    cfg: &Config,
    max_replicas: usize,
) -> Result<JointDesign> {
    anyhow::ensure!(!specs.is_empty(), "need at least one tenant");
    anyhow::ensure!(max_replicas >= 1, "need at least one replica per tenant");
    let (hb, hs) = (cfg.platform.big.cores, cfg.platform.small.cores);
    anyhow::ensure!(
        specs.len() <= hb + hs,
        "{} tenants cannot each own a core on {}B+{}s",
        specs.len(),
        hb,
        hs
    );
    // Budget-check the outer enumeration before any expensive work: the
    // split count is combinatorial in cores × tenants and past the budget
    // the search would hang rather than finish (satellite guard, DESIGN.md
    // §10).
    let all_splits = splits_checked(hb, hs, specs.len())?;
    let tms: Vec<TimeMatrix> =
        specs.iter().map(|s| s.time_matrix(cfg)).collect::<Result<_>>()?;
    let sla_declared = specs.iter().filter(|s| s.p99_sla_s.is_some()).count();

    // Tenants serving the same network under the same time source share a
    // design class, so duplicate tenants hit the memo instead of re-running
    // the per-budget replicated search.
    let class: Vec<usize> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| {
            specs[..i]
                .iter()
                .position(|o| o.network == s.network && o.time_source == s.time_source)
                .unwrap_or(i)
        })
        .collect();

    let mut memo: HashMap<(usize, CoreBudget), ReplicatedDesign> = HashMap::new();
    let mut best: Option<JointDesign> = None;
    for split in all_splits {
        let tenants: Vec<TenantDesign> = specs
            .iter()
            .zip(&split)
            .enumerate()
            .map(|(i, (spec, &budget))| {
                tenant_design(spec, &tms[i], budget, max_replicas, &mut memo, class[i])
            })
            .collect();
        let sla_met =
            tenants.iter().filter(|t| t.sla_ok == Some(true)).count();
        let weighted: f64 = specs
            .iter()
            .zip(&tenants)
            .map(|(s, t)| s.weight * t.served)
            .sum();
        let capacity: f64 = tenants.iter().map(|t| t.capacity).sum();
        let candidate =
            JointDesign { tenants, weighted_throughput: weighted, sla_met, sla_declared };
        let better = match &best {
            None => true,
            Some(b) => {
                let b_capacity: f64 = b.tenants.iter().map(|t| t.capacity).sum();
                candidate.sla_met > b.sla_met
                    || (candidate.sla_met == b.sla_met
                        && candidate.weighted_throughput > b.weighted_throughput + 1e-12)
                    || (candidate.sla_met == b.sla_met
                        && (candidate.weighted_throughput - b.weighted_throughput).abs()
                            <= 1e-12
                        && capacity > b_capacity + 1e-12)
            }
        };
        if better {
            best = Some(candidate);
        }
    }
    best.context("empty joint design space (fewer cores than tenants?)")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::zoo;
    use crate::simulator::platform::Platform;

    #[test]
    fn splits_cover_the_budget_and_respect_tenancy() {
        for (hb, hs, t) in [(4, 4, 2), (2, 6, 3), (1, 1, 2), (4, 4, 1)] {
            let all = splits(hb, hs, t);
            assert!(!all.is_empty(), "({hb},{hs},{t})");
            for split in &all {
                assert_eq!(split.len(), t);
                assert_eq!(split.iter().map(|b| b.big).sum::<usize>(), hb);
                assert_eq!(split.iter().map(|b| b.small).sum::<usize>(), hs);
                assert!(split.iter().all(|b| b.cores() >= 1));
            }
        }
        // Ordered: (1,0),(0,1) and (0,1),(1,0) are both present.
        let two = splits(1, 1, 2);
        assert_eq!(two.len(), 2);
        // More tenants than cores: no split.
        assert!(splits(1, 1, 3).is_empty());
    }

    #[test]
    fn count_splits_agrees_with_the_enumeration() {
        for hb in 0..=4usize {
            for hs in 0..=4usize {
                for t in 1..=4usize {
                    assert_eq!(
                        count_splits(hb, hs, t),
                        splits(hb, hs, t).len() as u64,
                        "({hb},{hs},{t})"
                    );
                }
            }
        }
        assert_eq!(count_splits(1, 1, 2), 2);
        assert_eq!(count_splits(1, 1, 3), 0);
        assert_eq!(count_splits(4, 4, 8), 70, "one core each: C(8,4)");
    }

    #[test]
    fn oversized_design_spaces_fail_with_a_named_error_not_a_hang() {
        // 8B+8s across 8 tenants is ~41M ordered splits: counting it is
        // instant, enumerating it would hang the planner. The guard must
        // refuse by name.
        let err = splits_checked(8, 8, 8).unwrap_err();
        assert!(err.splits > MAX_JOINT_SPLITS, "{err}");
        assert_eq!(err.limit, MAX_JOINT_SPLITS);
        assert_eq!((err.big, err.small, err.tenants), (8, 8, 8));
        assert!(err.to_string().contains("enumeration budget"), "{err}");
        // In-budget spaces pass through unchanged.
        let ok = splits_checked(4, 4, 2).unwrap();
        assert_eq!(ok, splits(4, 4, 2));
    }

    #[test]
    fn explore_joint_surfaces_the_split_budget_error() {
        // Blow up the platform so the 6-tenant outer enumeration passes the
        // budget; the search must fail fast with the named guard error.
        let mut cfg = Config::default();
        cfg.platform.big.cores = 24;
        cfg.platform.small.cores = 24;
        let specs: Vec<TenantSpec> =
            (0..6).map(|_| TenantSpec::new("alexnet", 1.0)).collect();
        let err = explore_joint(&specs, &cfg, 2).unwrap_err();
        assert!(
            err.downcast_ref::<SplitBudgetExceeded>().is_some(),
            "expected SplitBudgetExceeded, got: {err:#}"
        );
    }

    #[test]
    fn single_tenant_split_is_the_whole_board() {
        let all = splits(4, 4, 1);
        assert_eq!(all, vec![vec![CoreBudget::new(4, 4)]]);
    }

    #[test]
    fn predict_p99_grows_with_load_and_diverges_at_saturation() {
        let stages = vec![vec![0.01, 0.02]]; // capacity 50/s
        let light = predict_p99(&stages, 50.0, 5.0);
        let heavy = predict_p99(&stages, 50.0, 40.0);
        assert!(light >= 0.03, "at least the service latency: {light}");
        assert!(heavy > light, "more load, more tail: {light} vs {heavy}");
        assert!(predict_p99(&stages, 50.0, 49.0).is_infinite());
        assert!(predict_p99(&stages, 50.0, 500.0).is_infinite());
    }

    #[test]
    fn single_tenant_joint_matches_the_replicated_search() {
        let cfg = Config::default();
        let spec = TenantSpec::new("alexnet", 1e9); // saturating
        let joint = explore_joint(&[spec], &cfg, 4).unwrap();
        let tm = TimeMatrix::measured(&Platform::hikey970(), &zoo::alexnet());
        let direct = dse::explore_replicated(&tm, 4, 4, 4);
        assert!((joint.tenants[0].capacity - direct.throughput).abs() < 1e-9);
        assert_eq!(joint.sla_declared, 0);
    }

    #[test]
    fn loaded_tenant_attracts_more_cores_than_an_idle_one() {
        // One saturating tenant, one nearly idle: the saturated tenant must
        // end up with most of the board.
        let cfg = Config::default();
        let specs = [
            TenantSpec::new("squeezenet", 1e9),
            TenantSpec::new("alexnet", 0.01),
        ];
        let joint = explore_joint(&specs, &cfg, 4).unwrap();
        assert!(
            joint.tenants[0].budget.cores() > joint.tenants[1].budget.cores(),
            "{:?}",
            joint.tenants.iter().map(|t| t.budget).collect::<Vec<_>>()
        );
        // The idle tenant's demand is still met.
        assert!(joint.tenants[1].served >= 0.01 - 1e-9);
    }

    #[test]
    fn more_tenants_than_cores_is_an_error() {
        let cfg = Config::default();
        let specs: Vec<TenantSpec> =
            (0..9).map(|_| TenantSpec::new("alexnet", 1.0)).collect();
        assert!(explore_joint(&specs, &cfg, 4).is_err());
    }
}
