//! The unified multi-tenant serving report: one shape for the DES
//! co-simulation ([`crate::tenancy::simulate_multi`]) and the wall-clock
//! deploy ([`crate::tenancy::deploy_multi`]), rendered by one path
//! ([`crate::reports::render_multi_serve`]) and serialized for
//! `--metrics-out`.

use anyhow::{Context, Result};

use crate::api::{LatencyReport, Plan};
use crate::dse::PipelineConfig;
use crate::obs::{AttribReport, MetricsSnapshot};
use crate::util::json::Json;

/// Runtime knobs shared by both multi-tenant execution backends; the
/// [`MultiPlan`](crate::tenancy::MultiPlan) itself fixes every design
/// decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MultiServeOptions {
    /// Arrivals generated per tenant.
    pub images: usize,
    /// Inter-stage queue capacity inside each replica.
    pub queue_cap: usize,
    /// Front-door admission queue capacity per tenant; arrivals beyond it
    /// are shed (counted per tenant), never queued unboundedly.
    pub admission_cap: usize,
    /// Base arrival seed; tenant `i` without a pinned seed draws its
    /// Poisson stream from `seed + 7919·i`. The seed-stream audit
    /// (DESIGN.md §15) pins the scheme: harness repetitions perturb the
    /// base by `+rep` with `rep < 7919`, so rep and tenant offsets occupy
    /// disjoint residues (mixed-radix digits) and no two (rep, tenant)
    /// pairs in range share a SplitMix64 stream.
    pub seed: u64,
    /// Wall-clock deploys sleep for `stage_time * time_scale` per item
    /// (ignored by the DES).
    pub time_scale: f64,
    /// Replace every tenant's Poisson stream with a deterministic uniform
    /// stream at the same rate (the CLI's `--arrival uniform:RATE` form).
    pub uniform_arrivals: bool,
}

impl Default for MultiServeOptions {
    fn default() -> MultiServeOptions {
        MultiServeOptions {
            images: 300,
            queue_cap: 2,
            admission_cap: 8,
            seed: 7,
            time_scale: 0.05,
            uniform_arrivals: false,
        }
    }
}

impl MultiServeOptions {
    /// Arrival seed for tenant `idx`: its pinned seed, or a deterministic
    /// derivation from the run seed that keeps the streams distinct.
    pub fn tenant_seed(&self, pinned: Option<u64>, idx: usize) -> u64 {
        pinned.unwrap_or_else(|| self.seed.wrapping_add(7919 * idx as u64))
    }
}

/// Which backend produced a [`MultiServeReport`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MultiServeMode {
    /// Discrete-event co-simulation.
    Des,
    /// Wall-clock thread fleets over synthetic sleep stages; latencies and
    /// throughputs in the report are normalized back by `time_scale` so
    /// they compare directly with the DES and the SLAs.
    Synthetic { time_scale: f64 },
}

/// One tenant's slice of a co-serving run.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantReport {
    pub name: String,
    pub network: String,
    /// `3B+1s` display of the tenant's core slice.
    pub budget: String,
    /// `B2-s1 | s3` display of the tenant's fleet.
    pub pipeline: String,
    pub rate_hz: f64,
    pub weight: f64,
    /// Arrivals offered / admitted / shed at the front door
    /// (`offered == admitted + shed`).
    pub offered: usize,
    pub admitted: usize,
    pub shed: usize,
    /// Served rate over the tenant's busy horizon (imgs/s).
    pub throughput: f64,
    /// The plan's Eq. 12 slice capacity (imgs/s).
    pub capacity: f64,
    /// End-to-end latency percentiles (arrival → completion), `None` when
    /// nothing was admitted.
    pub latency: Option<LatencyReport>,
    /// Declared p99 SLA, if any.
    pub p99_sla_s: Option<f64>,
    /// `Some(met)` when an SLA was declared: observed p99 ≤ SLA.
    pub sla_ok: Option<bool>,
    /// Busiest stage's busy fraction across the tenant's replicas.
    pub utilization: f64,
}

/// Unified result of co-serving a [`MultiPlan`](crate::tenancy::MultiPlan)
/// through either backend.
#[derive(Debug, Clone, PartialEq)]
pub struct MultiServeReport {
    pub mode: MultiServeMode,
    /// Board wall-clock (or simulated-clock) duration in seconds.
    pub wall_s: f64,
    /// Items served across all tenants.
    pub images: usize,
    /// Items shed across all tenants.
    pub shed: usize,
    /// `Σ_t w_t · observed_throughput_t` (imgs/s) — the objective the
    /// joint DSE optimized, measured.
    pub weighted_throughput: f64,
    /// Busy core-seconds over available core-seconds for the whole board.
    pub board_utilization: f64,
    pub tenants: Vec<TenantReport>,
    /// Frozen observability registry (DESIGN.md §13) when the run was
    /// recorded; `None` under a disabled [`crate::obs::Recorder`], keeping
    /// unrecorded report bytes unchanged.
    pub metrics: Option<MetricsSnapshot>,
    /// Prediction-error attribution over the recorded spans (DESIGN.md
    /// §14): where each admitted item's latency went, and how each stage's
    /// observed service compares to its Eq. 10 prediction. `None` when the
    /// run was not recorded.
    pub attrib: Option<AttribReport>,
}

impl MultiServeReport {
    /// Every declared SLA that was met, over every declared SLA.
    pub fn sla_counts(&self) -> (usize, usize) {
        let declared = self.tenants.iter().filter(|t| t.sla_ok.is_some()).count();
        let met = self.tenants.iter().filter(|t| t.sla_ok == Some(true)).count();
        (met, declared)
    }

    /// JSON shape of the report — what `serve-multi --metrics-out`
    /// captures.
    pub fn to_json(&self) -> Json {
        let mode = match self.mode {
            MultiServeMode::Des => Json::obj(vec![("kind", Json::str("des"))]),
            MultiServeMode::Synthetic { time_scale } => Json::obj(vec![
                ("kind", Json::str("synthetic")),
                ("time_scale", Json::num(time_scale)),
            ]),
        };
        let tenants = Json::Arr(
            self.tenants
                .iter()
                .map(|t| {
                    let latency = match &t.latency {
                        None => Json::Null,
                        Some(l) => Json::obj(vec![
                            ("p50", Json::num(l.p50)),
                            ("p95", Json::num(l.p95)),
                            ("p99", Json::num(l.p99)),
                        ]),
                    };
                    Json::obj(vec![
                        ("name", Json::str(&t.name)),
                        ("network", Json::str(&t.network)),
                        ("budget", Json::str(&t.budget)),
                        ("pipeline", Json::str(&t.pipeline)),
                        ("rate_hz", Json::num(t.rate_hz)),
                        ("weight", Json::num(t.weight)),
                        ("offered", Json::num(t.offered as f64)),
                        ("admitted", Json::num(t.admitted as f64)),
                        ("shed", Json::num(t.shed as f64)),
                        ("throughput", Json::num(t.throughput)),
                        ("capacity", Json::num(t.capacity)),
                        ("latency", latency),
                        ("p99_sla_s", t.p99_sla_s.map_or(Json::Null, Json::num)),
                        (
                            "sla_ok",
                            t.sla_ok.map_or(Json::Null, Json::Bool),
                        ),
                        ("utilization", Json::num(t.utilization)),
                    ])
                })
                .collect(),
        );
        let mut fields = vec![
            ("mode", mode),
            ("wall_s", Json::num(self.wall_s)),
            ("images", Json::num(self.images as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("weighted_throughput", Json::num(self.weighted_throughput)),
            ("board_utilization", Json::num(self.board_utilization)),
            ("tenants", tenants),
        ];
        if let Some(m) = &self.metrics {
            fields.push(("metrics", m.to_json()));
        }
        if let Some(a) = &self.attrib {
            fields.push(("attrib", a.to_json()));
        }
        Json::obj(fields)
    }
}

/// Busy core-seconds of one tenant's fleet: `Σ_r Σ_s busy[r][s] ·
/// cores(stage s)`, with stage core counts recovered from the plan's
/// pipeline notation. The board-utilization numerator both backends share.
pub(crate) fn core_seconds(plan: &Plan, busy: &[Vec<f64>]) -> Result<f64> {
    let mut total = 0.0;
    for (r, replica) in plan.replicas.iter().enumerate() {
        let p = PipelineConfig::parse(&replica.pipeline).with_context(|| {
            format!("replica {r} pipeline {:?} is not a core-notation pipeline", replica.pipeline)
        })?;
        anyhow::ensure!(
            p.num_stages() == busy[r].len(),
            "replica {r}: {} stages in the pipeline, {} busy entries",
            p.num_stages(),
            busy[r].len()
        );
        for (s, b) in busy[r].iter().enumerate() {
            total += b * p.stages[s].count as f64;
        }
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn core_seconds_weighs_stages_by_core_count() {
        let plan = crate::api::PlanSpec::new("alexnet")
            .pipeline("B2-s2")
            .compile()
            .unwrap();
        // 2 cores busy 3 s + 2 cores busy 1 s = 8 core-seconds.
        let cs = core_seconds(&plan, &[vec![3.0, 1.0]]).unwrap();
        assert!((cs - 8.0).abs() < 1e-12);
        // Mismatched stage count is an error, not a silent truncation.
        assert!(core_seconds(&plan, &[vec![3.0]]).is_err());
    }

    #[test]
    fn report_json_is_parseable() {
        let report = MultiServeReport {
            mode: MultiServeMode::Des,
            wall_s: 10.0,
            images: 500,
            shed: 3,
            weighted_throughput: 51.5,
            board_utilization: 0.83,
            tenants: vec![TenantReport {
                name: "alexnet".into(),
                network: "alexnet".into(),
                budget: "3B+1s".into(),
                pipeline: "B2-s1 | B1".into(),
                rate_hz: 30.0,
                weight: 1.0,
                offered: 300,
                admitted: 298,
                shed: 2,
                throughput: 29.6,
                capacity: 41.0,
                latency: Some(LatencyReport { p50: 0.02, p95: 0.04, p99: 0.05 }),
                p99_sla_s: Some(0.08),
                sla_ok: Some(true),
                utilization: 0.71,
            }],
            metrics: None,
            attrib: None,
        };
        let text = report.to_json().to_string();
        let j = Json::parse(&text).expect("multi report JSON reparses");
        assert_eq!(j.req("mode").unwrap().req("kind").unwrap().as_str(), Some("des"));
        let t = &j.req("tenants").unwrap().as_arr().unwrap()[0];
        assert_eq!(t.req("sla_ok").unwrap().as_bool(), Some(true));
        assert_eq!(t.req("shed").unwrap().as_usize(), Some(2));
        assert_eq!(report.sla_counts(), (1, 1));
    }
}
