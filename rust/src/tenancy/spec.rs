//! Tenant descriptions: what each co-served network demands of the board.
//!
//! A [`TenantSpec`] pairs a workload (a zoo network, or the network behind
//! an existing [`Plan`](crate::api::Plan) artifact) with its service
//! contract: the offered arrival rate, an optional p99 latency SLA, and a
//! weight expressing how much the operator values this tenant's throughput
//! in the joint objective ([`crate::tenancy::explore_joint`]). The CLI form
//! is a repeatable `--tenant key=value,...` option parsed by
//! [`TenantSpec::parse`].

use anyhow::{Context, Result};

use crate::api::{Plan, TimeSource};
use crate::cnn::zoo;
use crate::config::Config;
use crate::perfmodel::{PerfModel, TimeMatrix};

/// Parse a human duration into seconds: `80ms`, `1.5s`, or a bare number
/// (seconds).
pub fn parse_duration_s(s: &str) -> Result<f64> {
    let (txt, scale) = if let Some(ms) = s.strip_suffix("ms") {
        (ms, 1e-3)
    } else if let Some(sec) = s.strip_suffix('s') {
        (sec, 1.0)
    } else {
        (s, 1.0)
    };
    let v: f64 = txt
        .parse()
        .map_err(|_| anyhow::anyhow!("bad duration {s:?} (expected e.g. 80ms, 0.08, 1.5s)"))?;
    anyhow::ensure!(v.is_finite() && v > 0.0, "duration must be positive, got {s:?}");
    Ok(v * scale)
}

/// One tenant of a co-served board: workload + service contract.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    /// Display name; defaults to the network name (auto-suffixed `#k` when
    /// several tenants serve the same network).
    pub name: String,
    /// Zoo network this tenant serves.
    pub network: String,
    /// Offered Poisson arrival rate (images/s).
    pub rate_hz: f64,
    /// Optional p99 end-to-end latency SLA in seconds.
    pub p99_sla_s: Option<f64>,
    /// Weight of this tenant's served rate in the joint objective (>= 0).
    pub weight: f64,
    /// Arrival-stream seed; `None` derives one from the run's `--seed` and
    /// the tenant index, so streams stay reproducible but distinct.
    pub seed: Option<u64>,
    /// Which layer times the joint DSE scores this tenant with.
    pub time_source: TimeSource,
}

impl TenantSpec {
    /// A measured-times tenant with unit weight and no SLA.
    pub fn new(network: &str, rate_hz: f64) -> TenantSpec {
        TenantSpec {
            name: network.to_string(),
            network: network.to_string(),
            rate_hz,
            p99_sla_s: None,
            weight: 1.0,
            seed: None,
            time_source: TimeSource::Measured,
        }
    }

    pub fn with_sla(mut self, p99_s: f64) -> TenantSpec {
        self.p99_sla_s = Some(p99_s);
        self
    }

    pub fn with_weight(mut self, weight: f64) -> TenantSpec {
        self.weight = weight;
        self
    }

    /// Parse one `--tenant` value: comma-separated `key=value` pairs.
    ///
    /// Keys: `net=NAME` or `plan=FILE` (exactly one; a plan artifact
    /// contributes its network and time source — the *design* is re-searched
    /// inside the tenant's core slice by the joint DSE), `rate=HZ`
    /// (required), `p99=DUR` (e.g. `80ms`), `weight=W`, `seed=N`,
    /// `name=LABEL`.
    pub fn parse(s: &str) -> Result<TenantSpec> {
        let mut net: Option<String> = None;
        let mut time_source = TimeSource::Measured;
        let mut rate: Option<f64> = None;
        let mut p99 = None;
        let mut weight = 1.0;
        let mut seed = None;
        let mut name: Option<String> = None;
        for part in s.split(',').filter(|p| !p.is_empty()) {
            let (k, v) = part
                .split_once('=')
                .with_context(|| format!("bad tenant field {part:?} (expected key=value)"))?;
            if matches!(k, "net" | "plan") {
                anyhow::ensure!(
                    net.is_none(),
                    "tenant spec {s:?} names its workload twice (net= and plan= are \
                     mutually exclusive, each given at most once)"
                );
            }
            match k {
                "net" => net = Some(v.to_string()),
                "plan" => {
                    let plan = Plan::load(std::path::Path::new(v))?;
                    anyhow::ensure!(
                        plan.artifacts.is_none(),
                        "tenant plan {v:?} is artifact-bound; co-serving drives \
                         big.LITTLE zoo plans"
                    );
                    anyhow::ensure!(
                        plan.time_source != TimeSource::ProfiledArtifacts,
                        "tenant plan {v:?} carries profiled-artifact times"
                    );
                    time_source = plan.time_source;
                    net = Some(plan.network);
                }
                "rate" => {
                    let r: f64 = v
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad tenant rate {v:?}"))?;
                    anyhow::ensure!(
                        r.is_finite() && r > 0.0,
                        "tenant rate must be positive, got {v:?}"
                    );
                    rate = Some(r);
                }
                "p99" => p99 = Some(parse_duration_s(v)?),
                "weight" => {
                    let w: f64 = v
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad tenant weight {v:?}"))?;
                    anyhow::ensure!(
                        w.is_finite() && w >= 0.0,
                        "tenant weight must be >= 0, got {v:?}"
                    );
                    weight = w;
                }
                "seed" => {
                    let n: u64 = v
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad tenant seed {v:?}"))?;
                    // MultiPlan serializes seeds as JSON numbers (f64):
                    // anything past 2^53 would round silently on save/load.
                    anyhow::ensure!(
                        n < (1u64 << 53),
                        "tenant seed {n} exceeds 2^53 and would lose precision \
                         in the plan artifact"
                    );
                    seed = Some(n);
                }
                "name" => name = Some(v.to_string()),
                other => anyhow::bail!(
                    "unknown tenant field {other:?} (net|plan|rate|p99|weight|seed|name)"
                ),
            }
        }
        let network = net.context("tenant needs net=NAME or plan=FILE")?;
        anyhow::ensure!(
            zoo::by_name(&network).is_some(),
            "unknown network {network:?} in tenant spec {s:?}"
        );
        let rate_hz = rate.context("tenant needs rate=HZ (offered images/s)")?;
        Ok(TenantSpec {
            name: name.unwrap_or_else(|| network.clone()),
            network,
            rate_hz,
            p99_sla_s: p99,
            weight,
            seed,
            time_source,
        })
    }

    /// Parse every `--tenant` occurrence, de-duplicating default names
    /// (`alexnet`, `alexnet#2`, …). Explicitly colliding `name=` labels are
    /// an error.
    pub fn parse_all(values: &[&str]) -> Result<Vec<TenantSpec>> {
        anyhow::ensure!(!values.is_empty(), "need at least one --tenant spec");
        let mut out: Vec<TenantSpec> = Vec::with_capacity(values.len());
        for v in values {
            let mut spec = TenantSpec::parse(v)?;
            let explicit = spec.name != spec.network;
            let mut k = 1;
            while out.iter().any(|t| t.name == spec.name) {
                anyhow::ensure!(
                    !explicit,
                    "duplicate tenant name {:?} (give each tenant a unique name=)",
                    spec.name
                );
                k += 1;
                spec.name = format!("{}#{k}", spec.network);
            }
            out.push(spec);
        }
        Ok(out)
    }

    /// The layer-time matrix the joint DSE scores this tenant with.
    pub fn time_matrix(&self, cfg: &Config) -> Result<TimeMatrix> {
        let net = zoo::by_name(&self.network)
            .with_context(|| format!("unknown network {:?}", self.network))?;
        match self.time_source {
            TimeSource::Measured => Ok(TimeMatrix::measured(&cfg.platform, &net)),
            TimeSource::Predicted => {
                let model = PerfModel::fit(&cfg.platform);
                Ok(TimeMatrix::predicted(&cfg.platform, &model, &net))
            }
            TimeSource::ProfiledArtifacts => anyhow::bail!(
                "tenant {:?}: profiled-artifact times have no big.LITTLE matrix",
                self.name
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_examples() {
        let a = TenantSpec::parse("net=alexnet,rate=30").unwrap();
        assert_eq!(a.name, "alexnet");
        assert_eq!(a.rate_hz, 30.0);
        assert_eq!(a.p99_sla_s, None);
        assert_eq!(a.weight, 1.0);

        let s = TenantSpec::parse("net=squeezenet,rate=60,p99=80ms,weight=2,seed=5").unwrap();
        assert_eq!(s.network, "squeezenet");
        assert_eq!(s.rate_hz, 60.0);
        assert!((s.p99_sla_s.unwrap() - 0.080).abs() < 1e-12);
        assert_eq!(s.weight, 2.0);
        assert_eq!(s.seed, Some(5));
    }

    #[test]
    fn duration_forms() {
        assert!((parse_duration_s("80ms").unwrap() - 0.08).abs() < 1e-12);
        assert!((parse_duration_s("1.5s").unwrap() - 1.5).abs() < 1e-12);
        assert!((parse_duration_s("0.25").unwrap() - 0.25).abs() < 1e-12);
        assert!(parse_duration_s("-3ms").is_err());
        assert!(parse_duration_s("fast").is_err());
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(TenantSpec::parse("rate=30").is_err(), "missing net");
        assert!(TenantSpec::parse("net=alexnet").is_err(), "missing rate");
        assert!(TenantSpec::parse("net=vgg19,rate=30").is_err(), "unknown net");
        assert!(TenantSpec::parse("net=alexnet,rate=0").is_err(), "zero rate");
        assert!(TenantSpec::parse("net=alexnet,rate=30,p99=never").is_err());
        assert!(TenantSpec::parse("net=alexnet,rate=30,turbo=1").is_err(), "unknown key");
        assert!(TenantSpec::parse("net=alexnet,rate=30,weight=-1").is_err());
        // net= and plan= are mutually exclusive, in either order.
        let err = TenantSpec::parse("net=alexnet,net=squeezenet,rate=5").unwrap_err();
        assert!(err.to_string().contains("mutually exclusive"), "{err}");
        assert!(TenantSpec::parse("net=alexnet,plan=x.json,rate=5").is_err());
        assert!(TenantSpec::parse("plan=x.json,net=alexnet,rate=5").is_err());
    }

    #[test]
    fn parse_all_suffixes_duplicate_default_names() {
        let specs =
            TenantSpec::parse_all(&["net=alexnet,rate=10", "net=alexnet,rate=20"]).unwrap();
        assert_eq!(specs[0].name, "alexnet");
        assert_eq!(specs[1].name, "alexnet#2");
        let err = TenantSpec::parse_all(&[
            "net=alexnet,rate=10,name=x",
            "net=squeezenet,rate=20,name=x",
        ])
        .unwrap_err();
        assert!(err.to_string().contains("duplicate tenant name"), "{err}");
    }
}
