//! `pipeit` — Pipe-it CLI (L3 leader entrypoint).
//!
//! Every subcommand is a thin wrapper over the `pipeit::api` Plan → Deploy
//! facade (DESIGN.md §8): `plan` compiles a serializable design artifact,
//! `serve` / `simulate` execute one (freshly compiled or loaded from
//! `--plan plan.json`), and the legacy forms (`explore`, `serve --net`,
//! `simulate --net --pipeline`, …) compile a plan in-process and run it.
//!
//! All simulator-backed subcommands accept `--platform configs/<f>.json`.

use std::path::Path;

use anyhow::{Context, Result};

use pipeit::adapt::{self, AdaptOptions, ClusterThrottle, DriftConfig};
use pipeit::api::{DeployOptions, Plan, PlanSpec, Strategy, TimeSource};
use pipeit::cluster::{
    BoardSpec, ClusterPlan, ClusterServeOptions, ClusterSpec, DispatchPolicy,
};
use pipeit::cnn::zoo;
use pipeit::config::Config;
use pipeit::dse;
use pipeit::harness::{self, BenchReport, RunnerOptions, Suite};
use pipeit::obs::{self, Recorder};
use pipeit::perfmodel::{PerfModel, TimeMatrix};
use pipeit::reports::{
    render_attrib, render_bench, render_bench_compare, render_cluster,
    render_history, render_metrics, render_multi_serve, render_serve, Reporter,
};
use pipeit::simulator::arrivals::ArrivalSpec;
use pipeit::simulator::platform::CoreType;
use pipeit::tenancy::{
    parse_duration_s, predict_p99, MultiPlan, MultiServeOptions, TenantPlan, TenantSpec,
};
use pipeit::util::cli::Args;
use pipeit::util::json::Json;
use pipeit::util::table::{f, Table};

const USAGE: &str = "\
pipeit — Pipe-it: high-throughput CNN inference on big.LITTLE (TCAD'19 reproduction)

USAGE: pipeit <plan|serve|simulate|plan-multi|serve-multi|simulate-multi|plan-cluster|serve-cluster|simulate-cluster|bench|attrib|trace|explore|predict|count|tables> [options]

  plan       --net N [--predicted] [--platform F] [--out plan.json]
             [--strategy serial|pipeline|replicated|exhaustive|energy]
             [--replicas R | --max-replicas 4] [--pipeline B4-s2-s2]
             [--min-throughput T] [--mem-intensity 0.6]
                                               compile a serving-plan artifact
  plan       --artifacts DIR [--stages 3] [--replicas R] [--profile]
             [--out plan.json]                 plan over AOT artifacts
  serve      --plan plan.json [--images 60] [--queue-cap 2] [--time-scale 0.1]
             [--batch 1] [--seed 7]            deploy a saved plan
  simulate   --plan plan.json [--images 500] [--queue-cap 2]
                                               DES a saved plan
  explore    --net N [--predicted] [--platform F]
             [--replicated] [--max-replicas 4]   also search replica partitions
  predict    --net N [--platform F]            per-layer time matrix (ms)
  simulate   --net N --pipeline B4-s2-s2 [--images 500] [--queue-cap 2]
  count      [--net N] [--max-replicas 4]      design-space sizes (Eq. 1-2 + fleet)
  serve      --net N [--replicas 1] [--images 60] [--queue-cap 2]
             [--time-scale 0.1]                simulated-time fleet serving
  serve      --net N|--plan plan.json --adapt [--adapt-interval 50]
             [--drift-threshold 0.35] [--throttle AT:FACTOR[:big|small][,..]]
                                               closed-loop adaptive serving:
                                               telemetry -> drift -> recalibrate
                                               -> re-plan -> hot-swap; --throttle
                                               without --adapt = baseline run
                                               under the same disturbance
  serve      --artifacts artifacts/pipenet_tiny [--replicas 1] [--images 50]
             [--batch 1] [--stages 3] [--queue-cap 2] [--serial] [--seed 7]
                                               real PJRT serving (needs --features pjrt)
  serve      --net N|--plan P --arrival poisson:RATE[:SEED]|uniform:RATE
             [--p99 80ms] [--admission-cap 8]  open-loop wall-clock serving:
                                               paced arrivals, bounded admission,
                                               shed-on-full
  simulate   --net N --pipeline S|--plan P --arrival poisson:RATE[:SEED]|uniform:RATE
             [--p99 80ms] [--admission-cap 8]  open-loop DES (reproducible seed)
  plan-multi --tenant net=alexnet,rate=30 --tenant net=squeezenet,rate=60,p99=80ms
             [--predicted] [--platform F] [--max-replicas 4] [--out mp.json]
                                               joint cross-network DSE: split the
                                               core budget across tenants, maximize
                                               weighted SLA-feasible throughput
                                               (tenant keys: net|plan,rate,p99,
                                               weight,seed,name)
  serve-multi    --plan mp.json | --tenant ... [--images 300] [--queue-cap 2]
             [--admission-cap 8] [--time-scale 0.05] [--seed 7]
                                               wall-clock co-serving: per-tenant
                                               fleets + shared shed-on-full front door
  simulate-multi --plan mp.json | --tenant ... [--images 2000] [--queue-cap 2]
             [--admission-cap 8] [--seed 7]    DES co-simulation of the same board
  plan-cluster --board cores=4+4 --board cores=2+6,seed=11 --net alexnet --rate 200
             [--tenant ... instead of --net/--rate] [--predicted] [--platform F]
             [--max-replicas 4] [--out cp.json]  cluster DSE over N heterogeneous
                                               boards: per-board search (replicated
                                               or joint), capacity-proportional
                                               traffic shares (board keys: cores,
                                               platform, seed, name)
  serve-cluster    --plan cp.json | --board ... [--images 240]
             [--policy round-robin|least-outstanding|p2c] [--queue-cap 2]
             [--admission-cap 8] [--time-scale 0.05] [--seed 7]
             [--disable-board NAME]            wall-clock fleet-of-boards serving:
                                               one run_fleet per board fleet behind
                                               a single router thread
  simulate-cluster --plan cp.json | --board ... [--images 2000] [--policy P]
             [--disable-board NAME] [--seed 7]  deterministic cluster DES (seeded
                                               per-board arrival/dispatch streams)
  bench      [--suite quick|full] [--seed 7] [--reps 5] [--warmup 1]
             [--out BENCH_0.json]              run the benchmark harness: every
                                               serving mode x execution twin,
                                               robust stats (median, MAD
                                               rejection, bootstrap CI), and a
                                               schema-versioned perf artifact;
                                               quick = DES only (deterministic),
                                               full adds the wall-clock twins
  bench      --compare old.json new.json [--min-delta 0.01]
                                               classify each scenario improved/
                                               REGRESSED/unchanged by CI overlap;
                                               exits non-zero on any regression
  bench      history [DIR] [--dat history.dat]
                                               longitudinal trajectory over a
                                               directory of BENCH_*.json
                                               artifacts: per-scenario medians
                                               per artifact, first->last drift;
                                               --dat writes a gnuplot-ready
                                               column file
  attrib     --trace trace.jsonl [--json attrib.json]
                                               explain the miss: decompose each
                                               traced item's latency into front
                                               wait + queue wait + stage service
  attrib     --plan plan.json --simulate [--images 500] [--queue-cap 2]
             [--json attrib.json]              DES a saved plan and attribute
                                               observed stage service against
                                               its Eq. 10 predictions
  trace      convert trace.jsonl trace.chrome.json
                                               convert a --trace-out span dump to
                                               Chrome-trace/Perfetto JSON (load in
                                               chrome://tracing or ui.perfetto.dev)
  tables     [--platform F]                    regenerate every paper table & figure

every serve/simulate form also takes --metrics-out metrics.json, and the six
closed-loop serve/simulate forms take --trace-out trace.jsonl (record per-item
spans + metrics registry; prints the observability footer)

networks: alexnet googlenet mobilenet resnet50 squeezenet";

fn main() -> Result<()> {
    let args = Args::parse(
        std::env::args().skip(1),
        &["predicted", "serial", "measured", "replicated", "profile", "adapt", "simulate"],
    )?;
    let Some(cmd) = args.positional.first().map(|s| s.as_str()) else {
        println!("{USAGE}");
        return Ok(());
    };
    let cfg = Config::load_or_default(args.get("platform"))?;

    match cmd {
        "tables" => {
            Reporter::new(cfg).print_all();
        }
        "plan" => {
            let plan = compile_from_args(&args, &cfg)?;
            print!("{}", plan.summary());
            if let Some(out) = args.get("out") {
                plan.save(Path::new(out))?;
                println!("plan saved : {out}");
            }
        }
        "explore" => explore(&args, &cfg)?,
        "predict" => predict(&args, &cfg)?,
        "simulate" => {
            let images = args.get_usize("images", 500)?;
            let cap = args.get_usize("queue-cap", 2)?;
            let plan = if let Some(path) = args.get("plan") {
                reject_compile_flags(&args)?;
                Plan::load(Path::new(path))?
            } else {
                let spec = args.get("pipeline").context(
                    "--pipeline required (e.g. B4-s2-s2), or --plan plan.json",
                )?;
                let net = args.get("net").context("--net is required")?;
                PlanSpec::new(net).platform(cfg).pipeline(spec).compile()?
            };
            if args.get("arrival").is_some() {
                run_open_loop(plan, &args, false)?;
            } else {
                print!("{}", plan.summary());
                let rec = trace_recorder(&args);
                let report = plan.simulate_recorded(images, cap, &rec)?;
                print!("{}", render_serve(&report));
                write_metrics(&args, &report.to_json())?;
                write_trace(&args, &rec, "sim")?;
            }
        }
        "plan-multi" => {
            let specs = tenant_specs_from_args(&args)?;
            let mp =
                MultiPlan::compile(&specs, &cfg, args.get_usize("max-replicas", 4)?)?;
            print!("{}", mp.summary());
            if let Some(out) = args.get("out") {
                mp.save(Path::new(out))?;
                println!("plan saved : {out}");
            }
        }
        "serve-multi" | "simulate-multi" => {
            let mp = if let Some(path) = args.get("plan") {
                for key in ["tenant", "max-replicas"] {
                    anyhow::ensure!(
                        args.get(key).is_none(),
                        "--{key} is a plan-compile option; the plan file fixes the \
                         design (recompile with `pipeit plan-multi --{key} ...`)"
                    );
                }
                anyhow::ensure!(
                    !args.has_flag("predicted"),
                    "--predicted is a plan-compile option; the plan file fixes the \
                     time source (recompile with `pipeit plan-multi --predicted ...`)"
                );
                MultiPlan::load(Path::new(path))?
            } else {
                let specs = tenant_specs_from_args(&args)?;
                MultiPlan::compile(&specs, &cfg, args.get_usize("max-replicas", 4)?)?
            };
            let deploy = cmd == "serve-multi";
            let opts = multi_opts(&args, if deploy { 300 } else { 2000 })?;
            print!("{}", mp.summary());
            let rec = trace_recorder(&args);
            let report = if deploy {
                mp.deploy_recorded(&opts, &rec)?
            } else {
                mp.simulate_recorded(&opts, &rec)?
            };
            println!();
            print!("{}", render_multi_serve(&report));
            write_metrics(&args, &report.to_json())?;
            write_trace(&args, &rec, if deploy { "wall" } else { "sim" })?;
        }
        "plan-cluster" => {
            let spec = cluster_spec_from_args(&args)?;
            let cp = ClusterPlan::compile(&spec, &cfg)?;
            print!("{}", cp.summary());
            if let Some(out) = args.get("out") {
                cp.save(Path::new(out))?;
                println!("plan saved : {out}");
            }
        }
        "serve-cluster" | "simulate-cluster" => {
            let cp = if let Some(path) = args.get("plan") {
                anyhow::ensure!(
                    args.get_all("board").is_empty(),
                    "--board is a plan-compile option; the plan file fixes the \
                     fleet (recompile with `pipeit plan-cluster --board ...`)"
                );
                for key in ["net", "rate", "tenant", "max-replicas"] {
                    anyhow::ensure!(
                        args.get(key).is_none(),
                        "--{key} is a plan-compile option; the plan file fixes the \
                         design (recompile with `pipeit plan-cluster --{key} ...`)"
                    );
                }
                anyhow::ensure!(
                    !args.has_flag("predicted"),
                    "--predicted is a plan-compile option; the plan file fixes the \
                     time source (recompile with `pipeit plan-cluster --predicted ...`)"
                );
                ClusterPlan::load(Path::new(path))?
            } else {
                ClusterPlan::compile(&cluster_spec_from_args(&args)?, &cfg)?
            };
            let deploy = cmd == "serve-cluster";
            let opts = cluster_opts(&args, if deploy { 240 } else { 2000 })?;
            print!("{}", cp.summary());
            let rec = trace_recorder(&args);
            let report = if deploy {
                cp.deploy_recorded(&opts, &rec)?
            } else {
                cp.simulate_recorded(&opts, &rec)?
            };
            println!();
            print!("{}", render_cluster(&report));
            write_metrics(&args, &report.to_json())?;
            write_trace(&args, &rec, if deploy { "wall" } else { "sim" })?;
        }
        "bench" => bench(&args)?,
        "count" => count(&args, &cfg)?,
        "serve" => {
            let replicas = args.get_usize("replicas", 1)?;
            anyhow::ensure!(replicas >= 1, "--replicas must be >= 1");
            if let Some(path) = args.get("plan") {
                reject_compile_flags(&args)?;
                let plan = Plan::load(Path::new(path))?;
                if args.get("arrival").is_some() {
                    anyhow::ensure!(
                        !args.has_flag("adapt") && args.get("throttle").is_none(),
                        "--arrival (open-loop serving) cannot be combined with \
                         --adapt/--throttle"
                    );
                    run_open_loop(plan, &args, true)?;
                } else if args.has_flag("adapt") || args.get("throttle").is_some() {
                    run_adaptive(plan, &cfg, &args)?;
                } else {
                    print!("{}", plan.summary());
                    let rec = trace_recorder(&args);
                    let report = plan.deploy_recorded(&deploy_opts(&args)?, &rec)?;
                    println!();
                    print!("{}", render_serve(&report));
                    write_metrics(&args, &report.to_json())?;
                    write_trace(&args, &rec, "wall")?;
                }
            } else if args.get("artifacts").is_some() {
                serve_artifacts(&args, replicas)?;
            } else if args.get("net").is_some() {
                if args.get("arrival").is_some() {
                    anyhow::ensure!(
                        !args.has_flag("adapt") && args.get("throttle").is_none(),
                        "--arrival (open-loop serving) cannot be combined with \
                         --adapt/--throttle"
                    );
                    let net = args.get("net").context("--net is required")?;
                    let plan = PlanSpec::new(net)
                        .platform(cfg.clone())
                        .strategy(Strategy::Replicated {
                            max_replicas: replicas,
                            exact: true,
                        })
                        .compile()?;
                    run_open_loop(plan, &args, true)?;
                } else {
                    serve_simulated(&args, &cfg, replicas)?;
                }
            } else {
                anyhow::bail!(
                    "serve needs --plan plan.json, --net N (simulated-time fleet), \
                     or --artifacts DIR (real PJRT serving)\n\n{USAGE}"
                );
            }
        }
        "trace" => {
            let sub = args.positional.get(1).map(|s| s.as_str());
            anyhow::ensure!(
                sub == Some("convert"),
                "usage: pipeit trace convert trace.jsonl trace.chrome.json"
            );
            let input = args.positional.get(2).context(
                "usage: pipeit trace convert trace.jsonl trace.chrome.json",
            )?;
            let output = args.positional.get(3).context(
                "usage: pipeit trace convert trace.jsonl trace.chrome.json",
            )?;
            let n = obs::convert_trace(Path::new(input), Path::new(output))?;
            println!("trace      : {input} -> {output} ({n} spans)");
        }
        "attrib" => attrib(&args)?,
        other => {
            println!("unknown subcommand {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

/// `bench`: run the benchmark harness and write the `BENCH_<n>.json` perf
/// artifact, or — with `--compare old.json new.json` — classify each
/// scenario by confidence-interval overlap and exit non-zero on any
/// regression (the CI perf gate).
fn bench(args: &Args) -> Result<()> {
    if args.positional.get(1).map(|s| s.as_str()) == Some("history") {
        return bench_history(args);
    }
    if let Some(old_path) = args.get("compare") {
        let new_path = args.positional.get(1).map(|s| s.as_str()).context(
            "bench --compare takes two artifacts: --compare old.json new.json",
        )?;
        for key in ["suite", "out", "seed", "reps", "warmup"] {
            anyhow::ensure!(
                args.get(key).is_none(),
                "--{key} runs a new bench; --compare reads two existing artifacts"
            );
        }
        let old = BenchReport::load(Path::new(old_path))?;
        let new = BenchReport::load(Path::new(new_path))?;
        let min_delta = args.get_f64("min-delta", harness::DEFAULT_MIN_REL_DELTA)?;
        anyhow::ensure!(min_delta >= 0.0, "--min-delta must be >= 0");
        let cmp = harness::compare(&old, &new, min_delta);
        print!("{}", render_bench_compare(&cmp));
        if cmp.has_regressions() {
            std::process::exit(3);
        }
        return Ok(());
    }
    anyhow::ensure!(
        args.get("min-delta").is_none(),
        "--min-delta applies to --compare (the regression-gate floor)"
    );
    let suite = Suite::parse(args.get_or("suite", "quick"))?;
    let d = RunnerOptions::default();
    let opts = RunnerOptions {
        warmup: args.get_usize("warmup", d.warmup)?,
        reps: args.get_usize("reps", d.reps)?,
        seed: args.get_usize("seed", d.seed as usize)? as u64,
        ..d
    };
    anyhow::ensure!(opts.reps >= 1, "--reps must be >= 1");
    // Seeds ride through the JSON artifact as an f64: cap them where the
    // mantissa ends so save -> load can never round one silently (same
    // contract as tenant seeds).
    anyhow::ensure!(
        opts.seed < (1u64 << 53),
        "--seed must be below 2^53 (seeds are stored in the JSON artifact)"
    );
    let report = harness::run_suite(suite, &opts)?;
    print!("{}", render_bench(&report));
    if let Some(out) = args.get("out") {
        report.save(Path::new(out))?;
        println!("bench saved : {out}");
    }
    Ok(())
}

/// `bench history`: the longitudinal trajectory — load every
/// `BENCH_*.json` in a directory (label = file stem, numeric stems first),
/// render per-scenario medians per artifact, and optionally write a
/// gnuplot-ready `.dat` column file.
fn bench_history(args: &Args) -> Result<()> {
    for key in ["suite", "out", "seed", "reps", "warmup", "compare", "min-delta"] {
        anyhow::ensure!(
            args.get(key).is_none(),
            "--{key} does not apply to bench history (it reads existing artifacts)"
        );
    }
    let dir = args.positional.get(2).map(|s| s.as_str()).unwrap_or(".");
    let history = harness::BenchHistory::load_dir(Path::new(dir))?;
    print!("{}", render_history(&history));
    if let Some(out) = args.get("dat") {
        std::fs::write(out, history.dat()).with_context(|| format!("writing {out}"))?;
        println!("dat saved  : {out}");
    }
    Ok(())
}

/// `attrib`: prediction-error attribution (DESIGN.md §14) — decompose each
/// item's end-to-end latency into front-door wait, inter-stage queue wait,
/// and per-stage service, from either a recorded span trace or a fresh
/// recorded DES run of a saved plan (where observed stage service is also
/// read against the plan's Eq. 10 predictions).
fn attrib(args: &Args) -> Result<()> {
    let report = if let Some(path) = args.get("trace") {
        anyhow::ensure!(
            args.get("plan").is_none() && !args.has_flag("simulate"),
            "attrib takes either --trace trace.jsonl or --plan plan.json --simulate"
        );
        let (clock, spans) = obs::load_trace(Path::new(path))?;
        println!("attrib     : {path} ({} spans, {clock} clock)", spans.len());
        // No plan to read predictions from: decomposition only, the
        // predicted/residual columns render "-".
        obs::attribute(&spans, &obs::PredictedTimes::new())?
    } else if let Some(path) = args.get("plan") {
        anyhow::ensure!(
            args.has_flag("simulate"),
            "attrib --plan needs --simulate (DES the plan, then attribute); to \
             attribute a live run, serve with --trace-out and feed the trace back"
        );
        let plan = Plan::load(Path::new(path))?;
        print!("{}", plan.summary());
        let images = args.get_usize("images", 500)?;
        let cap = args.get_usize("queue-cap", 2)?;
        let rec = Recorder::on();
        let serve = plan.simulate_recorded(images, cap, &rec)?;
        serve.attrib.context("recorded DES run produced no attribution")?
    } else {
        anyhow::bail!(
            "attrib needs --trace trace.jsonl or --plan plan.json --simulate\n\n{USAGE}"
        );
    };
    print!("{}", render_attrib(&report));
    if let Some(out) = args.get("json") {
        std::fs::write(out, format!("{}\n", report.to_json()))
            .with_context(|| format!("writing {out}"))?;
        println!("attrib json: {out}");
    }
    Ok(())
}

/// With `--plan`, the design is fixed by the plan file: reject every
/// plan-compile option instead of silently ignoring it.
fn reject_compile_flags(args: &Args) -> Result<()> {
    let options = [
        "net", "artifacts", "replicas", "stages", "strategy", "pipeline",
        "max-replicas", "min-throughput", "mem-intensity",
    ];
    for key in options {
        anyhow::ensure!(
            args.get(key).is_none(),
            "--{key} is a plan-compile option; the plan file fixes the design \
             (recompile with `pipeit plan --{key} ...`)"
        );
    }
    for flag in ["serial", "predicted", "profile"] {
        anyhow::ensure!(
            !args.has_flag(flag),
            "--{flag} is a plan-compile option; the plan file fixes the design \
             (recompile with `pipeit plan`)"
        );
    }
    Ok(())
}

/// Write a metrics JSON artifact when `--metrics-out` was given.
fn write_metrics(args: &Args, json: &Json) -> Result<()> {
    if let Some(path) = args.get("metrics-out") {
        std::fs::write(path, format!("{json}\n"))
            .with_context(|| format!("writing {path}"))?;
        println!("metrics    : {path}");
    }
    Ok(())
}

/// The run's recorder: enabled only when `--trace-out` was given, so the
/// default path keeps the zero-cost disabled recorder on every hot path.
fn trace_recorder(args: &Args) -> Recorder {
    if args.get("trace-out").is_some() {
        Recorder::on()
    } else {
        Recorder::off()
    }
}

/// Write the schema-versioned JSONL span trace and print the observability
/// footer when `--trace-out` was given. `clock` is `"sim"` for DES runs
/// and `"wall"` for thread-fleet runs (trace timestamps are raw wall
/// seconds there, not normalized model time).
fn write_trace(args: &Args, rec: &Recorder, clock: &str) -> Result<()> {
    if let Some(path) = args.get("trace-out") {
        obs::write_trace(rec, clock, Path::new(path))?;
        if let Some(snap) = rec.snapshot() {
            println!();
            print!("{}", render_metrics(&snap));
        }
        println!("trace      : {path} (pipeit trace convert {path} trace.chrome.json)");
    }
    Ok(())
}

/// `--throttle AT:FACTOR[:big|small][,...]` — scripted disturbances.
fn parse_throttles(args: &Args) -> Result<Vec<ClusterThrottle>> {
    args.get_list("throttle")
        .into_iter()
        .map(ClusterThrottle::parse)
        .collect()
}

/// Closed-loop adaptive serving (`serve --adapt`), and — with `--throttle`
/// but no `--adapt` — the non-adaptive baseline under the same disturbance
/// (the comparison the throttle-recovery acceptance criterion is stated
/// against).
fn run_adaptive(plan: Plan, cfg: &Config, args: &Args) -> Result<()> {
    anyhow::ensure!(
        plan.artifacts.is_none(),
        "--adapt/--throttle apply to big.LITTLE plans (zoo networks); artifact \
         serving has no cluster time matrix to re-plan from"
    );
    anyhow::ensure!(
        plan.platform == cfg.platform.name
            && plan.big == cfg.platform.big.cores
            && plan.small == cfg.platform.small.cores,
        "plan was compiled for {} ({}B+{}s) but the current platform is {} \
         ({}B+{}s); pass the matching --platform file",
        plan.platform,
        plan.big,
        plan.small,
        cfg.platform.name,
        cfg.platform.big.cores,
        cfg.platform.small.cores
    );
    let net = zoo::by_name(&plan.network)
        .with_context(|| format!("unknown network {:?}", plan.network))?;
    let tm = match plan.time_source {
        TimeSource::Measured => TimeMatrix::measured(&cfg.platform, &net),
        TimeSource::Predicted => {
            let model = PerfModel::fit(&cfg.platform);
            TimeMatrix::predicted(&cfg.platform, &model, &net)
        }
        TimeSource::ProfiledArtifacts => anyhow::bail!(
            "--adapt applies to zoo-network plans (measured or predicted times)"
        ),
    };
    let script = parse_throttles(args)?;
    let adapt_enabled = args.has_flag("adapt");
    let defaults = AdaptOptions::default();
    let threshold = args.get_f64("drift-threshold", defaults.drift.threshold)?;
    let opts = AdaptOptions {
        interval: args.get_usize("adapt-interval", defaults.interval)?,
        drift: DriftConfig {
            // Baseline (--throttle without --adapt): a threshold no honest
            // ratio reaches, so the detector never confirms a swap.
            threshold: if adapt_enabled { threshold } else { 1e12 },
            ..defaults.drift
        },
        ..defaults
    };
    let deploy = deploy_opts(args)?;

    print!("{}", plan.summary());
    for t in &script {
        println!(
            "throttle   : t={:.2}s {}-cluster x{:.2}",
            t.at,
            if t.core == CoreType::Big { "big" } else { "small" },
            t.factor
        );
    }
    if !adapt_enabled {
        println!("adaptation : disabled (baseline run; pass --adapt to close the loop)");
    }
    let rec = trace_recorder(args);
    let out = adapt::deploy_adaptive_recorded(
        &plan, &tm, &cfg.power, &script, &opts, &deploy, &rec,
    )?;
    println!();
    print!("{}", render_serve(&out.report));
    println!("adaptations: {}", out.report.adaptations.len());
    if !out.report.adaptations.is_empty() {
        println!(
            "post-swap  : {:.2} imgs/s sustained over {} imgs on {}",
            out.post_swap_throughput(),
            out.post_swap_images,
            out.final_plan.partition_display()
        );
    }
    write_metrics(
        args,
        &Json::obj(vec![
            ("serve", out.report.to_json()),
            ("telemetry", out.final_snapshot.to_json()),
        ]),
    )?;
    write_trace(args, &rec, "wall")
}

/// Parse every `--tenant` occurrence into [`TenantSpec`]s; `--predicted`
/// switches all tenants to the fitted-predictor time matrix.
fn tenant_specs_from_args(args: &Args) -> Result<Vec<TenantSpec>> {
    let vals = args.get_all("tenant");
    anyhow::ensure!(
        !vals.is_empty(),
        "need at least one --tenant net=NAME,rate=HZ[,p99=80ms][,weight=W] spec \
         (or --plan mp.json)\n\n{USAGE}"
    );
    let mut specs = TenantSpec::parse_all(&vals)?;
    if args.has_flag("predicted") {
        for s in &mut specs {
            s.time_source = TimeSource::Predicted;
        }
    }
    Ok(specs)
}

/// Parse the cluster fleet (`--board`, repeatable) and its workloads —
/// either the single-network shorthand `--net N --rate HZ` or full
/// `--tenant` specs; `--predicted` switches every workload to the fitted
/// predictor.
fn cluster_spec_from_args(args: &Args) -> Result<ClusterSpec> {
    let board_vals = args.get_all("board");
    anyhow::ensure!(
        !board_vals.is_empty(),
        "need at least one --board cores=BIG+SMALL[,platform=F][,seed=N][,name=L] \
         spec (or --plan cp.json)\n\n{USAGE}"
    );
    let boards = BoardSpec::parse_all(&board_vals)?;
    let tenant_vals = args.get_all("tenant");
    let mut workloads = if tenant_vals.is_empty() {
        let net = args
            .get("net")
            .context("cluster workloads: --net N --rate HZ, or --tenant specs")?;
        anyhow::ensure!(
            args.get("rate").is_some(),
            "--rate HZ (cluster-wide offered images/s) is required with --net"
        );
        let rate = args.get_f64("rate", 0.0)?;
        anyhow::ensure!(rate > 0.0, "--rate must be positive");
        vec![TenantSpec::new(net, rate)]
    } else {
        anyhow::ensure!(
            args.get("net").is_none() && args.get("rate").is_none(),
            "--net/--rate and --tenant are alternative workload forms; use one"
        );
        TenantSpec::parse_all(&tenant_vals)?
    };
    if args.has_flag("predicted") {
        for w in &mut workloads {
            w.time_source = TimeSource::Predicted;
        }
    }
    Ok(ClusterSpec {
        boards,
        workloads,
        max_replicas: args.get_usize("max-replicas", 4)?,
    })
}

/// Runtime knobs shared by `serve-cluster` and `simulate-cluster`.
fn cluster_opts(args: &Args, default_images: usize) -> Result<ClusterServeOptions> {
    let d = ClusterServeOptions::default();
    Ok(ClusterServeOptions {
        images: args.get_usize("images", default_images)?,
        queue_cap: args.get_usize("queue-cap", d.queue_cap)?,
        admission_cap: args.get_usize("admission-cap", d.admission_cap)?,
        seed: args.get_usize("seed", d.seed as usize)? as u64,
        time_scale: args.get_f64("time-scale", d.time_scale)?,
        uniform_arrivals: false,
        policy: match args.get("policy") {
            Some(p) => DispatchPolicy::parse(p)?,
            None => d.policy,
        },
        disabled: args
            .get_all("disable-board")
            .into_iter()
            .map(String::from)
            .collect(),
    })
}

/// Runtime knobs shared by the multi-tenant serve/simulate forms and the
/// single-tenant open-loop (`--arrival`) forms.
fn multi_opts(args: &Args, default_images: usize) -> Result<MultiServeOptions> {
    let d = MultiServeOptions::default();
    Ok(MultiServeOptions {
        images: args.get_usize("images", default_images)?,
        queue_cap: args.get_usize("queue-cap", d.queue_cap)?,
        admission_cap: args.get_usize("admission-cap", d.admission_cap)?,
        seed: args.get_usize("seed", d.seed as usize)? as u64,
        time_scale: args.get_f64("time-scale", d.time_scale)?,
        uniform_arrivals: false,
    })
}

/// Open-loop (arrival-driven) serving of ONE plan: wrap it as a
/// single-tenant [`MultiPlan`] so the `--arrival` forms run through the
/// same admission/shedding engine and render through the same
/// [`render_multi_serve`] path as true co-serving.
fn run_open_loop(plan: Plan, args: &Args, deploy: bool) -> Result<()> {
    anyhow::ensure!(
        plan.artifacts.is_none(),
        "--arrival applies to big.LITTLE plans (zoo networks)"
    );
    let spec = ArrivalSpec::parse(args.get("arrival").context("--arrival is required")?)?;
    let p99 = args.get("p99").map(parse_duration_s).transpose()?;
    let mut opts = multi_opts(args, if deploy { 60 } else { 500 })?;
    opts.uniform_arrivals = matches!(spec, ArrivalSpec::Uniform { .. });
    let pinned_seed = match spec {
        ArrivalSpec::Poisson { seed, .. } => seed,
        ArrivalSpec::Uniform { .. } => None,
    };
    let rate = spec.rate_hz();
    let stage_times: Vec<Vec<f64>> =
        plan.replicas.iter().map(|r| r.stage_times.clone()).collect();
    anyhow::ensure!(
        stage_times.iter().all(|t| !t.is_empty()),
        "plan for {:?} carries no stage-time profile; open-loop serving needs \
         Eq. 10 times",
        plan.network
    );
    let pred_p99 = predict_p99(&stage_times, plan.throughput, rate);
    let tenant = TenantPlan {
        name: plan.network.clone(),
        rate_hz: rate,
        p99_sla_s: p99,
        weight: 1.0,
        seed: pinned_seed,
        predicted_served: rate.min(plan.throughput),
        predicted_p99: pred_p99.is_finite().then_some(pred_p99),
        plan: plan.clone(),
    };
    let mp = MultiPlan {
        platform: plan.platform.clone(),
        big: plan.big,
        small: plan.small,
        weighted_throughput: tenant.predicted_served,
        tenants: vec![tenant],
    };
    print!("{}", plan.summary());
    println!("arrival    : {spec} (open loop, admission cap {})", opts.admission_cap);
    let report = if deploy { mp.deploy(&opts)? } else { mp.simulate(&opts)? };
    println!();
    print!("{}", render_multi_serve(&report));
    write_metrics(args, &report.to_json())
}

/// Deploy knobs shared by every `serve` form.
fn deploy_opts(args: &Args) -> Result<DeployOptions> {
    let opts = DeployOptions {
        images: args.get_usize("images", 60)?,
        queue_cap: args.get_usize("queue-cap", 2)?,
        time_scale: args.get_f64("time-scale", 0.1)?,
        batch: args.get_usize("batch", 1)?,
        seed: args.get_usize("seed", 7)? as u64,
    };
    anyhow::ensure!(opts.images >= 1, "--images must be >= 1");
    anyhow::ensure!(opts.time_scale > 0.0, "--time-scale must be positive");
    Ok(opts)
}

/// Build a [`PlanSpec`] from `plan` subcommand flags and compile it.
/// Every flag is applied to the spec — invalid combinations (e.g.
/// `--artifacts` + `--pipeline`) surface as the facade's compile errors
/// instead of being silently dropped.
fn compile_from_args(args: &Args, cfg: &Config) -> Result<Plan> {
    anyhow::ensure!(
        !(args.has_flag("profile") && args.has_flag("predicted")),
        "--profile and --predicted are mutually exclusive time sources"
    );
    let mut spec = if let Some(dir) = args.get("artifacts") {
        PlanSpec::from_artifacts(dir).stages(args.get_usize("stages", 3)?)
    } else {
        let net = args.get("net").context("plan needs --net N or --artifacts DIR")?;
        PlanSpec::new(net).platform(cfg.clone())
    };
    spec = spec.strategy(strategy_from_args(args)?);
    if args.has_flag("predicted") {
        spec = spec.time_source(TimeSource::Predicted);
    }
    if args.has_flag("profile") {
        spec = spec.time_source(TimeSource::ProfiledArtifacts);
    }
    if let Some(p) = args.get("pipeline") {
        spec = spec.pipeline(p);
    }
    spec.compile()
}

/// `--strategy` plus its parameter flags. Defaults: `--replicas R` implies
/// an exact R-replica fleet, otherwise the paper's single-pipeline DSE.
fn strategy_from_args(args: &Args) -> Result<Strategy> {
    let default = if args.get("replicas").is_some() { "replicated" } else { "pipeline" };
    Ok(match args.get_or("strategy", default) {
        "serial" => Strategy::Serial,
        "pipeline" => Strategy::Pipeline,
        "exhaustive" => Strategy::Exhaustive,
        "replicated" => match args.get("replicas") {
            Some(_) => Strategy::Replicated {
                max_replicas: args.get_usize("replicas", 1)?,
                exact: true,
            },
            None => Strategy::Replicated {
                max_replicas: args.get_usize("max-replicas", 4)?,
                exact: false,
            },
        },
        "energy" => Strategy::Energy {
            min_throughput: args.get_f64("min-throughput", 0.0)?,
            mem_intensity: args.get_f64("mem-intensity", 0.6)?,
        },
        other => anyhow::bail!(
            "unknown strategy {other:?} (serial|pipeline|replicated|exhaustive|energy)"
        ),
    })
}

/// `explore`: the single-pipeline DSE, plus the replicated fleet space
/// with `--replicated` — both as compiled plans.
fn explore(args: &Args, cfg: &Config) -> Result<()> {
    let net = args.get("net").context("--net is required")?;
    let spec = |strategy: Strategy| {
        let s = PlanSpec::new(net).platform(cfg.clone()).strategy(strategy);
        if args.has_flag("predicted") {
            s.time_source(TimeSource::Predicted)
        } else {
            s
        }
    };
    let plan = spec(Strategy::Pipeline).compile()?;
    println!("network    : {}", plan.network);
    print!("{}", plan.design_summary());

    if args.has_flag("replicated") {
        let max_r = args.get_usize("max-replicas", 4)?;
        let fleet =
            spec(Strategy::Replicated { max_replicas: max_r, exact: false }).compile()?;
        println!();
        println!(
            "replicated : {} (R={})",
            fleet.partition_display(),
            fleet.num_replicas()
        );
        for (i, r) in fleet.replicas.iter().enumerate() {
            let budget = format!("{}B+{}s", r.big, r.small);
            println!(
                "  replica {i}: {budget:<6} {}  alloc {}  {:.2} imgs/s",
                r.pipeline,
                fleet.allocation_of(i).display_1based(),
                r.throughput
            );
        }
        println!(
            "aggregate  : {:.2} imgs/s ({:+.1}% vs best single pipeline)",
            fleet.throughput,
            100.0 * (fleet.throughput / plan.throughput - 1.0)
        );
        let sim = fleet.simulate(1000, 2)?;
        println!("simulated  : {:.2} imgs/s (DES, 1000 images)", sim.throughput);
    }
    Ok(())
}

/// `predict`: dump the layer x config time matrix (not a plan — the raw
/// perfmodel view the planner consumes).
fn predict(args: &Args, cfg: &Config) -> Result<()> {
    let name = args.get("net").context("--net is required")?;
    let net = zoo::by_name(name).with_context(|| format!("unknown network {name:?}"))?;
    let model = PerfModel::fit(&cfg.platform);
    let tm = TimeMatrix::predicted(&cfg.platform, &model, &net);
    let mut t = Table::new(
        &format!("{} predicted layer times (ms)", net.name),
        &["layer", "B1", "B2", "B3", "B4", "s1", "s2", "s3", "s4"],
    );
    for (j, name) in tm.layer_names.iter().enumerate() {
        let mut row = vec![name.clone()];
        for ci in 0..tm.configs.len() {
            row.push(f(tm.layer(j, ci) * 1e3, 2));
        }
        t.row(row);
    }
    t.print();
    Ok(())
}

/// `count`: design-space sizes (Eq. 1-2 + the replicated extension).
fn count(args: &Args, cfg: &Config) -> Result<()> {
    let (hb, hs) = (cfg.platform.big.cores, cfg.platform.small.cores);
    println!("pipelines on {}B+{}s: {}", hb, hs, dse::count::total_pipelines(hb, hs));
    let max_r = args.get_usize("max-replicas", 4)?;
    println!(
        "replicated (R<={max_r}): {} core partitions, {} fleet pipelines",
        dse::count::core_partitions(hb, hs, max_r),
        dse::count::replicated_pipelines(hb, hs, max_r)
    );
    let nets = match args.get("net") {
        Some(name) => {
            vec![zoo::by_name(name).with_context(|| format!("unknown network {name:?}"))?]
        }
        None => zoo::all_networks(),
    };
    for net in nets {
        println!(
            "{:<11} W={:<3} design points = {}",
            net.name,
            net.num_layers(),
            dse::count::design_points(net.num_layers(), hb, hs)
        );
    }
    Ok(())
}

/// Simulated-time serving: compile an exact-R replicated plan for the
/// network and deploy it on the REAL thread fleet (shared admission queue,
/// LOW dispatch) with synthetic stages that sleep for the predicted stage
/// service times, scaled by `--time-scale`. Runs in every build — no PJRT
/// required — and prints wall-clock numbers next to the DES prediction.
fn serve_simulated(args: &Args, cfg: &Config, replicas: usize) -> Result<()> {
    anyhow::ensure!(
        !args.has_flag("serial"),
        "--serial applies to --artifacts serving only"
    );
    for key in ["batch", "stages", "seed"] {
        anyhow::ensure!(
            args.get(key).is_none(),
            "--{key} applies to --artifacts serving only"
        );
    }
    let net = args.get("net").context("--net is required")?;
    let opts = deploy_opts(args)?;
    let (hb, hs) = (cfg.platform.big.cores, cfg.platform.small.cores);

    let plan = PlanSpec::new(net)
        .platform(cfg.clone())
        .strategy(Strategy::Replicated { max_replicas: replicas, exact: true })
        .compile()?;
    if args.has_flag("adapt") || args.get("throttle").is_some() {
        return run_adaptive(plan, cfg, args);
    }
    println!(
        "simulated-time serving: {} on {} ({}B+{}s), {} replicas",
        plan.network, cfg.platform.name, hb, hs, replicas
    );
    print!("{}", plan.design_summary());

    let sim = plan.simulate(opts.images, opts.queue_cap)?;
    let rec = trace_recorder(args);
    let report = plan.deploy_recorded(&opts, &rec)?;
    println!();
    print!("{}", render_serve(&report));
    println!(
        "predicted  : {:.2} imgs/s aggregate (DES, unscaled Eq. 10 times)",
        sim.throughput
    );
    write_metrics(args, &report.to_json())?;
    write_trace(args, &rec, "wall")?;
    Ok(())
}

/// Real PJRT serving over AOT artifacts (requires `--features pjrt`).
fn serve_artifacts(args: &Args, replicas: usize) -> Result<()> {
    let dir = args.get("artifacts").context("--artifacts is required")?;
    anyhow::ensure!(
        !args.has_flag("adapt") && args.get("throttle").is_none(),
        "--adapt/--throttle apply to --net or --plan serving (big.LITTLE plans)"
    );
    anyhow::ensure!(
        args.get("arrival").is_none(),
        "--arrival applies to --net or --plan serving (big.LITTLE plans)"
    );
    if args.has_flag("serial") {
        anyhow::ensure!(
            replicas == 1,
            "--serial serves on one thread; it cannot be combined with --replicas {replicas}"
        );
    }
    let strategy = if args.has_flag("serial") {
        Strategy::Serial
    } else if replicas > 1 {
        Strategy::Replicated { max_replicas: replicas, exact: true }
    } else {
        Strategy::Pipeline
    };
    let mut spec = PlanSpec::from_artifacts(dir)
        .stages(args.get_usize("stages", 3)?)
        .strategy(strategy);
    if args.has_flag("profile") {
        spec = spec.time_source(TimeSource::ProfiledArtifacts);
    }
    let plan = spec.compile()?;
    print!("{}", plan.summary());
    let opts = DeployOptions {
        images: args.get_usize("images", 50)?,
        ..deploy_opts(args)?
    };
    let report = plan.deploy(&opts)?;
    print!("{}", render_serve(&report));
    write_metrics(args, &report.to_json())?;
    Ok(())
}
