//! `pipeit` — Pipe-it CLI (L3 leader entrypoint).
//!
//! Subcommands:
//!   tables                         print every paper table/figure (paper-vs-ours)
//!   explore   --net N [--predicted]  run the DSE, print config + allocation
//!   predict   --net N              dump the layer x config time matrix
//!   simulate  --net N --pipeline P [--images I] [--queue-cap C]
//!   count     [--net N]            design-space sizes (Eq. 1-2)
//!   serve     --artifacts DIR [--images I] [--batch B] [--stages K]
//!                                  real PJRT serving over AOT artifacts
//!
//! All simulator-backed subcommands accept `--platform configs/<f>.json`.

use anyhow::{Context, Result};

use pipeit::cnn::zoo;
use pipeit::config::Config;
use pipeit::coordinator;
use pipeit::dse;
use pipeit::perfmodel::{PerfModel, TimeMatrix};
use pipeit::reports::Reporter;
use pipeit::runtime::Manifest;
use pipeit::simulator::pipeline_sim;
use pipeit::util::cli::Args;
use pipeit::util::table::{f, Table};

const USAGE: &str = "\
pipeit — Pipe-it: high-throughput CNN inference on big.LITTLE (TCAD'19 reproduction)

USAGE: pipeit <tables|explore|predict|simulate|count|serve> [options]

  tables     [--platform F]                 regenerate every paper table & figure
  explore    --net N [--predicted] [--platform F]
  predict    --net N [--platform F]         per-layer time matrix (ms)
  simulate   --net N --pipeline B4-s2-s2 [--images 500] [--queue-cap 2]
  count      [--net N]                      design-space sizes (Eq. 1-2)
  serve      --artifacts artifacts/pipenet_tiny [--images 50] [--batch 1]
             [--stages 3] [--queue-cap 2] [--serial] [--seed 7]

networks: alexnet googlenet mobilenet resnet50 squeezenet";

fn net_arg(args: &Args) -> Result<pipeit::cnn::Network> {
    let name = args.get("net").context("--net is required")?;
    zoo::by_name(name).with_context(|| format!("unknown network {name:?}"))
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["predicted", "serial", "measured"]);
    let Some(cmd) = args.positional.first().map(|s| s.as_str()) else {
        println!("{USAGE}");
        return Ok(());
    };
    let cfg = Config::load_or_default(args.get("platform"))?;

    match cmd {
        "tables" => {
            Reporter::new(cfg).print_all();
        }
        "explore" => {
            let net = net_arg(&args)?;
            let (hb, hs) = (cfg.platform.big.cores, cfg.platform.small.cores);
            let tm = if args.has_flag("predicted") {
                let model = PerfModel::fit(&cfg.platform);
                TimeMatrix::predicted(&cfg.platform, &model, &net)
            } else {
                TimeMatrix::measured(&cfg.platform, &net)
            };
            let pt = dse::explore(&tm, hb, hs);
            println!("network    : {}", net.name);
            println!("pipeline   : {}", pt.pipeline);
            println!("allocation : {}", pt.allocation.display_1based());
            println!("throughput : {:.2} imgs/s (Eq. 12)", pt.throughput);
            let times = dse::point_stage_times(&tm, &pt);
            for (i, (s, t)) in pt.pipeline.stages.iter().zip(&times).enumerate() {
                println!("  stage {i}: {s}  {:.1} ms", t * 1e3);
            }
        }
        "predict" => {
            let net = net_arg(&args)?;
            let model = PerfModel::fit(&cfg.platform);
            let tm = TimeMatrix::predicted(&cfg.platform, &model, &net);
            let mut t = Table::new(
                &format!("{} predicted layer times (ms)", net.name),
                &["layer", "B1", "B2", "B3", "B4", "s1", "s2", "s3", "s4"],
            );
            for (j, name) in tm.layer_names.iter().enumerate() {
                let mut row = vec![name.clone()];
                for ci in 0..tm.configs.len() {
                    row.push(f(tm.layer(j, ci) * 1e3, 2));
                }
                t.row(row);
            }
            t.print();
        }
        "simulate" => {
            let net = net_arg(&args)?;
            let spec = args.get("pipeline").context("--pipeline required (e.g. B4-s2-s2)")?;
            let p = dse::PipelineConfig::parse(spec)?;
            anyhow::ensure!(
                p.is_valid(cfg.platform.big.cores, cfg.platform.small.cores),
                "pipeline exceeds platform core budget"
            );
            let tm = TimeMatrix::measured(&cfg.platform, &net);
            let alloc = dse::work_flow(&tm, &p, tm.num_layers());
            let times = dse::stage_times(&tm, &p, &alloc);
            let images = args.get_usize("images", 500)?;
            let cap = args.get_usize("queue-cap", 2)?;
            let sim = pipeline_sim::simulate(&times, images, cap);
            println!("network    : {}", net.name);
            println!("pipeline   : {p}");
            println!("allocation : {}", alloc.display_1based());
            println!(
                "eq12 tp    : {:.2} imgs/s",
                pipeline_sim::steady_state_throughput(&times)
            );
            println!(
                "sim tp     : {:.2} imgs/s over {images} images (cap {cap})",
                sim.throughput
            );
            println!("bottleneck : stage {}", sim.bottleneck);
            for (i, u) in sim.utilization.iter().enumerate() {
                println!("  stage {i} utilization {:.0}%", 100.0 * u);
            }
        }
        "count" => {
            let (hb, hs) = (cfg.platform.big.cores, cfg.platform.small.cores);
            println!(
                "pipelines on {}B+{}s: {}",
                hb,
                hs,
                dse::count::total_pipelines(hb, hs)
            );
            let nets = match args.get("net") {
                Some(_) => vec![net_arg(&args)?],
                None => zoo::all_networks(),
            };
            for net in nets {
                println!(
                    "{:<11} W={:<3} design points = {}",
                    net.name,
                    net.num_layers(),
                    dse::count::design_points(net.num_layers(), hb, hs)
                );
            }
        }
        "serve" => {
            let dir = args.get("artifacts").context("--artifacts DIR required")?;
            let manifest = Manifest::load(std::path::Path::new(dir))?;
            let images = args.get_usize("images", 50)?;
            let batch = args.get_usize("batch", 1)?;
            let cap = args.get_usize("queue-cap", 2)?;
            let stages = args.get_usize("stages", 3)?;
            let seed = args.get_usize("seed", 7)? as u64;
            if args.has_flag("serial") {
                let (_, report) = coordinator::serve_serial(&manifest, images, batch, seed)?;
                println!("serial (kernel-level analogue) on {}:", manifest.name);
                print!("{}", report.render());
            } else {
                let alloc = balance_by_macs(&manifest, stages);
                println!(
                    "pipelined serving on {} with {} stages: {}",
                    manifest.name,
                    alloc.active_stages(),
                    alloc.display_1based()
                );
                let (_, report) =
                    coordinator::serve_pipelined(&manifest, &alloc, images, batch, cap, seed)?;
                print!("{}", report.render());
            }
        }
        other => {
            println!("unknown subcommand {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

/// Balance manifest layers into `k` contiguous stages by MAC count (the
/// host is a symmetric CPU, so MACs are the balancing proxy).
fn balance_by_macs(manifest: &Manifest, k: usize) -> dse::Allocation {
    let w = manifest.num_layers();
    let k = k.clamp(1, w);
    let total: usize = manifest.layers.iter().map(|l| l.macs).sum();
    let target = total as f64 / k as f64;
    let mut ranges = Vec::with_capacity(k);
    let mut lo = 0;
    let mut acc = 0.0;
    for (i, l) in manifest.layers.iter().enumerate() {
        acc += l.macs as f64;
        let stages_left = k - ranges.len();
        let layers_left = w - i - 1;
        if (acc >= target && stages_left > 1 && layers_left >= stages_left - 1)
            || layers_left + 1 == stages_left
        {
            ranges.push((lo, i + 1));
            lo = i + 1;
            acc = 0.0;
        }
    }
    if lo < w {
        ranges.push((lo, w));
    }
    dse::Allocation { ranges }
}
